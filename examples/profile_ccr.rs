//! Distributed profiler demo (§III.B, Fig. 3): why naive per-process
//! profiling overestimates communication time under worker skew, and how
//! timeline alignment fixes it — first on synthetic skewed timelines, then
//! live on the real DP engine over the tiny artifacts.
//!
//!     make artifacts && cargo run --release --example profile_ccr

use covap::covap::interval_from_ccr;
use covap::profiler::synthetic_profile;
use covap::util::bench::Table;
use covap::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    // ---- synthetic: sweep skew ----
    let mut t = Table::new(&["skew", "naive CCR", "aligned CCR", "naive err", "chosen I"]);
    let (comp, comm) = (0.135, 0.280); // ResNet-101's Table I profile
    for skew in [0.0, 0.1, 0.2, 0.4, 0.6] {
        let p = synthetic_profile(8, 12, comp, comm, skew, 99);
        let r = p.ccr();
        t.row(&[
            format!("{:.0}%", skew * 100.0),
            format!("{:.2}", r.naive_ccr),
            format!("{:.2}", r.ccr),
            format!("{:+.0}%", (r.naive_comm_s / comm - 1.0) * 100.0),
            format!("{}", interval_from_ccr(r.ccr)),
        ]);
    }
    t.print("distributed profiler vs naive profiler (synthetic ResNet-101 timeline)");
    println!("\ntrue CCR = {:.2}; the aligned estimate stays put while the naive one", comm / comp);
    println!("inflates with skew — the paper reports up to 20% error (§III.B).");

    // ---- live: profile the real engine ----
    println!("\nlive profile over artifacts/tiny (4 workers, 3 iterations):");
    use covap::compress::SchemeKind;
    use covap::config::RunConfig;
    use covap::coordinator::DpEngine;
    use covap::runtime::{ModelArtifacts, Runtime};
    let cfg = RunConfig {
        workers: 4,
        steps: 3,
        profile_steps: 3,
        scheme: SchemeKind::Baseline,
        ..RunConfig::default()
    };
    let rt = Runtime::cpu()?;
    let arts = ModelArtifacts::load(&rt, &cfg.artifacts)?;
    let mut engine = DpEngine::new(cfg, arts)?;
    for _ in 0..3 {
        engine.step()?;
    }
    let r = engine.profile_report();
    println!("  T_comp         = {}", fmt_secs(r.comp_s));
    println!("  T_comm naive   = {}", fmt_secs(r.naive_comm_s));
    println!("  T_comm aligned = {}", fmt_secs(r.aligned_comm_s));
    println!("  CCR aligned    = {:.3}  ->  interval I = {}", r.ccr, interval_from_ccr(r.ccr));
    println!("  (tiny model on a fast simulated fabric is compute-bound: I = 1, no compression)");
    Ok(())
}
