//! Quickstart: train the tiny transformer with default DDP-Overlapping vs
//! COVAP on 4 simulated workers and compare loss + simulated cluster time.
//!
//!     make artifacts && cargo run --release --example quickstart

use covap::compress::SchemeKind;
use covap::config::RunConfig;
use covap::covap::EfScheduler;
use covap::network::NetworkModel;
use covap::runtime::{ModelArtifacts, Runtime};
use covap::trainer::train_with;
use covap::util::fmt_secs;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let steps = 40;

    let mut results = Vec::new();
    for scheme in [
        SchemeKind::Baseline,
        // constant full error feedback: the ramped scheduler is for long
        // runs on big models; at 40 demo steps it would still be at 0.1
        SchemeKind::Covap { interval: 4, ef: EfScheduler::constant(1.0) },
    ] {
        let cfg = RunConfig {
            workers: 4,
            steps,
            lr: 3e-3,
            scheme: scheme.clone(),
            seed: 7,
            // a slow public-cloud-like fabric so DP is communication-bound
            // (CCR > 1) and compression has something to win
            net: NetworkModel { nic_gbps: 0.2, efficiency: 0.32, latency_s: 100e-6, intra_gbps: 0.2 },
            ..RunConfig::default()
        };
        // fresh artifact bundle per run (compiled executables are cheap to
        // reload for the tiny preset)
        let arts = ModelArtifacts::load(&rt, &cfg.artifacts)?;
        println!("--- {} ---", scheme.label());
        let report = train_with(cfg, arts, true)?;
        let s = report.metrics.summary();
        results.push((scheme.label(), s));
    }

    println!("\n== quickstart summary ({steps} steps, 4 workers) ==");
    println!("{:<10} {:>12} {:>14} {:>16}", "scheme", "final loss", "sim time", "wire traffic");
    for (name, s) in &results {
        println!(
            "{:<10} {:>12.4} {:>14} {:>16}",
            name,
            s.final_loss,
            fmt_secs(s.total_sim_s),
            covap::util::fmt_bytes(s.total_wire_bytes)
        );
    }
    let (base, cov) = (&results[0].1, &results[1].1);
    println!(
        "\nCOVAP: {:.1}% of baseline wire volume, {:.2}x faster simulated cluster time,\n\
         final loss within {:+.3} of baseline.",
        100.0 * cov.total_wire_bytes as f64 / base.total_wire_bytes as f64,
        base.total_sim_s / cov.total_sim_s,
        cov.final_loss - base.final_loss,
    );
    Ok(())
}
