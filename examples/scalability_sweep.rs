//! Fig. 11-style scalability sweep: simulated speedups of all GC schemes
//! across 8/16/32/64-GPU clusters for a chosen workload, plus a
//! collective-topology sweep (ring / hier / tree) with the per-level
//! wire-byte breakdown each hop schedule accounts.
//!
//!     cargo run --release --example scalability_sweep -- [--dnn VGG-19]

use covap::comm::TopologyKind;
use covap::compress::SchemeKind;
use covap::covap::interval_from_ccr;
use covap::harness::{
    allgather_rank_memory, calibrated_profiles, paper_profile, scheme_breakdown,
    scheme_level_bytes,
};
use covap::network::{ClusterSpec, NetworkModel};
use covap::sim::Policy;
use covap::util::bench::Table;
use covap::util::cli::Args;
use covap::util::{fmt_bytes, fmt_secs};
use covap::workload;

const V100_MEM: usize = 16 << 30;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let name = args.get_or("dnn", "VGG-19");
    let w = workload::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown DNN '{name}'"))?;
    let net = NetworkModel::default();
    let clusters = [8usize, 16, 32, 64];

    // Default: replay the paper's measured compression overheads (Table II).
    // --measured: use this build's own compressor timings, GPU-calibrated.
    let measured = args.has("measured");
    let mut table = Table::new(&["scheme", "8 GPUs", "16 GPUs", "32 GPUs", "64 GPUs"]);
    let profiles: Vec<_> = if measured {
        calibrated_profiles(&SchemeKind::evaluation_set(), 1 << 21, 3)
    } else {
        SchemeKind::evaluation_set().into_iter().map(|k| { let p = paper_profile(&k); (k, p) }).collect()
    };
    for (kind, profile) in profiles {
        let mut row = vec![kind.label().to_string()];
        for &gpus in &clusters {
            let cluster = ClusterSpec::ecs(gpus);
            // paper: AllGather-based schemes OOM beyond 16 GPUs on VGG-19
            if allgather_rank_memory(&kind, w.total_params(), gpus) > V100_MEM {
                row.push("OOM".into());
                continue;
            }
            // COVAP adapts its interval to the cluster's CCR (§III.B)
            let kind_here = match &kind {
                SchemeKind::Covap { ef, .. } => SchemeKind::Covap {
                    interval: interval_from_ccr(w.ccr(&net, cluster)),
                    ef: *ef,
                },
                k => k.clone(),
            };
            let b = scheme_breakdown(
                &w,
                &kind_here,
                &profile,
                &net,
                cluster,
                TopologyKind::Auto.resolve(cluster),
                Policy::Overlap,
            );
            row.push(format!("{:.1}x", b.speedup(gpus)));
        }
        table.row(&row);
    }
    let mut linear = vec!["linear scaling".to_string()];
    for &gpus in &clusters {
        linear.push(format!("{gpus}.0x"));
    }
    table.row(&linear);
    table.print(&format!("Fig. 11 — scalability, {} @ 30 Gbps", w.name));
    covap::log_info!(
        target: "example",
        "OOM = AllGather payload exceeds 16 GB V100 memory, matching the paper's \
         exclusion of Top-k/Random-k/DGC/EFsignSGD/Ok-topk beyond 16 GPUs on VGG-19."
    );

    // ---- topology sweep: exposed comm + per-level wire bytes ----------
    // Same workload on the paper's 4x8 cluster under every collective
    // topology: the hierarchy shifts most of the volume from the NIC
    // (inter) onto the PCIe fabric (intra); the tree trades bandwidth for
    // O(log P) rounds (its win is the small-frame sync round).
    let cluster = ClusterSpec::ecs(32);
    let mut tt = Table::new(&[
        "topology", "scheme", "exposed", "speedup", "inter B/step", "intra B/step",
    ]);
    for topo_kind in TopologyKind::all() {
        let topo = topo_kind.resolve(cluster);
        for kind in [
            SchemeKind::Baseline,
            SchemeKind::Fp16,
            SchemeKind::Covap {
                interval: interval_from_ccr(w.ccr(&net, cluster)),
                ef: Default::default(),
            },
        ] {
            let prof = paper_profile(&kind);
            let b = scheme_breakdown(&w, &kind, &prof, &net, cluster, topo, Policy::Overlap);
            let lb = scheme_level_bytes(&w, &kind, topo, cluster);
            tt.row(&[
                topo_kind.spec().to_string(),
                kind.label().to_string(),
                fmt_secs(b.t_comm_exposed_s),
                format!("{:.1}x", b.speedup(cluster.world())),
                fmt_bytes(lb.inter),
                fmt_bytes(lb.intra),
            ]);
        }
    }
    tt.print(&format!("Topologies — {} @ 4x8, per-level wire bytes", w.name));
    Ok(())
}
