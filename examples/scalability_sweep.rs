//! Fig. 11-style scalability sweep: simulated speedups of all GC schemes
//! across 8/16/32/64-GPU clusters for a chosen workload.
//!
//!     cargo run --release --example scalability_sweep -- [--dnn VGG-19]

use covap::compress::SchemeKind;
use covap::covap::interval_from_ccr;
use covap::harness::{allgather_rank_memory, calibrated_profiles, paper_profile, scheme_breakdown};
use covap::network::{ClusterSpec, NetworkModel};
use covap::sim::Policy;
use covap::util::bench::Table;
use covap::util::cli::Args;
use covap::workload;

const V100_MEM: usize = 16 << 30;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let name = args.get_or("dnn", "VGG-19");
    let w = workload::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown DNN '{name}'"))?;
    let net = NetworkModel::default();
    let clusters = [8usize, 16, 32, 64];

    // Default: replay the paper's measured compression overheads (Table II).
    // --measured: use this build's own compressor timings, GPU-calibrated.
    let measured = args.has("measured");
    let mut table = Table::new(&["scheme", "8 GPUs", "16 GPUs", "32 GPUs", "64 GPUs"]);
    let profiles: Vec<_> = if measured {
        calibrated_profiles(&SchemeKind::evaluation_set(), 1 << 21, 3)
    } else {
        SchemeKind::evaluation_set().into_iter().map(|k| { let p = paper_profile(&k); (k, p) }).collect()
    };
    for (kind, profile) in profiles {
        let mut row = vec![kind.label().to_string()];
        for &gpus in &clusters {
            let cluster = ClusterSpec::ecs(gpus);
            // paper: AllGather-based schemes OOM beyond 16 GPUs on VGG-19
            if allgather_rank_memory(&kind, w.total_params(), gpus) > V100_MEM {
                row.push("OOM".into());
                continue;
            }
            // COVAP adapts its interval to the cluster's CCR (§III.B)
            let kind_here = match &kind {
                SchemeKind::Covap { ef, .. } => SchemeKind::Covap {
                    interval: interval_from_ccr(w.ccr(&net, cluster)),
                    ef: *ef,
                },
                k => k.clone(),
            };
            let b = scheme_breakdown(&w, &kind_here, &profile, &net, cluster, Policy::Overlap);
            row.push(format!("{:.1}x", b.speedup(gpus)));
        }
        table.row(&row);
    }
    let mut linear = vec!["linear scaling".to_string()];
    for &gpus in &clusters {
        linear.push(format!("{gpus}.0x"));
    }
    table.row(&linear);
    table.print(&format!("Fig. 11 — scalability, {} @ 30 Gbps", w.name));
    println!("\n(OOM = AllGather payload exceeds 16 GB V100 memory, matching the paper's\n exclusion of Top-k/Random-k/DGC/EFsignSGD/Ok-topk beyond 16 GPUs on VGG-19.)");
    Ok(())
}
