//! End-to-end validation driver (EXPERIMENTS.md §E2E): train the `small`
//! preset transformer LM (~4.3M params) with P simulated DP workers on the
//! synthetic Markov-Zipf corpus, through the full three-layer stack:
//! rust coordinator -> PJRT CPU -> AOT HLO (JAX model + Pallas attention).
//!
//!     make artifacts
//!     cargo run --release --example train_transformer -- \
//!         [--scheme covap|baseline|fp16|...] [--workers 4] [--steps 150]
//!         [--preset small] [--adaptive] [--csv PATH] [--compute-scale F]
//!
//! Logs the loss curve to CSV and prints the simulated-cluster speedup.

use std::path::PathBuf;

use covap::compress::SchemeKind;
use covap::config::RunConfig;
use covap::covap::EfScheduler;
use covap::runtime::{ModelArtifacts, Runtime};
use covap::trainer::train_with;
use covap::util::cli::Args;
use covap::util::{fmt_bytes, fmt_secs};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let preset = args.get_or("preset", "small");
    let workers: usize = args.get_parsed("workers", 4)?;
    let steps: u64 = args.get_parsed("steps", 150)?;
    let mut scheme = SchemeKind::paper_default(&args.get_or("scheme", "covap"))
        .ok_or_else(|| anyhow::anyhow!("unknown scheme"))?;
    // The paper's EF scheduler plateaus (100 steps) suit multi-epoch runs;
    // scale the ramp so compensation saturates by ~half this run.
    if let SchemeKind::Covap { interval, .. } = scheme {
        scheme = SchemeKind::Covap {
            interval,
            ef: EfScheduler {
                init_value: 0.3,
                ascend_steps: (steps / 14).max(1),
                ascend_range: 0.1,
            },
        };
    }
    let csv = args.get_or("csv", &format!("train_{}_{}.csv", preset, scheme.label()));

    let mut cfg = RunConfig {
        artifacts: PathBuf::from(format!("artifacts/{preset}")),
        workers,
        cluster: covap::config::default_cluster(workers),
        steps,
        lr: args.get_parsed("lr", 1e-3f32)?,
        scheme,
        seed: args.get_parsed("seed", 42u64)?,
        metrics_csv: Some(PathBuf::from(&csv)),
        // 1-core-CPU step -> simulated-V100 step (see EXPERIMENTS.md
        // "Calibration"); 0.01 puts the small preset in the paper's CCR
        // regime on the default 30 Gbps fabric.
        compute_scale: args.get_parsed("compute-scale", 0.01f64)?,
        // 2 MiB buckets: the small model is 16.6 MiB; the paper-default
        // 25 MiB cap would leave a single bucket and nothing to overlap
        bucket_bytes: (args.get_parsed("bucket-mb", 2.0f64)? * 1024.0 * 1024.0) as usize,
        ..RunConfig::default()
    };
    if args.has("adaptive") {
        // closed-loop adaptive mode: profile the first steps, switch to
        // COVAP with I = ceil(CCR), keep re-profiling in windows. Only
        // covap@auto re-shards — any other requested scheme keeps running.
        cfg.profile_steps = 3;
        cfg.scheme = match cfg.scheme.clone() {
            SchemeKind::Covap { ef, .. } | SchemeKind::CovapAuto { ef } => {
                SchemeKind::CovapAuto { ef }
            }
            other => other,
        };
    }

    println!(
        "e2e train: preset={preset} workers={workers} steps={steps} scheme={} cluster={}x{}",
        cfg.scheme.label(),
        cfg.cluster.nodes,
        cfg.cluster.gpus_per_node
    );
    let rt = Runtime::cpu()?;
    let arts = ModelArtifacts::load(&rt, &cfg.artifacts)?;
    println!(
        "model: {} params ({})",
        arts.manifest.param_count,
        fmt_bytes(arts.manifest.param_bytes())
    );

    let t0 = std::time::Instant::now();
    let report = train_with(cfg, arts, true)?;
    let s = report.metrics.summary();

    println!("\n== e2e summary ==");
    println!("steps             : {}", s.steps);
    println!("first loss        : {:.4}", report.metrics.records.first().map(|r| r.loss).unwrap_or(f32::NAN));
    println!("final loss        : {:.4}", s.final_loss);
    println!("mean loss last 10 : {:.4}", s.mean_loss_last10);
    println!("sim cluster time  : {}", fmt_secs(s.total_sim_s));
    println!("wall time         : {}", fmt_secs(t0.elapsed().as_secs_f64()));
    println!("wire traffic/rank : {}", fmt_bytes(s.total_wire_bytes));
    println!("mean speedup      : {:.2}x of {} linear", report.mean_speedup, workers);
    if let Some(i) = report.chosen_interval {
        println!("adaptive interval : {i}");
    }
    println!("loss curve        : {csv}");
    Ok(())
}
