"""AOT compile path: lower the L2/L1 computations to HLO *text* artifacts.

Runs ONCE at build time (`make artifacts`); the rust binary is self-contained
afterwards. Interchange format is HLO text, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per preset, emits into artifacts/<preset>/:
    fwd_bwd.hlo.txt      (params f32[N], tokens i32[B,T+1]) -> (loss f32[], grads f32[N])
    sgd_update.hlo.txt   (params, grads, lr f32[])          -> (params',)
    adam_update.hlo.txt  (params, m, v, grads, step i32[], lr f32[]) -> (params', m', v')
    ef_compress.hlo.txt  (g f32[EB], r f32[EB], coeff f32[], keep f32[]) -> (out, new_r)
    quantize.hlo.txt     (x f32[EB]) -> (x_q,)
    manifest.json        model config + flat layer table + artifact signatures
"""

import argparse
import dataclasses
import functools
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ef_compress, quantize_fp16

# Canonical bucket length (elements) for the standalone compression
# artifacts. The rust runtime pads real buckets up to this size when routing
# compression through XLA instead of the native hot path.
EF_BLOCK = 1 << 20

PRESETS = {
    # ~92k params — unit/integration tests; compiles in seconds.
    "tiny": M.ModelConfig(
        vocab=256, d_model=64, n_heads=2, n_layers=2, d_ff=128,
        seq_len=64, batch=2,
    ),
    # ~4.3M params — the end-to-end training example (examples/train_transformer).
    "small": M.ModelConfig(
        vocab=4096, d_model=256, n_heads=8, n_layers=4, d_ff=1024,
        seq_len=128, batch=4,
    ),
    # ~26M params — heavier runs / perf measurements.
    "base": M.ModelConfig(
        vocab=8192, d_model=512, n_heads=8, n_layers=8, d_ff=2048,
        seq_len=256, batch=4,
    ),
    # ~124M params — GPT-2-small scale; compile-only target on this testbed.
    "gpt2s": M.ModelConfig(
        vocab=32768, d_model=768, n_heads=12, n_layers=12, d_ff=3072,
        seq_len=512, batch=4,
    ),
}


def to_hlo_text(lowered) -> str:
    """jax.jit(...).lower(...) -> XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_all(cfg: M.ModelConfig):
    """Return {artifact_name: (hlo_text, signature_doc)}."""
    n = M.param_count(cfg)
    pv = _spec((n,))
    tokens = _spec((cfg.batch, cfg.seq_len + 1), jnp.int32)
    scalar_f = _spec(())
    scalar_i = _spec((), jnp.int32)
    eb = _spec((EF_BLOCK,))

    def fwd_bwd(params, toks):
        return M.fwd_bwd(cfg, params, toks)

    def sgd(params, grads, lr):
        return (M.sgd_update(params, grads, lr),)

    def adam(params, m, v, grads, step, lr):
        return M.adam_update(params, m, v, grads, step, lr)

    def ef(g, r, coeff, keep):
        return ef_compress(g, r, coeff, keep)

    def quant(x):
        return (quantize_fp16(x),)

    arts = {}
    arts["fwd_bwd"] = (
        jax.jit(fwd_bwd).lower(pv, tokens),
        {
            "inputs": [f"params f32[{n}]", f"tokens i32[{cfg.batch},{cfg.seq_len + 1}]"],
            "outputs": ["loss f32[]", f"grads f32[{n}]"],
        },
    )
    arts["sgd_update"] = (
        jax.jit(sgd).lower(pv, pv, scalar_f),
        {
            "inputs": [f"params f32[{n}]", f"grads f32[{n}]", "lr f32[]"],
            "outputs": [f"params f32[{n}]"],
        },
    )
    arts["adam_update"] = (
        jax.jit(adam).lower(pv, pv, pv, pv, scalar_i, scalar_f),
        {
            "inputs": [
                f"params f32[{n}]", f"m f32[{n}]", f"v f32[{n}]",
                f"grads f32[{n}]", "step i32[]", "lr f32[]",
            ],
            "outputs": [f"params f32[{n}]", f"m f32[{n}]", f"v f32[{n}]"],
        },
    )
    arts["ef_compress"] = (
        jax.jit(ef).lower(eb, eb, scalar_f, scalar_f),
        {
            "inputs": [
                f"g f32[{EF_BLOCK}]", f"r f32[{EF_BLOCK}]",
                "coeff f32[]", "keep f32[]",
            ],
            "outputs": [f"out f32[{EF_BLOCK}]", f"new_r f32[{EF_BLOCK}]"],
        },
    )
    arts["quantize"] = (
        jax.jit(quant).lower(eb),
        {
            "inputs": [f"x f32[{EF_BLOCK}]"],
            "outputs": [f"x_q f32[{EF_BLOCK}]"],
        },
    )
    return {k: (to_hlo_text(low), sig) for k, (low, sig) in arts.items()}


def build_manifest(preset: str, cfg: M.ModelConfig, sigs) -> dict:
    return {
        "preset": preset,
        "config": dataclasses.asdict(cfg),
        "param_count": M.param_count(cfg),
        "ef_block": EF_BLOCK,
        "params": [
            {
                "name": name,
                "offset": off,
                "numel": int(math.prod(shape)),
                "shape": list(shape),
            }
            for name, off, shape in M.param_table(cfg)
        ],
        "artifacts": {
            name: {"file": f"{name}.hlo.txt", **sig}
            for name, sig in sigs.items()
        },
    }


def emit(preset: str, out_dir: str) -> None:
    cfg = PRESETS[preset]
    os.makedirs(out_dir, exist_ok=True)
    arts = lower_all(cfg)
    for name, (text, _sig) in arts.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"  {path}: {len(text)} chars")
    manifest = build_manifest(preset, cfg, {k: s for k, (_t, s) in arts.items()})
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {out_dir}/manifest.json: {manifest['param_count']} params")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", action="append", default=None,
                    help=f"one of {list(PRESETS)}; repeatable")
    ap.add_argument("--out-root", default="../artifacts")
    args = ap.parse_args()
    presets = args.preset or ["tiny", "small"]
    for p in presets:
        print(f"[aot] preset={p}")
        emit(p, os.path.join(args.out_root, p))


if __name__ == "__main__":
    main()
