"""L1: Pallas kernels for the paper's compute hot spots + pure-jnp oracles."""

from .attention import attention
from .ef_compress import ef_compress
from .quantize import quantize_fp16

__all__ = ["attention", "ef_compress", "quantize_fp16"]
