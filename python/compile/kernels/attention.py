"""L1 Pallas kernel: causal self-attention tile kernel (model hot spot).

Flash-attention-style tiling rethought for TPU (DESIGN.md Hardware-Adaptation):
instead of CUDA threadblocks + shared memory, the grid iterates (batch*heads,
q-blocks) and BlockSpec stages a q tile plus the full K/V stripes of that head
through VMEM. For the sequence lengths this model targets (T <= 512, dh <= 128)
K and V stripes are T*dh*4 B <= 256 KiB each — comfortably VMEM-resident, so a
single-pass stable softmax beats the online two-pass variant (no rescaling
traffic). The matmuls q@K^T and p@V are MXU work (128-lane friendly dh).

interpret=True for CPU-PJRT executability (see ef_compress.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = jnp.finfo(jnp.float32).min


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq, t, scale, causal):
    iq = pl.program_id(1)
    q = q_ref[0, :, :]  # [bq, dh]
    k = k_ref[0, :, :]  # [t, dh]
    v = v_ref[0, :, :]  # [t, dh]
    s = jnp.dot(q, k.T) * scale  # [bq, t] — MXU
    if causal:
        rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, t), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bq, t), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, :, :] = jnp.dot(p, v)  # MXU


def _attention_fwd_pallas(q, k, v, bq, causal):
    bh, t, dh = q.shape
    if bq is None:
        bq = min(t, 128)
    if t % bq != 0:
        raise ValueError(f"T={t} must be a multiple of bq={bq}")
    scale = 1.0 / (dh**0.5)
    kernel = functools.partial(
        _kernel, bq=bq, t=t, scale=scale, causal=causal
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, t // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dh), jnp.float32),
        interpret=True,
    )(q, k, v)


def _probs(q, k, causal):
    """Softmax attention probabilities (shared by the analytic backward)."""
    dh = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        t = q.shape[1]
        rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
        s = jnp.where((cols <= rows)[None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    return p / jnp.sum(p, axis=-1, keepdims=True)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _attention(q, k, v, bq, causal):
    return _attention_fwd_pallas(q, k, v, bq, causal)


def _attention_vjp_fwd(q, k, v, bq, causal):
    return _attention_fwd_pallas(q, k, v, bq, causal), (q, k, v)


def _attention_vjp_bwd(bq, causal, res, do):
    # Flash-attention-style backward: recompute p from (q, k) instead of
    # saving the [T, T] probability matrix. Pallas JVP rules cannot
    # differentiate through program_id, hence the analytic path here; it is
    # the exact gradient of the forward kernel's math.
    q, k, v = res
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    p = _probs(q, k, causal)
    dv = jnp.einsum("bts,btd->bsd", p, do)
    dp = jnp.einsum("btd,bsd->bts", do, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bts,bsd->btd", ds, k) * scale
    dk = jnp.einsum("bts,btd->bsd", ds, q) * scale
    return dq, dk, dv


_attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("bq", "causal"))
def attention(q, k, v, *, bq=None, causal=True):
    """Causal SDPA. q, k, v: f32[BH, T, dh] -> f32[BH, T, dh].

    Forward runs the Pallas tile kernel; backward is the analytic
    recompute-from-(q,k) gradient (see _attention_vjp_bwd). bq: q-tile rows
    per grid step (defaults to min(T, 128), the MXU-native tile height); T
    must be a multiple of bq.
    """
    t = q.shape[1]
    if bq is None:
        bq = min(t, 128)
    return _attention(q, k, v, bq, causal)
