"""L1 Pallas kernel: fused error-feedback compress (COVAP hot spot).

The per-bucket compression step of COVAP is a streaming elementwise op:

    acc   = g + coeff * r
    out   = keep ? acc : 0
    new_r = keep ? 0   : acc

On TPU this is HBM-bandwidth bound (no MXU work). The BlockSpec streams
`block` elements of g and r through VMEM per grid step; with f32 inputs the
VMEM working set is 4 buffers * block * 4 B. The default block of 64 Ki
elements uses 1 MiB — small enough for double buffering in a 16 MiB VMEM
(see DESIGN.md section Hardware-Adaptation).

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute; the interpret path lowers to plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64 * 1024


def _kernel(coeff_ref, keep_ref, g_ref, r_ref, out_ref, newr_ref):
    coeff = coeff_ref[0]
    keep = keep_ref[0]
    acc = g_ref[...] + coeff * r_ref[...]
    out_ref[...] = acc * keep
    newr_ref[...] = acc * (1.0 - keep)


@functools.partial(jax.jit, static_argnames=("block",))
def ef_compress(g, r, coeff, keep, *, block=DEFAULT_BLOCK):
    """Fused EF compress over one bucket.

    Args:
      g, r:  f32[n] with n a multiple of `block` (callers pad; the rust
             runtime pads buckets to the artifact's canonical size).
      coeff: f32 scalar (compensation coefficient).
      keep:  f32 scalar (1.0 transmit, 0.0 drop) — scalar, not per-element:
             COVAP's filter granularity is the whole bucket.
      block: VMEM tile size in elements.
    Returns (out, new_r): f32[n] each.
    """
    n = g.shape[0]
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    coeff = jnp.asarray(coeff, jnp.float32).reshape((1,))
    keep = jnp.asarray(keep, jnp.float32).reshape((1,))
    grid = (n // block,)
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    vec_spec = pl.BlockSpec((block,), lambda i: (i,))
    out, new_r = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, vec_spec, vec_spec],
        out_specs=[vec_spec, vec_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(coeff, keep, g, r)
    return out, new_r
