"""L1 Pallas kernel: FP16 quantize/dequantize baseline.

The FP16 baseline scheme in the paper halves communication volume by casting
gradients to half precision before AllReduce. The round-trip cast models the
quantization error on the training path (the rust coordinator performs the
actual byte-halving on its simulated wire).

Streaming elementwise, HBM-bound; same VMEM tiling story as ef_compress.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 64 * 1024


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.float16).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def quantize_fp16(x, *, block=DEFAULT_BLOCK):
    """Round-trip f32 -> f16 -> f32 over a flat vector (n % block == 0)."""
    n = x.shape[0]
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x)
