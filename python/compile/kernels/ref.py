"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy only. pytest (python/tests/test_kernel.py)
asserts allclose between kernel and oracle across shape/dtype sweeps.
"""

import jax
import jax.numpy as jnp


def ef_compress_ref(g, r, coeff, keep):
    """Error-feedback compress for one communication bucket.

    acc   = g + coeff * r          (residual re-injection, scheduled coeff)
    out   = acc  if keep else 0    (COVAP coarse filter: whole-bucket keep/drop)
    new_r = 0    if keep else acc  (residual accumulation for dropped buckets)

    Args:
      g:     f32[n] local gradient of the bucket.
      r:     f32[n] residual carried from previous iterations.
      coeff: scalar f32 compensation coefficient in [0, 1].
      keep:  scalar f32, 1.0 transmit / 0.0 drop.
    Returns (out, new_r), both f32[n].
    """
    acc = g + coeff * r
    out = acc * keep
    new_r = acc * (1.0 - keep)
    return out, new_r


def quantize_fp16_ref(x):
    """FP16 quantization baseline: round-trip f32 -> f16 -> f32."""
    return x.astype(jnp.float16).astype(jnp.float32)


def attention_ref(q, k, v, causal=True):
    """Reference scaled-dot-product attention.

    q, k, v: f32[B*H, T, dh]. Returns f32[B*H, T, dh].
    """
    dh = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        t = q.shape[1]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask[None, :, :], s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v)
