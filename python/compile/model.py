"""L2: decoder-only transformer LM over a FLAT f32 parameter vector.

The whole model lives in a single f32[N] vector. The flat layout is the
contract with the rust coordinator (L3): gradients come back as f32[N] and
rust builds DDP communication buckets as (offset, len) slices using the
per-parameter layer table exported in artifacts/manifest.json — exactly the
paper's bucket model (PyTorch DDP allocates whole parameter tensors into
fixed-size buckets).

Layout (offsets in manifest.json):
    tok_embed [V, D]          (tied LM head)
    pos_embed [T, D]
    per block l in 0..L (contiguous, layer-major):
        ln1_scale [D], ln1_bias [D]
        w_qkv [D, 3D], b_qkv [3D]
        w_o [D, D],    b_o [D]
        ln2_scale [D], ln2_bias [D]
        w_fc1 [D, F],  b_fc1 [F]
        w_fc2 [F, D],  b_fc2 [D]
    lnf_scale [D], lnf_bias [D]

Attention uses the L1 Pallas kernel (kernels.attention), so the kernel
lowers into the same HLO artifact the rust runtime executes.
"""

import dataclasses
import functools
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    d_model: int = 64
    n_heads: int = 2
    n_layers: int = 2
    d_ff: int = 128
    seq_len: int = 64
    batch: int = 2  # per-worker micro-batch

    def __post_init__(self):
        assert self.d_model % self.n_heads == 0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


def _numel(shape) -> int:
    return int(math.prod(shape))


def block_param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) of each parameter tensor inside one transformer block."""
    d, f = cfg.d_model, cfg.d_ff
    return [
        ("ln1_scale", (d,)),
        ("ln1_bias", (d,)),
        ("w_qkv", (d, 3 * d)),
        ("b_qkv", (3 * d,)),
        ("w_o", (d, d)),
        ("b_o", (d,)),
        ("ln2_scale", (d,)),
        ("ln2_bias", (d,)),
        ("w_fc1", (d, f)),
        ("b_fc1", (f,)),
        ("w_fc2", (f, d)),
        ("b_fc2", (d,)),
    ]


def param_table(cfg: ModelConfig) -> List[Tuple[str, int, Tuple[int, ...]]]:
    """Full layer table: (name, offset, shape) for every parameter tensor.

    This is the source of truth for manifest.json and for the rust
    bucketizer; order == memory order in the flat vector.
    """
    table = []
    off = 0

    def add(name, shape):
        nonlocal off
        table.append((name, off, shape))
        off += _numel(shape)

    add("tok_embed", (cfg.vocab, cfg.d_model))
    add("pos_embed", (cfg.seq_len, cfg.d_model))
    for l in range(cfg.n_layers):
        for name, shape in block_param_specs(cfg):
            add(f"h{l}.{name}", shape)
    add("lnf_scale", (cfg.d_model,))
    add("lnf_bias", (cfg.d_model,))
    return table


def param_count(cfg: ModelConfig) -> int:
    name, off, shape = param_table(cfg)[-1]
    return off + _numel(shape)


def block_numel(cfg: ModelConfig) -> int:
    return sum(_numel(s) for _, s in block_param_specs(cfg))


def _layernorm(x, scale, bias):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _split_block(cfg: ModelConfig, flat):
    """flat f32[block_numel] -> dict of this block's parameter tensors."""
    out = {}
    off = 0
    for name, shape in block_param_specs(cfg):
        n = _numel(shape)
        out[name] = flat[off : off + n].reshape(shape)
        off += n
    return out


def _block_fwd(cfg: ModelConfig, x, flat_block):
    """One pre-LN transformer block. x: f32[B, T, D]."""
    p = _split_block(cfg, flat_block)
    b, t, d = x.shape
    h = _layernorm(x, p["ln1_scale"], p["ln1_bias"])
    qkv = h @ p["w_qkv"] + p["b_qkv"]  # [B, T, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):  # [B, T, D] -> [B*H, T, dh]
        z = z.reshape(b, t, cfg.n_heads, cfg.d_head)
        return z.transpose(0, 2, 1, 3).reshape(b * cfg.n_heads, t, cfg.d_head)

    o = attention(heads(q), heads(k), heads(v), causal=True)
    o = (
        o.reshape(b, cfg.n_heads, t, cfg.d_head)
        .transpose(0, 2, 1, 3)
        .reshape(b, t, d)
    )
    x = x + o @ p["w_o"] + p["b_o"]
    h = _layernorm(x, p["ln2_scale"], p["ln2_bias"])
    h = jax.nn.gelu(h @ p["w_fc1"] + p["b_fc1"]) @ p["w_fc2"] + p["b_fc2"]
    return x + h


def forward(cfg: ModelConfig, params, tokens):
    """Next-token logits. params: f32[N]; tokens: i32[B, T] -> f32[B, T, V]."""
    d = cfg.d_model
    tok_embed = params[: cfg.vocab * d].reshape(cfg.vocab, d)
    off = cfg.vocab * d
    pos_embed = params[off : off + cfg.seq_len * d].reshape(cfg.seq_len, d)
    off += cfg.seq_len * d
    bn = block_numel(cfg)
    blocks = params[off : off + cfg.n_layers * bn].reshape(cfg.n_layers, bn)
    off += cfg.n_layers * bn
    lnf_scale = params[off : off + d]
    lnf_bias = params[off + d : off + 2 * d]

    t = tokens.shape[1]
    x = tok_embed[tokens] + pos_embed[:t]

    def body(x, flat_block):
        return _block_fwd(cfg, x, flat_block), None

    x, _ = jax.lax.scan(body, x, blocks)
    x = _layernorm(x, lnf_scale, lnf_bias)
    return x @ tok_embed.T  # tied head


def loss_fn(cfg: ModelConfig, params, tokens):
    """Mean next-token cross entropy. tokens: i32[B, T+1]."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def fwd_bwd(cfg: ModelConfig, params, tokens):
    """(loss f32[], grads f32[N]) — the per-worker step the rust DP loop runs."""
    return jax.value_and_grad(functools.partial(loss_fn, cfg))(params, tokens)


def sgd_update(params, grads, lr):
    """params' = params - lr * grads (lr: f32[] runtime scalar)."""
    return params - lr * grads


def adam_update(params, m, v, grads, step, lr, beta1=0.9, beta2=0.999, eps=1e-8):
    """Adam over flat vectors. step: i32[] (1-based); returns (params', m', v')."""
    step_f = step.astype(jnp.float32)
    m = beta1 * m + (1.0 - beta1) * grads
    v = beta2 * v + (1.0 - beta2) * grads * grads
    mhat = m / (1.0 - beta1**step_f)
    vhat = v / (1.0 - beta2**step_f)
    return params - lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def init_params(cfg: ModelConfig, key) -> jnp.ndarray:
    """Reference initializer (tests / python-side experiments).

    The rust coordinator performs the same scheme natively from the
    manifest layer table: N(0, 0.02) for matrices/embeddings, zeros for
    biases, ones for layernorm scales.
    """
    parts = []
    for name, off, shape in param_table(cfg):
        key, sub = jax.random.split(key)
        n = _numel(shape)
        base = name.split(".")[-1]
        if base.endswith("_scale"):
            parts.append(jnp.ones((n,), jnp.float32))
        elif base.endswith("_bias") or base.startswith("b_"):
            parts.append(jnp.zeros((n,), jnp.float32))
        else:
            parts.append(0.02 * jax.random.normal(sub, (n,), jnp.float32))
    return jnp.concatenate(parts)
