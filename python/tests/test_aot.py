"""AOT path: HLO text is emitted, parseable-looking, and manifest-consistent."""

import json
import math
import os

import pytest

from compile import aot
from compile import model as M

CFG = aot.PRESETS["tiny"]


@pytest.fixture(scope="module")
def arts():
    return aot.lower_all(CFG)


class TestLowering:
    def test_all_artifacts_emitted(self, arts):
        assert set(arts) == {
            "fwd_bwd", "sgd_update", "adam_update", "ef_compress", "quantize"
        }

    def test_hlo_text_looks_like_hlo(self, arts):
        for name, (text, _sig) in arts.items():
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_fwd_bwd_signature_shapes(self, arts):
        text, sig = arts["fwd_bwd"]
        n = M.param_count(CFG)
        assert f"f32[{n}]" in text
        assert f"grads f32[{n}]" in sig["outputs"][1]

    def test_no_custom_calls(self, arts):
        """interpret=True pallas must lower to plain HLO (no Mosaic
        custom-calls the CPU PJRT client cannot execute)."""
        for name, (text, _sig) in arts.items():
            assert "custom-call" not in text.lower(), name


class TestManifest:
    def test_roundtrip(self, tmp_path, arts):
        manifest = aot.build_manifest(
            "tiny", CFG, {k: s for k, (_t, s) in arts.items()}
        )
        p = tmp_path / "manifest.json"
        p.write_text(json.dumps(manifest))
        m = json.loads(p.read_text())
        assert m["param_count"] == M.param_count(CFG)
        # contiguity: params tile the flat vector exactly
        off = 0
        for e in m["params"]:
            assert e["offset"] == off
            assert e["numel"] == math.prod(e["shape"])
            off += e["numel"]
        assert off == m["param_count"]

    def test_ef_block_is_kernel_aligned(self):
        from compile.kernels.ef_compress import DEFAULT_BLOCK

        assert aot.EF_BLOCK % DEFAULT_BLOCK == 0
