"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes/dtypes per the repo's testing contract; each kernel
also gets targeted edge-case tests (zero inputs, keep/drop, extreme values).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ef_compress, quantize_fp16
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- ef_compress
class TestEfCompress:
    @given(
        blocks=st.integers(1, 4),
        block_log2=st.integers(8, 12),
        coeff=st.floats(0.0, 1.0),
        keep=st.sampled_from([0.0, 1.0]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, blocks, block_log2, coeff, keep, seed):
        block = 1 << block_log2
        n = blocks * block
        g = _rand(seed, (n,))
        r = _rand(seed + 1, (n,))
        out, new_r = ef_compress(g, r, coeff, keep, block=block)
        eout, enew_r = ref.ef_compress_ref(g, r, coeff, keep)
        # atol floor covers fused-multiply-add reassociation in the kernel.
        np.testing.assert_allclose(out, eout, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(new_r, enew_r, rtol=1e-5, atol=1e-6)

    def test_keep_transmits_everything(self):
        g, r = _rand(0, (1024,)), _rand(1, (1024,))
        out, new_r = ef_compress(g, r, 1.0, 1.0, block=256)
        np.testing.assert_allclose(out, g + r, rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(new_r), 0.0)

    def test_drop_accumulates_residual(self):
        g, r = _rand(0, (1024,)), _rand(1, (1024,))
        out, new_r = ef_compress(g, r, 1.0, 0.0, block=256)
        np.testing.assert_array_equal(np.asarray(out), 0.0)
        np.testing.assert_allclose(new_r, g + r, rtol=1e-6)

    def test_mass_conservation(self):
        """out + new_r == g + coeff*r regardless of keep — EF never loses mass."""
        g, r = _rand(2, (2048,)), _rand(3, (2048,))
        for keep in (0.0, 1.0):
            out, new_r = ef_compress(g, r, 0.37, keep, block=512)
            np.testing.assert_allclose(
                np.asarray(out) + np.asarray(new_r),
                np.asarray(g + 0.37 * r),
                rtol=1e-6, atol=1e-7,
            )

    def test_rejects_misaligned(self):
        with pytest.raises(ValueError):
            ef_compress(jnp.zeros(100), jnp.zeros(100), 1.0, 1.0, block=64)


# ------------------------------------------------------------------- quantize
class TestQuantize:
    @given(
        blocks=st.integers(1, 4),
        block_log2=st.integers(8, 12),
        scale=st.floats(1e-3, 1e3),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, blocks, block_log2, scale, seed):
        block = 1 << block_log2
        x = _rand(seed, (blocks * block,), scale)
        np.testing.assert_array_equal(
            np.asarray(quantize_fp16(x, block=block)),
            np.asarray(ref.quantize_fp16_ref(x)),
        )

    def test_overflow_saturates_like_f16(self):
        x = jnp.full((256,), 1e38, jnp.float32)
        got = np.asarray(quantize_fp16(x, block=256))
        want = np.asarray(ref.quantize_fp16_ref(x))
        np.testing.assert_array_equal(got, want)

    def test_exact_on_representable(self):
        x = jnp.arange(256, dtype=jnp.float32)  # small ints are f16-exact
        np.testing.assert_array_equal(np.asarray(quantize_fp16(x, block=256)), np.asarray(x))


# ------------------------------------------------------------------ attention
class TestAttention:
    @given(
        bh=st.integers(1, 4),
        t_log2=st.integers(4, 7),
        dh=st.sampled_from([8, 16, 32, 64]),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, bh, t_log2, dh, causal, seed):
        t = 1 << t_log2
        q = _rand(seed, (bh, t, dh))
        k = _rand(seed + 1, (bh, t, dh))
        v = _rand(seed + 2, (bh, t, dh))
        got = attention(q, k, v, bq=min(t, 32), causal=causal)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_tile_boundary_invariance(self):
        """Output must not depend on the q-tile size."""
        q, k, v = (_rand(i, (2, 64, 16)) for i in range(3))
        a = attention(q, k, v, bq=16)
        b = attention(q, k, v, bq=64)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_causal_first_row_is_v0(self):
        """Row 0 of causal attention can only attend to position 0."""
        q, k, v = (_rand(i, (1, 32, 8)) for i in range(3))
        out = attention(q, k, v, bq=8, causal=True)
        np.testing.assert_allclose(out[0, 0], v[0, 0], rtol=1e-5, atol=1e-6)

    def test_gradients_match_ref(self):
        q, k, v = (_rand(i, (2, 32, 16)) for i in range(3))

        def f_kernel(q, k, v):
            return jnp.sum(attention(q, k, v, bq=8) ** 2)

        def f_ref(q, k, v):
            return jnp.sum(ref.attention_ref(q, k, v) ** 2)

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_rejects_bad_tile(self):
        q = jnp.zeros((1, 48, 8))
        with pytest.raises(ValueError):
            attention(q, q, q, bq=32)
