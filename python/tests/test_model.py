"""L2 correctness: model shapes, layout table, loss/grad sanity, optimizers."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig()  # tiny


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(
        jax.random.PRNGKey(1), (CFG.batch, CFG.seq_len + 1), 0, CFG.vocab
    )


class TestLayout:
    def test_table_is_contiguous_and_ordered(self):
        off = 0
        for name, offset, shape in M.param_table(CFG):
            assert offset == off, name
            off += int(np.prod(shape))
        assert off == M.param_count(CFG)

    def test_block_region_is_layer_major(self):
        table = {n: (o, s) for n, o, s in M.param_table(CFG)}
        bn = M.block_numel(CFG)
        base = table["h0.ln1_scale"][0]
        assert table["h1.ln1_scale"][0] == base + bn

    def test_init_matches_count(self, params):
        assert params.shape == (M.param_count(CFG),)

    def test_init_layernorm_scales_are_one(self, params):
        table = {n: (o, s) for n, o, s in M.param_table(CFG)}
        off, shape = table["h0.ln1_scale"]
        np.testing.assert_array_equal(
            np.asarray(params[off : off + shape[0]]), 1.0
        )


class TestForward:
    def test_logits_shape(self, params, tokens):
        logits = M.forward(CFG, params, tokens[:, :-1])
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)

    def test_initial_loss_near_log_vocab(self, params, tokens):
        loss = M.loss_fn(CFG, params, tokens)
        assert abs(float(loss) - math.log(CFG.vocab)) < 0.5

    def test_causality(self, params, tokens):
        """Changing a future token must not change past logits."""
        inp = tokens[:, :-1]
        logits_a = M.forward(CFG, params, inp)
        inp_b = inp.at[:, -1].set((inp[:, -1] + 1) % CFG.vocab)
        logits_b = M.forward(CFG, params, inp_b)
        np.testing.assert_allclose(
            logits_a[:, :-1], logits_b[:, :-1], rtol=1e-5, atol=1e-5
        )

    def test_grads_finite_and_nonzero(self, params, tokens):
        loss, g = M.fwd_bwd(CFG, params, tokens)
        assert np.isfinite(np.asarray(g)).all()
        assert float(jnp.linalg.norm(g)) > 0


class TestOptimizers:
    def test_sgd_descends(self, params, tokens):
        loss, g = M.fwd_bwd(CFG, params, tokens)
        loss2, _ = M.fwd_bwd(CFG, M.sgd_update(params, g, 0.1), tokens)
        assert float(loss2) < float(loss)

    def test_adam_descends_over_steps(self, params, tokens):
        p = params
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        losses = []
        for step in range(1, 6):
            loss, g = M.fwd_bwd(CFG, p, tokens)
            losses.append(float(loss))
            p, m, v = M.adam_update(
                p, m, v, g, jnp.int32(step), jnp.float32(1e-2)
            )
        assert losses[-1] < losses[0]

    def test_adam_bias_correction_first_step(self):
        """With m=v=0 and step=1, Adam moves by ~lr*sign(g)."""
        p = jnp.zeros((8,))
        g = jnp.array([1.0, -1.0, 2.0, -2.0, 0.5, -0.5, 3.0, -3.0])
        p2, m, v = M.adam_update(
            p, jnp.zeros_like(p), jnp.zeros_like(p), g,
            jnp.int32(1), jnp.float32(0.1),
        )
        np.testing.assert_allclose(
            np.asarray(p2), -0.1 * np.sign(g), rtol=1e-4
        )
