//! Ablation — the error-feedback compensation scheduler (§III.D): real
//! tiny-LM training under COVAP I=4 with (a) no error feedback, (b) full
//! constant feedback, (c) the paper's ramped scheduler.
//!
//! The paper's motivation: no EF loses mass (poor convergence); constant
//! full EF on large models can destabilize early training (stale bursts);
//! the ramp interpolates. On the tiny LM the instability is mild, so the
//! reproduced signal is: no-EF ≪ ramped ≈ constant.

use std::path::PathBuf;

use covap::compress::SchemeKind;
use covap::config::RunConfig;
use covap::covap::EfScheduler;
use covap::runtime::{ModelArtifacts, Runtime};
use covap::trainer::train_with;
use covap::util::bench::Table;
use covap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps: u64 = args.get_parsed("steps", 80)?;
    let rt = Runtime::cpu()?;

    let variants: [(&str, EfScheduler); 4] = [
        ("no error feedback", EfScheduler::constant(0.0)),
        ("constant c=1.0", EfScheduler::constant(1.0)),
        ("constant c=0.5", EfScheduler::constant(0.5)),
        (
            "ramped 0.3 -> 1.0",
            EfScheduler { init_value: 0.3, ascend_steps: (steps / 14).max(1), ascend_range: 0.1 },
        ),
    ];

    let mut t = Table::new(&["EF variant", "final loss", "mean last-10"]);
    let mut baseline = f32::NAN;
    {
        let cfg = RunConfig {
            artifacts: PathBuf::from("artifacts/tiny"),
            workers: 4,
            steps,
            lr: 3e-3,
            scheme: SchemeKind::Baseline,
            seed: 21,
            ..RunConfig::default()
        };
        let arts = ModelArtifacts::load(&rt, &cfg.artifacts)?;
        let s = train_with(cfg, arts, false)?.metrics.summary();
        baseline = s.mean_loss_last10;
        t.row(&["(dense baseline)".into(), format!("{:.3}", s.final_loss), format!("{:.3}", s.mean_loss_last10)]);
    }
    for (name, ef) in variants {
        let cfg = RunConfig {
            artifacts: PathBuf::from("artifacts/tiny"),
            workers: 4,
            steps,
            lr: 3e-3,
            scheme: SchemeKind::Covap { interval: 4, ef },
            seed: 21,
            ..RunConfig::default()
        };
        let arts = ModelArtifacts::load(&rt, &cfg.artifacts)?;
        let s = train_with(cfg, arts, false)?.metrics.summary();
        t.row(&[
            name.to_string(),
            format!("{:.3}", s.final_loss),
            format!("{:.3}", s.mean_loss_last10),
        ]);
        println!("{name} done");
    }
    t.print(&format!(
        "Ablation — EF scheduler, COVAP I=4, {steps} steps (baseline last-10 = {baseline:.3})"
    ));
    Ok(())
}
