//! Ablation — tensor sharding (§III.C / Fig. 4): COVAP on VGG-19 with and
//! without slicing the oversized FC1 bucket, plus per-step balance.
//!
//! Without sharding, the step that draws the 107.5 M-element tensor pays a
//! ~628 ms collective that nothing can hide; with sharding the per-step
//! volume is balanced and every step overlaps.

use covap::compress::CollectiveOp;
use covap::covap::{shard_buckets, CoarseFilter};
use covap::harness::{bucket_comp_fractions, workload_buckets};
use covap::network::{ClusterSpec, NetworkModel};
use covap::sim::{simulate_iteration, Policy, TensorCost};
use covap::util::bench::Table;
use covap::workload;

fn main() {
    let w = workload::vgg19();
    let net = NetworkModel::default();
    let cluster = ClusterSpec::ecs(64);
    let interval = 4;
    let buckets = workload_buckets(&w);
    let fracs = bucket_comp_fractions(&w, &buckets);

    // tensors = either raw buckets or shards
    let variants: [(&str, Vec<(usize, f64)>); 2] = [
        (
            "no sharding",
            buckets
                .iter()
                .zip(fracs.iter())
                .map(|(&n, &f)| (n, w.t_comp_s * f))
                .collect(),
        ),
        (
            "with sharding",
            shard_buckets(&buckets, interval)
                .iter()
                .map(|s| {
                    let comp =
                        if s.offset == 0 { w.t_comp_s * fracs[s.bucket] } else { 0.0 };
                    (s.len, comp)
                })
                .collect(),
        ),
    ];

    let mut t = Table::new(&[
        "variant", "tensors", "worst step", "best step", "mean step", "speedup",
    ]);
    for (name, tensors) in &variants {
        let filter = CoarseFilter::new(interval);
        let mut step_times = Vec::new();
        for step in 0..interval as u64 {
            let costs: Vec<TensorCost> = tensors
                .iter()
                .enumerate()
                .map(|(i, &(n, comp_s))| TensorCost {
                    comp_s,
                    compress_s: 0.0,
                    wire_bytes: if filter.keep(i, step) { n * 4 } else { 0 },
                    collective: CollectiveOp::AllReduce,
                    rounds: 1,
                    sync_rounds: 0,
                    data_dependency: false,
                })
                .collect();
            let b = simulate_iteration(&net, cluster, w.t_before_s, &costs, Policy::Overlap);
            step_times.push(b.total_s);
        }
        let mean = step_times.iter().sum::<f64>() / step_times.len() as f64;
        let worst = step_times.iter().cloned().fold(f64::MIN, f64::max);
        let best = step_times.iter().cloned().fold(f64::MAX, f64::min);
        t.row(&[
            name.to_string(),
            format!("{}", tensors.len()),
            format!("{:.0}ms", worst * 1e3),
            format!("{:.0}ms", best * 1e3),
            format!("{:.0}ms", mean * 1e3),
            format!("{:.1}x", 64.0 * (w.t_before_s + w.t_comp_s) / mean),
        ]);
    }
    t.print(&format!(
        "Ablation — tensor sharding, VGG-19, COVAP I={interval} (paper Fig. 4)"
    ));
    println!("\nWithout sharding the FC1 step is the straggler (Fig. 4b); sharding");
    println!("balances per-step volume and lifts the mean-step speedup (Fig. 4c).");
}
