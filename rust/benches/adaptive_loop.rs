//! adaptive_loop — the closed-loop interval controller under CCR drift.
//!
//! Scenario: covap@auto on the threaded backend, paced ring. After two
//! stable profiling windows the emulated wire bandwidth drops (the
//! `pace_schedule` scenario knob), so communication suddenly costs ~5× —
//! the warmup-chosen interval no longer hides it. The windowed re-profiler
//! must measure the drifted CCR from the *measured* per-rank spans,
//! re-select a larger interval within one window, re-shard with residual
//! preservation, and bring the measured exposed communication back near
//! the pre-drift (overlap-optimal) level.
//!
//!     cargo bench --bench adaptive_loop -- [--quick]
//!         [--json BENCH_adaptive_loop.json] [--pace-gbps F] [--drop-gbps F]
//!
//! Emits BENCH_adaptive_loop.json: the chosen-interval trajectory (every
//! windowed decision) plus per-phase exposed-communication means.

use std::path::PathBuf;

use covap::compress::SchemeKind;
use covap::config::{ExecBackend, Optimizer, RunConfig};
use covap::coordinator::DpEngine;
use covap::covap::{EfScheduler, IntervalDecision};
use covap::network::NetworkModel;
use covap::runtime::ModelArtifacts;
use covap::util::bench::Table;
use covap::util::cli::Args;
use covap::util::fmt_secs;
use covap::util::json::Json;

struct Outcome {
    /// Mean measured exposed comm per phase (s).
    pre: f64,
    mid: f64,
    post: f64,
    /// Interval after warmup / after the post-drop re-selection.
    i0: usize,
    i1: usize,
    /// Step of the first post-drop switch decision (if any).
    switch_step: Option<u64>,
    decisions: Vec<IntervalDecision>,
    intervals: Vec<(u64, usize)>,
}

struct Shape {
    warmup: u64,
    window: u64,
    drop_at: u64,
    total: u64,
}

fn shape(quick: bool) -> Shape {
    let warmup = if quick { 3 } else { 4 };
    let window = if quick { 4 } else { 6 };
    let drop_at = warmup + 2 * window;
    Shape { warmup, window, drop_at, total: drop_at + 3 * window }
}

fn run_once(sh: &Shape, pace0: f64, pace1: f64, seed: u64) -> anyhow::Result<Outcome> {
    let cfg = RunConfig {
        workers: 4,
        scheme: SchemeKind::CovapAuto { ef: EfScheduler::constant(1.0) },
        backend: ExecBackend::Threaded,
        optimizer: Optimizer::Sgd,
        lr: 0.05,
        seed,
        bucket_bytes: 16 * 1024,
        synth_work: 6,
        pace_gbps: pace0,
        pace_schedule: vec![(sh.drop_at, pace1)],
        profile_steps: sh.warmup,
        profile_window: sh.window,
        // the acceptance criterion wants re-selection within ONE window
        profile_hysteresis: 1,
        steps: sh.total,
        // keep hop latency negligible so transfer time is
        // bandwidth-dominated — the regime where the controller's
        // dense-equivalent volume rescale is exact and its fixed point
        // stable (a per-tensor latency floor does not shrink with I)
        net: NetworkModel { latency_s: 2e-6, ..NetworkModel::default() },
        ..RunConfig::default()
    };
    let mut engine = DpEngine::new(cfg, ModelArtifacts::synthetic("tiny"))?;

    let mut exposed = Vec::with_capacity(sh.total as usize);
    let mut intervals = Vec::with_capacity(sh.total as usize);
    for s in 0..sh.total {
        let out = engine.step()?;
        let m = out.measured.expect("threaded backend measures");
        exposed.push(m.exposed_s);
        intervals.push((s, engine.chosen_interval.unwrap_or(1)));
    }
    let decisions = engine.adaptive_history().to_vec();

    let mean = |lo: u64, hi: u64| -> f64 {
        let xs = &exposed[lo as usize..hi as usize];
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    let i0 = decisions.first().map(|d| d.interval).unwrap_or(1);
    let post_drop_switch =
        decisions.iter().find(|d| d.step >= sh.drop_at && d.switched);
    Ok(Outcome {
        // pre: the settled window right before the drop
        pre: mean(sh.drop_at - sh.window, sh.drop_at),
        // mid: the drifted window (old interval, slow wire)
        mid: mean(sh.drop_at, sh.drop_at + sh.window),
        // post: the final window, after re-selection settled
        post: mean(sh.total - sh.window, sh.total),
        i0,
        i1: decisions.last().map(|d| d.interval).unwrap_or(i0),
        switch_step: post_drop_switch.map(|d| d.step),
        decisions,
        intervals,
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let quick = args.has("quick");
    let pace0: f64 = args.get_parsed("pace-gbps", 1.0)?;
    let pace1: f64 = args.get_parsed("drop-gbps", 0.2)?;
    let json_path = PathBuf::from(args.get_or("json", "BENCH_adaptive_loop.json"));
    let sh = shape(quick);

    // Wall-clock assertions on a possibly oversubscribed CI box: retry a
    // couple of times before declaring the loop broken (same policy as
    // the exec_parity overlap test).
    let attempts = 3;
    let mut outcome: Option<Outcome> = None;
    let mut last_err = String::new();
    for attempt in 0..attempts {
        let o = run_once(&sh, pace0, pace1, 42 + attempt as u64)?;
        let recovered = o.post <= o.pre * 1.15 + 1e-3;
        let reselect_ok = match o.switch_step {
            // "within one profiling window" of the drop
            Some(s) => s < sh.drop_at + sh.window && o.i1 > o.i0,
            None => false,
        };
        if recovered && reselect_ok {
            outcome = Some(o);
            break;
        }
        last_err = format!(
            "attempt {attempt}: i0={} i1={} switch={:?} pre={} mid={} post={}",
            o.i0,
            o.i1,
            o.switch_step,
            fmt_secs(o.pre),
            fmt_secs(o.mid),
            fmt_secs(o.post)
        );
        covap::log_warn!(target: "bench", "{last_err} — retrying");
        outcome = Some(o);
    }
    let o = outcome.expect("at least one attempt ran");

    // ---- report ----
    let mut t = Table::new(&["phase", "steps", "interval", "exposed comm (meas)"]);
    t.row(&[
        "pre-drift".into(),
        format!("{}..{}", sh.drop_at - sh.window, sh.drop_at),
        o.i0.to_string(),
        fmt_secs(o.pre),
    ]);
    t.row(&[
        "post-drop (stale I)".into(),
        format!("{}..{}", sh.drop_at, sh.drop_at + sh.window),
        o.i0.to_string(),
        fmt_secs(o.mid),
    ]);
    t.row(&[
        "re-selected".into(),
        format!("{}..{}", sh.total - sh.window, sh.total),
        o.i1.to_string(),
        fmt_secs(o.post),
    ]);
    t.print(&format!(
        "adaptive loop — pace {pace0} -> {pace1} Gbps at step {} (P=4, covap@auto)",
        sh.drop_at
    ));
    let mut td = Table::new(&["window end", "dense-eq CCR", "proposed I", "in force", "switched"]);
    for d in &o.decisions {
        td.row(&[
            d.step.to_string(),
            format!("{:.2}", d.ccr),
            d.proposed.to_string(),
            d.interval.to_string(),
            if d.switched { "yes".into() } else { String::new() },
        ]);
    }
    td.print("controller decisions (chosen-interval trajectory)");

    // ---- machine-readable artifact ----
    let mut rows: Vec<Json> = Vec::new();
    for d in &o.decisions {
        rows.push(Json::obj(vec![
            ("kind", Json::from("decision")),
            ("step", Json::from(d.step as usize)),
            ("ccr", Json::from(d.ccr)),
            ("proposed", Json::from(d.proposed)),
            ("interval", Json::from(d.interval)),
            ("switched", Json::from(d.switched)),
        ]));
    }
    for (name, lo, hi, interval, exposed) in [
        ("pre_drift", sh.drop_at - sh.window, sh.drop_at, o.i0, o.pre),
        ("post_drop", sh.drop_at, sh.drop_at + sh.window, o.i0, o.mid),
        ("re_selected", sh.total - sh.window, sh.total, o.i1, o.post),
    ] {
        rows.push(Json::obj(vec![
            ("kind", Json::from("phase")),
            ("phase", Json::from(name)),
            ("from_step", Json::from(lo as usize)),
            ("until_step", Json::from(hi as usize)),
            ("interval", Json::from(interval)),
            ("exposed_s", Json::from(exposed)),
        ]));
    }
    rows.push(Json::obj(vec![
        ("kind", Json::from("summary")),
        ("pace_gbps", Json::from(pace0)),
        ("drop_gbps", Json::from(pace1)),
        ("drop_step", Json::from(sh.drop_at as usize)),
        ("warmup_interval", Json::from(o.i0)),
        ("reselected_interval", Json::from(o.i1)),
        (
            "switch_step",
            match o.switch_step {
                Some(s) => Json::from(s as usize),
                None => Json::Null,
            },
        ),
        ("pre_exposed_s", Json::from(o.pre)),
        ("post_exposed_s", Json::from(o.post)),
        // per-step [step, interval-in-force] — the full chosen-interval
        // trajectory, not just the window decisions
        (
            "interval_trajectory",
            Json::Arr(
                o.intervals
                    .iter()
                    .map(|&(s, i)| {
                        Json::Arr(vec![Json::from(s as usize), Json::from(i)])
                    })
                    .collect(),
            ),
        ),
    ]));
    let meta = covap::harness::BenchMeta::new(covap::harness::iso_timestamp_now())
        .scheme("covap@auto")
        .topology("ring")
        .backend("threaded");
    covap::harness::write_bench_doc(&json_path, "adaptive_loop", &meta, rows)?;
    println!("\nwrote {}", json_path.display());

    // ---- acceptance criteria (closed-loop bench) ----
    let switch_step = o.switch_step.unwrap_or_else(|| {
        panic!("controller never re-selected after the drop ({last_err})")
    });
    assert!(
        switch_step < sh.drop_at + sh.window,
        "re-selection must land within one profiling window of the drop \
         (switch at {switch_step}, drop at {}, window {})",
        sh.drop_at,
        sh.window
    );
    assert!(
        o.i1 > o.i0,
        "bandwidth dropped {pace0} -> {pace1} Gbps: the interval must grow ({} -> {})",
        o.i0,
        o.i1
    );
    assert!(
        o.post <= o.pre * 1.15 + 1e-3,
        "exposed comm must return to within 15% of pre-drift: pre {} post {} ({last_err})",
        fmt_secs(o.pre),
        fmt_secs(o.post)
    );
    println!(
        "\nclosed loop OK: I {} -> {} at step {}, exposed {} -> {} -> {}",
        o.i0,
        o.i1,
        switch_step,
        fmt_secs(o.pre),
        fmt_secs(o.mid),
        fmt_secs(o.post)
    );
    Ok(())
}
