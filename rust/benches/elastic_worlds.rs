//! elastic_worlds — scripted membership chaos on the threaded backend.
//!
//! Scenario: covap on a paced ring, 4 workers. The membership schedule
//! walks the full elastic repertoire of DESIGN.md §12 — a rank *fails*,
//! a straggler is *evicted* (leave), the failed rank *rejoins*, and an
//! operator *scales out* back to the original fleet:
//!
//!     world: 4 --fail--> 3 --evict--> 2 --rejoin--> 3 --scale-out--> 4
//!
//! Every event quiesces at a step boundary, redistributes the COVAP
//! error-feedback residuals, re-derives (and statically verifies) the
//! collective schedule, and resumes — the run must *complete*, not
//! abort. The bench asserts:
//!
//! * all four reconfigurations happened (engine generation == 4) and
//!   each cost a bounded amount of wall-clock;
//! * exposed communication in the final window (world restored to 4)
//!   recovers to near its pre-event level — elasticity does not leak a
//!   permanent overlap penalty.
//!
//!     cargo bench --bench elastic_worlds -- [--quick]
//!         [--json BENCH_elastic.json] [--pace-gbps F]
//!
//! Emits BENCH_elastic.json: per-phase world size and measured exposed
//! comm, per-event measured reconfiguration cost plus the analytic
//! prediction from `sim::price_reconfiguration`.

use std::path::PathBuf;

use covap::compress::SchemeKind;
use covap::config::{ExecBackend, Optimizer, RunConfig};
use covap::coordinator::{parse_membership_schedule, DpEngine};
use covap::covap::EfScheduler;
use covap::network::ClusterSpec;
use covap::obs::with_global;
use covap::runtime::ModelArtifacts;
use covap::sim::price_reconfiguration;
use covap::util::bench::Table;
use covap::util::cli::Args;
use covap::util::fmt_secs;
use covap::util::json::Json;

/// One membership event of the scripted chaos run.
struct Event {
    label: &'static str,
    spec: &'static str,
    /// world size in force after the event
    world: usize,
}

const EVENTS: [Event; 4] = [
    Event { label: "fail", spec: "fail:3", world: 3 },
    Event { label: "evict", spec: "leave:0", world: 2 },
    Event { label: "rejoin", spec: "join:1", world: 3 },
    Event { label: "scale-out", spec: "join:1", world: 4 },
];

struct Shape {
    window: u64,
    total: u64,
}

fn shape(quick: bool) -> Shape {
    let window = if quick { 4 } else { 6 };
    Shape { window, total: window * (EVENTS.len() as u64 + 1) }
}

struct Outcome {
    /// Mean measured exposed comm per phase (s), one entry per window:
    /// pre-event, then one per membership event.
    exposed: Vec<f64>,
    /// world size in force during each window
    worlds: Vec<usize>,
    generation: u64,
    /// measured reconfiguration cost: (count, mean_s, max_s)
    reconfig: (u64, f64, f64),
    /// bytes of residual state handed off per departure event
    moved_bytes: usize,
}

fn run_once(sh: &Shape, pace: f64, seed: u64) -> anyhow::Result<Outcome> {
    let schedule: String = EVENTS
        .iter()
        .enumerate()
        .map(|(i, e)| format!("{}:{}", sh.window * (i as u64 + 1), e.spec))
        .collect::<Vec<_>>()
        .join(",");
    let cfg = RunConfig {
        workers: 4,
        cluster: ClusterSpec::new(4, 1),
        scheme: SchemeKind::Covap { interval: 2, ef: EfScheduler::constant(1.0) },
        backend: ExecBackend::Threaded,
        optimizer: Optimizer::Sgd,
        lr: 0.05,
        seed,
        bucket_bytes: 16 * 1024,
        synth_work: 6,
        pace_gbps: pace,
        steps: sh.total,
        membership_schedule: parse_membership_schedule(&schedule)?,
        elastic: true,
        ..RunConfig::default()
    };
    cfg.validate()?;

    // the engine publishes reconfig_cost_s into the global registry;
    // start from a clean slate so the histogram is this run's alone
    with_global(|r| r.clear());
    let mut engine = DpEngine::new(cfg, ModelArtifacts::synthetic("tiny"))?;
    let moved_bytes = engine.params().len() * 4;

    let mut exposed_steps = Vec::with_capacity(sh.total as usize);
    for _ in 0..sh.total {
        let out = engine.step()?;
        let m = out.measured.expect("threaded backend measures");
        exposed_steps.push(m.exposed_s);
    }

    let mean = |lo: u64, hi: u64| -> f64 {
        // skip the window's first step: it carries the re-world's cold
        // caches (and window 0's step 0 carries process warm-up)
        let xs = &exposed_steps[(lo + 1) as usize..hi as usize];
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    let n_windows = EVENTS.len() + 1;
    let exposed: Vec<f64> = (0..n_windows as u64)
        .map(|w| mean(w * sh.window, (w + 1) * sh.window))
        .collect();
    let mut worlds = vec![4usize];
    worlds.extend(EVENTS.iter().map(|e| e.world));

    let reconfig = with_global(|r| match r.histogram("reconfig_cost_s") {
        Some(h) => (h.count(), h.sum() / h.count().max(1) as f64, h.percentile(1.0)),
        None => (0, 0.0, 0.0),
    });
    Ok(Outcome { exposed, worlds, generation: engine.generation(), reconfig, moved_bytes })
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let quick = args.has("quick");
    let pace: f64 = args.get_parsed("pace-gbps", 1.0)?;
    let json_path = PathBuf::from(args.get_or("json", "BENCH_elastic.json"));
    let sh = shape(quick);

    // Wall-clock assertions on a possibly oversubscribed CI box: retry a
    // couple of times before declaring recovery broken (same policy as
    // the adaptive_loop bench).
    let attempts = 3;
    let mut outcome: Option<Outcome> = None;
    let mut last_err = String::new();
    for attempt in 0..attempts {
        let o = run_once(&sh, pace, 42 + attempt as u64)?;
        let pre = o.exposed[0];
        let post = *o.exposed.last().unwrap();
        let recovered = post <= pre * 1.25 + 1e-3;
        if recovered {
            outcome = Some(o);
            break;
        }
        last_err = format!(
            "attempt {attempt}: exposed pre {} post {}",
            fmt_secs(pre),
            fmt_secs(post)
        );
        covap::log_warn!(target: "bench", "{last_err} — retrying");
        outcome = Some(o);
    }
    let o = outcome.expect("at least one attempt ran");

    // analytic prediction for each event's reconfiguration cost
    let cfg = RunConfig::default();
    let net = cfg.net;
    let mut predicted = Vec::new();
    let mut prev_world = 4usize;
    for e in &EVENTS {
        let moved = if e.world < prev_world { o.moved_bytes } else { 0 };
        let c = price_reconfiguration(
            &net,
            ClusterSpec::new(prev_world, 1),
            ClusterSpec::new(e.world, 1),
            moved,
        );
        predicted.push((e.label, prev_world, e.world, moved, c));
        prev_world = e.world;
    }

    // ---- report ----
    let mut t = Table::new(&["phase", "steps", "world", "exposed comm (meas)"]);
    let labels: Vec<String> = std::iter::once("pre-event".to_string())
        .chain(EVENTS.iter().map(|e| format!("after {}", e.label)))
        .collect();
    for (w, label) in labels.iter().enumerate() {
        let (lo, hi) = (w as u64 * sh.window, (w as u64 + 1) * sh.window);
        t.row(&[
            label.clone(),
            format!("{lo}..{hi}"),
            o.worlds[w].to_string(),
            fmt_secs(o.exposed[w]),
        ]);
    }
    t.print(&format!(
        "elastic worlds — fail/evict/rejoin/scale-out at every {} steps (P=4, covap)",
        sh.window
    ));
    let mut tc = Table::new(&["event", "world", "moved", "predicted (model)", "measured mean"]);
    for (label, from, to, moved, c) in &predicted {
        tc.row(&[
            (*label).into(),
            format!("{from}->{to}"),
            format!("{} B", moved),
            fmt_secs(c.total_s),
            fmt_secs(o.reconfig.1),
        ]);
    }
    tc.print("reconfiguration cost (analytic network model vs measured wall-clock)");

    // ---- machine-readable artifact ----
    let mut rows: Vec<Json> = Vec::new();
    for (w, label) in labels.iter().enumerate() {
        rows.push(Json::obj(vec![
            ("kind", Json::from("phase")),
            ("phase", Json::from(label.as_str())),
            ("from_step", Json::from((w as u64 * sh.window) as usize)),
            ("until_step", Json::from(((w as u64 + 1) * sh.window) as usize)),
            ("world", Json::from(o.worlds[w])),
            ("exposed_s", Json::from(o.exposed[w])),
        ]));
    }
    for (label, from, to, moved, c) in &predicted {
        rows.push(Json::obj(vec![
            ("kind", Json::from("reconfig")),
            ("event", Json::from(*label)),
            ("world_from", Json::from(*from)),
            ("world_to", Json::from(*to)),
            ("moved_bytes", Json::from(*moved)),
            ("predicted_quiesce_s", Json::from(c.quiesce_s)),
            ("predicted_state_move_s", Json::from(c.state_move_s)),
            ("predicted_resync_s", Json::from(c.resync_s)),
            ("predicted_total_s", Json::from(c.total_s)),
        ]));
    }
    rows.push(Json::obj(vec![
        ("kind", Json::from("summary")),
        ("pace_gbps", Json::from(pace)),
        ("events", Json::from(o.generation as usize)),
        ("reconfig_count", Json::from(o.reconfig.0 as usize)),
        ("reconfig_mean_s", Json::from(o.reconfig.1)),
        ("reconfig_max_s", Json::from(o.reconfig.2)),
        ("pre_exposed_s", Json::from(o.exposed[0])),
        ("post_exposed_s", Json::from(*o.exposed.last().unwrap())),
    ]));
    let meta = covap::harness::BenchMeta::new(covap::harness::iso_timestamp_now())
        .scheme("covap@2")
        .topology("auto")
        .backend("threaded");
    covap::harness::write_bench_doc(&json_path, "elastic_worlds", &meta, rows)?;
    println!("\nwrote {}", json_path.display());

    // ---- acceptance criteria (elastic bench) ----
    assert_eq!(
        o.generation,
        EVENTS.len() as u64,
        "every scripted membership event must re-world the fleet"
    );
    assert_eq!(
        o.reconfig.0,
        EVENTS.len() as u64,
        "every re-world must record its reconfiguration cost"
    );
    assert!(
        o.reconfig.2 < 5.0,
        "a single reconfiguration must stay bounded (max {} s)",
        o.reconfig.2
    );
    let (pre, post) = (o.exposed[0], *o.exposed.last().unwrap());
    assert!(
        post <= pre * 1.25 + 1e-3,
        "exposed comm must recover once the world is restored: pre {} post {} ({last_err})",
        fmt_secs(pre),
        fmt_secs(post)
    );
    println!(
        "\nelastic worlds OK: {} re-worlds (mean cost {}), exposed {} -> {}",
        o.generation,
        fmt_secs(o.reconfig.1),
        fmt_secs(pre),
        fmt_secs(post)
    );
    Ok(())
}
