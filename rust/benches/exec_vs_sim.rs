//! exec_vs_sim — measured overlap vs simulated overlap.
//!
//! For every GC scheme and rank count: run the identical configuration
//! through the analytic backend (discrete-event timeline, predicted
//! breakdown) and the threaded rank executor (real OS threads, ring
//! collectives over channels, measured breakdown), verify the two are
//! numerically bit-identical, and print/record the timing columns side by
//! side. Then sweep COVAP across Overlap vs Sequential policies to show
//! the measured exposed communication actually shrinks under wait-free
//! backprop — the paper's central mechanism, measured rather than
//! asserted.
//!
//!     cargo bench --bench exec_vs_sim -- [--quick] [--pace-gbps F]
//!         [--json BENCH_exec_vs_sim.json] [--steps N]
//!
//! Emits a machine-readable BENCH_exec_vs_sim.json (scheme, world,
//! measured wall, simulated wall, exposed comm, wire bytes).

use std::path::PathBuf;

use covap::compress::SchemeKind;
use covap::config::{Optimizer, RunConfig};
use covap::exec::compare_backends;
use covap::harness::{iso_timestamp_now, write_bench_json, BenchMeta, BenchRow};
use covap::sim::Policy;
use covap::util::bench::Table;
use covap::util::cli::Args;
use covap::util::fmt_secs;

fn base_cfg(workers: usize, scheme: SchemeKind, policy: Policy, pace_gbps: f64) -> RunConfig {
    RunConfig {
        workers,
        scheme,
        policy,
        pace_gbps,
        optimizer: Optimizer::Sgd,
        lr: 0.05,
        seed: 42,
        // small buckets -> enough communication tensors for overlap to
        // matter on the tiny synthetic preset (~83k params)
        bucket_bytes: 16 * 1024,
        // inflate synthetic backward cost so computation and (paced)
        // communication are the same order of magnitude
        synth_work: 6,
        ..RunConfig::default()
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let quick = args.has("quick");
    let pace: f64 = args.get_parsed("pace-gbps", 1.0)?;
    let steps: u64 = args.get_parsed("steps", if quick { 3 } else { 5 })?;
    let json_path =
        PathBuf::from(args.get_or("json", "BENCH_exec_vs_sim.json"));
    let worlds: Vec<usize> = if quick { vec![4] } else { vec![2, 4, 8] };

    let mut rows: Vec<BenchRow> = Vec::new();

    // ---- part 1: per-scheme backend parity + timing columns ----
    let mut t = Table::new(&[
        "scheme", "P", "bitwise", "meas wall", "sim wall", "meas exp'", "sim exp'", "wire/step",
    ]);
    let schemes: Vec<SchemeKind> = if quick {
        vec![
            SchemeKind::Baseline,
            SchemeKind::Covap { interval: 4, ef: Default::default() },
            SchemeKind::TopK { ratio: 0.01 },
            SchemeKind::Fp16,
        ]
    } else {
        SchemeKind::evaluation_set()
    };
    let mut all_bitwise = true;
    for &world in &worlds {
        for kind in &schemes {
            let cfg = base_cfg(world, kind.clone(), Policy::Overlap, pace);
            let c = compare_backends(&cfg, "tiny", steps)?;
            all_bitwise &= c.bitwise_equal;
            t.row(&[
                c.scheme.clone(),
                world.to_string(),
                if c.bitwise_equal { "yes".into() } else { "NO".into() },
                fmt_secs(c.measured.wall_s),
                fmt_secs(c.sim.total_s),
                fmt_secs(c.measured.exposed_s),
                fmt_secs(c.sim.t_comm_exposed_s),
                covap::util::fmt_bytes(c.wire_bytes),
            ]);
            rows.push(BenchRow {
                scheme: c.scheme.clone(),
                world,
                policy: "overlap".into(),
                measured_wall_s: c.measured.wall_s,
                sim_wall_s: c.sim.total_s,
                measured_exposed_s: c.measured.exposed_s,
                sim_exposed_s: c.sim.t_comm_exposed_s,
                wire_bytes: c.wire_bytes,
                moved_bytes: c.measured.moved_bytes,
                bitwise_equal: Some(c.bitwise_equal),
            });
        }
    }
    t.print("exec vs sim — backend parity and timings");
    assert!(all_bitwise, "threaded backend diverged from analytic backend");

    // ---- compression-ratio ordering from measured frames ----
    // The recorded wire bytes are encoded frame lengths (what the ring
    // moved), not a size model: the paper's Table II ordering
    // COVAP/Top-k/DGC << FP16 < baseline must hold on them directly.
    let biggest = *worlds.last().unwrap();
    let wire_of = |label: &str| -> Option<usize> {
        rows.iter()
            .find(|r| r.world == biggest && r.policy == "overlap" && r.scheme == label)
            .map(|r| r.wire_bytes)
    };
    if let (Some(base), Some(fp16)) = (wire_of("DDPovlp"), wire_of("FP16")) {
        assert!(fp16 < base, "FP16 ({fp16} B/step) must beat dense ({base} B/step)");
        if let Some(w) = wire_of("COVAP") {
            assert!(
                w * 3 < fp16 * 2,
                "COVAP measured wire ({w} B/step) must sit well below FP16 ({fp16} B)"
            );
        }
        for sparse in ["Top-k", "DGC"] {
            if let Some(w) = wire_of(sparse) {
                assert!(
                    w * 2 < fp16,
                    "{sparse} measured wire ({w} B/step) must sit well below FP16 ({fp16} B)"
                );
            }
        }
    }

    // ---- part 2: COVAP measured overlap vs sequential ----
    let mut t2 = Table::new(&[
        "P", "policy", "meas exp'", "sim exp'", "meas wall", "overlap wins",
    ]);
    for &world in &worlds {
        let kind = SchemeKind::Covap { interval: 4, ef: Default::default() };
        let ovl = compare_backends(
            &base_cfg(world, kind.clone(), Policy::Overlap, pace),
            "tiny",
            steps,
        )?;
        let seq = compare_backends(
            &base_cfg(world, kind.clone(), Policy::Sequential, pace),
            "tiny",
            steps,
        )?;
        let wins = ovl.measured.exposed_s < seq.measured.exposed_s;
        for (label, c) in [("overlap", &ovl), ("sequential", &seq)] {
            t2.row(&[
                world.to_string(),
                label.to_string(),
                fmt_secs(c.measured.exposed_s),
                fmt_secs(c.sim.t_comm_exposed_s),
                fmt_secs(c.measured.wall_s),
                if label == "overlap" && wins { "yes".into() } else { "".into() },
            ]);
            rows.push(BenchRow {
                scheme: "COVAP".into(),
                world,
                policy: label.to_string(),
                measured_wall_s: c.measured.wall_s,
                sim_wall_s: c.sim.total_s,
                measured_exposed_s: c.measured.exposed_s,
                sim_exposed_s: c.sim.t_comm_exposed_s,
                wire_bytes: c.wire_bytes,
                moved_bytes: c.measured.moved_bytes,
                bitwise_equal: Some(c.bitwise_equal),
            });
        }
        if world >= 4 {
            assert!(
                wins,
                "P={world}: measured exposed comm under Overlap \
                 ({:.4}s) must beat Sequential ({:.4}s)",
                ovl.measured.exposed_s, seq.measured.exposed_s
            );
        }
    }
    t2.print("COVAP — measured overlap vs sequential (paced ring)");

    let meta = BenchMeta::new(iso_timestamp_now())
        .scheme("sweep")
        .topology("ring")
        .backend("both");
    write_bench_json(&json_path, "exec_vs_sim", &meta, &rows)?;
    println!("\nwrote {}", json_path.display());
    Ok(())
}
