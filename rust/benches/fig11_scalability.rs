//! Fig. 11 — speedups of all GC schemes on 8/16/32/64-GPU clusters for
//! ResNet-101, VGG-19 and Bert (the scalability study).
//!
//! Default replays the paper's Table II compression overheads;
//! --measured uses this build's own compressor timings.

use covap::compress::SchemeKind;
use covap::covap::interval_from_ccr;
use covap::harness::{
    allgather_rank_memory, calibrated_profiles, paper_profile, scheme_breakdown,
};
use covap::network::{ClusterSpec, NetworkModel};
use covap::sim::Policy;
use covap::util::bench::Table;
use covap::util::cli::Args;
use covap::workload;

const V100_MEM: usize = 16 << 30;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let measured = args.has("measured");
    let net = NetworkModel::default();
    let clusters = [8usize, 16, 32, 64];
    let kinds = SchemeKind::evaluation_set();
    let profiles: Vec<_> = if measured {
        calibrated_profiles(&kinds, 1 << 21, 3)
    } else {
        kinds.iter().map(|k| (k.clone(), paper_profile(k))).collect()
    };

    for (fig, w) in [
        ("Fig. 11(a)", workload::resnet101()),
        ("Fig. 11(b)", workload::vgg19()),
        ("Fig. 11(c)", workload::bert()),
    ] {
        let mut t = Table::new(&["scheme", "8", "16", "32", "64", "64-GPU eff"]);
        for (kind, prof) in &profiles {
            let mut row = vec![kind.label().to_string()];
            let mut last = f64::NAN;
            for &gpus in &clusters {
                let cluster = ClusterSpec::ecs(gpus);
                if allgather_rank_memory(kind, w.total_params(), gpus) > V100_MEM {
                    row.push("OOM".into());
                    last = f64::NAN;
                    continue;
                }
                let kind_here = match kind {
                    SchemeKind::Covap { ef, .. } => SchemeKind::Covap {
                        interval: interval_from_ccr(w.ccr(&net, cluster)),
                        ef: *ef,
                    },
                    k => k.clone(),
                };
                let topo = covap::comm::TopologyKind::Auto.resolve(cluster);
                let b =
                    scheme_breakdown(&w, &kind_here, prof, &net, cluster, topo, Policy::Overlap);
                last = b.speedup(gpus) / gpus as f64;
                row.push(format!("{:.1}x", b.speedup(gpus)));
            }
            row.push(if last.is_nan() { "-".into() } else { format!("{:.0}%", last * 100.0) });
            t.row(&row);
        }
        let mut lin = vec!["linear scaling".to_string()];
        for &g in &clusters {
            lin.push(format!("{g}.0x"));
        }
        lin.push("100%".into());
        t.row(&lin);
        t.print(&format!("{fig} — scalability, {}", w.name));
    }
    println!("\nShape checks vs paper: COVAP within a few % of linear scaling on all");
    println!("cluster sizes; AllGather-based schemes OOM on VGG-19/Bert at scale;");
    println!("AllReduce-based schemes keep scaling; COVAP's margin grows with cluster");
    println!("size because its interval adapts to the rising CCR.");
    Ok(())
}
