//! Fig. 5 — speedups of COVAP under different compression ratios
//! (ResNet-101 / VGG-19 / Bert, 64 GPUs). The paper's claim: speedup rises
//! until the ratio reaches ceil(CCR) — the value COVAP selects — and
//! saturates beyond it.

use covap::compress::SchemeKind;
use covap::covap::interval_from_ccr;
use covap::harness::{paper_profile, scheme_breakdown};
use covap::network::{ClusterSpec, NetworkModel};
use covap::sim::Policy;
use covap::util::bench::Table;
use covap::workload;

fn main() {
    let net = NetworkModel::default();
    let cluster = ClusterSpec::ecs(64);
    let ratios: Vec<usize> = vec![1, 2, 3, 4, 5, 6, 8];

    let mut t = Table::new(&[
        "DNN", "CCR", "I*", "r=1", "r=2", "r=3", "r=4", "r=5", "r=6", "r=8",
    ]);
    for w in [workload::resnet101(), workload::vgg19(), workload::bert()] {
        let ccr = w.ccr(&net, cluster);
        let chosen = interval_from_ccr(ccr);
        let mut row = vec![
            w.name.to_string(),
            format!("{ccr:.2}"),
            format!("{chosen}"),
        ];
        for &r in &ratios {
            let kind = if r == 1 {
                SchemeKind::Baseline
            } else {
                SchemeKind::Covap { interval: r, ef: Default::default() }
            };
            let prof = paper_profile(&kind);
            let topo = covap::comm::TopologyKind::Auto.resolve(cluster);
            let b = scheme_breakdown(&w, &kind, &prof, &net, cluster, topo, Policy::Overlap);
            row.push(format!("{:.1}x", b.speedup(64)));
        }
        t.row(&row);
    }
    t.print("Fig. 5 — COVAP speedup vs compression ratio (64 GPUs; linear scaling = 64x)");
    println!("\nI* = ceil(CCR) is the interval COVAP selects (§III.B). Paper shape: the");
    println!("speedup curve knees at I* — ResNet-101 flattens past 3, VGG-19/Bert past 4");
    println!("(paper max speedups: 51.51 and 54.55 at ratio 4).");
}
