//! Fig. 6 — time-to-solution curves: REAL training of the tiny transformer
//! LM under every GC scheme, with per-step simulated cluster time on the
//! paper's 64-GPU/30 Gbps fabric. Loss-vs-simulated-time curves land in
//! results/fig6_<scheme>.csv.
//!
//! (The paper trains ResNet/VGG/Bert/GPT-2 to completion on 64 V100s; this
//! testbed trains the real LM end-to-end through the same coordinator and
//! reports the same curve shape: COVAP reaches a given loss in the least
//! simulated time; Top-k/EFsignSGD trail badly.)
//!
//! Flags: --steps N (default 60) --workers N (default 4) --preset tiny

use std::path::PathBuf;

use covap::compress::SchemeKind;
use covap::config::RunConfig;
use covap::covap::EfScheduler;
use covap::network::{ClusterSpec, NetworkModel};
use covap::runtime::{ModelArtifacts, Runtime};
use covap::trainer::train_with;
use covap::util::bench::Table;
use covap::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps: u64 = args.get_parsed("steps", 60)?;
    let workers: usize = args.get_parsed("workers", 4)?;
    let preset = args.get_or("preset", "tiny");
    std::fs::create_dir_all("results").ok();

    let rt = Runtime::cpu()?;
    let mut t = Table::new(&[
        "scheme", "final loss", "mean last-10", "sim time", "tts to 4.5",
    ]);
    for kind in SchemeKind::evaluation_set() {
        // The paper's EF scheduler plateaus are sized for multi-thousand-step
        // runs; scale the ramp to this run so full compensation is reached
        // by ~half the budget (same shape, shorter timescale).
        let kind = match kind {
            SchemeKind::Covap { interval, .. } => SchemeKind::Covap {
                interval,
                ef: EfScheduler {
                    init_value: 0.3,
                    ascend_steps: (steps / 14).max(1),
                    ascend_range: 0.1,
                },
            },
            k => k,
        };
        let cfg = RunConfig {
            artifacts: PathBuf::from(format!("artifacts/{preset}")),
            workers,
            cluster: ClusterSpec::ecs(64),
            // tiny model on 30 Gbps is compute-bound; a slow public-cloud
            // fabric puts it in the paper's CCR>1 regime so time-to-solution
            // actually exercises the communication path
            net: NetworkModel { nic_gbps: 0.2, efficiency: 0.32, latency_s: 100e-6, intra_gbps: 0.4 },
            steps,
            lr: 3e-3,
            scheme: kind.clone(),
            seed: 11,
            metrics_csv: Some(PathBuf::from(format!(
                "results/fig6_{}.csv",
                kind.label().replace('-', "").to_lowercase()
            ))),
            ..RunConfig::default()
        };
        let arts = ModelArtifacts::load(&rt, &cfg.artifacts)?;
        let report = train_with(cfg, arts, false)?;
        let s = report.metrics.summary();
        // time-to-solution: simulated time at which loss first <= 4.5
        let mut tts = f64::NAN;
        let mut acc = 0.0;
        for r in &report.metrics.records {
            acc += r.sim_s;
            if r.loss <= 4.5 && tts.is_nan() {
                tts = acc;
            }
        }
        t.row(&[
            kind.label().to_string(),
            format!("{:.3}", s.final_loss),
            format!("{:.3}", s.mean_loss_last10),
            format!("{:.2}s", s.total_sim_s),
            if tts.is_nan() { "n/a".into() } else { format!("{tts:.2}s") },
        ]);
        println!("{} done", kind.label());
    }
    t.print(&format!(
        "Fig. 6 — time-to-solution, real LM training ({steps} steps, {workers} workers, sim 64 GPUs)"
    ));
    println!("\ncurves: results/fig6_<scheme>.csv (loss vs simulated cluster time)");
    Ok(())
}
