//! Figs. 7–10 — per-iteration time breakdowns (compression, computation,
//! exposed communication T_comm') for every GC scheme on the four DNNs,
//! 64 GPUs @ 30 Gbps, replaying the paper's Table II compression overheads.
//!
//! Pass --measured to use this build's own (GPU-calibrated) compressor
//! timings instead of the paper's.

use covap::compress::SchemeKind;
use covap::covap::interval_from_ccr;
use covap::harness::{calibrated_profiles, paper_profile, scheme_breakdown};
use covap::network::{ClusterSpec, NetworkModel};
use covap::sim::Policy;
use covap::util::bench::Table;
use covap::util::cli::Args;
use covap::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let measured = args.has("measured");
    let net = NetworkModel::default();
    let cluster = ClusterSpec::ecs(64);

    let kinds = SchemeKind::evaluation_set();
    let profiles: Vec<_> = if measured {
        println!("measuring native compressor throughput...");
        calibrated_profiles(&kinds, 1 << 21, 3)
    } else {
        kinds.iter().map(|k| (k.clone(), paper_profile(k))).collect()
    };

    for (fig, w) in [
        ("Fig. 7", workload::resnet101()),
        ("Fig. 8", workload::vgg19()),
        ("Fig. 9", workload::bert()),
        ("Fig. 10", workload::gpt2()),
    ] {
        let ccr = w.ccr(&net, cluster);
        let mut t = Table::new(&[
            "scheme", "T_compress", "T_comp+before", "T_comm'", "T_iter", "speedup",
        ]);
        for (kind, prof) in &profiles {
            // COVAP adapts I = ceil(CCR) per workload (§III.B)
            let kind = match kind {
                SchemeKind::Covap { ef, .. } => SchemeKind::Covap {
                    interval: interval_from_ccr(ccr),
                    ef: *ef,
                },
                k => k.clone(),
            };
            let topo = covap::comm::TopologyKind::Auto.resolve(cluster);
            let b = scheme_breakdown(&w, &kind, prof, &net, cluster, topo, Policy::Overlap);
            t.row(&[
                kind.label().to_string(),
                format!("{:.0}ms", b.t_compress_s * 1e3),
                format!("{:.0}ms", (b.t_before_s + b.t_comp_s) * 1e3),
                format!("{:.0}ms", b.t_comm_exposed_s * 1e3),
                format!("{:.0}ms", b.total_s * 1e3),
                format!("{:.1}x", b.speedup(64)),
            ]);
        }
        t.print(&format!(
            "{fig} — iteration breakdown, {} (CCR {:.2}, I* = {})",
            w.name,
            ccr,
            interval_from_ccr(ccr)
        ));
    }
    println!("\nShape checks vs paper: Top-k's compression dwarfs everything (Fig 7:");
    println!("~370ms on ResNet-101); Ok-topk's communication cannot overlap (data");
    println!("dependency) despite low volume; COVAP has near-zero compression AND");
    println!("near-zero exposed communication.");
    Ok(())
}
