//! §Perf — L3 hot-path micro-benchmarks (the data behind EXPERIMENTS.md
//! §Perf), now centred on the zero-allocation steady-state claim:
//!
//! * per-scheme **compress+encode throughput** (GB/s of gradient input
//!   turned into wire frames through `RankCompressor::compress_into`);
//! * per-scheme **total overhead per element** (compress + combine), the
//!   measured analogue of the paper's Table II column — COVAP must be the
//!   cheapest of all compression schemes;
//! * **steady-state allocations per step**, counted by a global counting
//!   allocator across the compress→encode→combine hot path after warmup —
//!   asserted to be exactly zero for covap / topk / signsgd / fp16 (the
//!   issue's mandatory set) plus the dense baseline; DGC/Random-k have
//!   data-dependent selection sizes and the replicated schemes allocate
//!   internally, so they are reported, not asserted.
//!
//!     cargo bench --bench perf_hotpath -- [--quick]
//!         [--json BENCH_perf_hotpath.json]
//!
//! Emits a machine-readable BENCH_perf_hotpath.json through the harness
//! emitter so CI tracks the perf trajectory across PRs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use covap::comm::ring_allreduce;
use covap::compress::{
    build_rank_pair, f16_to_f32, f32_to_f16, RankCombiner, RankCompressor, SchemeKind,
    Scratch,
};
use covap::covap::CoarseFilter;
use covap::harness::{iso_timestamp_now, write_bench_doc, BenchMeta};
use covap::util::bench::{sink, time_fn, Table};
use covap::util::cli::Args;
use covap::util::json::Json;
use covap::util::rng::Rng;

/// Counts every heap allocation (alloc / alloc_zeroed / realloc) made
/// through the global allocator — the instrument behind the
/// allocations-per-step column.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// One rank's (compressor, combiner) pair.
type Pair = (Box<dyn RankCompressor>, Box<dyn RankCombiner>);

/// One scheme's measured hot-path profile.
struct HotPath {
    label: &'static str,
    /// GB/s of raw gradient input through compress_into (incl. encode).
    compress_gbps: f64,
    /// Seconds of (compress + combine) per gradient element per worker.
    s_per_elem: f64,
    /// Total heap allocations over the steady-state measured window
    /// (compress + combine, all workers, all tensors).
    steady_allocs: u64,
    /// Steps in the measured window (for the per-step report).
    measured_steps: u64,
    /// Allocations observed during the cold first step (sanity: the
    /// counter sees the warmup).
    warmup_allocs: u64,
}

impl HotPath {
    fn allocs_per_step(&self) -> f64 {
        self.steady_allocs as f64 / self.measured_steps as f64
    }
}

/// Drive `world` rank compressors + one combiner over `tensors` tensors
/// through the frame-level hot path, with persistent buffers — exactly the
/// per-rank steady state the executor runs.
fn measure_scheme(kind: &SchemeKind, n: usize, world: usize, tensors: usize) -> HotPath {
    let seed = 0xBE7C;
    let mut pairs: Vec<Pair> = (0..world).map(|_| build_rank_pair(kind, world, seed)).collect();
    let mut scratch = Scratch::new();
    let mut frames: Vec<Vec<u8>> = (0..world).map(|_| Vec::new()).collect();
    let mut update: Vec<f32> = Vec::new();

    // per-worker gradients, distinct but fixed across steps
    let mut rng = Rng::seed(0x9E7);
    let grads: Vec<Vec<f32>> =
        (0..world).map(|_| (0..n).map(|_| rng.normal() as f32).collect()).collect();

    let mut step = 0u64;
    let mut compress_s = 0.0f64;
    let mut combine_s = 0.0f64;
    let mut run_step = |pairs: &mut [Pair],
                        scratch: &mut Scratch,
                        frames: &mut Vec<Vec<u8>>,
                        update: &mut Vec<f32>,
                        compress_s: &mut f64,
                        combine_s: &mut f64| {
        for tensor in 0..tensors {
            let t0 = Instant::now();
            for ((c, _), (g, frame)) in
                pairs.iter_mut().zip(grads.iter().zip(frames.iter_mut()))
            {
                c.compress_into(tensor, step, g, scratch, frame);
            }
            let t1 = Instant::now();
            // one combiner replica (identical across ranks)
            let record = pairs[0].1.combine_into(tensor, step, n, frames, scratch, update, 0.0);
            let t2 = Instant::now();
            *compress_s += (t1 - t0).as_secs_f64();
            *combine_s += (t2 - t1).as_secs_f64();
            sink(record.wire_bytes);
            sink(update.last().copied());
        }
        step += 1;
    };

    // cold first step: warms every buffer; the counter must see it
    let before_cold = allocs();
    run_step(&mut pairs, &mut scratch, &mut frames, &mut update, &mut compress_s, &mut combine_s);
    let warmup_allocs = allocs() - before_cold;
    // finish warmup: two full COVAP intervals so every (tensor, phase)
    // combination has run at least once
    for _ in 0..7 {
        run_step(&mut pairs, &mut scratch, &mut frames, &mut update, &mut compress_s, &mut combine_s);
    }

    // measured window
    compress_s = 0.0;
    combine_s = 0.0;
    let measured_steps = 8u64;
    let before = allocs();
    for _ in 0..measured_steps {
        run_step(&mut pairs, &mut scratch, &mut frames, &mut update, &mut compress_s, &mut combine_s);
    }
    let steady_allocs = allocs() - before;

    let in_bytes = measured_steps as f64 * tensors as f64 * world as f64 * n as f64 * 4.0;
    let elems = measured_steps as f64 * tensors as f64 * world as f64 * n as f64;
    HotPath {
        label: kind.label(),
        compress_gbps: in_bytes / compress_s / 1e9,
        s_per_elem: (compress_s + combine_s) / elems,
        steady_allocs,
        measured_steps,
        warmup_allocs,
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let quick = args.has("quick");
    let json_path = PathBuf::from(args.get_or("json", "BENCH_perf_hotpath.json"));
    let n: usize = if quick { 1 << 16 } else { 1 << 20 };
    let world = 2usize;
    let tensors = 4usize;

    let kinds = SchemeKind::evaluation_set();
    let mut profiles: Vec<HotPath> = Vec::new();
    let mut t = Table::new(&[
        "scheme",
        "compress+encode",
        "overhead/elem",
        "allocs/step (steady)",
    ]);
    for kind in &kinds {
        let p = measure_scheme(kind, n, world, tensors);
        assert!(
            p.warmup_allocs > 0,
            "{}: the counting allocator saw no warmup allocations — instrument broken",
            p.label
        );
        t.row(&[
            p.label.into(),
            format!("{:.2} GB/s", p.compress_gbps),
            format!("{:.3}ns", p.s_per_elem * 1e9),
            format!("{:.1}", p.allocs_per_step()),
        ]);
        profiles.push(p);
    }
    t.print(&format!(
        "perf — per-rank hot path ({world} workers x {tensors} tensors x {n} elems)"
    ));

    // The issue's acceptance: zero steady-state heap allocations on the
    // compress→encode→combine path for at least covap/topk/signsgd/fp16
    // (the dense baseline rides along for free; DGC/Random-k have
    // data-dependent selection sizes and the replicated schemes allocate
    // internally — reported above, not asserted).
    for must_be_zero in ["COVAP", "Top-k", "EFsignSGD", "FP16", "DDPovlp"] {
        let p = profiles.iter().find(|p| p.label == must_be_zero).expect("scheme present");
        assert!(
            p.steady_allocs == 0,
            "{}: {} allocations over {} steady-state steps (must be 0)",
            p.label,
            p.steady_allocs,
            p.measured_steps
        );
    }

    // Table II ordering: COVAP's measured per-element overhead is the
    // lowest of all compression schemes (the uncompressed baseline is the
    // no-op row the paper reports as 0).
    let covap = profiles.iter().find(|p| p.label == "COVAP").expect("covap present");
    for p in profiles.iter().filter(|p| p.label != "COVAP" && p.label != "DDPovlp") {
        assert!(
            covap.s_per_elem < p.s_per_elem,
            "COVAP {:.3}ns/elem must undercut {} {:.3}ns/elem (Table II ordering)",
            covap.s_per_elem * 1e9,
            p.label,
            p.s_per_elem * 1e9
        );
    }
    println!(
        "\nzero-alloc steady state: OK (covap/topk/signsgd/fp16 + baseline); \
         COVAP overhead lowest: OK"
    );

    // Observability must not erode the guarantee just asserted: a disabled
    // log site costs zero allocations, and trace capture (when someone
    // turns it on) stays bounded per event.
    obs_overhead_checks();

    // publish the headline number into the shared registry so the bench
    // envelope's "metrics" field carries it too
    let total_steady: u64 = profiles.iter().map(|p| p.steady_allocs).sum();
    covap::obs::with_global(|r| r.counter_add("bench_steady_allocs", total_steady));

    // machine-readable artifact for the CI trajectory
    let rows: Vec<Json> = profiles
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("scheme", Json::from(p.label)),
                ("elems", Json::from(n)),
                ("world", Json::from(world)),
                ("tensors", Json::from(tensors)),
                ("compress_gbps", Json::from(p.compress_gbps)),
                ("s_per_elem", Json::from(p.s_per_elem)),
                ("allocs_per_step", Json::from(p.allocs_per_step())),
                ("warmup_allocs", Json::from(p.warmup_allocs as usize)),
            ])
        })
        .collect();
    let meta = BenchMeta::new(iso_timestamp_now())
        .scheme("sweep")
        .topology("ring")
        .backend("inline");
    write_bench_doc(&json_path, "perf_hotpath", &meta, rows)?;
    covap::log_info!(target: "bench", "wrote {}", json_path.display());

    if !quick {
        legacy_micro_benches();
    }
    Ok(())
}

/// DESIGN.md §10 acceptance: observability is free when off and bounded
/// when on.
///
/// * A log site below the active level must cost **zero** heap
///   allocations — the macro gates on one relaxed atomic load before
///   touching `format_args!`, so the (allocating) message expression is
///   never evaluated.
/// * With tracing on, `TraceBuilder::complete` allocates only the event's
///   own JSON object — bounded per event, and nothing on the
///   compress→encode→combine path itself (the engine stamps at step
///   granularity).
fn obs_overhead_checks() {
    use covap::obs::{log, TraceBuilder, TID_COMPUTE};

    // 1) disabled log sites are alloc-free
    let prev = log::level();
    log::set_level(log::LogLevel::Warn);
    let before = allocs();
    for i in 0..1000u64 {
        covap::log_debug!(
            target: "bench",
            "never formatted: {}",
            format!("step {}", sink(i)) // would allocate if evaluated
        );
        covap::log_info!(target: "bench", "also below Warn: {}", sink(i));
    }
    let disabled_allocs = allocs() - before;
    log::set_level(prev);
    assert!(
        disabled_allocs == 0,
        "disabled log sites made {disabled_allocs} allocations over 2000 calls (must be 0)"
    );

    // 2) trace capture is bounded: after a warm-up event, N complete()
    // calls cost at most a fixed number of allocations each
    let mut tb = TraceBuilder::new();
    tb.complete(0, TID_COMPUTE, "warm", "measured", 0.0, 1e-6, vec![("tensor", Json::from(0usize))]);
    tb.end_step();
    let events = 256u64;
    let before = allocs();
    for i in 0..events {
        tb.complete(
            0,
            TID_COMPUTE,
            "compute",
            "measured",
            i as f64 * 1e-6,
            (i + 1) as f64 * 1e-6,
            vec![("tensor", Json::from(i as usize)), ("step", Json::from(0usize))],
        );
    }
    let per_event = (allocs() - before) as f64 / events as f64;
    sink(tb.len());
    assert!(
        per_event <= 64.0,
        "trace capture cost {per_event:.1} allocations/event (bound: 64)"
    );
    println!(
        "obs overhead: disabled log sites 0 allocs; trace capture {per_event:.1} allocs/event (<= 64)"
    );
}

/// The original L3 micro-benchmarks (filter decision, f16 conversion,
/// in-place ring) — full mode only.
fn legacy_micro_benches() {
    let n = 1 << 22; // 4 Mi elements = 16 MiB
    let mut rng = Rng::seed(0xBE7C);
    let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

    let mut t = Table::new(&["hot path", "median", "throughput"]);

    // COVAP filter decision: O(1) per tensor
    let filter = CoarseFilter::new(4);
    let s = time_fn(3, 200, || {
        let mut keep = 0usize;
        for tensor in 0..1024usize {
            keep += usize::from(filter.keep(tensor, sink(7)));
        }
        keep
    });
    t.row(&[
        "COVAP filter (1024 tensors)".into(),
        format!("{:.2}µs", s.median_s * 1e6),
        format!("{:.1}ns/tensor", s.median_s * 1e9 / 1024.0),
    ]);

    // f16 pack+unpack
    let s = time_fn(2, 10, || {
        let mut acc = 0.0f32;
        for &x in &g[..1 << 20] {
            acc += f16_to_f32(f32_to_f16(x));
        }
        acc
    });
    t.row(&[
        "f32->f16->f32 roundtrip (1Mi)".into(),
        format!("{:.2}ms", s.median_s * 1e3),
        format!("{:.2} GB/s", s.gbps(1 << 22)),
    ]);

    // ring allreduce, 4 ranks x 4Mi
    let bufs: Vec<Vec<f32>> = (0..4).map(|w| g.iter().map(|x| x * (w as f32 + 1.0)).collect()).collect();
    let s = time_fn(1, 5, || {
        let mut b = bufs.clone();
        ring_allreduce(&mut b);
        b[0][0]
    });
    t.row(&[
        "ring allreduce (4 ranks, 16MiB)".into(),
        format!("{:.2}ms", s.median_s * 1e3),
        format!("{:.2} GB/s", s.gbps(4 * n * 4)),
    ]);

    t.print("perf — L3 legacy hot paths (1-core testbed)");
}
