//! §Perf — L3 hot-path micro-benchmarks (the data behind EXPERIMENTS.md
//! §Perf): compressor throughputs, filter decision cost, EF accumulate
//! bandwidth, ring allreduce bandwidth, f16 pack/unpack.

use covap::comm::ring_allreduce;
use covap::compress::{f16_to_f32, f32_to_f16, SchemeKind};
use covap::covap::CoarseFilter;
use covap::util::bench::{sink, time_fn, Table};
use covap::util::rng::Rng;

fn main() {
    let n = 1 << 22; // 4 Mi elements = 16 MiB
    let mut rng = Rng::seed(0xBE7C);
    let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

    let mut t = Table::new(&["hot path", "median", "throughput"]);

    // COVAP filter decision: O(1) per tensor
    let filter = CoarseFilter::new(4);
    let s = time_fn(3, 200, || {
        let mut keep = 0usize;
        for tensor in 0..1024usize {
            keep += usize::from(filter.keep(tensor, sink(7)));
        }
        keep
    });
    t.row(&[
        "COVAP filter (1024 tensors)".into(),
        format!("{:.2}µs", s.median_s * 1e6),
        format!("{:.1}ns/tensor", s.median_s * 1e9 / 1024.0),
    ]);

    // scheme round throughput (1 worker, includes EF where applicable)
    for kind in [
        SchemeKind::Covap { interval: 1, ef: Default::default() },
        SchemeKind::Fp16,
        SchemeKind::TopK { ratio: 0.01 },
        SchemeKind::Dgc { ratio: 0.001 },
        SchemeKind::RandomK { ratio: 0.01 },
        SchemeKind::EfSignSgd,
        SchemeKind::PowerSgd { rank: 1 },
        SchemeKind::OkTopk { ratio: 0.01 },
    ] {
        let mut scheme = kind.build(1, 1);
        let refs: Vec<&[f32]> = vec![&g];
        let mut step = 0u64;
        let s = time_fn(1, 5, || {
            let (u, _) = scheme.round(0, step, &refs);
            step += 1;
            u[0]
        });
        t.row(&[
            format!("{} round (4Mi elems)", kind.label()),
            format!("{:.2}ms", s.median_s * 1e3),
            format!("{:.2} GB/s", s.gbps(n * 4)),
        ]);
    }

    // f16 pack+unpack
    let s = time_fn(2, 10, || {
        let mut acc = 0.0f32;
        for &x in &g[..1 << 20] {
            acc += f16_to_f32(f32_to_f16(x));
        }
        acc
    });
    t.row(&[
        "f32->f16->f32 roundtrip (1Mi)".into(),
        format!("{:.2}ms", s.median_s * 1e3),
        format!("{:.2} GB/s", s.gbps(1 << 22)),
    ]);

    // ring allreduce, 4 ranks x 4Mi
    let bufs: Vec<Vec<f32>> = (0..4).map(|w| g.iter().map(|x| x * (w as f32 + 1.0)).collect()).collect();
    let s = time_fn(1, 5, || {
        let mut b = bufs.clone();
        ring_allreduce(&mut b);
        b[0][0]
    });
    t.row(&[
        "ring allreduce (4 ranks, 16MiB)".into(),
        format!("{:.2}ms", s.median_s * 1e3),
        format!("{:.2} GB/s", s.gbps(4 * n * 4)),
    ]);

    t.print("perf — L3 hot paths (1-core testbed)");
}
