//! service_capacity — tenant-count sweep on the shared-fabric service.
//!
//! The multi-tenant payoff of overlapping-aware compression (DESIGN.md
//! §14): on a shared inter-node fabric, each of `N` overlapping tenants
//! sees `base/N` of the spine, so a dense tenant's step time degrades
//! like `C + N·M` while a compressed tenant's degrades like `C + N·m`
//! with `m ≈ M/I` — COVAP flattens the contention slope. This bench
//! sweeps the tenant count for baseline (dense DDP), fp16 and
//! covap@auto on one cluster and finds, per scheme, the largest tenant
//! count whose **tail time-to-solution** stays within a fixed budget
//! (anchored at a multiple of the solo dense run). Acceptance: COVAP
//! sustains strictly more tenants than the dense baseline within the
//! same budget.
//!
//!     cargo bench --bench service_capacity -- [--quick]
//!         [--json BENCH_service_capacity.json] [--budget-factor F]
//!
//! Analytic backend, virtual time — the whole sweep is deterministic.
//! Emits BENCH_service_capacity.json: one row per (scheme, tenants)
//! cell plus a per-scheme summary row with the sustained tenant count.

use std::path::PathBuf;

use covap::compress::SchemeKind;
use covap::harness::{iso_timestamp_now, write_bench_doc, BenchMeta};
use covap::network::ClusterSpec;
use covap::service::{run_trace, JobSpec, ServiceReport, ServiceSpec};
use covap::util::bench::Table;
use covap::util::cli::Args;
use covap::util::fmt_secs;
use covap::util::json::Json;

/// One shared cluster for the whole sweep: every tenant gang-schedules
/// 4 ranks over 2 nodes, so 6 tenants fill the fabric side by side and
/// all of them contend for the one spine.
const CLUSTER: (usize, usize) = (12, 2);
const BASE_GBPS: f64 = 1.0;

fn sweep(quick: bool) -> &'static [usize] {
    if quick {
        &[1, 2, 4]
    } else {
        &[1, 2, 3, 4, 6]
    }
}

fn trace(scheme: &SchemeKind, tenants: usize, steps: u64) -> ServiceSpec {
    let jobs = (0..tenants)
        .map(|i| {
            let mut j = JobSpec::new(i, &format!("tenant-{i}"), scheme.clone(), 4);
            j.nodes = 2;
            j.steps = steps;
            j
        })
        .collect();
    ServiceSpec {
        cluster: ClusterSpec::new(CLUSTER.0, CLUSTER.1),
        base_gbps: BASE_GBPS,
        jobs,
    }
}

fn mean_exposed_s(r: &ServiceReport) -> f64 {
    r.jobs.iter().map(|j| j.sim_exposed_s).sum::<f64>() / r.jobs.len() as f64
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let quick = args.has("quick");
    let budget_factor: f64 = args.get_parsed("budget-factor", 2.5)?;
    let json_path = PathBuf::from(args.get_or("json", "BENCH_service_capacity.json"));
    let steps: u64 = if quick { 4 } else { 8 };

    let schemes: Vec<(&str, SchemeKind)> = vec![
        ("baseline", SchemeKind::Baseline),
        ("fp16", SchemeKind::Fp16),
        ("covap@auto", SchemeKind::parse("covap@auto").expect("spec")),
    ];

    // The budget every scheme is held to: a multiple of the *dense solo*
    // tail TTS — the "users tolerate this much slowdown of the
    // uncontended dense run" line.
    let solo_dense = run_trace(trace(&schemes[0].1, 1, steps))?;
    let budget_s = budget_factor * solo_dense.tail_tts_s();
    println!(
        "service_capacity: {}x{} cluster @ {} Gbps, {} steps/job, \
         tail-TTS budget {} ({}x dense solo)",
        CLUSTER.0,
        CLUSTER.1,
        BASE_GBPS,
        steps,
        fmt_secs(budget_s),
        budget_factor
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut t = Table::new(&["scheme", "tenants", "tail tts", "mean exposed", "fabric load", "fits"]);
    let mut sustained: Vec<(&str, usize)> = Vec::new();
    for (label, scheme) in &schemes {
        let mut max_fit = 0usize;
        for &n in sweep(quick) {
            let report = run_trace(trace(scheme, n, steps))?;
            assert_eq!(report.jobs.len(), n, "{label}: tenant starved at n={n}");
            let tail = report.tail_tts_s();
            let fits = tail <= budget_s;
            if fits {
                max_fit = max_fit.max(n);
            }
            t.row(&[
                label.to_string(),
                n.to_string(),
                fmt_secs(tail),
                fmt_secs(mean_exposed_s(&report)),
                format!("{:.2}", report.fabric_load),
                if fits { "yes".into() } else { "no".into() },
            ]);
            rows.push(Json::obj(vec![
                ("scheme", Json::from(*label)),
                ("tenants", Json::from(n)),
                ("steps", Json::from(steps as usize)),
                ("tail_tts_s", Json::from(tail)),
                ("mean_exposed_s", Json::from(mean_exposed_s(&report))),
                ("makespan_s", Json::from(report.makespan_s)),
                ("fabric_load", Json::from(report.fabric_load)),
                ("gpu_utilization", Json::from(report.gpu_utilization)),
                ("budget_s", Json::from(budget_s)),
                ("fits_budget", Json::from(fits)),
            ]));
        }
        sustained.push((label, max_fit));
        rows.push(Json::obj(vec![
            ("summary", Json::from(1usize)),
            ("scheme", Json::from(*label)),
            ("sustained_tenants", Json::from(max_fit)),
            ("budget_s", Json::from(budget_s)),
            ("budget_factor", Json::from(budget_factor)),
        ]));
    }
    t.print("service capacity — tail TTS by scheme x tenant count (virtual time)");

    let mut s = Table::new(&["scheme", "sustained tenants"]);
    for (label, n) in &sustained {
        s.row(&[label.to_string(), n.to_string()]);
    }
    s.print(&format!("tenants sustained within {} tail-TTS budget", fmt_secs(budget_s)));

    let meta = BenchMeta::new(iso_timestamp_now())
        .scheme("sweep")
        .topology("auto")
        .backend("analytic");
    write_bench_doc(&json_path, "service_capacity", &meta, rows)?;
    println!("wrote {}", json_path.display());

    // ---- acceptance criteria (multi-tenant capacity bench) ----
    let by = |name: &str| sustained.iter().find(|(l, _)| *l == name).map(|(_, n)| *n).unwrap();
    let (base_n, fp16_n, covap_n) = (by("baseline"), by("fp16"), by("covap@auto"));
    assert!(base_n >= 1, "dense solo run must fit its own budget");
    assert!(
        covap_n > base_n,
        "covap@auto must sustain strictly more tenants than dense baseline \
         within the {budget_factor}x budget (covap {covap_n} vs baseline {base_n})"
    );
    assert!(
        covap_n >= fp16_n,
        "covap@auto should not sustain fewer tenants than fp16 \
         (covap {covap_n} vs fp16 {fp16_n})"
    );
    println!(
        "OK: sustained tenants baseline={base_n} fp16={fp16_n} covap@auto={covap_n} \
         within {} ({}x dense solo)",
        fmt_secs(budget_s),
        budget_factor
    );
    Ok(())
}
