//! Table II — compression overheads and communication-time reductions of
//! the GC schemes on VGG-19 (143.65 M gradients, 64 GPUs, 30 Gbps).
//!
//! Two overhead columns:
//!   * `ours` — this build's rust compressors, measured on real N(0,1)
//!     gradients at 2^22 elements and extrapolated linearly to model size,
//!     GPU-calibrated via the FP16 anchor (see harness::calibrated_profiles).
//!   * `paper` — the paper's measured numbers (their PyTorch/CUDA and
//!     mpi4py implementations).
//!
//! The comm-reduction column is the network model: dense allreduce time
//! minus the scheme's compressed collective time.

use covap::compress::SchemeKind;
use covap::harness::{
    calibrated_profiles, collective_of, paper_profile, rounds_of, wire_bytes,
};
use covap::network::{ClusterSpec, NetworkModel};
use covap::util::bench::Table;
use covap::workload;

fn main() {
    let w = workload::vgg19();
    let n = w.total_params();
    let net = NetworkModel::default();
    let cluster = ClusterSpec::ecs(64);
    let dense_s = net.allreduce_s(n * 4, cluster);

    let kinds: Vec<SchemeKind> = SchemeKind::evaluation_set()
        .into_iter()
        .filter(|k| !matches!(k, SchemeKind::Baseline))
        .collect();
    println!("measuring native compressor throughput (2^22-element sample)...");
    let profiles = calibrated_profiles(&kinds, 1 << 22, 3);

    let paper_rows = [
        ("Top-k", "k=1%", 1560.0, 603.0),
        ("DGC", "k=0.1%", 25.0, 747.0),
        ("Random-k", "k=1%", 200.0, 653.0),
        ("FP16", "-", 5.0, 423.0),
        ("EFsignSGD", "-", 20.0, -210.0),
        ("PowerSGD", "rank=1", 20.0, 753.0),
        ("Ok-topk", "k=1%", 500.0, 674.0),
        ("COVAP", "I=4", 0.0, f64::NAN),
    ];

    let mut t = Table::new(&[
        "scheme", "hyper", "T_compress ours", "T_compress paper",
        "comm reduction ours", "comm reduction paper",
    ]);
    for (kind, prof) in &profiles {
        let label = kind.label();
        let Some(&(_, hyper, p_compress, p_red)) =
            paper_rows.iter().find(|(l, ..)| *l == label)
        else {
            continue;
        };
        // compressed collective time over the whole model
        let wire = match kind {
            SchemeKind::Covap { interval, .. } => {
                // per-iteration average: 1/I of the model goes out densely
                (wire_bytes(kind, n) as f64 / *interval as f64) as usize
            }
            k => wire_bytes(k, n),
        };
        let (rounds, syncs, _dep) = rounds_of(kind);
        let comm_s = match collective_of(kind) {
            covap::compress::CollectiveOp::AllReduce => net.allreduce_s(wire, cluster),
            covap::compress::CollectiveOp::AllGather => net.allgather_s(wire, cluster),
        } * rounds as f64
            + syncs as f64 * net.sync_round_s(cluster);
        let ours_compress_ms = prof.s_per_elem * n as f64 * 1e3;
        let ours_red_ms = (dense_s - comm_s) * 1e3;
        t.row(&[
            label.to_string(),
            hyper.to_string(),
            format!("{ours_compress_ms:.1}ms"),
            format!("{p_compress:.0}ms"),
            format!("{ours_red_ms:.0}ms"),
            if p_red.is_nan() { "-".into() } else { format!("{p_red:.0}ms") },
        ]);
        // sanity: paper_profile replays the Table II overheads (COVAP has
        // no paper number — "close to zero" — so allow its 2 ms stand-in)
        let pp = paper_profile(kind);
        assert!((pp.s_per_elem * 143_652_544.0 - p_compress / 1e3).abs() <= 2e-3 + 1e-9);
    }
    t.print("Table II — compression overhead & comm reduction (VGG-19, 64 GPUs)");
    println!("\nShape checks vs paper: Top-k is the most expensive compressor; DGC ~ an");
    println!("order cheaper; COVAP's filter cost is near zero; EFsignSGD's allgather");
    println!("*increases* communication time at this scale (negative reduction).");
    println!("Our native Ok-topk is much faster than the paper's mpi4py reimplementation.");
}
