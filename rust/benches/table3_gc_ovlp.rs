//! Table III — applying GC and Overlapping concurrently (ResNet-101):
//! Random-k and FP16 reduce CCR to ~1 and push DP near linear scaling.
//!
//! Paper row (ResNet-101, CCR 2.1, S_LS 2.67):
//!   Random-k: CCR after 1.07, S_GC 1.29x, S_GC&ovlp 2.05x
//!   FP16:     CCR after 1.04, S_GC 1.42x, S_GC&ovlp 2.35x

use covap::compress::CollectiveOp;
use covap::harness::{bucket_comp_fractions, workload_buckets};
use covap::network::{ClusterSpec, NetworkModel};
use covap::sim::{simulate_iteration, Breakdown, Policy, TensorCost};
use covap::util::bench::Table;
use covap::workload;

fn main() {
    let w = workload::resnet101();
    let net = NetworkModel::default();
    let cluster = ClusterSpec::ecs(64);
    let t_ls = w.t_before_s + w.t_comp_s;

    // Table III needs Random-k in its AllReduce-compatible form: all
    // workers draw the SAME indices from a shared seed (our implementation
    // does — compress::RandomK), so the k values are summable in-network.
    // ratio 0.25 with (idx,val) wire = half the dense bytes -> the paper's
    // "CCR after ~ 1.07" regime.
    //
    // (scheme label, wire bytes per element, compression overhead per iter)
    let rows: [(&str, f64, f64); 2] = [
        ("Random-k", 0.25 * 8.0 / 4.0, 0.200 * 44_654_504.0 / 143_652_544.0),
        ("FP16", 0.5, 0.005 * 44_654_504.0 / 143_652_544.0),
    ];

    let breakdown = |wire_per_byte: f64, compress_total: f64, policy: Policy| -> Breakdown {
        let buckets = workload_buckets(&w);
        let fracs = bucket_comp_fractions(&w, &buckets);
        let total: usize = buckets.iter().sum();
        let costs: Vec<TensorCost> = buckets
            .iter()
            .zip(fracs.iter())
            .map(|(&n, &f)| TensorCost {
                comp_s: w.t_comp_s * f,
                compress_s: compress_total * n as f64 / total as f64,
                wire_bytes: (n as f64 * 4.0 * wire_per_byte) as usize,
                collective: CollectiveOp::AllReduce,
                rounds: 1,
                sync_rounds: 0,
                data_dependency: false,
            })
            .collect();
        simulate_iteration(&net, cluster, w.t_before_s, &costs, policy)
    };

    let mut t = Table::new(&[
        "scheme", "CCR", "CCR after", "S_GC", "S_GC&ovlp", "S_LS",
        "paper S_GC", "paper S_GC&ovlp",
    ]);
    let paper = [("Random-k", 1.29, 2.05), ("FP16", 1.42, 2.35)];
    let base_seq = breakdown(1.0, 0.0, Policy::Sequential);
    for (label, wire, compress) in rows {
        let seq = breakdown(wire, compress, Policy::Sequential);
        let ovl = breakdown(wire, compress, Policy::Overlap);
        let ccr_after = seq.t_comm_s / w.t_comp_s;
        let (p_gc, p_ovlp) = paper
            .iter()
            .find(|(l, ..)| *l == label)
            .map(|&(_, a, b)| (a, b))
            .unwrap();
        t.row(&[
            label.to_string(),
            format!("{:.2}", w.ccr(&net, cluster)),
            format!("{ccr_after:.2}"),
            format!("{:.2}x", base_seq.total_s / seq.total_s),
            format!("{:.2}x", base_seq.total_s / ovl.total_s),
            format!("{:.2}x", base_seq.total_s / t_ls),
            format!("{p_gc:.2}x"),
            format!("{p_ovlp:.2}x"),
        ]);
    }
    t.print("Table III — GC + Overlapping concurrently (ResNet-101, 64 GPUs)");
    println!("\nShape check: combining GC with Overlapping (S_GC&ovlp) recovers most of");
    println!("the linear-scaling headroom that either technique alone leaves on the table.");
}
