//! Tables IV & V — VGG-19 layer sizes and communication-tensor times.
//!
//! Table IV: per-layer parameter counts and their share of the model
//! (FC1 = 102,760,448 = 71.53%). Table V: the six DDP communication
//! buckets observed in 8-node training, their element counts and
//! communication times (tensor 3 = 603 ms = 72.67% of 830 ms).

use covap::network::{ClusterSpec, NetworkModel};
use covap::util::bench::Table;
use covap::workload;

fn main() {
    let w = workload::vgg19();
    let total = w.total_params();
    let weights_total: usize = w
        .layers
        .iter()
        .filter(|l| l.name.ends_with(".weight"))
        .map(|l| l.numel)
        .sum();

    // ---- Table IV (the paper lists the big FC layers explicitly) ----
    let mut t4 = Table::new(&["layer", "parameters", "ratio", "paper ratio"]);
    for (name, paper_ratio) in [
        ("conv1_1.weight", "0.00%"),
        ("conv1_2.weight", "0.03%"),
        ("fc1.weight", "71.53%"),
        ("fc2.weight", "11.68%"),
        ("fc3.weight", "2.85%"),
    ] {
        let l = w.layers.iter().find(|l| l.name == name).unwrap();
        t4.row(&[
            name.to_string(),
            format!("{}", l.numel),
            format!("{:.2}%", 100.0 * l.numel as f64 / weights_total as f64),
            paper_ratio.to_string(),
        ]);
    }
    t4.row(&[
        "total (weights)".into(),
        format!("{weights_total}"),
        "100.00%".into(),
        "100.00%".into(),
    ]);
    t4.print("Table IV — VGG-19 layer sizes");
    assert_eq!(weights_total, 143_652_544, "Table IV total must match digit-for-digit");

    // ---- Table V ----
    let net = NetworkModel::default();
    let cluster = ClusterSpec::ecs(64); // 8 nodes
    let buckets = w.paper_buckets.clone().unwrap();
    let total_comm: f64 = buckets.iter().map(|&n| net.allreduce_s(n * 4, cluster)).sum();
    let paper_ms = [16.177, 99.205, 603.238, 36.513, 40.743, 34.218];
    let mut t5 = Table::new(&[
        "tensor", "elements", "comm time", "ratio", "paper time", "paper ratio",
    ]);
    for (i, (&n, &pms)) in buckets.iter().zip(paper_ms.iter()).enumerate() {
        let s = net.allreduce_s(n * 4, cluster);
        t5.row(&[
            format!("{}", i + 1),
            format!("{n}"),
            format!("{:.1}ms", s * 1e3),
            format!("{:.2}%", 100.0 * s / total_comm),
            format!("{pms:.1}ms"),
            format!("{:.2}%", 100.0 * pms / 830.094),
        ]);
    }
    t5.row(&[
        "total".into(),
        format!("{}", total),
        format!("{:.1}ms", total_comm * 1e3),
        "100.00%".into(),
        "830.1ms".into(),
        "100.00%".into(),
    ]);
    t5.print("Table V — VGG-19 communication tensors (8 nodes, 30 Gbps)");
    println!("\nShape check: tensor 3 (FC1's bucket) dominates total communication —");
    println!("the imbalance COVAP's tensor sharding (§III.C) removes.");
}
