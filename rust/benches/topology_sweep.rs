//! topology_sweep — exposed communication of ring vs hier vs tree.
//!
//! Modeled half: for cluster shapes 1×8 / 4×8 / 16×8 and schemes
//! baseline, fp16, covap@auto (priced at its auto-selected interval),
//! run the timeline simulator under every topology and report exposed
//! communication plus the per-level wire-byte split the hop schedules
//! account. Measured half: run the threaded executor (paced, 2-level
//! fabric emulation) on a real rank fleet for the dense baseline under
//! ring vs hier and compare measured exposed communication.
//!
//! Asserts the PR's acceptance criterion: on a 4×8 `ClusterSpec` the
//! hierarchical topology's modeled AND measured exposed comm beat the
//! flat ring for the dense baseline, and every measured cell stays
//! bitwise-equal across backends.
//!
//!     cargo bench --bench topology_sweep -- [--quick] [--dnn VGG-19]
//!         [--steps N] [--json BENCH_topology.json]
//!
//! Emits a machine-readable BENCH_topology.json via
//! `harness::write_bench_doc`.

use std::path::PathBuf;

use covap::comm::TopologyKind;
use covap::compress::SchemeKind;
use covap::config::RunConfig;
use covap::covap::interval_from_ccr;
use covap::exec::compare_backends;
use covap::harness::{
    iso_timestamp_now, paper_profile, scheme_breakdown, scheme_level_bytes, write_bench_doc,
    BenchMeta,
};
use covap::network::{ClusterSpec, NetworkModel};
use covap::sim::Policy;
use covap::util::bench::Table;
use covap::util::cli::Args;
use covap::util::json::Json;
use covap::util::{fmt_bytes, fmt_secs};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let quick = args.has("quick");
    let steps: u64 = args.get_parsed("steps", if quick { 3 } else { 4 })?;
    let json_path = PathBuf::from(args.get_or("json", "BENCH_topology.json"));
    let name = args.get_or("dnn", "VGG-19");
    let w = covap::workload::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown DNN '{name}'"))?;
    let net = NetworkModel::default();
    let mut rows: Vec<Json> = Vec::new();

    // ---- modeled sweep: shapes x topologies x schemes ----
    let shapes = [
        ClusterSpec::new(1, 8),
        ClusterSpec::new(4, 8),
        ClusterSpec::new(16, 8),
    ];
    let mut t = Table::new(&[
        "cluster", "topology", "scheme", "exposed", "total", "inter B/step", "intra B/step",
    ]);
    // exposed comm of (cluster, topology) for the acceptance assertion
    let mut baseline_exposed: Vec<(usize, &'static str, f64)> = Vec::new();
    for &cluster in &shapes {
        let schemes = [
            ("baseline", SchemeKind::Baseline),
            ("fp16", SchemeKind::Fp16),
            (
                "covap@auto",
                SchemeKind::Covap {
                    interval: interval_from_ccr(w.ccr(&net, cluster)),
                    ef: Default::default(),
                },
            ),
        ];
        for topo_kind in TopologyKind::all() {
            let topo = topo_kind.resolve(cluster);
            for (label, kind) in &schemes {
                let prof = paper_profile(kind);
                let b = scheme_breakdown(&w, kind, &prof, &net, cluster, topo, Policy::Overlap);
                let lb = scheme_level_bytes(&w, kind, topo, cluster);
                if *label == "baseline" {
                    baseline_exposed.push((
                        cluster.nodes,
                        topo_kind.spec(),
                        b.t_comm_exposed_s,
                    ));
                }
                t.row(&[
                    format!("{}x{}", cluster.nodes, cluster.gpus_per_node),
                    topo_kind.spec().to_string(),
                    label.to_string(),
                    fmt_secs(b.t_comm_exposed_s),
                    fmt_secs(b.total_s),
                    fmt_bytes(lb.inter),
                    fmt_bytes(lb.intra),
                ]);
                rows.push(Json::obj(vec![
                    ("mode", Json::from("modeled")),
                    ("dnn", Json::from(w.name)),
                    ("nodes", Json::from(cluster.nodes)),
                    ("gpus_per_node", Json::from(cluster.gpus_per_node)),
                    ("topology", Json::from(topo_kind.spec())),
                    ("scheme", Json::from(*label)),
                    ("exposed_s", Json::from(b.t_comm_exposed_s)),
                    ("total_s", Json::from(b.total_s)),
                    ("speedup", Json::from(b.speedup(cluster.world()))),
                    ("wire_inter_bytes", Json::from(lb.inter)),
                    ("wire_intra_bytes", Json::from(lb.intra)),
                ]));
            }
        }
    }
    t.print(&format!("topology sweep — modeled, {} @ 30 Gbps", w.name));

    // acceptance (modeled half): hier beats ring at 4x8 for the baseline
    let modeled_of = |topo: &str| -> f64 {
        baseline_exposed
            .iter()
            .find(|(n, t, _)| *n == 4 && *t == topo)
            .map(|(_, _, e)| *e)
            .expect("4x8 row present")
    };
    assert!(
        modeled_of("hier") < modeled_of("ring"),
        "4x8 modeled exposed comm: hier {:.4}s must beat ring {:.4}s",
        modeled_of("hier"),
        modeled_of("ring")
    );

    // ---- measured sweep: dense baseline on a real rank fleet ----
    // Emulated 2-level fabric: slow inter wire, 10x faster intra fabric
    // (the paper's order-of-magnitude NIC/PCIe gap).
    let cluster = if quick {
        ClusterSpec::new(4, 2)
    } else {
        ClusterSpec::new(4, 8)
    };
    let mk_cfg = |topology: TopologyKind| -> RunConfig {
        let mut cfg = RunConfig {
            workers: cluster.world(),
            cluster,
            scheme: SchemeKind::Baseline,
            topology,
            optimizer: covap::config::Optimizer::Sgd,
            lr: 0.05,
            seed: 7,
            bucket_bytes: 16 * 1024,
            pace_gbps: 0.3,
            ..RunConfig::default()
        };
        cfg.net.intra_gbps = 96.0; // intra_bps / effective_bps = 10x
        cfg
    };
    let mut t2 = Table::new(&[
        "topology", "bitwise", "meas exp'", "sim exp'", "moved/rank", "inter moved",
    ]);
    // Wall-clock ordering on a possibly oversubscribed box: retry shield,
    // same pattern as exec_parity.
    let mut ok = false;
    let mut last = (f64::NAN, f64::NAN);
    for attempt in 0..3usize {
        let ring = compare_backends(&mk_cfg(TopologyKind::Ring), "tiny", steps)?;
        let hier = compare_backends(&mk_cfg(TopologyKind::Hier), "tiny", steps)?;
        assert!(ring.bitwise_equal, "ring: threaded diverged from analytic");
        assert!(hier.bitwise_equal, "hier: threaded diverged from analytic");
        assert!(
            hier.measured.moved_inter_bytes < ring.measured.moved_inter_bytes,
            "hier must move fewer inter-node bytes ({} vs {})",
            hier.measured.moved_inter_bytes,
            ring.measured.moved_inter_bytes
        );
        if attempt == 0 {
            for (label, c) in [("ring", &ring), ("hier", &hier)] {
                t2.row(&[
                    label.to_string(),
                    if c.bitwise_equal { "yes".into() } else { "NO".into() },
                    fmt_secs(c.measured.exposed_s),
                    fmt_secs(c.sim.t_comm_exposed_s),
                    fmt_bytes(c.measured.moved_bytes),
                    fmt_bytes(c.measured.moved_inter_bytes),
                ]);
            }
        }
        for (label, c) in [("ring", &ring), ("hier", &hier)] {
            rows.push(Json::obj(vec![
                ("mode", Json::from("measured")),
                ("nodes", Json::from(cluster.nodes)),
                ("gpus_per_node", Json::from(cluster.gpus_per_node)),
                ("topology", Json::from(label)),
                ("scheme", Json::from("baseline")),
                ("attempt", Json::from(attempt)),
                ("measured_exposed_s", Json::from(c.measured.exposed_s)),
                ("sim_exposed_s", Json::from(c.sim.t_comm_exposed_s)),
                ("measured_wall_s", Json::from(c.measured.wall_s)),
                ("moved_bytes", Json::from(c.measured.moved_bytes)),
                ("moved_inter_bytes", Json::from(c.measured.moved_inter_bytes)),
                ("bitwise_equal", Json::from(c.bitwise_equal)),
            ]));
        }
        last = (hier.measured.exposed_s, ring.measured.exposed_s);
        if hier.measured.exposed_s < ring.measured.exposed_s {
            ok = true;
            break;
        }
        covap::log_warn!(target: "bench", "attempt {attempt}: hier {last:?} not yet < ring, retrying");
    }
    t2.print(&format!(
        "topology sweep — measured, dense baseline, {}x{} paced fleet",
        cluster.nodes, cluster.gpus_per_node
    ));
    assert!(
        ok,
        "measured exposed comm: hier {:.4}s must beat flat ring {:.4}s (3 attempts)",
        last.0, last.1
    );

    let meta = BenchMeta::new(iso_timestamp_now())
        .scheme("sweep")
        .topology("sweep")
        .backend("both");
    write_bench_doc(&json_path, "topology", &meta, rows)?;
    println!("\nwrote {}", json_path.display());
    Ok(())
}
