//! trace_export — the CI driver for the unified trace layer (DESIGN.md
//! §10): run 4 threaded ranks under covap@auto with tracing on, export the
//! Chrome-Trace/Perfetto `trace.json`, and validate it against the schema
//! the `tests/trace_schema.rs` property suite enforces. The same config is
//! replayed on the analytic backend so both producers are exercised in one
//! job.
//!
//!     cargo bench --bench trace_export -- [--quick]
//!         [--out trace.json] [--json BENCH_trace_export.json]
//!
//! The exported file is the artifact CI uploads — drop it on
//! <https://ui.perfetto.dev> to see per-rank compute/comm streams, the
//! predicted analytic timeline, controller decisions, pacer changes and
//! wire-byte counters on one timeline.

use std::path::PathBuf;

use covap::compress::SchemeKind;
use covap::config::{ExecBackend, Optimizer, RunConfig};
use covap::coordinator::DpEngine;
use covap::covap::EfScheduler;
use covap::obs::validate_trace;
use covap::runtime::ModelArtifacts;
use covap::util::cli::Args;
use covap::util::json::Json;

fn traced_cfg(backend: ExecBackend, steps: u64, out: &PathBuf) -> RunConfig {
    RunConfig {
        workers: 4,
        scheme: SchemeKind::CovapAuto { ef: EfScheduler::constant(1.0) },
        backend,
        optimizer: Optimizer::Sgd,
        lr: 0.05,
        seed: 11,
        bucket_bytes: 16 * 1024,
        synth_work: 6,
        pace_gbps: 1.0,
        // mid-run bandwidth drop so a pacer instant lands in the trace
        pace_schedule: vec![(steps / 2, 0.5)],
        profile_steps: 2,
        profile_window: 2,
        profile_hysteresis: 1,
        steps,
        trace_out: Some(out.clone()),
        ..RunConfig::default()
    }
}

/// Run `steps` engine steps with tracing on; return the trace document and
/// the number of events in it.
fn run_traced(cfg: RunConfig) -> anyhow::Result<(Json, usize)> {
    let steps = cfg.steps;
    let mut engine = DpEngine::new(cfg, ModelArtifacts::synthetic("tiny"))?;
    for _ in 0..steps {
        engine.step()?;
    }
    let doc = engine.trace_json().expect("tracing was enabled");
    validate_trace(&doc)?;
    let n = doc.get("traceEvents")?.as_arr()?.len();
    engine.write_trace()?;
    Ok((doc, n))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let quick = args.has("quick");
    let out = PathBuf::from(args.get_or("out", "trace.json"));
    let json_path = PathBuf::from(args.get_or("json", "BENCH_trace_export.json"));
    let steps: u64 = if quick { 6 } else { 10 };

    // Threaded backend last: both runs write through the same --out path
    // and the uploaded artifact should be the one with measured ranks.
    let (_, analytic_events) =
        run_traced(traced_cfg(ExecBackend::Analytic, steps, &out))?;
    let (doc, threaded_events) =
        run_traced(traced_cfg(ExecBackend::Threaded, steps, &out))?;

    // The threaded trace must carry both producers: measured per-rank
    // spans and the predicted analytic timeline.
    let events = doc.get("traceEvents")?.as_arr()?;
    let has_cat = |cat: &str| {
        events.iter().any(
            |e| matches!(e.get_or("cat", &Json::Null), Json::Str(s) if s == cat),
        )
    };
    anyhow::ensure!(has_cat("measured"), "threaded trace must have measured spans");
    anyhow::ensure!(has_cat("predicted"), "threaded trace must have predicted spans");
    let instants = events
        .iter()
        .filter(|e| matches!(e.get_or("ph", &Json::Null), Json::Str(s) if s == "i"))
        .count();
    anyhow::ensure!(instants > 0, "covap@auto run must emit instant events");

    let rows = vec![Json::obj(vec![
        ("world", Json::from(4usize)),
        ("steps", Json::from(steps as usize)),
        ("scheme", Json::from("covap@auto")),
        ("analytic_events", Json::from(analytic_events)),
        ("threaded_events", Json::from(threaded_events)),
        ("instant_events", Json::from(instants)),
        ("trace_path", Json::from(out.to_string_lossy().as_ref())),
    ])];
    let meta = covap::harness::BenchMeta::new(covap::harness::iso_timestamp_now())
        .scheme("covap@auto")
        .topology("auto")
        .backend("both");
    covap::harness::write_bench_doc(&json_path, "trace_export", &meta, rows)?;
    covap::log_info!(target: "bench", "wrote {}", json_path.display());

    println!(
        "trace export OK: {threaded_events} events (threaded), {analytic_events} (analytic), \
         schema valid -> {}",
        out.display()
    );
    Ok(())
}
