//! Explicit-state model checker for the elastic membership protocol.
//!
//! A dependency-free, stateright-style breadth-first exploration of the
//! [`crate::analysis::model`] state machine: starting from
//! [`ProtocolState::initial`], every enabled [`Action`] is applied at
//! every reachable state, deduplicated through a hash set, until the
//! frontier empties or the state budget trips. Safety invariants are
//! checked inside [`ProtocolState::apply`] on **every** transition
//! (EF-mass conservation as exact token-multiset arithmetic,
//! exactly-once export, FIFO reconfigure/export ordering, uniform
//! torn-step skipping, stale-layout steps); liveness is checked by
//! classifying every terminal state (clean quiescence, no deadlock with
//! pending work).
//!
//! Because the model delegates every re-world decision through
//! [`Transitions::real`] to the production functions in
//! `coordinator::membership` and `exec::rank`, a clean sweep is a proof
//! about the shipped transition code at the explored bounds — and the
//! seeded mutants in [`mutants`] demonstrate the proof has teeth: each
//! swaps exactly one function pointer for a plausibly-wrong variant and
//! must be rejected with its own distinct [`ProtocolViolation`] kind.
//!
//! Entry points: `covap check-protocol` (world sweep + mutant
//! self-test, JSON report) and the `protocol_check` integration test.

use std::collections::HashSet;

use crate::analysis::model::{ProtocolState, ProtocolViolation, Script, Transitions};
use crate::coordinator::membership::{world_evolution, MembershipAction, MembershipEvent};

/// Exploration limits. `max_states` bounds memory, not correctness: if
/// it trips, the checker reports [`ProtocolViolation::StateBoundExceeded`]
/// rather than silently passing on a truncated space.
#[derive(Debug, Clone, Copy)]
pub struct Bounds {
    pub max_states: usize,
}

impl Default for Bounds {
    fn default() -> Bounds {
        Bounds { max_states: 500_000 }
    }
}

/// What one exhaustive exploration covered.
#[derive(Debug, Clone, Copy, Default)]
pub struct CheckReport {
    /// Distinct reachable states.
    pub states: usize,
    /// BFS frontier depth at exhaustion (longest shortest-path).
    pub depth: usize,
    /// Terminal (quiescent) states classified.
    pub terminals: usize,
    /// Transitions taken (edges explored, including duplicates).
    pub transitions: usize,
}

/// Aggregate over every auto-enumerated script of one world size.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorldReport {
    pub world: usize,
    pub scripts: usize,
    pub states: usize,
    pub max_depth: usize,
    pub terminals: usize,
    pub transitions: usize,
}

/// Exhaustively explore every interleaving of `script` under `t`.
/// `Ok` means every reachable state satisfied every invariant and every
/// terminal is a clean quiescence; `Err` carries the first (BFS-order,
/// deterministic) violation.
pub fn check_script(
    script: &Script,
    t: &Transitions,
    bounds: &Bounds,
) -> Result<CheckReport, ProtocolViolation> {
    let init = ProtocolState::initial(script);
    let mut seen: HashSet<ProtocolState> = HashSet::new();
    seen.insert(init.clone());
    let mut frontier = vec![init];
    let mut report = CheckReport::default();
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for state in &frontier {
            let actions = state.enabled_actions(script);
            if actions.is_empty() {
                report.terminals += 1;
                state.classify_terminal(script)?;
                continue;
            }
            for action in actions {
                report.transitions += 1;
                let succ = state.apply(action, script, t)?;
                if seen.insert(succ.clone()) {
                    if seen.len() > bounds.max_states {
                        return Err(ProtocolViolation::StateBoundExceeded {
                            states: seen.len(),
                        });
                    }
                    next.push(succ);
                }
            }
        }
        if !next.is_empty() {
            report.depth += 1;
        }
        frontier = next;
    }
    report.states = seen.len();
    Ok(report)
}

/// Auto-enumerate the event scripts the sweep proves: the quiet
/// baseline, every single scheduled fail/leave/join at every step
/// boundary (first and last rank — the two positions `redistribute`
/// treats differently), detected failures firing at *any* explored
/// point, and the validated two-event combinations (shrink-then-grow,
/// grow-then-shrink, double shrink, detected racing a scheduled join).
pub fn enumerate_scripts(world: usize, steps: u8) -> Vec<Script> {
    // exercise both branches of next_cluster across the sweep
    let gpn = if world % 2 == 0 { 2 } else { 1 };
    let mk = |scheduled: Vec<(u8, MembershipAction)>, detected: Vec<usize>| Script {
        world,
        gpn,
        steps,
        scheduled,
        detected,
    };
    let mut out = vec![mk(vec![], vec![])];

    let mut singles = vec![
        MembershipAction::Fail { rank: 0 },
        MembershipAction::Join { count: 1 },
    ];
    if world > 1 {
        singles.push(MembershipAction::Fail { rank: world - 1 });
        singles.push(MembershipAction::Leave { rank: 0 });
        singles.push(MembershipAction::Leave { rank: world - 1 });
    }
    for at in 0..steps {
        for &a in &singles {
            out.push(mk(vec![(at, a)], vec![]));
        }
    }

    // two scheduled events, kept only if the evolving world stays valid
    let pairs = [
        (MembershipAction::Fail { rank: 0 }, MembershipAction::Join { count: 1 }),
        (MembershipAction::Leave { rank: world.saturating_sub(1) }, MembershipAction::Join { count: 1 }),
        (MembershipAction::Join { count: 1 }, MembershipAction::Fail { rank: 0 }),
        (MembershipAction::Fail { rank: 0 }, MembershipAction::Fail { rank: 0 }),
    ];
    for &(a, b) in &pairs {
        let events = [
            MembershipEvent { at_step: 0, action: a },
            MembershipEvent { at_step: 1, action: b },
        ];
        if steps >= 2 && world_evolution(world, &events).is_ok() {
            out.push(mk(vec![(0, a), (1, b)], vec![]));
        }
    }

    // detected failures: may strike anywhere, including mid-barrier
    out.push(mk(vec![], vec![0]));
    if world > 1 {
        out.push(mk(vec![], vec![world - 1]));
    }
    if world >= 3 {
        out.push(mk(vec![], vec![0, 1]));
    }
    // a detected failure racing a scheduled join
    out.push(mk(vec![(0, MembershipAction::Join { count: 1 })], vec![0]));
    out
}

/// Run the full auto-enumerated sweep for one world size. On violation,
/// the label of the offending script rides along with the diagnosis.
pub fn check_world(
    world: usize,
    steps: u8,
    t: &Transitions,
    bounds: &Bounds,
) -> Result<WorldReport, (String, ProtocolViolation)> {
    let mut agg = WorldReport { world, ..WorldReport::default() };
    for script in enumerate_scripts(world, steps) {
        let rep =
            check_script(&script, t, bounds).map_err(|v| (script.label(), v))?;
        agg.scripts += 1;
        agg.states += rep.states;
        agg.max_depth = agg.max_depth.max(rep.depth);
        agg.terminals += rep.terminals;
        agg.transitions += rep.transitions;
    }
    Ok(agg)
}

/// Seeded mutants: each swaps exactly one [`Transitions`] pointer (or
/// flag) for a plausibly-wrong implementation of the same contract. The
/// checker must reject every one with the distinct violation kind named
/// in [`SELF_TEST_CASES`] — that is the proof the invariants are live.
pub mod mutants {
    use super::*;
    use crate::coordinator::membership;
    use crate::exec::rank::CmdTag;

    fn fold_into(slot: &mut Option<Vec<f32>>, orphan: &[f32]) {
        let dst = slot.get_or_insert_with(Vec::new);
        if dst.len() < orphan.len() {
            dst.resize(orphan.len(), 0.0);
        }
        for (d, o) in dst.iter_mut().zip(orphan) {
            *d += *o;
        }
    }

    fn redistribute_lost_orphan(
        mut states: Vec<Option<Vec<f32>>>,
        action: MembershipAction,
        _last_combined: &[f32],
    ) -> Vec<Option<Vec<f32>>> {
        match action {
            MembershipAction::Join { count } => {
                states.extend(std::iter::repeat_with(|| None).take(count));
                states
            }
            MembershipAction::Leave { rank } | MembershipAction::Fail { rank } => {
                // the bug: evict the rank, drop its residuals on the floor
                if rank < states.len() {
                    states.remove(rank);
                }
                states
            }
        }
    }

    /// Tentpole mutant 1: residuals of an evicted rank are never folded.
    pub fn lost_residual_on_eviction() -> Transitions {
        Transitions { redistribute: redistribute_lost_orphan, ..Transitions::real() }
    }

    fn quiesce_reconfigure_first(_action: MembershipAction) -> Vec<CmdTag> {
        // the bug: the rank rebuilds its layout before serving the export
        vec![CmdTag::Reconfigure, CmdTag::ExportState]
    }

    /// Tentpole mutant 2: export requested after the layout rebuild, so
    /// the reply reflects the post-event generation.
    pub fn export_after_rebuild() -> Transitions {
        Transitions { quiesce_cmds: quiesce_reconfigure_first, ..Transitions::real() }
    }

    fn redistribute_double_surrogate(
        states: Vec<Option<Vec<f32>>>,
        action: MembershipAction,
        last_combined: &[f32],
    ) -> Vec<Option<Vec<f32>>> {
        let mut out = membership::redistribute(states, action, last_combined);
        if matches!(action, MembershipAction::Fail { .. }) {
            // the bug: the surrogate is applied a second time
            if let Some(slot) = out.first_mut() {
                fold_into(slot, last_combined);
            }
        }
        out
    }

    /// Tentpole mutant 3: the last-combined surrogate is folded twice.
    pub fn double_fold_surrogate() -> Transitions {
        Transitions { redistribute: redistribute_double_surrogate, ..Transitions::real() }
    }

    /// Tentpole mutant 4: ranks already at a poisoned barrier apply the
    /// torn step instead of skipping it uniformly.
    pub fn barrier_skip_divergence() -> Transitions {
        Transitions { abort_advances_arrived: true, ..Transitions::real() }
    }

    fn redistribute_drop_survivor(
        states: Vec<Option<Vec<f32>>>,
        action: MembershipAction,
        last_combined: &[f32],
    ) -> Vec<Option<Vec<f32>>> {
        let mut out = membership::redistribute(states, action, last_combined);
        // the bug: the highest-numbered survivor comes back empty
        if out.len() > 1 {
            let i = out.len() - 1;
            out[i] = Some(Vec::new());
        }
        out
    }

    /// Satellite mutant: a survivor's residual state is wiped in transit.
    pub fn drop_survivor_residual() -> Transitions {
        Transitions { redistribute: redistribute_drop_survivor, ..Transitions::real() }
    }

    fn redistribute_misroute(
        states: Vec<Option<Vec<f32>>>,
        action: MembershipAction,
        last_combined: &[f32],
    ) -> Vec<Option<Vec<f32>>> {
        match action {
            MembershipAction::Join { .. } => {
                membership::redistribute(states, action, last_combined)
            }
            MembershipAction::Leave { rank } | MembershipAction::Fail { rank } => {
                let mut s = states;
                let exported = if rank < s.len() { s.remove(rank) } else { None };
                let orphan = exported.unwrap_or_else(|| last_combined.to_vec());
                // the bug: the orphan lands on the last rank, not rank 0
                if let Some(slot) = s.last_mut() {
                    fold_into(slot, &orphan);
                }
                s
            }
        }
    }

    /// Satellite mutant: the leaver's export is folded into the wrong
    /// (highest-numbered) surviving rank.
    pub fn misroute_fold() -> Transitions {
        Transitions { redistribute: redistribute_misroute, ..Transitions::real() }
    }

    fn skip_every_leaver(action: MembershipAction) -> Option<usize> {
        match action {
            MembershipAction::Fail { rank }
            | MembershipAction::Leave { rank } => Some(rank),
            MembershipAction::Join { .. } => None,
        }
    }

    /// Extra mutant: the collector never waits for a clean leaver's
    /// export, so the fold runs without it.
    pub fn skip_leaver_export() -> Transitions {
        Transitions { export_skip: skip_every_leaver, ..Transitions::real() }
    }

    fn quiesce_double_export(_action: MembershipAction) -> Vec<CmdTag> {
        vec![CmdTag::ExportState, CmdTag::ExportState]
    }

    /// Extra mutant: every rank is asked for its state twice per quiesce.
    pub fn double_export_request() -> Transitions {
        Transitions { quiesce_cmds: quiesce_double_export, ..Transitions::real() }
    }
}

/// The seeded-mutant battery the CLI and CI run: (name, constructor,
/// script, violation kind the checker must answer with). Worlds of 3
/// guarantee a non-donor survivor so misrouting/wiping is observable.
#[allow(clippy::type_complexity)]
pub fn self_test_cases() -> Vec<(&'static str, Transitions, Script, &'static str)> {
    let fail0 = Script {
        world: 3,
        gpn: 1,
        steps: 2,
        scheduled: vec![(0, MembershipAction::Fail { rank: 0 })],
        detected: vec![],
    };
    let leave0 = Script {
        world: 3,
        gpn: 1,
        steps: 2,
        scheduled: vec![(0, MembershipAction::Leave { rank: 0 })],
        detected: vec![],
    };
    let detected = Script {
        world: 3,
        gpn: 1,
        steps: 2,
        scheduled: vec![],
        detected: vec![2],
    };
    vec![
        (
            "lost-residual-on-eviction",
            mutants::lost_residual_on_eviction(),
            fail0.clone(),
            "mass-not-conserved",
        ),
        (
            "export-after-rebuild",
            mutants::export_after_rebuild(),
            leave0.clone(),
            "stale-export",
        ),
        (
            "double-fold-surrogate",
            mutants::double_fold_surrogate(),
            fail0.clone(),
            "mass-duplicated",
        ),
        (
            "barrier-skip-divergence",
            mutants::barrier_skip_divergence(),
            detected,
            "torn-step-divergence",
        ),
        (
            "drop-survivor-residual",
            mutants::drop_survivor_residual(),
            leave0.clone(),
            "survivor-state-changed",
        ),
        ("misroute-fold", mutants::misroute_fold(), leave0.clone(), "misrouted-fold"),
        (
            "skip-leaver-export",
            mutants::skip_leaver_export(),
            leave0,
            "export-missed",
        ),
        (
            "double-export-request",
            mutants::double_export_request(),
            fail0,
            "duplicate-export",
        ),
    ]
}

/// Run the whole seeded-mutant battery. `Ok` returns (mutant, caught
/// kind) pairs; `Err` names the first mutant that escaped or was caught
/// with the wrong diagnosis.
pub fn run_self_test(bounds: &Bounds) -> Result<Vec<(&'static str, &'static str)>, String> {
    let mut caught = Vec::new();
    for (name, t, script, want) in self_test_cases() {
        match check_script(&script, &t, bounds) {
            Ok(rep) => {
                return Err(format!(
                    "mutant '{name}' escaped: {} states explored on {} with no \
                     violation",
                    rep.states,
                    script.label()
                ));
            }
            Err(v) if v.kind() == want => caught.push((name, want)),
            Err(v) => {
                return Err(format!(
                    "mutant '{name}' caught with '{}' (wanted '{want}'): {v}",
                    v.kind()
                ));
            }
        }
    }
    Ok(caught)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::model::CoordPhase;

    #[test]
    fn real_transitions_survive_a_small_world_sweep() {
        let rep = check_world(2, 2, &Transitions::real(), &Bounds::default())
            .expect("real protocol must be violation-free");
        assert!(rep.scripts >= 8, "enumeration shrank: {} scripts", rep.scripts);
        assert!(rep.states > rep.scripts, "exploration is degenerate");
        assert!(rep.terminals > 0, "no terminal states classified");
    }

    #[test]
    fn quiet_script_state_space_is_tiny_and_exact() {
        let script =
            Script { world: 2, gpn: 1, steps: 1, scheduled: vec![], detected: vec![] };
        let rep = check_script(&script, &Transitions::real(), &Bounds::default())
            .expect("quiet script is violation-free");
        // issue, two deliveries in either order, barrier: a handful of
        // states — if this grows, the model sprouted accidental branching
        assert!(rep.states <= 8, "quiet world-2 space exploded: {}", rep.states);
        assert_eq!(rep.terminals, 1);
    }

    #[test]
    fn state_budget_trips_as_a_typed_violation() {
        let script =
            Script { world: 4, gpn: 1, steps: 2, scheduled: vec![], detected: vec![0] };
        let got = check_script(&script, &Transitions::real(), &Bounds { max_states: 10 });
        assert!(matches!(
            got,
            Err(ProtocolViolation::StateBoundExceeded { states }) if states > 10
        ));
    }

    #[test]
    fn every_seeded_mutant_is_caught_with_its_own_kind() {
        let caught = run_self_test(&Bounds::default()).expect("self-test must pass");
        assert_eq!(caught.len(), self_test_cases().len());
        let kinds: std::collections::HashSet<&str> =
            caught.iter().map(|&(_, k)| k).collect();
        assert_eq!(
            kinds.len(),
            caught.len(),
            "each mutant must map to a distinct violation kind"
        );
    }

    #[test]
    fn self_test_scripts_are_clean_under_the_real_transitions() {
        for (name, _, script, _) in self_test_cases() {
            let rep = check_script(&script, &Transitions::real(), &Bounds::default());
            assert!(rep.is_ok(), "script for mutant '{name}' dirty on real code");
        }
    }

    #[test]
    fn enumeration_scales_with_world_and_stays_valid() {
        for world in 2..=5 {
            let scripts = enumerate_scripts(world, 2);
            assert!(scripts.len() >= 10, "world {world}: {} scripts", scripts.len());
            for s in &scripts {
                assert_eq!(s.world, world);
                assert!(s.scheduled.len() + s.detected.len() <= 2);
            }
        }
    }

    #[test]
    fn deadlock_classification_names_the_stuck_work() {
        let script =
            Script { world: 2, gpn: 1, steps: 3, scheduled: vec![], detected: vec![] };
        let state = crate::analysis::model::ProtocolState::initial(&script);
        // a terminal before the target depth is a liveness failure
        let got = state.classify_terminal(&script);
        match got {
            Err(ProtocolViolation::Deadlock { detail }) => {
                assert!(detail.contains("steps"), "detail: {detail}")
            }
            other => panic!("wanted Deadlock, got {other:?}"),
        }
    }

    #[test]
    fn mid_protocol_terminal_is_a_deadlock() {
        let script =
            Script { world: 2, gpn: 1, steps: 0, scheduled: vec![], detected: vec![] };
        let mut state = crate::analysis::model::ProtocolState::initial(&script);
        state.coord = CoordPhase::Collecting {
            action: MembershipAction::Join { count: 1 },
            got: vec![None, None],
            need: vec![false, false],
        };
        let got = state.classify_terminal(&script);
        assert!(matches!(got, Err(ProtocolViolation::Deadlock { .. })));
    }
}
