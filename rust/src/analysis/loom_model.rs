//! Exhaustive-interleaving (loom) models of the executor's two riskiest
//! dynamic protocols. Compiled and run only under
//! `RUSTFLAGS="--cfg loom"` (see DESIGN.md §11):
//!
//! ```text
//! cd rust && RUSTFLAGS="--cfg loom" cargo test --release --lib analysis::loom_model
//! ```
//!
//! **Model boundaries.** These are *models*, not the production code
//! under loom: `exec::ring`/`exec::rank` are built on `std::sync::mpsc`
//! and OS threads, which loom cannot instrument. Each model re-expresses
//! one protocol's synchronization skeleton over loom primitives — a
//! hand-rolled unbounded channel on `loom::sync::{Mutex, Condvar}` — and
//! checks the protocol-level invariants the real code relies on. What is
//! modeled: epoch-tagged parking and the circulating spare pool of
//! `allgather_sched` (model A, 2 ranks × 3 back-to-back epochs), the
//! comm→compute recycle channel racing `Cmd::Reconfigure` through the
//! FIFO work queue (model B, one rank's thread pair), a rank failure
//! racing the engine's `Cmd::Reconfigure` → `Cmd::ExportState` sequence
//! during an elastic re-world (model C — the fail-during-reconfigure
//! hazard of DESIGN.md §12), and a detected failure on one rank racing a
//! *different* rank's in-flight `Cmd::ExportState` inside the same
//! quiesce window (model D — the cross-rank window the explicit-state
//! protocol checker of DESIGN.md §13 deliberately leaves to loom, since
//! it disables detected failures while collecting). What is **not**
//! modeled: frame payload encoding, pacing/time, worlds beyond 2–3
//! ranks, or mpsc's internals (assumed linearizable FIFO — the same
//! assumption the std documentation guarantees).

use std::collections::VecDeque;

use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;

/// Minimal unbounded FIFO channel on loom primitives: `send` never
/// blocks, `recv` blocks until a value is available — the synchronization
/// shape of `std::sync::mpsc` as the executor uses it.
struct Chan<T> {
    q: Mutex<VecDeque<T>>,
    cv: Condvar,
}

impl<T> Chan<T> {
    fn new() -> Chan<T> {
        Chan { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    fn send(&self, v: T) {
        self.q.lock().unwrap().push_back(v);
        self.cv.notify_all();
    }

    fn recv(&self) -> T {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(v) = q.pop_front() {
                return v;
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    fn try_recv(&self) -> Option<T> {
        self.q.lock().unwrap().pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A mesh frame as model A sees it: the epoch tag plus the buffer
    /// whose allocation circulates through the pool.
    struct Frame {
        epoch: u64,
        data: Vec<u8>,
    }

    /// Model A — `exec::ring::allgather_sched`'s spare-buffer rotation and
    /// epoch parking, 2 ranks running 3 collectives back to back with no
    /// cross-rank synchronization between them. Checked in every
    /// interleaving:
    /// * frames arriving early carry exactly `epoch + 1` (skew ≤ 1);
    /// * the parking queue never exceeds `recv_count` (= 1 here);
    /// * each epoch's delivery is exactly-once and bitwise-correct;
    /// * only the warm-up epoch allocates — afterwards the spare pool
    ///   (fed by adopted arrivals) always has a buffer for the next send.
    #[test]
    fn spare_pool_rotation_and_epoch_parking() {
        loom::model(|| {
            const EPOCHS: u64 = 3;
            let chans: Vec<Arc<Chan<Frame>>> =
                (0..2).map(|_| Arc::new(Chan::new())).collect();
            let mut handles = Vec::new();
            for rank in 0..2usize {
                let rx = chans[rank].clone();
                let tx = chans[1 - rank].clone();
                handles.push(thread::spawn(move || {
                    let peer = (1 - rank) as u8;
                    let mut spares: Vec<Vec<u8>> = Vec::new();
                    let mut pending: VecDeque<Frame> = VecDeque::new();
                    let mut allocs = 0usize;
                    for epoch in 0..EPOCHS {
                        let mut buf = spares.pop().unwrap_or_else(|| {
                            allocs += 1;
                            Vec::new()
                        });
                        buf.clear();
                        buf.extend_from_slice(&[epoch as u8, rank as u8]);
                        tx.send(Frame { epoch, data: buf });
                        // drain any frame of THIS epoch parked during the
                        // previous collective, then block for the rest
                        let mut got = 0usize;
                        while let Some(i) =
                            pending.iter().position(|f| f.epoch == epoch)
                        {
                            let f = pending.remove(i).unwrap();
                            assert_eq!(f.data, [epoch as u8, peer]);
                            spares.push(f.data);
                            got += 1;
                        }
                        while got < 1 {
                            let f = rx.recv();
                            if f.epoch == epoch {
                                assert_eq!(f.data, [epoch as u8, peer]);
                                spares.push(f.data);
                                got += 1;
                            } else {
                                assert_eq!(
                                    f.epoch,
                                    epoch + 1,
                                    "peer ran more than one collective ahead"
                                );
                                pending.push_back(f);
                                assert!(
                                    pending.len() <= 1,
                                    "parking queue exceeded recv_count"
                                );
                            }
                        }
                        assert_eq!(got, 1, "exactly-once delivery per epoch");
                    }
                    assert_eq!(allocs, 1, "steady state must not allocate");
                    assert!(pending.is_empty(), "nothing parked past the last epoch");
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    /// Work-queue items as model B sees them (`exec::rank::Work`
    /// skeleton): a compressed frame whose first byte records the scheme
    /// it was compressed under, a scheme swap, or shutdown.
    enum Work {
        Tensor(Vec<u8>),
        Reconfig(u8),
        Stop,
    }

    /// Model B — one rank's compute/comm thread pair: the comm→compute
    /// recycle channel racing `Cmd::Reconfigure` through the FIFO work
    /// queue. The production invariant: because `Work` is a single FIFO,
    /// the comm thread's combiner is *always* on the same scheme as the
    /// frame it combines, even when the swap lands mid-step and spent
    /// buffers from the old scheme are being reused for new-scheme
    /// frames. Checked in every interleaving, plus buffer conservation:
    /// every buffer the compute thread ever allocated ends parked in the
    /// recycle channel — none lost, none duplicated.
    #[test]
    fn recycle_channel_vs_reconfigure_fifo() {
        loom::model(|| {
            let work = Arc::new(Chan::<Work>::new());
            let recycle = Arc::new(Chan::<Vec<u8>>::new());

            let compute = {
                let work = work.clone();
                let recycle = recycle.clone();
                thread::spawn(move || {
                    let mut scheme = 0u8;
                    let mut allocs = 0usize;
                    for step in 0..2 {
                        for _tensor in 0..2 {
                            let mut frame = recycle.try_recv().unwrap_or_else(|| {
                                allocs += 1;
                                Vec::new()
                            });
                            frame.clear();
                            frame.push(scheme);
                            work.send(Work::Tensor(frame));
                        }
                        if step == 0 {
                            scheme = 1;
                            work.send(Work::Reconfig(scheme));
                        }
                    }
                    work.send(Work::Stop);
                    allocs
                })
            };

            let comm = {
                let work = work.clone();
                let recycle = recycle.clone();
                thread::spawn(move || {
                    let mut tag = 0u8;
                    let mut processed = 0usize;
                    loop {
                        match work.recv() {
                            Work::Tensor(frame) => {
                                assert_eq!(
                                    frame[0], tag,
                                    "frame from a stale scheme crossed a reconfigure"
                                );
                                processed += 1;
                                recycle.send(frame);
                            }
                            Work::Reconfig(t) => tag = t,
                            Work::Stop => break,
                        }
                    }
                    assert_eq!(processed, 4, "every tensor combined exactly once");
                })
            };

            let allocs = compute.join().unwrap();
            comm.join().unwrap();
            let mut parked = 0usize;
            while recycle.try_recv().is_some() {
                parked += 1;
            }
            assert_eq!(parked, allocs, "buffer conservation through the recycle loop");
        });
    }

    /// Command-queue items as model C sees them (`exec::rank::Cmd`
    /// skeleton during an elastic re-world): a shard-layout swap, a state
    /// export request, an injected failure, shutdown.
    enum Cmd {
        Reconfig(u8),
        Export,
        Fail,
        Stop,
    }

    /// Replies as the engine's `export_states` collector sees them.
    enum Msg {
        State(u8),
        Failed,
        Stopped,
    }

    /// Model C — `fail_rank` racing `Cmd::Reconfigure` → `Cmd::ExportState`
    /// during an elastic membership change (one rank's compute thread vs
    /// the engine and a failure injector). The production invariants,
    /// checked in every interleaving:
    /// * **no stale export**: because each rank's command queue is a
    ///   single FIFO and the engine enqueues the reconfigure before the
    ///   export, any state the engine receives reflects the *new* shard
    ///   layout — a failure can suppress the export but never reorder it;
    /// * **no deadlocked collector**: every compute-thread exit path
    ///   (failure, shutdown) emits a terminal message first, so the
    ///   engine-side `export_states` loop always terminates — the dead
    ///   rank falls to the deterministic surrogate instead of a hang.
    #[test]
    fn export_never_observes_stale_layout_under_failure_race() {
        loom::model(|| {
            let cmd = Arc::new(Chan::<Cmd>::new());
            let res = Arc::new(Chan::<Msg>::new());

            // the rank's compute thread: owns the layout, drains the FIFO
            let compute = {
                let cmd = cmd.clone();
                let res = res.clone();
                thread::spawn(move || {
                    let mut layout = 0u8;
                    loop {
                        match cmd.recv() {
                            Cmd::Reconfig(v) => layout = v,
                            Cmd::Export => res.send(Msg::State(layout)),
                            Cmd::Fail => {
                                res.send(Msg::Failed);
                                return;
                            }
                            Cmd::Stop => {
                                res.send(Msg::Stopped);
                                return;
                            }
                        }
                    }
                })
            };

            // the failure injector races the engine's whole sequence
            let injector = {
                let cmd = cmd.clone();
                thread::spawn(move || cmd.send(Cmd::Fail))
            };

            // the engine: re-shard, request state for the re-world, stop
            cmd.send(Cmd::Reconfig(1));
            cmd.send(Cmd::Export);
            cmd.send(Cmd::Stop);

            // collect until a terminal message (the export_states loop)
            loop {
                match res.recv() {
                    Msg::State(layout) => {
                        assert_eq!(layout, 1, "export observed a pre-reconfigure layout");
                    }
                    // dead before exporting: the engine saw it and falls
                    // back to the surrogate — or a clean stop after a
                    // fresh export. Either way the loop ends.
                    Msg::Failed | Msg::Stopped => break,
                }
            }
            injector.join().unwrap();
            compute.join().unwrap();
        });
    }

    /// Model D — a detected failure on rank B racing rank A's in-flight
    /// `Cmd::ExportState` inside the *same* quiesce window (two rank
    /// compute threads vs the engine's collector and a failure injector).
    /// This is the cross-rank window the explicit-state protocol checker
    /// (`analysis::checker`, DESIGN.md §13) deliberately excludes — it
    /// disables detected failures while collecting — so loom carries the
    /// proof here. Checked in every interleaving:
    /// * **live exports are isolated**: rank A is healthy, so its export
    ///   arrives exactly once and observes the post-reconfigure layout,
    ///   no matter where B's failure lands;
    /// * **no duplicate export from the dying rank**: B contributes at
    ///   most one state (FIFO: its export either precedes the failure or
    ///   is suppressed by it, never both);
    /// * **EF-mass conservation**: each rank hands over exactly one unit
    ///   of residual state — A's export, and B's export *or* the
    ///   deterministic surrogate when the failure wins the race;
    /// * **no deadlocked collector**: both ranks always resolve
    ///   terminally, so the collect loop exits.
    #[test]
    fn export_races_detected_failure_on_peer_rank() {
        loom::model(|| {
            let cmd_a = Arc::new(Chan::<Cmd>::new());
            let cmd_b = Arc::new(Chan::<Cmd>::new());
            let res = Arc::new(Chan::<(u8, Msg)>::new());

            fn spawn_rank(
                id: u8,
                cmd: Arc<Chan<Cmd>>,
                res: Arc<Chan<(u8, Msg)>>,
            ) -> thread::JoinHandle<()> {
                thread::spawn(move || {
                    let mut layout = 0u8;
                    loop {
                        match cmd.recv() {
                            Cmd::Reconfig(v) => layout = v,
                            Cmd::Export => res.send((id, Msg::State(layout))),
                            Cmd::Fail => {
                                res.send((id, Msg::Failed));
                                return;
                            }
                            Cmd::Stop => {
                                res.send((id, Msg::Stopped));
                                return;
                            }
                        }
                    }
                })
            }
            let ra = spawn_rank(0, cmd_a.clone(), res.clone());
            let rb = spawn_rank(1, cmd_b.clone(), res.clone());

            // the detected failure strikes rank B anywhere in the window
            let injector = {
                let cmd_b = cmd_b.clone();
                thread::spawn(move || cmd_b.send(Cmd::Fail))
            };

            // the engine's quiesce: reconfigure-then-export, both ranks
            for c in [&cmd_a, &cmd_b] {
                c.send(Cmd::Reconfig(1));
                c.send(Cmd::Export);
                c.send(Cmd::Stop);
            }

            // collect until both ranks resolve terminally
            let mut states = [0usize; 2];
            let mut done = [false, false];
            while !(done[0] && done[1]) {
                let (id, msg) = res.recv();
                match msg {
                    Msg::State(layout) => {
                        assert_eq!(layout, 1, "export observed a pre-reconfigure layout");
                        states[id as usize] += 1;
                    }
                    Msg::Failed | Msg::Stopped => done[id as usize] = true,
                }
            }
            assert_eq!(states[0], 1, "peer failure lost or duplicated a live export");
            assert!(states[1] <= 1, "failed rank exported twice in one quiesce");
            let mass = states[0] + states[1] + usize::from(states[1] == 0);
            assert_eq!(mass, 2, "EF mass not conserved across the quiesce window");
            injector.join().unwrap();
            ra.join().unwrap();
            rb.join().unwrap();
        });
    }
}
