//! Static verification of the executor's communication contracts
//! (DESIGN.md §11).
//!
//! The threaded executor's safety argument rests on properties of the
//! [`crate::comm::topology::HopSchedule`] it executes — not on anything
//! it checks at runtime. This module proves those properties *statically*
//! from the hop list alone, so a schedule for a world far too big to
//! execute in tests (P = 1024 and beyond) is certified without spawning a
//! thread:
//!
//! * **deadlock-freedom** — the same-round hop-dependency graph is empty
//!   (every forward depends on a strictly earlier round), so no
//!   receive-then-forward chain can cyclically block;
//! * **exactly-once delivery** — each rank receives each slot exactly
//!   once and never its own, so arrival-order-insensitive slot storage
//!   needs no round bookkeeping;
//! * **strictly-earlier sourcing** — every hop's source holds the slot it
//!   forwards (its own, or one acquired at a strictly earlier round);
//! * **bounded in-flight frames** — per-slot delivery chains all
//!   originate at the slot's owner, which bounds epoch skew by 1 and the
//!   parking queue by `recv_count` (see [`verifier::verify_schedule`] for
//!   the proof-by-construction);
//! * **wire-byte conservation** — every byte sent is received exactly
//!   once, and claimed frame lengths match the codec arithmetic in
//!   [`crate::harness::wire_bytes`].
//!
//! [`verifier::verify_schedule`] is the single implementation behind
//! [`crate::comm::topology::HopSchedule::validate`], the
//! `debug_assertions` hook at schedule build, the `verify-schedules` CLI
//! sweep, and the mutation-style negative tests in
//! `tests/schedule_verify.rs`.
//!
//! [`model`] + [`checker`] extend the same static story to the elastic
//! membership protocol (DESIGN.md §13): a small-step state machine over
//! per-rank command FIFOs, layout generations and EF residual mass as
//! exact token multisets, explored exhaustively (stateright-style BFS)
//! over every interleaving of scheduled and detected fail/join/leave
//! events. Because the model delegates every re-world decision to the
//! production functions ([`crate::coordinator::membership`],
//! [`crate::exec::fifo_layout_gen_at`]) through
//! [`model::Transitions::real`], a clean sweep proves, at the explored
//! bounds: EF-mass conservation across folds, exactly-once export per
//! leaver, no step against a stale shard layout, uniform torn-step
//! skipping, and deadlock-free quiescence. Seeded mutants
//! ([`checker::mutants`]) prove each invariant is live.
//!
//! [`loom_model`] (compiled only under `RUSTFLAGS="--cfg loom"`) holds
//! exhaustive-interleaving models of the riskiest dynamic protocols:
//! the circulating spare-buffer pool with epoch parking
//! (`exec::ring::allgather_sched`), the comm→compute recycle channel
//! racing `Cmd::Reconfigure` (`exec::rank`), a rank failure racing
//! the elastic re-world's reconfigure→export sequence
//! (`exec::ThreadedExec::export_states`), and an `ExportState` racing a
//! detected failure on a *different* rank inside one quiesce window —
//! the two windows the explicit-state checker deliberately leaves to
//! loom (it disables detected failures while collecting).

pub mod checker;
pub mod model;
pub mod verifier;

#[cfg(loom)]
pub mod loom_model;

pub use checker::{
    check_script, check_world, enumerate_scripts, run_self_test, Bounds, CheckReport,
    WorldReport,
};
pub use model::{ProtocolState, ProtocolViolation, Script, Transitions};
pub use verifier::{
    verify_frame_lengths, verify_schedule, wire_conservation, ScheduleReport, ScheduleViolation,
    WireReport,
};
