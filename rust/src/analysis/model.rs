//! Small-step state machine of the elastic membership protocol
//! (DESIGN.md §13) — the model half of the explicit-state checker in
//! [`crate::analysis::checker`].
//!
//! One [`ProtocolState`] captures everything the re-world protocol's
//! correctness depends on: per-rank command FIFOs ([`CmdTag`] — the same
//! vocabulary `exec::rank::Cmd` ships), per-rank shard-layout
//! generations, step counters, the step barrier with poison, the
//! coordinator's quiesce/collect/fold phases, and per-rank error-feedback
//! residual mass as **token multisets** (dense `u8` count vectors — an
//! exact, hashable stand-in for the engine's `f32` residual vectors, so
//! "mass conserved" is integer arithmetic, not float tolerance).
//!
//! The machine is **shared-implementation, not hand-mirrored**: every
//! re-world decision is delegated through [`Transitions`], whose
//! [`Transitions::real`] wiring points straight at the production
//! functions — [`membership::redistribute`],
//! [`membership::validated_next_world`], [`membership::export_skip`],
//! [`membership::next_cluster`], [`membership::generation_seed`] and
//! [`crate::exec::fifo_layout_gen_at`]. The checker therefore proves
//! properties of the code the engine runs; seeded mutants (see
//! [`crate::analysis::checker::mutants`]) swap individual function
//! pointers to prove the checker would notice if that code regressed.
//!
//! Nondeterminism = one [`Action`] per enabled choice: rank queue
//! deliveries interleave freely, detected failures fire at any point
//! outside a quiesce window, barrier completion races poison. The BFS in
//! `checker` explores all of it; [`ProtocolState::apply`] reports any
//! invariant breach as a typed [`ProtocolViolation`].

use std::fmt;

use crate::coordinator::membership::{self as membership, MembershipAction};
use crate::exec::rank::CmdTag;

/// A residual-mass multiset: `bag[t]` = how many copies of token `t` this
/// rank holds. All bags in one run share a fixed token universe
/// (`minted` ids: one per initial rank, plus the surrogate token the
/// retained last-combined update stands for), so element-wise `u8`
/// arithmetic is the exact multiset union the conservation proof needs.
pub type TokenBag = Vec<u8>;

fn bag_add(a: &TokenBag, b: &TokenBag) -> TokenBag {
    let mut out = a.clone();
    if out.len() < b.len() {
        out.resize(b.len(), 0);
    }
    for (o, x) in out.iter_mut().zip(b.iter()) {
        *o = o.saturating_add(*x);
    }
    out
}

fn bag_total(b: &TokenBag) -> u32 {
    b.iter().map(|&c| c as u32).sum()
}

fn bag_is_zero(b: &TokenBag) -> bool {
    b.iter().all(|&c| c == 0)
}

/// Lower a token bag into the `f32` residual-vector shape the production
/// [`membership::redistribute`] operates on (counts are small integers,
/// exact in f32).
pub fn bag_to_f32(b: &TokenBag) -> Vec<f32> {
    b.iter().map(|&c| c as f32).collect()
}

/// Lift a redistributed `f32` vector back into a token bag. `None` if the
/// vector is not a valid multiset over the minted universe — negative,
/// fractional or overflowing counts mean the transition manufactured or
/// shredded mass in a way no token reshuffle can express.
pub fn f32_to_bag(v: &[f32], minted: usize) -> Option<TokenBag> {
    if v.len() > minted {
        return None;
    }
    let mut out = vec![0u8; minted];
    for (i, &x) in v.iter().enumerate() {
        if !(0.0..=255.0).contains(&x) || x.fract() != 0.0 {
            return None;
        }
        out[i] = x as u8;
    }
    Some(out)
}

/// One membership disturbance in a checker script.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProtocolEvent {
    /// Fires deterministically at the named step boundary, like a
    /// `--membership-schedule` entry.
    Scheduled { at_step: u8, action: MembershipAction },
    /// A crash the engine *detects*: may fire at any explored point
    /// outside a quiesce window (including mid-barrier, where it poisons
    /// the step) — or never. `rank` indexes the world current at fire
    /// time.
    Detected { rank: usize },
}

/// One bounded exploration: an initial world plus the disturbances the
/// BFS interleaves against `steps` completed barriers.
#[derive(Debug, Clone)]
pub struct Script {
    pub world: usize,
    /// Initial gpus-per-node of the modeled cluster (re-derived through
    /// [`membership::next_cluster`] on every fold).
    pub gpn: usize,
    /// Barriers the coordinator must complete (the depth bound).
    pub steps: u8,
    pub scheduled: Vec<(u8, MembershipAction)>,
    /// Ranks whose detected failure the BFS may fire at any point.
    pub detected: Vec<usize>,
}

impl Script {
    pub fn label(&self) -> String {
        let mut parts: Vec<String> = self
            .scheduled
            .iter()
            .map(|(s, a)| format!("{s}:{}", a.spec()))
            .collect();
        parts.extend(self.detected.iter().map(|r| format!("det:{r}")));
        if parts.is_empty() {
            parts.push("quiet".to_string());
        }
        format!("w{}g{} s{} [{}]", self.world, self.gpn, self.steps, parts.join(","))
    }

    /// Token universe: one id per initial rank + the surrogate token.
    pub fn minted(&self) -> usize {
        self.world + 1
    }
}

/// What one rank's export reply carried: its residual bag and the shard
/// layout generation the FIFO says the export observed.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ExportReply {
    pub bag: TokenBag,
    pub observed_gen: u8,
}

/// One rank as the model sees it.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct RankState {
    pub alive: bool,
    /// Shard-layout generation this rank's compressor holds.
    pub layout_gen: u8,
    /// Steps this rank has applied (must track the coordinator's).
    pub steps_done: u8,
    /// Pending commands, FIFO. Processed head-first by [`Action::Deliver`].
    pub queue: Vec<CmdTag>,
    /// EF residual mass.
    pub bag: TokenBag,
    /// Export replies served for the quiesce in progress.
    pub exports_served: u8,
}

/// Coordinator phase.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum CoordPhase {
    Idle,
    /// A step barrier is in flight.
    Stepping { arrived: Vec<bool>, poisoned: bool },
    /// Quiesce: exports requested, waiting for every `need`ed reply.
    Collecting {
        action: MembershipAction,
        got: Vec<Option<ExportReply>>,
        need: Vec<bool>,
    },
}

/// One nondeterministic choice at a state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Coordinator broadcasts `Step` to every live rank.
    IssueStep,
    /// Rank `r` processes its queue head.
    Deliver(usize),
    /// All live ranks arrived: the barrier releases and the step applies.
    CompleteBarrier,
    /// The poisoned barrier releases: the torn step is skipped.
    AbortBarrier,
    /// The due scheduled event begins its quiesce.
    FireScheduled,
    /// Detected failure `i` strikes now.
    FireDetected(usize),
    /// Coordinator reacts to a detected failure: quiesce for the re-world.
    HandleFailure,
    /// All exports in: redistribute, verify, rebuild the world.
    Fold,
}

/// The membership protocol's transition implementation, as function
/// pointers so the checker and the engine run the *same* code —
/// [`Transitions::real`] — while seeded mutants swap exactly one pointer.
#[derive(Clone, Copy)]
pub struct Transitions {
    /// [`membership::redistribute`] — the residual-mass handoff.
    pub redistribute:
        fn(Vec<Option<Vec<f32>>>, MembershipAction, &[f32]) -> Vec<Option<Vec<f32>>>,
    /// [`membership::validated_next_world`] — world-size guard.
    pub next_world: fn(usize, MembershipAction) -> anyhow::Result<usize>,
    /// [`membership::export_skip`] — who the collector must not wait on.
    pub export_skip: fn(MembershipAction) -> Option<usize>,
    /// [`membership::next_cluster`] — re-worlded cluster shape.
    pub next_cluster: fn(usize, usize) -> (usize, usize),
    /// [`membership::generation_seed`] — the never-replay seed mix.
    pub generation_seed: fn(u64, u64) -> u64,
    /// [`crate::exec::fifo_layout_gen_at`] — per-rank FIFO semantics: the
    /// layout generation a queued command observes.
    pub observed_gen: fn(u8, &[CmdTag], usize) -> u8,
    /// What the coordinator enqueues to each surviving rank at quiesce
    /// (the pure mirror of `ThreadedExec::export_states`' send loop).
    pub quiesce_cmds: fn(MembershipAction) -> Vec<CmdTag>,
    /// Seeded-mutant knob for the barrier-poison rule. The real abort
    /// path skips the torn step on *every* survivor; `true` models a
    /// broken runtime where ranks already at the barrier apply it.
    pub abort_advances_arrived: bool,
}

fn real_quiesce_cmds(_action: MembershipAction) -> Vec<CmdTag> {
    vec![CmdTag::ExportState]
}

impl Transitions {
    /// The production protocol: every pointer is the function the engine
    /// itself calls from `DpEngine::apply_membership` / `exec::rank`.
    pub fn real() -> Transitions {
        Transitions {
            redistribute: membership::redistribute,
            next_world: membership::validated_next_world,
            export_skip: membership::export_skip,
            next_cluster: membership::next_cluster,
            generation_seed: membership::generation_seed,
            observed_gen: crate::exec::rank::fifo_layout_gen_at,
            quiesce_cmds: real_quiesce_cmds,
            abort_advances_arrived: false,
        }
    }
}

/// Base seed the generation-seed invariant is checked against (the value
/// is arbitrary — the invariant is `generation_seed(seed, g) != seed` for
/// every g >= 1).
pub const MODEL_SEED: u64 = 0x5EED_C0DE;

/// A safety or liveness breach, one variant per invariant — the protocol
/// analogue of [`crate::analysis::ScheduleViolation`]. Every message
/// names the state that broke and the contract it broke.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtocolViolation {
    /// Residual token mass vanished across a fold: the conservation
    /// contract (survivors bitwise + orphan folded into new rank 0) lost
    /// `missing` tokens.
    MassNotConserved { action: String, missing: u32 },
    /// Residual token mass was manufactured across a fold — some donor
    /// was folded more than once.
    MassDuplicated { action: String, excess: u32 },
    /// A survivor's residual bag changed across a fold in a way the
    /// handoff contract does not allow (survivors keep state bitwise).
    SurvivorStateChanged { action: String, rank: usize },
    /// The orphaned residual mass was folded into a rank other than the
    /// deterministic donor (new rank 0).
    MisroutedFold { action: String, rank: usize },
    /// An export reply observed shard layout generation `observed` while
    /// the fold assumed `expected` — the reconfigure/export FIFO ordering
    /// was broken.
    StaleExport { rank: usize, observed: u8, expected: u8 },
    /// A rank executed a training step against a stale shard layout.
    StaleLayoutStep { rank: usize, have: u8, want: u8 },
    /// After a poisoned barrier, survivors disagreed about the torn step
    /// (some applied it, some skipped) — or a rank's step counter
    /// diverged from the coordinator's.
    TornStepDivergence { rank: usize, steps_done: u8, step: u8 },
    /// A leaver's exactly-once export never arrived before the fold.
    ExportMissed { rank: usize },
    /// A rank served more than one export for a single quiesce window.
    DuplicateExport { rank: usize },
    /// A terminal state with unfinished work: pending commands, an open
    /// quiesce, an unhandled failure or an unfired scheduled event.
    Deadlock { detail: String },
    /// A transition produced an impossible world (guard rejected it, or
    /// the re-derived cluster shape does not cover the world).
    WorldInvalid { detail: String },
    /// `redistribute` returned a state vector of the wrong world size.
    ShapeMismatch { got: usize, want: usize },
    /// The generation-mixed seed replayed the base stream.
    SeedReplay { generation: u64 },
    /// The explorer hit its state budget before exhausting the space —
    /// not a protocol bug, but the proof is incomplete at these bounds.
    StateBoundExceeded { states: usize },
}

impl ProtocolViolation {
    /// Stable variant name, for reporting and mutant self-tests.
    pub fn kind(&self) -> &'static str {
        match self {
            ProtocolViolation::MassNotConserved { .. } => "mass-not-conserved",
            ProtocolViolation::MassDuplicated { .. } => "mass-duplicated",
            ProtocolViolation::SurvivorStateChanged { .. } => "survivor-state-changed",
            ProtocolViolation::MisroutedFold { .. } => "misrouted-fold",
            ProtocolViolation::StaleExport { .. } => "stale-export",
            ProtocolViolation::StaleLayoutStep { .. } => "stale-layout-step",
            ProtocolViolation::TornStepDivergence { .. } => "torn-step-divergence",
            ProtocolViolation::ExportMissed { .. } => "export-missed",
            ProtocolViolation::DuplicateExport { .. } => "duplicate-export",
            ProtocolViolation::Deadlock { .. } => "deadlock",
            ProtocolViolation::WorldInvalid { .. } => "world-invalid",
            ProtocolViolation::ShapeMismatch { .. } => "shape-mismatch",
            ProtocolViolation::SeedReplay { .. } => "seed-replay",
            ProtocolViolation::StateBoundExceeded { .. } => "state-bound-exceeded",
        }
    }
}

impl fmt::Display for ProtocolViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolViolation::MassNotConserved { action, missing } => write!(
                f,
                "EF residual mass not conserved across '{action}': {missing} token(s) \
                 lost — the orphaned state was dropped instead of folded into the donor"
            ),
            ProtocolViolation::MassDuplicated { action, excess } => write!(
                f,
                "EF residual mass manufactured across '{action}': {excess} surplus \
                 token(s) — an orphan/surrogate was folded more than once"
            ),
            ProtocolViolation::SurvivorStateChanged { action, rank } => write!(
                f,
                "survivor rank {rank}'s residuals changed across '{action}' — the \
                 handoff contract requires survivors to keep their state bitwise"
            ),
            ProtocolViolation::MisroutedFold { action, rank } => write!(
                f,
                "orphaned residual mass from '{action}' was folded into rank {rank} — \
                 the deterministic donor is new rank 0, anything else breaks \
                 analytic/threaded parity"
            ),
            ProtocolViolation::StaleExport { rank, observed, expected } => write!(
                f,
                "rank {rank}'s export observed shard layout generation {observed}, but \
                 the fold assumed generation {expected} — the reconfigure-before-export \
                 FIFO ordering was violated"
            ),
            ProtocolViolation::StaleLayoutStep { rank, have, want } => write!(
                f,
                "rank {rank} executed a step holding shard layout generation {have} \
                 while the world is at generation {want} — its update would be sliced \
                 by a stale layout"
            ),
            ProtocolViolation::TornStepDivergence { rank, steps_done, step } => write!(
                f,
                "rank {rank} has applied {steps_done} step(s) while the coordinator \
                 completed {step} — a torn (barrier-poisoned) step must be skipped by \
                 every survivor uniformly"
            ),
            ProtocolViolation::ExportMissed { rank } => write!(
                f,
                "leaving rank {rank}'s residual export never arrived — a clean leave \
                 must hand its state over exactly once before departing"
            ),
            ProtocolViolation::DuplicateExport { rank } => write!(
                f,
                "rank {rank} served more than one export in a single quiesce window — \
                 exactly-once export is what makes the fold arithmetic exact"
            ),
            ProtocolViolation::Deadlock { detail } => write!(
                f,
                "terminal state with unfinished work ({detail}) — every schedule must \
                 quiesce with empty queues and all events applied"
            ),
            ProtocolViolation::WorldInvalid { detail } => {
                write!(f, "membership transition produced an invalid world: {detail}")
            }
            ProtocolViolation::ShapeMismatch { got, want } => write!(
                f,
                "redistribute returned {got} rank state(s) for a world of {want}"
            ),
            ProtocolViolation::SeedReplay { generation } => write!(
                f,
                "generation {generation}'s mixed seed equals the base seed — the \
                 re-world would replay the pre-event sample stream"
            ),
            ProtocolViolation::StateBoundExceeded { states } => write!(
                f,
                "state budget exhausted after {states} states — raise the bound or \
                 shrink the script; the proof is incomplete at these bounds"
            ),
        }
    }
}

impl std::error::Error for ProtocolViolation {}

/// One explored configuration of the whole protocol. `Hash`/`Eq` make the
/// BFS's visited set exact — two states are the same iff every queue,
/// bag, counter and phase is the same.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ProtocolState {
    /// Completed barriers.
    pub step: u8,
    /// Membership generation == shard-layout generation in force.
    pub gen: u8,
    /// Current gpus-per-node (evolves through `next_cluster`).
    pub gpn: u8,
    pub ranks: Vec<RankState>,
    pub coord: CoordPhase,
    /// Index of the next unfired scheduled event.
    pub next_scheduled: usize,
    pub detected_fired: Vec<bool>,
    /// A detected failure awaiting the coordinator's re-world.
    pub pending_fail: Option<usize>,
    /// The retained last-combined update (the Fail surrogate), as mass.
    pub last_combined: TokenBag,
}

impl ProtocolState {
    /// The pre-disturbance world: rank `r` holds one copy of token `r`,
    /// the retained last-combined update holds the surrogate token.
    pub fn initial(script: &Script) -> ProtocolState {
        let minted = script.minted();
        let ranks = (0..script.world)
            .map(|r| {
                let mut bag = vec![0u8; minted];
                bag[r] = 1;
                RankState {
                    alive: true,
                    layout_gen: 0,
                    steps_done: 0,
                    queue: Vec::new(),
                    bag,
                    exports_served: 0,
                }
            })
            .collect();
        let mut last_combined = vec![0u8; minted];
        last_combined[script.world] = 1;
        ProtocolState {
            step: 0,
            gen: 0,
            gpn: script.gpn.min(255) as u8,
            ranks,
            coord: CoordPhase::Idle,
            next_scheduled: 0,
            detected_fired: vec![false; script.detected.len()],
            pending_fail: None,
            last_combined,
        }
    }

    fn scheduled_due(&self, script: &Script) -> bool {
        script
            .scheduled
            .get(self.next_scheduled)
            .is_some_and(|&(at, _)| at <= self.step)
    }

    /// Every action enabled at this state — the BFS's branching.
    pub fn enabled_actions(&self, script: &Script) -> Vec<Action> {
        let mut out = Vec::new();
        for (r, rk) in self.ranks.iter().enumerate() {
            if rk.alive && !rk.queue.is_empty() {
                out.push(Action::Deliver(r));
            }
        }
        match &self.coord {
            CoordPhase::Idle => {
                if self.pending_fail.is_some() {
                    out.push(Action::HandleFailure);
                } else if self.scheduled_due(script) {
                    out.push(Action::FireScheduled);
                } else if self.step < script.steps {
                    out.push(Action::IssueStep);
                }
            }
            CoordPhase::Stepping { arrived, poisoned } => {
                if *poisoned {
                    out.push(Action::AbortBarrier);
                } else if self
                    .ranks
                    .iter()
                    .enumerate()
                    .all(|(r, rk)| !rk.alive || arrived.get(r).copied().unwrap_or(false))
                {
                    out.push(Action::CompleteBarrier);
                }
            }
            CoordPhase::Collecting { got, need, .. } => {
                if need
                    .iter()
                    .enumerate()
                    .all(|(r, &n)| !n || got.get(r).is_some_and(|g| g.is_some()))
                {
                    out.push(Action::Fold);
                }
            }
        }
        // detected failures strike at any explored point outside a
        // quiesce window (the in-window race is loom model C/D territory)
        if self.pending_fail.is_none()
            && !matches!(self.coord, CoordPhase::Collecting { .. })
        {
            for (i, &fired) in self.detected_fired.iter().enumerate() {
                if fired {
                    continue;
                }
                let rank = script.detected[i];
                if self.ranks.get(rank).is_some_and(|rk| rk.alive) {
                    out.push(Action::FireDetected(i));
                }
            }
        }
        out
    }

    /// Apply one action, checking every invariant the transition can
    /// break. Pure: returns the successor state or the violation.
    pub fn apply(
        &self,
        action: Action,
        script: &Script,
        t: &Transitions,
    ) -> Result<ProtocolState, ProtocolViolation> {
        let mut s = self.clone();
        match action {
            Action::IssueStep => {
                for rk in s.ranks.iter_mut().filter(|rk| rk.alive) {
                    rk.queue.push(CmdTag::Step);
                }
                let n = s.ranks.len();
                s.coord = CoordPhase::Stepping { arrived: vec![false; n], poisoned: false };
            }
            Action::Deliver(r) => s.deliver(r, t)?,
            Action::CompleteBarrier => {
                for (r, rk) in s.ranks.iter().enumerate() {
                    if rk.alive && rk.steps_done != s.step {
                        return Err(ProtocolViolation::TornStepDivergence {
                            rank: r,
                            steps_done: rk.steps_done,
                            step: s.step,
                        });
                    }
                }
                for rk in s.ranks.iter_mut().filter(|rk| rk.alive) {
                    rk.steps_done = rk.steps_done.saturating_add(1);
                }
                s.step = s.step.saturating_add(1);
                s.coord = CoordPhase::Idle;
            }
            Action::AbortBarrier => {
                let arrived = match &s.coord {
                    CoordPhase::Stepping { arrived, .. } => arrived.clone(),
                    _ => vec![],
                };
                for (r, rk) in s.ranks.iter_mut().enumerate() {
                    if !rk.alive {
                        continue;
                    }
                    if t.abort_advances_arrived && arrived.get(r).copied().unwrap_or(false)
                    {
                        // seeded mutant: a survivor applies the torn step
                        rk.steps_done = rk.steps_done.saturating_add(1);
                    }
                    rk.queue.retain(|c| !matches!(c, CmdTag::Step));
                }
                s.coord = CoordPhase::Idle;
            }
            Action::FireScheduled => {
                let (_, act) = script.scheduled[self.next_scheduled];
                s.next_scheduled += 1;
                if let MembershipAction::Fail { rank } = act {
                    if let Some(rk) = s.ranks.get_mut(rank) {
                        rk.alive = false;
                        rk.queue.clear();
                    }
                }
                s.begin_quiesce(act, t);
            }
            Action::FireDetected(i) => {
                let rank = script.detected[i];
                s.detected_fired[i] = true;
                if let Some(rk) = s.ranks.get_mut(rank) {
                    rk.alive = false;
                    rk.queue.clear();
                }
                s.pending_fail = Some(rank);
                if let CoordPhase::Stepping { poisoned, .. } = &mut s.coord {
                    *poisoned = true;
                }
            }
            Action::HandleFailure => {
                let rank = match s.pending_fail.take() {
                    Some(r) => r,
                    None => return Ok(s),
                };
                s.begin_quiesce(MembershipAction::Fail { rank }, t);
            }
            Action::Fold => s.fold(script, t)?,
        }
        Ok(s)
    }

    /// Rank `r` processes its FIFO head. The observed layout generation
    /// comes from the shared [`crate::exec::fifo_layout_gen_at`], so the
    /// model's delivery semantics are the executor's by construction.
    fn deliver(&mut self, r: usize, t: &Transitions) -> Result<(), ProtocolViolation> {
        let (head, observed) = {
            let rk = &self.ranks[r];
            match rk.queue.first() {
                Some(&h) => (h, (t.observed_gen)(rk.layout_gen, &rk.queue, 0)),
                None => return Ok(()),
            }
        };
        self.ranks[r].queue.remove(0);
        match head {
            CmdTag::Step => {
                if observed != self.gen {
                    return Err(ProtocolViolation::StaleLayoutStep {
                        rank: r,
                        have: observed,
                        want: self.gen,
                    });
                }
                if let CoordPhase::Stepping { arrived, .. } = &mut self.coord {
                    if let Some(a) = arrived.get_mut(r) {
                        *a = true;
                    }
                }
            }
            CmdTag::Reconfigure => {
                self.ranks[r].layout_gen = observed.saturating_add(1);
            }
            CmdTag::ExportState => {
                let reply = ExportReply {
                    bag: self.ranks[r].bag.clone(),
                    observed_gen: observed,
                };
                self.ranks[r].exports_served =
                    self.ranks[r].exports_served.saturating_add(1);
                if self.ranks[r].exports_served > 1 {
                    return Err(ProtocolViolation::DuplicateExport { rank: r });
                }
                if let CoordPhase::Collecting { got, .. } = &mut self.coord {
                    if let Some(slot) = got.get_mut(r) {
                        *slot = Some(reply);
                    }
                }
            }
            // not part of the membership protocol's quiesce vocabulary
            CmdTag::SetPacer | CmdTag::SetWork | CmdTag::Fail | CmdTag::Shutdown => {}
        }
        Ok(())
    }

    /// Enter the quiesce for `action`: enqueue the coordinator's command
    /// sequence to every live rank and start collecting.
    fn begin_quiesce(&mut self, action: MembershipAction, t: &Transitions) {
        let skip = (t.export_skip)(action);
        let cmds = (t.quiesce_cmds)(action);
        let world = self.ranks.len();
        let mut need = vec![false; world];
        for (r, rk) in self.ranks.iter_mut().enumerate() {
            if !rk.alive || Some(r) == skip {
                continue;
            }
            rk.exports_served = 0;
            rk.queue.extend(cmds.iter().copied());
            need[r] = true;
        }
        self.coord = CoordPhase::Collecting { action, got: vec![None; world], need };
    }

    /// The fold: run the production `redistribute` on the collected
    /// exports and verify the result against the independently-computed
    /// specification mapping (survivors bitwise, orphan into new rank 0,
    /// joiners clean, total mass conserved), then rebuild the world.
    fn fold(&mut self, script: &Script, t: &Transitions) -> Result<(), ProtocolViolation> {
        let (action, got) = match &self.coord {
            CoordPhase::Collecting { action, got, .. } => (*action, got.clone()),
            _ => return Ok(()),
        };
        let minted = script.minted();
        let world = self.ranks.len();
        let label = action.spec();

        // uniform-progress check at the boundary the fold quiesces on
        for (r, rk) in self.ranks.iter().enumerate() {
            if rk.alive && rk.steps_done != self.step {
                return Err(ProtocolViolation::TornStepDivergence {
                    rank: r,
                    steps_done: rk.steps_done,
                    step: self.step,
                });
            }
        }

        // exactly-once export for a clean leaver
        if let MembershipAction::Leave { rank } = action {
            match got.get(rank) {
                Some(Some(_)) => {
                    if self.ranks[rank].exports_served != 1 {
                        return Err(ProtocolViolation::DuplicateExport { rank });
                    }
                }
                _ => return Err(ProtocolViolation::ExportMissed { rank }),
            }
        }

        // FIFO ordering: every export must reflect the generation this
        // fold is redistributing under
        for (r, reply) in got.iter().enumerate() {
            if let Some(reply) = reply {
                if reply.observed_gen != self.gen {
                    return Err(ProtocolViolation::StaleExport {
                        rank: r,
                        observed: reply.observed_gen,
                        expected: self.gen,
                    });
                }
            }
        }

        // the production transition, on the production types
        let states: Vec<Option<Vec<f32>>> = got
            .iter()
            .map(|g| g.as_ref().map(|reply| bag_to_f32(&reply.bag)))
            .collect();
        let new_world = (t.next_world)(world, action).map_err(|e| {
            ProtocolViolation::WorldInvalid { detail: e.to_string() }
        })?;
        let out = (t.redistribute)(states, action, &bag_to_f32(&self.last_combined));
        if out.len() != new_world {
            return Err(ProtocolViolation::ShapeMismatch { got: out.len(), want: new_world });
        }
        let mut actual: Vec<TokenBag> = Vec::with_capacity(new_world);
        for st in &out {
            let bag = match st {
                None => vec![0u8; minted],
                Some(v) => match f32_to_bag(v, minted) {
                    Some(b) => b,
                    None => {
                        return Err(ProtocolViolation::MassNotConserved {
                            action: label,
                            missing: 0,
                        })
                    }
                },
            };
            actual.push(bag);
        }

        // the specification mapping, computed independently from the
        // model's ground-truth bags
        let zero = vec![0u8; minted];
        let (expected, orphan): (Vec<TokenBag>, TokenBag) = match action {
            MembershipAction::Join { count } => {
                let mut exp: Vec<TokenBag> =
                    self.ranks.iter().map(|rk| rk.bag.clone()).collect();
                exp.extend(std::iter::repeat_with(|| zero.clone()).take(count));
                (exp, zero.clone())
            }
            MembershipAction::Leave { rank } | MembershipAction::Fail { rank } => {
                let orphan = match action {
                    MembershipAction::Leave { .. } => self.ranks[rank].bag.clone(),
                    _ => self.last_combined.clone(),
                };
                let survivors: Vec<&RankState> = self
                    .ranks
                    .iter()
                    .enumerate()
                    .filter(|&(r, _)| r != rank)
                    .map(|(_, rk)| rk)
                    .collect();
                let mut exp: Vec<TokenBag> =
                    survivors.iter().map(|rk| rk.bag.clone()).collect();
                if let Some(first) = exp.first_mut() {
                    *first = bag_add(first, &orphan);
                }
                (exp, orphan)
            }
        };

        // decision tree: survivors first (a misrouted orphan shows up as
        // a non-donor gaining exactly the orphan), then the donor, whose
        // deviation is classified by total mass
        for i in 1..new_world {
            if actual[i] != expected[i] {
                if !bag_is_zero(&orphan) && actual[i] == bag_add(&expected[i], &orphan) {
                    return Err(ProtocolViolation::MisroutedFold { action: label, rank: i });
                }
                return Err(ProtocolViolation::SurvivorStateChanged {
                    action: label,
                    rank: i,
                });
            }
        }
        if actual.first() != expected.first() {
            let tot_a: u32 = actual.iter().map(bag_total).sum();
            let tot_e: u32 = expected.iter().map(bag_total).sum();
            return Err(match tot_a.cmp(&tot_e) {
                std::cmp::Ordering::Greater => ProtocolViolation::MassDuplicated {
                    action: label,
                    excess: tot_a - tot_e,
                },
                std::cmp::Ordering::Less => ProtocolViolation::MassNotConserved {
                    action: label,
                    missing: tot_e - tot_a,
                },
                std::cmp::Ordering::Equal => ProtocolViolation::SurvivorStateChanged {
                    action: label,
                    rank: 0,
                },
            });
        }

        // rebuild the world on the re-derived cluster and mixed seed
        let generation = (self.gen as u64) + 1;
        let (nodes, gpn) = (t.next_cluster)(new_world, self.gpn as usize);
        if nodes * gpn != new_world {
            return Err(ProtocolViolation::WorldInvalid {
                detail: format!(
                    "cluster {nodes}x{gpn} does not cover the new world of {new_world}"
                ),
            });
        }
        if (t.generation_seed)(MODEL_SEED, generation) == MODEL_SEED {
            return Err(ProtocolViolation::SeedReplay { generation });
        }
        self.gen = self.gen.saturating_add(1);
        self.gpn = gpn.min(255) as u8;
        self.ranks = actual
            .into_iter()
            .map(|bag| RankState {
                alive: true,
                layout_gen: self.gen,
                steps_done: self.step,
                queue: Vec::new(),
                bag,
                exports_served: 0,
            })
            .collect();
        self.coord = CoordPhase::Idle;
        Ok(())
    }

    /// Liveness: a state with no enabled action must be a clean quiesce —
    /// target depth reached, every scheduled event applied, no pending
    /// failure, no queued command — and every survivor in step.
    pub fn classify_terminal(&self, script: &Script) -> Result<(), ProtocolViolation> {
        let mut stuck = Vec::new();
        if self.step < script.steps {
            stuck.push(format!("{} of {} steps", self.step, script.steps));
        }
        if self.next_scheduled < script.scheduled.len() {
            stuck.push(format!(
                "{} unfired scheduled event(s)",
                script.scheduled.len() - self.next_scheduled
            ));
        }
        if self.pending_fail.is_some() {
            stuck.push("an unhandled detected failure".to_string());
        }
        if !matches!(self.coord, CoordPhase::Idle) {
            stuck.push("coordinator mid-protocol".to_string());
        }
        if self.ranks.iter().any(|rk| rk.alive && !rk.queue.is_empty()) {
            stuck.push("pending rank commands".to_string());
        }
        if !stuck.is_empty() {
            return Err(ProtocolViolation::Deadlock { detail: stuck.join(", ") });
        }
        for (r, rk) in self.ranks.iter().enumerate() {
            if rk.alive && rk.steps_done != self.step {
                return Err(ProtocolViolation::TornStepDivergence {
                    rank: r,
                    steps_done: rk.steps_done,
                    step: self.step,
                });
            }
        }
        Ok(())
    }

    /// Total residual token mass in the world (the conserved quantity,
    /// modulo the documented Fail surrogate substitution).
    pub fn total_mass(&self) -> u32 {
        self.ranks.iter().map(|rk| bag_total(&rk.bag)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(world: usize) -> Script {
        Script { world, gpn: 1, steps: 2, scheduled: vec![], detected: vec![] }
    }

    #[test]
    fn initial_state_mints_one_token_per_rank_plus_surrogate() {
        let s = ProtocolState::initial(&quiet(3));
        assert_eq!(s.total_mass(), 3);
        assert_eq!(s.ranks.len(), 3);
        assert_eq!(bag_total(&s.last_combined), 1);
        assert_eq!(s.last_combined[3], 1, "surrogate token is id `world`");
    }

    #[test]
    fn bag_roundtrip_rejects_non_multisets() {
        assert_eq!(f32_to_bag(&[1.0, 0.0, 2.0], 3), Some(vec![1, 0, 2]));
        assert_eq!(f32_to_bag(&[1.5], 2), None, "fractional counts are not tokens");
        assert_eq!(f32_to_bag(&[-1.0], 2), None, "negative mass is not a multiset");
        assert_eq!(f32_to_bag(&[1.0, 1.0, 1.0], 2), None, "universe overflow");
        let bag = vec![2u8, 0, 1];
        assert_eq!(f32_to_bag(&bag_to_f32(&bag), 3), Some(bag));
    }

    #[test]
    fn quiet_script_steps_to_clean_quiescence() {
        let script = quiet(2);
        let t = Transitions::real();
        let mut s = ProtocolState::initial(&script);
        // drive one deterministic schedule to the end
        let mut guard = 0;
        loop {
            let acts = s.enabled_actions(&script);
            let Some(&a) = acts.first() else { break };
            s = s.apply(a, &script, &t).expect("no violation on the real protocol");
            guard += 1;
            assert!(guard < 100, "schedule failed to quiesce");
        }
        assert!(s.classify_terminal(&script).is_ok());
        assert_eq!(s.step, 2);
        assert_eq!(s.total_mass(), 2, "stepping is mass-neutral");
    }

    #[test]
    fn violation_kinds_are_distinct_and_displayable() {
        let all = [
            ProtocolViolation::MassNotConserved { action: "x".into(), missing: 1 },
            ProtocolViolation::MassDuplicated { action: "x".into(), excess: 1 },
            ProtocolViolation::SurvivorStateChanged { action: "x".into(), rank: 0 },
            ProtocolViolation::MisroutedFold { action: "x".into(), rank: 1 },
            ProtocolViolation::StaleExport { rank: 0, observed: 1, expected: 0 },
            ProtocolViolation::StaleLayoutStep { rank: 0, have: 0, want: 1 },
            ProtocolViolation::TornStepDivergence { rank: 0, steps_done: 1, step: 0 },
            ProtocolViolation::ExportMissed { rank: 0 },
            ProtocolViolation::DuplicateExport { rank: 0 },
            ProtocolViolation::Deadlock { detail: "x".into() },
            ProtocolViolation::WorldInvalid { detail: "x".into() },
            ProtocolViolation::ShapeMismatch { got: 1, want: 2 },
            ProtocolViolation::SeedReplay { generation: 1 },
            ProtocolViolation::StateBoundExceeded { states: 1 },
        ];
        let kinds: std::collections::HashSet<&str> =
            all.iter().map(|v| v.kind()).collect();
        assert_eq!(kinds.len(), all.len(), "kind() must be injective over variants");
        for v in &all {
            assert!(!v.to_string().is_empty());
        }
    }
}
