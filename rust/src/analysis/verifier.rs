//! The static [`HopSchedule`] verifier: proves the executor contract from
//! the hop list alone, without executing anything.
//!
//! Every check returns a distinct [`ScheduleViolation`] naming the exact
//! hop/rank/slot, so a rejected schedule is actionable — the mutation
//! suite in `tests/schedule_verify.rs` pins one variant per corruption.
//!
//! ## The bounded-in-flight argument (proof by construction)
//!
//! The executor parks frames that arrive one collective early
//! (`exec::ring::GatherScratch::pending`). That queue is bounded because
//! **epoch skew is bounded by 1**, which follows from invariants this
//! verifier establishes — it is not a separate runtime property:
//!
//! 1. *Chains root at the owner.* Strictly-earlier sourcing means the
//!    acquisition round strictly decreases along any slot's
//!    delivered-from chain, so every chain terminates at the only rank
//!    holding the slot without a delivery: its owner (checked:
//!    [`ScheduleViolation::SourceMissingSlot`] /
//!    [`ScheduleViolation::SameRoundForward`]).
//! 2. *Completing epoch `e` requires every owner to have started `e`.*
//!    By completeness (checked: [`ScheduleViolation::IncompleteGather`]),
//!    a rank finishing epoch `e` received every slot, and by (1) each of
//!    those deliveries descends from the owner's epoch-`e` send.
//! 3. Therefore while any rank is still *inside* epoch `e`, it has not
//!    sent its own epoch-`e+1` frame, no epoch-`e+1` chain for its slot
//!    exists, no peer can complete `e+1`, and no epoch-`e+2` frame can be
//!    emitted: a frame arriving at a rank in epoch `e` is tagged `e` or
//!    `e+1`, never more. The executor enforces the corollary at runtime
//!    (`MeshError::EpochSkew`).
//!
//! With skew ≤ 1, a rank's inbound queue holds at most `recv_count`
//! undelivered current-epoch frames plus `recv_count` parked next-epoch
//! frames: `max_in_flight = 2·recv_count ≤ 2(P-1)`, including across
//! back-to-back epochs. [`ScheduleReport`] carries the computed bounds.

use std::fmt;

use crate::comm::topology::{HopSchedule, LevelBytes};
use crate::compress::SchemeKind;

/// One reason a schedule fails verification. Variants are deliberately
/// fine-grained: each mutation class gets its own rejection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleViolation {
    /// A hop names a rank or slot outside `0..world`.
    HopOutOfRange { hop: usize, src: u32, dst: u32, slot: u32, world: usize },
    /// A hop sends from a rank to itself.
    SelfHop { hop: usize, round: u32, rank: u32 },
    /// The hop list is not sorted by round.
    OutOfRoundOrder { hop: usize, prev: u32, round: u32 },
    /// A rank is scheduled to receive its own slot.
    OwnSlotDelivery { round: u32, rank: u32 },
    /// A rank receives the same slot twice (breaks exactly-once storage).
    DuplicateDelivery { first_round: u32, round: u32, dst: u32, slot: u32 },
    /// A hop's source never acquires the slot it forwards, or acquires it
    /// at a *later* round than the forward.
    SourceMissingSlot { round: u32, src: u32, slot: u32, acquired: Option<u32> },
    /// Same-round forwards form a dependency cycle: every hop in `hops`
    /// waits on another's delivery — the executor would deadlock.
    RoundCycle { round: u32, hops: Vec<usize> },
    /// A source forwards a slot acquired in the *same* round. Acyclic, so
    /// executable under ordered intra-round delivery — but the executor
    /// guarantees no such ordering, so it is banned outright.
    SameRoundForward { round: u32, src: u32, slot: u32 },
    /// A rank ends the schedule missing `missing` slots.
    IncompleteGather { rank: u32, missing: usize },
    /// The schedule's cached `recv_count` disagrees with its hop list
    /// (the executor trusts the cache for its receive loop).
    RecvCountMismatch { rank: u32, recorded: usize, actual: usize },
    /// A rank's parking bound exceeds `world - 1` frames. Unreachable
    /// while exactly-once delivery holds — kept so the bound is checked
    /// arithmetic, not an assumption.
    InFlightOverflow { rank: u32, parked: usize, limit: usize },
    /// A claimed per-slot frame length disagrees with the codec
    /// arithmetic (`harness::wire_bytes`).
    WireByteMismatch { slot: u32, expected: usize, got: usize },
    /// Received bytes at a rank differ from the total minus its own frame
    /// — bytes were created or destroyed on the wire.
    WireNotConserved { rank: u32, expected: usize, got: usize },
}

impl fmt::Display for ScheduleViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use ScheduleViolation::*;
        match self {
            HopOutOfRange { hop, src, dst, slot, world } => write!(
                f,
                "hop {hop}: ({src} -> {dst}, slot {slot}) out of range for world {world}"
            ),
            SelfHop { hop, round, rank } => {
                write!(f, "hop {hop} (round {round}): rank {rank} sends to itself")
            }
            OutOfRoundOrder { hop, prev, round } => write!(
                f,
                "hop {hop}: round {round} after round {prev} — hop list must be round-sorted"
            ),
            OwnSlotDelivery { round, rank } => {
                write!(f, "round {round}: rank {rank} scheduled to receive its own slot")
            }
            DuplicateDelivery { first_round, round, dst, slot } => write!(
                f,
                "round {round}: rank {dst} receives slot {slot} again (first at round \
                 {first_round}) — exactly-once delivery broken"
            ),
            SourceMissingSlot { round, src, slot, acquired: None } => write!(
                f,
                "round {round}: rank {src} forwards slot {slot} it never acquires"
            ),
            SourceMissingSlot { round, src, slot, acquired: Some(a) } => write!(
                f,
                "round {round}: rank {src} forwards slot {slot} it only acquires at the \
                 later round {a}"
            ),
            RoundCycle { round, hops } => write!(
                f,
                "round {round}: same-round forwards form a dependency cycle through hops \
                 {hops:?} — the executor would deadlock"
            ),
            SameRoundForward { round, src, slot } => write!(
                f,
                "round {round}: rank {src} forwards slot {slot} acquired in the same round \
                 (dependencies must point to strictly earlier rounds)"
            ),
            IncompleteGather { rank, missing } => {
                write!(f, "rank {rank} ends the schedule missing {missing} slot(s)")
            }
            RecvCountMismatch { rank, recorded, actual } => write!(
                f,
                "rank {rank}: cached recv_count {recorded} != {actual} deliveries in the \
                 hop list"
            ),
            InFlightOverflow { rank, parked, limit } => write!(
                f,
                "rank {rank}: parking bound {parked} exceeds the per-link limit {limit}"
            ),
            WireByteMismatch { slot, expected, got } => write!(
                f,
                "slot {slot}: claimed frame length {got} B != codec arithmetic {expected} B"
            ),
            WireNotConserved { rank, expected, got } => write!(
                f,
                "rank {rank}: receives {got} B but conservation requires {expected} B \
                 (total minus its own frame)"
            ),
        }
    }
}

impl std::error::Error for ScheduleViolation {}

/// What verification proves about a valid schedule — the statically
/// derived execution bounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScheduleReport {
    pub world: usize,
    pub rounds: usize,
    pub hops: usize,
    /// Worst-rank frames received per collective (`P - 1` when `P > 1`).
    pub max_recv: usize,
    /// Worst-rank bound on next-epoch frames parked while the current
    /// epoch drains (= `max_recv`; see the module docs for why).
    pub max_park_bound: usize,
    /// Worst-rank bound on frames simultaneously queued on one inbound
    /// link across back-to-back epochs (= `2·max_recv`).
    pub max_in_flight: usize,
    /// The epoch-skew bound the parking protocol relies on (always 1).
    pub epoch_skew: u64,
}

/// Outcome of [`wire_conservation`]: schedule-wide byte accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireReport {
    /// Total bytes moved over the whole schedule (sum over hops).
    pub total_sent: usize,
    /// The same total split per link level.
    pub levels: LevelBytes,
    /// Worst-rank sent bytes.
    pub max_rank_sent: usize,
}

const NO_HOP: usize = usize::MAX;

/// Statically verify the full executor contract (see module docs) and
/// return the proven execution bounds. O(hops + world²) time, O(world²)
/// memory — P = 1024 verifies in well under a second per topology.
pub fn verify_schedule(s: &HopSchedule) -> Result<ScheduleReport, ScheduleViolation> {
    let p = s.world();
    let hops = s.hops();

    // Pass 1 — per-hop structure + the exactly-once delivery map.
    // deliv[dst·p + slot] = index of the hop delivering `slot` to `dst`.
    let mut deliv = vec![NO_HOP; p * p];
    let mut prev_round = 0u32;
    for (i, h) in hops.iter().enumerate() {
        let (src, dst, slot) = (h.src as usize, h.dst as usize, h.slot as usize);
        if src >= p || dst >= p || slot >= p {
            return Err(ScheduleViolation::HopOutOfRange {
                hop: i,
                src: h.src,
                dst: h.dst,
                slot: h.slot,
                world: p,
            });
        }
        if src == dst {
            return Err(ScheduleViolation::SelfHop { hop: i, round: h.round, rank: h.src });
        }
        if h.round < prev_round {
            return Err(ScheduleViolation::OutOfRoundOrder {
                hop: i,
                prev: prev_round,
                round: h.round,
            });
        }
        prev_round = h.round;
        if dst == slot {
            return Err(ScheduleViolation::OwnSlotDelivery { round: h.round, rank: h.dst });
        }
        let cell = &mut deliv[dst * p + slot];
        if *cell != NO_HOP {
            return Err(ScheduleViolation::DuplicateDelivery {
                first_round: hops[*cell].round,
                round: h.round,
                dst: h.dst,
                slot: h.slot,
            });
        }
        *cell = i;
    }

    // Pass 2 — sourcing: each hop's source must hold the slot it forwards
    // (its own, or acquired at an earlier round). Same-round producer
    // edges are collected for the dependency analysis below.
    let mut same_round_edges: Vec<(usize, usize)> = Vec::new();
    for (i, h) in hops.iter().enumerate() {
        let (src, slot) = (h.src as usize, h.slot as usize);
        if src == slot {
            continue; // owns the slot from round 0
        }
        let producer = deliv[src * p + slot];
        if producer == NO_HOP {
            return Err(ScheduleViolation::SourceMissingSlot {
                round: h.round,
                src: h.src,
                slot: h.slot,
                acquired: None,
            });
        }
        let pr = hops[producer].round;
        if pr > h.round {
            return Err(ScheduleViolation::SourceMissingSlot {
                round: h.round,
                src: h.src,
                slot: h.slot,
                acquired: Some(pr),
            });
        }
        if pr == h.round {
            same_round_edges.push((producer, i));
        }
    }

    // Pass 3 — deadlock-freedom. Same-round edges partition by round
    // (both endpoints share one), so one toposort covers all rounds. A
    // cycle is a genuine executor deadlock and is reported as such;
    // acyclic same-round forwards are banned too, but distinctly — they
    // only execute under intra-round ordered delivery, which the mesh
    // does not guarantee.
    if !same_round_edges.is_empty() {
        let mut indeg = vec![0usize; hops.len()];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); hops.len()];
        for &(from, to) in &same_round_edges {
            indeg[to] += 1;
            adj[from].push(to);
        }
        let mut queue: Vec<usize> = (0..hops.len()).filter(|&i| indeg[i] == 0).collect();
        let mut seen = queue.len();
        while let Some(i) = queue.pop() {
            for &j in &adj[i] {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    queue.push(j);
                    seen += 1;
                }
            }
        }
        if seen < hops.len() {
            let cycle: Vec<usize> =
                (0..hops.len()).filter(|&i| indeg[i] > 0).take(8).collect();
            let round = hops[cycle[0]].round;
            return Err(ScheduleViolation::RoundCycle { round, hops: cycle });
        }
        let (_, consumer) = same_round_edges[0];
        let h = &hops[consumer];
        return Err(ScheduleViolation::SameRoundForward {
            round: h.round,
            src: h.src,
            slot: h.slot,
        });
    }

    // Pass 4 — completeness + cached-recv consistency.
    let mut actual_recv = vec![0usize; p];
    for h in hops {
        actual_recv[h.dst as usize] += 1;
    }
    for r in 0..p {
        let missing = (0..p).filter(|&sl| sl != r && deliv[r * p + sl] == NO_HOP).count();
        if missing > 0 {
            return Err(ScheduleViolation::IncompleteGather { rank: r as u32, missing });
        }
        if s.recv_count(r) != actual_recv[r] {
            return Err(ScheduleViolation::RecvCountMismatch {
                rank: r as u32,
                recorded: s.recv_count(r),
                actual: actual_recv[r],
            });
        }
    }

    // Pass 5 — bounded in-flight. With the invariants above established,
    // epoch skew ≤ 1 holds by construction (module docs), so each rank
    // parks at most recv_count next-epoch frames; the explicit limit
    // check is defense in depth against a future invariant regression.
    let max_recv = actual_recv.iter().copied().max().unwrap_or(0);
    let limit = p.saturating_sub(1);
    for (r, &parked) in actual_recv.iter().enumerate() {
        if parked > limit {
            return Err(ScheduleViolation::InFlightOverflow { rank: r as u32, parked, limit });
        }
    }

    Ok(ScheduleReport {
        world: p,
        rounds: s.rounds(),
        hops: hops.len(),
        max_recv,
        max_park_bound: max_recv,
        max_in_flight: 2 * max_recv,
        epoch_skew: 1,
    })
}

/// Check claimed per-slot frame lengths against the codec arithmetic
/// ([`crate::harness::wire_bytes`]) for an `n`-element tensor under
/// `kind`. Frames are size-uniform across ranks for every scheme in the
/// evaluation set, so each slot must claim exactly the arithmetic length.
/// Returns that length.
pub fn verify_frame_lengths(
    kind: &SchemeKind,
    n: usize,
    claimed: &[usize],
) -> Result<usize, ScheduleViolation> {
    let expected = crate::harness::wire_bytes(kind, n);
    for (slot, &got) in claimed.iter().enumerate() {
        if got != expected {
            return Err(ScheduleViolation::WireByteMismatch {
                slot: slot as u32,
                expected,
                got,
            });
        }
    }
    Ok(expected)
}

/// Wire-byte conservation over a (structurally valid) schedule for
/// per-slot frame lengths `lens` (`lens[s]` = encoded length of rank
/// `s`'s frame): every byte sent is received exactly once, and a
/// complete allgather delivers to each rank exactly the total minus its
/// own frame. Checked against the raw hop list — independently of the
/// accounting helpers (`level_bytes_uniform`/`max_level_hops`), so the
/// accounting layer cannot drift from what the executor moves.
pub fn wire_conservation(
    s: &HopSchedule,
    lens: &[usize],
) -> Result<WireReport, ScheduleViolation> {
    let p = s.world();
    assert_eq!(lens.len(), p, "one frame length per rank");
    let mut sent = vec![0usize; p];
    let mut recv = vec![0usize; p];
    let mut levels = LevelBytes::default();
    for h in s.hops() {
        let b = lens[h.slot as usize];
        sent[h.src as usize] += b;
        recv[h.dst as usize] += b;
        levels.add(h.level, b);
    }
    let total: usize = lens.iter().sum();
    if p > 1 {
        for r in 0..p {
            let expected = total - lens[r];
            if recv[r] != expected {
                return Err(ScheduleViolation::WireNotConserved {
                    rank: r as u32,
                    expected,
                    got: recv[r],
                });
            }
        }
    }
    let total_sent: usize = sent.iter().sum();
    debug_assert_eq!(total_sent, recv.iter().sum::<usize>(), "hop loop accounting");
    Ok(WireReport {
        total_sent,
        levels,
        max_rank_sent: sent.into_iter().max().unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::topology::TopologyKind;
    use crate::network::ClusterSpec;

    fn shapes() -> Vec<ClusterSpec> {
        vec![
            ClusterSpec::new(1, 1),
            ClusterSpec::new(1, 3),
            ClusterSpec::new(3, 1),
            ClusterSpec::new(2, 2),
            ClusterSpec::new(2, 3),
            ClusterSpec::new(3, 2),
            ClusterSpec::new(4, 8),
        ]
    }

    #[test]
    fn every_builder_schedule_verifies_with_tight_bounds() {
        for c in shapes() {
            let p = c.world();
            for kind in TopologyKind::all() {
                let s = kind.resolve(c).allgather_schedule(c);
                let rep = verify_schedule(&s)
                    .unwrap_or_else(|v| panic!("{} {c:?}: {v}", kind.spec()));
                assert_eq!(rep.world, p);
                assert_eq!(rep.hops, p * p.saturating_sub(1), "complete allgather hop count");
                assert_eq!(rep.max_recv, p.saturating_sub(1));
                assert_eq!(rep.max_in_flight, 2 * p.saturating_sub(1));
                assert_eq!(rep.epoch_skew, 1);
            }
        }
    }

    #[test]
    fn wire_conservation_holds_for_uniform_and_ragged_lengths() {
        for c in shapes() {
            let p = c.world();
            for kind in TopologyKind::all() {
                let s = kind.resolve(c).allgather_schedule(c);
                // uniform: cross-check the totals against the accounting
                // helpers the analytic backend uses
                let uni = vec![64usize; p];
                let w = wire_conservation(&s, &uni).expect("uniform conserves");
                let helper_total: usize =
                    (0..p).map(|r| s.level_bytes_uniform(r, 64).total()).sum();
                assert_eq!(w.total_sent, helper_total, "{} {c:?}", kind.spec());
                assert_eq!(w.levels.total(), w.total_sent);
                // ragged: conservation is per-slot, not per-average
                let ragged: Vec<usize> = (0..p).map(|r| 10 + 7 * r).collect();
                wire_conservation(&s, &ragged).expect("ragged conserves");
            }
        }
    }

    #[test]
    fn frame_lengths_check_against_codec_arithmetic() {
        let n = 4096;
        for kind in SchemeKind::evaluation_set() {
            let expected = crate::harness::wire_bytes(&kind, n);
            let claimed = vec![expected; 4];
            assert_eq!(verify_frame_lengths(&kind, n, &claimed), Ok(expected));
            let mut bad = claimed.clone();
            bad[2] += 1;
            assert_eq!(
                verify_frame_lengths(&kind, n, &bad),
                Err(ScheduleViolation::WireByteMismatch {
                    slot: 2,
                    expected,
                    got: expected + 1
                }),
                "{}",
                kind.label()
            );
        }
    }

    #[test]
    fn violations_render_actionable_messages() {
        let v = ScheduleViolation::DuplicateDelivery {
            first_round: 0,
            round: 2,
            dst: 3,
            slot: 1,
        };
        let msg = v.to_string();
        assert!(msg.contains("rank 3"), "{msg}");
        assert!(msg.contains("slot 1"), "{msg}");
        assert!(msg.contains("exactly-once"), "{msg}");
    }
}
