//! Collective communication over the simulated cluster: real numerics
//! (ring allreduce / allgather executed over in-process worker buffers)
//! plus wire-cost accounting priced by the network model.
//!
//! The ring allreduce is implemented chunk-for-chunk as NCCL would run it —
//! reduce-scatter then allgather over P logical ranks — rather than as a
//! shortcut `sum`, so chunking invariants (uneven divisions, single-element
//! buffers) are genuinely exercised and the per-rank traffic we charge to
//! the network model matches what the implementation actually moves.
//!
//! [`RingSchedule`] is the chunk schedule itself, factored out so the
//! in-place path here and the threaded executor (`exec::ring`) move
//! byte-identical chunks in the identical order — which is what makes the
//! two paths bitwise-comparable (`exec::ring` is property-tested against
//! [`ring_allreduce`]).
//!
//! Collective *pricing* lives in [`topology`]: every algorithm (flat
//! ring, hierarchical 2-level, binomial tree) is one hop schedule behind
//! the [`topology::Collective`] trait, which both the analytic and the
//! threaded backends consume. The old `allreduce_cost`/`allgather_cost`
//! free functions are retired in its favor.

pub mod topology;

pub use topology::{Collective, CollectiveCost, LevelBytes, LinkLevel, TopologyKind};

/// The chunk schedule of a P-rank ring collective over `n` elements.
///
/// Chunk `c` covers `[c*n/p, (c+1)*n/p)`. Reduce-scatter runs P-1 steps; at
/// step `s` rank `r` sends its partial of chunk `(r - s) mod p` to rank
/// `r+1`, which accumulates `own += incoming`. The allgather phase rotates
/// the completed chunks another P-1 steps. Addition order per chunk is a
/// fixed sequential chain, so any two implementations that follow this
/// schedule produce bitwise-identical sums.
#[derive(Debug, Clone)]
pub struct RingSchedule {
    p: usize,
    n: usize,
    starts: Vec<usize>,
}

impl RingSchedule {
    pub fn new(p: usize, n: usize) -> RingSchedule {
        assert!(p >= 1);
        RingSchedule { p, n, starts: (0..=p).map(|c| c * n / p).collect() }
    }

    pub fn world(&self) -> usize {
        self.p
    }

    pub fn elems(&self) -> usize {
        self.n
    }

    /// Element range of chunk `c`.
    pub fn chunk(&self, c: usize) -> std::ops::Range<usize> {
        self.starts[c]..self.starts[c + 1]
    }

    /// Chunk rank `r` sends to `r+1` at reduce-scatter step `s`.
    pub fn rs_chunk(&self, r: usize, s: usize) -> usize {
        (r + self.p - s) % self.p
    }

    /// Chunk rank `r` sends to `r+1` at allgather step `s`.
    pub fn ag_chunk(&self, r: usize, s: usize) -> usize {
        (r + 1 + self.p - s) % self.p
    }

    /// After reduce-scatter, rank `r` holds the full sum of this chunk.
    pub fn owned_chunk(&self, r: usize) -> usize {
        (r + 1) % self.p
    }

    /// Bytes rank `r` sends over one full allreduce (f32 payload).
    pub fn allreduce_sent_bytes(&self, r: usize) -> usize {
        let mut b = 0;
        for s in 0..self.p.saturating_sub(1) {
            b += self.chunk(self.rs_chunk(r, s)).len() * 4;
            b += self.chunk(self.ag_chunk(r, s)).len() * 4;
        }
        b
    }
}

/// Slot a rank forwards at hop `s` of a P-1-hop object-granular ring
/// rotation (allgather of one object per rank): rank `r` starts by sending
/// its own slot (`s = 0`), then forwards whatever it received last hop.
/// Shared by the in-place [`ring_allgather`] and the threaded
/// `exec::ring::allgather_frames`, so both walk the identical rotation.
pub fn rot_send(p: usize, r: usize, s: usize) -> usize {
    (r + p - s % p) % p
}

/// Slot rank `r` receives at hop `s` — its predecessor's [`rot_send`].
pub fn rot_recv(p: usize, r: usize, s: usize) -> usize {
    rot_send(p, (r + p - 1) % p, s)
}

/// In-place ring AllReduce (sum) over per-rank buffers.
///
/// Implements reduce-scatter + allgather with P-1 steps each over P chunks.
/// All buffers must be the same length. Returns per-rank traffic (bytes) of
/// the f32 payload.
pub fn ring_allreduce(bufs: &mut [Vec<f32>]) -> usize {
    let p = bufs.len();
    assert!(p >= 1);
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "ragged buffers");
    if p == 1 || n == 0 {
        return 0;
    }
    let sched = RingSchedule::new(p, n);
    let mut traffic = 0usize;

    // Reduce-scatter: step s, rank r sends chunk (r - s) to rank r+1.
    for s in 0..p - 1 {
        for r in 0..p {
            let c = sched.rs_chunk(r, s);
            let dst = (r + 1) % p;
            let range = sched.chunk(c);
            traffic += range.len() * 4;
            // dst.chunk[c] += src.chunk[c]
            let (src, dst_buf) = if r < dst {
                let (a, b) = bufs.split_at_mut(dst);
                (&a[r], &mut b[0])
            } else {
                let (a, b) = bufs.split_at_mut(r);
                (&b[0], &mut a[dst])
            };
            for (d, sv) in dst_buf[range.clone()].iter_mut().zip(src[range.clone()].iter()) {
                *d += sv;
            }
        }
    }
    // After reduce-scatter, rank r holds the full sum of chunk (r+1) % p.
    // Allgather: rotate the completed chunks around the ring.
    for s in 0..p - 1 {
        for r in 0..p {
            let c = sched.ag_chunk(r, s);
            let dst = (r + 1) % p;
            let range = sched.chunk(c);
            traffic += range.len() * 4;
            let (src, dst_buf) = if r < dst {
                let (a, b) = bufs.split_at_mut(dst);
                (&a[r], &mut b[0])
            } else {
                let (a, b) = bufs.split_at_mut(r);
                (&b[0], &mut a[dst])
            };
            dst_buf[range.clone()].copy_from_slice(&src[range.clone()]);
        }
    }
    traffic / p // per-rank
}

/// Ring AllGather at object granularity: every rank ends with the
/// rank-major concatenation of all ranks' payloads (sizes may differ).
/// Executed as the real P-1-step rotation — each rank forwards the slot it
/// received in the previous step — and cross-checked against the direct
/// copy. Returns (the concatenation every rank converges to, the maximum
/// bytes any one rank sent).
pub fn ring_allgather(payloads: &[Vec<f32>]) -> (Vec<f32>, usize) {
    let p = payloads.len();
    assert!(p >= 1);
    // slots[r][c] = rank r's copy of rank c's payload (None = not arrived)
    let mut slots: Vec<Vec<Option<Vec<f32>>>> = (0..p)
        .map(|r| {
            (0..p)
                .map(|c| if c == r { Some(payloads[c].clone()) } else { None })
                .collect()
        })
        .collect();
    let mut sent = vec![0usize; p];
    for s in 0..p.saturating_sub(1) {
        // snapshot the outgoing slot ids first (simultaneous exchange)
        let moves: Vec<(usize, usize, Vec<f32>)> = (0..p)
            .map(|r| {
                let c = rot_send(p, r, s);
                let payload =
                    slots[r][c].clone().expect("rotation invariant: slot present");
                sent[r] += payload.len() * 4;
                ((r + 1) % p, c, payload)
            })
            .collect();
        for (dst, c, payload) in moves {
            slots[dst][c] = Some(payload);
        }
    }
    let concat: Vec<f32> = payloads.iter().flat_map(|v| v.iter().copied()).collect();
    for (r, row) in slots.iter().enumerate() {
        let got: Vec<f32> = row
            .iter()
            .flat_map(|o| o.as_ref().expect("all slots arrive").iter().copied())
            .collect();
        debug_assert_eq!(got, concat, "rank {r} rotation mismatch");
    }
    (concat, sent.into_iter().max().unwrap_or(0))
}

/// AllGather: every rank receives every rank's payload. Returns the
/// gathered Vec (rank-major) — callers slice per rank. This is the
/// topology-invariant *oracle*: every [`topology::Collective`] frame
/// allgather must converge to exactly this rank-major concatenation
/// (property-tested in `exec::ring`).
pub fn allgather<T: Clone>(payloads: &[Vec<T>]) -> Vec<Vec<T>> {
    // Numerically trivial in-process; the cost model charges the real wire.
    payloads.to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn allreduce_sums_exactly() {
        let mut bufs = vec![
            vec![1.0f32, 2.0, 3.0, 4.0, 5.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0],
            vec![100.0, 200.0, 300.0, 400.0, 500.0],
        ];
        ring_allreduce(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![111.0, 222.0, 333.0, 444.0, 555.0]);
        }
    }

    #[test]
    fn allreduce_matches_naive_sum_property() {
        prop::check("ring==sum", 51, 60, |rng: &mut Rng| {
            let p = 1 + rng.below(7);
            let n = rng.below(257); // includes n < p and n = 0
            let bufs: Vec<Vec<f32>> =
                (0..p).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
            let want: Vec<f32> =
                (0..n).map(|i| bufs.iter().map(|b| b[i]).sum()).collect();
            let mut got = bufs.clone();
            ring_allreduce(&mut got);
            for b in &got {
                for (g, w) in b.iter().zip(want.iter()) {
                    assert!(
                        (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                        "p={p} n={n}: {g} vs {w}"
                    );
                }
            }
        });
    }

    /// Satellite coverage: the degenerate splits called out in the issue —
    /// uneven n % p, n < p, p = 1, and empty buffers.
    #[test]
    fn allreduce_degenerate_splits() {
        for (p, n) in [(1usize, 0usize), (1, 5), (3, 0), (4, 1), (4, 3), (5, 7), (7, 257)] {
            let mut rng = Rng::seed((p * 1000 + n) as u64);
            let bufs: Vec<Vec<f32>> = (0..p).map(|_| prop::vec_f32(&mut rng, n, 1.0)).collect();
            let want: Vec<f32> =
                (0..n).map(|i| bufs.iter().map(|b| b[i]).sum()).collect();
            let mut got = bufs.clone();
            ring_allreduce(&mut got);
            for b in &got {
                for (g, w) in b.iter().zip(want.iter()) {
                    assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "p={p} n={n}");
                }
            }
        }
    }

    #[test]
    fn allgather_matches_naive_concat_property() {
        prop::check("ring-ag==concat", 52, 60, |rng: &mut Rng| {
            let p = 1 + rng.below(6);
            // ragged sizes, including empty payloads
            let payloads: Vec<Vec<f32>> = (0..p)
                .map(|_| prop::vec_f32(rng, rng.below(64), 1.0))
                .collect();
            let want: Vec<f32> =
                payloads.iter().flat_map(|v| v.iter().copied()).collect();
            let (got, sent_max) = ring_allgather(&payloads);
            assert_eq!(got, want);
            let total: usize = payloads.iter().map(|v| v.len() * 4).sum();
            assert!(sent_max <= total * p, "sent {sent_max} vs total {total}");
        });
    }

    #[test]
    fn rotation_delivers_every_slot_once() {
        for p in 1..=6usize {
            for r in 0..p {
                // receives are the predecessor's sends
                for s in 0..p - 1 {
                    assert_eq!(rot_recv(p, r, s), rot_send(p, (r + p - 1) % p, s));
                }
                // after P-1 hops rank r has received every slot except its own
                let mut have: Vec<bool> = (0..p).map(|c| c == r).collect();
                for s in 0..p - 1 {
                    let c = rot_recv(p, r, s);
                    assert!(!have[c], "p={p} r={r} s={s}: duplicate slot {c}");
                    have[c] = true;
                }
                assert!(have.iter().all(|&h| h), "p={p} r={r}: missing slots");
            }
        }
    }

    #[test]
    fn ring_schedule_partitions_and_rotates() {
        prop::check("ring-schedule", 53, 80, |rng: &mut Rng| {
            let p = 1 + rng.below(8);
            let n = rng.below(300);
            let s = RingSchedule::new(p, n);
            // chunks tile [0, n)
            let mut end = 0usize;
            for c in 0..p {
                let r = s.chunk(c);
                assert_eq!(r.start, end);
                end = r.end;
            }
            assert_eq!(end, n);
            // each reduce-scatter step sends p distinct chunks
            for step in 0..p.saturating_sub(1) {
                let mut seen = vec![false; p];
                for r in 0..p {
                    let c = s.rs_chunk(r, step);
                    assert!(!seen[c]);
                    seen[c] = true;
                }
            }
            // ownership: rank r's owned chunk is the one it last accumulated
            for r in 0..p {
                assert!(s.owned_chunk(r) < p);
            }
        });
    }

    #[test]
    fn schedule_traffic_matches_inplace_accounting() {
        let p = 4;
        let n = 1000;
        let mut bufs: Vec<Vec<f32>> = (0..p).map(|_| vec![1.0f32; n]).collect();
        let per_rank = ring_allreduce(&mut bufs);
        let sched = RingSchedule::new(p, n);
        // in-place accounting divides total by p; per-rank schedule sends
        // the same volume up to chunk rounding
        let sent = sched.allreduce_sent_bytes(0);
        assert!(
            (per_rank as i64 - sent as i64).unsigned_abs() as usize <= p * 8,
            "{per_rank} vs {sent}"
        );
    }

    #[test]
    fn allreduce_traffic_matches_ring_formula() {
        // per-rank traffic = 2 * (p-1)/p * bytes (up to chunk rounding)
        let p = 4;
        let n = 1000;
        let mut bufs: Vec<Vec<f32>> = (0..p).map(|_| vec![1.0f32; n]).collect();
        let per_rank = ring_allreduce(&mut bufs);
        let ideal = 2 * (p - 1) * n * 4 / p;
        assert!(
            (per_rank as i64 - ideal as i64).unsigned_abs() as usize <= p * 4,
            "{per_rank} vs {ideal}"
        );
    }

    /// Satellite regression: single-rank worlds are a no-op collective —
    /// the schedule charges zero bytes and the in-place path moves none.
    #[test]
    fn single_rank_is_noop() {
        let mut bufs = vec![vec![1.0f32, 2.0]];
        assert_eq!(ring_allreduce(&mut bufs), 0);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
        for n in [0usize, 1, 7, 1000] {
            let s = RingSchedule::new(1, n);
            assert_eq!(s.allreduce_sent_bytes(0), 0, "p=1 n={n} must send nothing");
        }
        let (got, sent) = ring_allgather(&[vec![1.0f32, 2.0]]);
        assert_eq!(got, vec![1.0, 2.0]);
        assert_eq!(sent, 0);
    }

    #[test]
    fn cost_helpers_price_by_kind() {
        use crate::network::{ClusterSpec, NetworkModel};
        let net = NetworkModel::default();
        let c = ClusterSpec::ecs(64);
        let topo = TopologyKind::Auto.resolve(c);
        let ar = topo.allreduce_cost(&net, c, 1 << 20);
        let ag = topo.allgather_cost(&net, c, 1 << 20);
        assert!(ag.sim_s > ar.sim_s);
        assert!(ag.bytes_per_rank > ar.bytes_per_rank);
    }
}
