//! Collective communication over the simulated cluster: real numerics
//! (ring allreduce / allgather executed over in-process worker buffers)
//! plus wire-cost accounting priced by the network model.
//!
//! The ring allreduce is implemented chunk-for-chunk as NCCL would run it —
//! reduce-scatter then allgather over P logical ranks — rather than as a
//! shortcut `sum`, so chunking invariants (uneven divisions, single-element
//! buffers) are genuinely exercised and the per-rank traffic we charge to
//! the network model matches what the implementation actually moves.

use crate::network::{ClusterSpec, NetworkModel};

/// Outcome of one collective: simulated wall time + bytes each rank moved.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveCost {
    pub sim_s: f64,
    pub bytes_per_rank: usize,
}

/// In-place ring AllReduce (sum) over per-rank buffers.
///
/// Implements reduce-scatter + allgather with P-1 steps each over P chunks.
/// All buffers must be the same length. Returns per-rank traffic (bytes) of
/// the f32 payload.
pub fn ring_allreduce(bufs: &mut [Vec<f32>]) -> usize {
    let p = bufs.len();
    assert!(p >= 1);
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "ragged buffers");
    if p == 1 || n == 0 {
        return 0;
    }

    // chunk boundaries: chunk c = [starts[c], starts[c+1])
    let starts: Vec<usize> = (0..=p).map(|c| c * n / p).collect();
    let chunk = |c: usize| starts[c]..starts[c + 1];

    let mut traffic = 0usize;

    // Reduce-scatter: step s, rank r sends chunk (r - s) to rank r+1.
    for s in 0..p - 1 {
        for r in 0..p {
            let c = (r + p - s) % p;
            let dst = (r + 1) % p;
            let range = chunk(c);
            traffic += range.len() * 4;
            // dst.chunk[c] += src.chunk[c]
            let (src, dst_buf) = if r < dst {
                let (a, b) = bufs.split_at_mut(dst);
                (&a[r], &mut b[0])
            } else {
                let (a, b) = bufs.split_at_mut(r);
                (&b[0], &mut a[dst])
            };
            for (d, sv) in dst_buf[range.clone()].iter_mut().zip(src[range.clone()].iter()) {
                *d += sv;
            }
        }
    }
    // After reduce-scatter, rank r holds the full sum of chunk (r+1) % p.
    // Allgather: rotate the completed chunks around the ring.
    for s in 0..p - 1 {
        for r in 0..p {
            let c = (r + 1 + p - s) % p;
            let dst = (r + 1) % p;
            let range = chunk(c);
            traffic += range.len() * 4;
            let (src, dst_buf) = if r < dst {
                let (a, b) = bufs.split_at_mut(dst);
                (&a[r], &mut b[0])
            } else {
                let (a, b) = bufs.split_at_mut(r);
                (&b[0], &mut a[dst])
            };
            dst_buf[range.clone()].copy_from_slice(&src[range.clone()]);
        }
    }
    traffic / p // per-rank
}

/// AllGather: every rank receives every rank's payload. Returns the
/// gathered Vec (rank-major) — callers slice per rank.
pub fn allgather<T: Clone>(payloads: &[Vec<T>]) -> Vec<Vec<T>> {
    // Numerically trivial in-process; the cost model charges the real wire.
    payloads.to_vec()
}

/// Price a dense-f32 allreduce of `bytes` on the given fabric.
pub fn allreduce_cost(net: &NetworkModel, cluster: ClusterSpec, bytes: usize) -> CollectiveCost {
    CollectiveCost { sim_s: net.allreduce_s(bytes, cluster), bytes_per_rank: bytes }
}

/// Price an allgather where each rank contributes `bytes`.
pub fn allgather_cost(net: &NetworkModel, cluster: ClusterSpec, bytes: usize) -> CollectiveCost {
    CollectiveCost {
        sim_s: net.allgather_s(bytes, cluster),
        bytes_per_rank: bytes * (cluster.world() - 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn allreduce_sums_exactly() {
        let mut bufs = vec![
            vec![1.0f32, 2.0, 3.0, 4.0, 5.0],
            vec![10.0, 20.0, 30.0, 40.0, 50.0],
            vec![100.0, 200.0, 300.0, 400.0, 500.0],
        ];
        ring_allreduce(&mut bufs);
        for b in &bufs {
            assert_eq!(b, &vec![111.0, 222.0, 333.0, 444.0, 555.0]);
        }
    }

    #[test]
    fn allreduce_matches_naive_sum_property() {
        prop::check("ring==sum", 51, 60, |rng: &mut Rng| {
            let p = 1 + rng.below(7);
            let n = rng.below(257); // includes n < p and n = 0
            let bufs: Vec<Vec<f32>> =
                (0..p).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
            let want: Vec<f32> =
                (0..n).map(|i| bufs.iter().map(|b| b[i]).sum()).collect();
            let mut got = bufs.clone();
            ring_allreduce(&mut got);
            for b in &got {
                for (g, w) in b.iter().zip(want.iter()) {
                    assert!(
                        (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                        "p={p} n={n}: {g} vs {w}"
                    );
                }
            }
        });
    }

    #[test]
    fn allreduce_traffic_matches_ring_formula() {
        // per-rank traffic = 2 * (p-1)/p * bytes (up to chunk rounding)
        let p = 4;
        let n = 1000;
        let mut bufs: Vec<Vec<f32>> = (0..p).map(|_| vec![1.0f32; n]).collect();
        let per_rank = ring_allreduce(&mut bufs);
        let ideal = 2 * (p - 1) * n * 4 / p;
        assert!(
            (per_rank as i64 - ideal as i64).unsigned_abs() as usize <= p * 4,
            "{per_rank} vs {ideal}"
        );
    }

    #[test]
    fn single_rank_is_noop() {
        let mut bufs = vec![vec![1.0f32, 2.0]];
        assert_eq!(ring_allreduce(&mut bufs), 0);
        assert_eq!(bufs[0], vec![1.0, 2.0]);
    }

    #[test]
    fn cost_helpers_price_by_kind() {
        let net = NetworkModel::default();
        let c = ClusterSpec::ecs(64);
        let ar = allreduce_cost(&net, c, 1 << 20);
        let ag = allgather_cost(&net, c, 1 << 20);
        assert!(ag.sim_s > ar.sim_s);
        assert!(ag.bytes_per_rank > ar.bytes_per_rank);
    }
}
