//! The topology layer: pluggable collective algorithms behind one
//! [`Collective`] trait (DESIGN.md §9).
//!
//! Each algorithm — flat ring, hierarchical 2-level, binomial tree — is
//! implemented **once**, as an object-granular [`HopSchedule`]: the exact
//! sequence of `(round, src, dst, slot)` frame movements of an allgather
//! where every rank contributes one wire frame. Both backends consume
//! that single schedule:
//!
//! * the **analytic** backend prices each hop against the per-level
//!   [`NetworkModel`] (intra-node vs inter-node bandwidth and latency) —
//!   [`HopSchedule::cost_uniform`] — and derives per-level wire-byte
//!   accounting from the same hop list
//!   ([`HopSchedule::level_bytes_uniform`]);
//! * the **threaded** backend (`exec::ring::allgather_sched`) rotates the
//!   real encoded frames hop by hop over per-level paced links.
//!
//! The gathered *result* is topology-invariant — every rank ends holding
//! the rank-major frames of all ranks, each received exactly once — so
//! swapping topologies never changes numerics, only who moves which bytes
//! over which link. That invariant (each rank receives each slot exactly
//! once, never its own, and every hop's source already holds the slot it
//! forwards) is what makes the threaded executor's epoch-tagged delivery
//! deadlock-free; it is property-tested below for every topology over
//! degenerate cluster shapes (`p = 1`, `nodes = 1`, `gpus_per_node = 1`).

use crate::network::{ClusterSpec, NetworkModel};

use super::rot_send;

/// Which link a hop crosses: the intra-node fabric (PCIe/NVLink) or the
/// inter-node NIC. Classified from the cluster shape (`rank / g`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkLevel {
    Intra,
    Inter,
}

/// Per-level byte counts of one collective (what a rank sent over each
/// link class). `intra + inter` is the total wire traffic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LevelBytes {
    pub intra: usize,
    pub inter: usize,
}

impl LevelBytes {
    pub fn total(&self) -> usize {
        self.intra + self.inter
    }

    pub fn add(&mut self, level: LinkLevel, bytes: usize) {
        match level {
            LinkLevel::Intra => self.intra += bytes,
            LinkLevel::Inter => self.inter += bytes,
        }
    }
}

/// One frame movement: at `round`, rank `src` sends its copy of slot
/// `slot` (rank `slot`'s frame) to rank `dst` over `level`.
#[derive(Debug, Clone, Copy)]
pub struct Hop {
    pub round: u32,
    pub src: u32,
    pub dst: u32,
    pub slot: u32,
    pub level: LinkLevel,
}

/// A complete object-granular allgather schedule over `world` ranks:
/// every rank starts holding its own slot and ends holding all of them.
///
/// Contract (checked by [`HopSchedule::validate`], property-tested for
/// every topology): hops are sorted by round; each rank receives each
/// slot **exactly once** and never its own; a hop's source holds the slot
/// it forwards (its own, or one received at a strictly earlier round).
/// Exactly-once delivery is what lets the threaded executor store frames
/// on arrival without round bookkeeping, and the strictly-earlier-round
/// dependency is what makes that execution deadlock-free.
#[derive(Debug, Clone)]
pub struct HopSchedule {
    world: usize,
    rounds: usize,
    hops: Vec<Hop>,
    /// Frames each rank receives over the whole schedule (`p - 1` for a
    /// complete allgather; kept explicit so the executor needs no rule).
    recvs: Vec<usize>,
}

/// Incremental builder: classifies each hop's level from the cluster
/// shape and tracks the round count.
struct SchedBuilder {
    cluster: ClusterSpec,
    hops: Vec<Hop>,
    rounds: usize,
}

impl SchedBuilder {
    fn new(cluster: ClusterSpec) -> SchedBuilder {
        SchedBuilder { cluster, hops: Vec::new(), rounds: 0 }
    }

    fn push(&mut self, round: usize, src: usize, dst: usize, slot: usize) {
        debug_assert_ne!(src, dst, "self-hop");
        let level = link_level(self.cluster, src, dst);
        self.rounds = self.rounds.max(round + 1);
        self.hops.push(Hop {
            round: round as u32,
            src: src as u32,
            dst: dst as u32,
            slot: slot as u32,
            level,
        });
    }

    fn finish(self) -> HopSchedule {
        let world = self.cluster.world();
        let mut recvs = vec![0usize; world];
        for h in &self.hops {
            recvs[h.dst as usize] += 1;
        }
        let s = HopSchedule { world, rounds: self.rounds, hops: self.hops, recvs };
        // Static verification at build time (debug builds): every schedule
        // a builder emits satisfies the executor contract before anything
        // runs. Release builds (and P=1024 sweeps) verify on demand via
        // `analysis::verify_schedule` / the verify-schedules CLI.
        #[cfg(debug_assertions)]
        if let Err(v) = crate::analysis::verify_schedule(&s) {
            panic!("SchedBuilder emitted an invalid schedule: {v}");
        }
        s
    }
}

impl HopSchedule {
    pub fn world(&self) -> usize {
        self.world
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }

    pub fn hops(&self) -> &[Hop] {
        &self.hops
    }

    /// Frames rank `r` receives over the whole schedule.
    pub fn recv_count(&self, r: usize) -> usize {
        self.recvs[r]
    }

    /// Hops rank `r` sends per link level (frame *counts*). Callers that
    /// stamp accounting per record should cache this — it scans the whole
    /// hop list — and multiply by the frame length themselves.
    pub fn level_hops(&self, r: usize) -> LevelBytes {
        let mut out = LevelBytes::default();
        for h in &self.hops {
            if h.src as usize == r {
                out.add(h.level, 1);
            }
        }
        out
    }

    /// Worst-rank hops per link level, maxima taken independently — the
    /// per-level traffic budget one collective costs the busiest NIC and
    /// the busiest PCIe lane (which may be different ranks: on a
    /// multi-node flat ring the node-boundary rank ships everything over
    /// the NIC while interior ranks ship everything intra). This is the
    /// reduction the measured side uses too (`exec::timeline::aggregate`
    /// takes worst-rank moved bytes per level), so stamped accounting and
    /// measured traffic agree for size-uniform schemes.
    pub fn max_level_hops(&self) -> LevelBytes {
        let mut per = vec![LevelBytes::default(); self.world];
        for h in &self.hops {
            per[h.src as usize].add(h.level, 1);
        }
        let mut out = LevelBytes::default();
        for lb in per {
            out.intra = out.intra.max(lb.intra);
            out.inter = out.inter.max(lb.inter);
        }
        out
    }

    /// Bytes rank `r` sends per link level when every frame is `bytes`
    /// long — the per-rank view (tests compare it against each rank's
    /// measured traffic). Stamped accounting (`CommRecord.levels`) uses
    /// the worst-rank [`HopSchedule::max_level_hops`] instead: on a
    /// multi-node flat ring rank 0 never crosses a node while the
    /// boundary rank ships everything over the NIC.
    pub fn level_bytes_uniform(&self, r: usize, bytes: usize) -> LevelBytes {
        let hops = self.level_hops(r);
        LevelBytes { intra: hops.intra * bytes, inter: hops.inter * bytes }
    }

    /// Price the schedule on the α–β model with uniform `bytes`-long
    /// frames: within a round each rank's sends serialize on its own link
    /// (one NIC / one PCIe lane per rank), rounds rendezvous on the
    /// slowest rank — the lockstep form of what the threaded executor
    /// does with per-level `exec::ring::Pacer`s.
    pub fn cost_uniform(&self, net: &NetworkModel, bytes: usize) -> f64 {
        let mut per_src = vec![0.0f64; self.world];
        let mut total = 0.0;
        let mut i = 0;
        while i < self.hops.len() {
            let round = self.hops[i].round;
            per_src.fill(0.0);
            let mut worst = 0.0f64;
            while i < self.hops.len() && self.hops[i].round == round {
                let h = &self.hops[i];
                let (bps, lat) = level_rate(net, h.level);
                let src = h.src as usize;
                per_src[src] += bytes as f64 / bps + lat;
                worst = worst.max(per_src[src]);
                i += 1;
            }
            total += worst;
        }
        total
    }

    /// Check the full allgather contract; panics with the verifier's
    /// diagnostic on the first violation. This is a thin wrapper over the
    /// single implementation in [`crate::analysis::verify_schedule`]
    /// (which `tests/schedule_verify.rs` cross-checks against an
    /// independent hand-rolled oracle); the panic signature is kept for
    /// the historical property tests.
    pub fn validate(&self) {
        if let Err(v) = crate::analysis::verify_schedule(self) {
            panic!("invalid hop schedule: {v}");
        }
    }

    /// Assemble a schedule from a raw hop list, recomputing the receive
    /// counts and the round count. **No verification runs** — this is the
    /// constructor the mutation tests use to feed deliberately corrupt
    /// schedules to the verifier, and the staging point any future
    /// elastic-membership rebuild can use before verifying explicitly.
    pub fn from_raw_hops(world: usize, hops: Vec<Hop>) -> HopSchedule {
        let rounds = hops.iter().map(|h| h.round as usize + 1).max().unwrap_or(0);
        let mut recvs = vec![0usize; world];
        for h in &hops {
            // out-of-range destinations stay constructible: the verifier
            // reports them as HopOutOfRange instead of panicking here
            if let Some(r) = recvs.get_mut(h.dst as usize) {
                *r += 1;
            }
        }
        HopSchedule { world, rounds, hops, recvs }
    }
}

/// Effective (bytes/s, per-hop latency) of one link level.
pub fn level_rate(net: &NetworkModel, level: LinkLevel) -> (f64, f64) {
    match level {
        LinkLevel::Intra => (net.intra_bps(), NetworkModel::INTRA_LATENCY_S),
        LinkLevel::Inter => (net.effective_bps(), net.latency_s),
    }
}

/// The link class a hop between two ranks crosses — the single
/// classification rule every schedule builder and closed-form pricer
/// shares (rank-major placement via [`ClusterSpec::node_of`]).
pub fn link_level(cluster: ClusterSpec, a: usize, b: usize) -> LinkLevel {
    if cluster.node_of(a) == cluster.node_of(b) {
        LinkLevel::Intra
    } else {
        LinkLevel::Inter
    }
}

/// Outcome of pricing one collective: simulated wall time + the dense
/// payload bytes each rank contributes/receives (accounting volume).
/// Replaces the retired `comm::{allreduce_cost, allgather_cost}` free
/// functions — costs now come from a [`Collective`], never a bare model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectiveCost {
    pub sim_s: f64,
    pub bytes_per_rank: usize,
}

/// One collective algorithm (topology): the schedule/cost split.
///
/// `allgather_schedule` is the single implementation of the algorithm —
/// the threaded executor executes it frame by frame, and the default
/// `allgather_s` prices the identical hop list per level. `allreduce_s`
/// prices the topology's dense summable collective (chunk-granular, so it
/// is closed-form rather than schedule-derived); `sync_round_s` prices
/// the small synchronous rendezvous of data-dependent schemes, where the
/// binomial tree's `O(log P)` depth is the whole point.
pub trait Collective: Send + Sync {
    fn name(&self) -> &'static str;

    /// The object-granular allgather hop schedule (one frame per rank)
    /// for a cluster of exactly `cluster.world()` ranks.
    fn allgather_schedule(&self, cluster: ClusterSpec) -> HopSchedule;

    /// Price a dense ring/tree AllReduce of `bytes` per rank.
    fn allreduce_s(&self, net: &NetworkModel, cluster: ClusterSpec, bytes: usize) -> f64;

    /// Price the frame allgather where each rank contributes `bytes`.
    /// The default rebuilds the hop schedule and prices it per level —
    /// always correct, but O(hops) per call; the provided topologies
    /// override it with round-walk forms that compute the identical
    /// per-round maxima without materializing the hop list.
    fn allgather_s(&self, net: &NetworkModel, cluster: ClusterSpec, bytes: usize) -> f64 {
        self.allgather_schedule(cluster)
            .cost_uniform(net, bytes)
            .max(net.latency_s)
    }

    /// A small synchronous rendezvous (threshold / count exchange).
    fn sync_round_s(&self, net: &NetworkModel, cluster: ClusterSpec) -> f64;

    fn allreduce_cost(
        &self,
        net: &NetworkModel,
        cluster: ClusterSpec,
        bytes: usize,
    ) -> CollectiveCost {
        CollectiveCost { sim_s: self.allreduce_s(net, cluster, bytes), bytes_per_rank: bytes }
    }

    fn allgather_cost(
        &self,
        net: &NetworkModel,
        cluster: ClusterSpec,
        bytes: usize,
    ) -> CollectiveCost {
        CollectiveCost {
            sim_s: self.allgather_s(net, cluster, bytes),
            bytes_per_rank: bytes * (cluster.world() - 1),
        }
    }
}

/// Flat ring over all `P` ranks in rank-major order: hops within a node
/// are intra-level, the node-boundary hops cross the NIC. The rotation is
/// [`rot_send`] — identical to the pre-topology `exec::ring` path, so the
/// slot movement of existing ring tests is unchanged.
#[derive(Debug, Clone, Copy, Default)]
pub struct FlatRing;

/// Hierarchical 2-level collective: intra-node ring allgather (every rank
/// gets its node's bundle), `g` parallel inter-node rings (local rank `j`
/// of each node rotates the `j`-slots across nodes), then an intra-node
/// ring allgather of the remote bundles. The 2-level pipelined collective
/// is exactly what the calibrated [`NetworkModel`] α–β pricing models
/// (DESIGN.md §2), so this topology's analytic allreduce *and* allgather
/// costs delegate to it; the per-level byte accounting and the threaded
/// execution derive from the hop schedule.
#[derive(Debug, Clone, Copy, Default)]
pub struct Hier2Level;

/// Binomial tree: gather everything to rank 0 up a binomial tree
/// (`ceil(log2 P)` rounds), then broadcast down the mirror tree, each
/// parent sending a child exactly the slots outside the child's own
/// subtree (so delivery stays exactly-once). Latency-optimal — `O(log P)`
/// rounds instead of `O(P)` — which is why it wins for the small-frame
/// sync rounds; bandwidth-poor at the root for large frames.
#[derive(Debug, Clone, Copy, Default)]
pub struct BinomialTree;

pub static RING: FlatRing = FlatRing;
pub static HIER: Hier2Level = Hier2Level;
pub static TREE: BinomialTree = BinomialTree;

fn ceil_log2(p: usize) -> u32 {
    debug_assert!(p >= 1);
    usize::BITS - (p - 1).leading_zeros()
}

impl Collective for FlatRing {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn allgather_schedule(&self, cluster: ClusterSpec) -> HopSchedule {
        let p = cluster.world();
        let mut b = SchedBuilder::new(cluster);
        for s in 0..p.saturating_sub(1) {
            for r in 0..p {
                b.push(s, r, (r + 1) % p, rot_send(p, r, s));
            }
        }
        b.finish()
    }

    fn allreduce_s(&self, net: &NetworkModel, c: ClusterSpec, bytes: usize) -> f64 {
        let p = c.world();
        if p <= 1 {
            return net.latency_s;
        }
        // 2(P-1) rounds of one `bytes/P` chunk per link; every round is
        // bound by its slowest hop. Degenerates to the calibrated
        // NetworkModel formulas (same arithmetic, up to fp association)
        // when the cluster is single-node or one-rank-per-node.
        let rounds = 2.0 * (p as f64 - 1.0);
        let chunk = bytes as f64 / p as f64;
        let intra_s = chunk / net.intra_bps() + NetworkModel::INTRA_LATENCY_S;
        let round_s = if c.nodes > 1 {
            let inter_s = chunk / net.effective_bps() + net.latency_s;
            if c.gpus_per_node > 1 {
                inter_s.max(intra_s)
            } else {
                inter_s
            }
        } else {
            intra_s
        };
        (rounds * round_s).max(net.latency_s)
    }

    /// Closed form of the ring schedule's per-round pricing: P-1 rounds,
    /// each rank sends one slot, the round rendezvouses on its slowest
    /// hop (the inter-node one whenever the ring crosses nodes — with a
    /// max against the intra hop, matching the schedule's true per-round
    /// worst on fabrics where PCIe is the slower link).
    fn allgather_s(&self, net: &NetworkModel, c: ClusterSpec, bytes: usize) -> f64 {
        let p = c.world();
        if p <= 1 {
            return net.latency_s;
        }
        let (intra_bps, intra_lat) = level_rate(net, LinkLevel::Intra);
        let intra_hop = bytes as f64 / intra_bps + intra_lat;
        let round_s = if c.nodes > 1 {
            let (bps, lat) = level_rate(net, LinkLevel::Inter);
            let inter_hop = bytes as f64 / bps + lat;
            if c.gpus_per_node > 1 {
                inter_hop.max(intra_hop)
            } else {
                inter_hop
            }
        } else {
            intra_hop
        };
        ((p as f64 - 1.0) * round_s).max(net.latency_s)
    }

    fn sync_round_s(&self, net: &NetworkModel, c: ClusterSpec) -> f64 {
        if c.nodes == 1 {
            net.latency_s
        } else {
            2.0 * (c.world() as f64 - 1.0) * net.latency_s
        }
    }
}

impl Collective for Hier2Level {
    fn name(&self) -> &'static str {
        "hier"
    }

    fn allgather_schedule(&self, cluster: ClusterSpec) -> HopSchedule {
        let n = cluster.nodes;
        let g = cluster.gpus_per_node;
        let mut b = SchedBuilder::new(cluster);
        let mut round = 0usize;
        // Phase A: intra-node ring allgather of the local slots — every
        // rank ends holding its node's bundle.
        for s in 0..g.saturating_sub(1) {
            for node in 0..n {
                for j in 0..g {
                    let src = node * g + j;
                    let dst = node * g + (j + 1) % g;
                    let slot = node * g + rot_send(g, j, s);
                    b.push(round + s, src, dst, slot);
                }
            }
        }
        round += g.saturating_sub(1);
        // Phase B: g parallel inter-node rings — ring j (local rank j of
        // every node) rotates the j-slots across nodes, so each node's
        // NIC moves (N-1) * g frames total but each *rank* only (N-1).
        for s in 0..n.saturating_sub(1) {
            for j in 0..g {
                for node in 0..n {
                    let src = node * g + j;
                    let dst = ((node + 1) % n) * g + j;
                    let slot = rot_send(n, node, s) * g + j;
                    b.push(round + s, src, dst, slot);
                }
            }
        }
        round += n.saturating_sub(1);
        // Phase C: intra-node ring allgather of the remote bundles —
        // local rank j contributes the (N-1) j-slots it fetched in B.
        for s in 0..g.saturating_sub(1) {
            for node in 0..n {
                for j in 0..g {
                    let src = node * g + j;
                    let dst = node * g + (j + 1) % g;
                    let owner = rot_send(g, j, s);
                    for m in 0..n {
                        if m != node {
                            b.push(round + s, src, dst, m * g + owner);
                        }
                    }
                }
            }
        }
        b.finish()
    }

    fn allreduce_s(&self, net: &NetworkModel, c: ClusterSpec, bytes: usize) -> f64 {
        // The calibrated α–β model *is* the pipelined 2-level allreduce
        // (intra reduce / inter ring / intra broadcast, slower stage
        // binds) — DESIGN.md §2.
        net.allreduce_s(bytes, c)
    }

    /// The calibrated α–β allgather (per-node NIC shared by all g local
    /// ranks, intra and inter stages pipelined). Pricing the hop schedule
    /// with per-rank links would credit phase B's g parallel rings with
    /// g× the node's NIC bandwidth — so, like `allreduce_s`, the analytic
    /// cost stays with the Table-I-calibrated model and the hop schedule
    /// remains the source of byte accounting and threaded execution only.
    /// (Ring and tree have at most one inter-node sender per node per
    /// round, so their schedule-derived pricing has no such contention
    /// blind spot.) This also keeps `TopologyKind::Auto` pricing on
    /// 2-level clusters bitwise-identical to the pre-topology
    /// `NetworkModel::allgather_s` path.
    fn allgather_s(&self, net: &NetworkModel, c: ClusterSpec, bytes: usize) -> f64 {
        net.allgather_s(bytes, c)
    }

    fn sync_round_s(&self, net: &NetworkModel, c: ClusterSpec) -> f64 {
        net.sync_round_s(c)
    }
}

impl Collective for BinomialTree {
    fn name(&self) -> &'static str {
        "tree"
    }

    fn allgather_schedule(&self, cluster: ClusterSpec) -> HopSchedule {
        let p = cluster.world();
        let mut b = SchedBuilder::new(cluster);
        if p <= 1 {
            return b.finish();
        }
        let k_max = ceil_log2(p);
        let mut round = 0usize;
        // Gather: round k, rank r (r ≡ 2^k mod 2^(k+1)) ships its whole
        // subtree [r, r + 2^k) to its parent r - 2^k.
        for k in 0..k_max {
            let stride = 1usize << k;
            let mut r = stride;
            while r < p {
                for slot in r..(r + stride).min(p) {
                    b.push(round, r, r - stride, slot);
                }
                r += 2 * stride;
            }
            round += 1;
        }
        // Broadcast: mirror tree, each parent sending a child exactly the
        // slots outside the child's subtree (the child gathered those
        // itself), keeping delivery exactly-once.
        for k in (0..k_max).rev() {
            let stride = 1usize << k;
            let mut r = 0usize;
            while r < p {
                let dst = r + stride;
                if dst < p {
                    let sub = dst..(dst + stride).min(p);
                    for slot in 0..p {
                        if !sub.contains(&slot) {
                            b.push(round, r, dst, slot);
                        }
                    }
                }
                r += 2 * stride;
            }
            round += 1;
        }
        b.finish()
    }

    fn allreduce_s(&self, net: &NetworkModel, c: ClusterSpec, bytes: usize) -> f64 {
        let p = c.world();
        if p <= 1 {
            return net.latency_s;
        }
        // Reduce up + broadcast down: 2·ceil(log2 P) rounds, each moving
        // the full buffer over the round's widest link. A round crosses
        // nodes iff any of its parent↔child pairs does (checked against
        // the actual rank-major placement — sub-stride hops still cross
        // when gpus_per_node is not a power of two).
        let mut total = 0.0;
        for k in 0..ceil_log2(p) {
            let stride = 1usize << k;
            let mut level = LinkLevel::Intra;
            let mut r = stride;
            while r < p {
                if link_level(c, r, r - stride) == LinkLevel::Inter {
                    level = LinkLevel::Inter;
                    break;
                }
                r += 2 * stride;
            }
            let (bps, lat) = level_rate(net, level);
            total += bytes as f64 / bps + lat;
        }
        (2.0 * total).max(net.latency_s)
    }

    /// Round walk over the gather/broadcast trees without materializing
    /// the hop list: per round, each sender ships its whole
    /// subtree-complement serially, and the round rendezvouses on its
    /// slowest sender — the same per-round maxima
    /// [`HopSchedule::cost_uniform`] computes from the schedule.
    fn allgather_s(&self, net: &NetworkModel, c: ClusterSpec, bytes: usize) -> f64 {
        let p = c.world();
        if p <= 1 {
            return net.latency_s;
        }
        let mut total = 0.0;
        // gather: sender r ships [r, r+stride) ∩ [0, p) to r - stride
        for k in 0..ceil_log2(p) {
            let stride = 1usize << k;
            let mut worst = 0.0f64;
            let mut r = stride;
            while r < p {
                let cnt = (r + stride).min(p) - r;
                let (bps, lat) = level_rate(net, link_level(c, r, r - stride));
                worst = worst.max(cnt as f64 * (bytes as f64 / bps + lat));
                r += 2 * stride;
            }
            total += worst;
        }
        // broadcast: sender r ships everything outside the child's
        // subtree to dst = r + stride
        for k in (0..ceil_log2(p)).rev() {
            let stride = 1usize << k;
            let mut worst = 0.0f64;
            let mut r = 0usize;
            while r < p {
                let dst = r + stride;
                if dst < p {
                    let cnt = p - ((dst + stride).min(p) - dst);
                    let (bps, lat) = level_rate(net, link_level(c, r, dst));
                    worst = worst.max(cnt as f64 * (bytes as f64 / bps + lat));
                }
                r += 2 * stride;
            }
            total += worst;
        }
        total.max(net.latency_s)
    }

    fn sync_round_s(&self, net: &NetworkModel, c: ClusterSpec) -> f64 {
        let p = c.world();
        if p <= 1 {
            return net.latency_s;
        }
        let lat = if c.nodes > 1 {
            net.latency_s
        } else {
            NetworkModel::INTRA_LATENCY_S
        };
        (2.0 * ceil_log2(p) as f64 * lat).max(net.latency_s)
    }
}

/// The config-facing topology selector (`topology = ring | hier | tree |
/// auto` in JSON/CLI). `Auto` picks by cluster shape: hierarchical when
/// the cluster actually has two levels (`nodes > 1` *and*
/// `gpus_per_node > 1`), flat ring otherwise — a single-node or
/// one-rank-per-node cluster has only one link class, where `hier`
/// degenerates to the ring anyway.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TopologyKind {
    Ring,
    Hier,
    Tree,
    #[default]
    Auto,
}

impl TopologyKind {
    pub fn parse(s: &str) -> Option<TopologyKind> {
        match s.to_ascii_lowercase().as_str() {
            "ring" | "flat" => Some(TopologyKind::Ring),
            "hier" | "hierarchical" | "2level" => Some(TopologyKind::Hier),
            "tree" | "binomial" => Some(TopologyKind::Tree),
            "auto" => Some(TopologyKind::Auto),
            _ => None,
        }
    }

    /// Canonical spec string; `parse(&k.spec())` round-trips.
    pub fn spec(&self) -> &'static str {
        match self {
            TopologyKind::Ring => "ring",
            TopologyKind::Hier => "hier",
            TopologyKind::Tree => "tree",
            TopologyKind::Auto => "auto",
        }
    }

    /// Resolve to the concrete algorithm for a cluster shape.
    pub fn resolve(&self, cluster: ClusterSpec) -> &'static dyn Collective {
        match self {
            TopologyKind::Ring => &RING,
            TopologyKind::Hier => &HIER,
            TopologyKind::Tree => &TREE,
            TopologyKind::Auto => {
                if cluster.nodes > 1 && cluster.gpus_per_node > 1 {
                    &HIER
                } else {
                    &RING
                }
            }
        }
    }

    pub fn all() -> [TopologyKind; 3] {
        [TopologyKind::Ring, TopologyKind::Hier, TopologyKind::Tree]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<ClusterSpec> {
        vec![
            ClusterSpec::new(1, 1),
            ClusterSpec::new(1, 3),
            ClusterSpec::new(1, 8),
            ClusterSpec::new(3, 1),
            ClusterSpec::new(2, 2),
            ClusterSpec::new(2, 3),
            ClusterSpec::new(3, 2),
            ClusterSpec::new(4, 8),
            ClusterSpec::new(5, 3), // non-power-of-two world for the tree
        ]
    }

    /// The schedule contract for every topology × degenerate/odd shapes:
    /// exactly-once delivery, sources hold what they forward, everyone
    /// converges. This is the satellite property the executor relies on.
    #[test]
    fn every_topology_schedule_is_a_complete_allgather() {
        for c in shapes() {
            for kind in TopologyKind::all() {
                let topo = kind.resolve(c);
                let s = topo.allgather_schedule(c);
                assert_eq!(s.world(), c.world(), "{}", topo.name());
                s.validate();
                for r in 0..c.world() {
                    assert_eq!(
                        s.recv_count(r),
                        c.world() - 1,
                        "{} {c:?}: rank {r} must receive P-1 frames",
                        topo.name()
                    );
                }
            }
        }
    }

    #[test]
    fn ring_matches_legacy_rotation() {
        // The flat ring must move exactly the rot_send slots the
        // pre-topology executor moved (bitwise compatibility anchor).
        let c = ClusterSpec::new(5, 1);
        let s = RING.allgather_schedule(c);
        for h in s.hops() {
            assert_eq!(h.dst as usize, (h.src as usize + 1) % 5);
            assert_eq!(
                h.slot as usize,
                rot_send(5, h.src as usize, h.round as usize)
            );
        }
        assert_eq!(s.rounds(), 4);
    }

    #[test]
    fn single_rank_schedules_are_empty_noops() {
        // Satellite regression: p = 1 worlds are no-op collectives.
        let c = ClusterSpec::new(1, 1);
        for kind in TopologyKind::all() {
            let s = kind.resolve(c).allgather_schedule(c);
            assert!(s.hops().is_empty(), "{}", kind.spec());
            assert_eq!(s.recv_count(0), 0);
            assert_eq!(s.level_bytes_uniform(0, 128), LevelBytes::default());
        }
    }

    #[test]
    fn hier_moves_fewer_inter_bytes_than_ring() {
        // The point of the hierarchy: per-rank inter-node traffic drops
        // from (P-1)·b to (N-1)·b frames.
        let c = ClusterSpec::new(4, 8);
        let b = 1000usize;
        let ring = RING.allgather_schedule(c);
        let hier = HIER.allgather_schedule(c);
        // ring: the node-boundary rank ships all P-1 slots over the NIC
        let ring_inter: usize =
            (0..c.world()).map(|r| ring.level_bytes_uniform(r, b).inter).max().unwrap();
        let hier_inter: usize =
            (0..c.world()).map(|r| hier.level_bytes_uniform(r, b).inter).max().unwrap();
        assert_eq!(ring_inter, 31 * b);
        assert_eq!(hier_inter, 3 * b, "each rank rides its own inter ring");
        // total per-node NIC traffic also drops: (N-1)·g vs (P-1)
        let node_inter = |s: &HopSchedule| -> usize {
            (0..8).map(|j| s.level_bytes_uniform(j, b).inter).sum()
        };
        assert_eq!(node_inter(&ring), 31 * b);
        assert_eq!(node_inter(&hier), 24 * b);
        // every rank's totals are symmetric in the hierarchical schedule
        for r in 0..c.world() {
            assert_eq!(hier.level_bytes_uniform(r, b), hier.level_bytes_uniform(0, b));
        }
        // the worst-rank accounting reduction sees exactly those maxima —
        // NOT rank 0's walk, which on the flat ring never crosses a node
        assert_eq!(ring.max_level_hops().inter * b, ring_inter);
        assert_eq!(hier.max_level_hops().inter * b, hier_inter);
        assert_eq!(ring.level_bytes_uniform(0, b).inter, 0, "rank 0 stays on-node");
        assert_eq!(ring.max_level_hops().intra, 31, "interior ranks ship everything intra");
    }

    #[test]
    fn hier_degenerates_to_ring_on_flat_clusters() {
        for c in [ClusterSpec::new(1, 6), ClusterSpec::new(6, 1)] {
            let hier = HIER.allgather_schedule(c);
            let ring = RING.allgather_schedule(c);
            assert_eq!(hier.hops().len(), ring.hops().len(), "{c:?}");
            assert_eq!(hier.rounds(), ring.rounds(), "{c:?}");
        }
    }

    #[test]
    fn tree_has_log_depth() {
        let c = ClusterSpec::new(8, 8);
        let s = TREE.allgather_schedule(c);
        assert_eq!(s.rounds(), 12, "2 * ceil(log2 64)");
        let ring = RING.allgather_schedule(c);
        assert!(s.rounds() < ring.rounds() / 4);
    }

    #[test]
    fn modeled_costs_order_sensibly() {
        let net = NetworkModel::default();
        let c = ClusterSpec::new(4, 8);
        let mb = 1 << 20;
        // hierarchical beats the flat ring on a 2-level cluster for the
        // dense allreduce (the acceptance criterion's modeled half)
        assert!(HIER.allreduce_s(&net, c, 32 * mb) < RING.allreduce_s(&net, c, 32 * mb));
        // hier's allgather pricing IS the calibrated per-node-NIC model —
        // pinned so `auto` on 2-level clusters reprices nothing
        assert_eq!(HIER.allgather_s(&net, c, mb), net.allgather_s(mb, c));
        // the tree wins the latency race (tiny frames) but loses the
        // bandwidth race (large frames) against the ring
        assert!(TREE.sync_round_s(&net, c) < RING.sync_round_s(&net, c));
        assert!(TREE.allgather_s(&net, c, 8 * mb) > RING.allgather_s(&net, c, 8 * mb));
    }

    #[test]
    fn ring_degenerate_costs_match_calibrated_model() {
        // On one-level clusters the flat ring must reproduce the
        // calibrated NetworkModel allreduce (same arithmetic; tolerance
        // covers fp association only) — existing pricing and its Table-I
        // calibration are unchanged where there is no topology choice to
        // make.
        let net = NetworkModel::default();
        for c in [
            ClusterSpec::new(4, 1),
            ClusterSpec::new(9, 1),
            ClusterSpec::new(1, 8),
            ClusterSpec::new(1, 1),
        ] {
            for bytes in [0usize, 1 << 10, 100 << 20] {
                let ring = RING.allreduce_s(&net, c, bytes);
                let model = net.allreduce_s(bytes, c);
                assert!(
                    (ring - model).abs() <= 1e-12 * model.abs().max(1e-12),
                    "{c:?} bytes={bytes}: {ring} vs {model}"
                );
            }
        }
        // The allgather drifts from the legacy model by a bounded,
        // documented amount only: the schedule charges the per-hop intra
        // latency the legacy single-node formula omitted (the legacy
        // *allreduce* always charged it — the old model was internally
        // inconsistent). One-rank-per-node shapes stay exact.
        for c in [ClusterSpec::new(4, 1), ClusterSpec::new(9, 1)] {
            let bytes = 1 << 20;
            let ring = RING.allgather_s(&net, c, bytes);
            let model = net.allgather_s(bytes, c);
            assert!(
                (ring - model).abs() <= 1e-12 * model.abs(),
                "{c:?}: {ring} vs {model}"
            );
        }
        let c = ClusterSpec::new(1, 8);
        let bytes = 1 << 20;
        let drift = RING.allgather_s(&net, c, bytes) - net.allgather_s(bytes, c);
        let bound = (c.world() - 1) as f64 * NetworkModel::INTRA_LATENCY_S;
        assert!(
            drift >= 0.0 && drift <= bound + 1e-12,
            "single-node allgather drift {drift} must be the per-hop intra \
             latency only (<= {bound})"
        );
    }

    /// The closed-form `allgather_s` overrides of ring and tree exist
    /// only to avoid rebuilding O(P²)-hop schedules on the pricing hot
    /// path — they must agree with the schedule-derived default (the
    /// single source of truth) on every shape, to fp association. (Hier
    /// is deliberately absent: its analytic cost is the calibrated
    /// per-node-NIC model, not the per-rank-link schedule pricing.)
    #[test]
    fn closed_form_costs_match_schedule_pricing() {
        let net = NetworkModel::default();
        for c in shapes() {
            for topo in [&RING as &dyn Collective, &TREE as &dyn Collective] {
                let want = topo
                    .allgather_schedule(c)
                    .cost_uniform(&net, 4096)
                    .max(net.latency_s);
                let got = topo.allgather_s(&net, c, 4096);
                assert!(
                    (got - want).abs() <= 1e-9 * want.max(1e-12),
                    "{} {c:?}: closed form {got} vs schedule {want}",
                    topo.name()
                );
            }
        }
    }

    /// Satellite of the tree fix: on a cluster whose gpus_per_node is not
    /// a power of two, sub-stride tree hops cross node boundaries and
    /// must be priced at the NIC rate — the allreduce can never price
    /// below a single inter-node traversal there.
    #[test]
    fn tree_allreduce_sees_cross_node_substride_hops() {
        let net = NetworkModel::default();
        let c = ClusterSpec::new(2, 3);
        let bytes = 8 << 20;
        let floor = bytes as f64 / net.effective_bps();
        assert!(
            TREE.allreduce_s(&net, c, bytes) >= 2.0 * floor,
            "reduce+broadcast must each cross the NIC at least once"
        );
    }

    #[test]
    fn auto_resolves_by_cluster_shape() {
        assert_eq!(TopologyKind::Auto.resolve(ClusterSpec::new(4, 8)).name(), "hier");
        assert_eq!(TopologyKind::Auto.resolve(ClusterSpec::new(4, 1)).name(), "ring");
        assert_eq!(TopologyKind::Auto.resolve(ClusterSpec::new(1, 8)).name(), "ring");
        assert_eq!(TopologyKind::Tree.resolve(ClusterSpec::new(1, 1)).name(), "tree");
    }

    #[test]
    fn kind_specs_round_trip() {
        for k in [TopologyKind::Ring, TopologyKind::Hier, TopologyKind::Tree, TopologyKind::Auto] {
            assert_eq!(TopologyKind::parse(k.spec()), Some(k));
        }
        assert_eq!(TopologyKind::parse("HIER"), Some(TopologyKind::Hier));
        assert_eq!(TopologyKind::parse("binomial"), Some(TopologyKind::Tree));
        assert!(TopologyKind::parse("mesh").is_none());
    }

    #[test]
    fn cost_uniform_prices_rounds_not_hops() {
        // Two hops by the same src in one round serialize; hops by
        // different srcs do not.
        let net = NetworkModel::default();
        let c = ClusterSpec::new(2, 2);
        let s = HIER.allgather_schedule(c);
        let cost = s.cost_uniform(&net, 1 << 20);
        assert!(cost > 0.0 && cost.is_finite());
        // empty schedule (p = 1) prices to zero, floored by the trait
        let s1 = HIER.allgather_schedule(ClusterSpec::new(1, 1));
        assert_eq!(s1.cost_uniform(&net, 1 << 20), 0.0);
        assert_eq!(
            HIER.allgather_s(&net, ClusterSpec::new(1, 1), 1 << 20),
            net.latency_s,
            "empty schedule floors at the collective-step latency"
        );
    }
}
