//! DDPovlp baseline: no compression — dense f32 AllReduce per bucket.
//!
//! The per-rank half is trivial: ship the raw gradient as a dense frame;
//! the shared [`MeanCombiner`](super::rank) folds all ranks' frames into
//! the mean. Replicated execution is `LockstepDriver` over this pair, like
//! every other scheme.

use super::rank::{encode_dense_into, RankCompressor, Scratch};

/// Ships this rank's gradient uncompressed.
pub(crate) struct DenseCompressor;

impl RankCompressor for DenseCompressor {
    fn name(&self) -> &'static str {
        "DDPovlp"
    }

    fn compress_into(
        &mut self,
        _tensor: usize,
        _step: u64,
        grad: &[f32],
        _scratch: &mut Scratch,
        frame: &mut Vec<u8>,
    ) {
        encode_dense_into(grad, frame);
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::super::rank::Payload;
    use super::*;

    #[test]
    fn dense_payload_preserves_bits() {
        let mut c = DenseCompressor;
        let g = vec![1.0f32, -0.0, f32::MIN_POSITIVE];
        let p = c.compress(0, 0, &g);
        let Payload::Dense(v) = p else { panic!("wrong variant") };
        assert!(v.iter().zip(g.iter()).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
