//! DDPovlp baseline: no compression — dense f32 AllReduce per bucket.


use super::{mean_of, CommRecord, Scheme};

pub struct Baseline {
    _private: (),
}

impl Baseline {
    pub fn new() -> Baseline {
        Baseline { _private: () }
    }
}

impl Default for Baseline {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheme for Baseline {
    fn name(&self) -> &'static str {
        "DDPovlp"
    }

    fn round(&mut self, _bucket: usize, _step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord) {
        let update = mean_of(grads);
        // The mean IS the collective (no local compression stage), so the
        // scheme's T_compress is exactly zero by construction.
        let rec = CommRecord::dense(grads[0].len() * 4, 0.0);
        (update, rec)
    }

    fn reset(&mut self) {}
}
