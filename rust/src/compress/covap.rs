//! COVAP's per-rank compressor: coarse filter + error feedback with the
//! compensation scheduler (§III.A + §III.D).
//!
//! The filter decision is O(1) per tensor and value-independent, so
//! T_compress is only the EF accumulate/store pass — and on dropped tensors
//! nothing at all goes on the wire (a zero-length frame). Sharding (§III.C)
//! happens upstream in the coordinator: by the time a "tensor" reaches this
//! compressor it is a shard-granular tensor. The combine half is the shared
//! [`MeanCombiner`](super::rank): kept tensors are dense frames averaged in
//! rank order.

use std::collections::HashMap;

use super::rank::{dense_frame_len, frame_header, RankCompressor, Scratch, TAG_DENSE};
use super::SchemeKind;
use crate::covap::{CoarseFilter, EfScheduler};

/// One rank's COVAP compute half: filter decision + this rank's residuals.
pub(crate) struct CovapCompressor {
    filter: CoarseFilter,
    scheduler: EfScheduler,
    /// This rank's residual per communication tensor (Algorithm 1's e_w).
    residuals: HashMap<usize, Vec<f32>>,
}

impl CovapCompressor {
    pub(crate) fn new(interval: usize, scheduler: EfScheduler) -> CovapCompressor {
        CovapCompressor {
            filter: CoarseFilter::new(interval),
            scheduler,
            residuals: HashMap::new(),
        }
    }
}

#[cfg(test)]
impl CovapCompressor {
    /// L2 mass currently parked in this rank's residuals (test diagnostics).
    fn residual_norm(&self) -> f64 {
        self.residuals
            .values()
            .flat_map(|r| r.iter())
            .map(|x| (*x as f64) * (*x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

impl RankCompressor for CovapCompressor {
    fn name(&self) -> &'static str {
        "COVAP"
    }

    fn compress_into(
        &mut self,
        tensor: usize,
        step: u64,
        grad: &[f32],
        _scratch: &mut Scratch,
        frame: &mut Vec<u8>,
    ) {
        let n = grad.len();
        let keep = self.filter.keep(tensor, step);
        let coeff = self.scheduler.coeff(step);
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        if keep {
            // transmit acc = g + c*r; residual resets; the EF accumulate
            // fuses with the wire encode into one allocation-free pass
            frame_header(frame, TAG_DENSE, n, dense_frame_len(n));
            for (&gi, ri) in grad.iter().zip(res.iter_mut()) {
                let a = gi + coeff * *ri;
                *ri = 0.0;
                frame.extend_from_slice(&a.to_le_bytes());
            }
        } else {
            // drop: fold the gradient into the residual in place; the empty
            // frame tells every combiner "this tensor moved zero bytes".
            frame.clear();
            for (ri, &gi) in res.iter_mut().zip(grad.iter()) {
                *ri = gi + coeff * *ri;
            }
        }
    }

    /// Interval re-shard with **residual preservation** (§III.D): residuals
    /// are keyed by communication-tensor slot, but the accumulated error
    /// lives at flat parameter offsets — so scatter every old slot's
    /// residual into flat space and slice the new layout back out. Pure
    /// copies: the error mass survives the re-shard bitwise, instead of
    /// being dropped the way a rebuild would (the old adaptive path's
    /// leak). Only COVAP-family kinds are migratable; anything else tells
    /// the caller to rebuild.
    fn reconfigure(
        &mut self,
        kind: &SchemeKind,
        old: &[(usize, usize)],
        new: &[(usize, usize)],
    ) -> bool {
        let (interval, scheduler) = match kind {
            SchemeKind::Covap { interval, ef } => (*interval, *ef),
            SchemeKind::CovapAuto { ef } => (1, *ef),
            _ => return false,
        };
        let span = old.iter().chain(new.iter()).map(|&(o, n)| o + n).max().unwrap_or(0);
        let mut flat = vec![0.0f32; span];
        for (slot, &(off, numel)) in old.iter().enumerate() {
            if let Some(r) = self.residuals.get(&slot) {
                debug_assert_eq!(r.len(), numel, "slot {slot} residual length");
                let n = r.len().min(numel);
                flat[off..off + n].copy_from_slice(&r[..n]);
            }
        }
        self.residuals.clear();
        for (slot, &(off, numel)) in new.iter().enumerate() {
            self.residuals.insert(slot, flat[off..off + numel].to_vec());
        }
        self.filter = CoarseFilter::new(interval);
        self.scheduler = scheduler;
        true
    }

    /// Flatten the residual map over `layout` — the same scatter
    /// [`CovapCompressor::reconfigure`] performs, exposed so the membership
    /// controller can hand a departing rank's error mass to a survivor.
    fn export_residuals(&self, layout: &[(usize, usize)]) -> Option<Vec<f32>> {
        let span = layout.iter().map(|&(o, n)| o + n).max().unwrap_or(0);
        let mut flat = vec![0.0f32; span];
        for (slot, &(off, numel)) in layout.iter().enumerate() {
            if let Some(r) = self.residuals.get(&slot) {
                let n = r.len().min(numel);
                flat[off..off + n].copy_from_slice(&r[..n]);
            }
        }
        Some(flat)
    }

    /// Adopt a flat residual vector as this rank's EF state, sliced by
    /// `layout`. Slots reaching past `flat` (a shorter donor) fill with
    /// zeros — missing error mass is simply absent, never invented.
    fn import_residuals(&mut self, flat: &[f32], layout: &[(usize, usize)]) -> bool {
        self.residuals.clear();
        for (slot, &(off, numel)) in layout.iter().enumerate() {
            let mut r = vec![0.0f32; numel];
            if off < flat.len() {
                let n = numel.min(flat.len() - off);
                r[..n].copy_from_slice(&flat[off..off + n]);
            }
            self.residuals.insert(slot, r);
        }
        true
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::rank::{MeanCombiner, Payload, RankCombiner};
    use super::super::SchemeKind;
    use super::*;

    fn run(interval: usize, steps: u64, grads: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let kind = SchemeKind::Covap { interval, ef: EfScheduler::constant(1.0) };
        let mut s = kind.build(grads.len(), 0);
        (0..steps).map(|t| s.round(0, t, &refs).0).collect()
    }

    #[test]
    fn kept_step_transmits_mean() {
        let g0 = vec![2.0f32, 4.0];
        let g1 = vec![4.0f32, 8.0];
        let updates = run(1, 1, &[g0, g1]);
        assert_eq!(updates[0], vec![3.0, 6.0]);
    }

    #[test]
    fn dropped_steps_accumulate_then_flush() {
        // interval 4, tensor 0: kept at steps 0, 4. With constant gradient g
        // and full compensation: step 0 transmits g (residual 0); steps 1-3
        // accumulate g each; step 4 transmits g + residual(3g) = 4g.
        let g = vec![1.0f32; 8];
        let updates = run(4, 5, std::slice::from_ref(&g));
        assert_eq!(updates[0], vec![1.0; 8]);
        // dropped rounds signal "all zeros" with an empty update
        assert!(updates[1..4].iter().all(|u| u.is_empty()));
        assert_eq!(updates[4], vec![4.0; 8]);
    }

    #[test]
    fn no_mass_lost_over_interval() {
        // Sum of updates over a full interval == sum of gradients fed
        // (full-compensation EF conservation). Driven as two independent
        // rank compressors + the shared combiner — the canonical path.
        let g0 = vec![0.5f32, -1.5, 2.0];
        let g1 = vec![1.5f32, 0.5, -1.0];
        let grads: [&[f32]; 2] = [&g0, &g1];
        let mut cs: Vec<CovapCompressor> =
            (0..2).map(|_| CovapCompressor::new(3, EfScheduler::constant(1.0))).collect();
        let mut cb = MeanCombiner;
        // tensor 0 with I=3 is kept at steps 0 and 3; the window [0, 3]
        // includes the flush of the two dropped rounds.
        let mut total = vec![0.0f64; 3];
        for step in 0..4 {
            let payloads: Vec<Payload> = cs
                .iter_mut()
                .zip(grads.iter())
                .map(|(c, g)| c.compress(0, step, g))
                .collect();
            let rr = cb.combine(0, step, 3, &payloads, 0.0);
            for (t, x) in total.iter_mut().zip(rr.update.iter()) {
                *t += *x as f64;
            }
            // empty = dropped round, contributes zero
        }
        let expected: Vec<f64> =
            g0.iter().zip(g1.iter()).map(|(a, b)| 4.0 * ((a + b) / 2.0) as f64).collect();
        for (t, e) in total.iter().zip(expected.iter()) {
            assert!((t - e).abs() < 1e-5, "{total:?} vs {expected:?}");
        }
        let residual: f64 = cs.iter().map(|c| c.residual_norm()).sum();
        assert!(residual < 1e-6, "all residual flushed after full cycle");
    }

    #[test]
    fn wire_bytes_zero_on_drop() {
        let g = vec![1.0f32; 128];
        let refs: Vec<&[f32]> = vec![&g];
        let kind = SchemeKind::Covap { interval: 4, ef: EfScheduler::default() };
        let mut s = kind.build(1, 0);
        let (_, rec_keep) = s.round(0, 0, &refs);
        let (_, rec_drop) = s.round(0, 1, &refs);
        assert_eq!(rec_keep.wire_bytes, dense_frame_len(128));
        assert_eq!(rec_drop.wire_bytes, 0);
        assert!(!rec_keep.data_dependency);
    }

    #[test]
    fn scheduler_dampens_early_residual() {
        // With init 0.0 (never compensate), dropped gradients are simply
        // lost: flush at step I transmits only the current gradient.
        let g = vec![1.0f32; 4];
        let refs: Vec<&[f32]> = vec![&g];
        let kind = SchemeKind::Covap {
            interval: 2,
            ef: EfScheduler { init_value: 0.0, ascend_steps: u64::MAX, ascend_range: 0.0 },
        };
        let mut s = kind.build(1, 0);
        let (u0, _) = s.round(0, 0, &refs); // kept
        let (_u1, _) = s.round(0, 1, &refs); // dropped
        let (u2, _) = s.round(0, 2, &refs); // kept: coeff 0 -> residual ignored
        assert_eq!(u0, vec![1.0; 4]);
        assert_eq!(u2, vec![1.0; 4]);
    }

    /// Flatten a compressor's residual map over a slot layout.
    fn flat_residuals(c: &CovapCompressor, layout: &[(usize, usize)]) -> Vec<u32> {
        let span = layout.iter().map(|&(o, n)| o + n).max().unwrap_or(0);
        let mut flat = vec![0.0f32; span];
        for (slot, &(off, numel)) in layout.iter().enumerate() {
            if let Some(r) = c.residuals.get(&slot) {
                let n = numel.min(r.len());
                flat[off..off + n].copy_from_slice(&r[..n]);
            }
        }
        flat.iter().map(|x| x.to_bits()).collect()
    }

    /// The re-shard acceptance criterion: remapping to a different shard
    /// layout preserves the EF residual mass **bitwise** — same flat
    /// values, just resliced — and a second remap back is the identity.
    #[test]
    fn reconfigure_remaps_residuals_bitwise() {
        let ef = EfScheduler::constant(1.0);
        let mut c = CovapCompressor::new(3, ef);
        let old = [(0usize, 8usize), (8, 4)];
        let g0: Vec<f32> = (0..8).map(|i| 0.25 * i as f32 - 0.8).collect();
        let g1: Vec<f32> = (0..4).map(|i| 1.5 - 0.4 * i as f32).collect();
        // step 1: both tensors dropped ((t + 1) % 3 != 0) -> residuals park
        for (t, g) in [(0usize, &g0), (1, &g1)] {
            let p = c.compress(t, 1, g);
            assert!(matches!(p, Payload::Empty), "tensor {t} must be dropped");
        }
        let before = flat_residuals(&c, &old);
        assert!(before.iter().any(|&b| b != 0), "residuals must be nonzero");

        // re-shard 2 tensors -> 3 (different slicing of the same 12 params)
        let new = [(0usize, 3usize), (3, 5), (8, 4)];
        let kind = SchemeKind::Covap { interval: 4, ef };
        assert!(c.reconfigure(&kind, &old, &new));
        assert_eq!(flat_residuals(&c, &new), before, "remap must preserve bits");
        assert_eq!(c.filter.interval(), 4);

        // and back: still the identical flat residual vector
        assert!(c.reconfigure(&SchemeKind::Covap { interval: 3, ef }, &new, &old));
        assert_eq!(flat_residuals(&c, &old), before);
    }

    /// Elastic handoff primitive: export flattens exactly like the test
    /// oracle, import slices it back, and the round trip is the bitwise
    /// identity — including across a *different* layout (re-world + re-shard
    /// in one move).
    #[test]
    fn export_import_roundtrips_bitwise() {
        let ef = EfScheduler::constant(1.0);
        let mut c = CovapCompressor::new(3, ef);
        let old = [(0usize, 8usize), (8, 4)];
        let g0: Vec<f32> = (0..8).map(|i| 0.5 * i as f32 - 1.7).collect();
        let g1: Vec<f32> = (0..4).map(|i| 0.3 * i as f32 + 0.2).collect();
        for (t, g) in [(0usize, &g0), (1, &g1)] {
            assert!(matches!(c.compress(t, 1, g), Payload::Empty));
        }
        let flat = c.export_residuals(&old).expect("covap state is portable");
        let bits: Vec<u32> = flat.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, flat_residuals(&c, &old), "export matches the oracle");

        let new = [(0usize, 5usize), (5, 7)];
        let mut fresh = CovapCompressor::new(3, ef);
        assert!(fresh.import_residuals(&flat, &new));
        assert_eq!(flat_residuals(&fresh, &new), bits, "import preserves bits");
        // re-export under the new layout: still the identical flat vector
        let back = fresh.export_residuals(&new).unwrap();
        assert_eq!(back.iter().map(|x| x.to_bits()).collect::<Vec<_>>(), bits);
    }

    /// A remapped compressor behaves exactly like one that accumulated
    /// under the new layout all along would on the *kept* step: the flush
    /// transmits g + c·r with the remapped residuals.
    #[test]
    fn post_reshard_flush_uses_remapped_residuals() {
        let ef = EfScheduler::constant(1.0);
        let mut c = CovapCompressor::new(2, ef);
        let g = vec![1.0f32; 6];
        // one tensor [0, 6); step 1 drops it ((0 + 1) % 2 == 1)
        assert!(matches!(c.compress(0, 1, &g), Payload::Empty));
        // re-shard into two tensors of 3; interval 2 keeps tensor 0 at
        // step 2 and tensor 1 at step 3
        let old = [(0usize, 6usize)];
        let new = [(0usize, 3usize), (3, 3)];
        assert!(c.reconfigure(&SchemeKind::Covap { interval: 2, ef }, &old, &new));
        let p = c.compress(0, 2, &g[0..3]);
        let Payload::Dense(v) = p else { panic!("kept tensor must be dense") };
        // flush = g + 1.0 * residual(=1.0 each) = 2.0
        assert_eq!(v, vec![2.0f32; 3]);
    }

    /// Cross-scheme migrations are refused (caller rebuilds instead), and
    /// stateless compressors refuse COVAP state (default impl).
    #[test]
    fn reconfigure_rejects_foreign_schemes() {
        let ef = EfScheduler::default();
        let mut c = CovapCompressor::new(2, ef);
        assert!(!c.reconfigure(&SchemeKind::TopK { ratio: 0.01 }, &[], &[]));
        let (mut dense, _) = super::super::rank::build_rank_pair(&SchemeKind::Baseline, 1, 0);
        assert!(!dense.reconfigure(&SchemeKind::Covap { interval: 2, ef }, &[], &[]));
        // covap -> covap@auto migrates to interval 1 (dense)
        assert!(c.reconfigure(&SchemeKind::CovapAuto { ef }, &[], &[]));
        assert_eq!(c.filter.interval(), 1);
    }

    #[test]
    fn different_buckets_rotate() {
        let g = vec![1.0f32; 4];
        let refs: Vec<&[f32]> = vec![&g];
        let kind = SchemeKind::Covap { interval: 2, ef: EfScheduler::constant(1.0) };
        let mut s = kind.build(1, 0);
        let (_, r0) = s.round(0, 0, &refs); // (0+0)%2==0 keep
        let (_, r1) = s.round(1, 0, &refs); // (1+0)%2==1 drop
        assert!(r0.wire_bytes > 0);
        assert_eq!(r1.wire_bytes, 0);
    }
}
