//! COVAP as a [`Scheme`]: coarse filter + error feedback with the
//! compensation scheduler (§III.A + §III.D).
//!
//! The filter decision is O(1) per tensor and value-independent, so
//! T_compress is only the EF accumulate/store pass — and on dropped tensors
//! nothing at all goes on the wire. Sharding (§III.C) happens upstream in
//! the coordinator: by the time a "bucket" reaches this scheme it is a
//! shard-granular tensor.

use std::collections::HashMap;
use std::time::Instant;

use super::{CommRecord, Collective, Scheme};
use crate::covap::{CoarseFilter, EfScheduler};

pub struct CovapScheme {
    filter: CoarseFilter,
    scheduler: EfScheduler,
    workers: usize,
    /// Per-bucket, per-worker residuals, updated in place (§Perf: the
    /// original EfState path materialized `acc` vectors and fresh zero
    /// residuals every round — three allocations + three passes per bucket;
    /// this fused version is one pass, zero steady-state allocations).
    residuals: HashMap<usize, Vec<Vec<f32>>>,
}

impl CovapScheme {
    pub fn new(interval: usize, scheduler: EfScheduler, workers: usize) -> CovapScheme {
        CovapScheme {
            filter: CoarseFilter::new(interval),
            scheduler,
            workers,
            residuals: HashMap::new(),
        }
    }

    pub fn interval(&self) -> usize {
        self.filter.interval()
    }

    /// Residual diagnostics for tests/metrics.
    pub fn residual_norm(&self) -> f64 {
        self.residuals
            .values()
            .flat_map(|ws| ws.iter())
            .flat_map(|r| r.iter())
            .map(|x| (*x as f64) * (*x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

impl Scheme for CovapScheme {
    fn name(&self) -> &'static str {
        "COVAP"
    }

    fn round(&mut self, bucket: usize, step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord) {
        assert_eq!(grads.len(), self.workers);
        let n = grads[0].len();
        let keep = self.filter.keep(bucket, step);
        let coeff = self.scheduler.coeff(step);
        let t0 = Instant::now();
        let res = self
            .residuals
            .entry(bucket)
            .or_insert_with(|| vec![vec![0.0; n]; grads.len()]);

        let update = if keep {
            // transmit: update = mean_w(g_w + c*r_w); residuals reset.
            let mut update = vec![0.0f32; n];
            for (g, r) in grads.iter().zip(res.iter_mut()) {
                for ((u, &gi), ri) in update.iter_mut().zip(g.iter()).zip(r.iter_mut()) {
                    *u += gi + coeff * *ri;
                    *ri = 0.0;
                }
            }
            let inv = 1.0 / grads.len() as f32;
            for u in &mut update {
                *u *= inv;
            }
            update
        } else {
            // drop: fold the gradient into the residual in place; an empty
            // update vector means "all zeros" to the coordinator (nothing
            // was transmitted).
            for (g, r) in grads.iter().zip(res.iter_mut()) {
                for (ri, &gi) in r.iter_mut().zip(g.iter()) {
                    *ri = gi + coeff * *ri;
                }
            }
            Vec::new()
        };
        let compress_s = t0.elapsed().as_secs_f64();
        let rec = CommRecord {
            wire_bytes: if keep { n * 4 } else { 0 },
            collective: Collective::AllReduce,
            rounds: 1,
            sync_rounds: 0,
            compress_s,
            data_dependency: false,
        };
        (update, rec)
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(interval: usize, steps: u64, grads: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut s = CovapScheme::new(interval, EfScheduler::constant(1.0), grads.len());
        (0..steps).map(|t| s.round(0, t, &refs).0).collect()
    }

    #[test]
    fn kept_step_transmits_mean() {
        let g0 = vec![2.0f32, 4.0];
        let g1 = vec![4.0f32, 8.0];
        let updates = run(1, 1, &[g0, g1]);
        assert_eq!(updates[0], vec![3.0, 6.0]);
    }

    #[test]
    fn dropped_steps_accumulate_then_flush() {
        // interval 4, bucket 0: kept at steps 0, 4. With constant gradient g
        // and full compensation, step 4 transmits g + 3g (three dropped
        // rounds of residual) + ... wait: step 0 transmits g (residual 0);
        // steps 1-3 accumulate g each; step 4 transmits g + residual(3g) = 4g.
        let g = vec![1.0f32; 8];
        let updates = run(4, 5, std::slice::from_ref(&g));
        assert_eq!(updates[0], vec![1.0; 8]);
        // dropped rounds signal "all zeros" with an empty update
        assert!(updates[1..4].iter().all(|u| u.is_empty()));
        assert_eq!(updates[4], vec![4.0; 8]);
    }

    #[test]
    fn no_mass_lost_over_interval() {
        // Sum of updates over a full interval == sum of gradients fed
        // (full-compensation EF conservation).
        let mut s = CovapScheme::new(3, EfScheduler::constant(1.0), 2);
        let g0 = vec![0.5f32, -1.5, 2.0];
        let g1 = vec![1.5f32, 0.5, -1.0];
        let refs: Vec<&[f32]> = vec![&g0, &g1];
        // bucket 0 with I=3 is kept at steps 0 and 3; the window [0, 3]
        // includes the flush of the two dropped rounds.
        let mut total = vec![0.0f64; 3];
        for step in 0..4 {
            let (u, _) = s.round(0, step, &refs);
            for (t, x) in total.iter_mut().zip(u.iter()) {
                *t += *x as f64;
            }
            // empty = dropped round, contributes zero
        }
        let expected: Vec<f64> =
            g0.iter().zip(g1.iter()).map(|(a, b)| 4.0 * ((a + b) / 2.0) as f64).collect();
        for (t, e) in total.iter().zip(expected.iter()) {
            assert!((t - e).abs() < 1e-5, "{total:?} vs {expected:?}");
        }
        assert!(s.residual_norm() < 1e-6, "all residual flushed after full cycle");
    }

    #[test]
    fn wire_bytes_zero_on_drop() {
        let g = vec![1.0f32; 128];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = CovapScheme::new(4, EfScheduler::default(), 1);
        let (_, rec_keep) = s.round(0, 0, &refs);
        let (_, rec_drop) = s.round(0, 1, &refs);
        assert_eq!(rec_keep.wire_bytes, 512);
        assert_eq!(rec_drop.wire_bytes, 0);
        assert!(!rec_keep.data_dependency);
    }

    #[test]
    fn scheduler_dampens_early_residual() {
        // With init 0.0 (never compensate), dropped gradients are simply
        // lost: flush at step I transmits only the current gradient.
        let g = vec![1.0f32; 4];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = CovapScheme::new(
            2,
            EfScheduler { init_value: 0.0, ascend_steps: u64::MAX, ascend_range: 0.0 },
            1,
        );
        let (u0, _) = s.round(0, 0, &refs); // kept
        let (_u1, _) = s.round(0, 1, &refs); // dropped
        let (u2, _) = s.round(0, 2, &refs); // kept: coeff 0 -> residual ignored
        assert_eq!(u0, vec![1.0; 4]);
        assert_eq!(u2, vec![1.0; 4]);
    }

    #[test]
    fn different_buckets_rotate() {
        let g = vec![1.0f32; 4];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = CovapScheme::new(2, EfScheduler::constant(1.0), 1);
        let (_, r0) = s.round(0, 0, &refs); // (0+0)%2==0 keep
        let (_, r1) = s.round(1, 0, &refs); // (1+0)%2==1 drop
        assert!(r0.wire_bytes > 0);
        assert_eq!(r1.wire_bytes, 0);
    }
}
