//! Error-feedback residual state (Algorithm 1 of the paper).
//!
//! Per (worker, bucket) residual vectors. Each scheme that is lossy w.r.t.
//! the transmitted gradient stores `acc - transmitted` here and re-injects
//! it (optionally scaled by the COVAP scheduler coefficient) next round.

use std::collections::HashMap;

/// Residual store: (bucket -> per-worker residual vectors).
#[derive(Debug, Default)]
pub struct EfState {
    residuals: HashMap<usize, Vec<Vec<f32>>>,
    workers: usize,
}

impl EfState {
    pub fn new(workers: usize) -> EfState {
        EfState { residuals: HashMap::new(), workers }
    }

    /// acc_w = g_w + coeff * r_w for every worker; returns the accumulated
    /// vectors (residuals are *consumed* — caller must `store` what was not
    /// transmitted).
    pub fn accumulate(&mut self, bucket: usize, coeff: f32, grads: &[&[f32]]) -> Vec<Vec<f32>> {
        assert_eq!(grads.len(), self.workers);
        let n = grads[0].len();
        let res = self
            .residuals
            .entry(bucket)
            .or_insert_with(|| vec![vec![0.0; n]; grads.len()]);
        grads
            .iter()
            .zip(res.iter())
            .map(|(g, r)| {
                debug_assert_eq!(g.len(), r.len());
                g.iter().zip(r.iter()).map(|(gi, ri)| gi + coeff * ri).collect()
            })
            .collect()
    }

    /// Store the untransmitted part for every worker.
    pub fn store(&mut self, bucket: usize, new_residuals: Vec<Vec<f32>>) {
        self.residuals.insert(bucket, new_residuals);
    }

    /// L2 mass currently parked in residuals (diagnostics / tests).
    pub fn residual_norm(&self) -> f64 {
        self.residuals
            .values()
            .flat_map(|ws| ws.iter())
            .flat_map(|r| r.iter())
            .map(|x| (*x as f64) * (*x as f64))
            .sum::<f64>()
            .sqrt()
    }

    pub fn clear(&mut self) {
        self.residuals.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_adds_scaled_residual() {
        let mut ef = EfState::new(2);
        let g0 = vec![1.0f32, 2.0];
        let g1 = vec![3.0f32, 4.0];
        // first round: residuals are zero
        let acc = ef.accumulate(0, 1.0, &[&g0, &g1]);
        assert_eq!(acc[0], g0);
        ef.store(0, vec![vec![0.5, 0.5], vec![1.0, 1.0]]);
        let acc = ef.accumulate(0, 0.5, &[&g0, &g1]);
        assert_eq!(acc[0], vec![1.25, 2.25]);
        assert_eq!(acc[1], vec![3.5, 4.5]);
    }

    #[test]
    fn buckets_are_independent() {
        let mut ef = EfState::new(1);
        let g = vec![1.0f32];
        ef.accumulate(0, 1.0, &[&g]);
        ef.store(0, vec![vec![9.0]]);
        let acc1 = ef.accumulate(1, 1.0, &[&g]);
        assert_eq!(acc1[0], vec![1.0]); // bucket 1 has no residual
        let acc0 = ef.accumulate(0, 1.0, &[&g]);
        assert_eq!(acc0[0], vec![10.0]);
    }

    #[test]
    fn clear_resets_mass() {
        let mut ef = EfState::new(1);
        let g = vec![3.0f32, 4.0];
        ef.accumulate(0, 1.0, &[&g]);
        ef.store(0, vec![vec![3.0, 4.0]]);
        assert!((ef.residual_norm() - 5.0).abs() < 1e-9);
        ef.clear();
        assert_eq!(ef.residual_norm(), 0.0);
    }
}
