//! FP16 quantization baseline: halve the wire volume by casting gradients
//! to IEEE half precision. AllReduce-compatible (halves are summable);
//! no error feedback in the paper's configuration.
//!
//! The per-rank half quantizes into a `Payload::Half` frame; the shared
//! [`MeanCombiner`](super::rank) dequantizes and averages in rank order.
//!
//! The f32<->f16 conversion is implemented from scratch (no `half` crate on
//! the offline testbed) with round-to-nearest-even, matching hardware
//! semantics — the same rounding the Pallas quantize kernel performs.

use super::rank::{frame_header, half_frame_len, RankCompressor, Scratch, TAG_HALF};

/// f32 -> f16 bits, round-to-nearest-even, with overflow->inf and
/// subnormal handling.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // inf / nan
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if e >= -14 {
        // normal f16
        let mut m = mant >> 13; // 10 bits
        let rest = mant & 0x1fff;
        // round to nearest even
        if rest > 0x1000 || (rest == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            m = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7c00;
            }
        }
        return sign | ((he as u16) << 10) | m as u16;
    }
    if e >= -25 {
        // subnormal f16
        let full = mant | 0x0080_0000; // implicit 1
        let shift = (-14 - e) as u32 + 13;
        let m = full >> shift;
        let rest = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut m = m;
        if rest > half || (rest == half && (m & 1) == 1) {
            m += 1;
        }
        return sign | m as u16; // may carry into exponent — that's correct
    }
    sign // underflow -> ±0
}

/// f16 bits -> f32.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    match (exp, mant) {
        (0, m) => {
            // zero / subnormal: value = ±m * 2^-24, exact in f32.
            let v = m as f32 * (1.0 / 16_777_216.0);
            if sign != 0 {
                -v
            } else {
                v
            }
        }
        (0x1f, 0) => f32::from_bits(sign | 0x7f80_0000),
        (0x1f, m) => f32::from_bits(sign | 0x7f80_0000 | (m << 13)),
        (e, m) => f32::from_bits(sign | ((e + 127 - 15) << 23) | (m << 13)),
    }
}

/// Quantizes this rank's gradient to a half-precision frame — the
/// quantize and the wire encode are one fused, allocation-free pass.
pub(crate) struct HalfCompressor;

impl RankCompressor for HalfCompressor {
    fn name(&self) -> &'static str {
        "FP16"
    }

    fn compress_into(
        &mut self,
        _tensor: usize,
        _step: u64,
        grad: &[f32],
        _scratch: &mut Scratch,
        frame: &mut Vec<u8>,
    ) {
        frame_header(frame, TAG_HALF, grad.len(), half_frame_len(grad.len()));
        for &x in grad {
            frame.extend_from_slice(&f32_to_f16(x).to_le_bytes());
        }
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::super::rank::half_frame_len;
    use super::super::SchemeKind;
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn exact_small_integers() {
        for i in -256i32..=256 {
            let x = i as f32;
            assert_eq!(f16_to_f32(f32_to_f16(x)), x, "{x}");
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16(1.0), 0x3c00);
        assert_eq!(f32_to_f16(-2.0), 0xc000);
        assert_eq!(f32_to_f16(65504.0), 0x7bff); // f16 max
        assert_eq!(f32_to_f16(1e30), 0x7c00); // -> inf
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f16_to_f32(0x3555), 0.333251953125); // ~1/3
    }

    #[test]
    fn subnormals_roundtrip() {
        let tiny = 5.96e-8_f32; // smallest f16 subnormal ~5.96e-8
        let h = f32_to_f16(tiny);
        assert!(h & 0x7fff != 0, "should not flush to zero");
        let back = f16_to_f32(h);
        assert!((back - tiny).abs() / tiny < 0.5);
    }

    #[test]
    fn nan_and_inf() {
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn roundtrip_error_within_half_ulp() {
        prop::check("f16-roundtrip", 21, 300, |rng: &mut Rng| {
            let x = (rng.normal() as f32) * 10.0;
            let y = f16_to_f32(f32_to_f16(x));
            // f16 has 11 significand bits: relative error <= 2^-11
            assert!((x - y).abs() <= x.abs() * (1.0 / 2048.0) + 1e-7, "{x} -> {y}");
        });
    }

    #[test]
    fn scheme_halves_wire() {
        let g = vec![0.5f32; 64];
        let refs: Vec<&[f32]> = vec![&g, &g];
        let mut s = SchemeKind::Fp16.build(2, 0);
        let (u, rec) = s.round(0, 0, &refs);
        // the measured half frame: tag + varint + 2 bytes per element
        assert_eq!(rec.wire_bytes, half_frame_len(64));
        assert!(rec.wire_bytes < 64 * 4 / 2 + 8, "must be ~half the dense volume");
        assert_eq!(u, g); // 0.5 is f16-exact
    }
}
