//! Gradient compression schemes: COVAP plus the paper's seven comparison
//! baselines (Table II / VII).
//!
//! A [`Scheme`] models one *communication bucket round* exactly as the
//! cluster would execute it: per-worker local compression (with per-worker
//! error-feedback state), the collective exchange, and decompression into
//! the averaged dense update. The numeric path is bit-faithful; the *wire*
//! cost is returned as a [`CommRecord`] that the timeline simulator prices
//! with the network model.
//!
//! `compress_s` in the record is the measured wall time of the local
//! compression work (the paper's `T_compress`) — this is what Table II and
//! the Fig. 7–10 breakdowns report.

mod baseline;
mod covap;
mod ef;
mod fp16;
mod oktopk;
mod powersgd;
pub mod rank;
mod randomk;
mod signsgd;
mod topk;

pub use baseline::Baseline;
pub use covap::CovapScheme;
pub use ef::EfState;
pub use fp16::{f16_to_f32, f32_to_f16, Fp16};
pub use oktopk::OkTopk;
pub use powersgd::PowerSgd;
pub use rank::{build_rank_pair, Payload, RankCombiner, RankCompressor, RankRound};
pub use randomk::RandomK;
pub use signsgd::EfSignSgd;
pub use topk::{Dgc, TopK};

use crate::covap::EfScheduler;

/// Which collective the scheme's wire format requires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Collective {
    /// Payloads are summable in-network (dense / shared-index sparse).
    AllReduce,
    /// Payloads must be gathered to every rank (worker-specific indices).
    AllGather,
}

/// Wire + overhead accounting for one bucket round.
#[derive(Debug, Clone, Copy)]
pub struct CommRecord {
    /// Bytes each rank puts on the wire for this bucket (0 = skipped).
    pub wire_bytes: usize,
    pub collective: Collective,
    /// Number of dependent collective rounds (PowerSGD = 2).
    pub rounds: u32,
    /// Extra synchronous rendezvous (threshold exchange etc.).
    pub sync_rounds: u32,
    /// Measured per-worker local compression+decompression wall time, s.
    pub compress_s: f64,
    /// True if the scheme's later computation depends on an earlier
    /// collective's *result* (breaks overlapping; §I "data dependency").
    pub data_dependency: bool,
}

impl CommRecord {
    pub fn dense(bytes: usize, compress_s: f64) -> CommRecord {
        CommRecord {
            wire_bytes: bytes,
            collective: Collective::AllReduce,
            rounds: 1,
            sync_rounds: 0,
            compress_s,
            data_dependency: false,
        }
    }
}

/// One gradient-compression scheme, holding all per-worker state.
///
/// `round` receives the per-worker raw bucket gradients and returns the
/// averaged dense update the optimizer applies, plus the comm record. The
/// scheme owns per-(worker, bucket) error-feedback residuals where the
/// algorithm uses them.
pub trait Scheme: Send {
    fn name(&self) -> &'static str;

    fn round(&mut self, bucket: usize, step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord);

    /// Reset all error-feedback / iteration state (new training run).
    fn reset(&mut self);
}

/// Scheme selector + hyperparameters (mirrors the paper's Table II column).
#[derive(Debug, Clone, PartialEq)]
pub enum SchemeKind {
    /// DDPovlp — no compression.
    Baseline,
    /// COVAP with a fixed interval (adaptive selection happens in the
    /// trainer via the profiler; see covap::interval_from_ccr).
    Covap { interval: usize, ef: EfScheduler },
    TopK { ratio: f64 },
    Dgc { ratio: f64 },
    RandomK { ratio: f64 },
    Fp16,
    EfSignSgd,
    PowerSgd { rank: usize },
    OkTopk { ratio: f64 },
}

impl SchemeKind {
    /// Paper defaults (Table II hyperparameter column).
    pub fn paper_default(name: &str) -> Option<SchemeKind> {
        Some(match name.to_ascii_lowercase().as_str() {
            "baseline" | "ddp" | "ddpovlp" => SchemeKind::Baseline,
            "covap" => SchemeKind::Covap { interval: 4, ef: EfScheduler::default() },
            "topk" | "top-k" => SchemeKind::TopK { ratio: 0.01 },
            "dgc" => SchemeKind::Dgc { ratio: 0.001 },
            "randomk" | "random-k" => SchemeKind::RandomK { ratio: 0.01 },
            "fp16" => SchemeKind::Fp16,
            "efsignsgd" => SchemeKind::EfSignSgd,
            "powersgd" => SchemeKind::PowerSgd { rank: 1 },
            "oktopk" | "ok-topk" => SchemeKind::OkTopk { ratio: 0.01 },
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchemeKind::Baseline => "DDPovlp",
            SchemeKind::Covap { .. } => "COVAP",
            SchemeKind::TopK { .. } => "Top-k",
            SchemeKind::Dgc { .. } => "DGC",
            SchemeKind::RandomK { .. } => "Random-k",
            SchemeKind::Fp16 => "FP16",
            SchemeKind::EfSignSgd => "EFsignSGD",
            SchemeKind::PowerSgd { .. } => "PowerSGD",
            SchemeKind::OkTopk { .. } => "Ok-topk",
        }
    }

    /// Instantiate for `workers` ranks with a deterministic seed.
    pub fn build(&self, workers: usize, seed: u64) -> Box<dyn Scheme> {
        match self.clone() {
            SchemeKind::Baseline => Box::new(Baseline::new()),
            SchemeKind::Covap { interval, ef } => {
                Box::new(CovapScheme::new(interval, ef, workers))
            }
            SchemeKind::TopK { ratio } => Box::new(TopK::new(ratio, workers)),
            SchemeKind::Dgc { ratio } => Box::new(Dgc::new(ratio, workers, seed)),
            SchemeKind::RandomK { ratio } => Box::new(RandomK::new(ratio, workers, seed)),
            SchemeKind::Fp16 => Box::new(Fp16::new()),
            SchemeKind::EfSignSgd => Box::new(EfSignSgd::new(workers)),
            SchemeKind::PowerSgd { rank } => Box::new(PowerSgd::new(rank, workers, seed)),
            SchemeKind::OkTopk { ratio } => Box::new(OkTopk::new(ratio, workers)),
        }
    }

    /// All schemes of the paper's evaluation, with paper hyperparameters.
    pub fn evaluation_set() -> Vec<SchemeKind> {
        vec![
            SchemeKind::Baseline,
            SchemeKind::TopK { ratio: 0.01 },
            SchemeKind::Dgc { ratio: 0.001 },
            SchemeKind::RandomK { ratio: 0.01 },
            SchemeKind::Fp16,
            SchemeKind::EfSignSgd,
            SchemeKind::PowerSgd { rank: 1 },
            SchemeKind::OkTopk { ratio: 0.01 },
            SchemeKind::Covap { interval: 4, ef: EfScheduler::default() },
        ]
    }
}

/// Mean of per-worker dense vectors (the collective's arithmetic result).
pub(crate) fn mean_of(grads: &[&[f32]]) -> Vec<f32> {
    let n = grads[0].len();
    let inv = 1.0 / grads.len() as f32;
    let mut out = vec![0.0f32; n];
    for g in grads {
        debug_assert_eq!(g.len(), n);
        for (o, x) in out.iter_mut().zip(g.iter()) {
            *o += x;
        }
    }
    for o in &mut out {
        *o *= inv;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// All schemes must be unbiased-ish on identical inputs: if every worker
    /// holds the same gradient g, the aggregated update of a dense-complete
    /// scheme equals g (baseline, fp16~, covap-kept buckets).
    #[test]
    fn baseline_identity_on_identical_grads() {
        let mut s = SchemeKind::Baseline.build(4, 0);
        let g: Vec<f32> = (0..100).map(|i| i as f32 * 0.1 - 5.0).collect();
        let refs: Vec<&[f32]> = (0..4).map(|_| g.as_slice()).collect();
        let (u, rec) = s.round(0, 0, &refs);
        assert_eq!(u, g);
        assert_eq!(rec.wire_bytes, 400);
    }

    #[test]
    fn mean_of_averages() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        assert_eq!(mean_of(&[&a, &b]), vec![2.0, 4.0]);
    }

    #[test]
    fn paper_default_lookup() {
        assert!(SchemeKind::paper_default("covap").is_some());
        assert!(SchemeKind::paper_default("PowerSGD").is_some());
        assert!(SchemeKind::paper_default("nope").is_none());
    }

    /// Property: every scheme preserves "signal mass" over repeated rounds —
    /// with error feedback, the sum of (update*P applied) + residuals equals
    /// the sum of raw gradients fed in (up to fp32 tolerance). We check the
    /// weaker, universal property: updates are finite and the scheme never
    /// panics across random shapes.
    #[test]
    fn all_schemes_finite_updates() {
        for kind in SchemeKind::evaluation_set() {
            prop::check(kind.label(), 42, 8, |rng: &mut Rng| {
                let workers = 1 + rng.below(4);
                let n = 32 + rng.below(2048);
                let mut s = kind.build(workers, 7);
                let gs: Vec<Vec<f32>> =
                    (0..workers).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
                let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
                for step in 0..5 {
                    let (u, rec) = s.round(0, step, &refs);
                    // empty update = "all zeros" (COVAP dropped tensors)
                    assert!(
                        u.is_empty() || u.len() == n,
                        "{}", kind.label()
                    );
                    assert!(u.iter().all(|x| x.is_finite()), "{}", kind.label());
                    assert!(rec.compress_s >= 0.0);
                }
            });
        }
    }
}
