//! Gradient compression schemes: COVAP plus the paper's seven comparison
//! baselines (Table II / VII).
//!
//! The **canonical API is per-rank**: every scheme natively implements
//! [`RankCompressor`] / [`RankCombiner`] (see [`rank`]) — one rank's
//! error-feedback accumulate + wire encode, and the deterministic decode of
//! all ranks' payloads into the dense update. That is the interface real
//! transports plug into, and the one the threaded executor drives.
//!
//! The replicated [`Scheme`] trait — one object modeling a whole worker
//! group, which the analytic backend and the paper-table harnesses consume
//! — is a thin adapter: [`LockstepDriver`] drives P compressor/combiner
//! pairs in sequence over the per-worker gradients. There is exactly one
//! compress/combine implementation per scheme; the two backends differ only
//! in *who drives it*, so their bitwise parity is structural.
//!
//! Wire accounting is a **measurement, not a model**: each round's
//! [`CommRecord::wire_bytes`] is the byte length of the encoded payload
//! frame ([`Payload::encode`]) that `exec::ring` actually moves, and the
//! timeline simulator prices those same measured sizes. `compress_s` in the
//! record is the measured wall time of the local compression work (the
//! paper's `T_compress`) — what Table II and the Fig. 7–10 breakdowns
//! report.

mod baseline;
mod covap;
mod ef;
mod fp16;
mod oktopk;
mod powersgd;
pub mod rank;
mod randomk;
mod signsgd;
mod topk;

pub use ef::EfState;
pub use fp16::{f16_to_f32, f32_to_f16};
pub use powersgd::PowerSgd;
pub use rank::{
    build_rank_pair, dense_frame_len, half_frame_len, sign_frame_len, sparse_frame_len,
    varint_len, DecodeError, Payload, RankCombiner, RankCompressor, RankRound,
    ReplicatedScheme, Scratch,
};

pub(crate) use topk::k_of;

use std::time::Instant;

use crate::comm::LevelBytes;
use crate::covap::EfScheduler;

/// Which collective *operation* the scheme's wire format requires. The
/// algorithm executing it (ring / hier / tree) is the orthogonal
/// [`crate::comm::Collective`] topology axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveOp {
    /// Payloads are summable in-network (dense / shared-index sparse).
    AllReduce,
    /// Payloads must be gathered to every rank (worker-specific indices).
    AllGather,
}

/// Wire + overhead accounting for one bucket round.
#[derive(Debug, Clone, Copy)]
pub struct CommRecord {
    /// Bytes of this rank's encoded payload frame for this bucket — the
    /// measured `Payload::encode().len()`, 0 = nothing transmitted.
    pub wire_bytes: usize,
    pub collective: CollectiveOp,
    /// Number of dependent collective rounds (PowerSGD = 2).
    pub rounds: u32,
    /// Extra synchronous rendezvous (threshold exchange etc.).
    pub sync_rounds: u32,
    /// Measured per-worker local compression+decompression wall time, s.
    pub compress_s: f64,
    /// True if the scheme's later computation depends on an earlier
    /// collective's *result* (breaks overlapping; §I "data dependency").
    pub data_dependency: bool,
    /// Per-link-level bytes the *busiest* rank sends rotating this
    /// tensor's frames through the configured topology (worst-rank
    /// uniform-frame arithmetic over the hop schedule, maxima per level
    /// independent; hierarchical moves fewer inter-node bytes). Combiners
    /// cannot see the topology, so they leave this zeroed and the engine
    /// fills it — identically on both backends.
    pub levels: LevelBytes,
}

impl CommRecord {
    // xtask: hot-path
    pub fn dense(bytes: usize, compress_s: f64) -> CommRecord {
        CommRecord {
            wire_bytes: bytes,
            collective: CollectiveOp::AllReduce,
            rounds: 1,
            sync_rounds: 0,
            compress_s,
            data_dependency: false,
            levels: LevelBytes::default(),
        }
    }
}

/// One gradient-compression scheme modeling a whole worker group.
///
/// `round` receives the per-worker raw bucket gradients and returns the
/// averaged dense update the optimizer applies, plus the comm record.
///
/// The sole implementation is [`LockstepDriver`]: the per-rank API
/// ([`rank`]) is canonical, and this trait is the lockstep adapter over it
/// for in-process (analytic-backend) execution.
pub trait Scheme: Send {
    fn name(&self) -> &'static str;

    fn round(&mut self, bucket: usize, step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord);

    /// Re-shard hook (see [`RankCompressor::reconfigure`]): migrate to
    /// `kind` while remapping per-tensor state from the `old` tensor
    /// layout to `new` (both `(flat offset, numel)` slot tables). Returns
    /// true when handled in place; false tells the caller to rebuild via
    /// [`SchemeKind::build`] (state dropped — the pre-remap behavior).
    fn reconfigure(
        &mut self,
        _kind: &SchemeKind,
        _old: &[(usize, usize)],
        _new: &[(usize, usize)],
    ) -> bool {
        false
    }

    /// Elastic-membership hook: flatten `rank`'s long-lived per-tensor
    /// state (EF residuals) over the slot `layout` into flat parameter
    /// space (see [`RankCompressor::export_residuals`]). `None` = no
    /// portable state.
    fn export_residuals(&self, _rank: usize, _layout: &[(usize, usize)]) -> Option<Vec<f32>> {
        None
    }

    /// Elastic-membership hook: adopt `flat` as `rank`'s per-tensor state,
    /// sliced by `layout` (see [`RankCompressor::import_residuals`]).
    /// Returns false when ignored (stateless scheme).
    fn import_residuals(&mut self, _rank: usize, _flat: &[f32], _layout: &[(usize, usize)]) -> bool {
        false
    }

    /// Reset all error-feedback / iteration state (new training run).
    fn reset(&mut self);
}

/// The generic replicated-path adapter: P per-rank compressors (each owning
/// its own rank's error-feedback state) plus one shared combiner, driven in
/// rank order over the per-worker gradients — exactly the sequence the
/// threaded executor runs concurrently, executed in lockstep on one thread.
///
/// The driver owns the same steady-state buffers a rank pair does — one
/// [`Scratch`] arena, P wire-frame buffers, one update buffer — so the
/// analytic backend's compress→encode→combine path is allocation-free
/// after warmup, exactly like the threaded executor's (the `Vec` handed
/// back by [`Scheme::round`] is the one remaining copy, owed to the
/// replicated trait's by-value signature).
pub struct LockstepDriver {
    label: &'static str,
    workers: usize,
    seed: u64,
    compressors: Vec<Box<dyn RankCompressor>>,
    /// Combiners are deterministic and bit-identical across ranks, so the
    /// driver holds a single replica (rank 0's).
    combiner: Box<dyn RankCombiner>,
    /// Reusable temporaries shared by the (sequentially-driven) halves.
    scratch: Scratch,
    /// Per-worker encoded wire frames, rank-major.
    frames: Vec<Vec<u8>>,
    /// Reusable combine output.
    update: Vec<f32>,
}

impl LockstepDriver {
    pub fn new(kind: &SchemeKind, workers: usize, seed: u64) -> LockstepDriver {
        assert!(workers >= 1, "lockstep driver needs at least one rank");
        let mut compressors: Vec<Box<dyn RankCompressor>> = Vec::with_capacity(workers);
        let mut combiner: Option<Box<dyn RankCombiner>> = None;
        for _ in 0..workers {
            let (c, cb) = build_rank_pair(kind, workers, seed);
            compressors.push(c);
            if combiner.is_none() {
                combiner = Some(cb);
            }
        }
        LockstepDriver {
            label: kind.label(),
            workers,
            seed,
            compressors,
            combiner: combiner.expect("workers >= 1"),
            scratch: Scratch::new(),
            frames: (0..workers).map(|_| Vec::new()).collect(),
            update: Vec::new(),
        }
    }
}

impl Scheme for LockstepDriver {
    fn name(&self) -> &'static str {
        self.label
    }

    fn round(&mut self, bucket: usize, step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord) {
        assert_eq!(grads.len(), self.workers, "grads must be rank-major over all workers");
        let n = grads[0].len();
        let t0 = Instant::now();
        for ((c, g), frame) in self
            .compressors
            .iter_mut()
            .zip(grads.iter())
            .zip(self.frames.iter_mut())
        {
            c.compress_into(bucket, step, g, &mut self.scratch, frame);
        }
        // Per-worker wall time of the compression halves. Combiners add
        // their own measured *decompression* (sparse scatter, sign unpack,
        // half dequantize) on top; a plain dense mean is the collective's
        // arithmetic and charges nothing — so the baseline's T_compress
        // stays ~zero and nothing is double-counted against the network
        // model's collective pricing.
        let compress_s = t0.elapsed().as_secs_f64() / self.workers as f64;
        let record = self.combiner.combine_into(
            bucket,
            step,
            n,
            &self.frames,
            &mut self.scratch,
            &mut self.update,
            compress_s,
        );
        (self.update.clone(), record)
    }

    /// In-place re-shard: every rank pair must accept the migration (same
    /// compressor type on all ranks, so they agree); the combiner is
    /// rebuilt from the new kind exactly as the threaded executor's comm
    /// threads do, keeping the two drivers' post-reshard state structural
    /// twins.
    fn reconfigure(
        &mut self,
        kind: &SchemeKind,
        old: &[(usize, usize)],
        new: &[(usize, usize)],
    ) -> bool {
        let mut ok = true;
        for c in &mut self.compressors {
            ok &= c.reconfigure(kind, old, new);
        }
        if ok {
            let (_, cb) = build_rank_pair(kind, self.workers, self.seed);
            self.combiner = cb;
            self.label = kind.label();
        }
        ok
    }

    fn export_residuals(&self, rank: usize, layout: &[(usize, usize)]) -> Option<Vec<f32>> {
        self.compressors.get(rank)?.export_residuals(layout)
    }

    fn import_residuals(&mut self, rank: usize, flat: &[f32], layout: &[(usize, usize)]) -> bool {
        match self.compressors.get_mut(rank) {
            Some(c) => c.import_residuals(flat, layout),
            None => false,
        }
    }

    fn reset(&mut self) {
        for c in &mut self.compressors {
            c.reset();
        }
        self.combiner.reset();
    }
}

/// Scheme selector + hyperparameters (mirrors the paper's Table II column).
#[derive(Debug, Clone, PartialEq)]
pub enum SchemeKind {
    /// DDPovlp — no compression.
    Baseline,
    /// COVAP with a fixed interval (adaptive selection happens in the
    /// trainer via the profiler; see covap::interval_from_ccr).
    Covap { interval: usize, ef: EfScheduler },
    /// COVAP in closed-loop adaptive mode (`covap@auto`): runs dense
    /// (interval 1) while the engine's interval controller profiles CCR,
    /// then re-shards to `ceil(CCR)` and keeps re-profiling in windows.
    /// Profiling swaps *only this* scheme — a configured `topk@...` etc.
    /// is never silently replaced (the old adaptive path's bug).
    CovapAuto { ef: EfScheduler },
    TopK { ratio: f64 },
    Dgc { ratio: f64 },
    RandomK { ratio: f64 },
    Fp16,
    EfSignSgd,
    PowerSgd { rank: usize },
    OkTopk { ratio: f64 },
}

impl SchemeKind {
    /// Paper defaults (Table II hyperparameter column).
    pub fn paper_default(name: &str) -> Option<SchemeKind> {
        Some(match name.to_ascii_lowercase().as_str() {
            "baseline" | "ddp" | "ddpovlp" => SchemeKind::Baseline,
            "covap" => SchemeKind::Covap { interval: 4, ef: EfScheduler::default() },
            "topk" | "top-k" => SchemeKind::TopK { ratio: 0.01 },
            "dgc" => SchemeKind::Dgc { ratio: 0.001 },
            "randomk" | "random-k" => SchemeKind::RandomK { ratio: 0.01 },
            "fp16" => SchemeKind::Fp16,
            "efsignsgd" => SchemeKind::EfSignSgd,
            "powersgd" => SchemeKind::PowerSgd { rank: 1 },
            "oktopk" | "ok-topk" => SchemeKind::OkTopk { ratio: 0.01 },
            _ => return None,
        })
    }

    /// Parse a scheme spec string: a paper-default name, optionally with a
    /// `@hyperparameter` suffix — `topk@0.05` (ratio), `powersgd@2` (rank),
    /// `covap@8` (fixed interval), `covap@auto` (closed-loop adaptive
    /// interval), `dgc@0.001`, `randomk@0.02`, `oktopk@0.01`. Schemes
    /// without a hyperparameter (`baseline`, `fp16`, `efsignsgd`) reject a
    /// suffix. Inverse of [`SchemeKind::spec`].
    pub fn parse(spec: &str) -> Option<SchemeKind> {
        let (name, param) = match spec.split_once('@') {
            Some((n, p)) => (n, Some(p)),
            None => (spec, None),
        };
        let mut kind = Self::paper_default(name)?;
        if let Some(p) = param {
            if matches!(kind, SchemeKind::Covap { .. }) && p.eq_ignore_ascii_case("auto") {
                return Some(SchemeKind::CovapAuto { ef: EfScheduler::default() });
            }
            match &mut kind {
                SchemeKind::TopK { ratio }
                | SchemeKind::Dgc { ratio }
                | SchemeKind::RandomK { ratio }
                | SchemeKind::OkTopk { ratio } => {
                    *ratio = p.parse().ok().filter(|r| *r > 0.0 && *r <= 1.0)?;
                }
                SchemeKind::PowerSgd { rank } => {
                    *rank = p.parse().ok().filter(|r| *r >= 1)?;
                }
                SchemeKind::Covap { interval, .. } => {
                    *interval = p.parse().ok().filter(|i| *i >= 1)?;
                }
                SchemeKind::Baseline | SchemeKind::Fp16 | SchemeKind::EfSignSgd => {
                    return None;
                }
                // paper_default never yields CovapAuto; the `@auto` suffix
                // is handled above.
                SchemeKind::CovapAuto { .. } => return None,
            }
        }
        Some(kind)
    }

    /// Canonical spec string; `SchemeKind::parse(&k.spec())` round-trips
    /// (the COVAP EF scheduler keeps its default — it is config-file-only).
    pub fn spec(&self) -> String {
        match self {
            SchemeKind::Baseline => "baseline".into(),
            SchemeKind::Covap { interval, .. } => format!("covap@{interval}"),
            SchemeKind::CovapAuto { .. } => "covap@auto".into(),
            SchemeKind::TopK { ratio } => format!("topk@{ratio}"),
            SchemeKind::Dgc { ratio } => format!("dgc@{ratio}"),
            SchemeKind::RandomK { ratio } => format!("randomk@{ratio}"),
            SchemeKind::Fp16 => "fp16".into(),
            SchemeKind::EfSignSgd => "efsignsgd".into(),
            SchemeKind::PowerSgd { rank } => format!("powersgd@{rank}"),
            SchemeKind::OkTopk { ratio } => format!("oktopk@{ratio}"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchemeKind::Baseline => "DDPovlp",
            SchemeKind::Covap { .. } => "COVAP",
            SchemeKind::CovapAuto { .. } => "COVAP-auto",
            SchemeKind::TopK { .. } => "Top-k",
            SchemeKind::Dgc { .. } => "DGC",
            SchemeKind::RandomK { .. } => "Random-k",
            SchemeKind::Fp16 => "FP16",
            SchemeKind::EfSignSgd => "EFsignSGD",
            SchemeKind::PowerSgd { .. } => "PowerSGD",
            SchemeKind::OkTopk { .. } => "Ok-topk",
        }
    }

    /// Instantiate the replicated-path adapter for `workers` ranks with a
    /// deterministic seed.
    pub fn build(&self, workers: usize, seed: u64) -> Box<dyn Scheme> {
        Box::new(LockstepDriver::new(self, workers, seed))
    }

    /// All schemes of the paper's evaluation, with paper hyperparameters.
    pub fn evaluation_set() -> Vec<SchemeKind> {
        vec![
            SchemeKind::Baseline,
            SchemeKind::TopK { ratio: 0.01 },
            SchemeKind::Dgc { ratio: 0.001 },
            SchemeKind::RandomK { ratio: 0.01 },
            SchemeKind::Fp16,
            SchemeKind::EfSignSgd,
            SchemeKind::PowerSgd { rank: 1 },
            SchemeKind::OkTopk { ratio: 0.01 },
            SchemeKind::Covap { interval: 4, ef: EfScheduler::default() },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// All schemes must be unbiased-ish on identical inputs: if every worker
    /// holds the same gradient g, the aggregated update of a dense-complete
    /// scheme equals g (baseline, fp16~, covap-kept buckets). The wire
    /// volume is the measured encoded frame, not `4 * n`.
    #[test]
    fn baseline_identity_on_identical_grads() {
        let mut s = SchemeKind::Baseline.build(4, 0);
        let g: Vec<f32> = (0..100).map(|i| i as f32 * 0.1 - 5.0).collect();
        let refs: Vec<&[f32]> = (0..4).map(|_| g.as_slice()).collect();
        let (u, rec) = s.round(0, 0, &refs);
        assert_eq!(u, g);
        assert_eq!(rec.wire_bytes, dense_frame_len(100));
        assert_eq!(rec.wire_bytes, Payload::Dense(g).encode().len());
    }

    #[test]
    fn paper_default_lookup() {
        assert!(SchemeKind::paper_default("covap").is_some());
        assert!(SchemeKind::paper_default("PowerSGD").is_some());
        assert!(SchemeKind::paper_default("nope").is_none());
    }

    #[test]
    fn spec_parsing_applies_hyperparameters() {
        assert_eq!(
            SchemeKind::parse("topk@0.05"),
            Some(SchemeKind::TopK { ratio: 0.05 })
        );
        assert_eq!(
            SchemeKind::parse("powersgd@2"),
            Some(SchemeKind::PowerSgd { rank: 2 })
        );
        assert_eq!(
            SchemeKind::parse("dgc@0.001"),
            Some(SchemeKind::Dgc { ratio: 0.001 })
        );
        match SchemeKind::parse("covap@8") {
            Some(SchemeKind::Covap { interval: 8, .. }) => {}
            other => panic!("covap@8 parsed to {other:?}"),
        }
        match SchemeKind::parse("covap@auto") {
            Some(SchemeKind::CovapAuto { .. }) => {}
            other => panic!("covap@auto parsed to {other:?}"),
        }
        match SchemeKind::parse("covap@AUTO") {
            Some(SchemeKind::CovapAuto { .. }) => {}
            other => panic!("covap@AUTO parsed to {other:?}"),
        }
        // bare names keep working
        assert_eq!(SchemeKind::parse("fp16"), Some(SchemeKind::Fp16));
        assert_eq!(
            SchemeKind::parse("oktopk@0.02"),
            Some(SchemeKind::OkTopk { ratio: 0.02 })
        );
    }

    #[test]
    fn spec_parsing_rejects_bad_hyperparameters() {
        for bad in [
            "fp16@2",       // no hyperparameter on fp16
            "baseline@1",   // ... or baseline
            "efsignsgd@3",  // ... or efsignsgd
            "topk@0",       // ratio out of range
            "topk@1.5",     // ratio out of range
            "topk@abc",     // not a number
            "powersgd@0",   // rank must be >= 1
            "covap@0",      // interval must be >= 1
            "covap@auto2",  // 'auto' is exact, not a prefix
            "topk@auto",    // only covap has an adaptive mode
            "nope@1",       // unknown scheme
        ] {
            assert!(SchemeKind::parse(bad).is_none(), "{bad} should be rejected");
        }
    }

    #[test]
    fn spec_roundtrips_for_evaluation_set() {
        for kind in SchemeKind::evaluation_set() {
            let spec = kind.spec();
            let back = SchemeKind::parse(&spec)
                .unwrap_or_else(|| panic!("spec '{spec}' failed to parse"));
            assert_eq!(back, kind, "spec '{spec}' did not round-trip");
        }
        // non-default hyperparameters round-trip too
        for kind in [
            SchemeKind::TopK { ratio: 0.05 },
            SchemeKind::Dgc { ratio: 0.0025 },
            SchemeKind::PowerSgd { rank: 4 },
            SchemeKind::Covap { interval: 7, ef: EfScheduler::default() },
            SchemeKind::CovapAuto { ef: EfScheduler::default() },
        ] {
            assert_eq!(SchemeKind::parse(&kind.spec()), Some(kind));
        }
    }

    /// Before its controller concludes, `covap@auto` *is* COVAP at
    /// interval 1 (dense warmup): the two specs produce bitwise-identical
    /// rounds, so profiling measures the true dense CCR.
    #[test]
    fn covap_auto_warmup_is_dense_interval_one() {
        let mut rng = Rng::seed(0xA07);
        let gs: Vec<Vec<f32>> = (0..3).map(|_| prop::vec_f32(&mut rng, 64, 1.0)).collect();
        let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
        let ef = EfScheduler::default();
        let mut auto_s = SchemeKind::CovapAuto { ef }.build(3, 9);
        let mut one = SchemeKind::Covap { interval: 1, ef }.build(3, 9);
        for step in 0..3 {
            for tensor in 0..2 {
                let (ua, ra) = auto_s.round(tensor, step, &refs);
                let (uo, ro) = one.round(tensor, step, &refs);
                assert_eq!(ua, uo, "step {step} tensor {tensor}");
                assert_eq!(ra.wire_bytes, ro.wire_bytes);
            }
        }
    }

    /// Property: every scheme preserves "signal mass" over repeated rounds —
    /// with error feedback, the sum of (update*P applied) + residuals equals
    /// the sum of raw gradients fed in (up to fp32 tolerance). We check the
    /// weaker, universal property: updates are finite and the scheme never
    /// panics across random shapes.
    #[test]
    fn all_schemes_finite_updates() {
        for kind in SchemeKind::evaluation_set() {
            prop::check(kind.label(), 42, 8, |rng: &mut Rng| {
                let workers = 1 + rng.below(4);
                let n = 32 + rng.below(2048);
                let mut s = kind.build(workers, 7);
                let gs: Vec<Vec<f32>> =
                    (0..workers).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
                let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
                for step in 0..5 {
                    let (u, rec) = s.round(0, step, &refs);
                    // empty update = "all zeros" (COVAP dropped tensors)
                    assert!(
                        u.is_empty() || u.len() == n,
                        "{}", kind.label()
                    );
                    assert!(u.iter().all(|x| x.is_finite()), "{}", kind.label());
                    assert!(rec.compress_s >= 0.0);
                }
            });
        }
    }

    #[test]
    fn driver_reset_clears_error_feedback() {
        let kind = SchemeKind::TopK { ratio: 0.25 };
        let g = vec![1.0f32, 0.4, 0.0, 0.0];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = kind.build(1, 3);
        let (first, _) = s.round(0, 0, &refs);
        let (_second, _) = s.round(0, 1, &refs); // residuals now nonzero
        s.reset();
        let (after_reset, _) = s.round(0, 0, &refs);
        assert_eq!(first, after_reset, "reset must restore the initial EF state");
    }
}
