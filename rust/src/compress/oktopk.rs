//! Ok-topk (Li & Hoefler 2022): near-optimal sparse allreduce with a
//! *global* top-k.
//!
//! The real algorithm splits the gradient across ranks, exchanges threshold
//! estimates, and reduces only ~O(k) values per rank. We reproduce the
//! numeric semantics (global top-k over the summed gradient, per-worker
//! error feedback on unselected coordinates) and the cost shape (an O(k)
//! sparse frame per rank on an AllReduce-style pattern, plus synchronous
//! threshold rendezvous rounds that serialize against computation — the
//! paper's "incompatible with Overlapping" point in §IV.C.1). The global
//! threshold makes the round inherently coupled, so Ok-topk runs as a
//! [`ReplicatedScheme`](super::rank) with `data_dependency` set.

use std::time::Instant;

use super::rank::{sparse_frame_len, ReplicatedScheme};
use super::{CollectiveOp, CommRecord, EfState};

pub struct OkTopk {
    ratio: f64,
    ef: EfState,
    /// Threshold carried from the previous iteration (the real algorithm
    /// re-estimates sparingly; we re-estimate every `REESTIMATE` steps).
    threshold: std::collections::HashMap<usize, f32>,
}

const REESTIMATE: u64 = 32;

impl OkTopk {
    pub fn new(ratio: f64, workers: usize) -> OkTopk {
        assert!(ratio > 0.0 && ratio <= 1.0);
        OkTopk { ratio, ef: EfState::new(workers), threshold: Default::default() }
    }
}

impl ReplicatedScheme for OkTopk {
    fn name(&self) -> &'static str {
        "Ok-topk"
    }

    fn round(&mut self, bucket: usize, step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord) {
        let n = grads[0].len();
        let k = ((self.ratio * n as f64).round() as usize).clamp(1, n);
        let t0 = Instant::now();
        let acc = self.ef.accumulate(bucket, 1.0, grads);

        // Global sum (what the sparse allreduce computes over selected
        // coordinates).
        let inv = 1.0 / acc.len() as f32;
        let mut mean = vec![0.0f32; n];
        for a in &acc {
            for (m, x) in mean.iter_mut().zip(a.iter()) {
                *m += x * inv;
            }
        }

        // Threshold: exact global k-th magnitude every REESTIMATE steps,
        // carried over otherwise (Ok-topk's amortized estimation).
        let thr = if step % REESTIMATE == 0 || !self.threshold.contains_key(&bucket) {
            // total_cmp: NaN-safe (a poisoned gradient cannot panic the
            // replica) and branch-cheaper than partial_cmp(..).unwrap();
            // identical order on the non-negative magnitudes.
            let mut mags: Vec<f32> = mean.iter().map(|x| x.abs()).collect();
            mags.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
            let t = mags[k - 1];
            self.threshold.insert(bucket, t);
            t
        } else {
            self.threshold[&bucket]
        };

        // Select globally, cap at 2k (stale thresholds can over-select).
        let cap = 2 * k;
        let mut update = vec![0.0f32; n];
        let mut selected = Vec::with_capacity(cap);
        for (i, &m) in mean.iter().enumerate() {
            if m.abs() >= thr && selected.len() < cap {
                update[i] = m;
                selected.push(i);
            }
        }

        // Per-worker EF on unselected coordinates.
        let mut residuals: Vec<Vec<f32>> = acc;
        for r in &mut residuals {
            for &i in &selected {
                r[i] = 0.0;
            }
        }
        self.ef.store(bucket, residuals);

        let compress_s = t0.elapsed().as_secs_f64() / grads.len() as f64;
        let rec = CommRecord {
            // the encoded sparse frame of the selected coordinates
            wire_bytes: sparse_frame_len(selected.len()),
            collective: CollectiveOp::AllReduce,
            rounds: 1,
            sync_rounds: 2, // split + threshold rendezvous
            compress_s,
            data_dependency: true,
            levels: crate::comm::LevelBytes::default(),
        };
        (update, rec)
    }

    fn reset(&mut self) {
        self.ef.clear();
        self.threshold.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn selects_global_topk_not_local() {
        // Worker 0 has a big +x at i=0; worker 1 has -x at i=0 (cancels) and
        // both have moderate +y at i=1 (adds). Global top-1 must pick i=1.
        let g0 = vec![10.0f32, 3.0, 0.0, 0.0];
        let g1 = vec![-10.0f32, 3.0, 0.0, 0.0];
        let refs: Vec<&[f32]> = vec![&g0, &g1];
        let mut s = OkTopk::new(0.25, 2); // k=1
        let (u, _) = s.round(0, 0, &refs);
        assert_eq!(u[0], 0.0, "cancelled coordinate must not be selected");
        assert_eq!(u[1], 3.0);
    }

    #[test]
    fn has_sync_dependency() {
        let g = vec![1.0f32; 16];
        let refs: Vec<&[f32]> = vec![&g];
        let (_, rec) = OkTopk::new(0.1, 1).round(0, 0, &refs);
        assert!(rec.data_dependency);
        assert!(rec.sync_rounds > 0);
        assert_eq!(rec.collective, CollectiveOp::AllReduce);
    }

    #[test]
    fn threshold_reuse_between_reestimates() {
        let mut rng = Rng::seed(11);
        let g: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = OkTopk::new(0.01, 1);
        let (_, r0) = s.round(0, 0, &refs);
        let (_, r1) = s.round(0, 1, &refs);
        // step 1 reuses threshold: strictly cheaper compress path
        assert!(r1.compress_s <= r0.compress_s * 1.5);
        assert!(r0.wire_bytes > 0 && r1.wire_bytes > 0);
    }

    #[test]
    fn ef_recovers_unselected_mass() {
        // Coordinate 1 is below the k=1 threshold every step, but its EF
        // residual grows by 0.2/step; at the step-32 threshold re-estimation
        // its accumulated mass (~6.6) tops the list and it gets flushed.
        let g = vec![1.0f32, 0.2, 0.0, 0.0];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = OkTopk::new(0.25, 1); // k=1
        let mut total = vec![0.0f64; 4];
        for step in 0..40 {
            let (u, _) = s.round(0, step, &refs);
            for (t, x) in total.iter_mut().zip(u.iter()) {
                *t += *x as f64;
            }
        }
        assert!(total[1] > 1.0, "accumulated coordinate must flush: {total:?}");
    }
}
