//! PowerSGD (Vogels et al. 2019): rank-r low-rank gradient approximation.
//!
//! The bucket gradient is viewed as a matrix M [rows x cols]. One power
//! iteration with a warm-started Q:
//!     P_w = M_w Q          -> AllReduce(mean)  -> orthonormalize P̂
//!     Q_w = M_wᵀ P̂        -> AllReduce(mean)
//!     update = P̂ Qᵀ / 1   (already the mean-gradient approximation)
//! Error feedback per worker: r_w = acc_w - P̂ Qᵀ.
//!
//! Two dependent AllReduce rounds: the Q matmul needs the *result* of the P
//! allreduce — inherently global, so PowerSGD runs as a
//! [`ReplicatedScheme`](super::rank): every rank holds an identical replica
//! fed the gathered raw gradients (see DESIGN.md §4). The wire accounting
//! charges the encoded frames of the P and Q factors the real algorithm
//! would move — tiny, even though overlapping is limited (Fig. 1e).

use std::time::Instant;

use super::rank::{dense_frame_len, ReplicatedScheme};
use super::{CollectiveOp, CommRecord, EfState};
use crate::util::rng::Rng;

pub struct PowerSgd {
    rank: usize,
    ef: EfState,
    /// Warm-started Q per bucket [cols x rank].
    q: std::collections::HashMap<usize, Vec<f32>>,
    seed: u64,
}

impl PowerSgd {
    pub fn new(rank: usize, workers: usize, seed: u64) -> PowerSgd {
        assert!(rank >= 1);
        PowerSgd { rank, ef: EfState::new(workers), q: Default::default(), seed }
    }

    /// Matrix shape for a flat bucket of n elements: cols ~ sqrt(n) capped,
    /// rows = ceil(n / cols) (tail zero-padded).
    pub fn shape(n: usize) -> (usize, usize) {
        let cols = ((n as f64).sqrt() as usize).clamp(1, 4096);
        let rows = n.div_ceil(cols);
        (rows, cols)
    }

    /// Encoded wire bytes of one round's factor frames for a bucket of `n`
    /// elements at rank `r`: the Dense frames of P [rows x r] and
    /// Q [cols x r] the algorithm exchanges.
    pub fn factor_frame_bytes(n: usize, r: usize) -> usize {
        let (rows, cols) = Self::shape(n);
        let r = r.clamp(1, cols.min(rows));
        dense_frame_len(rows * r) + dense_frame_len(cols * r)
    }
}

/// y[rows x r] = M[rows x cols] * Q[cols x r], M given flat (zero-padded).
fn mat_q(m: &[f32], rows: usize, cols: usize, q: &[f32], r: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * r];
    for i in 0..rows {
        let row = &m[i * cols..((i + 1) * cols).min(m.len())];
        for (j, &x) in row.iter().enumerate() {
            if x != 0.0 {
                let qrow = &q[j * r..j * r + r];
                let orow = &mut out[i * r..i * r + r];
                for (o, &qv) in orow.iter_mut().zip(qrow.iter()) {
                    *o += x * qv;
                }
            }
        }
    }
    out
}

/// y[cols x r] = Mᵀ * P, with M flat [rows x cols] zero-padded.
fn mat_t_p(m: &[f32], rows: usize, cols: usize, p: &[f32], r: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; cols * r];
    for i in 0..rows {
        let row = &m[i * cols..((i + 1) * cols).min(m.len())];
        let prow = &p[i * r..i * r + r];
        for (j, &x) in row.iter().enumerate() {
            if x != 0.0 {
                let orow = &mut out[j * r..j * r + r];
                for (o, &pv) in orow.iter_mut().zip(prow.iter()) {
                    *o += x * pv;
                }
            }
        }
    }
    out
}

/// In-place modified Gram-Schmidt on the r columns of P [rows x r].
fn orthonormalize(p: &mut [f32], rows: usize, r: usize) {
    for c in 0..r {
        // subtract projections on previous columns
        for prev in 0..c {
            let mut dot = 0.0f32;
            for i in 0..rows {
                dot += p[i * r + c] * p[i * r + prev];
            }
            for i in 0..rows {
                p[i * r + c] -= dot * p[i * r + prev];
            }
        }
        let norm: f32 = (0..rows).map(|i| p[i * r + c] * p[i * r + c]).sum::<f32>().sqrt();
        let inv = if norm > 1e-12 { 1.0 / norm } else { 0.0 };
        for i in 0..rows {
            p[i * r + c] *= inv;
        }
    }
}

impl ReplicatedScheme for PowerSgd {
    fn name(&self) -> &'static str {
        "PowerSGD"
    }

    fn round(&mut self, bucket: usize, _step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord) {
        let n = grads[0].len();
        let (rows, cols) = Self::shape(n);
        let r = self.rank.min(cols).min(rows);
        let t0 = Instant::now();
        let acc = self.ef.accumulate(bucket, 1.0, grads);

        let seed = self.seed;
        let q0 = self.q.entry(bucket).or_insert_with(|| {
            let mut rng = Rng::seed(seed ^ bucket as u64);
            (0..cols * r).map(|_| rng.normal() as f32).collect()
        });

        // Round 1: P = mean_w(M_w Q)
        let inv = 1.0 / acc.len() as f32;
        let mut p = vec![0.0f32; rows * r];
        for a in &acc {
            let pw = mat_q(a, rows, cols, q0, r);
            for (pi, x) in p.iter_mut().zip(pw.iter()) {
                *pi += x * inv;
            }
        }
        orthonormalize(&mut p, rows, r);

        // Round 2: Q = mean_w(M_wᵀ P̂)  (depends on round 1's result)
        let mut qn = vec![0.0f32; cols * r];
        for a in &acc {
            let qw = mat_t_p(a, rows, cols, &p, r);
            for (qi, x) in qn.iter_mut().zip(qw.iter()) {
                *qi += x * inv;
            }
        }

        // update = P̂ Qᵀ, cropped to n
        let mut update = vec![0.0f32; n];
        for i in 0..rows {
            for j in 0..cols {
                let idx = i * cols + j;
                if idx >= n {
                    break;
                }
                let mut v = 0.0f32;
                for c in 0..r {
                    v += p[i * r + c] * qn[j * r + c];
                }
                update[idx] = v;
            }
        }

        // EF: per-worker residual vs the shared low-rank reconstruction
        let residuals: Vec<Vec<f32>> = acc
            .iter()
            .map(|a| a.iter().zip(update.iter()).map(|(x, u)| x - u).collect())
            .collect();
        self.ef.store(bucket, residuals);
        // warm start next iteration
        self.q.insert(bucket, qn.clone());

        let compress_s = t0.elapsed().as_secs_f64() / grads.len() as f64;
        let rec = CommRecord {
            // the encoded P and Q frames the two collective rounds move
            wire_bytes: dense_frame_len(rows * r) + dense_frame_len(cols * r),
            collective: CollectiveOp::AllReduce,
            rounds: 2,
            sync_rounds: 0,
            compress_s,
            // per-bucket rounds are dependent on each other, but torch's
            // PowerSGD DDP hook still overlaps buckets with computation;
            // the timeline model charges 2 rounds instead (see harness).
            data_dependency: false,
            levels: crate::comm::LevelBytes::default(),
        };
        (update, rec)
    }

    fn reset(&mut self) {
        self.ef.clear();
        self.q.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_is_roughly_square() {
        let (rows, cols) = PowerSgd::shape(10_000);
        assert_eq!(cols, 100);
        assert_eq!(rows, 100);
        let (rows, cols) = PowerSgd::shape(10_001);
        assert!(rows * cols >= 10_001);
    }

    #[test]
    fn orthonormalize_produces_unit_orthogonal_columns() {
        let mut rng = Rng::seed(3);
        let (rows, r) = (50, 3);
        let mut p: Vec<f32> = (0..rows * r).map(|_| rng.normal() as f32).collect();
        orthonormalize(&mut p, rows, r);
        for a in 0..r {
            for b in a..r {
                let dot: f32 = (0..rows).map(|i| p[i * r + a] * p[i * r + b]).sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "col {a}x{b}: {dot}");
            }
        }
    }

    #[test]
    fn rank1_matrix_recovered_exactly() {
        // M = u vᵀ is rank 1: one power iteration reconstructs it (up to
        // fp32 noise).
        let rows = 32;
        let cols = 32;
        let mut rng = Rng::seed(4);
        let u: Vec<f32> = (0..rows).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        let m: Vec<f32> = (0..rows * cols).map(|i| u[i / cols] * v[i % cols]).collect();
        let refs: Vec<&[f32]> = vec![&m];
        let mut s = PowerSgd::new(1, 1, 7);
        let (rec_m, rec) = s.round(0, 0, &refs);
        let err: f32 = m.iter().zip(rec_m.iter()).map(|(a, b)| (a - b).abs()).sum::<f32>()
            / m.iter().map(|x| x.abs()).sum::<f32>();
        assert!(err < 1e-3, "relative err {err}");
        assert!(!rec.data_dependency);
        assert_eq!(rec.rounds, 2);
    }

    #[test]
    fn wire_volume_is_tiny_and_matches_factor_frames() {
        let g = vec![1.0f32; 1_000_000];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = PowerSgd::new(1, 1, 7);
        let (_, rec) = s.round(0, 0, &refs);
        assert!(rec.wire_bytes < 20_000, "{}", rec.wire_bytes); // vs 4 MB dense
        assert_eq!(rec.wire_bytes, PowerSgd::factor_frame_bytes(1_000_000, 1));
    }

    #[test]
    fn ef_plus_warm_start_converges_to_constant_gradient() {
        // Feeding the same gradient repeatedly, EF + warm started Q should
        // deliver (in cumulative mean) nearly the full gradient.
        let rows = 16;
        let cols = 16;
        let mut rng = Rng::seed(8);
        let g: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = PowerSgd::new(2, 1, 9);
        let steps = 60;
        let mut sum = vec![0.0f64; g.len()];
        for step in 0..steps {
            let (u, _) = s.round(0, step, &refs);
            for (acc, x) in sum.iter_mut().zip(u.iter()) {
                *acc += *x as f64;
            }
        }
        let num: f64 = sum
            .iter()
            .zip(g.iter())
            .map(|(s, gi)| (s / steps as f64 - *gi as f64).powi(2))
            .sum::<f64>();
        let den: f64 = g.iter().map(|x| (*x as f64).powi(2)).sum();
        assert!((num / den).sqrt() < 0.25, "relative tracking error {}", (num / den).sqrt());
    }
}
