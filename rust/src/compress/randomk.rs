//! Random-k sparsification (Stich et al. 2018) with error feedback.
//!
//! All ranks draw the *same* k indices from a shared (step, tensor)-seeded
//! stream, so no coordination is needed — but the scheme is wired as an
//! AllGather of sparse frames here, matching the GRACE implementation the
//! paper benchmarks (worker payloads gathered, then averaged; this is what
//! makes Random-k scale poorly in Fig. 11). The combine half is the shared
//! [`SparseCombiner`](super::rank).
//!
//! The paper notes Random-k diverged in most of their runs; we reproduce
//! the mechanism faithfully and observe the same instability in the
//! convergence harness.

use std::collections::HashMap;

use super::rank::{encode_sparse_into, RankCompressor, Scratch};
use super::topk::k_of;
use crate::util::rng::Rng;

/// The (seed, tensor, step) -> index-set rule. Identical on every rank, so
/// each draws the same coordinates locally with zero synchronization.
pub(crate) fn shared_indices(
    seed: u64,
    tensor: usize,
    step: u64,
    n: usize,
    k: usize,
) -> Vec<usize> {
    let mut rng =
        Rng::seed(seed ^ (step.wrapping_mul(0x9E37_79B9)) ^ (tensor as u64) << 32);
    rng.sample_indices(n, k)
}

/// One rank's random-k half: shared index draw + this rank's residuals.
pub(crate) struct RandomKCompressor {
    ratio: f64,
    seed: u64,
    residuals: HashMap<usize, Vec<f32>>,
}

impl RandomKCompressor {
    pub(crate) fn new(ratio: f64, seed: u64) -> RandomKCompressor {
        assert!(ratio > 0.0 && ratio <= 1.0);
        RandomKCompressor { ratio, seed, residuals: HashMap::new() }
    }
}

impl RankCompressor for RandomKCompressor {
    fn name(&self) -> &'static str {
        "Random-k"
    }

    fn compress_into(
        &mut self,
        tensor: usize,
        step: u64,
        grad: &[f32],
        scratch: &mut Scratch,
        frame: &mut Vec<u8>,
    ) {
        let n = grad.len();
        let k = k_of(self.ratio, n);
        // the shared draw itself still allocates (O(k) swap table) — the
        // mandatory zero-alloc set is covap/topk/signsgd/fp16; Random-k's
        // selection and encode reuse scratch like everyone else.
        scratch.sample.clear();
        scratch.sample.extend(shared_indices(self.seed, tensor, step, n, k));
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        scratch.acc.clear();
        scratch
            .acc
            .extend(grad.iter().zip(res.iter()).map(|(&gi, &ri)| gi + 1.0 * ri));
        scratch.idx.clear();
        scratch.val.clear();
        for &i in &scratch.sample {
            scratch.idx.push(i as u32);
            scratch.val.push(scratch.acc[i]);
            scratch.acc[i] = 0.0;
        }
        res.clear();
        res.extend_from_slice(&scratch.acc);
        encode_sparse_into(&scratch.idx, &scratch.val, frame);
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::rank::sparse_frame_len;
    use super::super::SchemeKind;
    use super::*;

    #[test]
    fn same_indices_for_all_workers_same_step() {
        let a = shared_indices(42, 3, 7, 1000, 100);
        let b = shared_indices(42, 3, 7, 1000, 100);
        assert_eq!(a, b);
        let c = shared_indices(42, 3, 8, 1000, 100);
        assert_ne!(a, c, "different step -> different indices");
    }

    #[test]
    fn update_is_mean_on_selected() {
        let g0 = vec![2.0f32; 100];
        let g1 = vec![4.0f32; 100];
        let refs: Vec<&[f32]> = vec![&g0, &g1];
        let mut s = SchemeKind::RandomK { ratio: 0.2 }.build(2, 1);
        let (u, rec) = s.round(0, 0, &refs);
        let nz: Vec<f32> = u.iter().copied().filter(|&x| x != 0.0).collect();
        assert_eq!(nz.len(), 20);
        assert!(nz.iter().all(|&x| x == 3.0));
        assert_eq!(rec.wire_bytes, sparse_frame_len(20));
    }

    #[test]
    fn ef_conserves_total_mass() {
        // Over many steps every coordinate is eventually sampled; total
        // update mass approaches total gradient mass.
        let g = vec![1.0f32; 50];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = SchemeKind::RandomK { ratio: 0.2 }.build(1, 9);
        let steps = 200u64;
        let mut total = 0.0f64;
        for step in 0..steps {
            let (u, _) = s.round(0, step, &refs);
            total += u.iter().map(|&x| x as f64).sum::<f64>();
        }
        let fed = steps as f64 * 50.0;
        assert!((total / fed - 1.0).abs() < 0.05, "mass ratio {}", total / fed);
    }
}
