//! Random-k sparsification (Stich et al. 2018) with error feedback.
//!
//! All workers draw the *same* k indices from a shared (step, bucket)-seeded
//! stream, so values are summable and an AllReduce of k values suffices —
//! but the scheme is wired as AllGather here, matching the GRACE
//! implementation the paper benchmarks (worker payloads gathered, then
//! averaged; this is what makes Random-k scale poorly in Fig. 11).
//!
//! The paper notes Random-k diverged in most of their runs; we reproduce
//! the mechanism faithfully and observe the same instability in the
//! convergence harness.

use std::time::Instant;

use super::{CommRecord, Collective, EfState, Scheme};
use crate::util::rng::Rng;

pub struct RandomK {
    ratio: f64,
    ef: EfState,
    seed: u64,
}

impl RandomK {
    pub fn new(ratio: f64, workers: usize, seed: u64) -> RandomK {
        assert!(ratio > 0.0 && ratio <= 1.0);
        RandomK { ratio, ef: EfState::new(workers), seed }
    }

    /// Shared index set for (step, bucket) — identical on every worker, no
    /// coordination needed (seeded from training seed).
    fn indices(&self, bucket: usize, step: u64, n: usize, k: usize) -> Vec<usize> {
        shared_indices(self.seed, bucket, step, n, k)
    }
}

/// The (seed, bucket, step) -> index-set rule, shared with the per-rank
/// executor path so both backends select identical coordinates.
pub(crate) fn shared_indices(
    seed: u64,
    bucket: usize,
    step: u64,
    n: usize,
    k: usize,
) -> Vec<usize> {
    let mut rng =
        Rng::seed(seed ^ (step.wrapping_mul(0x9E37_79B9)) ^ (bucket as u64) << 32);
    rng.sample_indices(n, k)
}

impl Scheme for RandomK {
    fn name(&self) -> &'static str {
        "Random-k"
    }

    fn round(&mut self, bucket: usize, step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord) {
        let n = grads[0].len();
        let k = ((self.ratio * n as f64).round() as usize).clamp(1, n);
        let t0 = Instant::now();
        let idx = self.indices(bucket, step, n, k);
        let acc = self.ef.accumulate(bucket, 1.0, grads);
        let mut update = vec![0.0f32; n];
        let inv = 1.0 / grads.len() as f32;
        let mut residuals = Vec::with_capacity(acc.len());
        for a in &acc {
            let mut r = a.clone();
            for &i in &idx {
                update[i] += a[i] * inv;
                r[i] = 0.0;
            }
            residuals.push(r);
        }
        self.ef.store(bucket, residuals);
        let compress_s = t0.elapsed().as_secs_f64() / grads.len() as f64;
        let rec = CommRecord {
            wire_bytes: k * 8,
            collective: Collective::AllGather,
            rounds: 1,
            sync_rounds: 0,
            compress_s,
            data_dependency: false,
        };
        (update, rec)
    }

    fn reset(&mut self) {
        self.ef.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_indices_for_all_workers_same_step() {
        let s = RandomK::new(0.1, 2, 42);
        let a = s.indices(3, 7, 1000, 100);
        let b = s.indices(3, 7, 1000, 100);
        assert_eq!(a, b);
        let c = s.indices(3, 8, 1000, 100);
        assert_ne!(a, c, "different step -> different indices");
    }

    #[test]
    fn update_is_mean_on_selected() {
        let g0 = vec![2.0f32; 100];
        let g1 = vec![4.0f32; 100];
        let refs: Vec<&[f32]> = vec![&g0, &g1];
        let mut s = RandomK::new(0.2, 2, 1);
        let (u, rec) = s.round(0, 0, &refs);
        let nz: Vec<f32> = u.iter().copied().filter(|&x| x != 0.0).collect();
        assert_eq!(nz.len(), 20);
        assert!(nz.iter().all(|&x| x == 3.0));
        assert_eq!(rec.wire_bytes, 20 * 8);
    }

    #[test]
    fn ef_conserves_total_mass() {
        // Over many steps every coordinate is eventually sampled; total
        // update mass approaches total gradient mass.
        let g = vec![1.0f32; 50];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = RandomK::new(0.2, 1, 9);
        let steps = 200u64;
        let mut total = 0.0f64;
        for step in 0..steps {
            let (u, _) = s.round(0, step, &refs);
            total += u.iter().map(|&x| x as f64).sum::<f64>();
        }
        let fed = steps as f64 * 50.0;
        assert!((total / fed - 1.0).abs() < 0.05, "mass ratio {}", total / fed);
    }
}
