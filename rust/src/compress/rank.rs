//! The canonical per-rank compression API and the wire codec.
//!
//! Every scheme is implemented *once*, as the two halves a cluster rank
//! actually executes:
//!
//! * [`RankCompressor::compress_into`] — runs on the rank's *compute*
//!   thread, right after the tensor's gradient is produced: error-feedback
//!   accumulate + wire-format encode, written **directly into the
//!   caller-provided frame buffer** (no intermediate `Payload`), touching
//!   only this rank's residuals.
//! * [`RankCombiner::combine_into`] — runs on the rank's *comm* thread
//!   after the frame exchange: fold every rank's encoded frame (rank-major
//!   order) into the caller-provided dense update. Deterministic, identical
//!   bits on every rank. Dense / half / sign / sparse frames are combined
//!   **decode-free**: the fold reads `f32::from_le_bytes` (etc.) straight
//!   off the frame bytes without materializing a `Payload`.
//!
//! Both halves borrow a per-rank [`Scratch`] arena for temporaries, so the
//! steady-state hot path (after the first full step has warmed every
//! buffer to its high-water capacity) performs **zero heap allocations**
//! for covap / topk / signsgd / fp16 and the dense baseline — asserted by
//! the allocation-counting `perf_hotpath` bench. (DGC and Random-k reuse
//! the same scratch but have data-dependent selection sizes that can grow
//! past the high-water mark, and the replicated schemes allocate
//! internally — the bench reports them without asserting.) See DESIGN.md
//! §7 "Buffer lifecycle" for the ownership rules.
//!
//! The replicated [`Scheme`](super::Scheme) trait the analytic backend
//! consumes is *not* a second implementation: it is the generic
//! [`LockstepDriver`](super::LockstepDriver) adapter, which drives P
//! compressor/combiner pairs in sequence over the per-worker gradients.
//! One implementation, two drivers — bitwise parity between the analytic
//! and threaded backends is structural, not a property-tested convention.
//!
//! Schemes whose round is inherently global (PowerSGD's dependent
//! two-round power iteration, Ok-topk's global threshold) implement
//! [`ReplicatedScheme`] instead: each rank ships its raw gradient and runs
//! an identical replica of the full scheme on the gathered set via
//! `ReplicaCombiner` — deterministic, hence still bitwise-identical
//! across ranks, at the cost of dense in-process traffic (the CommRecord
//! keeps charging the scheme's true encoded wire volume; see DESIGN.md §4).
//!
//! # Wire format
//!
//! [`Payload::encode_into`] / [`Payload::decode`] give every payload a real
//! byte-level frame — the thing `exec::ring` moves and the thing
//! `CommRecord::wire_bytes` measures. All integers are little-endian;
//! `varint` is LEB128 (7 data bits per byte, low group first):
//!
//! ```text
//! Empty  -> zero-length frame          (a dropped tensor sends nothing)
//! Dense  -> [0x01][varint n][n x f32]
//! Sparse -> [0x02][varint k][k x u32 idx][k x f32 val]
//! Sign   -> [0x03][varint n][f32 scale][ceil(n/8) sign bytes, bit i = i-th sign]
//! Half   -> [0x04][varint n][n x u16]
//! ```
//!
//! `decode(encode(p)) == p` bitwise for every variant (property-tested
//! below, including `n % 64 != 0` sign bitmaps and zero-length payloads),
//! and [`Payload::encoded_len`] — the arithmetic the accounting uses —
//! always equals the frame length `encode_into` produces. The
//! `Payload`-level `compress`/`combine` wrappers (provided trait methods)
//! are retained as the **property-test oracle** for the frame-level hot
//! path: they route through the same codec, so the lockstep parity test
//! pins decode-free combining against decode-then-fold bit for bit.

use std::time::Instant;

use super::{CollectiveOp, CommRecord, SchemeKind};
use crate::compress::{baseline, covap, fp16, oktopk, powersgd, randomk, signsgd, topk};

/// A wire-format payload one rank contributes to the collective.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Nothing transmitted (COVAP dropped tensor).
    Empty,
    /// Dense f32 (baseline, COVAP kept tensors, replicated raw gradients).
    Dense(Vec<f32>),
    /// (index, value) pairs — worker-specific sparse selections.
    Sparse { idx: Vec<u32>, val: Vec<f32> },
    /// 1-bit signs + one scale (EFsignSGD).
    Sign { scale: f32, bits: Vec<u64>, n: usize },
    /// IEEE half-precision quantization.
    Half(Vec<u16>),
}

pub(crate) const TAG_DENSE: u8 = 0x01;
pub(crate) const TAG_SPARSE: u8 = 0x02;
pub(crate) const TAG_SIGN: u8 = 0x03;
pub(crate) const TAG_HALF: u8 = 0x04;

/// Codec failure (truncated, oversized or malformed frame).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeError(pub &'static str);

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "payload decode error: {}", self.0)
    }
}

impl std::error::Error for DecodeError {}

/// Encoded size of a LEB128 varint.
pub fn varint_len(mut x: u64) -> usize {
    let mut len = 1;
    while x >= 0x80 {
        x >>= 7;
        len += 1;
    }
    len
}

// xtask: hot-path
pub(crate) fn write_varint(out: &mut Vec<u8>, mut x: u64) {
    while x >= 0x80 {
        out.push((x as u8 & 0x7f) | 0x80);
        x >>= 7;
    }
    out.push(x as u8);
}

/// Frame length of a dense f32 payload of `n` elements.
pub fn dense_frame_len(n: usize) -> usize {
    1 + varint_len(n as u64) + 4 * n
}

/// Frame length of a sparse payload of `k` (index, value) pairs.
pub fn sparse_frame_len(k: usize) -> usize {
    1 + varint_len(k as u64) + 8 * k
}

/// Frame length of a sign payload over `n` elements.
pub fn sign_frame_len(n: usize) -> usize {
    1 + varint_len(n as u64) + 4 + n.div_ceil(8)
}

/// Frame length of a half-precision payload of `n` elements.
pub fn half_frame_len(n: usize) -> usize {
    1 + varint_len(n as u64) + 2 * n
}

// ---- encode-into helpers (shared by Payload and the scheme compressors) ----

/// Clear `out`, reserve the exact frame length and write `[tag][varint n]`.
/// Scheme compressors stream their body bytes directly after this header,
/// so the whole compress+encode is one pass with no intermediate `Payload`.
// xtask: hot-path
pub(crate) fn frame_header(out: &mut Vec<u8>, tag: u8, n: usize, frame_len: usize) {
    out.clear();
    out.reserve(frame_len);
    out.push(tag);
    write_varint(out, n as u64);
}

/// Encode a dense f32 frame into `out` (cleared first).
// xtask: hot-path
pub(crate) fn encode_dense_into(v: &[f32], out: &mut Vec<u8>) {
    frame_header(out, TAG_DENSE, v.len(), dense_frame_len(v.len()));
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode a sparse (idx, val) frame into `out` (cleared first).
// xtask: hot-path
pub(crate) fn encode_sparse_into(idx: &[u32], val: &[f32], out: &mut Vec<u8>) {
    debug_assert_eq!(idx.len(), val.len());
    frame_header(out, TAG_SPARSE, idx.len(), sparse_frame_len(idx.len()));
    for i in idx {
        out.extend_from_slice(&i.to_le_bytes());
    }
    for x in val {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode a sign frame into `out` (cleared first).
///
/// Word-width note (the packing audit): `bits` packs sign `i` into u64
/// word `i / 64` at bit `i % 64`, LSB-first. The wire bitmap is
/// byte-granular, and byte `b` of the bitmap is byte `b % 8` of word
/// `b / 8` — hence the shift `(b % 8) * 8` below, which extracts a *byte*
/// (8-bit group), not a bit. Both layouts are little-endian LSB-first, so
/// sign `i` lands in frame byte `i / 8` at bit `i % 8`; decode rebuilds
/// the identical u64 words. The expression is only correct for 64-bit
/// bitmap words (8 bytes per word); `sign_packing_crosses_word_boundaries`
/// pins the cross-word layout at n = 63, 64, 65.
// xtask: hot-path
pub(crate) fn encode_sign_into(scale: f32, bits: &[u64], n: usize, out: &mut Vec<u8>) {
    frame_header(out, TAG_SIGN, n, sign_frame_len(n));
    out.extend_from_slice(&scale.to_le_bytes());
    for b in 0..n.div_ceil(8) {
        out.push((bits[b / 8] >> ((b % 8) * 8)) as u8);
    }
}

/// Encode a half-precision frame into `out` (cleared first).
// xtask: hot-path
pub(crate) fn encode_half_into(v: &[u16], out: &mut Vec<u8>) {
    frame_header(out, TAG_HALF, v.len(), half_frame_len(v.len()));
    for h in v {
        out.extend_from_slice(&h.to_le_bytes());
    }
}

/// Sequential little-endian reader over a frame.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        let end = self.pos.checked_add(n).ok_or(DecodeError("length overflow"))?;
        if end > self.buf.len() {
            return Err(DecodeError("truncated frame"));
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn varint(&mut self) -> Result<u64, DecodeError> {
        let mut x = 0u64;
        let mut shift = 0u32;
        loop {
            let b = *self.take(1)?.first().unwrap();
            if shift >= 64 || (shift == 63 && b > 1) {
                return Err(DecodeError("varint overflow"));
            }
            x |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(x);
            }
            shift += 7;
        }
    }

    /// A varint element count, sanity-checked against the bytes that must
    /// still follow (`stride` bytes per element) so a corrupt frame cannot
    /// trigger a huge allocation.
    fn count(&mut self, stride: usize) -> Result<usize, DecodeError> {
        let n = self.varint()? as usize;
        let need = n.checked_mul(stride).ok_or(DecodeError("length overflow"))?;
        if need > self.buf.len() - self.pos {
            return Err(DecodeError("count exceeds frame"));
        }
        Ok(n)
    }
}

/// Split a non-empty encoded frame into `(tag, element count, body)`
/// without materializing a `Payload` — the entry point of decode-free
/// combining. Panics on malformed frames: ring frames come from our own
/// codec ([`Payload::decode`] is the lenient path for untrusted input).
// xtask: hot-path
fn split_frame(frame: &[u8]) -> (u8, usize, &[u8]) {
    assert!(!frame.is_empty(), "cannot split an Empty frame");
    let tag = frame[0];
    let mut r = Reader { buf: frame, pos: 1 };
    let n = r.varint().expect("corrupt ring frame: bad varint") as usize;
    (tag, n, &frame[r.pos..])
}

impl Payload {
    /// Serialize into `out` (cleared first; capacity is reused across
    /// calls, so steady-state re-encodes allocate nothing once the buffer
    /// reached its high-water size). The resulting frame length always
    /// equals [`Payload::encoded_len`].
    // xtask: hot-path
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Payload::Empty => out.clear(),
            Payload::Dense(v) => encode_dense_into(v, out),
            Payload::Sparse { idx, val } => encode_sparse_into(idx, val, out),
            Payload::Sign { scale, bits, n } => encode_sign_into(*scale, bits, *n, out),
            Payload::Half(v) => encode_half_into(v, out),
        }
        debug_assert_eq!(out.len(), self.encoded_len());
    }

    /// Serialize to a fresh frame — [`Payload::encode_into`] into a new
    /// buffer. Convenience for tests and one-shot callers; the hot path
    /// encodes into reusable buffers.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut out);
        out
    }

    /// Parse a frame produced by [`Payload::encode_into`]. Bitwise-exact
    /// inverse.
    pub fn decode(buf: &[u8]) -> Result<Payload, DecodeError> {
        if buf.is_empty() {
            return Ok(Payload::Empty);
        }
        let tag = buf[0];
        let mut r = Reader { buf, pos: 1 };
        let p = match tag {
            TAG_DENSE => {
                let n = r.count(4)?;
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    let b: [u8; 4] = r.take(4)?.try_into().unwrap();
                    v.push(f32::from_le_bytes(b));
                }
                Payload::Dense(v)
            }
            TAG_SPARSE => {
                let k = r.count(8)?;
                let mut idx = Vec::with_capacity(k);
                for _ in 0..k {
                    let b: [u8; 4] = r.take(4)?.try_into().unwrap();
                    idx.push(u32::from_le_bytes(b));
                }
                let mut val = Vec::with_capacity(k);
                for _ in 0..k {
                    let b: [u8; 4] = r.take(4)?.try_into().unwrap();
                    val.push(f32::from_le_bytes(b));
                }
                Payload::Sparse { idx, val }
            }
            TAG_SIGN => {
                let n = r.varint()? as usize;
                let b: [u8; 4] = r.take(4)?.try_into().unwrap();
                let scale = f32::from_le_bytes(b);
                let bytes = r.take(n.div_ceil(8))?;
                let mut bits = vec![0u64; n.div_ceil(64)];
                for (b, &byte) in bytes.iter().enumerate() {
                    bits[b / 8] |= (byte as u64) << ((b % 8) * 8);
                }
                // clear padding bits beyond n (a well-formed encoder never
                // sets them; a corrupt frame must not smuggle them in)
                if n % 64 != 0 {
                    if let Some(last) = bits.last_mut() {
                        *last &= (1u64 << (n % 64)) - 1;
                    }
                }
                Payload::Sign { scale, bits, n }
            }
            TAG_HALF => {
                let n = r.count(2)?;
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    let b: [u8; 2] = r.take(2)?.try_into().unwrap();
                    v.push(u16::from_le_bytes(b));
                }
                Payload::Half(v)
            }
            _ => return Err(DecodeError("unknown variant tag")),
        };
        if r.pos != buf.len() {
            return Err(DecodeError("trailing bytes"));
        }
        Ok(p)
    }

    /// Bytes this payload occupies on the wire — exactly the frame length
    /// [`Payload::encode_into`] produces, computed without materializing
    /// the frame.
    pub fn encoded_len(&self) -> usize {
        match self {
            Payload::Empty => 0,
            Payload::Dense(v) => dense_frame_len(v.len()),
            Payload::Sparse { idx, .. } => sparse_frame_len(idx.len()),
            Payload::Sign { n, .. } => sign_frame_len(*n),
            Payload::Half(v) => half_frame_len(v.len()),
        }
    }
}

/// Bitwise equality (f32s compared by bit pattern, so `-0.0 != 0.0` and
/// NaN payloads compare equal to themselves — what the codec round-trip
/// property needs).
impl PartialEq for Payload {
    fn eq(&self, other: &Payload) -> bool {
        fn f32s_eq(a: &[f32], b: &[f32]) -> bool {
            a.len() == b.len()
                && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
        }
        match (self, other) {
            (Payload::Empty, Payload::Empty) => true,
            (Payload::Dense(a), Payload::Dense(b)) => f32s_eq(a, b),
            (
                Payload::Sparse { idx: ia, val: va },
                Payload::Sparse { idx: ib, val: vb },
            ) => ia == ib && f32s_eq(va, vb),
            (
                Payload::Sign { scale: sa, bits: ba, n: na },
                Payload::Sign { scale: sb, bits: bb, n: nb },
            ) => sa.to_bits() == sb.to_bits() && ba == bb && na == nb,
            (Payload::Half(a), Payload::Half(b)) => a == b,
            _ => false,
        }
    }
}

// ---- the per-rank scratch arena --------------------------------------------

/// Reusable per-rank temporaries for the compress/combine hot path.
///
/// One `Scratch` belongs to one driver thread (a rank's compute thread, a
/// rank's comm thread, or the lockstep driver); it is threaded into
/// [`RankCompressor::compress_into`] / [`RankCombiner::combine_into`] by
/// the caller. Buffers carry **no state between calls** — every method
/// clears what it uses — they only carry *capacity*, which grows to the
/// largest tensor seen and then stays put, making the steady state
/// allocation-free. Long-lived per-tensor state (EF residuals, warm-started
/// factors) lives inside the compressor/combiner objects instead, keyed by
/// tensor slot.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Error-feedback accumulate buffer (`g + c·r`).
    pub(crate) acc: Vec<f32>,
    /// Magnitude buffer for top-k selection / DGC threshold sampling.
    pub(crate) mags: Vec<f32>,
    /// Sparse selection indices.
    pub(crate) idx: Vec<u32>,
    /// Sparse selection values.
    pub(crate) val: Vec<f32>,
    /// Sign bitmap words.
    pub(crate) bits: Vec<u64>,
    /// Random-k shared index draw.
    pub(crate) sample: Vec<usize>,
    /// Per-worker dense gradients decoded for replicated schemes.
    pub(crate) grads: Vec<Vec<f32>>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }
}

/// One tensor round's outcome on a rank: the (replicated) dense update plus
/// the accounting record the simulator prices. Produced by the
/// `Payload`-level [`RankCombiner::combine`] oracle wrapper; the hot path
/// writes into caller-provided buffers instead.
#[derive(Debug, Clone)]
pub struct RankRound {
    pub update: Vec<f32>,
    pub record: CommRecord,
}

/// The compute-thread half: encode this rank's gradient.
pub trait RankCompressor: Send {
    fn name(&self) -> &'static str;

    /// Compress `grad` for communication tensor `tensor` at `step` and
    /// write the encoded wire frame into `frame` (cleared first; a frame
    /// left empty means `Payload::Empty` — nothing transmitted). Uses only
    /// this rank's error-feedback residuals plus `scratch` temporaries;
    /// steady state allocates nothing once buffers are warm.
    // &mut Vec (not &mut [u8]): implementors resize the frame.
    #[allow(clippy::ptr_arg)]
    fn compress_into(
        &mut self,
        tensor: usize,
        step: u64,
        grad: &[f32],
        scratch: &mut Scratch,
        frame: &mut Vec<u8>,
    );

    /// `Payload`-level convenience (tests, one-shot callers): run
    /// [`RankCompressor::compress_into`] with throwaway buffers and decode
    /// the frame back. Bitwise-identical to the frame the hot path ships
    /// (`decode ∘ encode = id` is property-tested).
    fn compress(&mut self, tensor: usize, step: u64, grad: &[f32]) -> Payload {
        let mut scratch = Scratch::new();
        let mut frame = Vec::new();
        self.compress_into(tensor, step, grad, &mut scratch, &mut frame);
        Payload::decode(&frame).expect("self-encoded frame must decode")
    }

    /// True when the backward pass must wait for this tensor's combine
    /// result before continuing (Ok-topk rendezvous semantics).
    fn data_dependency(&self) -> bool {
        false
    }

    /// Re-shard hook (§III.C/D): migrate this compressor to `kind` while
    /// remapping long-lived per-tensor state (EF residuals) from the `old`
    /// tensor layout to `new`. Both layouts are slot tables of
    /// `(flat offset, element count)` in the same flat parameter space,
    /// indexed by communication-tensor id. Returns true when the
    /// transition was handled in place — accumulated state survives —
    /// false when the caller should rebuild the compressor from scratch
    /// (stateless schemes, cross-scheme swaps).
    fn reconfigure(
        &mut self,
        _kind: &SchemeKind,
        _old: &[(usize, usize)],
        _new: &[(usize, usize)],
    ) -> bool {
        false
    }

    /// Elastic-membership hook: flatten this rank's long-lived per-tensor
    /// state (EF residuals) over the slot `layout` into one dense vector in
    /// flat parameter space. `None` (the default) means "no portable
    /// state" — stateless schemes hand nothing over when their rank leaves
    /// the world. The inverse is [`RankCompressor::import_residuals`];
    /// `import(export(x)) = x` bitwise for layouts covering the state.
    fn export_residuals(&self, _layout: &[(usize, usize)]) -> Option<Vec<f32>> {
        None
    }

    /// Elastic-membership hook: adopt `flat` (a vector in flat parameter
    /// space, e.g. a departed rank's exported residuals folded into this
    /// rank's) as this compressor's per-tensor state, sliced by `layout`.
    /// Returns false (the default) when the scheme carries no portable
    /// state and the import was ignored.
    fn import_residuals(&mut self, _flat: &[f32], _layout: &[(usize, usize)]) -> bool {
        false
    }

    fn reset(&mut self);
}

/// The comm-thread half: fold all ranks' frames into the dense update.
/// Must be deterministic and produce identical bits on every rank.
pub trait RankCombiner: Send {
    fn name(&self) -> &'static str;

    /// Fold the rank-major encoded `frames` (index = rank id) into
    /// `update` (cleared first; resized to `n`, or left empty for a
    /// dropped tensor = "all zeros"). `n` is the tensor's element count;
    /// `compress_s` is the measured compression wall time forwarded into
    /// the returned CommRecord. Dense/half/sign/sparse frames are folded
    /// decode-free; steady state allocates nothing once `update` and
    /// `scratch` are warm.
    // &mut Vec (not &mut [f32]): implementors resize the update.
    #[allow(clippy::too_many_arguments, clippy::ptr_arg)]
    fn combine_into(
        &mut self,
        tensor: usize,
        step: u64,
        n: usize,
        frames: &[Vec<u8>],
        scratch: &mut Scratch,
        update: &mut Vec<f32>,
        compress_s: f64,
    ) -> CommRecord;

    /// `Payload`-level oracle wrapper: encode `payloads` through the codec
    /// and fold the frames. The parity tests drive this against the
    /// frame-level path, pinning decode-free combining bit for bit.
    fn combine(
        &mut self,
        tensor: usize,
        step: u64,
        n: usize,
        payloads: &[Payload],
        compress_s: f64,
    ) -> RankRound {
        let frames: Vec<Vec<u8>> = payloads.iter().map(|p| p.encode()).collect();
        let mut scratch = Scratch::new();
        let mut update = Vec::new();
        let record =
            self.combine_into(tensor, step, n, &frames, &mut scratch, &mut update, compress_s);
        RankRound { update, record }
    }

    fn reset(&mut self);
}

/// A globally-coupled scheme that cannot be split into independent rank
/// halves: one deterministic round over the gathered per-worker gradients.
/// Run as an identical replica on every rank by `ReplicaCombiner` —
/// replication *is* its execution strategy, not a second implementation.
pub trait ReplicatedScheme: Send {
    fn name(&self) -> &'static str;
    fn round(&mut self, tensor: usize, step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord);
    fn reset(&mut self);
}

/// Build the (compressor, combiner) pair for ONE rank. Call once per rank
/// with identical `(kind, workers, seed)` so the replicas agree.
pub fn build_rank_pair(
    kind: &SchemeKind,
    workers: usize,
    seed: u64,
) -> (Box<dyn RankCompressor>, Box<dyn RankCombiner>) {
    match kind.clone() {
        SchemeKind::Baseline => (Box::new(baseline::DenseCompressor), Box::new(MeanCombiner)),
        SchemeKind::Covap { interval, ef } => {
            (Box::new(covap::CovapCompressor::new(interval, ef)), Box::new(MeanCombiner))
        }
        // adaptive mode warms up dense: interval 1 until the engine's
        // controller concludes and re-shards (the same compressor then
        // migrates in place via `reconfigure`, keeping its residuals)
        SchemeKind::CovapAuto { ef } => {
            (Box::new(covap::CovapCompressor::new(1, ef)), Box::new(MeanCombiner))
        }
        SchemeKind::Fp16 => (Box::new(fp16::HalfCompressor), Box::new(MeanCombiner)),
        SchemeKind::TopK { ratio } => {
            (Box::new(topk::TopKCompressor::new(ratio)), Box::new(SparseCombiner))
        }
        SchemeKind::Dgc { ratio } => {
            (Box::new(topk::DgcCompressor::new(ratio, seed)), Box::new(SparseCombiner))
        }
        SchemeKind::RandomK { ratio } => {
            (Box::new(randomk::RandomKCompressor::new(ratio, seed)), Box::new(SparseCombiner))
        }
        SchemeKind::EfSignSgd => {
            (Box::new(signsgd::SignCompressor::new()), Box::new(SignCombiner))
        }
        SchemeKind::PowerSgd { rank } => (
            Box::new(RawCompressor { dep: false }),
            Box::new(ReplicaCombiner {
                inner: Box::new(powersgd::PowerSgd::new(rank, workers, seed)),
            }),
        ),
        SchemeKind::OkTopk { ratio } => (
            Box::new(RawCompressor { dep: true }),
            Box::new(ReplicaCombiner { inner: Box::new(oktopk::OkTopk::new(ratio, workers)) }),
        ),
    }
}

/// Max encoded frame length over the gathered frames — the per-rank wire
/// volume the accounting charges (frames are identical sizes for
/// dense/half/sign schemes; sparse selections may differ per rank, where
/// the max is the conservative per-rank bound the old model also used).
// xtask: hot-path
fn max_frame_len(frames: &[Vec<u8>]) -> usize {
    frames.iter().map(|f| f.len()).max().unwrap_or(0)
}

// ---- shared wire-format combiners -----------------------------------------

/// Mean over dense-decodable frames in rank order (Dense and Half frames),
/// folded straight off the frame bytes. Serves every AllReduce-style mean
/// scheme: baseline, COVAP, FP16.
///
/// `compress_s` accounting: a pure Dense mean is the collective's own
/// arithmetic (in-network on real hardware) and charges nothing extra; a
/// fold involving Half frames is dequantization, so its measured wall time
/// is added to the record as the scheme's decompression cost.
pub(crate) struct MeanCombiner;

impl RankCombiner for MeanCombiner {
    fn name(&self) -> &'static str {
        "mean"
    }

    #[allow(clippy::too_many_arguments)]
    // xtask: hot-path
    fn combine_into(
        &mut self,
        _tensor: usize,
        _step: u64,
        n: usize,
        frames: &[Vec<u8>],
        _scratch: &mut Scratch,
        update: &mut Vec<f32>,
        compress_s: f64,
    ) -> CommRecord {
        if frames.iter().all(|f| f.is_empty()) {
            // COVAP dropped tensor: empty update = "all zeros".
            update.clear();
            return CommRecord::dense(0, compress_s);
        }
        let t0 = Instant::now();
        update.clear();
        update.resize(n, 0.0);
        let mut any_half = false;
        for f in frames {
            let (tag, fe, body) = split_frame(f);
            match tag {
                TAG_DENSE => {
                    debug_assert_eq!(fe, n);
                    for (u, b) in update.iter_mut().zip(body.chunks_exact(4)) {
                        *u += f32::from_le_bytes(b.try_into().unwrap());
                    }
                }
                TAG_HALF => {
                    debug_assert_eq!(fe, n);
                    any_half = true;
                    for (u, b) in update.iter_mut().zip(body.chunks_exact(2)) {
                        *u += fp16::f16_to_f32(u16::from_le_bytes(b.try_into().unwrap()));
                    }
                }
                t => panic!("mean combiner got frame tag {t:#04x}"),
            }
        }
        let inv = 1.0 / frames.len() as f32;
        for u in update.iter_mut() {
            *u *= inv;
        }
        let decode_s = if any_half { t0.elapsed().as_secs_f64() } else { 0.0 };
        CommRecord::dense(max_frame_len(frames), compress_s + decode_s)
    }

    fn reset(&mut self) {}
}

/// Rank-order mean over sparse frames: `update[i] += v / P` per worker
/// frame, reading the (idx, val) sections straight off the bytes. Serves
/// Top-k, DGC and Random-k. The scatter-add is the sparse format's
/// decompression, so its measured wall time joins `compress_s`.
pub(crate) struct SparseCombiner;

impl RankCombiner for SparseCombiner {
    fn name(&self) -> &'static str {
        "sparse-gather"
    }

    #[allow(clippy::too_many_arguments)]
    // xtask: hot-path
    fn combine_into(
        &mut self,
        _tensor: usize,
        _step: u64,
        n: usize,
        frames: &[Vec<u8>],
        _scratch: &mut Scratch,
        update: &mut Vec<f32>,
        compress_s: f64,
    ) -> CommRecord {
        let t0 = Instant::now();
        update.clear();
        update.resize(n, 0.0);
        let inv = 1.0 / frames.len() as f32;
        for f in frames {
            let (tag, k, body) = split_frame(f);
            assert_eq!(tag, TAG_SPARSE, "sparse combiner got frame tag {tag:#04x}");
            debug_assert_eq!(body.len(), 8 * k);
            let (idx_b, val_b) = body.split_at(4 * k);
            for (ib, vb) in idx_b.chunks_exact(4).zip(val_b.chunks_exact(4)) {
                let i = u32::from_le_bytes(ib.try_into().unwrap()) as usize;
                let v = f32::from_le_bytes(vb.try_into().unwrap());
                update[i] += v * inv;
            }
        }
        let compress_s = compress_s + t0.elapsed().as_secs_f64();
        CommRecord {
            wire_bytes: max_frame_len(frames),
            collective: CollectiveOp::AllGather,
            rounds: 1,
            sync_rounds: 0,
            compress_s,
            data_dependency: false,
            levels: crate::comm::LevelBytes::default(),
        }
    }

    fn reset(&mut self) {}
}

/// Rank-order mean over sign frames (EFsignSGD), reading the per-element
/// sign bits straight off the frame bitmap. The per-element unpack is this
/// scheme's decompression — the cost the paper's Table VII blames — so its
/// measured wall time joins `compress_s`.
pub(crate) struct SignCombiner;

impl RankCombiner for SignCombiner {
    fn name(&self) -> &'static str {
        "sign-gather"
    }

    #[allow(clippy::too_many_arguments)]
    // xtask: hot-path
    fn combine_into(
        &mut self,
        _tensor: usize,
        _step: u64,
        n: usize,
        frames: &[Vec<u8>],
        _scratch: &mut Scratch,
        update: &mut Vec<f32>,
        compress_s: f64,
    ) -> CommRecord {
        let t0 = Instant::now();
        update.clear();
        update.resize(n, 0.0);
        let inv = 1.0 / frames.len() as f32;
        for f in frames {
            let (tag, pn, body) = split_frame(f);
            assert_eq!(tag, TAG_SIGN, "sign combiner got frame tag {tag:#04x}");
            debug_assert_eq!(pn, n);
            let scale = f32::from_le_bytes(body[..4].try_into().unwrap());
            let bitmap = &body[4..];
            for (i, u) in update.iter_mut().enumerate() {
                let neg = bitmap[i / 8] >> (i % 8) & 1 == 1;
                let v = if neg { -scale } else { scale };
                *u += v * inv;
            }
        }
        let compress_s = compress_s + t0.elapsed().as_secs_f64();
        CommRecord {
            wire_bytes: max_frame_len(frames),
            collective: CollectiveOp::AllGather,
            rounds: 1,
            sync_rounds: 0,
            compress_s,
            data_dependency: false,
            levels: crate::comm::LevelBytes::default(),
        }
    }

    fn reset(&mut self) {}
}

// ---- replicated execution (PowerSGD / Ok-topk) ----------------------------

/// Ships the raw gradient for replicated execution.
pub(crate) struct RawCompressor {
    pub(crate) dep: bool,
}

impl RankCompressor for RawCompressor {
    fn name(&self) -> &'static str {
        "raw"
    }

    // xtask: hot-path
    fn compress_into(
        &mut self,
        _tensor: usize,
        _step: u64,
        grad: &[f32],
        _scratch: &mut Scratch,
        frame: &mut Vec<u8>,
    ) {
        encode_dense_into(grad, frame);
    }

    fn data_dependency(&self) -> bool {
        self.dep
    }

    fn reset(&mut self) {}
}

/// Every rank holds an identical replica of a [`ReplicatedScheme`] and
/// feeds it the gathered raw gradients (decoded into scratch buffers) —
/// deterministic, hence identical state and bitwise-identical output on
/// every rank and vs the analytic backend. The record keeps the scheme's
/// own (encoded) wire accounting.
pub(crate) struct ReplicaCombiner {
    pub(crate) inner: Box<dyn ReplicatedScheme>,
}

impl RankCombiner for ReplicaCombiner {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    #[allow(clippy::too_many_arguments)]
    fn combine_into(
        &mut self,
        tensor: usize,
        step: u64,
        _n: usize,
        frames: &[Vec<u8>],
        scratch: &mut Scratch,
        update: &mut Vec<f32>,
        _compress_s: f64,
    ) -> CommRecord {
        let w = frames.len();
        if scratch.grads.len() < w {
            scratch.grads.resize_with(w, Vec::new);
        }
        for (g, f) in scratch.grads.iter_mut().zip(frames.iter()) {
            let (tag, fe, body) = split_frame(f);
            assert_eq!(tag, TAG_DENSE, "replica combiner got frame tag {tag:#04x}");
            debug_assert_eq!(body.len(), 4 * fe);
            g.clear();
            g.extend(
                body.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())),
            );
        }
        let refs: Vec<&[f32]> = scratch.grads[..w].iter().map(|g| g.as_slice()).collect();
        let (u, record) = self.inner.round(tensor, step, &refs);
        update.clear();
        update.extend_from_slice(&u);
        record
    }

    fn reset(&mut self) {
        self.inner.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covap::EfScheduler;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Drive P rank pairs in lockstep through the **frame-level** hot path,
    /// exactly as the threaded executor does across threads: persistent
    /// scratch + frame buffers, `compress_into` / `combine_into`.
    fn lockstep_round(
        pairs: &mut [(Box<dyn RankCompressor>, Box<dyn RankCombiner>)],
        scratch: &mut Scratch,
        frames: &mut Vec<Vec<u8>>,
        tensor: usize,
        step: u64,
        grads: &[&[f32]],
    ) -> Vec<RankRound> {
        frames.resize_with(grads.len(), Vec::new);
        for (((c, _), g), frame) in
            pairs.iter_mut().zip(grads.iter()).zip(frames.iter_mut())
        {
            c.compress_into(tensor, step, g, scratch, frame);
        }
        let n = grads[0].len();
        pairs
            .iter_mut()
            .map(|(_, cb)| {
                let mut update = Vec::new();
                let record =
                    cb.combine_into(tensor, step, n, frames, scratch, &mut update, 0.0);
                RankRound { update, record }
            })
            .collect()
    }

    /// THE parity guarantee: for every scheme, independently-driven rank
    /// pairs (frame-level hot path) match the replicated `Scheme::round`
    /// (the lockstep driver) bit-for-bit across shapes, steps and multiple
    /// tensors, and every rank agrees with every other.
    #[test]
    fn rank_path_bitwise_matches_scheme_round() {
        for kind in SchemeKind::evaluation_set() {
            prop::check(kind.label(), 0xEC5, 6, |rng: &mut Rng| {
                let workers = 1 + rng.below(4);
                let n = 16 + rng.below(512);
                let seed = 0xABCD;
                let mut scheme = kind.build(workers, seed);
                let mut pairs: Vec<_> =
                    (0..workers).map(|_| build_rank_pair(&kind, workers, seed)).collect();
                let mut scratch = Scratch::new();
                let mut frames: Vec<Vec<u8>> = Vec::new();
                for step in 0..6u64 {
                    for tensor in 0..2usize {
                        let gs: Vec<Vec<f32>> =
                            (0..workers).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
                        let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
                        let (want, want_rec) = scheme.round(tensor, step, &refs);
                        let rounds = lockstep_round(
                            &mut pairs,
                            &mut scratch,
                            &mut frames,
                            tensor,
                            step,
                            &refs,
                        );
                        for (r, rr) in rounds.iter().enumerate() {
                            assert_eq!(
                                rr.update, want,
                                "{} rank {r} diverged at step {step} tensor {tensor}",
                                kind.label()
                            );
                            assert_eq!(
                                rr.record.wire_bytes, want_rec.wire_bytes,
                                "{} wire accounting rank {r}",
                                kind.label()
                            );
                            assert_eq!(rr.record.collective, want_rec.collective);
                        }
                    }
                }
            });
        }
    }

    /// Decode-free combining vs the decoded oracle: folding the frame
    /// bytes directly must equal decoding every payload and folding the
    /// decoded values with the same arithmetic — bit for bit.
    #[test]
    fn decode_free_combining_matches_decoded_oracle() {
        let mut rng = Rng::seed(0xDECF);
        let n = 97usize; // odd, n % 8 != 0, n % 64 != 0
        let workers = 3;

        // Mean over dense + half frames.
        let dense: Vec<Payload> = (0..workers)
            .map(|_| Payload::Dense(prop::vec_f32(&mut rng, n, 1.0)))
            .collect();
        let halves: Vec<Payload> = (0..workers)
            .map(|_| Payload::Half((0..n).map(|_| rng.below(1 << 16) as u16).collect()))
            .collect();
        for payloads in [dense, halves] {
            let got = MeanCombiner.combine(0, 0, n, &payloads, 0.0);
            let mut want = vec![0.0f32; n];
            for p in &payloads {
                match p {
                    Payload::Dense(g) => {
                        for (u, &x) in want.iter_mut().zip(g.iter()) {
                            *u += x;
                        }
                    }
                    Payload::Half(h) => {
                        for (u, &b) in want.iter_mut().zip(h.iter()) {
                            *u += fp16::f16_to_f32(b);
                        }
                    }
                    _ => unreachable!(),
                }
            }
            let inv = 1.0 / workers as f32;
            for u in &mut want {
                *u *= inv;
            }
            assert_eq!(got.update, want);
        }

        // Sparse scatter-add.
        let sparse: Vec<Payload> = (0..workers)
            .map(|_| {
                let k = 1 + rng.below(n);
                let idx: Vec<u32> = (0..k).map(|_| rng.below(n) as u32).collect();
                Payload::Sparse { idx, val: prop::vec_f32(&mut rng, k, 1.0) }
            })
            .collect();
        let got = SparseCombiner.combine(0, 0, n, &sparse, 0.0);
        let mut want = vec![0.0f32; n];
        let inv = 1.0 / workers as f32;
        for p in &sparse {
            let Payload::Sparse { idx, val } = p else { unreachable!() };
            for (&i, &v) in idx.iter().zip(val.iter()) {
                want[i as usize] += v * inv;
            }
        }
        assert_eq!(got.update, want);

        // Sign unpack.
        let signs: Vec<Payload> = (0..workers)
            .map(|_| {
                let g = prop::vec_f32(&mut rng, n, 1.0);
                let bits = crate::compress::signsgd::pack_signs(&g);
                Payload::Sign { scale: rng.next_f32(), bits, n }
            })
            .collect();
        let got = SignCombiner.combine(0, 0, n, &signs, 0.0);
        let mut want = vec![0.0f32; n];
        for p in &signs {
            let Payload::Sign { scale, bits, .. } = p else { unreachable!() };
            for (i, u) in want.iter_mut().enumerate() {
                let neg = bits[i / 64] >> (i % 64) & 1 == 1;
                let v = if neg { -*scale } else { *scale };
                *u += v * inv;
            }
        }
        assert_eq!(got.update, want);
    }

    #[test]
    fn covap_drop_rounds_are_empty_and_flush() {
        let kind = SchemeKind::Covap { interval: 3, ef: EfScheduler::constant(1.0) };
        let (mut c, mut cb) = build_rank_pair(&kind, 1, 7);
        let g = vec![1.0f32; 8];
        // tensor 0 kept at steps 0 and 3
        let p0 = c.compress(0, 0, &g);
        assert!(matches!(p0, Payload::Dense(_)));
        for step in 1..3 {
            let p = c.compress(0, step, &g);
            assert!(matches!(p, Payload::Empty));
            let r = cb.combine(0, step, 8, &[p], 0.0);
            assert!(r.update.is_empty());
            assert_eq!(r.record.wire_bytes, 0);
        }
        let p3 = c.compress(0, 3, &g);
        let r3 = cb.combine(0, 3, 8, &[p3], 0.0);
        // two dropped rounds of residual flush: 1 + 2 = 3
        assert_eq!(r3.update, vec![3.0f32; 8]);
    }

    #[test]
    fn data_dependency_only_for_oktopk() {
        for kind in SchemeKind::evaluation_set() {
            let (c, _) = build_rank_pair(&kind, 2, 1);
            let want = matches!(kind, SchemeKind::OkTopk { .. });
            assert_eq!(c.data_dependency(), want, "{}", kind.label());
        }
    }

    // ---- wire codec -------------------------------------------------------

    #[test]
    fn frame_lengths_match_formats() {
        assert_eq!(Payload::Empty.encoded_len(), 0);
        assert_eq!(Payload::Dense(vec![0.0; 10]).encoded_len(), 42);
        assert_eq!(
            Payload::Sparse { idx: vec![1, 2, 3], val: vec![0.0; 3] }.encoded_len(),
            26
        );
        assert_eq!(Payload::Half(vec![0; 10]).encoded_len(), 22);
        assert_eq!(
            Payload::Sign { scale: 1.0, bits: vec![0; 2], n: 100 }.encoded_len(),
            19
        );
        // the arithmetic helpers agree with the enum
        assert_eq!(dense_frame_len(10), 42);
        assert_eq!(sparse_frame_len(3), 26);
        assert_eq!(half_frame_len(10), 22);
        assert_eq!(sign_frame_len(100), 19);
    }

    #[test]
    fn varint_boundaries_roundtrip() {
        for x in [0u64, 1, 127, 128, 129, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, x);
            assert_eq!(buf.len(), varint_len(x), "{x}");
            let mut r = Reader { buf: &buf, pos: 0 };
            assert_eq!(r.varint().unwrap(), x);
            assert_eq!(r.pos, buf.len());
        }
    }

    fn roundtrip(p: &Payload) {
        let frame = p.encode();
        assert_eq!(frame.len(), p.encoded_len(), "{p:?}");
        // encode_into a dirty, differently-sized reused buffer must produce
        // the identical frame (the reservation/clear contract)
        let mut reused = vec![0xAAu8; 7];
        p.encode_into(&mut reused);
        assert_eq!(reused, frame, "encode_into must match encode bitwise");
        assert_eq!(reused.len(), p.encoded_len(), "encoded_len drift: {p:?}");
        let back = Payload::decode(&frame).unwrap();
        assert_eq!(&back, p, "codec round-trip");
        // re-encode is byte-identical (canonical form)
        assert_eq!(back.encode(), frame);
    }

    /// Satellite: decode(encode(p)) == p bitwise across all variants,
    /// including degenerate shapes, and `encoded_len()` equals the
    /// post-`encode_into` buffer length for every one of them.
    #[test]
    fn codec_roundtrips_degenerate_shapes() {
        roundtrip(&Payload::Empty);
        roundtrip(&Payload::Dense(Vec::new())); // zero-length dense
        roundtrip(&Payload::Dense(vec![7.25])); // n = 1
        roundtrip(&Payload::Dense(vec![0.0, -0.0, f32::NAN, f32::INFINITY, 1.5e-42]));
        roundtrip(&Payload::Sparse { idx: vec![7], val: vec![-3.25] }); // single-element
        roundtrip(&Payload::Sparse { idx: Vec::new(), val: Vec::new() });
        roundtrip(&Payload::Half(Vec::new()));
        roundtrip(&Payload::Half(vec![0x3c00])); // n = 1
        roundtrip(&Payload::Half(vec![0x3c00, 0x8000, 0x7fff]));
        // sign bitmaps with n % 64 != 0 (and n % 8 != 0)
        for n in [0usize, 1, 7, 8, 63, 64, 65, 100, 128, 129] {
            let g: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { -1.0 } else { 1.0 }).collect();
            let bits = crate::compress::signsgd::pack_signs(&g);
            roundtrip(&Payload::Sign { scale: 0.5, bits, n });
        }
    }

    /// Property form of the reservation contract: for random payloads of
    /// every variant, `encoded_len()` == the buffer length after
    /// `encode_into`, so the accounting arithmetic can never drift from
    /// the codec.
    #[test]
    fn codec_roundtrips_random_payloads() {
        prop::check("codec-roundtrip", 0xC0DEC, 60, |rng: &mut Rng| {
            let n = rng.below(300);
            let p = match rng.below(5) {
                0 => Payload::Empty,
                1 => Payload::Dense(prop::vec_f32(rng, n, 10.0)),
                2 => {
                    let k = rng.below(n + 1);
                    let idx: Vec<u32> = (0..k).map(|_| rng.below(1 << 20) as u32).collect();
                    Payload::Sparse { idx, val: prop::vec_f32(rng, k, 10.0) }
                }
                3 => {
                    let g = prop::vec_f32(rng, n, 1.0);
                    let bits = crate::compress::signsgd::pack_signs(&g);
                    Payload::Sign { scale: rng.next_f32(), bits, n }
                }
                _ => Payload::Half((0..n).map(|_| rng.below(1 << 16) as u16).collect()),
            };
            let mut frame = Vec::new();
            p.encode_into(&mut frame);
            assert_eq!(frame.len(), p.encoded_len());
            assert_eq!(&Payload::decode(&frame).unwrap(), &p);
        });
    }

    #[test]
    fn decode_rejects_malformed_frames() {
        // unknown tag
        assert!(Payload::decode(&[0x7f]).is_err());
        // truncated dense: claims 10 elements, carries none
        assert!(Payload::decode(&[TAG_DENSE, 10]).is_err());
        // trailing bytes after a complete frame
        let mut frame = Payload::Dense(vec![1.0]).encode();
        frame.push(0);
        assert!(Payload::decode(&frame).is_err());
        // varint overflow (10 continuation bytes)
        let frame = [TAG_DENSE, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff];
        assert!(Payload::decode(&frame).is_err());
        // absurd count cannot allocate: claims 2^40 elements in 3 bytes
        let mut frame = vec![TAG_DENSE];
        frame.extend_from_slice(&[0x80, 0x80, 0x80, 0x80, 0x80, 0x40]);
        assert!(Payload::decode(&frame).is_err());
    }

    #[test]
    fn sign_padding_bits_are_masked() {
        // a corrupt frame with padding bits set beyond n must decode to the
        // same payload as the clean frame (EF arithmetic indexes < n only,
        // but Vec<u64> equality in the parity checksums must hold).
        let clean = Payload::Sign { scale: 1.0, bits: vec![0b101], n: 3 };
        let mut frame = clean.encode();
        let last = frame.len() - 1;
        frame[last] |= 0xf0; // bits 4..8 are padding for n=3
        assert_eq!(&Payload::decode(&frame).unwrap(), &clean);
    }

    /// Satellite (packing audit): the sign bitmap crosses u64 word
    /// boundaries correctly — sign `i` is bit `i % 8` of wire byte `i / 8`
    /// for n straddling the 64-bit word edge (63, 64, 65), and the frame
    /// round-trips to identical bitmap words.
    #[test]
    fn sign_packing_crosses_word_boundaries() {
        for n in [63usize, 64, 65] {
            // negatives at word-boundary-sensitive positions
            let g: Vec<f32> = (0..n)
                .map(|i| if i % 5 == 0 || i >= 62 { -1.0 } else { 1.0 })
                .collect();
            let bits = crate::compress::signsgd::pack_signs(&g);
            let p = Payload::Sign { scale: 1.0, bits, n };
            let frame = p.encode();
            let bitmap = &frame[frame.len() - n.div_ceil(8)..];
            for (i, x) in g.iter().enumerate() {
                let bit = bitmap[i / 8] >> (i % 8) & 1;
                assert_eq!(
                    bit == 1,
                    x.is_sign_negative(),
                    "n={n} i={i}: wire bit must be the i-th sign"
                );
            }
            roundtrip(&p);
        }
    }

    #[test]
    fn compressor_payloads_roundtrip_through_codec() {
        // every scheme's real frame survives the wire bitwise
        let mut rng = Rng::seed(0x91E);
        let g = prop::vec_f32(&mut rng, 257, 1.0); // odd size on purpose
        let mut scratch = Scratch::new();
        for kind in SchemeKind::evaluation_set() {
            let (mut c, _) = build_rank_pair(&kind, 2, 5);
            let mut frame = Vec::new();
            c.compress_into(0, 0, &g, &mut scratch, &mut frame);
            let p = Payload::decode(&frame).expect("compressor frame must decode");
            roundtrip(&p);
            assert_eq!(p.encoded_len(), frame.len(), "{}", kind.label());
        }
    }

    /// Compressing the same gradient into a reused frame buffer (and with a
    /// reused scratch) yields bitwise-identical frames to fresh buffers —
    /// the hot path's reuse cannot leak state between tensors.
    #[test]
    fn reused_buffers_produce_identical_frames() {
        let mut rng = Rng::seed(0x5EED);
        let g1 = prop::vec_f32(&mut rng, 300, 1.0);
        let g2 = prop::vec_f32(&mut rng, 123, 1.0); // shrinking tensor
        for kind in SchemeKind::evaluation_set() {
            let (mut warm, _) = build_rank_pair(&kind, 1, 3);
            let (mut cold, _) = build_rank_pair(&kind, 1, 3);
            let mut scratch = Scratch::new();
            let mut frame = Vec::new();
            for (t, g) in [(0usize, &g1), (1, &g2), (0, &g1)] {
                warm.compress_into(t, 0, g, &mut scratch, &mut frame);
                let mut fresh = Vec::new();
                cold.compress_into(t, 0, g, &mut Scratch::new(), &mut fresh);
                assert_eq!(frame, fresh, "{} tensor {t}", kind.label());
            }
        }
    }
}
