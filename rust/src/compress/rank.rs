//! The canonical per-rank compression API and the wire codec.
//!
//! Every scheme is implemented *once*, as the two halves a cluster rank
//! actually executes:
//!
//! * [`RankCompressor::compress`] — runs on the rank's *compute* thread,
//!   right after the tensor's gradient is produced: error-feedback
//!   accumulate + wire-format encode, touching only this rank's residuals.
//! * [`RankCombiner::combine`] — runs on the rank's *comm* thread after
//!   the payload exchange: decode every rank's payload (rank-major order)
//!   into the dense update. Deterministic, identical bits on every rank.
//!
//! The replicated [`Scheme`](super::Scheme) trait the analytic backend
//! consumes is *not* a second implementation: it is the generic
//! [`LockstepDriver`](super::LockstepDriver) adapter, which drives P
//! compressor/combiner pairs in sequence over the per-worker gradients.
//! One implementation, two drivers — bitwise parity between the analytic
//! and threaded backends is structural, not a property-tested convention.
//!
//! Schemes whose round is inherently global (PowerSGD's dependent
//! two-round power iteration, Ok-topk's global threshold) implement
//! [`ReplicatedScheme`] instead: each rank ships its raw gradient and runs
//! an identical replica of the full scheme on the gathered set via
//! `ReplicaCombiner` — deterministic, hence still bitwise-identical
//! across ranks, at the cost of dense in-process traffic (the CommRecord
//! keeps charging the scheme's true encoded wire volume; see DESIGN.md §4).
//!
//! # Wire format
//!
//! [`Payload::encode`] / [`Payload::decode`] give every payload a real
//! byte-level frame — the thing `exec::ring` moves and the thing
//! `CommRecord::wire_bytes` measures. All integers are little-endian;
//! `varint` is LEB128 (7 data bits per byte, low group first):
//!
//! ```text
//! Empty  -> zero-length frame          (a dropped tensor sends nothing)
//! Dense  -> [0x01][varint n][n x f32]
//! Sparse -> [0x02][varint k][k x u32 idx][k x f32 val]
//! Sign   -> [0x03][varint n][f32 scale][ceil(n/8) sign bytes, bit i = i-th sign]
//! Half   -> [0x04][varint n][n x u16]
//! ```
//!
//! `decode(encode(p)) == p` bitwise for every variant (property-tested
//! below, including `n % 64 != 0` sign bitmaps and zero-length payloads),
//! and [`Payload::encoded_len`] — the arithmetic the accounting uses —
//! always equals `encode().len()`.

use std::time::Instant;

use super::{CommRecord, Collective, SchemeKind};
use crate::compress::{baseline, covap, fp16, oktopk, powersgd, randomk, signsgd, topk};

/// A wire-format payload one rank contributes to the collective.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Nothing transmitted (COVAP dropped tensor).
    Empty,
    /// Dense f32 (baseline, COVAP kept tensors, replicated raw gradients).
    Dense(Vec<f32>),
    /// (index, value) pairs — worker-specific sparse selections.
    Sparse { idx: Vec<u32>, val: Vec<f32> },
    /// 1-bit signs + one scale (EFsignSGD).
    Sign { scale: f32, bits: Vec<u64>, n: usize },
    /// IEEE half-precision quantization.
    Half(Vec<u16>),
}

const TAG_DENSE: u8 = 0x01;
const TAG_SPARSE: u8 = 0x02;
const TAG_SIGN: u8 = 0x03;
const TAG_HALF: u8 = 0x04;

/// Codec failure (truncated, oversized or malformed frame).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeError(pub &'static str);

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "payload decode error: {}", self.0)
    }
}

impl std::error::Error for DecodeError {}

/// Encoded size of a LEB128 varint.
pub fn varint_len(mut x: u64) -> usize {
    let mut len = 1;
    while x >= 0x80 {
        x >>= 7;
        len += 1;
    }
    len
}

fn write_varint(out: &mut Vec<u8>, mut x: u64) {
    while x >= 0x80 {
        out.push((x as u8 & 0x7f) | 0x80);
        x >>= 7;
    }
    out.push(x as u8);
}

/// Frame length of a dense f32 payload of `n` elements.
pub fn dense_frame_len(n: usize) -> usize {
    1 + varint_len(n as u64) + 4 * n
}

/// Frame length of a sparse payload of `k` (index, value) pairs.
pub fn sparse_frame_len(k: usize) -> usize {
    1 + varint_len(k as u64) + 8 * k
}

/// Frame length of a sign payload over `n` elements.
pub fn sign_frame_len(n: usize) -> usize {
    1 + varint_len(n as u64) + 4 + n.div_ceil(8)
}

/// Frame length of a half-precision payload of `n` elements.
pub fn half_frame_len(n: usize) -> usize {
    1 + varint_len(n as u64) + 2 * n
}

/// Sequential little-endian reader over a frame.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        let end = self.pos.checked_add(n).ok_or(DecodeError("length overflow"))?;
        if end > self.buf.len() {
            return Err(DecodeError("truncated frame"));
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn varint(&mut self) -> Result<u64, DecodeError> {
        let mut x = 0u64;
        let mut shift = 0u32;
        loop {
            let b = *self.take(1)?.first().unwrap();
            if shift >= 64 || (shift == 63 && b > 1) {
                return Err(DecodeError("varint overflow"));
            }
            x |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(x);
            }
            shift += 7;
        }
    }

    /// A varint element count, sanity-checked against the bytes that must
    /// still follow (`stride` bytes per element) so a corrupt frame cannot
    /// trigger a huge allocation.
    fn count(&mut self, stride: usize) -> Result<usize, DecodeError> {
        let n = self.varint()? as usize;
        let need = n.checked_mul(stride).ok_or(DecodeError("length overflow"))?;
        if need > self.buf.len() - self.pos {
            return Err(DecodeError("count exceeds frame"));
        }
        Ok(n)
    }
}

impl Payload {
    /// Serialize to the framed wire format (see module docs). The returned
    /// frame's length always equals [`Payload::encoded_len`].
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        match self {
            Payload::Empty => {}
            Payload::Dense(v) => {
                out.push(TAG_DENSE);
                write_varint(&mut out, v.len() as u64);
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Payload::Sparse { idx, val } => {
                debug_assert_eq!(idx.len(), val.len());
                out.push(TAG_SPARSE);
                write_varint(&mut out, idx.len() as u64);
                for i in idx {
                    out.extend_from_slice(&i.to_le_bytes());
                }
                for x in val {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Payload::Sign { scale, bits, n } => {
                out.push(TAG_SIGN);
                write_varint(&mut out, *n as u64);
                out.extend_from_slice(&scale.to_le_bytes());
                for b in 0..n.div_ceil(8) {
                    out.push((bits[b / 8] >> ((b % 8) * 8)) as u8);
                }
            }
            Payload::Half(v) => {
                out.push(TAG_HALF);
                write_varint(&mut out, v.len() as u64);
                for h in v {
                    out.extend_from_slice(&h.to_le_bytes());
                }
            }
        }
        debug_assert_eq!(out.len(), self.encoded_len());
        out
    }

    /// Parse a frame produced by [`Payload::encode`]. Bitwise-exact inverse.
    pub fn decode(buf: &[u8]) -> Result<Payload, DecodeError> {
        if buf.is_empty() {
            return Ok(Payload::Empty);
        }
        let tag = buf[0];
        let mut r = Reader { buf, pos: 1 };
        let p = match tag {
            TAG_DENSE => {
                let n = r.count(4)?;
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    let b: [u8; 4] = r.take(4)?.try_into().unwrap();
                    v.push(f32::from_le_bytes(b));
                }
                Payload::Dense(v)
            }
            TAG_SPARSE => {
                let k = r.count(8)?;
                let mut idx = Vec::with_capacity(k);
                for _ in 0..k {
                    let b: [u8; 4] = r.take(4)?.try_into().unwrap();
                    idx.push(u32::from_le_bytes(b));
                }
                let mut val = Vec::with_capacity(k);
                for _ in 0..k {
                    let b: [u8; 4] = r.take(4)?.try_into().unwrap();
                    val.push(f32::from_le_bytes(b));
                }
                Payload::Sparse { idx, val }
            }
            TAG_SIGN => {
                let n = r.varint()? as usize;
                let b: [u8; 4] = r.take(4)?.try_into().unwrap();
                let scale = f32::from_le_bytes(b);
                let bytes = r.take(n.div_ceil(8))?;
                let mut bits = vec![0u64; n.div_ceil(64)];
                for (b, &byte) in bytes.iter().enumerate() {
                    bits[b / 8] |= (byte as u64) << ((b % 8) * 8);
                }
                // clear padding bits beyond n (a well-formed encoder never
                // sets them; a corrupt frame must not smuggle them in)
                if n % 64 != 0 {
                    if let Some(last) = bits.last_mut() {
                        *last &= (1u64 << (n % 64)) - 1;
                    }
                }
                Payload::Sign { scale, bits, n }
            }
            TAG_HALF => {
                let n = r.count(2)?;
                let mut v = Vec::with_capacity(n);
                for _ in 0..n {
                    let b: [u8; 2] = r.take(2)?.try_into().unwrap();
                    v.push(u16::from_le_bytes(b));
                }
                Payload::Half(v)
            }
            _ => return Err(DecodeError("unknown variant tag")),
        };
        if r.pos != buf.len() {
            return Err(DecodeError("trailing bytes"));
        }
        Ok(p)
    }

    /// Bytes this payload occupies on the wire — exactly
    /// `self.encode().len()`, computed without materializing the frame.
    pub fn encoded_len(&self) -> usize {
        match self {
            Payload::Empty => 0,
            Payload::Dense(v) => dense_frame_len(v.len()),
            Payload::Sparse { idx, .. } => sparse_frame_len(idx.len()),
            Payload::Sign { n, .. } => sign_frame_len(*n),
            Payload::Half(v) => half_frame_len(v.len()),
        }
    }
}

/// Bitwise equality (f32s compared by bit pattern, so `-0.0 != 0.0` and
/// NaN payloads compare equal to themselves — what the codec round-trip
/// property needs).
impl PartialEq for Payload {
    fn eq(&self, other: &Payload) -> bool {
        fn f32s_eq(a: &[f32], b: &[f32]) -> bool {
            a.len() == b.len()
                && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
        }
        match (self, other) {
            (Payload::Empty, Payload::Empty) => true,
            (Payload::Dense(a), Payload::Dense(b)) => f32s_eq(a, b),
            (
                Payload::Sparse { idx: ia, val: va },
                Payload::Sparse { idx: ib, val: vb },
            ) => ia == ib && f32s_eq(va, vb),
            (
                Payload::Sign { scale: sa, bits: ba, n: na },
                Payload::Sign { scale: sb, bits: bb, n: nb },
            ) => sa.to_bits() == sb.to_bits() && ba == bb && na == nb,
            (Payload::Half(a), Payload::Half(b)) => a == b,
            _ => false,
        }
    }
}

/// One tensor round's outcome on a rank: the (replicated) dense update plus
/// the accounting record the simulator prices.
#[derive(Debug, Clone)]
pub struct RankRound {
    pub update: Vec<f32>,
    pub record: CommRecord,
}

/// The compute-thread half: encode this rank's gradient.
pub trait RankCompressor: Send {
    fn name(&self) -> &'static str;
    /// Compress `grad` for communication tensor `tensor` at `step`,
    /// using only this rank's error-feedback residuals.
    fn compress(&mut self, tensor: usize, step: u64, grad: &[f32]) -> Payload;
    /// True when the backward pass must wait for this tensor's combine
    /// result before continuing (Ok-topk rendezvous semantics).
    fn data_dependency(&self) -> bool {
        false
    }
    fn reset(&mut self);
}

/// The comm-thread half: fold all ranks' payloads into the dense update.
/// Must be deterministic and produce identical bits on every rank.
pub trait RankCombiner: Send {
    fn name(&self) -> &'static str;
    /// `payloads` is rank-major (index = rank id); `n` is the tensor's
    /// element count; `compress_s` is the measured compression wall time
    /// forwarded into the CommRecord.
    fn combine(
        &mut self,
        tensor: usize,
        step: u64,
        n: usize,
        payloads: &[Payload],
        compress_s: f64,
    ) -> RankRound;
    fn reset(&mut self);
}

/// A globally-coupled scheme that cannot be split into independent rank
/// halves: one deterministic round over the gathered per-worker gradients.
/// Run as an identical replica on every rank by `ReplicaCombiner` —
/// replication *is* its execution strategy, not a second implementation.
pub trait ReplicatedScheme: Send {
    fn name(&self) -> &'static str;
    fn round(&mut self, tensor: usize, step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord);
    fn reset(&mut self);
}

/// Build the (compressor, combiner) pair for ONE rank. Call once per rank
/// with identical `(kind, workers, seed)` so the replicas agree.
pub fn build_rank_pair(
    kind: &SchemeKind,
    workers: usize,
    seed: u64,
) -> (Box<dyn RankCompressor>, Box<dyn RankCombiner>) {
    match kind.clone() {
        SchemeKind::Baseline => (Box::new(baseline::DenseCompressor), Box::new(MeanCombiner)),
        SchemeKind::Covap { interval, ef } => {
            (Box::new(covap::CovapCompressor::new(interval, ef)), Box::new(MeanCombiner))
        }
        SchemeKind::Fp16 => (Box::new(fp16::HalfCompressor), Box::new(MeanCombiner)),
        SchemeKind::TopK { ratio } => {
            (Box::new(topk::TopKCompressor::new(ratio)), Box::new(SparseCombiner))
        }
        SchemeKind::Dgc { ratio } => {
            (Box::new(topk::DgcCompressor::new(ratio, seed)), Box::new(SparseCombiner))
        }
        SchemeKind::RandomK { ratio } => {
            (Box::new(randomk::RandomKCompressor::new(ratio, seed)), Box::new(SparseCombiner))
        }
        SchemeKind::EfSignSgd => {
            (Box::new(signsgd::SignCompressor::new()), Box::new(SignCombiner))
        }
        SchemeKind::PowerSgd { rank } => (
            Box::new(RawCompressor { dep: false }),
            Box::new(ReplicaCombiner {
                inner: Box::new(powersgd::PowerSgd::new(rank, workers, seed)),
            }),
        ),
        SchemeKind::OkTopk { ratio } => (
            Box::new(RawCompressor { dep: true }),
            Box::new(ReplicaCombiner { inner: Box::new(oktopk::OkTopk::new(ratio, workers)) }),
        ),
    }
}

/// Max encoded frame length over the gathered payloads — the per-rank wire
/// volume the accounting charges (payload frames are identical sizes for
/// dense/half/sign schemes; sparse selections may differ per rank, where
/// the max is the conservative per-rank bound the old model also used).
fn max_frame_len(payloads: &[Payload]) -> usize {
    payloads.iter().map(|p| p.encoded_len()).max().unwrap_or(0)
}

// ---- shared wire-format combiners -----------------------------------------

/// Mean over dense-decodable payloads in rank order (Dense and Half frames).
/// Serves every AllReduce-style mean scheme: baseline, COVAP, FP16.
///
/// `compress_s` accounting: a pure Dense mean is the collective's own
/// arithmetic (in-network on real hardware) and charges nothing extra; a
/// fold involving Half frames is dequantization, so its measured wall time
/// is added to the record as the scheme's decompression cost.
pub(crate) struct MeanCombiner;

impl RankCombiner for MeanCombiner {
    fn name(&self) -> &'static str {
        "mean"
    }

    fn combine(
        &mut self,
        _tensor: usize,
        _step: u64,
        n: usize,
        payloads: &[Payload],
        compress_s: f64,
    ) -> RankRound {
        if payloads.iter().all(|p| matches!(p, Payload::Empty)) {
            // COVAP dropped tensor: empty update = "all zeros".
            return RankRound {
                update: Vec::new(),
                record: CommRecord::dense(0, compress_s),
            };
        }
        let t0 = Instant::now();
        let mut update = vec![0.0f32; n];
        for p in payloads {
            match p {
                Payload::Dense(g) => {
                    for (u, &x) in update.iter_mut().zip(g.iter()) {
                        *u += x;
                    }
                }
                Payload::Half(h) => {
                    for (u, &b) in update.iter_mut().zip(h.iter()) {
                        *u += fp16::f16_to_f32(b);
                    }
                }
                other => panic!("mean combiner got {other:?}"),
            }
        }
        let inv = 1.0 / payloads.len() as f32;
        for u in &mut update {
            *u *= inv;
        }
        let decode_s = if payloads.iter().any(|p| matches!(p, Payload::Half(_))) {
            t0.elapsed().as_secs_f64()
        } else {
            0.0
        };
        RankRound {
            update,
            record: CommRecord::dense(max_frame_len(payloads), compress_s + decode_s),
        }
    }

    fn reset(&mut self) {}
}

/// Rank-order mean over sparse selections: `update[i] += v / P` per worker
/// payload. Serves Top-k, DGC and Random-k. The scatter-add is the sparse
/// format's decompression, so its measured wall time joins `compress_s`.
pub(crate) struct SparseCombiner;

impl RankCombiner for SparseCombiner {
    fn name(&self) -> &'static str {
        "sparse-gather"
    }

    fn combine(
        &mut self,
        _tensor: usize,
        _step: u64,
        n: usize,
        payloads: &[Payload],
        compress_s: f64,
    ) -> RankRound {
        let t0 = Instant::now();
        let mut update = vec![0.0f32; n];
        let inv = 1.0 / payloads.len() as f32;
        for p in payloads {
            let Payload::Sparse { idx, val } = p else {
                panic!("sparse combiner got {p:?}")
            };
            for (&i, &v) in idx.iter().zip(val.iter()) {
                update[i as usize] += v * inv;
            }
        }
        let compress_s = compress_s + t0.elapsed().as_secs_f64();
        RankRound {
            update,
            record: CommRecord {
                wire_bytes: max_frame_len(payloads),
                collective: Collective::AllGather,
                rounds: 1,
                sync_rounds: 0,
                compress_s,
                data_dependency: false,
            },
        }
    }

    fn reset(&mut self) {}
}

/// Rank-order mean over sign payloads (EFsignSGD). The per-element unpack
/// is this scheme's decompression — the cost the paper's Table VII blames —
/// so its measured wall time joins `compress_s`.
pub(crate) struct SignCombiner;

impl RankCombiner for SignCombiner {
    fn name(&self) -> &'static str {
        "sign-gather"
    }

    fn combine(
        &mut self,
        _tensor: usize,
        _step: u64,
        n: usize,
        payloads: &[Payload],
        compress_s: f64,
    ) -> RankRound {
        let t0 = Instant::now();
        let mut update = vec![0.0f32; n];
        let inv = 1.0 / payloads.len() as f32;
        for p in payloads {
            let Payload::Sign { scale, bits, n: pn } = p else {
                panic!("sign combiner got {p:?}")
            };
            debug_assert_eq!(*pn, n);
            for (i, u) in update.iter_mut().enumerate() {
                let neg = bits[i / 64] >> (i % 64) & 1 == 1;
                let v = if neg { -*scale } else { *scale };
                *u += v * inv;
            }
        }
        let compress_s = compress_s + t0.elapsed().as_secs_f64();
        RankRound {
            update,
            record: CommRecord {
                wire_bytes: max_frame_len(payloads),
                collective: Collective::AllGather,
                rounds: 1,
                sync_rounds: 0,
                compress_s,
                data_dependency: false,
            },
        }
    }

    fn reset(&mut self) {}
}

// ---- replicated execution (PowerSGD / Ok-topk) ----------------------------

/// Ships the raw gradient for replicated execution.
pub(crate) struct RawCompressor {
    pub(crate) dep: bool,
}

impl RankCompressor for RawCompressor {
    fn name(&self) -> &'static str {
        "raw"
    }

    fn compress(&mut self, _tensor: usize, _step: u64, grad: &[f32]) -> Payload {
        Payload::Dense(grad.to_vec())
    }

    fn data_dependency(&self) -> bool {
        self.dep
    }

    fn reset(&mut self) {}
}

/// Every rank holds an identical replica of a [`ReplicatedScheme`] and
/// feeds it the gathered raw gradients — deterministic, hence identical
/// state and bitwise-identical output on every rank and vs the analytic
/// backend. The record keeps the scheme's own (encoded) wire accounting.
pub(crate) struct ReplicaCombiner {
    pub(crate) inner: Box<dyn ReplicatedScheme>,
}

impl RankCombiner for ReplicaCombiner {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn combine(
        &mut self,
        tensor: usize,
        step: u64,
        _n: usize,
        payloads: &[Payload],
        _compress_s: f64,
    ) -> RankRound {
        let grads: Vec<&[f32]> = payloads
            .iter()
            .map(|p| match p {
                Payload::Dense(g) => g.as_slice(),
                other => panic!("replica combiner got {other:?}"),
            })
            .collect();
        let (update, record) = self.inner.round(tensor, step, &grads);
        RankRound { update, record }
    }

    fn reset(&mut self) {
        self.inner.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covap::EfScheduler;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Drive P rank pairs in lockstep, exactly as the threaded executor
    /// does across threads.
    fn lockstep_round(
        pairs: &mut [(Box<dyn RankCompressor>, Box<dyn RankCombiner>)],
        tensor: usize,
        step: u64,
        grads: &[&[f32]],
    ) -> Vec<RankRound> {
        let payloads: Vec<Payload> = pairs
            .iter_mut()
            .zip(grads.iter())
            .map(|((c, _), g)| c.compress(tensor, step, g))
            .collect();
        let n = grads[0].len();
        pairs
            .iter_mut()
            .map(|(_, cb)| cb.combine(tensor, step, n, &payloads, 0.0))
            .collect()
    }

    /// THE parity guarantee: for every scheme, independently-driven rank
    /// pairs match the replicated `Scheme::round` (now the lockstep driver)
    /// bit-for-bit across shapes, steps and multiple tensors, and every
    /// rank agrees with every other.
    #[test]
    fn rank_path_bitwise_matches_scheme_round() {
        for kind in SchemeKind::evaluation_set() {
            prop::check(kind.label(), 0xEC5, 6, |rng: &mut Rng| {
                let workers = 1 + rng.below(4);
                let n = 16 + rng.below(512);
                let seed = 0xABCD;
                let mut scheme = kind.build(workers, seed);
                let mut pairs: Vec<_> =
                    (0..workers).map(|_| build_rank_pair(&kind, workers, seed)).collect();
                for step in 0..6u64 {
                    for tensor in 0..2usize {
                        let gs: Vec<Vec<f32>> =
                            (0..workers).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
                        let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
                        let (want, want_rec) = scheme.round(tensor, step, &refs);
                        let rounds = lockstep_round(&mut pairs, tensor, step, &refs);
                        for (r, rr) in rounds.iter().enumerate() {
                            assert_eq!(
                                rr.update, want,
                                "{} rank {r} diverged at step {step} tensor {tensor}",
                                kind.label()
                            );
                            assert_eq!(
                                rr.record.wire_bytes, want_rec.wire_bytes,
                                "{} wire accounting rank {r}",
                                kind.label()
                            );
                            assert_eq!(rr.record.collective, want_rec.collective);
                        }
                    }
                }
            });
        }
    }

    #[test]
    fn covap_drop_rounds_are_empty_and_flush() {
        let kind = SchemeKind::Covap { interval: 3, ef: EfScheduler::constant(1.0) };
        let (mut c, mut cb) = build_rank_pair(&kind, 1, 7);
        let g = vec![1.0f32; 8];
        // tensor 0 kept at steps 0 and 3
        let p0 = c.compress(0, 0, &g);
        assert!(matches!(p0, Payload::Dense(_)));
        for step in 1..3 {
            let p = c.compress(0, step, &g);
            assert!(matches!(p, Payload::Empty));
            let r = cb.combine(0, step, 8, &[p], 0.0);
            assert!(r.update.is_empty());
            assert_eq!(r.record.wire_bytes, 0);
        }
        let p3 = c.compress(0, 3, &g);
        let r3 = cb.combine(0, 3, 8, &[p3], 0.0);
        // two dropped rounds of residual flush: 1 + 2 = 3
        assert_eq!(r3.update, vec![3.0f32; 8]);
    }

    #[test]
    fn data_dependency_only_for_oktopk() {
        for kind in SchemeKind::evaluation_set() {
            let (c, _) = build_rank_pair(&kind, 2, 1);
            let want = matches!(kind, SchemeKind::OkTopk { .. });
            assert_eq!(c.data_dependency(), want, "{}", kind.label());
        }
    }

    // ---- wire codec -------------------------------------------------------

    #[test]
    fn frame_lengths_match_formats() {
        assert_eq!(Payload::Empty.encoded_len(), 0);
        assert_eq!(Payload::Dense(vec![0.0; 10]).encoded_len(), 42);
        assert_eq!(
            Payload::Sparse { idx: vec![1, 2, 3], val: vec![0.0; 3] }.encoded_len(),
            26
        );
        assert_eq!(Payload::Half(vec![0; 10]).encoded_len(), 22);
        assert_eq!(
            Payload::Sign { scale: 1.0, bits: vec![0; 2], n: 100 }.encoded_len(),
            19
        );
        // the arithmetic helpers agree with the enum
        assert_eq!(dense_frame_len(10), 42);
        assert_eq!(sparse_frame_len(3), 26);
        assert_eq!(half_frame_len(10), 22);
        assert_eq!(sign_frame_len(100), 19);
    }

    #[test]
    fn varint_boundaries_roundtrip() {
        for x in [0u64, 1, 127, 128, 129, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            write_varint(&mut buf, x);
            assert_eq!(buf.len(), varint_len(x), "{x}");
            let mut r = Reader { buf: &buf, pos: 0 };
            assert_eq!(r.varint().unwrap(), x);
            assert_eq!(r.pos, buf.len());
        }
    }

    fn roundtrip(p: &Payload) {
        let frame = p.encode();
        assert_eq!(frame.len(), p.encoded_len(), "{p:?}");
        let back = Payload::decode(&frame).unwrap();
        assert_eq!(&back, p, "codec round-trip");
        // re-encode is byte-identical (canonical form)
        assert_eq!(back.encode(), frame);
    }

    /// Satellite: decode(encode(p)) == p bitwise across all variants,
    /// including degenerate shapes.
    #[test]
    fn codec_roundtrips_degenerate_shapes() {
        roundtrip(&Payload::Empty);
        roundtrip(&Payload::Dense(Vec::new())); // zero-length dense
        roundtrip(&Payload::Dense(vec![0.0, -0.0, f32::NAN, f32::INFINITY, 1.5e-42]));
        roundtrip(&Payload::Sparse { idx: vec![7], val: vec![-3.25] }); // single-element
        roundtrip(&Payload::Sparse { idx: Vec::new(), val: Vec::new() });
        roundtrip(&Payload::Half(Vec::new()));
        roundtrip(&Payload::Half(vec![0x3c00, 0x8000, 0x7fff]));
        // sign bitmaps with n % 64 != 0 (and n % 8 != 0)
        for n in [0usize, 1, 7, 8, 63, 64, 65, 100, 128, 129] {
            let g: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { -1.0 } else { 1.0 }).collect();
            let bits = crate::compress::signsgd::pack_signs(&g);
            roundtrip(&Payload::Sign { scale: 0.5, bits, n });
        }
    }

    #[test]
    fn codec_roundtrips_random_payloads() {
        prop::check("codec-roundtrip", 0xC0DEC, 60, |rng: &mut Rng| {
            let n = rng.below(300);
            let p = match rng.below(5) {
                0 => Payload::Empty,
                1 => Payload::Dense(prop::vec_f32(rng, n, 10.0)),
                2 => {
                    let k = rng.below(n + 1);
                    let idx: Vec<u32> = (0..k).map(|_| rng.below(1 << 20) as u32).collect();
                    Payload::Sparse { idx, val: prop::vec_f32(rng, k, 10.0) }
                }
                3 => {
                    let g = prop::vec_f32(rng, n, 1.0);
                    let bits = crate::compress::signsgd::pack_signs(&g);
                    Payload::Sign { scale: rng.next_f32(), bits, n }
                }
                _ => Payload::Half((0..n).map(|_| rng.below(1 << 16) as u16).collect()),
            };
            let frame = p.encode();
            assert_eq!(frame.len(), p.encoded_len());
            assert_eq!(&Payload::decode(&frame).unwrap(), &p);
        });
    }

    #[test]
    fn decode_rejects_malformed_frames() {
        // unknown tag
        assert!(Payload::decode(&[0x7f]).is_err());
        // truncated dense: claims 10 elements, carries none
        assert!(Payload::decode(&[TAG_DENSE, 10]).is_err());
        // trailing bytes after a complete frame
        let mut frame = Payload::Dense(vec![1.0]).encode();
        frame.push(0);
        assert!(Payload::decode(&frame).is_err());
        // varint overflow (10 continuation bytes)
        let frame = [TAG_DENSE, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff];
        assert!(Payload::decode(&frame).is_err());
        // absurd count cannot allocate: claims 2^40 elements in 3 bytes
        let mut frame = vec![TAG_DENSE];
        frame.extend_from_slice(&[0x80, 0x80, 0x80, 0x80, 0x80, 0x40]);
        assert!(Payload::decode(&frame).is_err());
    }

    #[test]
    fn sign_padding_bits_are_masked() {
        // a corrupt frame with padding bits set beyond n must decode to the
        // same payload as the clean frame (EF arithmetic indexes < n only,
        // but Vec<u64> equality in the parity checksums must hold).
        let clean = Payload::Sign { scale: 1.0, bits: vec![0b101], n: 3 };
        let mut frame = clean.encode();
        let last = frame.len() - 1;
        frame[last] |= 0xf0; // bits 4..8 are padding for n=3
        assert_eq!(&Payload::decode(&frame).unwrap(), &clean);
    }

    #[test]
    fn compressor_payloads_roundtrip_through_codec() {
        // every scheme's real payload survives the wire bitwise
        let mut rng = Rng::seed(0x91E);
        let g = prop::vec_f32(&mut rng, 257, 1.0); // odd size on purpose
        for kind in SchemeKind::evaluation_set() {
            let (mut c, _) = build_rank_pair(&kind, 2, 5);
            let p = c.compress(0, 0, &g);
            roundtrip(&p);
        }
    }
}
