//! Per-rank compression: one rank's half of a [`Scheme`] round.
//!
//! The replicated [`Scheme`] trait models a whole worker group in one
//! object — fine for the analytic backend, impossible for the threaded
//! executor where every rank runs on its own OS thread and owns only its
//! own error-feedback state. This module splits a compression round into
//! the two halves the cluster actually executes:
//!
//! * [`RankCompressor::compress`] — runs on the rank's *compute* thread,
//!   right after the tensor's gradient is produced: error-feedback
//!   accumulate + wire-format encode, touching only this rank's residuals.
//! * [`RankCombiner::combine`] — runs on the rank's *comm* thread after
//!   the payload exchange: decode every rank's payload (rank-major order)
//!   into the dense update.
//!
//! **Parity contract**: driving P compressor/combiner pairs in lockstep
//! over the same inputs produces *bitwise identical* updates to the
//! replicated `Scheme::round` — every accumulate/select/mean loop below
//! mirrors its `Scheme` counterpart's floating-point evaluation order
//! exactly, and the property test at the bottom enforces this for every
//! `SchemeKind`. This is what lets `ExecBackend::Threaded` reproduce the
//! analytic loss trajectory bit-for-bit.
//!
//! Schemes whose round is inherently global (DGC's sampled thresholds
//! drawn from one RNG stream, PowerSGD's dependent two-round power
//! iteration, Ok-topk's global threshold) fall back to [`Replicated`]
//! execution: each rank ships its raw gradient and runs an identical
//! replica of the full scheme on the gathered set — deterministic, so
//! still bitwise-parity, at the cost of dense in-process traffic (the
//! CommRecord keeps charging the scheme's true wire volume; see
//! DESIGN.md §4).

use std::collections::HashMap;

use super::fp16::{f16_to_f32, f32_to_f16};
use super::randomk::shared_indices;
use super::signsgd::pack_signs;
use super::topk::{k_of, kth_magnitude, select_sparse};
use super::{CommRecord, Collective, Scheme, SchemeKind};
use crate::covap::{CoarseFilter, EfScheduler};

/// A wire-format payload one rank contributes to the collective.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Nothing transmitted (COVAP dropped tensor).
    Empty,
    /// Dense f32 (baseline, COVAP kept tensors, replicated raw gradients).
    Dense(Vec<f32>),
    /// (index, value) pairs — worker-specific sparse selections.
    Sparse { idx: Vec<u32>, val: Vec<f32> },
    /// 1-bit signs + one scale (EFsignSGD).
    Sign { scale: f32, bits: Vec<u64>, n: usize },
    /// IEEE half-precision quantization.
    Half(Vec<u16>),
}

impl Payload {
    /// Bytes this payload occupies on the wire.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::Empty => 0,
            Payload::Dense(v) => v.len() * 4,
            Payload::Sparse { idx, .. } => idx.len() * 8,
            Payload::Sign { n, .. } => n.div_ceil(8) + 4,
            Payload::Half(v) => v.len() * 2,
        }
    }
}

/// One tensor round's outcome on a rank: the (replicated) dense update plus
/// the accounting record the simulator prices.
#[derive(Debug, Clone)]
pub struct RankRound {
    pub update: Vec<f32>,
    pub record: CommRecord,
}

/// The compute-thread half: encode this rank's gradient.
pub trait RankCompressor: Send {
    fn name(&self) -> &'static str;
    /// Compress `grad` for communication tensor `tensor` at `step`,
    /// using only this rank's error-feedback residuals.
    fn compress(&mut self, tensor: usize, step: u64, grad: &[f32]) -> Payload;
    /// True when the backward pass must wait for this tensor's combine
    /// result before continuing (Ok-topk rendezvous semantics).
    fn data_dependency(&self) -> bool {
        false
    }
    fn reset(&mut self);
}

/// The comm-thread half: fold all ranks' payloads into the dense update.
/// Must be deterministic and produce identical bits on every rank.
pub trait RankCombiner: Send {
    fn name(&self) -> &'static str;
    /// `payloads` is rank-major (index = rank id); `n` is the tensor's
    /// element count; `compress_s` is the measured compression wall time
    /// forwarded into the CommRecord.
    fn combine(
        &mut self,
        tensor: usize,
        step: u64,
        n: usize,
        payloads: &[Payload],
        compress_s: f64,
    ) -> RankRound;
    fn reset(&mut self);
}

/// Build the (compressor, combiner) pair for ONE rank. Call once per rank
/// with identical `(kind, workers, seed)` so the replicas agree.
pub fn build_rank_pair(
    kind: &SchemeKind,
    workers: usize,
    seed: u64,
) -> (Box<dyn RankCompressor>, Box<dyn RankCombiner>) {
    match kind.clone() {
        SchemeKind::Baseline => {
            (Box::new(DenseCompressor), Box::new(MeanCombiner { dense_bytes_per_elem: 4 }))
        }
        SchemeKind::Covap { interval, ef } => (
            Box::new(CovapCompressor {
                filter: CoarseFilter::new(interval),
                scheduler: ef,
                residuals: HashMap::new(),
            }),
            Box::new(MeanCombiner { dense_bytes_per_elem: 4 }),
        ),
        SchemeKind::Fp16 => {
            (Box::new(HalfCompressor), Box::new(MeanCombiner { dense_bytes_per_elem: 2 }))
        }
        SchemeKind::TopK { ratio } => (
            Box::new(TopKCompressor { ratio, residuals: HashMap::new() }),
            Box::new(SparseCombiner),
        ),
        SchemeKind::RandomK { ratio } => (
            Box::new(RandomKCompressor { ratio, seed, residuals: HashMap::new() }),
            Box::new(SparseCombiner),
        ),
        SchemeKind::EfSignSgd => (
            Box::new(SignCompressor { residuals: HashMap::new() }),
            Box::new(SignCombiner),
        ),
        // Globally-coupled schemes: replicated full-scheme execution.
        k @ (SchemeKind::Dgc { .. }
        | SchemeKind::PowerSgd { .. }
        | SchemeKind::OkTopk { .. }) => {
            let dep = matches!(k, SchemeKind::OkTopk { .. });
            (
                Box::new(RawCompressor { dep }),
                Box::new(Replicated { inner: k.build(workers, seed) }),
            )
        }
    }
}

// ---- dense / COVAP --------------------------------------------------------

struct DenseCompressor;

impl RankCompressor for DenseCompressor {
    fn name(&self) -> &'static str {
        "DDPovlp"
    }

    fn compress(&mut self, _tensor: usize, _step: u64, grad: &[f32]) -> Payload {
        Payload::Dense(grad.to_vec())
    }

    fn reset(&mut self) {}
}

struct CovapCompressor {
    filter: CoarseFilter,
    scheduler: EfScheduler,
    /// This rank's residual per communication tensor — the EF state that
    /// the replicated `CovapScheme` keeps for all workers at once.
    residuals: HashMap<usize, Vec<f32>>,
}

impl RankCompressor for CovapCompressor {
    fn name(&self) -> &'static str {
        "COVAP"
    }

    fn compress(&mut self, tensor: usize, step: u64, grad: &[f32]) -> Payload {
        let n = grad.len();
        let keep = self.filter.keep(tensor, step);
        let coeff = self.scheduler.coeff(step);
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        if keep {
            // same element expression as CovapScheme: gi + coeff * ri
            let acc: Vec<f32> = grad
                .iter()
                .zip(res.iter_mut())
                .map(|(&gi, ri)| {
                    let a = gi + coeff * *ri;
                    *ri = 0.0;
                    a
                })
                .collect();
            Payload::Dense(acc)
        } else {
            for (ri, &gi) in res.iter_mut().zip(grad.iter()) {
                *ri = gi + coeff * *ri;
            }
            Payload::Empty
        }
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

struct HalfCompressor;

impl RankCompressor for HalfCompressor {
    fn name(&self) -> &'static str {
        "FP16"
    }

    fn compress(&mut self, _tensor: usize, _step: u64, grad: &[f32]) -> Payload {
        Payload::Half(grad.iter().map(|&x| f32_to_f16(x)).collect())
    }

    fn reset(&mut self) {}
}

/// Mean over dense-decodable payloads in rank order — the exact accumulate
/// order of `mean_of` / `CovapScheme` / `Fp16::round`.
struct MeanCombiner {
    dense_bytes_per_elem: usize,
}

impl RankCombiner for MeanCombiner {
    fn name(&self) -> &'static str {
        "mean"
    }

    fn combine(
        &mut self,
        _tensor: usize,
        _step: u64,
        n: usize,
        payloads: &[Payload],
        compress_s: f64,
    ) -> RankRound {
        if payloads.iter().all(|p| matches!(p, Payload::Empty)) {
            // COVAP dropped tensor: empty update = "all zeros".
            return RankRound {
                update: Vec::new(),
                record: CommRecord::dense(0, compress_s),
            };
        }
        let mut update = vec![0.0f32; n];
        for p in payloads {
            match p {
                Payload::Dense(g) => {
                    for (u, &x) in update.iter_mut().zip(g.iter()) {
                        *u += x;
                    }
                }
                Payload::Half(h) => {
                    for (u, &b) in update.iter_mut().zip(h.iter()) {
                        *u += f16_to_f32(b);
                    }
                }
                other => panic!("mean combiner got {other:?}"),
            }
        }
        let inv = 1.0 / payloads.len() as f32;
        for u in &mut update {
            *u *= inv;
        }
        RankRound {
            update,
            record: CommRecord::dense(n * self.dense_bytes_per_elem, compress_s),
        }
    }

    fn reset(&mut self) {}
}

// ---- sparse (Top-k / Random-k) --------------------------------------------

struct TopKCompressor {
    ratio: f64,
    residuals: HashMap<usize, Vec<f32>>,
}

impl RankCompressor for TopKCompressor {
    fn name(&self) -> &'static str {
        "Top-k"
    }

    fn compress(&mut self, tensor: usize, _step: u64, grad: &[f32]) -> Payload {
        let n = grad.len();
        let k = k_of(self.ratio, n);
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        // acc = g + 1.0 * r, the EfState::accumulate expression
        let mut acc: Vec<f32> =
            grad.iter().zip(res.iter()).map(|(&gi, &ri)| gi + 1.0 * ri).collect();
        let thr = kth_magnitude(&acc, k);
        let (idx, val) = select_sparse(&acc, thr, k);
        for &i in &idx {
            acc[i as usize] = 0.0;
        }
        *res = acc;
        Payload::Sparse { idx, val }
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

struct RandomKCompressor {
    ratio: f64,
    seed: u64,
    residuals: HashMap<usize, Vec<f32>>,
}

impl RankCompressor for RandomKCompressor {
    fn name(&self) -> &'static str {
        "Random-k"
    }

    fn compress(&mut self, tensor: usize, step: u64, grad: &[f32]) -> Payload {
        let n = grad.len();
        let k = k_of(self.ratio, n);
        let idx = shared_indices(self.seed, tensor, step, n, k);
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        let mut acc: Vec<f32> =
            grad.iter().zip(res.iter()).map(|(&gi, &ri)| gi + 1.0 * ri).collect();
        let mut iv = Vec::with_capacity(k);
        let mut vv = Vec::with_capacity(k);
        for &i in &idx {
            iv.push(i as u32);
            vv.push(acc[i]);
            acc[i] = 0.0;
        }
        *res = acc;
        Payload::Sparse { idx: iv, val: vv }
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

/// Rank-order mean over sparse selections — mirrors `sparse_round`'s
/// `update[i] += v * inv` worker loop.
struct SparseCombiner;

impl RankCombiner for SparseCombiner {
    fn name(&self) -> &'static str {
        "sparse-gather"
    }

    fn combine(
        &mut self,
        _tensor: usize,
        _step: u64,
        n: usize,
        payloads: &[Payload],
        compress_s: f64,
    ) -> RankRound {
        let mut update = vec![0.0f32; n];
        let inv = 1.0 / payloads.len() as f32;
        let mut wire = 0usize;
        for p in payloads {
            let Payload::Sparse { idx, val } = p else {
                panic!("sparse combiner got {p:?}")
            };
            wire = wire.max(p.wire_bytes());
            for (&i, &v) in idx.iter().zip(val.iter()) {
                update[i as usize] += v * inv;
            }
        }
        RankRound {
            update,
            record: CommRecord {
                wire_bytes: wire,
                collective: Collective::AllGather,
                rounds: 1,
                sync_rounds: 0,
                compress_s,
                data_dependency: false,
            },
        }
    }

    fn reset(&mut self) {}
}

// ---- EFsignSGD ------------------------------------------------------------

struct SignCompressor {
    residuals: HashMap<usize, Vec<f32>>,
}

impl RankCompressor for SignCompressor {
    fn name(&self) -> &'static str {
        "EFsignSGD"
    }

    fn compress(&mut self, tensor: usize, _step: u64, grad: &[f32]) -> Payload {
        let n = grad.len();
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        let acc: Vec<f32> =
            grad.iter().zip(res.iter()).map(|(&gi, &ri)| gi + 1.0 * ri).collect();
        let scale = acc.iter().map(|x| x.abs()).sum::<f32>() / n as f32;
        let bits = pack_signs(&acc);
        // residual = acc - transmitted, same expression as EfSignSgd
        for (i, r) in res.iter_mut().enumerate() {
            let neg = bits[i / 64] >> (i % 64) & 1 == 1;
            let v = if neg { -scale } else { scale };
            *r = acc[i] - v;
        }
        Payload::Sign { scale, bits, n }
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

struct SignCombiner;

impl RankCombiner for SignCombiner {
    fn name(&self) -> &'static str {
        "sign-gather"
    }

    fn combine(
        &mut self,
        _tensor: usize,
        _step: u64,
        n: usize,
        payloads: &[Payload],
        compress_s: f64,
    ) -> RankRound {
        let mut update = vec![0.0f32; n];
        let inv = 1.0 / payloads.len() as f32;
        for p in payloads {
            let Payload::Sign { scale, bits, n: pn } = p else {
                panic!("sign combiner got {p:?}")
            };
            debug_assert_eq!(*pn, n);
            for (i, u) in update.iter_mut().enumerate() {
                let neg = bits[i / 64] >> (i % 64) & 1 == 1;
                let v = if neg { -*scale } else { *scale };
                *u += v * inv;
            }
        }
        RankRound {
            update,
            record: CommRecord {
                wire_bytes: n.div_ceil(8) + 4,
                collective: Collective::AllGather,
                rounds: 1,
                sync_rounds: 0,
                compress_s,
                data_dependency: false,
            },
        }
    }

    fn reset(&mut self) {}
}

// ---- replicated fallback (DGC / PowerSGD / Ok-topk) -----------------------

struct RawCompressor {
    dep: bool,
}

impl RankCompressor for RawCompressor {
    fn name(&self) -> &'static str {
        "raw"
    }

    fn compress(&mut self, _tensor: usize, _step: u64, grad: &[f32]) -> Payload {
        Payload::Dense(grad.to_vec())
    }

    fn data_dependency(&self) -> bool {
        self.dep
    }

    fn reset(&mut self) {}
}

/// Every rank holds an identical replica of the full scheme and feeds it
/// the gathered raw gradients — deterministic, hence identical state and
/// bitwise-identical output on every rank and vs the analytic backend.
struct Replicated {
    inner: Box<dyn Scheme>,
}

impl RankCombiner for Replicated {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn combine(
        &mut self,
        tensor: usize,
        step: u64,
        _n: usize,
        payloads: &[Payload],
        _compress_s: f64,
    ) -> RankRound {
        let grads: Vec<&[f32]> = payloads
            .iter()
            .map(|p| match p {
                Payload::Dense(g) => g.as_slice(),
                other => panic!("replicated combiner got {other:?}"),
            })
            .collect();
        let (update, record) = self.inner.round(tensor, step, &grads);
        RankRound { update, record }
    }

    fn reset(&mut self) {
        self.inner.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Drive P rank pairs in lockstep, exactly as the threaded executor
    /// does across threads.
    fn lockstep_round(
        pairs: &mut [(Box<dyn RankCompressor>, Box<dyn RankCombiner>)],
        tensor: usize,
        step: u64,
        grads: &[&[f32]],
    ) -> Vec<RankRound> {
        let payloads: Vec<Payload> = pairs
            .iter_mut()
            .zip(grads.iter())
            .map(|((c, _), g)| c.compress(tensor, step, g))
            .collect();
        let n = grads[0].len();
        pairs
            .iter_mut()
            .map(|(_, cb)| cb.combine(tensor, step, n, &payloads, 0.0))
            .collect()
    }

    /// THE parity guarantee: for every scheme, the per-rank path matches
    /// the replicated `Scheme::round` bit-for-bit across shapes, steps and
    /// multiple tensors, and every rank agrees with every other.
    #[test]
    fn rank_path_bitwise_matches_scheme_round() {
        for kind in SchemeKind::evaluation_set() {
            prop::check(kind.label(), 0xEC5, 6, |rng: &mut Rng| {
                let workers = 1 + rng.below(4);
                let n = 16 + rng.below(512);
                let seed = 0xABCD;
                let mut scheme = kind.build(workers, seed);
                let mut pairs: Vec<_> =
                    (0..workers).map(|_| build_rank_pair(&kind, workers, seed)).collect();
                for step in 0..6u64 {
                    for tensor in 0..2usize {
                        let gs: Vec<Vec<f32>> =
                            (0..workers).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
                        let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
                        let (want, want_rec) = scheme.round(tensor, step, &refs);
                        let rounds = lockstep_round(&mut pairs, tensor, step, &refs);
                        for (r, rr) in rounds.iter().enumerate() {
                            assert_eq!(
                                rr.update, want,
                                "{} rank {r} diverged at step {step} tensor {tensor}",
                                kind.label()
                            );
                            assert_eq!(
                                rr.record.wire_bytes, want_rec.wire_bytes,
                                "{} wire accounting rank {r}",
                                kind.label()
                            );
                            assert_eq!(rr.record.collective, want_rec.collective);
                        }
                    }
                }
            });
        }
    }

    #[test]
    fn covap_drop_rounds_are_empty_and_flush() {
        let kind = SchemeKind::Covap { interval: 3, ef: EfScheduler::constant(1.0) };
        let (mut c, mut cb) = build_rank_pair(&kind, 1, 7);
        let g = vec![1.0f32; 8];
        // tensor 0 kept at steps 0 and 3
        let p0 = c.compress(0, 0, &g);
        assert!(matches!(p0, Payload::Dense(_)));
        for step in 1..3 {
            let p = c.compress(0, step, &g);
            assert!(matches!(p, Payload::Empty));
            let r = cb.combine(0, step, 8, &[p], 0.0);
            assert!(r.update.is_empty());
            assert_eq!(r.record.wire_bytes, 0);
        }
        let p3 = c.compress(0, 3, &g);
        let r3 = cb.combine(0, 3, 8, &[p3], 0.0);
        // two dropped rounds of residual flush: 1 + 2 = 3
        assert_eq!(r3.update, vec![3.0f32; 8]);
    }

    #[test]
    fn payload_wire_bytes_match_formats() {
        assert_eq!(Payload::Empty.wire_bytes(), 0);
        assert_eq!(Payload::Dense(vec![0.0; 10]).wire_bytes(), 40);
        assert_eq!(
            Payload::Sparse { idx: vec![1, 2, 3], val: vec![0.0; 3] }.wire_bytes(),
            24
        );
        assert_eq!(Payload::Half(vec![0; 10]).wire_bytes(), 20);
        assert_eq!(Payload::Sign { scale: 1.0, bits: vec![0; 2], n: 100 }.wire_bytes(), 17);
    }

    #[test]
    fn data_dependency_only_for_oktopk() {
        for kind in SchemeKind::evaluation_set() {
            let (c, _) = build_rank_pair(&kind, 2, 1);
            let want = matches!(kind, SchemeKind::OkTopk { .. });
            assert_eq!(c.data_dependency(), want, "{}", kind.label());
        }
    }
}
