//! EFsignSGD (Karimireddy et al. 2019): transmit sign(acc) packed 1 bit per
//! gradient plus a single per-bucket scale (mean |acc|); error feedback
//! stores acc - transmitted.
//!
//! Signs are not summable, so the collective is AllGather — combined with
//! the per-element unpack cost this is why EFsignSGD lands at the bottom of
//! the paper's Table VII despite its 32x volume reduction.

use std::time::Instant;

use super::{CommRecord, Collective, EfState, Scheme};

pub struct EfSignSgd {
    ef: EfState,
}

impl EfSignSgd {
    pub fn new(workers: usize) -> EfSignSgd {
        EfSignSgd { ef: EfState::new(workers) }
    }
}

/// Pack the signs of xs into u64 words (1 = negative).
pub(crate) fn pack_signs(xs: &[f32]) -> Vec<u64> {
    let mut bits = vec![0u64; xs.len().div_ceil(64)];
    for (i, &x) in xs.iter().enumerate() {
        if x.is_sign_negative() {
            bits[i / 64] |= 1u64 << (i % 64);
        }
    }
    bits
}

impl Scheme for EfSignSgd {
    fn name(&self) -> &'static str {
        "EFsignSGD"
    }

    fn round(&mut self, bucket: usize, _step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord) {
        let n = grads[0].len();
        let t0 = Instant::now();
        let acc = self.ef.accumulate(bucket, 1.0, grads);
        let mut update = vec![0.0f32; n];
        let inv = 1.0 / grads.len() as f32;
        let mut residuals = Vec::with_capacity(acc.len());
        for a in &acc {
            let scale = a.iter().map(|x| x.abs()).sum::<f32>() / n as f32;
            let bits = pack_signs(a);
            // decompress: sign * scale; accumulate mean across workers
            let mut r = a.clone();
            for i in 0..n {
                let neg = bits[i / 64] >> (i % 64) & 1 == 1;
                let v = if neg { -scale } else { scale };
                update[i] += v * inv;
                r[i] -= v;
            }
            residuals.push(r);
        }
        self.ef.store(bucket, residuals);
        let compress_s = t0.elapsed().as_secs_f64() / grads.len() as f64;
        let rec = CommRecord {
            wire_bytes: n.div_ceil(8) + 4,
            collective: Collective::AllGather,
            rounds: 1,
            sync_rounds: 0,
            compress_s,
            data_dependency: false,
        };
        (update, rec)
    }

    fn reset(&mut self) {
        self.ef.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn sign_and_scale_roundtrip() {
        let g = vec![1.0f32, -1.0, 1.0, -1.0];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = EfSignSgd::new(1);
        let (u, rec) = s.round(0, 0, &refs);
        // |g| uniform: scale = 1, update = exact signs
        assert_eq!(u, g);
        assert_eq!(rec.wire_bytes, 1 + 4);
    }

    #[test]
    fn packs_32x_denser_than_f32() {
        let g = vec![0.5f32; 6400];
        let refs: Vec<&[f32]> = vec![&g];
        let (_, rec) = EfSignSgd::new(1).round(0, 0, &refs);
        assert_eq!(rec.wire_bytes, 800 + 4);
        assert!(rec.wire_bytes * 30 < 6400 * 4);
    }

    #[test]
    fn residual_holds_magnitude_error() {
        prop::check("efsign-residual", 33, 30, |rng: &mut Rng| {
            let n = 32 + rng.below(256);
            let g = prop::vec_f32(rng, n, 1.0);
            let refs: Vec<&[f32]> = vec![&g];
            let mut s = EfSignSgd::new(1);
            let (u, _) = s.round(0, 0, &refs);
            // transmitted + residual == original (EF identity)
            // residual = g - u (single worker), checked via second round:
            let (u2, _) = s.round(0, 1, &refs);
            // u2 = sign(g + (g - u)) * scale'; at minimum it must be finite
            assert!(u.iter().all(|x| x.is_finite()));
            assert!(u2.iter().all(|x| x.is_finite()));
        });
    }

    #[test]
    fn ef_drives_mean_error_down() {
        // Classic EF property: averaged over steps, the transmitted signal
        // tracks the true gradient despite 1-bit quantization.
        let g = vec![0.3f32, -1.7, 0.9, -0.2, 1.1, -0.6, 0.05, -2.2];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = EfSignSgd::new(1);
        let steps = 400;
        let mut sum = vec![0.0f64; g.len()];
        for step in 0..steps {
            let (u, _) = s.round(0, step, &refs);
            for (acc, x) in sum.iter_mut().zip(u.iter()) {
                *acc += *x as f64;
            }
        }
        for (i, (&total, &gi)) in sum.iter().zip(g.iter()).enumerate() {
            let mean = total / steps as f64;
            assert!(
                (mean - gi as f64).abs() < 0.12,
                "coord {i}: mean {mean} vs g {gi}"
            );
        }
    }
}
