//! EFsignSGD (Karimireddy et al. 2019): transmit sign(acc) packed 1 bit per
//! gradient plus a single per-tensor scale (mean |acc|); error feedback
//! stores acc - transmitted.
//!
//! Signs are not summable, so the collective is an AllGather of sign frames
//! folded by the shared [`SignCombiner`](super::rank) — combined with the
//! per-element unpack cost this is why EFsignSGD lands at the bottom of the
//! paper's Table VII despite its 32x volume reduction.

use std::collections::HashMap;

use super::rank::{encode_sign_into, RankCompressor, Scratch};

/// Pack the signs of xs into the caller's u64 word buffer (1 = negative),
/// cleared and resized first.
pub(crate) fn pack_signs_into(xs: &[f32], bits: &mut Vec<u64>) {
    bits.clear();
    bits.resize(xs.len().div_ceil(64), 0);
    for (i, &x) in xs.iter().enumerate() {
        if x.is_sign_negative() {
            bits[i / 64] |= 1u64 << (i % 64);
        }
    }
}

/// Allocating wrapper (tests and codec property helpers).
pub(crate) fn pack_signs(xs: &[f32]) -> Vec<u64> {
    let mut bits = Vec::new();
    pack_signs_into(xs, &mut bits);
    bits
}

/// One rank's EFsignSGD half: sign packing + this rank's residuals.
pub(crate) struct SignCompressor {
    residuals: HashMap<usize, Vec<f32>>,
}

impl SignCompressor {
    pub(crate) fn new() -> SignCompressor {
        SignCompressor { residuals: HashMap::new() }
    }
}

impl RankCompressor for SignCompressor {
    fn name(&self) -> &'static str {
        "EFsignSGD"
    }

    fn compress_into(
        &mut self,
        tensor: usize,
        _step: u64,
        grad: &[f32],
        scratch: &mut Scratch,
        frame: &mut Vec<u8>,
    ) {
        let n = grad.len();
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        scratch.acc.clear();
        scratch
            .acc
            .extend(grad.iter().zip(res.iter()).map(|(&gi, &ri)| gi + 1.0 * ri));
        let scale = scratch.acc.iter().map(|x| x.abs()).sum::<f32>() / n as f32;
        pack_signs_into(&scratch.acc, &mut scratch.bits);
        // residual = acc - transmitted
        for (i, r) in res.iter_mut().enumerate() {
            let neg = scratch.bits[i / 64] >> (i % 64) & 1 == 1;
            let v = if neg { -scale } else { scale };
            *r = scratch.acc[i] - v;
        }
        encode_sign_into(scale, &scratch.bits, n, frame);
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::rank::sign_frame_len;
    use super::super::SchemeKind;
    use super::*;

    #[test]
    fn sign_and_scale_roundtrip() {
        let g = vec![1.0f32, -1.0, 1.0, -1.0];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = SchemeKind::EfSignSgd.build(1, 0);
        let (u, rec) = s.round(0, 0, &refs);
        // |g| uniform: scale = 1, update = exact signs
        assert_eq!(u, g);
        assert_eq!(rec.wire_bytes, sign_frame_len(4));
    }

    #[test]
    fn packs_32x_denser_than_f32() {
        let g = vec![0.5f32; 6400];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = SchemeKind::EfSignSgd.build(1, 0);
        let (_, rec) = s.round(0, 0, &refs);
        assert_eq!(rec.wire_bytes, sign_frame_len(6400));
        assert!(rec.wire_bytes * 30 < 6400 * 4);
    }

    #[test]
    fn residual_holds_magnitude_error() {
        use crate::util::prop;
        use crate::util::rng::Rng;
        prop::check("efsign-residual", 33, 30, |rng: &mut Rng| {
            let n = 32 + rng.below(256);
            let g = prop::vec_f32(rng, n, 1.0);
            let refs: Vec<&[f32]> = vec![&g];
            let mut s = SchemeKind::EfSignSgd.build(1, 0);
            let (u, _) = s.round(0, 0, &refs);
            // transmitted + residual == original (EF identity)
            // residual = g - u (single worker), checked via second round:
            let (u2, _) = s.round(0, 1, &refs);
            // u2 = sign(g + (g - u)) * scale'; at minimum it must be finite
            assert!(u.iter().all(|x| x.is_finite()));
            assert!(u2.iter().all(|x| x.is_finite()));
        });
    }

    #[test]
    fn ef_drives_mean_error_down() {
        // Classic EF property: averaged over steps, the transmitted signal
        // tracks the true gradient despite 1-bit quantization.
        let g = vec![0.3f32, -1.7, 0.9, -0.2, 1.1, -0.6, 0.05, -2.2];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = SchemeKind::EfSignSgd.build(1, 0);
        let steps = 400;
        let mut sum = vec![0.0f64; g.len()];
        for step in 0..steps {
            let (u, _) = s.round(0, step, &refs);
            for (acc, x) in sum.iter_mut().zip(u.iter()) {
                *acc += *x as f64;
            }
        }
        for (i, (&total, &gi)) in sum.iter().zip(g.iter()).enumerate() {
            let mean = total / steps as f64;
            assert!(
                (mean - gi as f64).abs() < 0.12,
                "coord {i}: mean {mean} vs g {gi}"
            );
        }
    }
}
