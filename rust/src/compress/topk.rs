//! Top-k (Aji & Heafield 2017) and DGC (Lin et al. 2018) sparsification.
//!
//! Both transmit the k largest-magnitude gradients per bucket with error
//! feedback; worker index sets differ, so the wire format is AllGather
//! (idx, val) pairs. The difference the paper measures (Table II):
//! * Top-k does an exact selection — O(n) quickselect here, but the GPU
//!   `topk()` operator the paper times is far worse; either way it is the
//!   most expensive compressor.
//! * DGC estimates the threshold from a random sample (default 1%), then
//!   does one filter pass — cheaper by an order of magnitude.

use std::time::Instant;

use super::{CommRecord, Collective, EfState, Scheme};
use crate::util::rng::Rng;

/// Exact per-worker top-k with error feedback.
pub struct TopK {
    ratio: f64,
    ef: EfState,
    workers: usize,
}

impl TopK {
    pub fn new(ratio: f64, workers: usize) -> TopK {
        assert!(ratio > 0.0 && ratio <= 1.0);
        TopK { ratio, ef: EfState::new(workers), workers }
    }
}

/// k = max(1, ratio * n)
pub(crate) fn k_of(ratio: f64, n: usize) -> usize {
    ((ratio * n as f64).round() as usize).clamp(1, n)
}

/// |x| threshold such that >= k elements satisfy |x| >= t, via quickselect
/// on a scratch copy. Returns the k-th largest magnitude.
pub(crate) fn kth_magnitude(xs: &[f32], k: usize) -> f32 {
    debug_assert!(k >= 1 && k <= xs.len());
    let mut mags: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    let idx = k - 1;
    mags.select_nth_unstable_by(idx, |a, b| b.partial_cmp(a).unwrap());
    mags[idx]
}

/// One worker's sparse selection: indices with |acc| >= threshold, capped at
/// k entries (ties broken by order).
pub(crate) fn select_sparse(acc: &[f32], threshold: f32, k: usize) -> (Vec<u32>, Vec<f32>) {
    let mut idx = Vec::with_capacity(k);
    let mut val = Vec::with_capacity(k);
    for (i, &x) in acc.iter().enumerate() {
        if x.abs() >= threshold && idx.len() < k {
            idx.push(i as u32);
            val.push(x);
        }
    }
    (idx, val)
}

/// Shared round logic for Top-k / DGC given each worker's threshold rule.
fn sparse_round(
    ef: &mut EfState,
    bucket: usize,
    grads: &[&[f32]],
    thresh_of: impl Fn(&[f32], usize) -> f32,
    ratio: f64,
) -> (Vec<f32>, usize, f64) {
    let n = grads[0].len();
    let k = k_of(ratio, n);
    let t0 = Instant::now();
    let acc = ef.accumulate(bucket, 1.0, grads);
    let mut update = vec![0.0f32; n];
    let mut residuals = Vec::with_capacity(acc.len());
    let inv = 1.0 / grads.len() as f32;
    for a in &acc {
        let thr = thresh_of(a, k);
        let (idx, val) = select_sparse(a, thr, k);
        let mut r = a.clone();
        for (&i, &v) in idx.iter().zip(val.iter()) {
            update[i as usize] += v * inv;
            r[i as usize] = 0.0;
        }
        residuals.push(r);
    }
    ef.store(bucket, residuals);
    let compress_s = t0.elapsed().as_secs_f64() / grads.len() as f64;
    // wire: k (idx u32 + val f32) pairs per rank
    (update, k * 8, compress_s)
}

impl Scheme for TopK {
    fn name(&self) -> &'static str {
        "Top-k"
    }

    fn round(&mut self, bucket: usize, _step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord) {
        let _ = self.workers;
        let (update, wire, compress_s) =
            sparse_round(&mut self.ef, bucket, grads, kth_magnitude, self.ratio);
        let rec = CommRecord {
            wire_bytes: wire,
            collective: Collective::AllGather,
            rounds: 1,
            sync_rounds: 0,
            compress_s,
            data_dependency: false,
        };
        (update, rec)
    }

    fn reset(&mut self) {
        self.ef.clear();
    }
}

/// DGC: sampled-threshold top-k + error feedback.
pub struct Dgc {
    ratio: f64,
    ef: EfState,
    rng: Rng,
}

impl Dgc {
    pub fn new(ratio: f64, workers: usize, seed: u64) -> Dgc {
        assert!(ratio > 0.0 && ratio <= 1.0);
        Dgc { ratio, ef: EfState::new(workers), rng: Rng::seed(seed ^ 0xD6C) }
    }

    /// Threshold from a 1% uniform sample (min 256 elements).
    fn sampled_threshold(&mut self, xs: &[f32], k: usize) -> f32 {
        let n = xs.len();
        let sample_n = (n / 100).clamp(256.min(n), n);
        let mut sample: Vec<f32> = (0..sample_n)
            .map(|_| xs[self.rng.below(n)].abs())
            .collect();
        // k-th largest in the sample, scaled to the sample fraction.
        let ks = ((k as f64) * (sample_n as f64) / (n as f64)).round() as usize;
        let ks = ks.clamp(1, sample_n);
        sample.select_nth_unstable_by(ks - 1, |a, b| b.partial_cmp(a).unwrap());
        sample[ks - 1]
    }
}

impl Scheme for Dgc {
    fn name(&self) -> &'static str {
        "DGC"
    }

    fn round(&mut self, bucket: usize, _step: u64, grads: &[&[f32]]) -> (Vec<f32>, CommRecord) {
        // Pre-draw thresholds (borrow checker: rng is &mut self).
        let n = grads[0].len();
        let k = k_of(self.ratio, n);
        let t0 = Instant::now();
        let acc = self.ef.accumulate(bucket, 1.0, grads);
        let mut update = vec![0.0f32; n];
        let mut residuals = Vec::with_capacity(acc.len());
        let inv = 1.0 / grads.len() as f32;
        let mut sent_max = 0usize;
        for a in &acc {
            let thr = self.sampled_threshold(a, k);
            // DGC sends everything above the estimated threshold (count may
            // exceed k slightly — that is the algorithm's behaviour).
            let cap = 2 * k; // hierarchical re-selection bound
            let (idx, val) = select_sparse(a, thr, cap);
            sent_max = sent_max.max(idx.len());
            let mut r = a.clone();
            for (&i, &v) in idx.iter().zip(val.iter()) {
                update[i as usize] += v * inv;
                r[i as usize] = 0.0;
            }
            residuals.push(r);
        }
        self.ef.store(bucket, residuals);
        let compress_s = t0.elapsed().as_secs_f64() / grads.len() as f64;
        let rec = CommRecord {
            wire_bytes: sent_max * 8,
            collective: Collective::AllGather,
            rounds: 1,
            sync_rounds: 0,
            compress_s,
            data_dependency: false,
        };
        (update, rec)
    }

    fn reset(&mut self) {
        self.ef.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng as TRng;

    #[test]
    fn kth_magnitude_exact() {
        let xs = [0.1f32, -5.0, 3.0, -2.0, 0.5];
        assert_eq!(kth_magnitude(&xs, 1), 5.0);
        assert_eq!(kth_magnitude(&xs, 2), 3.0);
        assert_eq!(kth_magnitude(&xs, 5), 0.1);
    }

    #[test]
    fn topk_transmits_largest_only() {
        let g = vec![0.0f32, 10.0, 0.1, -20.0, 0.2, 0.3];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = TopK::new(2.0 / 6.0, 1);
        let (u, rec) = s.round(0, 0, &refs);
        assert_eq!(u, vec![0.0, 10.0, 0.0, -20.0, 0.0, 0.0]);
        assert_eq!(rec.wire_bytes, 2 * 8);
        assert_eq!(rec.collective, Collective::AllGather);
    }

    #[test]
    fn topk_error_feedback_recovers_small_values() {
        // A small gradient never selected still reaches the update through
        // residual accumulation once it grows past the top-k threshold.
        let mut s = TopK::new(0.25, 1); // k=1 of 4
        let g = vec![1.0f32, 0.4, 0.0, 0.0];
        let refs: Vec<&[f32]> = vec![&g];
        let mut second_slot_total = 0.0;
        for step in 0..5 {
            let (u, _) = s.round(0, step, &refs);
            second_slot_total += u[1];
        }
        assert!(second_slot_total > 0.0, "residual must eventually flush");
    }

    #[test]
    fn topk_update_mass_bounded_by_input() {
        prop::check("topk-mass", 31, 30, |rng: &mut TRng| {
            let n = 64 + rng.below(512);
            let workers = 1 + rng.below(3);
            let gs: Vec<Vec<f32>> = (0..workers).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
            let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
            let mut s = TopK::new(0.1, workers);
            let (u, _) = s.round(0, 0, &refs);
            let nz = u.iter().filter(|&&x| x != 0.0).count();
            // union of per-worker top-k: at most workers * k nonzeros
            assert!(nz <= workers * k_of(0.1, n) + 1);
        });
    }

    #[test]
    fn dgc_sends_roughly_k() {
        let mut rng = TRng::seed(5);
        let g: Vec<f32> = (0..10_000).map(|_| rng.normal() as f32).collect();
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = Dgc::new(0.01, 1, 3);
        let (u, rec) = s.round(0, 0, &refs);
        let nz = u.iter().filter(|&&x| x != 0.0).count();
        // sampled threshold: within 4x of nominal k, well below n
        assert!(nz >= 25 && nz <= 400, "nz={nz}");
        assert!(rec.wire_bytes <= 2 * 100 * 8);
    }

    #[test]
    fn dgc_cheaper_than_topk_on_large_buckets() {
        let mut rng = TRng::seed(6);
        let g: Vec<f32> = (0..2_000_000).map(|_| rng.normal() as f32).collect();
        let refs: Vec<&[f32]> = vec![&g];
        let mut topk = TopK::new(0.01, 1);
        let mut dgc = Dgc::new(0.01, 1, 3);
        let (_, r_top) = topk.round(0, 0, &refs);
        let (_, r_dgc) = dgc.round(0, 0, &refs);
        assert!(
            r_dgc.compress_s < r_top.compress_s,
            "DGC {:.4}s vs Top-k {:.4}s",
            r_dgc.compress_s,
            r_top.compress_s
        );
    }
}
