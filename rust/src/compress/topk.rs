//! Top-k (Aji & Heafield 2017) and DGC (Lin et al. 2018) sparsification.
//!
//! Both transmit the k largest-magnitude gradients per bucket with error
//! feedback; worker index sets differ, so the wire format is an AllGather
//! of sparse (idx, val) frames folded by the shared
//! [`SparseCombiner`](super::rank). The difference the paper measures
//! (Table II):
//! * Top-k does an exact selection — O(n) quickselect here, but the GPU
//!   `topk()` operator the paper times is far worse; either way it is the
//!   most expensive compressor.
//! * DGC estimates the threshold from a random sample (default 1%), then
//!   does one filter pass — cheaper by an order of magnitude. The sample
//!   is drawn from this rank's own accumulated gradient (local selection,
//!   as in GRACE), so DGC is a native per-rank scheme.

use std::collections::HashMap;

use super::rank::{Payload, RankCompressor};
use crate::util::rng::Rng;

/// k = max(1, ratio * n)
pub(crate) fn k_of(ratio: f64, n: usize) -> usize {
    ((ratio * n as f64).round() as usize).clamp(1, n)
}

/// |x| threshold such that >= k elements satisfy |x| >= t, via quickselect
/// on a scratch copy. Returns the k-th largest magnitude.
pub(crate) fn kth_magnitude(xs: &[f32], k: usize) -> f32 {
    debug_assert!(k >= 1 && k <= xs.len());
    let mut mags: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    let idx = k - 1;
    mags.select_nth_unstable_by(idx, |a, b| b.partial_cmp(a).unwrap());
    mags[idx]
}

/// One worker's sparse selection: indices with |acc| >= threshold, capped at
/// k entries (ties broken by order).
pub(crate) fn select_sparse(acc: &[f32], threshold: f32, k: usize) -> (Vec<u32>, Vec<f32>) {
    let mut idx = Vec::with_capacity(k);
    let mut val = Vec::with_capacity(k);
    for (i, &x) in acc.iter().enumerate() {
        if x.abs() >= threshold && idx.len() < k {
            idx.push(i as u32);
            val.push(x);
        }
    }
    (idx, val)
}

/// Exact per-rank top-k with error feedback.
pub(crate) struct TopKCompressor {
    ratio: f64,
    residuals: HashMap<usize, Vec<f32>>,
}

impl TopKCompressor {
    pub(crate) fn new(ratio: f64) -> TopKCompressor {
        assert!(ratio > 0.0 && ratio <= 1.0);
        TopKCompressor { ratio, residuals: HashMap::new() }
    }
}

impl RankCompressor for TopKCompressor {
    fn name(&self) -> &'static str {
        "Top-k"
    }

    fn compress(&mut self, tensor: usize, _step: u64, grad: &[f32]) -> Payload {
        let n = grad.len();
        let k = k_of(self.ratio, n);
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        // acc = g + 1.0 * r, the EF accumulate expression
        let mut acc: Vec<f32> =
            grad.iter().zip(res.iter()).map(|(&gi, &ri)| gi + 1.0 * ri).collect();
        let thr = kth_magnitude(&acc, k);
        let (idx, val) = select_sparse(&acc, thr, k);
        for &i in &idx {
            acc[i as usize] = 0.0;
        }
        *res = acc;
        Payload::Sparse { idx, val }
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

/// Threshold from a 1% uniform sample of |xs| (min 256 elements): the k-th
/// largest in the sample, scaled to the sample fraction.
fn sampled_threshold(rng: &mut Rng, xs: &[f32], k: usize) -> f32 {
    let n = xs.len();
    let sample_n = (n / 100).clamp(256.min(n), n);
    let mut sample: Vec<f32> = (0..sample_n).map(|_| xs[rng.below(n)].abs()).collect();
    let ks = ((k as f64) * (sample_n as f64) / (n as f64)).round() as usize;
    let ks = ks.clamp(1, sample_n);
    sample.select_nth_unstable_by(ks - 1, |a, b| b.partial_cmp(a).unwrap());
    sample[ks - 1]
}

/// DGC: sampled-threshold top-k + error feedback, local to this rank.
pub(crate) struct DgcCompressor {
    ratio: f64,
    /// Rank-local sampling stream. Seeded identically on every rank (the
    /// draw *count* per round is shape-determined, so streams stay aligned
    /// across ranks), but thresholds come from each rank's own values.
    rng: Rng,
    residuals: HashMap<usize, Vec<f32>>,
}

impl DgcCompressor {
    pub(crate) fn new(ratio: f64, seed: u64) -> DgcCompressor {
        assert!(ratio > 0.0 && ratio <= 1.0);
        DgcCompressor { ratio, rng: Rng::seed(seed ^ 0xD6C), residuals: HashMap::new() }
    }
}

impl RankCompressor for DgcCompressor {
    fn name(&self) -> &'static str {
        "DGC"
    }

    fn compress(&mut self, tensor: usize, _step: u64, grad: &[f32]) -> Payload {
        let n = grad.len();
        let k = k_of(self.ratio, n);
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        let mut acc: Vec<f32> =
            grad.iter().zip(res.iter()).map(|(&gi, &ri)| gi + 1.0 * ri).collect();
        let thr = sampled_threshold(&mut self.rng, &acc, k);
        // DGC sends everything above the estimated threshold (count may
        // exceed k slightly — that is the algorithm's behaviour), capped at
        // the hierarchical re-selection bound.
        let cap = 2 * k;
        let (idx, val) = select_sparse(&acc, thr, cap);
        for &i in &idx {
            acc[i as usize] = 0.0;
        }
        *res = acc;
        Payload::Sparse { idx, val }
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::rank::sparse_frame_len;
    use super::super::{Collective, SchemeKind};
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng as TRng;

    #[test]
    fn kth_magnitude_exact() {
        let xs = [0.1f32, -5.0, 3.0, -2.0, 0.5];
        assert_eq!(kth_magnitude(&xs, 1), 5.0);
        assert_eq!(kth_magnitude(&xs, 2), 3.0);
        assert_eq!(kth_magnitude(&xs, 5), 0.1);
    }

    #[test]
    fn topk_transmits_largest_only() {
        let g = vec![0.0f32, 10.0, 0.1, -20.0, 0.2, 0.3];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = SchemeKind::TopK { ratio: 2.0 / 6.0 }.build(1, 0);
        let (u, rec) = s.round(0, 0, &refs);
        assert_eq!(u, vec![0.0, 10.0, 0.0, -20.0, 0.0, 0.0]);
        assert_eq!(rec.wire_bytes, sparse_frame_len(2));
        assert_eq!(rec.collective, Collective::AllGather);
    }

    #[test]
    fn topk_error_feedback_recovers_small_values() {
        // A small gradient never selected still reaches the update through
        // residual accumulation once it grows past the top-k threshold.
        let mut s = SchemeKind::TopK { ratio: 0.25 }.build(1, 0); // k=1 of 4
        let g = vec![1.0f32, 0.4, 0.0, 0.0];
        let refs: Vec<&[f32]> = vec![&g];
        let mut second_slot_total = 0.0;
        for step in 0..5 {
            let (u, _) = s.round(0, step, &refs);
            second_slot_total += u[1];
        }
        assert!(second_slot_total > 0.0, "residual must eventually flush");
    }

    #[test]
    fn topk_update_mass_bounded_by_input() {
        prop::check("topk-mass", 31, 30, |rng: &mut TRng| {
            let n = 64 + rng.below(512);
            let workers = 1 + rng.below(3);
            let gs: Vec<Vec<f32>> = (0..workers).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
            let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
            let mut s = SchemeKind::TopK { ratio: 0.1 }.build(workers, 0);
            let (u, _) = s.round(0, 0, &refs);
            let nz = u.iter().filter(|&&x| x != 0.0).count();
            // union of per-worker top-k: at most workers * k nonzeros
            assert!(nz <= workers * k_of(0.1, n) + 1);
        });
    }

    #[test]
    fn dgc_sends_roughly_k() {
        let mut rng = TRng::seed(5);
        let g: Vec<f32> = (0..10_000).map(|_| rng.normal() as f32).collect();
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = SchemeKind::Dgc { ratio: 0.01 }.build(1, 3);
        let (u, rec) = s.round(0, 0, &refs);
        let nz = u.iter().filter(|&&x| x != 0.0).count();
        // sampled threshold: within 4x of nominal k, well below n
        assert!(nz >= 25 && nz <= 400, "nz={nz}");
        assert!(rec.wire_bytes <= sparse_frame_len(2 * 100));
    }

    #[test]
    fn dgc_cheaper_than_topk_on_large_buckets() {
        let mut rng = TRng::seed(6);
        let g: Vec<f32> = (0..2_000_000).map(|_| rng.normal() as f32).collect();
        let refs: Vec<&[f32]> = vec![&g];
        let mut topk = SchemeKind::TopK { ratio: 0.01 }.build(1, 3);
        let mut dgc = SchemeKind::Dgc { ratio: 0.01 }.build(1, 3);
        let (_, r_top) = topk.round(0, 0, &refs);
        let (_, r_dgc) = dgc.round(0, 0, &refs);
        assert!(
            r_dgc.compress_s < r_top.compress_s,
            "DGC {:.4}s vs Top-k {:.4}s",
            r_dgc.compress_s,
            r_top.compress_s
        );
    }
}
