//! Top-k (Aji & Heafield 2017) and DGC (Lin et al. 2018) sparsification.
//!
//! Both transmit the k largest-magnitude gradients per bucket with error
//! feedback; worker index sets differ, so the wire format is an AllGather
//! of sparse (idx, val) frames folded by the shared
//! [`SparseCombiner`](super::rank). The difference the paper measures
//! (Table II):
//! * Top-k does an exact selection — O(n) quickselect here, but the GPU
//!   `topk()` operator the paper times is far worse; either way it is the
//!   most expensive compressor.
//! * DGC estimates the threshold from a random sample (default 1%), then
//!   does one filter pass — cheaper by an order of magnitude. The sample
//!   is drawn from this rank's own accumulated gradient (local selection,
//!   as in GRACE), so DGC is a native per-rank scheme.
//!
//! Selection comparators use [`f32::total_cmp`], not
//! `partial_cmp(..).unwrap()`: magnitudes are non-negative, where the two
//! orders agree bit for bit, but `total_cmp` is branch-cheaper and cannot
//! panic on a NaN gradient (NaNs sort above +inf and simply fail the
//! `|x| >= threshold` filter, so a poisoned gradient degrades gracefully
//! instead of killing the rank thread — pinned by the NaN regression test).

use std::collections::HashMap;

use super::rank::{encode_sparse_into, RankCompressor, Scratch};
use crate::util::rng::Rng;

/// k = max(1, ratio * n)
pub(crate) fn k_of(ratio: f64, n: usize) -> usize {
    ((ratio * n as f64).round() as usize).clamp(1, n)
}

/// |x| threshold such that >= k elements satisfy |x| >= t, via quickselect
/// on the caller's magnitude scratch. Returns the k-th largest magnitude.
pub(crate) fn kth_magnitude_into(xs: &[f32], k: usize, mags: &mut Vec<f32>) -> f32 {
    debug_assert!(k >= 1 && k <= xs.len());
    mags.clear();
    mags.extend(xs.iter().map(|x| x.abs()));
    let idx = k - 1;
    mags.select_nth_unstable_by(idx, |a, b| b.total_cmp(a));
    mags[idx]
}

/// One worker's sparse selection into the caller's (idx, val) scratch:
/// indices with |acc| >= threshold, capped at `k` entries (ties broken by
/// order).
pub(crate) fn select_sparse_into(
    acc: &[f32],
    threshold: f32,
    k: usize,
    idx: &mut Vec<u32>,
    val: &mut Vec<f32>,
) {
    idx.clear();
    val.clear();
    for (i, &x) in acc.iter().enumerate() {
        if x.abs() >= threshold && idx.len() < k {
            idx.push(i as u32);
            val.push(x);
        }
    }
}

/// EF accumulate into the caller's scratch: `acc = g + 1.0 * r`.
fn accumulate_into(grad: &[f32], res: &[f32], acc: &mut Vec<f32>) {
    acc.clear();
    acc.extend(grad.iter().zip(res.iter()).map(|(&gi, &ri)| gi + 1.0 * ri));
}

/// Exact per-rank top-k with error feedback.
pub(crate) struct TopKCompressor {
    ratio: f64,
    residuals: HashMap<usize, Vec<f32>>,
}

impl TopKCompressor {
    pub(crate) fn new(ratio: f64) -> TopKCompressor {
        assert!(ratio > 0.0 && ratio <= 1.0);
        TopKCompressor { ratio, residuals: HashMap::new() }
    }
}

impl RankCompressor for TopKCompressor {
    fn name(&self) -> &'static str {
        "Top-k"
    }

    fn compress_into(
        &mut self,
        tensor: usize,
        _step: u64,
        grad: &[f32],
        scratch: &mut Scratch,
        frame: &mut Vec<u8>,
    ) {
        let n = grad.len();
        let k = k_of(self.ratio, n);
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        accumulate_into(grad, res, &mut scratch.acc);
        let thr = kth_magnitude_into(&scratch.acc, k, &mut scratch.mags);
        select_sparse_into(&scratch.acc, thr, k, &mut scratch.idx, &mut scratch.val);
        for &i in &scratch.idx {
            scratch.acc[i as usize] = 0.0;
        }
        // clear + extend (not copy_from_slice): adapts the residual length
        // if a tensor slot is reused with a different shape, like the old
        // `*res = acc` did; equally allocation-free once capacity is warm
        res.clear();
        res.extend_from_slice(&scratch.acc);
        encode_sparse_into(&scratch.idx, &scratch.val, frame);
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

/// Threshold from a 1% uniform sample of |xs| (min 256 elements): the k-th
/// largest in the sample, scaled to the sample fraction. Draws into the
/// caller's sample scratch.
fn sampled_threshold(rng: &mut Rng, xs: &[f32], k: usize, sample: &mut Vec<f32>) -> f32 {
    let n = xs.len();
    let sample_n = (n / 100).clamp(256.min(n), n);
    sample.clear();
    sample.extend((0..sample_n).map(|_| xs[rng.below(n)].abs()));
    let ks = ((k as f64) * (sample_n as f64) / (n as f64)).round() as usize;
    let ks = ks.clamp(1, sample_n);
    sample.select_nth_unstable_by(ks - 1, |a, b| b.total_cmp(a));
    sample[ks - 1]
}

/// DGC: sampled-threshold top-k + error feedback, local to this rank.
pub(crate) struct DgcCompressor {
    ratio: f64,
    /// Rank-local sampling stream. Seeded identically on every rank (the
    /// draw *count* per round is shape-determined, so streams stay aligned
    /// across ranks), but thresholds come from each rank's own values.
    rng: Rng,
    residuals: HashMap<usize, Vec<f32>>,
}

impl DgcCompressor {
    pub(crate) fn new(ratio: f64, seed: u64) -> DgcCompressor {
        assert!(ratio > 0.0 && ratio <= 1.0);
        DgcCompressor { ratio, rng: Rng::seed(seed ^ 0xD6C), residuals: HashMap::new() }
    }
}

impl RankCompressor for DgcCompressor {
    fn name(&self) -> &'static str {
        "DGC"
    }

    fn compress_into(
        &mut self,
        tensor: usize,
        _step: u64,
        grad: &[f32],
        scratch: &mut Scratch,
        frame: &mut Vec<u8>,
    ) {
        let n = grad.len();
        let k = k_of(self.ratio, n);
        let res = self.residuals.entry(tensor).or_insert_with(|| vec![0.0; n]);
        accumulate_into(grad, res, &mut scratch.acc);
        let thr = sampled_threshold(&mut self.rng, &scratch.acc, k, &mut scratch.mags);
        // DGC sends everything above the estimated threshold (count may
        // exceed k slightly — that is the algorithm's behaviour), capped at
        // the hierarchical re-selection bound.
        let cap = 2 * k;
        select_sparse_into(&scratch.acc, thr, cap, &mut scratch.idx, &mut scratch.val);
        for &i in &scratch.idx {
            scratch.acc[i as usize] = 0.0;
        }
        res.clear();
        res.extend_from_slice(&scratch.acc);
        encode_sparse_into(&scratch.idx, &scratch.val, frame);
    }

    fn reset(&mut self) {
        self.residuals.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::rank::sparse_frame_len;
    use super::super::{CollectiveOp, Payload, SchemeKind};
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng as TRng;

    /// Allocating wrapper for the assertions below.
    fn kth_magnitude(xs: &[f32], k: usize) -> f32 {
        kth_magnitude_into(xs, k, &mut Vec::new())
    }

    #[test]
    fn kth_magnitude_exact() {
        let xs = [0.1f32, -5.0, 3.0, -2.0, 0.5];
        assert_eq!(kth_magnitude(&xs, 1), 5.0);
        assert_eq!(kth_magnitude(&xs, 2), 3.0);
        assert_eq!(kth_magnitude(&xs, 5), 0.1);
    }

    #[test]
    fn topk_transmits_largest_only() {
        let g = vec![0.0f32, 10.0, 0.1, -20.0, 0.2, 0.3];
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = SchemeKind::TopK { ratio: 2.0 / 6.0 }.build(1, 0);
        let (u, rec) = s.round(0, 0, &refs);
        assert_eq!(u, vec![0.0, 10.0, 0.0, -20.0, 0.0, 0.0]);
        assert_eq!(rec.wire_bytes, sparse_frame_len(2));
        assert_eq!(rec.collective, CollectiveOp::AllGather);
    }

    #[test]
    fn topk_error_feedback_recovers_small_values() {
        // A small gradient never selected still reaches the update through
        // residual accumulation once it grows past the top-k threshold.
        let mut s = SchemeKind::TopK { ratio: 0.25 }.build(1, 0); // k=1 of 4
        let g = vec![1.0f32, 0.4, 0.0, 0.0];
        let refs: Vec<&[f32]> = vec![&g];
        let mut second_slot_total = 0.0;
        for step in 0..5 {
            let (u, _) = s.round(0, step, &refs);
            second_slot_total += u[1];
        }
        assert!(second_slot_total > 0.0, "residual must eventually flush");
    }

    #[test]
    fn topk_update_mass_bounded_by_input() {
        prop::check("topk-mass", 31, 30, |rng: &mut TRng| {
            let n = 64 + rng.below(512);
            let workers = 1 + rng.below(3);
            let gs: Vec<Vec<f32>> = (0..workers).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
            let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
            let mut s = SchemeKind::TopK { ratio: 0.1 }.build(workers, 0);
            let (u, _) = s.round(0, 0, &refs);
            let nz = u.iter().filter(|&&x| x != 0.0).count();
            // union of per-worker top-k: at most workers * k nonzeros
            assert!(nz <= workers * k_of(0.1, n) + 1);
        });
    }

    /// Satellite regression: a NaN gradient must flow through selection
    /// without panicking (`total_cmp` is total; the old
    /// `partial_cmp(..).unwrap()` comparators aborted the rank thread).
    #[test]
    fn nan_gradient_does_not_panic() {
        let mut g = vec![0.0f32; 512];
        for (i, x) in g.iter_mut().enumerate() {
            *x = (i as f32 * 0.37).sin();
        }
        g[13] = f32::NAN;
        g[200] = f32::NAN;
        let refs: Vec<&[f32]> = vec![&g];
        for kind in [
            SchemeKind::TopK { ratio: 0.05 },
            SchemeKind::Dgc { ratio: 0.05 },
            SchemeKind::OkTopk { ratio: 0.05 },
        ] {
            let mut s = kind.build(1, 9);
            for step in 0..3 {
                let (u, _) = s.round(0, step, &refs); // must not panic
                assert_eq!(u.len(), g.len(), "{}", kind.label());
            }
        }
        // the raw selection helpers, at NaN-dominated k
        let all_nan = vec![f32::NAN; 8];
        let thr = kth_magnitude(&all_nan, 4);
        assert!(thr.is_nan());
        let (mut idx, mut val) = (Vec::new(), Vec::new());
        select_sparse_into(&all_nan, thr, 4, &mut idx, &mut val);
        assert!(idx.is_empty(), "NaN threshold selects nothing (|x| >= NaN is false)");
    }

    /// With NaNs in the gradient, selection stays well-formed: NaN sorts
    /// above +inf in the total order, so the k-th magnitude may be NaN-free
    /// or NaN, but either way the emitted frame is a valid sparse frame of
    /// finite count that round-trips bitwise.
    #[test]
    fn nan_values_keep_frames_well_formed() {
        let mut c = TopKCompressor::new(0.5);
        let g = vec![f32::NAN, 10.0, 0.0, 0.1];
        let p = c.compress(0, 0, &g); // k=2: NaN outranks 10.0, thr = 10.0
        let Payload::Sparse { idx, val } = &p else { panic!("wrong variant") };
        // NaN fails |x| >= thr, so only the finite 10.0 is selected
        assert_eq!(idx, &[1]);
        assert_eq!(val.len(), 1);
        assert_eq!(val[0], 10.0);
        let frame = p.encode();
        assert_eq!(&Payload::decode(&frame).unwrap(), &p);
    }

    /// Reusing a tensor slot with a smaller gradient must adapt the
    /// residual length instead of panicking — the behaviour the old
    /// `*res = acc` assignment had (`copy_from_slice` would abort on the
    /// length mismatch).
    #[test]
    fn tensor_slot_shrink_does_not_panic() {
        let mut scratch = crate::compress::Scratch::new();
        let mut frame = Vec::new();
        for kind in [
            SchemeKind::TopK { ratio: 0.1 },
            SchemeKind::Dgc { ratio: 0.1 },
            SchemeKind::RandomK { ratio: 0.1 },
        ] {
            let (mut c, _) = super::super::rank::build_rank_pair(&kind, 1, 3);
            let big = vec![1.0f32; 100];
            let small = vec![2.0f32; 50];
            c.compress_into(0, 0, &big, &mut scratch, &mut frame);
            c.compress_into(0, 1, &small, &mut scratch, &mut frame); // shrink
            assert!(Payload::decode(&frame).is_ok(), "{}", kind.label());
        }
    }

    #[test]
    fn dgc_sends_roughly_k() {
        let mut rng = TRng::seed(5);
        let g: Vec<f32> = (0..10_000).map(|_| rng.normal() as f32).collect();
        let refs: Vec<&[f32]> = vec![&g];
        let mut s = SchemeKind::Dgc { ratio: 0.01 }.build(1, 3);
        let (u, rec) = s.round(0, 0, &refs);
        let nz = u.iter().filter(|&&x| x != 0.0).count();
        // sampled threshold: within 4x of nominal k, well below n
        assert!(nz >= 25 && nz <= 400, "nz={nz}");
        assert!(rec.wire_bytes <= sparse_frame_len(2 * 100));
    }

    #[test]
    fn dgc_cheaper_than_topk_on_large_buckets() {
        let mut rng = TRng::seed(6);
        let g: Vec<f32> = (0..2_000_000).map(|_| rng.normal() as f32).collect();
        let refs: Vec<&[f32]> = vec![&g];
        let mut topk = SchemeKind::TopK { ratio: 0.01 }.build(1, 3);
        let mut dgc = SchemeKind::Dgc { ratio: 0.01 }.build(1, 3);
        let (_, r_top) = topk.round(0, 0, &refs);
        let (_, r_dgc) = dgc.round(0, 0, &refs);
        assert!(
            r_dgc.compress_s < r_top.compress_s,
            "DGC {:.4}s vs Top-k {:.4}s",
            r_dgc.compress_s,
            r_top.compress_s
        );
    }
}
