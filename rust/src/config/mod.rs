//! Run configuration: JSON config files + CLI overrides -> a validated
//! [`RunConfig`]. This is the single knob surface for the trainer, the
//! examples and the bench harnesses.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::comm::TopologyKind;
use crate::compress::SchemeKind;
use crate::coordinator::membership::{
    parse_membership_schedule, world_evolution, MembershipEvent,
};
use crate::covap::EfScheduler;
use crate::network::{ClusterSpec, NetworkModel};
use crate::sim::Policy;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Optimizer selection (both are AOT artifacts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    Sgd,
    Adam,
}

/// Which execution backend runs the DP step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// In-process lockstep workers + the discrete-event timeline simulator
    /// (the original path; overlap is *predicted*).
    #[default]
    Analytic,
    /// P ranks on real OS threads (compute + comm thread each), ring
    /// collectives over channels; overlap is *measured*. Requires the
    /// synthetic model backend (see runtime).
    Threaded,
}

impl ExecBackend {
    pub fn parse(s: &str) -> Option<ExecBackend> {
        match s.to_ascii_lowercase().as_str() {
            "analytic" | "sim" => Some(ExecBackend::Analytic),
            "threaded" | "exec" => Some(ExecBackend::Threaded),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ExecBackend::Analytic => "analytic",
            ExecBackend::Threaded => "threaded",
        }
    }
}

fn policy_parse(s: &str) -> Option<Policy> {
    match s.to_ascii_lowercase().as_str() {
        "overlap" | "ovlp" => Some(Policy::Overlap),
        "sequential" | "seq" => Some(Policy::Sequential),
        _ => None,
    }
}

/// One injected straggler: rank `rank` runs its synthetic backward pass
/// `work_factor`× slower during steps `[from_step, until_step)`. Numerics
/// never change (the inflation recomputes identical values) — only the
/// measured compute time skews, which is exactly what the distributed
/// profiler's Fig. 3 alignment and the adaptive controller must absorb.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Straggler {
    pub rank: usize,
    pub work_factor: u32,
    pub from_step: u64,
    /// Exclusive; `u64::MAX` = straggles for the rest of the run.
    pub until_step: u64,
}

#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Artifact directory (artifacts/<preset>).
    pub artifacts: PathBuf,
    /// Logical DP workers (simulated ranks computing real gradients).
    pub workers: usize,
    /// Simulated cluster shape for the network model (defaults to
    /// `workers` GPUs in nodes of 8 — may be larger than `workers` when
    /// modeling big clusters).
    pub cluster: ClusterSpec,
    pub net: NetworkModel,
    pub scheme: SchemeKind,
    /// Collective topology: `ring` (flat, one level), `hier` (2-level
    /// intra/inter-node), `tree` (binomial), or `auto` (pick by
    /// `ClusterSpec` shape). Drives both the analytic pricing and the
    /// threaded executor's hop schedule + per-level pacing.
    pub topology: TopologyKind,
    pub steps: u64,
    pub lr: f32,
    pub optimizer: Optimizer,
    pub seed: u64,
    /// Bucket capacity in bytes (PyTorch DDP default: 25 MiB).
    pub bucket_bytes: usize,
    /// COVAP adaptive interval (`covap@auto`): profile CCR for this many
    /// warmup steps and set I = ceil(CCR). With any other scheme this only
    /// produces the CCR report — the configured scheme is never swapped.
    /// 0 with `covap@auto` = the engine's default warmup window.
    pub profile_steps: u64,
    /// `covap@auto` steady-state re-profiling window (steps per CCR
    /// measurement after warmup). 0 = reuse the warmup length.
    pub profile_window: u64,
    /// Consecutive windows that must propose the same *new* interval
    /// before the controller re-shards (hysteresis; >= 1).
    pub profile_hysteresis: u32,
    /// Mid-run bandwidth changes: at step `.0`, set the emulated wire
    /// (threaded pacer) and the modeled NIC rate to `.1` Gbit/s — the
    /// CCR-drift scenario knob. Rates must be > 0 (unlike `pace_gbps`,
    /// where 0 disables pacing).
    pub pace_schedule: Vec<(u64, f64)>,
    /// Per-rank straggler injection windows (synthetic backward skew).
    pub stragglers: Vec<Straggler>,
    /// Emit per-step metrics here (CSV) if set.
    pub metrics_csv: Option<PathBuf>,
    /// Emit a Perfetto-loadable Chrome Trace Event file here if set
    /// (measured per-rank spans + the predicted analytic timeline; see
    /// DESIGN.md §10). None = tracing fully off (zero cost).
    pub trace_out: Option<PathBuf>,
    /// Override the process log level (`--log-level` / `"log_level"`;
    /// otherwise the `COVAP_LOG` env var or the `info` default applies).
    pub log_level: Option<crate::obs::LogLevel>,
    /// Maps measured per-step compute wall time onto the simulated
    /// accelerator: sim_compute = wall * compute_scale. 1.0 = this CPU;
    /// ~0.01 puts the small preset's step on a V100-like timescale so the
    /// CCR regime matches the paper's (see EXPERIMENTS.md "Calibration").
    pub compute_scale: f64,
    /// Analytic (simulated) or threaded (measured) execution.
    pub backend: ExecBackend,
    /// Overlap (wait-free backprop) or sequential execution — drives both
    /// the simulator timeline and the threaded executor's queueing.
    pub policy: Policy,
    /// Threaded backend: emulated wire bandwidth in Gbit/s for ring hops
    /// (0 = move bytes at memcpy speed). Lets a fast in-process ring mimic
    /// the modeled fabric so measured and simulated breakdowns share a
    /// regime.
    pub pace_gbps: f64,
    /// Synthetic model: per-element compute inflation factor (>= 1). Does
    /// not change any numeric result, only backward-pass cost.
    pub synth_work: u32,
    /// Scripted membership events (`--membership-schedule
    /// "step:fail:rank,step:leave:rank,step:join[:count]"`): each fires at
    /// its step boundary and re-worlds the run live — residuals
    /// redistributed, hop schedule re-derived and re-verified (DESIGN.md
    /// §12). Validated against the evolving world at load time.
    pub membership_schedule: Vec<MembershipEvent>,
    /// Elastic recovery: when a rank failure is *detected* mid-run, evict
    /// the rank and re-world instead of aborting. Off by default — the
    /// pre-elastic fail-fast behavior is preserved exactly.
    pub elastic: bool,
    /// Threaded mesh: bounded receive retries before a silent peer is
    /// declared failed (0 = fail-fast on disconnect only, the default).
    pub comm_retry: u32,
    /// Threaded mesh: base receive timeout in milliseconds for the retry
    /// ladder (attempt k waits `comm_timeout_ms << k`). 0 disables
    /// timeouts entirely (blocking receives — the default).
    pub comm_timeout_ms: u64,
    /// Deterministic-timing mode (the service layer, DESIGN.md §14): when
    /// > 0, the simulated timeline prices every worker's forward/backward
    /// at exactly this many seconds — instead of the measured wall times —
    /// and compression at `model_compress_s_per_elem`, so breakdowns (and
    /// the service's virtual clocks derived from them) are
    /// bitwise-reproducible across runs. `compute_scale` is ignored in
    /// this mode. 0 = measure (the default).
    pub model_comp_s: f64,
    /// Modeled compression cost per element, seconds (only read when
    /// `model_comp_s` > 0).
    pub model_compress_s_per_elem: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts: PathBuf::from("artifacts/tiny"),
            workers: 4,
            // one simulated worker per node by default (network-bound DP);
            // use --gpus / cluster config to model bigger fleets
            cluster: ClusterSpec::new(4, 1),
            net: NetworkModel::default(),
            scheme: SchemeKind::Baseline,
            topology: TopologyKind::Auto,
            steps: 50,
            lr: 1e-3,
            optimizer: Optimizer::Adam,
            seed: 42,
            bucket_bytes: 25 * 1024 * 1024,
            profile_steps: 0,
            profile_window: 0,
            profile_hysteresis: 2,
            pace_schedule: Vec::new(),
            stragglers: Vec::new(),
            metrics_csv: None,
            trace_out: None,
            log_level: None,
            compute_scale: 1.0,
            backend: ExecBackend::Analytic,
            policy: Policy::Overlap,
            pace_gbps: 0.0,
            synth_work: 1,
            membership_schedule: Vec::new(),
            elastic: false,
            comm_retry: 0,
            comm_timeout_ms: 0,
            model_comp_s: 0.0,
            model_compress_s_per_elem: 0.0,
        }
    }
}

impl RunConfig {
    /// Load from a JSON file then apply CLI overrides.
    pub fn load(path: Option<&Path>, args: &Args) -> Result<RunConfig> {
        let mut cfg = match path {
            Some(p) => {
                let src = std::fs::read_to_string(p)
                    .with_context(|| format!("reading config {}", p.display()))?;
                Self::from_json(&Json::parse(&src)?)?
            }
            None => RunConfig::default(),
        };
        cfg.apply_args(args)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let d = RunConfig::default();
        let mut cfg = RunConfig {
            artifacts: PathBuf::from(
                j.get_or("artifacts", &Json::Str("artifacts/tiny".into())).as_str()?,
            ),
            workers: j.get_or("workers", &Json::from(d.workers)).as_usize()?,
            ..d.clone()
        };
        if let Ok(c) = j.get("cluster") {
            cfg.cluster = ClusterSpec::new(
                c.get("nodes")?.as_usize()?,
                c.get("gpus_per_node")?.as_usize()?,
            );
        } else {
            cfg.cluster = default_cluster(cfg.workers);
        }
        if let Ok(n) = j.get("network") {
            cfg.net = NetworkModel {
                nic_gbps: n.get_or("nic_gbps", &Json::from(30.0)).as_f64()?,
                efficiency: n.get_or("efficiency", &Json::from(0.32)).as_f64()?,
                latency_s: n.get_or("latency_s", &Json::from(50e-6)).as_f64()?,
                intra_gbps: n.get_or("intra_gbps", &Json::from(12.0)).as_f64()?,
            };
        }
        if let Ok(s) = j.get("scheme") {
            cfg.scheme = scheme_from_json(s)?;
        }
        if let Ok(t) = j.get("topology") {
            let s = t.as_str()?;
            cfg.topology = TopologyKind::parse(s).ok_or_else(|| {
                anyhow::anyhow!("unknown topology '{s}' (ring|hier|tree|auto)")
            })?;
        }
        cfg.steps = j.get_or("steps", &Json::from(d.steps as usize)).as_usize()? as u64;
        cfg.lr = j.get_or("lr", &Json::from(d.lr as f64)).as_f64()? as f32;
        cfg.optimizer = match j.get_or("optimizer", &Json::Str("adam".into())).as_str()? {
            "sgd" => Optimizer::Sgd,
            "adam" => Optimizer::Adam,
            o => bail!("unknown optimizer '{o}'"),
        };
        cfg.seed = j.get_or("seed", &Json::from(d.seed as usize)).as_usize()? as u64;
        cfg.bucket_bytes =
            j.get_or("bucket_bytes", &Json::from(d.bucket_bytes)).as_usize()?;
        cfg.profile_steps =
            j.get_or("profile_steps", &Json::from(d.profile_steps as usize)).as_usize()? as u64;
        cfg.profile_window =
            j.get_or("profile_window", &Json::from(d.profile_window as usize)).as_usize()? as u64;
        cfg.profile_hysteresis = j
            .get_or("profile_hysteresis", &Json::from(d.profile_hysteresis as usize))
            .as_usize()? as u32;
        if let Ok(ps) = j.get("pace_schedule") {
            for (i, row) in ps.as_arr()?.iter().enumerate() {
                let r = row.as_arr()?;
                if r.len() != 2 {
                    bail!("pace_schedule[{i}]: rows are [step, gbps]");
                }
                cfg.pace_schedule.push((r[0].as_usize()? as u64, r[1].as_f64()?));
            }
        }
        if let Ok(ss) = j.get("stragglers") {
            for row in ss.as_arr()? {
                cfg.stragglers.push(Straggler {
                    rank: row.get("rank")?.as_usize()?,
                    work_factor: row.get_or("work", &Json::from(2usize)).as_usize()? as u32,
                    from_step: row.get_or("from", &Json::from(0usize)).as_usize()? as u64,
                    until_step: match row.get("until") {
                        Ok(v) => v.as_usize()? as u64,
                        Err(_) => u64::MAX,
                    },
                });
            }
        }
        if let Ok(p) = j.get("trace_out") {
            cfg.trace_out = Some(PathBuf::from(p.as_str()?));
        }
        if let Ok(l) = j.get("log_level") {
            let s = l.as_str()?;
            cfg.log_level = Some(crate::obs::LogLevel::parse(s).ok_or_else(|| {
                anyhow::anyhow!("unknown log level '{s}' (off|error|warn|info|debug)")
            })?);
        }
        cfg.compute_scale = j.get_or("compute_scale", &Json::from(1.0)).as_f64()?;
        if let Ok(b) = j.get("backend") {
            let s = b.as_str()?;
            cfg.backend = ExecBackend::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown backend '{s}'"))?;
        }
        if let Ok(p) = j.get("policy") {
            let s = p.as_str()?;
            cfg.policy =
                policy_parse(s).ok_or_else(|| anyhow::anyhow!("unknown policy '{s}'"))?;
        }
        cfg.pace_gbps = j.get_or("pace_gbps", &Json::from(0.0)).as_f64()?;
        cfg.synth_work =
            j.get_or("synth_work", &Json::from(1usize)).as_usize()? as u32;
        if let Ok(m) = j.get("membership_schedule") {
            cfg.membership_schedule = parse_membership_schedule(m.as_str()?)?;
        }
        cfg.elastic = j.get_or("elastic", &Json::from(false)).as_bool()?;
        cfg.comm_retry =
            j.get_or("comm_retry", &Json::from(0usize)).as_usize()? as u32;
        cfg.comm_timeout_ms =
            j.get_or("comm_timeout_ms", &Json::from(0usize)).as_usize()? as u64;
        Ok(cfg)
    }

    /// CLI overrides: --artifacts --workers --scheme --steps --lr
    /// --optimizer --seed --bucket-mb --profile-steps --metrics-csv
    /// --trace-out --log-level --gpus (cluster size) --bandwidth-gbps.
    pub fn apply_args(&mut self, a: &Args) -> Result<()> {
        if let Some(v) = a.get("artifacts") {
            self.artifacts = PathBuf::from(v);
        }
        self.workers = a.get_parsed("workers", self.workers)?;
        self.cluster = default_cluster(self.workers);
        if let Some(g) = a.get("gpus") {
            let gpus: usize = g.parse().context("--gpus")?;
            self.cluster = if gpus % 8 == 0 && gpus >= 8 {
                ClusterSpec::ecs(gpus)
            } else {
                ClusterSpec::new(gpus, 1)
            };
        }
        if let Some(s) = a.get("scheme") {
            self.scheme = SchemeKind::parse(s).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scheme spec '{s}' (try e.g. covap, topk@0.05, powersgd@2)"
                )
            })?;
        }
        if let Some(i) = a.get("interval") {
            let interval: usize = i.parse().context("--interval")?;
            self.scheme = SchemeKind::Covap { interval, ef: EfScheduler::default() };
        }
        if let Some(t) = a.get("topology") {
            self.topology = TopologyKind::parse(t).ok_or_else(|| {
                anyhow::anyhow!("unknown topology '{t}' (ring|hier|tree|auto)")
            })?;
        }
        self.steps = a.get_parsed("steps", self.steps)?;
        self.lr = a.get_parsed("lr", self.lr)?;
        if let Some(o) = a.get("optimizer") {
            self.optimizer = match o {
                "sgd" => Optimizer::Sgd,
                "adam" => Optimizer::Adam,
                _ => bail!("unknown optimizer '{o}'"),
            };
        }
        self.seed = a.get_parsed("seed", self.seed)?;
        if let Some(mb) = a.get("bucket-mb") {
            let mb: f64 = mb.parse().context("--bucket-mb")?;
            self.bucket_bytes = (mb * 1024.0 * 1024.0) as usize;
        }
        self.profile_steps = a.get_parsed("profile-steps", self.profile_steps)?;
        self.profile_window = a.get_parsed("profile-window", self.profile_window)?;
        self.profile_hysteresis =
            a.get_parsed("profile-hysteresis", self.profile_hysteresis)?;
        if let Some(spec) = a.get("pace-schedule") {
            self.pace_schedule = parse_pace_schedule(spec)?;
        }
        if let Some(spec) = a.get("straggler") {
            self.stragglers = parse_stragglers(spec)?;
        }
        if let Some(p) = a.get("metrics-csv") {
            self.metrics_csv = Some(PathBuf::from(p));
        }
        if let Some(p) = a.get("trace-out") {
            self.trace_out = Some(PathBuf::from(p));
        }
        if let Some(l) = a.get("log-level") {
            self.log_level = Some(crate::obs::LogLevel::parse(l).ok_or_else(|| {
                anyhow::anyhow!("unknown log level '{l}' (off|error|warn|info|debug)")
            })?);
        }
        if let Some(bw) = a.get("bandwidth-gbps") {
            self.net.nic_gbps = bw.parse().context("--bandwidth-gbps")?;
        }
        self.compute_scale = a.get_parsed("compute-scale", self.compute_scale)?;
        if let Some(b) = a.get("backend") {
            self.backend = ExecBackend::parse(b)
                .ok_or_else(|| anyhow::anyhow!("unknown backend '{b}'"))?;
        }
        if let Some(p) = a.get("policy") {
            self.policy =
                policy_parse(p).ok_or_else(|| anyhow::anyhow!("unknown policy '{p}'"))?;
        }
        self.pace_gbps = a.get_parsed("pace-gbps", self.pace_gbps)?;
        self.synth_work = a.get_parsed("synth-work", self.synth_work)?;
        if let Some(spec) = a.get("membership-schedule") {
            self.membership_schedule = parse_membership_schedule(spec)?;
        }
        self.elastic = a.get_parsed("elastic", self.elastic)?;
        self.comm_retry = a.get_parsed("comm-retry", self.comm_retry)?;
        self.comm_timeout_ms = a.get_parsed("comm-timeout-ms", self.comm_timeout_ms)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("workers must be >= 1");
        }
        if self.bucket_bytes < 4096 {
            bail!("bucket_bytes too small ({}); min 4096", self.bucket_bytes);
        }
        if self.lr <= 0.0 || !self.lr.is_finite() {
            bail!("bad lr {}", self.lr);
        }
        if let SchemeKind::Covap { interval, .. } = &self.scheme {
            if *interval == 0 {
                bail!("covap interval must be >= 1");
            }
        }
        if self.synth_work == 0 {
            bail!("synth_work must be >= 1");
        }
        if self.pace_gbps < 0.0 || !self.pace_gbps.is_finite() {
            bail!("pace_gbps must be finite and >= 0, got {}", self.pace_gbps);
        }
        if self.profile_hysteresis == 0 {
            bail!("profile_hysteresis must be >= 1");
        }
        if self.model_comp_s < 0.0 || !self.model_comp_s.is_finite() {
            bail!("model_comp_s must be finite and >= 0, got {}", self.model_comp_s);
        }
        if self.model_compress_s_per_elem < 0.0 || !self.model_compress_s_per_elem.is_finite() {
            bail!(
                "model_compress_s_per_elem must be finite and >= 0, got {}",
                self.model_compress_s_per_elem
            );
        }
        for (i, (_, gbps)) in self.pace_schedule.iter().enumerate() {
            // strictly positive: 0 means "unpaced" for the threaded wire
            // but "zero bandwidth" (infinite time) for the α–β model — a
            // schedule entry must name a real bandwidth so both sides
            // drift together.
            if !gbps.is_finite() || *gbps <= 0.0 {
                bail!("pace_schedule[{i}]: gbps must be finite and > 0, got {gbps}");
            }
        }
        // The membership script is validated against the world it evolves
        // (ranks in range *at event time*, never-empty, ordered steps) and
        // yields the world-size bounds scenario scripts are checked
        // against: a straggler rank valid in *no* world of the run is a
        // config error; one valid only in a future (post-join) world is
        // legal but suspicious, so it warns.
        let (min_world, max_world) =
            world_evolution(self.workers, &self.membership_schedule)?;
        for s in &self.stragglers {
            if s.rank >= max_world {
                bail!(
                    "straggler rank {} out of range (workers {}, max world {})",
                    s.rank,
                    self.workers,
                    max_world
                );
            }
            if s.rank >= min_world {
                crate::log_warn!(
                    target: "config",
                    "straggler rank {} only exists in part of the run (world \
                     ranges {min_world}..={max_world} under the membership \
                     schedule); its window is inert while the rank is absent",
                    s.rank
                );
            }
            if s.work_factor == 0 {
                bail!("straggler work_factor must be >= 1");
            }
            if s.until_step <= s.from_step {
                bail!(
                    "straggler window empty: from {} until {}",
                    s.from_step,
                    s.until_step
                );
            }
        }
        // `hier` on a cluster without a second level still runs (the
        // schedule degenerates to the flat ring) but the request is
        // almost certainly a shape mistake — warn, don't fail.
        if self.topology == TopologyKind::Hier && self.cluster.nodes == 1 {
            crate::log_warn!(
                target: "config",
                "topology 'hier' on a single-node cluster ({}x{}) degenerates \
                 to the flat intra-node ring (use --gpus or a cluster config with \
                 nodes > 1 to model the hierarchy)",
                self.cluster.nodes,
                self.cluster.gpus_per_node
            );
        }
        // The silent-swap fix: profiling re-shards only covap@auto. Any
        // other scheme + profile_steps still *measures* CCR (the `profile`
        // subcommand's report) but keeps running the configured scheme.
        if self.profile_steps > 0 && !matches!(self.scheme, SchemeKind::CovapAuto { .. }) {
            crate::log_warn!(
                target: "config",
                "profile_steps={} with scheme '{}' only reports CCR; the \
                 scheme will NOT be swapped (use --scheme covap@auto for adaptive mode)",
                self.profile_steps,
                self.scheme.spec()
            );
        }
        if self.comm_retry > 0 && self.comm_timeout_ms == 0 {
            crate::log_warn!(
                target: "config",
                "comm_retry={} with comm_timeout_ms=0 is inert (blocking \
                 receives never time out; set --comm-timeout-ms > 0)",
                self.comm_retry
            );
        }
        // Scheduled membership events fire regardless of `elastic` (the
        // scripted chaos tests rely on that), but without `elastic` a
        // *detected* rank failure still aborts the run instead of
        // recovering — a combination that usually means the flag was
        // forgotten. Warn, don't fail.
        if !self.membership_schedule.is_empty() && !self.elastic {
            crate::log_warn!(
                target: "config",
                "membership_schedule has {} event(s) but elastic=false: \
                 scripted events still apply, yet detected failures abort \
                 instead of recovering (set --elastic for live recovery)",
                self.membership_schedule.len()
            );
        }
        Ok(())
    }
}

/// Parse `"step:gbps[,step:gbps...]"` into a pace schedule.
fn parse_pace_schedule(spec: &str) -> Result<Vec<(u64, f64)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let Some((at, gbps)) = part.split_once(':') else {
            bail!("--pace-schedule entries are step:gbps, got '{part}'");
        };
        out.push((
            at.trim().parse().context("--pace-schedule step")?,
            gbps.trim().parse().context("--pace-schedule gbps")?,
        ));
    }
    Ok(out)
}

/// Parse `"rank:factor[:from[:until]][,...]"` into straggler windows.
fn parse_stragglers(spec: &str) -> Result<Vec<Straggler>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let fields: Vec<&str> = part.split(':').collect();
        if fields.len() < 2 || fields.len() > 4 {
            bail!("--straggler entries are rank:factor[:from[:until]], got '{part}'");
        }
        out.push(Straggler {
            rank: fields[0].trim().parse().context("--straggler rank")?,
            work_factor: fields[1].trim().parse().context("--straggler factor")?,
            from_step: match fields.get(2) {
                Some(f) => f.trim().parse().context("--straggler from")?,
                None => 0,
            },
            until_step: match fields.get(3) {
                Some(f) => f.trim().parse().context("--straggler until")?,
                None => u64::MAX,
            },
        });
    }
    Ok(out)
}

/// Cluster shape implied by a worker count: multiples of 8 map onto the
/// paper's 8-GPU nodes, anything else is one worker per node.
pub fn default_cluster(workers: usize) -> ClusterSpec {
    if workers % 8 == 0 && workers >= 8 {
        ClusterSpec::ecs(workers)
    } else {
        // treat each simulated worker as its own node (network-bound DP)
        ClusterSpec::new(workers, 1)
    }
}

fn scheme_from_json(j: &Json) -> Result<SchemeKind> {
    // String form: a spec like "topk@0.05" (same grammar as --scheme).
    if let Json::Str(spec) = j {
        return SchemeKind::parse(spec)
            .ok_or_else(|| anyhow::anyhow!("unknown scheme spec '{spec}'"));
    }
    let name = j.get("name")?.as_str()?;
    let mut kind = SchemeKind::parse(name)
        .ok_or_else(|| anyhow::anyhow!("unknown scheme '{name}'"))?;
    match &mut kind {
        SchemeKind::Covap { interval, ef } => {
            if let Ok(i) = j.get("interval") {
                // {"name": "covap", "interval": "auto"} selects the
                // closed-loop adaptive mode (same as the covap@auto spec)
                if i.as_str().map(|s| s.eq_ignore_ascii_case("auto")).unwrap_or(false) {
                    let mut ef2 = *ef;
                    if let Ok(e) = j.get("ef") {
                        ef2 = ef_from_json(e)?;
                    }
                    return Ok(SchemeKind::CovapAuto { ef: ef2 });
                }
                *interval = i.as_usize()?;
            }
            if let Ok(e) = j.get("ef") {
                *ef = ef_from_json(e)?;
            }
        }
        SchemeKind::CovapAuto { ef } => {
            if let Ok(e) = j.get("ef") {
                *ef = ef_from_json(e)?;
            }
        }
        SchemeKind::TopK { ratio }
        | SchemeKind::Dgc { ratio }
        | SchemeKind::RandomK { ratio }
        | SchemeKind::OkTopk { ratio } => {
            if let Ok(r) = j.get("ratio") {
                *ratio = r.as_f64()?;
            }
        }
        SchemeKind::PowerSgd { rank } => {
            if let Ok(r) = j.get("rank") {
                *rank = r.as_usize()?;
            }
        }
        _ => {}
    }
    Ok(kind)
}

fn ef_from_json(e: &Json) -> Result<EfScheduler> {
    Ok(EfScheduler {
        init_value: e.get_or("init_value", &Json::from(0.1)).as_f64()? as f32,
        ascend_steps: e.get_or("ascend_steps", &Json::from(100usize)).as_usize()? as u64,
        ascend_range: e.get_or("ascend_range", &Json::from(0.09)).as_f64()? as f32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_with_scheme() {
        let j = Json::parse(
            r#"{"workers": 8, "steps": 10,
                "scheme": {"name": "covap", "interval": 3,
                           "ef": {"init_value": 0.2}},
                "network": {"nic_gbps": 100}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.cluster.world(), 8);
        assert_eq!(cfg.net.nic_gbps, 100.0);
        match cfg.scheme {
            SchemeKind::Covap { interval, ef } => {
                assert_eq!(interval, 3);
                assert!((ef.init_value - 0.2).abs() < 1e-6);
            }
            _ => panic!("wrong scheme"),
        }
    }

    #[test]
    fn cli_overrides_win() {
        let args = Args::parse(
            ["--scheme", "powersgd", "--steps", "7", "--bucket-mb", "1"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.bucket_bytes, 1024 * 1024);
        assert!(matches!(cfg.scheme, SchemeKind::PowerSgd { rank: 1 }));
    }

    #[test]
    fn scheme_spec_with_hyperparameters_parses_everywhere() {
        // CLI form
        let args = Args::parse(
            ["--scheme", "topk@0.05"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.scheme, SchemeKind::TopK { ratio: 0.05 });

        let args = Args::parse(
            ["--scheme", "powersgd@2"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.scheme, SchemeKind::PowerSgd { rank: 2 });

        // JSON string form
        let j = Json::parse(r#"{"scheme": "dgc@0.002"}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.scheme, SchemeKind::Dgc { ratio: 0.002 });

        // bad specs are rejected with an error, not silently defaulted
        let args = Args::parse(
            ["--scheme", "topk@nope"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        assert!(cfg.apply_args(&args).is_err());
    }

    #[test]
    fn interval_flag_selects_covap() {
        let args =
            Args::parse(["--interval", "5"].iter().map(|s| s.to_string())).unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert!(matches!(cfg.scheme, SchemeKind::Covap { interval: 5, .. }));
    }

    #[test]
    fn backend_and_policy_flags_parse() {
        let args = Args::parse(
            ["--backend", "threaded", "--policy", "seq", "--pace-gbps", "2.5",
             "--synth-work", "4"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.backend, ExecBackend::Threaded);
        assert_eq!(cfg.policy, Policy::Sequential);
        assert_eq!(cfg.pace_gbps, 2.5);
        assert_eq!(cfg.synth_work, 4);
        cfg.validate().unwrap();

        let j = Json::parse(r#"{"backend": "analytic", "policy": "overlap"}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.backend, ExecBackend::Analytic);
        assert_eq!(cfg.policy, Policy::Overlap);

        let mut bad = RunConfig::default();
        bad.synth_work = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn rejects_bad_values() {
        let mut cfg = RunConfig::default();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = RunConfig::default();
        cfg.lr = -1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn covap_auto_spec_parses_everywhere() {
        // CLI form
        let args = Args::parse(
            ["--scheme", "covap@auto", "--profile-steps", "4"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert!(matches!(cfg.scheme, SchemeKind::CovapAuto { .. }));
        assert_eq!(cfg.profile_steps, 4);
        cfg.validate().unwrap();

        // JSON string form
        let j = Json::parse(r#"{"scheme": "covap@auto"}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert!(matches!(cfg.scheme, SchemeKind::CovapAuto { .. }));

        // JSON object forms: name spec, and interval: "auto" with an EF block
        let j = Json::parse(
            r#"{"scheme": {"name": "covap@auto", "ef": {"init_value": 0.25}}}"#,
        )
        .unwrap();
        match RunConfig::from_json(&j).unwrap().scheme {
            SchemeKind::CovapAuto { ef } => assert!((ef.init_value - 0.25).abs() < 1e-6),
            other => panic!("wrong scheme {other:?}"),
        }
        let j = Json::parse(
            r#"{"scheme": {"name": "covap", "interval": "auto", "ef": {"init_value": 0.4}}}"#,
        )
        .unwrap();
        match RunConfig::from_json(&j).unwrap().scheme {
            SchemeKind::CovapAuto { ef } => assert!((ef.init_value - 0.4).abs() < 1e-6),
            other => panic!("wrong scheme {other:?}"),
        }
    }

    #[test]
    fn scenario_knobs_parse_from_cli_and_json() {
        let args = Args::parse(
            [
                "--pace-schedule", "30:0.25,60:2",
                "--straggler", "0:4:10:50,1:2",
                "--profile-window", "6",
                "--profile-hysteresis", "3",
            ]
            .iter()
            .map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.pace_schedule, vec![(30, 0.25), (60, 2.0)]);
        assert_eq!(
            cfg.stragglers,
            vec![
                Straggler { rank: 0, work_factor: 4, from_step: 10, until_step: 50 },
                Straggler { rank: 1, work_factor: 2, from_step: 0, until_step: u64::MAX },
            ]
        );
        assert_eq!(cfg.profile_window, 6);
        assert_eq!(cfg.profile_hysteresis, 3);
        cfg.validate().unwrap();

        let j = Json::parse(
            r#"{"workers": 4,
                "pace_schedule": [[20, 0.5]],
                "stragglers": [{"rank": 3, "work": 5, "from": 2, "until": 9}],
                "profile_window": 8, "profile_hysteresis": 1}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.pace_schedule, vec![(20, 0.5)]);
        assert_eq!(
            cfg.stragglers,
            vec![Straggler { rank: 3, work_factor: 5, from_step: 2, until_step: 9 }]
        );
        assert_eq!(cfg.profile_window, 8);
        assert_eq!(cfg.profile_hysteresis, 1);
        cfg.validate().unwrap();
    }

    #[test]
    fn scenario_knobs_validate() {
        let mut cfg = RunConfig::default(); // workers = 4
        cfg.stragglers =
            vec![Straggler { rank: 9, work_factor: 2, from_step: 0, until_step: 5 }];
        assert!(cfg.validate().is_err(), "rank out of range");

        let mut cfg = RunConfig::default();
        cfg.stragglers =
            vec![Straggler { rank: 0, work_factor: 0, from_step: 0, until_step: 5 }];
        assert!(cfg.validate().is_err(), "zero work factor");

        let mut cfg = RunConfig::default();
        cfg.stragglers =
            vec![Straggler { rank: 0, work_factor: 2, from_step: 5, until_step: 5 }];
        assert!(cfg.validate().is_err(), "empty window");

        let mut cfg = RunConfig::default();
        cfg.pace_schedule = vec![(3, f64::NAN)];
        assert!(cfg.validate().is_err(), "NaN bandwidth");

        let mut cfg = RunConfig::default();
        cfg.pace_schedule = vec![(3, 0.0)];
        assert!(
            cfg.validate().is_err(),
            "0 would mean unpaced wire but zero-bandwidth model"
        );

        let mut cfg = RunConfig::default();
        cfg.profile_hysteresis = 0;
        assert!(cfg.validate().is_err(), "zero hysteresis");

        // malformed CLI specs are rejected, not silently dropped
        let mut cfg = RunConfig::default();
        let bad = Args::parse(["--pace-schedule", "abc"].iter().map(|s| s.to_string()))
            .unwrap();
        assert!(cfg.apply_args(&bad).is_err());
        let bad =
            Args::parse(["--straggler", "1"].iter().map(|s| s.to_string())).unwrap();
        assert!(cfg.apply_args(&bad).is_err());
    }

    /// Satellite: `topology` parses from CLI and JSON (spec strings
    /// round-trip like SchemeKind's), defaults to `auto`, rejects unknown
    /// names, and `hier` on a single-node cluster still validates (warn,
    /// not error).
    #[test]
    fn topology_knob_parses_everywhere() {
        assert_eq!(RunConfig::default().topology, TopologyKind::Auto);

        // CLI form
        for (spec, want) in [
            ("ring", TopologyKind::Ring),
            ("hier", TopologyKind::Hier),
            ("tree", TopologyKind::Tree),
            ("auto", TopologyKind::Auto),
        ] {
            let args = Args::parse(
                ["--topology", spec].iter().map(|s| s.to_string()),
            )
            .unwrap();
            let mut cfg = RunConfig::default();
            cfg.apply_args(&args).unwrap();
            assert_eq!(cfg.topology, want, "--topology {spec}");
            cfg.validate().unwrap();
            // spec round-trip: what we store prints back to what parses
            assert_eq!(TopologyKind::parse(cfg.topology.spec()), Some(want));
        }

        // JSON form
        let j = Json::parse(r#"{"workers": 16, "topology": "hier"}"#).unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.topology, TopologyKind::Hier);
        assert_eq!(cfg.cluster, ClusterSpec::ecs(16));
        cfg.validate().unwrap();

        // unknown names are rejected, not silently defaulted
        let args = Args::parse(
            ["--topology", "mesh"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        assert!(cfg.apply_args(&args).is_err());
        let j = Json::parse(r#"{"topology": "mesh"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());

        // hier on a single-node cluster: warns but validates
        let mut cfg = RunConfig::default();
        cfg.cluster = ClusterSpec::new(1, 8);
        cfg.topology = TopologyKind::Hier;
        cfg.validate().unwrap();
    }

    /// Observability knobs: `--trace-out` / `--log-level` parse from CLI
    /// and JSON, default to off, and bad levels are rejected.
    #[test]
    fn observability_knobs_parse_everywhere() {
        let d = RunConfig::default();
        assert!(d.trace_out.is_none());
        assert!(d.log_level.is_none());

        let args = Args::parse(
            ["--trace-out", "out/trace.json", "--log-level", "debug"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.trace_out, Some(PathBuf::from("out/trace.json")));
        assert_eq!(cfg.log_level, Some(crate::obs::LogLevel::Debug));
        cfg.validate().unwrap();

        let j = Json::parse(
            r#"{"trace_out": "t.json", "log_level": "warn"}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.trace_out, Some(PathBuf::from("t.json")));
        assert_eq!(cfg.log_level, Some(crate::obs::LogLevel::Warn));

        // unknown levels are rejected, not silently defaulted
        let bad =
            Args::parse(["--log-level", "loud"].iter().map(|s| s.to_string())).unwrap();
        let mut cfg = RunConfig::default();
        assert!(cfg.apply_args(&bad).is_err());
        let j = Json::parse(r#"{"log_level": "loud"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    /// Elastic knobs parse from CLI and JSON and default to off (bounded
    /// retry preserves fail-fast, membership schedule empty).
    #[test]
    fn elastic_knobs_parse_everywhere() {
        let d = RunConfig::default();
        assert!(d.membership_schedule.is_empty());
        assert!(!d.elastic);
        assert_eq!((d.comm_retry, d.comm_timeout_ms), (0, 0));

        let args = Args::parse(
            [
                "--membership-schedule", "3:fail:1,6:join:2",
                "--elastic",
                "--comm-retry", "3",
                "--comm-timeout-ms", "50",
            ]
            .iter()
            .map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        assert_eq!(
            cfg.membership_schedule,
            vec![
                MembershipEvent { at_step: 3, action: crate::coordinator::membership::MembershipAction::Fail { rank: 1 } },
                MembershipEvent { at_step: 6, action: crate::coordinator::membership::MembershipAction::Join { count: 2 } },
            ]
        );
        assert!(cfg.elastic);
        assert_eq!((cfg.comm_retry, cfg.comm_timeout_ms), (3, 50));
        cfg.validate().unwrap();

        let j = Json::parse(
            r#"{"workers": 4, "membership_schedule": "2:leave:0",
                "elastic": true, "comm_retry": 2, "comm_timeout_ms": 25}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.membership_schedule.len(), 1);
        assert!(cfg.elastic);
        assert_eq!((cfg.comm_retry, cfg.comm_timeout_ms), (2, 25));
        cfg.validate().unwrap();

        // malformed scripts are rejected, not silently dropped
        let bad = Args::parse(
            ["--membership-schedule", "3:evict:1"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        assert!(cfg.apply_args(&bad).is_err());
    }

    /// Satellite regression: scenario scripts are validated against the
    /// *evolving* world, not just the starting one. A membership event
    /// naming a rank outside the world at its step is an error; a
    /// straggler rank valid in no world of the run is an error; one valid
    /// only in a future (post-join) world passes with a warning.
    #[test]
    fn membership_schedule_validates_against_evolving_world() {
        // event rank outside the world at event time (rank 1 already gone)
        let mut cfg = RunConfig { workers: 2, ..RunConfig::default() };
        cfg.membership_schedule = parse_membership_schedule("1:fail:1,2:fail:1").unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("outside the world"), "{err}");

        // straggler rank valid in *no* world -> error
        let mut cfg = RunConfig { workers: 2, ..RunConfig::default() };
        cfg.membership_schedule = parse_membership_schedule("1:join:3").unwrap();
        cfg.stragglers =
            vec![Straggler { rank: 9, work_factor: 2, from_step: 0, until_step: 5 }];
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("max world 5"), "{err}");

        // straggler rank valid only after the join -> warns but validates
        let mut cfg = RunConfig { workers: 2, ..RunConfig::default() };
        cfg.membership_schedule = parse_membership_schedule("1:join:3").unwrap();
        cfg.stragglers =
            vec![Straggler { rank: 4, work_factor: 2, from_step: 2, until_step: 5 }];
        cfg.validate().unwrap();

        // emptying the world is rejected
        let mut cfg = RunConfig { workers: 1, ..RunConfig::default() };
        cfg.membership_schedule = parse_membership_schedule("1:leave:0").unwrap();
        assert!(cfg.validate().is_err());

        // out-of-order schedules are rejected
        let mut cfg = RunConfig::default();
        cfg.membership_schedule = parse_membership_schedule("5:join,2:join").unwrap();
        assert!(cfg.validate().is_err());
    }

    /// Satellite regression: a membership schedule WITHOUT `elastic` is a
    /// warn-only combination — scripted events must keep applying (the
    /// scheduled-chaos parity tests depend on it), so validate() must
    /// return Ok, never gate behavior on the flag. The warning itself is
    /// log-only; what this pins down is that the combination stays legal
    /// in both directions.
    #[test]
    fn membership_schedule_without_elastic_is_warn_only() {
        let mut cfg = RunConfig { workers: 4, ..RunConfig::default() };
        cfg.membership_schedule = parse_membership_schedule("2:fail:1,4:join:1").unwrap();
        assert!(!cfg.elastic);
        cfg.validate().unwrap();

        // the same script with elastic on is equally fine (no warning path)
        cfg.elastic = true;
        cfg.validate().unwrap();
    }

    /// Satellite regression: a non-COVAP scheme plus profile_steps must
    /// still *validate* (warn-and-report, never swap) — the engine-side
    /// guarantee that top-k keeps running lives in the engine tests.
    #[test]
    fn profiling_with_non_covap_scheme_validates() {
        let args = Args::parse(
            ["--scheme", "topk@0.05", "--profile-steps", "20"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply_args(&args).unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.scheme, SchemeKind::TopK { ratio: 0.05 });
        assert_eq!(cfg.profile_steps, 20);
    }
}
