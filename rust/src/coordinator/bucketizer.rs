//! DDP-style gradient bucketing (Li et al., PyTorch Distributed, VLDB'20).
//!
//! Parameters are packed in *reverse registration order* (the order their
//! gradients become ready during backprop) into buckets of `cap_bytes`
//! capacity. A parameter tensor is never split (the paper's §III.C premise:
//! "the gradient tensor of one layer is used as the minimum unit"), so a
//! giant layer (VGG-19 FC1, 401 MB) yields an oversized bucket — exactly
//! the imbalance COVAP's tensor sharding then fixes.
//!
//! Close rule: a bucket is closed once its accumulated size reaches the
//! capacity (PyTorch's "at least cap" semantics), so every bucket except
//! possibly the last is >= min(cap, largest remaining param).

use crate::runtime::ParamEntry;

/// One communication bucket: a contiguous flat-vector slice (reverse-order
/// packing of contiguous params yields contiguous coverage).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bucket {
    pub id: usize,
    /// Offset into the flat parameter/gradient vector (elements).
    pub offset: usize,
    pub numel: usize,
    /// Names of the parameter tensors inside (diagnostics).
    pub params: Vec<String>,
}

impl Bucket {
    pub fn bytes(&self) -> usize {
        self.numel * 4
    }
}

/// Bucketize a manifest layer table with capacity `cap_bytes`.
/// Returns buckets in communication order (bucket 0 = last layers = first
/// gradients ready).
pub fn bucketize(params: &[ParamEntry], cap_bytes: usize) -> Vec<Bucket> {
    let items: Vec<(String, usize, usize)> =
        params.iter().map(|p| (p.name.clone(), p.offset, p.numel)).collect();
    bucketize_items(&items, cap_bytes)
}

/// Bucketize a plain (name, numel) layer list (workload descriptors).
/// Offsets are synthesized front-to-back.
pub fn bucketize_layers(layers: &[(String, usize)], cap_bytes: usize) -> Vec<Bucket> {
    let mut off = 0;
    let items: Vec<(String, usize, usize)> = layers
        .iter()
        .map(|(name, numel)| {
            let it = (name.clone(), off, *numel);
            off += numel;
            it
        })
        .collect();
    bucketize_items(&items, cap_bytes)
}

fn bucketize_items(items: &[(String, usize, usize)], cap_bytes: usize) -> Vec<Bucket> {
    assert!(cap_bytes >= 4);
    let cap_elems = cap_bytes / 4;
    let mut buckets = Vec::new();
    let mut cur: Vec<&(String, usize, usize)> = Vec::new();
    let mut cur_numel = 0usize;

    let mut flush = |cur: &mut Vec<&(String, usize, usize)>, cur_numel: &mut usize| {
        if cur.is_empty() {
            return;
        }
        // reverse traversal: the last-added param has the lowest offset
        let offset = cur.last().unwrap().1;
        let numel = *cur_numel;
        buckets.push(Bucket {
            id: 0, // assigned below
            offset,
            numel,
            params: cur.iter().map(|(n, _, _)| n.clone()).collect(),
        });
        cur.clear();
        *cur_numel = 0;
    };

    for item in items.iter().rev() {
        cur.push(item);
        cur_numel += item.2;
        if cur_numel >= cap_elems {
            flush(&mut cur, &mut cur_numel);
        }
    }
    flush(&mut cur, &mut cur_numel);

    for (i, b) in buckets.iter_mut().enumerate() {
        b.id = i;
    }
    buckets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn entries(sizes: &[usize]) -> Vec<(String, usize)> {
        sizes.iter().enumerate().map(|(i, &n)| (format!("p{i}"), n)).collect()
    }

    #[test]
    fn packs_reverse_order() {
        // layers [a:10, b:10, c:10], cap 20 elems (80 bytes):
        // reverse: c, b -> bucket0 (>=20 close); a -> bucket1
        let b = bucketize_layers(&entries(&[10, 10, 10]), 80);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].params, vec!["p2", "p1"]);
        assert_eq!(b[0].offset, 10);
        assert_eq!(b[0].numel, 20);
        assert_eq!(b[1].params, vec!["p0"]);
        assert_eq!(b[1].offset, 0);
    }

    #[test]
    fn oversized_layer_gets_own_bucket() {
        let b = bucketize_layers(&entries(&[5, 1000, 5]), 80);
        // reverse: p2 (5) -> open; p1 (1000) joins p2's bucket and closes it
        // immediately (>= cap); p0 -> last bucket.
        assert_eq!(b.len(), 2);
        assert!(b[0].numel >= 1000);
    }

    #[test]
    fn buckets_partition_flat_vector() {
        prop::check("bucket-partition", 61, 200, |rng: &mut Rng| {
            let n = 1 + rng.below(40);
            let sizes: Vec<usize> = (0..n).map(|_| 1 + rng.below(10_000)).collect();
            let total: usize = sizes.iter().sum();
            let cap = 4 * (1 + rng.below(20_000));
            let buckets = bucketize_layers(&entries(&sizes), cap);
            // communication order is reverse flat order: bucket i starts
            // where bucket i+1 ends... verify exact tiling.
            let mut covered = vec![false; total];
            for b in &buckets {
                for i in b.offset..b.offset + b.numel {
                    assert!(!covered[i], "overlap at {i}");
                    covered[i] = true;
                }
            }
            assert!(covered.iter().all(|&c| c), "gap in coverage");
            // every param name appears exactly once
            let names: usize = buckets.iter().map(|b| b.params.len()).sum();
            assert_eq!(names, n);
        });
    }

    #[test]
    fn all_but_last_bucket_reach_cap() {
        prop::check("bucket-cap", 62, 100, |rng: &mut Rng| {
            let n = 1 + rng.below(30);
            let sizes: Vec<usize> = (0..n).map(|_| 1 + rng.below(5000)).collect();
            let cap_elems = 1 + rng.below(8000);
            let buckets = bucketize_layers(&entries(&sizes), cap_elems * 4);
            for b in &buckets[..buckets.len().saturating_sub(1)] {
                assert!(b.numel >= cap_elems, "non-final bucket under cap");
            }
        });
    }

    #[test]
    fn vgg19_bucket_count_plausible() {
        // 25 MB cap over VGG-19 -> a handful of buckets, dominated by FC1's
        // giant bucket (the paper observed 6).
        let w = crate::workload::vgg19();
        let layers: Vec<(String, usize)> =
            w.layers.iter().map(|l| (l.name.clone(), l.numel)).collect();
        let buckets = bucketize_layers(&layers, 25 * 1024 * 1024);
        assert!(
            (4..=9).contains(&buckets.len()),
            "VGG-19 bucket count {} (paper: 6)",
            buckets.len()
        );
        let max = buckets.iter().map(|b| b.numel).max().unwrap();
        assert!(max > 100_000_000, "FC1 dominates the largest bucket");
    }
}
