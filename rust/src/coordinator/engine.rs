//! The data-parallel training engine.
//!
//! P logical workers each run the model backend (PJRT artifact or the
//! synthetic-gradient model) on their own data shard (real numerics);
//! per-bucket (or per-shard, once COVAP sharding is active) gradients go
//! through the configured compression scheme; the reduced gradient feeds
//! the optimizer. Every step also produces the simulated cluster-time
//! breakdown via the overlap timeline — and, under
//! [`ExecBackend::Threaded`], the *measured* breakdown from the threaded
//! rank executor, so predictions and reality sit side by side.
//!
//! The two backends are numerically bit-identical *structurally*: the
//! per-rank compressor/combiner pairs (`compress::rank`) are the single
//! implementation of every scheme — the analytic path drives them in
//! lockstep through `compress::LockstepDriver`, the threaded path drives
//! the same pairs concurrently — and the executor still cross-checks every
//! rank's reduced gradient by checksum each step. Wire accounting in both
//! backends is the measured encoded-frame length of each payload.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::analysis::verify_schedule;
use crate::comm::topology::{Collective, LevelBytes};
use crate::compress::{CommRecord, Scheme, SchemeKind};
use crate::config::{ExecBackend, Optimizer, RunConfig};
use crate::coordinator::bucketizer::{bucketize, Bucket};
use crate::coordinator::membership::{
    export_skip, generation_seed, next_cluster, redistribute, validated_next_world,
    MembershipAction,
};
use crate::covap::{shard_buckets, EfScheduler, IntervalController, IntervalDecision};
use crate::data::{DataShard, SyntheticCorpus};
use crate::exec::{
    MeasuredBreakdown, PacerSet, RankFailure, RankTimeline, RetryPolicy, Span, SpanKind,
    ThreadedExec,
};
use crate::network::ClusterSpec;
use crate::obs::log::{emit_kv, LogLevel};
use crate::obs::{registry, TraceBuilder, TID_COMM, TID_COMPUTE};
use crate::profiler::{Event, EventKind, Profile};
use crate::runtime::ModelArtifacts;
use crate::sim::{simulate_iteration_on, simulate_iteration_spans, Breakdown, TensorCost};
use crate::util::json::Json;

/// Default warmup window (steps) when `covap@auto` runs without an
/// explicit `profile_steps`.
const DEFAULT_WARMUP_STEPS: u64 = 8;

/// What one backend step hands back to the engine: per-worker losses and
/// compute walls, per-tensor records, the reduced gradient, and — threaded
/// only — the measured breakdown + per-rank span timelines.
type StepData = (
    Vec<f32>,
    Vec<f64>,
    Vec<CommRecord>,
    Vec<f32>,
    Option<MeasuredBreakdown>,
    Option<Vec<RankTimeline>>,
);

/// A communication tensor: a bucket or a COVAP shard of one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommTensor {
    /// Absolute offset into the flat gradient vector.
    pub offset: usize,
    pub numel: usize,
    /// Source bucket id (diagnostics).
    pub bucket: usize,
}

/// Per-step output.
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub step: u64,
    /// Mean worker loss.
    pub loss: f32,
    /// Wall time of the whole step on this testbed.
    pub wall_s: f64,
    /// Simulated cluster breakdown (Eq. 3/4/6 timeline).
    pub breakdown: Breakdown,
    /// Measured breakdown from the threaded executor (None on Analytic).
    pub measured: Option<MeasuredBreakdown>,
    /// Total wire bytes per rank this step.
    pub wire_bytes: usize,
    /// The collective traffic split by link level: bytes the *busiest*
    /// rank sends over intra- vs inter-node links rotating this step's
    /// frames through the configured topology (summed record accounting;
    /// maxima per level taken independently, so the two columns may
    /// belong to different ranks).
    pub wire_levels: LevelBytes,
    /// Summed per-tensor compression overhead (per-worker mean).
    pub compress_s: f64,
}

pub struct DpEngine {
    pub cfg: RunConfig,
    arts: ModelArtifacts,
    scheme: Box<dyn Scheme>,
    buckets: Vec<Bucket>,
    tensors: Vec<CommTensor>,
    shards: Vec<DataShard>,
    /// Replicated model state (identical across workers in synchronous DP,
    /// so stored once).
    params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u64,
    /// The resolved collective topology (from `cfg.topology` + cluster).
    topo: &'static dyn Collective,
    /// Worst-rank per-level hop *counts* through the topology's schedule
    /// over the *modeled* cluster (independent maxima — the busiest NIC
    /// and the busiest PCIe lane) — the per-level wire accounting both
    /// backends stamp into their records (levels = counts × frame
    /// length), precomputed once so stamping is two multiplications.
    acct_hops: LevelBytes,
    /// The threaded rank executor (ExecBackend::Threaded only).
    exec: Option<ThreadedExec>,
    /// Profile of warmup steps (the CCR report; any scheme).
    profile: Profile,
    /// The closed-loop interval controller (`covap@auto` only — profiling
    /// never swaps any other configured scheme).
    controller: Option<IntervalController>,
    /// Effective per-rank synth_work currently applied (straggler windows).
    rank_work: Vec<u32>,
    /// Chosen interval once profiling concludes (COVAP adaptive mode).
    pub chosen_interval: Option<usize>,
    /// Perfetto trace accumulator (only when `cfg.trace_out` is set —
    /// tracing is strictly zero-cost otherwise).
    trace: Option<TraceBuilder>,
    /// The most recent combined update (bitwise-identical on both
    /// backends) — the deterministic surrogate for a *failed* rank's
    /// unrecoverable EF residuals (DESIGN.md §12).
    last_combined: Vec<f32>,
    /// World generation: bumped by every membership event. Mixed into the
    /// post-event shard/scheme seed so a re-world never replays the
    /// pre-event data stream — identically on both backends.
    generation: u64,
    /// Cursor into `cfg.membership_schedule` (events already fired).
    membership_idx: usize,
    /// Analytic-backend injected failure, surfaced at the next `step()`
    /// exactly like a detected threaded failure (parity for chaos tests).
    pending_failure: Option<(usize, String)>,
}

impl DpEngine {
    pub fn new(mut cfg: RunConfig, mut arts: ModelArtifacts) -> Result<DpEngine> {
        arts.set_synth_work(cfg.synth_work);
        let manifest = &arts.manifest;
        let n = manifest.param_count;
        let dims = manifest.dims.clone();
        ensure!(cfg.workers >= 1);

        let buckets = bucketize(&manifest.params, cfg.bucket_bytes);
        let tensors = plain_tensors(&buckets);

        // covap@auto always profiles: default the warmup window if unset,
        // and spin up the closed-loop controller (warmup -> windowed
        // re-profiling with hysteresis).
        let controller = if matches!(cfg.scheme, SchemeKind::CovapAuto { .. }) {
            if cfg.profile_steps == 0 {
                cfg.profile_steps = DEFAULT_WARMUP_STEPS;
            }
            let warmup = cfg.profile_steps;
            let window = if cfg.profile_window > 0 { cfg.profile_window } else { warmup };
            Some(IntervalController::new(
                cfg.workers,
                1,
                warmup,
                window,
                cfg.profile_hysteresis.max(1),
            ))
        } else {
            None
        };

        let corpus = SyntheticCorpus::new(dims.vocab);
        let make_shards = || -> Vec<DataShard> {
            (0..cfg.workers)
                .map(|w| {
                    DataShard::new(corpus.clone(), cfg.seed, w, dims.batch, dims.seq_len + 1)
                })
                .collect()
        };
        let shards = make_shards();

        let params = init_params(manifest, cfg.seed);
        let scheme = cfg.scheme.build(cfg.workers, cfg.seed);

        // Resolve the collective topology once: `auto` picks by cluster
        // shape. The accounting schedule covers the modeled cluster; the
        // executor's schedule must cover exactly `workers` ranks, so when
        // the modeled cluster is bigger than the rank fleet it falls back
        // to one-rank-per-node grouping (every hop inter-node — the
        // pre-topology behavior).
        let topo = cfg.topology.resolve(cfg.cluster);
        let acct_hops = topo.allgather_schedule(cfg.cluster).max_level_hops();
        let exec = match cfg.backend {
            ExecBackend::Analytic => None,
            ExecBackend::Threaded => {
                let models = arts.rank_models(cfg.workers)?;
                let pacers = PacerSet::from_net(cfg.pace_gbps, &cfg.net);
                let exec_cluster = if cfg.cluster.world() == cfg.workers {
                    cfg.cluster
                } else {
                    ClusterSpec::new(cfg.workers, 1)
                };
                let sched = Arc::new(
                    cfg.topology.resolve(exec_cluster).allgather_schedule(exec_cluster),
                );
                let retry = RetryPolicy {
                    retries: cfg.comm_retry,
                    timeout_ms: cfg.comm_timeout_ms,
                };
                // the executor gets its own identical shard streams; the
                // engine's copies go unused in this mode
                Some(ThreadedExec::with_state(
                    cfg.scheme.clone(),
                    cfg.seed,
                    models,
                    make_shards(),
                    sched,
                    pacers,
                    retry,
                    (0..cfg.workers).map(|_| None).collect(),
                    Vec::new(),
                ))
            }
        };

        Ok(DpEngine {
            rank_work: vec![cfg.synth_work; cfg.workers],
            profile: Profile::for_world(cfg.workers),
            trace: cfg.trace_out.as_ref().map(|_| TraceBuilder::new()),
            cfg,
            arts,
            scheme,
            buckets,
            tensors,
            shards,
            params: params.clone(),
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
            topo,
            acct_hops,
            exec,
            controller,
            chosen_interval: None,
            last_combined: Vec::new(),
            generation: 0,
            membership_idx: 0,
            pending_failure: None,
        })
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    pub fn tensors(&self) -> &[CommTensor] {
        &self.tensors
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Run one synchronous DP step.
    pub fn step(&mut self) -> Result<StepOutput> {
        let wall0 = Instant::now();
        // ---- elastic membership: scheduled events land on this step
        // boundary, before any rank computes (DESIGN.md §12) ----
        while let Some(ev) = self.cfg.membership_schedule.get(self.membership_idx).copied()
        {
            if ev.at_step > self.step {
                break;
            }
            self.membership_idx += 1;
            self.apply_membership(ev.action)?;
        }
        // analytic-backend injected failure: surfaces here exactly like a
        // detected threaded one — recover when elastic, abort otherwise
        if let Some((rank, reason)) = self.pending_failure.take() {
            if self.cfg.elastic {
                self.apply_membership(MembershipAction::Fail { rank })?;
            } else {
                return Err(RankFailure {
                    rank,
                    step: self.step,
                    during: false,
                    reason,
                }
                .into());
            }
        }
        // remember whether a scheduled pacer change fires this step (the
        // trace marks it as an instant event)
        let pace_event = self
            .cfg
            .pace_schedule
            .iter()
            .find(|(at, _)| *at == self.step)
            .map(|&(_, gbps)| gbps);
        self.apply_scenario();
        let attempt = if self.exec.is_some() {
            self.step_threaded()
        } else {
            self.step_analytic()
        };
        let (losses, comp_walls, mut records, reduced, measured, timelines) = match attempt
        {
            Ok(data) => data,
            // Elastic recovery: a detected rank failure aborted the
            // in-flight step before any rank applied it (the barrier
            // poison makes survivors skip it bitwise-uniformly), so evict
            // the dead rank, re-world, and run the step on the new fleet.
            Err(e) => {
                let detected = match e.downcast_ref::<RankFailure>() {
                    Some(f) if self.cfg.elastic => Some((f.rank, f.reason.clone())),
                    _ => None,
                };
                let Some((rank, reason)) = detected else { return Err(e) };
                crate::log_warn!(
                    target: "membership",
                    "rank {rank} failed at step {} ({reason}): evicting and \
                     re-worlding instead of aborting",
                    self.step
                );
                self.apply_membership(MembershipAction::Fail { rank })?;
                if self.exec.is_some() {
                    self.step_threaded()?
                } else {
                    self.step_analytic()?
                }
            }
        };
        // retain the combined update: the deterministic surrogate for a
        // failed rank's unrecoverable residuals (identical on both
        // backends, so parity survives a crash)
        self.last_combined.clear();
        self.last_combined.extend_from_slice(&reduced);

        // Per-level wire accounting: route every record's measured frame
        // length through the topology's hop schedule over the modeled
        // cluster. Combiners cannot see the topology, so the engine
        // stamps this — with the same arithmetic on both backends, which
        // keeps the records backend-identical.
        for r in &mut records {
            r.levels = LevelBytes {
                intra: self.acct_hops.intra * r.wire_bytes,
                inter: self.acct_hops.inter * r.wire_bytes,
            };
        }

        // ---- optimizer ----
        self.apply_update(&reduced)?;

        // ---- simulated timeline (both backends, for cross-validation);
        // predicted spans are collected only when tracing is active ----
        let mut sim_spans: Vec<Span> = Vec::new();
        let breakdown = if self.trace.is_some() {
            self.simulate_spans(&comp_walls, &records, &mut sim_spans)
        } else {
            self.simulate(&comp_walls, &records)
        };

        // ---- profiling: measured spans (threaded) or the modeled dense
        // collective (analytic) — built only when someone consumes them
        // (warmup report and/or the adaptive controller) ----
        let profiling = self.cfg.profile_steps > 0 && self.step < self.cfg.profile_steps;
        let events = if profiling || self.controller.is_some() {
            self.step_events(&comp_walls, timelines.as_deref())
        } else {
            Vec::new()
        };
        if profiling {
            for e in &events {
                self.profile.record(e.clone());
            }
        }

        let wire_bytes: usize = records.iter().map(|r| r.wire_bytes).sum();
        let mut wire_levels = LevelBytes::default();
        for r in &records {
            wire_levels.intra += r.levels.intra;
            wire_levels.inter += r.levels.inter;
        }
        let compress_s: f64 = records.iter().map(|r| r.compress_s).sum();
        let loss = losses.iter().sum::<f32>() / losses.len() as f32;
        let out = StepOutput {
            step: self.step,
            loss,
            wall_s: wall0.elapsed().as_secs_f64(),
            breakdown,
            measured,
            wire_bytes,
            wire_levels,
            compress_s,
        };
        let step_now = self.step;
        self.step += 1;

        // ---- the closed adaptive loop (covap@auto only) ----
        let mut decision: Option<IntervalDecision> = None;
        if let Some(mut ctrl) = self.controller.take() {
            for e in events {
                ctrl.record(e);
            }
            let dense_bytes: usize = self.tensors.iter().map(|t| t.numel * 4).sum();
            // Under the threaded backend the events are measurements of
            // the *compressed* traffic, so the controller rescales by
            // dense/wire; the analytic events already model the dense
            // collective, so the scale must stay 1.
            let ctrl_wire = if timelines.is_some() { wire_bytes } else { dense_bytes };
            let hist_before = ctrl.history().len();
            let switch = ctrl.end_step(step_now, ctrl_wire, dense_bytes);
            if ctrl.history().len() > hist_before {
                decision = ctrl.history().last().copied();
            }
            if ctrl.concluded() {
                self.chosen_interval = Some(ctrl.current_interval());
            }
            self.controller = Some(ctrl);
            if let Some(interval) = switch {
                self.set_covap_interval(interval);
            }
        }

        self.record_obs(
            step_now,
            &out,
            &records,
            &comp_walls,
            timelines.as_deref(),
            &sim_spans,
            pace_event,
            decision,
        );
        Ok(out)
    }

    fn step_analytic(&mut self) -> Result<StepData> {
        let n = self.params.len();
        let dims = self.arts.manifest.dims.clone();

        // ---- per-worker forward/backward (real gradients) ----
        let mut losses = Vec::with_capacity(self.cfg.workers);
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(self.cfg.workers);
        let mut comp_walls = Vec::with_capacity(self.cfg.workers);
        for w in 0..self.cfg.workers {
            let batch = self.shards[w].next_batch();
            // straggler windows skew this worker's wall time, never values
            self.arts.set_synth_work(self.rank_work[w]);
            let t0 = Instant::now();
            let (loss, g) =
                self.arts.run_fwd_bwd(&self.params, &batch, dims.batch, dims.seq_len + 1)?;
            comp_walls.push(t0.elapsed().as_secs_f64());
            ensure!(g.len() == n, "gradient length mismatch");
            losses.push(loss);
            grads.push(g);
        }

        // ---- per-tensor compression + collective ----
        let mut reduced = vec![0.0f32; n];
        let mut records: Vec<CommRecord> = Vec::with_capacity(self.tensors.len());
        for (t_idx, t) in self.tensors.iter().enumerate() {
            let slices: Vec<&[f32]> = grads
                .iter()
                .map(|g| &g[t.offset..t.offset + t.numel])
                .collect();
            let (update, rec) = self.scheme.round(t_idx, self.step, &slices);
            // empty update = scheme transmitted nothing (COVAP dropped
            // tensor); `reduced` is already zeroed there.
            if !update.is_empty() {
                reduced[t.offset..t.offset + t.numel].copy_from_slice(&update);
            }
            records.push(rec);
        }
        Ok((losses, comp_walls, records, reduced, None, None))
    }

    fn step_threaded(&mut self) -> Result<StepData> {
        let Some(exec) = self.exec.as_mut() else {
            bail!("step_threaded called without a threaded backend");
        };
        let out = exec.step(
            self.step,
            Arc::new(self.params.clone()),
            Arc::new(self.tensors.clone()),
            self.cfg.policy,
        )?;
        Ok((
            out.losses,
            out.comp_walls,
            out.records,
            out.reduced,
            Some(out.measured),
            Some(out.timelines),
        ))
    }

    /// Set the effective wire bandwidth (Gbit/s) this engine sees from the
    /// next step on: the α–β model's NIC rate for analytic pricing and the
    /// threaded pacers for the measured wire, exactly as if a
    /// `pace_schedule` entry fired this step. The service layer's
    /// contention model (DESIGN.md §14) calls this between steps as jobs
    /// sharing the fabric arrive and depart; like a scheduled pace change
    /// it never changes numeric results, only timing.
    pub fn set_effective_pace(&mut self, gbps: f64) {
        if self.cfg.pace_gbps == gbps {
            return;
        }
        self.cfg.pace_gbps = gbps;
        self.cfg.net.nic_gbps = gbps;
        if let Some(exec) = &self.exec {
            exec.set_pacers(PacerSet::from_net(gbps, &self.cfg.net));
        }
    }

    /// Current effective wire bandwidth in Gbit/s (base rate until a pace
    /// event or [`DpEngine::set_effective_pace`] changes it).
    pub fn effective_pace(&self) -> f64 {
        self.cfg.pace_gbps
    }

    /// Apply this step's scenario knobs before executing it: scheduled
    /// bandwidth changes hit both the threaded pacer and the α–β model's
    /// NIC rate (so measured *and* modeled CCR drift together), straggler
    /// windows skew per-rank synthetic compute cost. Neither ever changes
    /// numeric results.
    fn apply_scenario(&mut self) {
        let step = self.step;
        for i in 0..self.cfg.pace_schedule.len() {
            let (at, gbps) = self.cfg.pace_schedule[i];
            if at == step {
                self.set_effective_pace(gbps);
            }
        }
        if self.cfg.stragglers.is_empty() {
            return;
        }
        for w in 0..self.cfg.workers {
            let mut work = self.cfg.synth_work;
            for s in &self.cfg.stragglers {
                if s.rank == w && step >= s.from_step && step < s.until_step {
                    work = work.saturating_mul(s.work_factor);
                }
            }
            if self.rank_work[w] != work {
                self.rank_work[w] = work;
                if let Some(exec) = &self.exec {
                    exec.set_rank_work(w, work);
                }
            }
        }
    }

    fn apply_update(&mut self, grads: &[f32]) -> Result<()> {
        match self.cfg.optimizer {
            Optimizer::Sgd => {
                self.params = self.arts.run_sgd(&self.params, grads, self.cfg.lr)?;
            }
            Optimizer::Adam => {
                let (p, m, v) = self.arts.run_adam(
                    &self.params,
                    &self.m,
                    &self.v,
                    grads,
                    self.step as i32 + 1,
                    self.cfg.lr,
                )?;
                self.params = p;
                self.m = m;
                self.v = v;
            }
        }
        Ok(())
    }

    /// Build the simulated iteration timeline. Computation time per tensor:
    /// the paper's Table-I-style T_comp split across tensors by size; we use
    /// the *measured* mean worker fwd_bwd wall time as (T_before + T_comp)
    /// with the Bert-like 80/170 split.
    fn simulate(&self, comp_walls: &[f64], records: &[CommRecord]) -> Breakdown {
        let (t_before, costs) = self.tensor_costs(comp_walls, records);
        simulate_iteration_on(
            self.topo,
            &self.cfg.net,
            self.cfg.cluster,
            t_before,
            &costs,
            self.cfg.policy,
        )
    }

    /// [`Self::simulate`] while also collecting the predicted per-tensor
    /// spans — the analytic timeline the trace exporter overlays against
    /// measurements.
    fn simulate_spans(
        &self,
        comp_walls: &[f64],
        records: &[CommRecord],
        spans: &mut Vec<Span>,
    ) -> Breakdown {
        let (t_before, costs) = self.tensor_costs(comp_walls, records);
        simulate_iteration_spans(
            self.topo,
            &self.cfg.net,
            self.cfg.cluster,
            t_before,
            &costs,
            self.cfg.policy,
            spans,
        )
    }

    fn tensor_costs(
        &self,
        comp_walls: &[f64],
        records: &[CommRecord],
    ) -> (f64, Vec<TensorCost>) {
        // model_comp_s > 0: deterministic-timing mode (the service layer)
        // prices compute/compression from the model instead of measured
        // walls, so the breakdown is bitwise-reproducible across runs.
        let modeled = self.cfg.model_comp_s > 0.0;
        let mean_wall = if modeled {
            self.cfg.model_comp_s
        } else {
            comp_walls.iter().sum::<f64>() / comp_walls.len() as f64 * self.cfg.compute_scale
        };
        let t_before = mean_wall * 0.32; // fwd ~1/3, bwd ~2/3
        let t_comp_total = mean_wall - t_before;
        let total_elems: usize = self.tensors.iter().map(|t| t.numel).sum();
        let costs: Vec<TensorCost> = self
            .tensors
            .iter()
            .zip(records.iter())
            .map(|(t, r)| TensorCost {
                comp_s: t_comp_total * t.numel as f64 / total_elems as f64,
                // compression runs on the same accelerator as the backward
                // pass: map its measured wall time with the same scale
                compress_s: if modeled {
                    t.numel as f64 * self.cfg.model_compress_s_per_elem
                } else {
                    r.compress_s * self.cfg.compute_scale
                },
                wire_bytes: r.wire_bytes,
                collective: r.collective,
                rounds: r.rounds,
                sync_rounds: r.sync_rounds,
                data_dependency: r.data_dependency,
            })
            .collect();
        (t_before, costs)
    }

    /// Build this step's profiler events. Under the threaded backend these
    /// are the *measured* per-rank spans — the Fig. 3 skew-alignment
    /// machinery finally sees real skew (comm ops keyed by `(step,
    /// tensor)`, compute + compression busy time on the compute stream).
    /// Under the analytic backend: per-worker measured compute walls plus
    /// the modeled dense-equivalent collective with rendezvous semantics.
    fn step_events(&self, comp_walls: &[f64], timelines: Option<&[RankTimeline]>) -> Vec<Event> {
        let step = self.step;
        if let Some(tls) = timelines {
            let mut events =
                Vec::with_capacity(tls.iter().map(|t| t.spans.len()).sum::<usize>());
            for tl in tls {
                for s in &tl.spans {
                    events.push(Event {
                        worker: tl.rank,
                        kind: match s.kind {
                            SpanKind::Comm => EventKind::Comm,
                            SpanKind::Compute | SpanKind::Compress => EventKind::Compute,
                        },
                        step,
                        op: s.tensor,
                        start_s: s.start_s,
                        end_s: s.end_s.max(s.start_s),
                    });
                }
            }
            events
        } else {
            // deterministic-timing mode: every worker arrives at the
            // modeled compute time, so profiling (and covap@auto's
            // interval choice) is reproducible too
            let arrive: Vec<f64> = if self.cfg.model_comp_s > 0.0 {
                vec![self.cfg.model_comp_s; comp_walls.len()]
            } else {
                comp_walls.iter().map(|w| w * self.cfg.compute_scale).collect()
            };
            let mut events = Vec::with_capacity(arrive.len() * 2);
            for (w, &d) in arrive.iter().enumerate() {
                events.push(Event {
                    worker: w,
                    kind: EventKind::Compute,
                    step,
                    op: 0,
                    start_s: 0.0,
                    end_s: d,
                });
            }
            // the dense-equivalent collective with rendezvous semantics
            let last = arrive.iter().copied().fold(f64::MIN, f64::max);
            let dense_bytes: usize = self.tensors.iter().map(|t| t.numel * 4).sum();
            let dur = self.topo.allreduce_s(&self.cfg.net, self.cfg.cluster, dense_bytes);
            for (w, &a) in arrive.iter().enumerate() {
                events.push(Event {
                    worker: w,
                    kind: EventKind::Comm,
                    step,
                    op: 0,
                    start_s: a,
                    end_s: last + dur,
                });
            }
            events
        }
    }

    /// Switch the engine to COVAP with the given interval and apply tensor
    /// sharding (§III.C) over the buckets. **Residual-preserving**: the
    /// running scheme's per-rank EF residuals are remapped by flat offset
    /// into the new shard layout (`Scheme::reconfigure` in the analytic
    /// driver, `Cmd::Reconfigure` on every threaded rank) — accumulated
    /// gradient error survives the re-shard instead of leaking (§III.D).
    /// Schemes that cannot migrate (cross-scheme swaps) are rebuilt.
    pub fn set_covap_interval(&mut self, interval: usize) {
        self.chosen_interval = Some(interval);
        let ef = match &self.cfg.scheme {
            SchemeKind::Covap { ef, .. } | SchemeKind::CovapAuto { ef } => *ef,
            _ => EfScheduler::default(),
        };
        let kind = SchemeKind::Covap { interval, ef };

        // sharding: slice oversized buckets
        let sizes: Vec<usize> = self.buckets.iter().map(|b| b.numel).collect();
        let shards = shard_buckets(&sizes, interval);
        let new_tensors: Vec<CommTensor> = shards
            .iter()
            .map(|s| CommTensor {
                offset: self.buckets[s.bucket].offset + s.offset,
                numel: s.len,
                bucket: s.bucket,
            })
            .collect();
        let old_layout: Vec<(usize, usize)> =
            self.tensors.iter().map(|t| (t.offset, t.numel)).collect();
        let new_layout: Vec<(usize, usize)> =
            new_tensors.iter().map(|t| (t.offset, t.numel)).collect();

        if !self.scheme.reconfigure(&kind, &old_layout, &new_layout) {
            self.scheme = kind.build(self.cfg.workers, self.cfg.seed);
        }
        if let Some(exec) = &self.exec {
            exec.reconfigure(&kind, &old_layout, &new_layout);
        }
        self.cfg.scheme = kind;
        self.tensors = new_tensors;
    }

    /// Apply one membership action *now*, at the current step boundary:
    /// export every old rank's flattened EF residuals, redistribute them
    /// into the new world ([`redistribute`] — survivors bitwise, orphaned
    /// error mass folded into new rank 0, joiners clean), re-derive the
    /// collective hop schedule for the new `ClusterSpec` and gate it
    /// through [`verify_schedule`] before any rank runs on it, then
    /// rebuild scheme/shards/executor from the new `(world, generation)`
    /// pair. Both backends reach bitwise-identical post-event state from
    /// identical inputs (DESIGN.md §12).
    pub fn apply_membership(&mut self, action: MembershipAction) -> Result<()> {
        let t0 = Instant::now();
        let old_world = self.cfg.workers;
        // the pure transition functions below (validated_next_world,
        // export_skip, next_cluster, generation_seed, redistribute) are
        // shared with the protocol model checker — see analysis::checker
        let new_world = validated_next_world(old_world, action)?;

        // 1. export: every old rank's EF residuals, flattened over the
        //    current tensor layout. A *failed* rank's threads may already
        //    be dead, so the threaded collector never waits on it (its
        //    export is discarded by the redistribution rule either way).
        let layout: Vec<(usize, usize)> =
            self.tensors.iter().map(|t| (t.offset, t.numel)).collect();
        let states: Vec<Option<Vec<f32>>> = match self.exec.as_mut() {
            Some(exec) => exec.export_states(&layout, export_skip(action)),
            None => (0..old_world)
                .map(|r| self.scheme.export_residuals(r, &layout))
                .collect(),
        };

        // 2. redistribute into the new world (pure + deterministic)
        let states = redistribute(states, action, &self.last_combined);

        // 3. re-world the config and re-derive the modeled topology; the
        //    fresh accounting schedule is verified before use
        self.generation += 1;
        self.cfg.workers = new_world;
        let (nodes, gpn) = next_cluster(new_world, self.cfg.cluster.gpus_per_node);
        self.cfg.cluster = ClusterSpec::new(nodes, gpn);
        self.topo = self.cfg.topology.resolve(self.cfg.cluster);
        let acct_sched = self.topo.allgather_schedule(self.cfg.cluster);
        verify_schedule(&acct_sched).map_err(|v| {
            anyhow::anyhow!("re-derived accounting schedule rejected: {v}")
        })?;
        self.acct_hops = acct_sched.max_level_hops();

        // 4. fresh deterministic shards for the new generation (the
        //    generation-mixed seed keeps both backends identical while
        //    never replaying the pre-event stream)
        let gseed = generation_seed(self.cfg.seed, self.generation);
        let dims = self.arts.manifest.dims.clone();
        let corpus = SyntheticCorpus::new(dims.vocab);
        let make_shards = || -> Vec<DataShard> {
            (0..new_world)
                .map(|w| {
                    DataShard::new(corpus.clone(), gseed, w, dims.batch, dims.seq_len + 1)
                })
                .collect()
        };
        self.shards = make_shards();

        // 5. rebuild the scheme for the new world and import the
        //    redistributed residuals (survivors bitwise)
        let mut scheme = self.cfg.scheme.build(new_world, gseed);
        for (r, st) in states.iter().enumerate() {
            if let Some(flat) = st {
                scheme.import_residuals(r, flat, &layout);
            }
        }
        self.scheme = scheme;

        // 6. threaded backend: join the old fleet, verify the re-derived
        //    executor schedule, and spawn the new world with the imported
        //    per-rank states
        if self.exec.is_some() {
            self.exec = None; // Drop joins the old rank threads
            let models = self.arts.rank_models(new_world)?;
            let pacers = PacerSet::from_net(self.cfg.pace_gbps, &self.cfg.net);
            let exec_cluster = if self.cfg.cluster.world() == new_world {
                self.cfg.cluster
            } else {
                ClusterSpec::new(new_world, 1)
            };
            let sched =
                self.cfg.topology.resolve(exec_cluster).allgather_schedule(exec_cluster);
            verify_schedule(&sched).map_err(|v| {
                anyhow::anyhow!("re-derived executor schedule rejected: {v}")
            })?;
            let retry = RetryPolicy {
                retries: self.cfg.comm_retry,
                timeout_ms: self.cfg.comm_timeout_ms,
            };
            self.exec = Some(ThreadedExec::with_state(
                self.cfg.scheme.clone(),
                gseed,
                models,
                make_shards(),
                Arc::new(sched),
                pacers,
                retry,
                states,
                layout,
            ));
        }

        // 7. per-rank scenario state and the profiler follow the new world
        self.rank_work = vec![self.cfg.synth_work; new_world];
        self.profile = Profile::for_world(new_world);

        let cost_s = t0.elapsed().as_secs_f64();
        let spec = action.spec();
        registry::with_global(|r| {
            r.counter_add("membership_events", 1);
            r.counter_add(
                match action {
                    MembershipAction::Fail { .. } => "membership_failures",
                    MembershipAction::Leave { .. } => "membership_leaves",
                    MembershipAction::Join { .. } => "membership_joins",
                },
                1,
            );
            r.gauge_set("world", new_world as f64);
            r.observe("reconfig_cost_s", cost_s);
        });
        emit_kv(
            LogLevel::Info,
            "membership",
            "reworld",
            &[
                ("action", spec.clone()),
                ("step", self.step.to_string()),
                ("world", format!("{old_world}->{new_world}")),
                ("generation", self.generation.to_string()),
                ("cost_s", format!("{cost_s:.6}")),
            ],
        );
        if let Some(trace) = self.trace.as_mut() {
            trace.process(new_world, "sim (predicted)");
            trace.thread(new_world, TID_COMPUTE, "compute");
            trace.instant(
                new_world,
                TID_COMPUTE,
                "membership",
                0.0,
                vec![
                    ("step", Json::from(self.step as usize)),
                    ("action", Json::from(spec.as_str())),
                    ("world", Json::from(new_world)),
                    ("cost_s", Json::from(cost_s)),
                ],
            );
        }
        Ok(())
    }

    /// Inject a rank failure (chaos tests / the elastic bench). Threaded:
    /// the rank's threads actually die mid-protocol. Analytic: the
    /// failure is recorded and surfaces at the next [`Self::step`]
    /// exactly like a detected one — keeping backend parity for recovery
    /// tests.
    pub fn inject_failure(&mut self, rank: usize, reason: &str) {
        match &self.exec {
            Some(exec) => exec.fail_rank(rank, reason),
            None => self.pending_failure = Some((rank, reason.to_string())),
        }
    }

    /// Snapshot every rank's flattened EF residual state over the current
    /// tensor layout (`None` = stateless scheme). Non-destructive — the
    /// parity oracle for the elastic tests.
    pub fn residual_state(&mut self) -> Vec<Option<Vec<f32>>> {
        let layout: Vec<(usize, usize)> =
            self.tensors.iter().map(|t| (t.offset, t.numel)).collect();
        match self.exec.as_mut() {
            Some(exec) => exec.export_states(&layout, None),
            None => (0..self.cfg.workers)
                .map(|r| self.scheme.export_residuals(r, &layout))
                .collect(),
        }
    }

    /// World generation (membership events applied so far).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// CCR report of the warmup profile (for logging).
    pub fn profile_report(&self) -> crate::profiler::CcrReport {
        self.profile.ccr()
    }

    /// The adaptive controller's decision log (empty unless the scheme is
    /// `covap@auto`): every windowed CCR measurement, proposal and switch.
    pub fn adaptive_history(&self) -> &[IntervalDecision] {
        self.controller.as_ref().map(|c| c.history()).unwrap_or(&[])
    }

    /// Snapshot the accumulated Perfetto trace document (None unless
    /// `trace_out` is configured).
    pub fn trace_json(&self) -> Option<Json> {
        self.trace.as_ref().map(|t| t.to_json())
    }

    /// Write the accumulated trace to `cfg.trace_out`, returning the path
    /// written (None when tracing is off).
    pub fn write_trace(&self) -> Result<Option<PathBuf>> {
        match (&self.trace, &self.cfg.trace_out) {
            (Some(t), Some(path)) => {
                t.write(path)?;
                Ok(Some(path.clone()))
            }
            _ => Ok(None),
        }
    }

    /// Stamp this step into the global metrics registry, log the
    /// controller decision (if any) as a structured event, and — when
    /// `--trace-out` is active — append the step's spans, instants and
    /// counters to the trace. Runs once per step, far from the per-tensor
    /// hot path.
    fn record_obs(
        &mut self,
        step: u64,
        out: &StepOutput,
        records: &[CommRecord],
        comp_walls: &[f64],
        timelines: Option<&[RankTimeline]>,
        sim_spans: &[Span],
        pace_event: Option<f64>,
        decision: Option<IntervalDecision>,
    ) {
        // modeled rendezvous skew: spread of the scaled compute walls
        // (identical arithmetic on both backends)
        let (mut min_w, mut max_w) = (f64::INFINITY, f64::NEG_INFINITY);
        for &w in comp_walls {
            min_w = min_w.min(w * self.cfg.compute_scale);
            max_w = max_w.max(w * self.cfg.compute_scale);
        }
        let skew = if min_w.is_finite() { (max_w - min_w).max(0.0) } else { 0.0 };

        registry::with_global(|r| {
            r.counter_add("steps", 1);
            r.counter_add("wire_bytes", out.wire_bytes as u64);
            r.counter_add("wire_bytes_intra", out.wire_levels.intra as u64);
            r.counter_add("wire_bytes_inter", out.wire_levels.inter as u64);
            r.observe("step_wall_s", out.wall_s);
            r.observe("sim_total_s", out.breakdown.total_s);
            r.observe("sim_exposed_s", out.breakdown.t_comm_exposed_s);
            r.observe("compress_s", out.compress_s);
            r.gauge_set("barrier_skew_s", skew);
            if let Some(tls) = timelines {
                for tl in tls {
                    r.observe("barrier_wait_s", tl.barrier_wait_s);
                    for s in &tl.spans {
                        r.observe(span_metric(s.kind), s.duration());
                    }
                }
            } else {
                for s in sim_spans {
                    r.observe(span_metric(s.kind), s.duration());
                }
            }
            if let Some(d) = &decision {
                r.counter_add("controller_decisions", 1);
                if d.switched {
                    r.counter_add("controller_switches", 1);
                }
                r.gauge_set("interval", d.interval as f64);
                r.gauge_set("ccr", d.ccr);
            }
        });

        if let Some(d) = &decision {
            emit_kv(LogLevel::Info, "controller", "interval_decision", &d.kv());
        }

        let scheme = self.cfg.scheme.spec();
        let sim_pid = self.cfg.workers;
        let Some(trace) = self.trace.as_mut() else { return };
        trace.process(sim_pid, "sim (predicted)");
        trace.thread(sim_pid, TID_COMPUTE, "compute");
        trace.thread(sim_pid, TID_COMM, "comm");
        let span_args = |s: &Span| -> Vec<(&str, Json)> {
            let (wire, intra, inter) = records
                .get(s.tensor)
                .map(|r| (r.wire_bytes, r.levels.intra, r.levels.inter))
                .unwrap_or((0, 0, 0));
            vec![
                ("tensor", Json::from(s.tensor)),
                ("scheme", Json::from(scheme.as_str())),
                ("wire_bytes", Json::from(wire)),
                ("intra_bytes", Json::from(intra)),
                ("inter_bytes", Json::from(inter)),
                ("step", Json::from(step as usize)),
            ]
        };
        let span_name = |k: SpanKind| match k {
            SpanKind::Compute => "compute",
            SpanKind::Compress => "compress",
            SpanKind::Comm => "comm",
        };
        let stream = |k: SpanKind| if k == SpanKind::Comm { TID_COMM } else { TID_COMPUTE };

        // measured per-rank timelines (threaded backend only)
        if let Some(tls) = timelines {
            let mut lift = 0.0f64;
            for tl in tls {
                for s in &tl.spans {
                    lift = lift.min(s.start_s);
                }
            }
            let lift = -lift; // keep every trace ts >= 0
            for tl in tls {
                let pname = format!("rank {}", tl.rank);
                trace.process(tl.rank, &pname);
                trace.thread(tl.rank, TID_COMPUTE, "compute");
                trace.thread(tl.rank, TID_COMM, "comm");
                for s in &tl.spans {
                    trace.complete(
                        tl.rank,
                        stream(s.kind),
                        span_name(s.kind),
                        "measured",
                        s.start_s + lift,
                        s.end_s.max(s.start_s) + lift,
                        span_args(s),
                    );
                }
                trace.instant(
                    tl.rank,
                    TID_COMM,
                    "barrier_wait",
                    0.0,
                    vec![
                        ("rank", Json::from(tl.rank)),
                        ("step", Json::from(step as usize)),
                        ("wait_s", Json::from(tl.barrier_wait_s)),
                    ],
                );
            }
        }

        // predicted timeline (both backends -> visual diff in one window)
        for s in sim_spans {
            trace.complete(
                sim_pid,
                stream(s.kind),
                span_name(s.kind),
                "predicted",
                s.start_s,
                s.end_s,
                span_args(s),
            );
        }
        trace.instant(
            sim_pid,
            TID_COMPUTE,
            "barrier_skew",
            0.0,
            vec![("step", Json::from(step as usize)), ("skew_s", Json::from(skew))],
        );
        if let Some(gbps) = pace_event {
            trace.instant(
                sim_pid,
                TID_COMM,
                "pacer",
                0.0,
                vec![("step", Json::from(step as usize)), ("gbps", Json::from(gbps))],
            );
        }
        if let Some(d) = &decision {
            trace.instant(
                sim_pid,
                TID_COMPUTE,
                "controller_decision",
                0.0,
                vec![
                    ("step", Json::from(d.step as usize)),
                    ("ccr", Json::from(d.ccr)),
                    ("proposed", Json::from(d.proposed)),
                    ("interval", Json::from(d.interval)),
                    ("switched", Json::from(d.switched)),
                ],
            );
        }
        trace.counter(
            sim_pid,
            "wire_bytes",
            0.0,
            &[
                ("intra", out.wire_levels.intra as f64),
                ("inter", out.wire_levels.inter as f64),
            ],
        );
        trace.end_step();
    }
}

/// Registry histogram name for a span kind.
fn span_metric(kind: SpanKind) -> &'static str {
    match kind {
        SpanKind::Compute => "span_compute_s",
        SpanKind::Compress => "span_compress_s",
        SpanKind::Comm => "span_comm_s",
    }
}

fn plain_tensors(buckets: &[Bucket]) -> Vec<CommTensor> {
    buckets
        .iter()
        .map(|b| CommTensor { offset: b.offset, numel: b.numel, bucket: b.id })
        .collect()
}

/// Initialize the flat parameter vector from the manifest layer table:
/// N(0, 0.02) for weight matrices/embeddings, zeros for biases, ones for
/// layernorm scales (matches python model.init_params).
pub fn init_params(manifest: &crate::runtime::Manifest, seed: u64) -> Vec<f32> {
    use crate::util::rng::Rng;
    let mut rng = Rng::seed(seed ^ 0x1A17);
    let mut out = vec![0.0f32; manifest.param_count];
    for p in &manifest.params {
        let base = p.name.rsplit('.').next().unwrap_or(&p.name);
        let dst = &mut out[p.offset..p.offset + p.numel];
        if base.ends_with("_scale") {
            dst.fill(1.0);
        } else if base.ends_with("_bias") || base.starts_with("b_") {
            dst.fill(0.0);
        } else {
            for x in dst {
                *x = rng.normal() as f32 * 0.02;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn tiny_manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "preset": "t",
          "config": {"vocab": 16, "d_model": 4, "n_heads": 2, "n_layers": 1,
                     "d_ff": 8, "seq_len": 8, "batch": 2},
          "param_count": 100,
          "ef_block": 64,
          "params": [
            {"name": "tok_embed", "offset": 0, "numel": 64, "shape": [16, 4]},
            {"name": "h0.b_qkv", "offset": 64, "numel": 12, "shape": [12]},
            {"name": "h0.ln1_scale", "offset": 76, "numel": 4, "shape": [4]},
            {"name": "h0.w_o", "offset": 80, "numel": 16, "shape": [4, 4]},
            {"name": "lnf_bias", "offset": 96, "numel": 4, "shape": [4]}
          ],
          "artifacts": {}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn init_respects_param_classes() {
        let m = tiny_manifest();
        let p = init_params(&m, 1);
        assert_eq!(p.len(), 100);
        // ln scale -> ones
        assert!(p[76..80].iter().all(|&x| x == 1.0));
        // biases -> zeros
        assert!(p[64..76].iter().all(|&x| x == 0.0));
        assert!(p[96..].iter().all(|&x| x == 0.0));
        // embeddings -> small nonzero
        assert!(p[0..64].iter().any(|&x| x != 0.0));
        assert!(p[0..64].iter().all(|&x| x.abs() < 0.2));
    }

    #[test]
    fn init_is_deterministic() {
        let m = tiny_manifest();
        assert_eq!(init_params(&m, 9), init_params(&m, 9));
        assert_ne!(init_params(&m, 9), init_params(&m, 10));
    }

    // ---- synthetic-backend engine tests (run without artifacts) ----------

    fn synth_cfg(scheme: SchemeKind, backend: ExecBackend, steps: u64) -> RunConfig {
        RunConfig {
            workers: 2,
            steps,
            lr: 0.1,
            scheme,
            seed: 77,
            optimizer: Optimizer::Sgd,
            backend,
            bucket_bytes: 16 * 1024, // several buckets on the tiny preset
            ..RunConfig::default()
        }
    }

    #[test]
    fn synthetic_engine_descends() {
        let arts = ModelArtifacts::synthetic("tiny");
        if !arts.is_synthetic() {
            return; // pjrt build without artifacts: nothing to test here
        }
        let cfg = synth_cfg(SchemeKind::Baseline, ExecBackend::Analytic, 20);
        let mut e = DpEngine::new(cfg, arts).unwrap();
        let first = e.step().unwrap().loss;
        let mut last = first;
        for _ in 0..19 {
            last = e.step().unwrap().loss;
        }
        assert!(last < first * 0.9, "no descent: {first} -> {last}");
    }

    /// The acceptance criterion, engine-level: with the same RNG seed the
    /// threaded backend reproduces the analytic loss trajectory exactly.
    #[test]
    fn threaded_backend_bitwise_matches_analytic() {
        if !ModelArtifacts::synthetic("tiny").is_synthetic() {
            return;
        }
        let steps = 4u64;
        for kind in [
            SchemeKind::Baseline,
            SchemeKind::Covap { interval: 2, ef: EfScheduler::default() },
        ] {
            let arts_a = ModelArtifacts::synthetic("tiny");
            let arts_b = ModelArtifacts::synthetic("tiny");
            let mut a = DpEngine::new(
                synth_cfg(kind.clone(), ExecBackend::Analytic, steps),
                arts_a,
            )
            .unwrap();
            let mut b = DpEngine::new(
                synth_cfg(kind.clone(), ExecBackend::Threaded, steps),
                arts_b,
            )
            .unwrap();
            for s in 0..steps {
                let oa = a.step().unwrap();
                let ob = b.step().unwrap();
                assert_eq!(
                    oa.loss.to_bits(),
                    ob.loss.to_bits(),
                    "{} loss diverged at step {s}",
                    kind.label()
                );
                assert!(ob.measured.is_some());
                assert!(oa.measured.is_none());
            }
            assert_eq!(a.params(), b.params(), "{} params diverged", kind.label());
        }
    }

    /// The silent-swap regression (satellite): `--scheme topk@0.05
    /// --profile-steps N` must still run top-k after warmup — profiling
    /// only re-shards `covap@auto`.
    #[test]
    fn profiling_never_swaps_non_covap_schemes() {
        if !ModelArtifacts::synthetic("tiny").is_synthetic() {
            return;
        }
        for backend in [ExecBackend::Analytic, ExecBackend::Threaded] {
            let mut cfg = synth_cfg(SchemeKind::TopK { ratio: 0.05 }, backend, 5);
            cfg.profile_steps = 2;
            let mut e = DpEngine::new(cfg, ModelArtifacts::synthetic("tiny")).unwrap();
            for _ in 0..5 {
                e.step().unwrap();
            }
            assert_eq!(e.chosen_interval, None, "{backend:?}: no interval may be chosen");
            assert!(
                matches!(e.cfg.scheme, SchemeKind::TopK { ratio } if ratio == 0.05),
                "{backend:?}: scheme was swapped to {:?}",
                e.cfg.scheme
            );
            assert!(e.adaptive_history().is_empty());
            // the warmup CCR report still works (profiling = reporting)
            assert!(e.profile_report().comp_s > 0.0);
        }
    }

    /// covap@auto closes the loop: warmup profiles, concludes an interval,
    /// re-shards, and the comm tensors still partition the flat vector.
    /// A crushed modeled fabric forces CCR >> 1, so the chosen interval
    /// must exceed the dense warmup interval of 1.
    #[test]
    fn covap_auto_concludes_and_reshards() {
        if !ModelArtifacts::synthetic("tiny").is_synthetic() {
            return;
        }
        let mut cfg = synth_cfg(
            SchemeKind::CovapAuto { ef: EfScheduler::default() },
            ExecBackend::Analytic,
            6,
        );
        cfg.profile_steps = 2;
        cfg.net.nic_gbps = 0.001; // modeled dense allreduce dwarfs compute
        let arts = ModelArtifacts::synthetic("tiny");
        let param_count = arts.manifest.param_count;
        let mut e = DpEngine::new(cfg, arts).unwrap();
        for _ in 0..4 {
            e.step().unwrap();
        }
        let i = e.chosen_interval.expect("interval chosen after warmup");
        assert!(i > 1, "CCR >> 1 must pick a compressing interval, got {i}");
        assert!(
            matches!(e.cfg.scheme, SchemeKind::Covap { interval, .. } if interval == i),
            "scheme after conclusion: {:?}",
            e.cfg.scheme
        );
        let hist = e.adaptive_history();
        assert!(!hist.is_empty() && hist[0].switched && hist[0].interval == i);
        // comm tensors still partition the flat vector exactly
        let mut covered = vec![false; param_count];
        for t in e.tensors() {
            for j in t.offset..t.offset + t.numel {
                assert!(!covered[j], "overlap at {j}");
                covered[j] = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "gap in tensor coverage");
    }

    /// Tracing is strictly opt-in, and when on, both backends emit a
    /// schema-valid trace: predicted spans always, measured rank spans
    /// only under the threaded backend.
    #[test]
    fn trace_capture_is_opt_in_and_schema_valid() {
        if !ModelArtifacts::synthetic("tiny").is_synthetic() {
            return;
        }
        for backend in [ExecBackend::Analytic, ExecBackend::Threaded] {
            let mut off = DpEngine::new(
                synth_cfg(SchemeKind::Baseline, backend, 2),
                ModelArtifacts::synthetic("tiny"),
            )
            .unwrap();
            off.step().unwrap();
            assert!(off.trace_json().is_none(), "{backend:?}: tracing must be opt-in");

            let mut cfg = synth_cfg(
                SchemeKind::Covap { interval: 2, ef: EfScheduler::default() },
                backend,
                2,
            );
            cfg.trace_out = Some(PathBuf::from("unused_trace.json"));
            let mut e = DpEngine::new(cfg, ModelArtifacts::synthetic("tiny")).unwrap();
            for _ in 0..2 {
                e.step().unwrap();
            }
            let doc = e.trace_json().expect("tracing enabled");
            crate::obs::validate_trace(&doc).unwrap();
            let events = doc.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
            let null = Json::Null;
            let has_cat = |cat: &str| {
                events
                    .iter()
                    .any(|ev| matches!(ev.get_or("cat", &null), Json::Str(s) if s == cat))
            };
            assert!(has_cat("predicted"), "{backend:?}: predicted spans missing");
            assert_eq!(
                has_cat("measured"),
                matches!(backend, ExecBackend::Threaded),
                "{backend:?}: measured spans only on the threaded backend"
            );
        }
    }

    fn bits_of(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// The elastic tentpole, engine level: a scripted fail → scale-out →
    /// leave run re-worlds live on BOTH backends, every step stays
    /// bitwise-identical across them, and the post-run EF residual states
    /// match bitwise (the conservation criterion).
    #[test]
    fn scheduled_membership_keeps_backends_bitwise() {
        if !ModelArtifacts::synthetic("tiny").is_synthetic() {
            return;
        }
        let schedule = crate::coordinator::membership::parse_membership_schedule(
            "1:fail:2,3:join:2,5:leave:0",
        )
        .unwrap();
        let mk = |backend| {
            let mut cfg = synth_cfg(
                SchemeKind::Covap { interval: 2, ef: EfScheduler::default() },
                backend,
                7,
            );
            cfg.workers = 3;
            cfg.cluster = crate::config::default_cluster(3);
            cfg.membership_schedule = schedule.clone();
            DpEngine::new(cfg, ModelArtifacts::synthetic("tiny")).unwrap()
        };
        let mut a = mk(ExecBackend::Analytic);
        let mut b = mk(ExecBackend::Threaded);
        for s in 0..7 {
            let oa = a.step().unwrap();
            let ob = b.step().unwrap();
            assert_eq!(oa.loss.to_bits(), ob.loss.to_bits(), "loss diverged at step {s}");
        }
        // worlds: 3 -> 2 (fail) -> 4 (join 2) -> 3 (leave)
        assert_eq!((a.generation(), a.cfg.workers), (3, 3));
        assert_eq!((b.generation(), b.cfg.workers), (3, 3));
        let (ra, rb) = (a.residual_state(), b.residual_state());
        assert_eq!(ra.len(), rb.len());
        for (r, (x, y)) in ra.iter().zip(rb.iter()).enumerate() {
            let x = x.as_ref().expect("covap exports residuals");
            let y = y.as_ref().expect("covap exports residuals");
            assert_eq!(bits_of(x), bits_of(y), "rank {r} residuals diverged");
        }
        assert_eq!(a.params(), b.params());
    }

    /// A mid-run *detected* rank failure under `elastic: true` completes
    /// the run instead of aborting, and the recovered trajectory matches
    /// the analytic twin (same injection) bitwise. With elastic off the
    /// typed failure still surfaces — fail-fast behavior is preserved.
    #[test]
    fn reactive_failure_recovers_and_matches_analytic() {
        if !ModelArtifacts::synthetic("tiny").is_synthetic() {
            return;
        }
        let mk = |backend, elastic| {
            let mut cfg = synth_cfg(
                SchemeKind::Covap { interval: 2, ef: EfScheduler::default() },
                backend,
                4,
            );
            cfg.workers = 3;
            cfg.cluster = crate::config::default_cluster(3);
            cfg.elastic = elastic;
            DpEngine::new(cfg, ModelArtifacts::synthetic("tiny")).unwrap()
        };
        let mut a = mk(ExecBackend::Analytic, true);
        let mut b = mk(ExecBackend::Threaded, true);
        let (oa, ob) = (a.step().unwrap(), b.step().unwrap());
        assert_eq!(oa.loss.to_bits(), ob.loss.to_bits());
        a.inject_failure(1, "chaos");
        b.inject_failure(1, "chaos");
        for s in 1..4 {
            let oa = a.step().unwrap();
            let ob = b.step().unwrap();
            assert_eq!(oa.loss.to_bits(), ob.loss.to_bits(), "diverged at step {s}");
        }
        assert_eq!((a.generation(), a.cfg.workers), (1, 2));
        assert_eq!((b.generation(), b.cfg.workers), (1, 2));
        assert_eq!(a.params(), b.params());

        let mut c = mk(ExecBackend::Threaded, false);
        c.step().unwrap();
        c.inject_failure(0, "hard fault");
        let err = c.step().unwrap_err();
        let f = err.downcast_ref::<RankFailure>().expect("typed failure");
        assert_eq!(f.rank, 0);
        assert!(f.reason.contains("hard fault"));
    }

    /// Scenario knobs (mid-run pace change + straggler injection) must
    /// never change numerics: with and without them, and across backends,
    /// the loss trajectory is bit-identical.
    #[test]
    fn scenario_knobs_preserve_numerics() {
        if !ModelArtifacts::synthetic("tiny").is_synthetic() {
            return;
        }
        let scenario = |mut cfg: RunConfig| {
            cfg.pace_schedule = vec![(1, 0.5)];
            cfg.stragglers = vec![crate::config::Straggler {
                rank: 0,
                work_factor: 3,
                from_step: 1,
                until_step: 3,
            }];
            cfg
        };
        let kind = SchemeKind::Covap { interval: 2, ef: EfScheduler::default() };
        let mut clean = DpEngine::new(
            synth_cfg(kind.clone(), ExecBackend::Analytic, 4),
            ModelArtifacts::synthetic("tiny"),
        )
        .unwrap();
        let mut sc_a = DpEngine::new(
            scenario(synth_cfg(kind.clone(), ExecBackend::Analytic, 4)),
            ModelArtifacts::synthetic("tiny"),
        )
        .unwrap();
        let mut sc_t = DpEngine::new(
            scenario(synth_cfg(kind, ExecBackend::Threaded, 4)),
            ModelArtifacts::synthetic("tiny"),
        )
        .unwrap();
        for s in 0..4 {
            let l0 = clean.step().unwrap().loss;
            let la = sc_a.step().unwrap().loss;
            let lt = sc_t.step().unwrap().loss;
            assert_eq!(l0.to_bits(), la.to_bits(), "analytic scenario diverged at {s}");
            assert_eq!(l0.to_bits(), lt.to_bits(), "threaded scenario diverged at {s}");
        }
        assert_eq!(clean.params(), sc_a.params());
        assert_eq!(clean.params(), sc_t.params());
    }
}
