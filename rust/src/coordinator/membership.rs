//! Elastic membership: turn a rank failure, a straggler eviction, or an
//! operator-requested scale-out into a live world reconfiguration instead
//! of an abort.
//!
//! The controller's contract has three parts, all deterministic:
//!
//! 1. **Quiesce** — membership changes land at step boundaries. Scheduled
//!    events (from `--membership-schedule`) fire before the step they name
//!    executes; a *detected* failure aborts the in-flight step (no rank
//!    applies its update — the barrier poison makes survivors skip it
//!    bitwise-uniformly, see `exec::rank::run_step`), so the re-world
//!    still happens on a clean boundary.
//! 2. **Redistribute** — [`redistribute`] maps the old world's per-rank
//!    error-feedback residual vectors (flattened over the tensor layout)
//!    into the new world. Survivors keep their residuals bitwise; a
//!    departed rank's error mass is folded into the new rank 0; joiners
//!    start clean. A rank that *left* cleanly hands over its exact
//!    residuals; a rank that *died* hands over nothing recoverable, so
//!    both backends reconstruct the same deterministic surrogate from the
//!    engine's retained last-combined update — keeping analytic/threaded
//!    parity exact even through a crash.
//! 3. **Re-derive** — the new world's `ClusterSpec` yields a fresh
//!    `HopSchedule` which must pass `analysis::verify_schedule` before any
//!    rank thread is spawned onto it.
//!
//! The parity argument: both backends export bitwise-identical states
//! (the live checksum invariant guarantees they agree before the event),
//! run this module's *pure* redistribution, and rebuild scheme/shard
//! state from identical `(kind, world, seed, generation)` inputs — so
//! post-event parity is structural, not coincidental.

use anyhow::{bail, Result};

/// One membership change, applied at a step boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MembershipAction {
    /// Rank dies without warning (crash, OOM, fabric partition). Its
    /// residuals are unrecoverable; the deterministic surrogate rule
    /// applies (see [`redistribute`]).
    Fail { rank: usize },
    /// Rank leaves cleanly (straggler eviction, planned drain): it hands
    /// its exact residuals over before departing.
    Leave { rank: usize },
    /// `count` fresh ranks join with zero residuals (scale-out).
    Join { count: usize },
}

impl MembershipAction {
    pub fn spec(&self) -> String {
        match self {
            MembershipAction::Fail { rank } => format!("fail:{rank}"),
            MembershipAction::Leave { rank } => format!("leave:{rank}"),
            MembershipAction::Join { count } => format!("join:{count}"),
        }
    }

    /// World size after applying this action to a `world`-rank fleet.
    pub fn next_world(&self, world: usize) -> usize {
        match self {
            MembershipAction::Fail { .. } | MembershipAction::Leave { .. } => {
                world.saturating_sub(1)
            }
            MembershipAction::Join { count } => world + count,
        }
    }
}

/// A scheduled membership event: `action` fires before step `at_step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MembershipEvent {
    pub at_step: u64,
    pub action: MembershipAction,
}

/// Parse a `--membership-schedule` script:
/// `"step:fail:rank,step:leave:rank,step:join[:count]"` — e.g.
/// `"3:fail:1,6:join:2,9:leave:0"`. Events must be sorted by step
/// (validated later against the starting world by [`world_evolution`]).
pub fn parse_membership_schedule(s: &str) -> Result<Vec<MembershipEvent>> {
    let mut events = Vec::new();
    for part in s.split(',').filter(|p| !p.trim().is_empty()) {
        let fields: Vec<&str> = part.trim().split(':').collect();
        let err = || format!("bad membership event '{part}' (want step:fail|leave:rank or step:join[:count])");
        if fields.len() < 2 || fields.len() > 3 {
            bail!("{}", err());
        }
        let at_step: u64 = fields[0].parse().map_err(|_| anyhow::anyhow!("{}", err()))?;
        let action = match (fields[1], fields.get(2)) {
            ("fail", Some(r)) => MembershipAction::Fail {
                rank: r.parse().map_err(|_| anyhow::anyhow!("{}", err()))?,
            },
            ("leave", Some(r)) => MembershipAction::Leave {
                rank: r.parse().map_err(|_| anyhow::anyhow!("{}", err()))?,
            },
            ("join", None) => MembershipAction::Join { count: 1 },
            ("join", Some(c)) => {
                let count: usize = c.parse().map_err(|_| anyhow::anyhow!("{}", err()))?;
                if count == 0 {
                    bail!("membership event '{part}': join count must be >= 1");
                }
                MembershipAction::Join { count }
            }
            _ => bail!("{}", err()),
        };
        events.push(MembershipEvent { at_step, action });
    }
    Ok(events)
}

/// Walk a schedule from `initial` workers, validating every event against
/// the world it will actually see: ranks must be in range at event time,
/// the world must never empty, and steps must be non-decreasing. Returns
/// `(min_world, max_world)` over the whole run — the bounds config
/// validation checks straggler/pace scripts against (a straggler rank
/// valid only in a *future* world is a warning upstream; one valid in
/// *no* world is an error).
pub fn world_evolution(initial: usize, events: &[MembershipEvent]) -> Result<(usize, usize)> {
    let mut world = initial;
    let (mut min_w, mut max_w) = (initial, initial);
    let mut last_step = 0u64;
    for e in events {
        if e.at_step < last_step {
            bail!(
                "membership schedule out of order: step {} after step {last_step}",
                e.at_step
            );
        }
        last_step = e.at_step;
        match e.action {
            MembershipAction::Fail { rank } | MembershipAction::Leave { rank } => {
                if rank >= world {
                    bail!(
                        "membership event '{}' at step {}: rank {rank} outside the \
                         world of {world} at that point",
                        e.action.spec(),
                        e.at_step
                    );
                }
                if world == 1 {
                    bail!(
                        "membership event '{}' at step {}: cannot empty the world",
                        e.action.spec(),
                        e.at_step
                    );
                }
            }
            MembershipAction::Join { .. } => {}
        }
        world = e.action.next_world(world);
        min_w = min_w.min(world);
        max_w = max_w.max(world);
    }
    Ok((min_w, max_w))
}

// ---- pure transition functions --------------------------------------
//
// Every re-world decision the engine makes is factored out here so the
// protocol model checker (`analysis::model` / `analysis::checker`) drives
// the *same* transition implementation the engine runs — a divergence
// between "what we prove" and "what we ship" is a compile error, not a
// hand-mirroring bug. All four are total, allocation-free and
// deterministic; `DpEngine::apply_membership` is a thin impure shell
// around them (export, thread respawn, observability).

/// Validate `action` against the world it fires in and return the world
/// size after it — the guard `apply_membership` runs before touching any
/// state. Rejects out-of-range ranks and emptying the world.
pub fn validated_next_world(world: usize, action: MembershipAction) -> Result<usize> {
    if let MembershipAction::Fail { rank } | MembershipAction::Leave { rank } = action {
        if rank >= world {
            bail!(
                "membership action {}: rank outside the world of {world}",
                action.spec()
            );
        }
    }
    let next = action.next_world(world);
    if next == 0 {
        bail!("membership action {} would empty the world", action.spec());
    }
    Ok(next)
}

/// Which old rank the export collector must skip: a *failed* rank's
/// threads may already be dead, so no `ExportState` is sent to it (its
/// state is unrecoverable and the surrogate rule applies either way).
/// Leavers are alive and must export — exactly once.
// xtask: hot-path
pub fn export_skip(action: MembershipAction) -> Option<usize> {
    match action {
        MembershipAction::Fail { rank } => Some(rank),
        MembershipAction::Leave { .. } | MembershipAction::Join { .. } => None,
    }
}

/// Cluster shape for the re-worlded fleet: preserve the machine's
/// gpus-per-node when the new world still fills whole nodes, else fall
/// back to one flat rank per node. Returns `(nodes, gpus_per_node)`;
/// the product is always exactly `new_world`.
// xtask: hot-path
pub fn next_cluster(new_world: usize, gpus_per_node: usize) -> (usize, usize) {
    let gpn = gpus_per_node.max(1);
    if new_world % gpn == 0 {
        (new_world / gpn, gpn)
    } else {
        (new_world, 1)
    }
}

/// The generation-mixed data/scheme seed: both backends rebuild shards
/// and schemes from `(kind, world, generation_seed(..))`, so they stay
/// bitwise identical across a re-world while never replaying the
/// pre-event sample stream (`generation >= 1` always perturbs the seed).
// xtask: hot-path
pub fn generation_seed(seed: u64, generation: u64) -> u64 {
    seed ^ generation.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// The pure heart of the re-world: map the old world's per-rank flattened
/// EF residual states into the new world's.
///
/// * `states[r]` is old rank `r`'s residuals flattened over the tensor
///   layout (`None` = unknown: dead rank, or a stateless scheme).
/// * `last_combined` is the engine's retained copy of the most recent
///   combined update — bitwise-identical on both backends — used as the
///   deterministic surrogate for a *failed* rank's unrecoverable state.
///
/// Rules (the residual-handoff contract, DESIGN.md §12):
/// * **Survivors keep their residuals bitwise**, reindexed in survivor
///   order. Elasticity must not perturb ranks that didn't move.
/// * **Leave**: the departing rank's exported residuals are the orphan.
/// * **Fail**: nothing was exported; the orphan is reconstructed as the
///   retained `last_combined` update — the same deterministic rule on
///   both backends, so parity survives the crash. (The true state is
///   gone; any recovery is an estimate, and this one restores the error
///   mass the dead rank most recently contributed to.)
/// * The orphan folds element-wise into **new rank 0**'s residuals (one
///   deterministic donor beats smearing rounding error across the fleet).
/// * **Join**: new ranks start with no state (`None` → zero residuals).
pub fn redistribute(
    mut states: Vec<Option<Vec<f32>>>,
    action: MembershipAction,
    last_combined: &[f32],
) -> Vec<Option<Vec<f32>>> {
    match action {
        MembershipAction::Join { count } => {
            for _ in 0..count {
                states.push(None);
            }
            states
        }
        MembershipAction::Leave { rank } | MembershipAction::Fail { rank } => {
            if rank >= states.len() {
                return states;
            }
            let exported = states.remove(rank);
            let orphan: Option<Vec<f32>> = match action {
                MembershipAction::Leave { .. } => exported,
                // dead rank: deterministic surrogate (see doc above)
                MembershipAction::Fail { .. } => {
                    if last_combined.is_empty() {
                        None
                    } else {
                        Some(last_combined.to_vec())
                    }
                }
                MembershipAction::Join { .. } => unreachable!(),
            };
            if let Some(orphan) = orphan {
                let donor = match states.first_mut() {
                    Some(d) => d,
                    None => return states,
                };
                match donor {
                    Some(d) => {
                        if d.len() < orphan.len() {
                            d.resize(orphan.len(), 0.0);
                        }
                        for (di, oi) in d.iter_mut().zip(orphan.iter()) {
                            *di += *oi;
                        }
                    }
                    None => *donor = Some(orphan),
                }
            }
            states
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_schedule_grammar() {
        let ev = parse_membership_schedule("3:fail:1,6:join:2,9:leave:0,12:join").unwrap();
        assert_eq!(
            ev,
            vec![
                MembershipEvent { at_step: 3, action: MembershipAction::Fail { rank: 1 } },
                MembershipEvent { at_step: 6, action: MembershipAction::Join { count: 2 } },
                MembershipEvent { at_step: 9, action: MembershipAction::Leave { rank: 0 } },
                MembershipEvent { at_step: 12, action: MembershipAction::Join { count: 1 } },
            ]
        );
        assert!(parse_membership_schedule("").unwrap().is_empty());
        for bad in ["x:fail:1", "3:evict:1", "3:fail", "3:join:0", "3:fail:1:9", "3"] {
            assert!(parse_membership_schedule(bad).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn world_evolution_validates_against_evolving_world() {
        // 2 ranks: fail rank 1 (world 1), join 3 (world 4), leave rank 3
        let ev = parse_membership_schedule("1:fail:1,2:join:3,5:leave:3").unwrap();
        assert_eq!(world_evolution(2, &ev).unwrap(), (1, 4));

        // rank valid initially but not at event time
        let ev = parse_membership_schedule("1:fail:1,2:fail:1").unwrap();
        let err = world_evolution(2, &ev).unwrap_err().to_string();
        assert!(err.contains("outside the world"), "{err}");

        // emptying the world
        let ev = parse_membership_schedule("1:fail:0").unwrap();
        assert!(world_evolution(1, &ev).is_err());

        // out-of-order steps
        let ev = parse_membership_schedule("5:join,2:join").unwrap();
        assert!(world_evolution(2, &ev).is_err());
    }

    #[test]
    fn validated_next_world_guards_the_transition() {
        assert_eq!(validated_next_world(3, MembershipAction::Fail { rank: 2 }).unwrap(), 2);
        assert_eq!(validated_next_world(2, MembershipAction::Leave { rank: 0 }).unwrap(), 1);
        assert_eq!(validated_next_world(1, MembershipAction::Join { count: 4 }).unwrap(), 5);
        assert!(validated_next_world(2, MembershipAction::Fail { rank: 2 }).is_err());
        assert!(validated_next_world(1, MembershipAction::Leave { rank: 0 }).is_err());
    }

    #[test]
    fn export_skip_only_skips_failed_ranks() {
        assert_eq!(export_skip(MembershipAction::Fail { rank: 3 }), Some(3));
        assert_eq!(export_skip(MembershipAction::Leave { rank: 3 }), None);
        assert_eq!(export_skip(MembershipAction::Join { count: 1 }), None);
    }

    #[test]
    fn next_cluster_preserves_gpn_when_divisible() {
        assert_eq!(next_cluster(8, 4), (2, 4));
        assert_eq!(next_cluster(7, 4), (7, 1));
        assert_eq!(next_cluster(3, 0), (3, 1)); // degenerate gpn clamps to 1
        for world in 1..=17usize {
            for gpn in 0..=5usize {
                let (n, g) = next_cluster(world, gpn);
                assert_eq!(n * g, world, "cluster shape must cover the world exactly");
            }
        }
    }

    #[test]
    fn generation_seed_never_replays_the_base_stream() {
        assert_eq!(generation_seed(42, 0), 42);
        let mut seen = std::collections::HashSet::new();
        seen.insert(42u64);
        for gen in 1..=64u64 {
            assert!(seen.insert(generation_seed(42, gen)), "seed replayed at gen {gen}");
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// The conservation criterion: survivors bitwise unchanged, and the
    /// new rank 0 holds exactly old-rank-0 + orphan.
    #[test]
    fn leave_folds_exact_residuals_into_rank0() {
        let s0 = vec![0.5f32, -1.25, 2.0];
        let s1 = vec![0.125f32, 3.5, -0.75];
        let s2 = vec![1.0f32, 0.0, -2.5];
        let out = redistribute(
            vec![Some(s0.clone()), Some(s1.clone()), Some(s2.clone())],
            MembershipAction::Leave { rank: 1 },
            &[9.0, 9.0, 9.0], // ignored on Leave
        );
        assert_eq!(out.len(), 2);
        let want0: Vec<f32> = s0.iter().zip(s1.iter()).map(|(a, b)| a + b).collect();
        assert_eq!(bits(out[0].as_ref().unwrap()), bits(&want0));
        // the other survivor is bitwise untouched, reindexed 2 -> 1
        assert_eq!(bits(out[1].as_ref().unwrap()), bits(&s2));
    }

    #[test]
    fn fail_reconstructs_orphan_from_last_combined() {
        let s0 = vec![1.0f32, 2.0];
        let s2 = vec![-1.0f32, 4.0];
        let last = vec![0.25f32, -0.5];
        let out = redistribute(
            vec![Some(s0.clone()), Some(vec![7.0, 7.0]), Some(s2.clone())],
            MembershipAction::Fail { rank: 1 },
            &last,
        );
        // the dead rank's true state (7.0s) is gone; the surrogate is last_combined
        let want0: Vec<f32> = s0.iter().zip(last.iter()).map(|(a, b)| a + b).collect();
        assert_eq!(bits(out[0].as_ref().unwrap()), bits(&want0));
        assert_eq!(bits(out[1].as_ref().unwrap()), bits(&s2));
    }

    #[test]
    fn join_appends_clean_ranks() {
        let s0 = vec![1.5f32];
        let out = redistribute(
            vec![Some(s0.clone())],
            MembershipAction::Join { count: 2 },
            &[],
        );
        assert_eq!(out.len(), 3);
        assert_eq!(bits(out[0].as_ref().unwrap()), bits(&s0));
        assert!(out[1].is_none() && out[2].is_none());
    }

    #[test]
    fn stateless_donor_adopts_the_orphan() {
        // rank 0 had no portable state (stateless scheme / fresh joiner):
        // the orphan becomes its state rather than being dropped
        let out = redistribute(
            vec![None, Some(vec![2.0f32, -2.0])],
            MembershipAction::Leave { rank: 1 },
            &[],
        );
        assert_eq!(out.len(), 1);
        assert_eq!(bits(out[0].as_ref().unwrap()), bits(&[2.0, -2.0]));
    }

    #[test]
    fn fail_of_rank0_donates_to_new_rank0() {
        let s1 = vec![1.0f32, 1.0];
        let last = vec![0.5f32, 0.25];
        let out = redistribute(
            vec![Some(vec![3.0, 3.0]), Some(s1.clone())],
            MembershipAction::Fail { rank: 0 },
            &last,
        );
        assert_eq!(out.len(), 1);
        let want: Vec<f32> = s1.iter().zip(last.iter()).map(|(a, b)| a + b).collect();
        assert_eq!(bits(out[0].as_ref().unwrap()), bits(&want));
    }
}
