//! L3 coordinator: gradient bucketing and the data-parallel training engine.
//!
//! [`bucketizer`] reproduces the DDP bucket model: parameter tensors are
//! packed into fixed-capacity communication buckets in gradient-ready
//! (reverse registration) order. [`engine`] runs synchronous DP over P
//! simulated workers: each computes *real* gradients through the PJRT
//! artifact on its own data shard; buckets flow through the configured
//! compression scheme; the overlap timeline is priced by the network model.

pub mod bucketizer;
pub mod engine;
pub mod membership;

pub use bucketizer::{bucketize, bucketize_layers, Bucket};
pub use engine::{CommTensor, DpEngine, StepOutput};
pub use membership::{
    parse_membership_schedule, redistribute, world_evolution, MembershipAction, MembershipEvent,
};
