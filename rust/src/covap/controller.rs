//! The closed-loop adaptive-interval controller (§III.B, closed).
//!
//! The paper's adaptive mode is a one-shot warmup: profile CCR once, set
//! `I = ceil(CCR)`, never look back. Real runs drift — bandwidth drops,
//! stragglers appear, pacing changes — and a stale interval either exposes
//! communication again (I too small) or wastes accuracy on compression the
//! network no longer needs (I too large). GraVAC and Agarwal et al. both
//! argue the ratio must keep tracking the measured regime.
//!
//! [`IntervalController`] closes the loop:
//!
//! * **Warmup window** (`warmup` steps): the initial CCR measurement — the
//!   paper's §III.B profiling — concluded with an immediate re-shard to
//!   `ceil(CCR)` (no hysteresis: there is no prior interval worth
//!   defending).
//! * **Steady windows** (`window` steps each): re-profile continuously.
//!   Every window produces a *dense-equivalent* CCR: the aligned
//!   communication time is rescaled by `dense_bytes / wire_bytes` so a
//!   measurement taken under compression (COVAP moves ~1/I of the dense
//!   volume) still estimates what the *uncompressed* traffic would cost —
//!   the quantity `ceil(CCR)` is defined over.
//! * **Hysteresis**: a re-shard only fires after `hysteresis` consecutive
//!   windows propose the *same* new interval. `ceil` sits on a cliff — a
//!   CCR hovering at 3.99/4.01 would otherwise re-shard every window, and
//!   each re-shard perturbs the EF residual layout. A window proposing the
//!   current interval resets the pending streak.
//!
//! The controller is pure bookkeeping over [`Profile`] events — the engine
//! feeds it *measured* per-rank spans under `ExecBackend::Threaded` and
//! the modeled dense collective under `Analytic` (see
//! `DpEngine::step_events`), and applies the returned interval via its
//! residual-preserving re-shard path.

use crate::covap::interval_from_ccr;
use crate::profiler::{Event, Profile};

/// One windowed CCR decision (the controller's audit log; benches emit it
/// as the chosen-interval trajectory).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IntervalDecision {
    /// Step at whose end the window closed.
    pub step: u64,
    /// Dense-equivalent CCR measured over the window.
    pub ccr: f64,
    /// `ceil(CCR)` this window proposed.
    pub proposed: usize,
    /// Interval in force after the decision.
    pub interval: usize,
    /// True when this decision re-sharded (warmup conclusion or an open
    /// hysteresis gate).
    pub switched: bool,
}

impl IntervalDecision {
    /// The decision as `key=value` pairs for the structured log
    /// (`obs::log::emit_kv`) — one line per window:
    /// `interval_decision step=.. ccr=.. proposed=.. interval=.. switched=..`.
    pub fn kv(&self) -> Vec<(&'static str, String)> {
        vec![
            ("step", self.step.to_string()),
            ("ccr", format!("{:.3}", self.ccr)),
            ("proposed", self.proposed.to_string()),
            ("interval", self.interval.to_string()),
            ("switched", self.switched.to_string()),
        ]
    }
}

/// Windowed re-profiler + hysteresis gate for COVAP's interval.
pub struct IntervalController {
    warmup: u64,
    window: u64,
    hysteresis: u32,
    current: usize,
    warmed_up: bool,
    profile: Profile,
    steps_in_window: u64,
    wire_sum: u64,
    dense_sum: u64,
    /// Candidate interval + how many consecutive windows proposed it.
    pending: Option<(usize, u32)>,
    history: Vec<IntervalDecision>,
}

impl IntervalController {
    /// `world` ranks, starting at `initial` (the warmup transmission
    /// interval, 1 for `covap@auto`), warmup window of `warmup` steps,
    /// steady windows of `window` steps, `hysteresis` consecutive windows
    /// to open the re-shard gate.
    pub fn new(
        world: usize,
        initial: usize,
        warmup: u64,
        window: u64,
        hysteresis: u32,
    ) -> IntervalController {
        assert!(warmup >= 1, "warmup window must be >= 1 step");
        assert!(window >= 1, "profiling window must be >= 1 step");
        assert!(hysteresis >= 1, "hysteresis must be >= 1 window");
        IntervalController {
            warmup,
            window,
            hysteresis,
            current: initial.max(1),
            warmed_up: false,
            // window rollover only clears events (Profile::clear keeps the
            // world-size configuration), so the controller needs no copy
            profile: Profile::for_world(world),
            steps_in_window: 0,
            wire_sum: 0,
            dense_sum: 0,
            pending: None,
            history: Vec::new(),
        }
    }

    /// Interval currently in force.
    pub fn current_interval(&self) -> usize {
        self.current
    }

    /// True once the warmup window concluded (an interval has been chosen).
    pub fn concluded(&self) -> bool {
        self.warmed_up
    }

    /// Every windowed decision so far, oldest first.
    pub fn history(&self) -> &[IntervalDecision] {
        &self.history
    }

    /// Feed one operator event (measured span or modeled collective) of
    /// the current step into the window's profile.
    pub fn record(&mut self, e: Event) {
        self.profile.record(e);
    }

    /// Close step `step`: account its wire volume (`wire_bytes` actually
    /// transmitted per rank vs `dense_bytes` the uncompressed tensors
    /// would have moved) and, on a window boundary, decide. Returns
    /// `Some(new_interval)` when the engine must re-shard.
    pub fn end_step(&mut self, step: u64, wire_bytes: usize, dense_bytes: usize) -> Option<usize> {
        self.wire_sum += wire_bytes as u64;
        self.dense_sum += dense_bytes as u64;
        self.steps_in_window += 1;
        let len = if self.warmed_up { self.window } else { self.warmup };
        if self.steps_in_window < len {
            return None;
        }

        let report = self.profile.ccr();
        let scale = if self.wire_sum > 0 {
            self.dense_sum as f64 / self.wire_sum as f64
        } else {
            f64::NAN
        };
        let ccr = report.ccr * scale;
        self.profile.clear();
        self.steps_in_window = 0;
        self.wire_sum = 0;
        self.dense_sum = 0;
        if !ccr.is_finite() {
            // degenerate window (no compute measured / nothing moved):
            // hold the interval, decide again next window
            return None;
        }
        let proposed = interval_from_ccr(ccr);

        if !self.warmed_up {
            // §III.B one-shot conclusion: adopt ceil(CCR) immediately.
            self.warmed_up = true;
            let switched = proposed != self.current;
            self.current = proposed;
            self.history.push(IntervalDecision {
                step,
                ccr,
                proposed,
                interval: proposed,
                switched,
            });
            return if switched { Some(proposed) } else { None };
        }

        let mut switched = false;
        if proposed == self.current {
            self.pending = None;
        } else {
            let streak = match self.pending {
                Some((p, c)) if p == proposed => c + 1,
                _ => 1,
            };
            if streak >= self.hysteresis {
                self.pending = None;
                self.current = proposed;
                switched = true;
            } else {
                self.pending = Some((proposed, streak));
            }
        }
        self.history.push(IntervalDecision {
            step,
            ccr,
            proposed,
            interval: self.current,
            switched,
        });
        if switched {
            Some(self.current)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::EventKind;

    #[test]
    fn decision_kv_pairs_are_complete_and_ordered() {
        let d = IntervalDecision {
            step: 7,
            ccr: 3.14159,
            proposed: 4,
            interval: 4,
            switched: true,
        };
        let kv = d.kv();
        let keys: Vec<&str> = kv.iter().map(|(k, _)| *k).collect();
        assert_eq!(keys, ["step", "ccr", "proposed", "interval", "switched"]);
        assert_eq!(kv[0].1, "7");
        assert_eq!(kv[1].1, "3.142");
        assert_eq!(kv[4].1, "true");
    }

    /// Feed one idealized step: every worker computes for `comp_s`, then
    /// one rendezvous collective of `comm_s` — and close the step with the
    /// given volume accounting.
    fn feed_step(
        ctrl: &mut IntervalController,
        world: usize,
        step: u64,
        comp_s: f64,
        comm_s: f64,
        wire: usize,
        dense: usize,
    ) -> Option<usize> {
        for w in 0..world {
            ctrl.record(Event {
                worker: w,
                kind: EventKind::Compute,
                step,
                op: 0,
                start_s: 0.0,
                end_s: comp_s,
            });
            ctrl.record(Event {
                worker: w,
                kind: EventKind::Comm,
                step,
                op: 0,
                start_s: comp_s,
                end_s: comp_s + comm_s,
            });
        }
        ctrl.end_step(step, wire, dense)
    }

    #[test]
    fn warmup_concludes_to_ceil_ccr_immediately() {
        let mut c = IntervalController::new(2, 1, 2, 4, 2);
        assert!(!c.concluded());
        assert_eq!(feed_step(&mut c, 2, 0, 1.0, 2.5, 1000, 1000), None);
        // CCR 2.5 -> ceil 3, adopted without hysteresis
        assert_eq!(feed_step(&mut c, 2, 1, 1.0, 2.5, 1000, 1000), Some(3));
        assert!(c.concluded());
        assert_eq!(c.current_interval(), 3);
        let d = c.history()[0];
        assert!(d.switched && d.proposed == 3 && (d.ccr - 2.5).abs() < 1e-9);
    }

    #[test]
    fn compressed_windows_rescale_to_dense_equivalent_ccr() {
        let mut c = IntervalController::new(2, 1, 1, 3, 2);
        // warmup: dense, CCR 2.5 -> interval 3
        assert_eq!(feed_step(&mut c, 2, 0, 1.0, 2.5, 999, 999), Some(3));
        // steady state under I=3: measured comm and wire both ~1/3 of
        // dense; the rescale recovers CCR 2.5 -> proposal 3 == current.
        for s in 1..=3 {
            let got = feed_step(&mut c, 2, s, 1.0, 2.5 / 3.0, 333, 999);
            assert_eq!(got, None, "step {s}");
        }
        let d = *c.history().last().unwrap();
        assert!((d.ccr - 2.5).abs() < 1e-6, "rescaled ccr {}", d.ccr);
        assert_eq!(d.proposed, 3);
        assert!(!d.switched);
    }

    #[test]
    fn hysteresis_needs_consecutive_agreeing_windows() {
        let mut c = IntervalController::new(1, 1, 1, 2, 2);
        assert_eq!(feed_step(&mut c, 1, 0, 1.0, 2.0, 10, 10), Some(2));
        // bandwidth drops: dense-equivalent CCR jumps to ~6
        let mut step = 1;
        let mut drift = |c: &mut IntervalController, comm: f64| {
            let mut out = None;
            for _ in 0..2 {
                out = feed_step(c, 1, step, 1.0, comm, 5, 10);
                step += 1;
            }
            out
        };
        // first drifted window: proposal 6, gate stays closed
        assert_eq!(drift(&mut c, 3.0), None);
        assert_eq!(c.current_interval(), 2);
        // second consecutive window proposing 6: gate opens
        assert_eq!(drift(&mut c, 3.0), Some(6));
        assert_eq!(c.current_interval(), 6);
        let switched: Vec<bool> = c.history().iter().map(|d| d.switched).collect();
        assert_eq!(switched, vec![true, false, true]);
    }

    #[test]
    fn flapping_proposals_never_open_the_gate() {
        let mut c = IntervalController::new(1, 1, 1, 1, 2);
        assert_eq!(feed_step(&mut c, 1, 0, 1.0, 3.0, 10, 10), Some(3));
        // alternate between ceil 5 and ceil 2 forever: streak never hits 2
        for s in 0..10u64 {
            let comm = if s % 2 == 0 { 4.5 } else { 1.5 };
            assert_eq!(feed_step(&mut c, 1, 1 + s, 1.0, comm, 10, 10), None, "step {s}");
        }
        assert_eq!(c.current_interval(), 3);
        assert!(c.history().iter().skip(1).all(|d| !d.switched));
    }

    #[test]
    fn returning_to_current_resets_the_streak() {
        let mut c = IntervalController::new(1, 1, 1, 1, 2);
        assert_eq!(feed_step(&mut c, 1, 0, 1.0, 3.0, 10, 10), Some(3));
        // one window proposing 6...
        assert_eq!(feed_step(&mut c, 1, 1, 1.0, 6.0, 10, 10), None);
        // ...then one back at 3: pending streak must reset...
        assert_eq!(feed_step(&mut c, 1, 2, 1.0, 3.0, 10, 10), None);
        // ...so the next 6-window starts a fresh streak of 1, not 2.
        assert_eq!(feed_step(&mut c, 1, 3, 1.0, 6.0, 10, 10), None);
        assert_eq!(c.current_interval(), 3);
        // and a second consecutive 6-window finally switches
        assert_eq!(feed_step(&mut c, 1, 4, 1.0, 6.0, 10, 10), Some(6));
    }

    #[test]
    fn degenerate_windows_hold_without_deciding() {
        let mut c = IntervalController::new(1, 1, 1, 1, 1);
        // nothing moved: scale is undefined -> no decision, no history row
        assert_eq!(feed_step(&mut c, 1, 0, 1.0, 0.5, 0, 10), None);
        assert!(c.history().is_empty());
        assert!(!c.concluded());
        // zero compute: CCR NaN -> same
        assert_eq!(c.end_step(1, 10, 10), None);
        assert!(c.history().is_empty());
        // a healthy window still works afterwards
        assert_eq!(feed_step(&mut c, 1, 2, 1.0, 3.5, 10, 10), Some(4));
    }
}
