//! §III.A — the coarse-grained gradient filter.
//!
//! Granularity is the communication tensor (bucket/shard), not individual
//! gradients: tensor `t` is transmitted in iteration `s` iff
//! `(t + s) % I == 0`. The decision is a modular counter — O(1) per tensor,
//! no value inspection, no synchronization (every worker derives the same
//! decision from (t, s, I) locally), hence zero data dependency.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoarseFilter {
    interval: usize,
}

impl CoarseFilter {
    pub fn new(interval: usize) -> CoarseFilter {
        assert!(interval >= 1, "interval must be >= 1");
        CoarseFilter { interval }
    }

    pub fn interval(&self) -> usize {
        self.interval
    }

    /// Is tensor `t` transmitted at iteration `step`?
    #[inline]
    pub fn keep(&self, tensor: usize, step: u64) -> bool {
        (tensor as u64 + step) % self.interval as u64 == 0
    }

    /// The tensors transmitted at `step` out of `n_tensors` — each step
    /// selects ~n/I tensors, rotating so every tensor goes exactly once per
    /// I iterations.
    pub fn selected(&self, n_tensors: usize, step: u64) -> Vec<usize> {
        (0..n_tensors).filter(|&t| self.keep(t, step)).collect()
    }

    /// Effective compression ratio (volume reduction factor) = I.
    pub fn ratio(&self) -> f64 {
        self.interval as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn paper_fig2a_example() {
        // I = 4: tensor 0 goes at steps 0, 4, 8...; the paper's 1-indexed
        // description ("first tensor at the 1st and 5th iterations") maps to
        // 0-indexed steps here. Tensor t goes when (t + s) % 4 == 0.
        let f = CoarseFilter::new(4);
        assert!(f.keep(0, 0) && f.keep(0, 4) && !f.keep(0, 1));
        assert!(f.keep(3, 1) && f.keep(2, 2) && f.keep(1, 3));
    }

    #[test]
    fn every_tensor_exactly_once_per_interval() {
        // Invariant (staleness bound): over any window of I consecutive
        // steps, each tensor is transmitted exactly once.
        prop::check("filter-coverage", 11, 100, |rng: &mut Rng| {
            let i = 1 + rng.below(16);
            let n = 1 + rng.below(64);
            let start = rng.below(1000) as u64;
            let f = CoarseFilter::new(i);
            for t in 0..n {
                let count = (start..start + i as u64).filter(|&s| f.keep(t, s)).count();
                assert_eq!(count, 1, "tensor {t} interval {i} window start {start}");
            }
        });
    }

    #[test]
    fn per_step_load_is_balanced() {
        // Each step transmits floor(n/I) or ceil(n/I) tensors.
        prop::check("filter-balance", 12, 100, |rng: &mut Rng| {
            let i = 1 + rng.below(8);
            let n = 1 + rng.below(100);
            let f = CoarseFilter::new(i);
            for s in 0..(2 * i as u64) {
                let k = f.selected(n, s).len();
                assert!(k == n / i || k == n / i + (n % i != 0) as usize, "n={n} I={i} k={k}");
            }
        });
    }

    #[test]
    fn interval_one_keeps_everything() {
        let f = CoarseFilter::new(1);
        assert!((0..50).all(|t| f.keep(t, 17)));
    }
}
