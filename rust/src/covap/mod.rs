//! COVAP core: the paper's §III — coarse-grained filter, adaptive interval
//! selection (one-shot *and* the closed-loop controller), tensor sharding,
//! and the error-feedback scheduler.

mod controller;
mod filter;
mod scheduler;
mod sharding;

pub use controller::{IntervalController, IntervalDecision};
pub use filter::CoarseFilter;
pub use scheduler::EfScheduler;
pub use sharding::{shard_buckets, Shard};

/// §III.B: the interval (compression ratio) is ceil(CCR), clamped to >= 1.
///
/// COVAP must reduce communication volume by at least CCR× so that the
/// compressed communication fits under the computation for full overlap;
/// ceil() compresses "a little more than CCR times".
pub fn interval_from_ccr(ccr: f64) -> usize {
    if !ccr.is_finite() || ccr <= 1.0 {
        1
    } else {
        ccr.ceil() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_is_ceil_ccr() {
        assert_eq!(interval_from_ccr(2.1), 3);
        assert_eq!(interval_from_ccr(4.0), 4);
        assert_eq!(interval_from_ccr(3.5), 4);
    }

    #[test]
    fn interval_clamps_low_and_garbage() {
        assert_eq!(interval_from_ccr(0.4), 1); // computation-bound: no compression
        assert_eq!(interval_from_ccr(1.0), 1);
        assert_eq!(interval_from_ccr(f64::NAN), 1);
        assert_eq!(interval_from_ccr(f64::INFINITY), 1);
    }
}
