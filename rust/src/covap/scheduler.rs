//! §III.D — the error-feedback compensation scheduler.
//!
//! Residuals are re-injected scaled by
//! `min(init_value + floor(step / ascend_steps) * ascend_range, 1)`:
//! small early in training (large stale compensation harms accuracy,
//! cf. LSDDL) and ramping to full feedback.

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EfScheduler {
    pub init_value: f32,
    pub ascend_steps: u64,
    pub ascend_range: f32,
}

impl Default for EfScheduler {
    fn default() -> Self {
        // Reaches full compensation after ~10 * ascend_steps iterations.
        EfScheduler { init_value: 0.1, ascend_steps: 100, ascend_range: 0.09 }
    }
}

impl EfScheduler {
    /// Constant-coefficient feedback (classic error feedback).
    pub fn constant(c: f32) -> EfScheduler {
        EfScheduler { init_value: c, ascend_steps: u64::MAX, ascend_range: 0.0 }
    }

    /// Compensation coefficient at iteration `step`.
    ///
    /// Overflow audit: `(step / ascend_steps) as f32` can reach ~1.8e19 for
    /// huge step counts, and multiplying by a large `ascend_range` then
    /// saturates f32 *before* the `.min(1.0)` clamp. The coefficient is
    /// capped at 1.0 anyway, so the ascent count is clamped to the first
    /// plateau past saturation — every reachable value is unchanged, and
    /// `coeff(u64::MAX - 1)` stays finite (pinned below).
    pub fn coeff(&self, step: u64) -> f32 {
        if self.ascend_range <= 0.0 {
            return self.init_value.min(1.0);
        }
        // Plateaus beyond this count cannot change the clamped result.
        let cap = ((1.0f32 - self.init_value).max(0.0) / self.ascend_range).ceil();
        let cap = if cap.is_finite() { cap as u64 + 1 } else { u32::MAX as u64 };
        let ascents = (step / self.ascend_steps.max(1)).min(cap) as f32;
        (self.init_value + ascents * self.ascend_range).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascends_in_plateaus() {
        let s = EfScheduler { init_value: 0.1, ascend_steps: 10, ascend_range: 0.2 };
        assert_eq!(s.coeff(0), 0.1);
        assert_eq!(s.coeff(9), 0.1);
        assert!((s.coeff(10) - 0.3).abs() < 1e-6);
        assert!((s.coeff(25) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn caps_at_one() {
        let s = EfScheduler { init_value: 0.5, ascend_steps: 1, ascend_range: 0.5 };
        assert_eq!(s.coeff(100), 1.0);
    }

    #[test]
    fn constant_never_moves() {
        let s = EfScheduler::constant(0.7);
        assert_eq!(s.coeff(0), 0.7);
        assert_eq!(s.coeff(1_000_000), 0.7);
    }

    #[test]
    fn default_reaches_full_feedback() {
        let s = EfScheduler::default();
        assert_eq!(s.coeff(0), 0.1);
        assert_eq!(s.coeff(1000), 1.0);
    }

    /// Satellite (overflow audit): near-u64::MAX step counts with a short
    /// ascend period must neither saturate f32 into inf/NaN nor dodge the
    /// 1.0 cap — the coefficient is exactly 1.0 and finite.
    #[test]
    fn huge_step_counts_stay_finite_and_clamped() {
        for s in [
            EfScheduler { init_value: 0.1, ascend_steps: 1, ascend_range: 0.09 },
            EfScheduler { init_value: 0.0, ascend_steps: 1, ascend_range: f32::MAX },
            EfScheduler { init_value: 0.5, ascend_steps: 7, ascend_range: 1e30 },
            EfScheduler::default(),
        ] {
            let c = s.coeff(u64::MAX - 1);
            assert!(c.is_finite(), "{s:?} -> {c}");
            assert_eq!(c, 1.0, "{s:?}");
        }
        // and a tiny range: clamped ascents still approach the init value
        let s = EfScheduler { init_value: 0.3, ascend_steps: 1, ascend_range: 0.0 };
        assert_eq!(s.coeff(u64::MAX - 1), 0.3);
    }
}
