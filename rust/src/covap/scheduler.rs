//! §III.D — the error-feedback compensation scheduler.
//!
//! Residuals are re-injected scaled by
//! `min(init_value + floor(step / ascend_steps) * ascend_range, 1)`:
//! small early in training (large stale compensation harms accuracy,
//! cf. LSDDL) and ramping to full feedback.

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EfScheduler {
    pub init_value: f32,
    pub ascend_steps: u64,
    pub ascend_range: f32,
}

impl Default for EfScheduler {
    fn default() -> Self {
        // Reaches full compensation after ~10 * ascend_steps iterations.
        EfScheduler { init_value: 0.1, ascend_steps: 100, ascend_range: 0.09 }
    }
}

impl EfScheduler {
    /// Constant-coefficient feedback (classic error feedback).
    pub fn constant(c: f32) -> EfScheduler {
        EfScheduler { init_value: c, ascend_steps: u64::MAX, ascend_range: 0.0 }
    }

    /// Compensation coefficient at iteration `step`.
    pub fn coeff(&self, step: u64) -> f32 {
        let ascents = (step / self.ascend_steps) as f32;
        (self.init_value + ascents * self.ascend_range).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascends_in_plateaus() {
        let s = EfScheduler { init_value: 0.1, ascend_steps: 10, ascend_range: 0.2 };
        assert_eq!(s.coeff(0), 0.1);
        assert_eq!(s.coeff(9), 0.1);
        assert!((s.coeff(10) - 0.3).abs() < 1e-6);
        assert!((s.coeff(25) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn caps_at_one() {
        let s = EfScheduler { init_value: 0.5, ascend_steps: 1, ascend_range: 0.5 };
        assert_eq!(s.coeff(100), 1.0);
    }

    #[test]
    fn constant_never_moves() {
        let s = EfScheduler::constant(0.7);
        assert_eq!(s.coeff(0), 0.7);
        assert_eq!(s.coeff(1_000_000), 0.7);
    }

    #[test]
    fn default_reaches_full_feedback() {
        let s = EfScheduler::default();
        assert_eq!(s.coeff(0), 0.1);
        assert_eq!(s.coeff(1000), 1.0);
    }
}
