//! §III.C — tensor sharding: slice oversized communication tensors so the
//! per-iteration transmitted volume is balanced.
//!
//! After bucket construction, find the median element count; any bucket
//! with `numel >= 2 * median` is sliced evenly into
//! `min(floor(numel / median), I)` shards (at least 2). Shards become
//! independent tensors for the coarse filter.

/// A slice of an original bucket: the unit COVAP's filter selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// Index of the source bucket.
    pub bucket: usize,
    /// Offset in elements within the bucket.
    pub offset: usize,
    pub len: usize,
}

/// Shard `bucket_sizes` (elements) for filter interval `interval`.
/// Returns shards in bucket order; un-sliced buckets appear as one shard.
pub fn shard_buckets(bucket_sizes: &[usize], interval: usize) -> Vec<Shard> {
    assert!(interval >= 1);
    if bucket_sizes.is_empty() {
        return vec![];
    }
    // Degenerate case: a single communication bucket (small models fit in
    // one 25 MiB bucket). The median rule can never fire (median == numel),
    // yet the imbalance is maximal — one step carries the whole model and
    // the rest carry nothing. Slice it straight into I shards.
    if bucket_sizes.len() == 1 && interval > 1 {
        let numel = bucket_sizes[0];
        let parts = interval.min(numel.max(1));
        let base = numel / parts;
        let extra = numel % parts;
        let mut off = 0;
        return (0..parts)
            .map(|p| {
                let len = base + usize::from(p < extra);
                let s = Shard { bucket: 0, offset: off, len };
                off += len;
                s
            })
            .collect();
    }
    let median = median_of(bucket_sizes);
    let mut shards = Vec::new();
    for (b, &numel) in bucket_sizes.iter().enumerate() {
        // numel >= 2*median implies floor(numel/median) >= 2; the interval
        // cap can still reduce it to 1 (I = 1 means "transmit everything",
        // where sharding is moot).
        let parts = if median > 0 && numel >= 2 * median {
            (numel / median).min(interval)
        } else {
            1
        };
        // Even split: first (numel % parts) shards get one extra element.
        let base = numel / parts;
        let extra = numel % parts;
        let mut off = 0;
        for p in 0..parts {
            let len = base + usize::from(p < extra);
            shards.push(Shard { bucket: b, offset: off, len });
            off += len;
        }
        debug_assert_eq!(off, numel);
    }
    shards
}

fn median_of(xs: &[usize]) -> usize {
    let mut v = xs.to_vec();
    v.sort_unstable();
    v[v.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// The paper's VGG-19 example (Table V): median = 5,590,260... the paper
    /// uses ~5.59M; with our exact Table V sizes the median is 7,079,424's
    /// neighbour — check the qualitative claim: tensor 3 (107.5M elements)
    /// shards into `min(floor(numel/median), I)` parts.
    #[test]
    fn vgg19_table5_sharding() {
        let sizes = [4_101_096, 16_781_312, 107_480_576, 7_079_424, 7_669_760, 555_072];
        // paper: interval 4 for VGG-19
        let shards = shard_buckets(&sizes, 4);
        let parts_of = |b: usize| shards.iter().filter(|s| s.bucket == b).count();
        assert_eq!(parts_of(2), 4, "oversized tensor capped at I shards");
        assert_eq!(parts_of(0), 1);
        assert_eq!(parts_of(5), 1);
        // tensor 2 (16.78M vs median 7.07M/7.67M): floor ratio = 2 shards
        assert_eq!(parts_of(1), 2);
    }

    #[test]
    fn with_large_interval_matches_paper_counts() {
        // With I >= 19 the paper says tensors 2 and 3 shard into 3 and 19
        // parts and the total tensor count becomes 26.
        let sizes = [4_101_096, 16_781_312, 107_480_576, 7_079_424, 7_669_760, 555_072];
        // Paper's median (mean-like midpoint) is 5,590,260; ours is the true
        // median of 6 values = lower-middle after sort. Use the paper's
        // qualitative outcome with a large interval:
        let shards = shard_buckets(&sizes, 32);
        let parts_of = |b: usize| shards.iter().filter(|s| s.bucket == b).count();
        assert!(parts_of(1) >= 2);
        assert!(parts_of(2) >= 14, "giant tensor shards ~numel/median times");
    }

    #[test]
    fn shards_tile_buckets_exactly() {
        prop::check("shard-partition", 13, 200, |rng: &mut Rng| {
            let nb = 1 + rng.below(12);
            let sizes: Vec<usize> = (0..nb).map(|_| 1 + rng.below(1 << 20)).collect();
            let interval = 1 + rng.below(8);
            let shards = shard_buckets(&sizes, interval);
            for (b, &numel) in sizes.iter().enumerate() {
                let mut bs: Vec<_> = shards.iter().filter(|s| s.bucket == b).collect();
                bs.sort_by_key(|s| s.offset);
                assert!(!bs.is_empty());
                assert_eq!(bs[0].offset, 0);
                let mut end = 0;
                for s in &bs {
                    assert_eq!(s.offset, end, "gap in bucket {b}");
                    assert!(s.len > 0);
                    end = s.offset + s.len;
                }
                assert_eq!(end, numel, "bucket {b} not fully covered");
            }
        });
    }

    #[test]
    fn shard_sizes_balanced_within_one() {
        prop::check("shard-balance", 14, 200, |rng: &mut Rng| {
            let nb = 2 + rng.below(8);
            let sizes: Vec<usize> = (0..nb).map(|_| 1 + rng.below(1 << 22)).collect();
            let shards = shard_buckets(&sizes, 1 + rng.below(8));
            for b in 0..nb {
                let lens: Vec<usize> =
                    shards.iter().filter(|s| s.bucket == b).map(|s| s.len).collect();
                let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(mx - mn <= 1, "bucket {b} uneven: {lens:?}");
            }
        });
    }

    #[test]
    fn never_more_shards_than_interval() {
        prop::check("shard-cap", 15, 200, |rng: &mut Rng| {
            let nb = 1 + rng.below(10);
            let sizes: Vec<usize> = (0..nb).map(|_| 1 + rng.below(1 << 24)).collect();
            let interval = 1 + rng.below(6);
            let shards = shard_buckets(&sizes, interval);
            for b in 0..nb {
                let parts = shards.iter().filter(|s| s.bucket == b).count();
                assert!(parts <= interval, "bucket {b}: {parts} > I={interval}");
            }
        });
    }

    #[test]
    fn single_bucket_slices_into_interval() {
        let shards = shard_buckets(&[1000], 4);
        assert_eq!(shards.len(), 4);
        assert!(shards.iter().all(|s| s.len == 250));
        assert_eq!(shard_buckets(&[1000], 1).len(), 1);
    }

    #[test]
    fn uniform_buckets_untouched() {
        let shards = shard_buckets(&[100, 100, 100, 100], 4);
        assert_eq!(shards.len(), 4);
        assert!(shards.iter().all(|s| s.len == 100 && s.offset == 0));
    }
}
