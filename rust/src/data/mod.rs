//! Synthetic training data: a structured token stream with learnable
//! next-token statistics, sharded per DP worker.
//!
//! The generator is a two-level Markov source: a Zipfian unigram base
//! distribution blended with a deterministic successor rule, so an LM can
//! reduce loss well below log(vocab) — giving the convergence experiments
//! (Table VII analogue) a real signal without shipping a corpus.

use crate::util::rng::Rng;

/// Markov-Zipf synthetic corpus.
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    vocab: usize,
    /// Probability of following the deterministic successor instead of
    /// sampling from the Zipf base.
    succ_prob: f64,
    /// Cumulative Zipf distribution for inverse-CDF sampling.
    zipf_cdf: Vec<f64>,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize) -> SyntheticCorpus {
        assert!(vocab >= 4);
        let s = 1.1; // Zipf exponent
        let mut weights: Vec<f64> = (1..=vocab).map(|r| 1.0 / (r as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in &mut weights {
            acc += *w / total;
            *w = acc;
        }
        SyntheticCorpus { vocab, succ_prob: 0.75, zipf_cdf: weights }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    fn zipf(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        self.zipf_cdf.partition_point(|&c| c < u).min(self.vocab - 1)
    }

    /// Deterministic successor rule (affine map — learnable by an LM).
    fn successor(&self, t: usize) -> usize {
        (t.wrapping_mul(31).wrapping_add(7)) % self.vocab
    }

    /// Next token given the previous one.
    pub fn next(&self, prev: usize, rng: &mut Rng) -> usize {
        if rng.next_f64() < self.succ_prob {
            self.successor(prev)
        } else {
            self.zipf(rng)
        }
    }

    /// A [batch, seq+1] token block (i32 for the model artifact).
    pub fn batch(&self, rng: &mut Rng, batch: usize, seq_plus1: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq_plus1);
        for _ in 0..batch {
            let mut t = self.zipf(rng);
            out.push(t as i32);
            for _ in 1..seq_plus1 {
                t = self.next(t, rng);
                out.push(t as i32);
            }
        }
        out
    }
}

/// A worker's shard: an independent deterministic stream (fork of the run
/// seed), mirroring disjoint DataLoader partitions.
#[derive(Debug, Clone)]
pub struct DataShard {
    corpus: SyntheticCorpus,
    rng: Rng,
    batch: usize,
    seq_plus1: usize,
}

impl DataShard {
    pub fn new(
        corpus: SyntheticCorpus,
        run_seed: u64,
        worker: usize,
        batch: usize,
        seq_plus1: usize,
    ) -> DataShard {
        let rng = Rng::seed(run_seed).fork(worker as u64 + 1);
        DataShard { corpus, rng, batch, seq_plus1 }
    }

    /// The next [batch, seq+1] block for this worker.
    pub fn next_batch(&mut self) -> Vec<i32> {
        self.corpus.batch(&mut self.rng, self.batch, self.seq_plus1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shape_and_range() {
        let c = SyntheticCorpus::new(256);
        let mut rng = Rng::seed(1);
        let b = c.batch(&mut rng, 4, 65);
        assert_eq!(b.len(), 4 * 65);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn stream_is_learnable() {
        // Successor rule fires ~75% of the time: bigram (t, successor(t))
        // must dominate.
        let c = SyntheticCorpus::new(64);
        let mut rng = Rng::seed(2);
        let toks = c.batch(&mut rng, 1, 10_001);
        let mut hits = 0;
        for w in toks.windows(2) {
            if w[1] as usize == c.successor(w[0] as usize) {
                hits += 1;
            }
        }
        let rate = hits as f64 / 10_000.0;
        assert!((0.70..0.85).contains(&rate), "successor rate {rate}");
    }

    #[test]
    fn shards_are_disjoint_streams() {
        let c = SyntheticCorpus::new(128);
        let mut s0 = DataShard::new(c.clone(), 7, 0, 2, 17);
        let mut s1 = DataShard::new(c.clone(), 7, 1, 2, 17);
        assert_ne!(s0.next_batch(), s1.next_batch());
        // deterministic per worker
        let mut s0b = DataShard::new(c, 7, 0, 2, 17);
        assert_eq!(s0b.next_batch(), DataShard::new(SyntheticCorpus::new(128), 7, 0, 2, 17).next_batch());
    }

    #[test]
    fn zipf_is_skewed() {
        let c = SyntheticCorpus::new(1000);
        let mut rng = Rng::seed(3);
        let mut low = 0;
        for _ in 0..10_000 {
            if c.zipf(&mut rng) < 10 {
                low += 1;
            }
        }
        // top-10 of 1000 ranks should carry a large mass under Zipf(1.1)
        assert!(low > 2_000, "top-10 mass {low}/10000");
    }
}
