//! A reusable sense-reversing barrier with wait-time measurement.
//!
//! `std::sync::Barrier` works, but rendezvous *wait time* is exactly the
//! quantity the distributed profiler cares about (fast ranks blocking for
//! stragglers inflate naive communication measurements, §III.B), so this
//! barrier returns how long each rank waited — the executor feeds that
//! skew into its measured timeline.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

struct State {
    count: usize,
    generation: u64,
    aborted: bool,
}

/// Reusable barrier for `parties` threads.
pub struct Barrier {
    parties: usize,
    state: Mutex<State>,
    cv: Condvar,
}

impl Barrier {
    pub fn new(parties: usize) -> Barrier {
        assert!(parties >= 1);
        Barrier {
            parties,
            state: Mutex::new(State { count: 0, generation: 0, aborted: false }),
            cv: Condvar::new(),
        }
    }

    pub fn parties(&self) -> usize {
        self.parties
    }

    /// Block until all parties arrive; returns this thread's wait time.
    /// The last arrival waits ~zero — the spread over ranks is the skew.
    /// Returns immediately once the barrier is [`abort`](Barrier::abort)ed.
    ///
    /// Lock poisoning is deliberately ignored (`PoisonError::into_inner`):
    /// the state is a plain counter triple that is valid after any partial
    /// update, and a panicking peer must release — not poison-panic — the
    /// surviving ranks, or teardown would cascade.
    pub fn wait(&self) -> Duration {
        let t0 = Instant::now();
        let mut st = self
            .state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if st.aborted {
            return t0.elapsed();
        }
        let gen = st.generation;
        st.count += 1;
        if st.count == self.parties {
            st.count = 0;
            st.generation = st.generation.wrapping_add(1);
            self.cv.notify_all();
            return t0.elapsed();
        }
        while st.generation == gen && !st.aborted {
            st = self
                .cv
                .wait(st)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        t0.elapsed()
    }

    /// True once [`abort`](Barrier::abort) poisoned the barrier. A waiter
    /// released by `wait()` cannot tell a normal release from an abort (the
    /// return value is its wait time either way), so compute threads check
    /// this immediately after the rendezvous: on an aborted barrier they
    /// must *skip* the step — no gradient, no EF accumulate, no shard
    /// advance — and stay alive for the membership controller's state
    /// export instead of marching into a dead mesh.
    pub fn is_aborted(&self) -> bool {
        self.state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .aborted
    }

    /// Poison the barrier: release every current waiter and make all
    /// future waits return immediately. Used during executor teardown so
    /// a dead rank can never strand its peers in the rendezvous — the
    /// released ranks then fail fast on their broken channels instead of
    /// hanging the process.
    pub fn abort(&self) {
        let mut st = self
            .state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        st.aborted = true;
        st.count = 0;
        st.generation = st.generation.wrapping_add(1);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn releases_all_parties() {
        let b = Arc::new(Barrier::new(4));
        let hits = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let b = b.clone();
                let hits = hits.clone();
                s.spawn(move || {
                    b.wait();
                    hits.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn is_reusable_across_generations() {
        let b = Arc::new(Barrier::new(3));
        let sum = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..3 {
                let b = b.clone();
                let sum = sum.clone();
                s.spawn(move || {
                    for round in 0..10usize {
                        b.wait();
                        sum.fetch_add(round, Ordering::SeqCst);
                        b.wait(); // separate the rounds
                    }
                });
            }
        });
        assert_eq!(sum.load(Ordering::SeqCst), 3 * (0..10).sum::<usize>());
    }

    #[test]
    fn straggler_wait_is_measured() {
        let b = Arc::new(Barrier::new(2));
        let waits = std::thread::scope(|s| {
            let b1 = b.clone();
            let fast = s.spawn(move || b1.wait());
            let b2 = b.clone();
            let slow = s.spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                b2.wait()
            });
            (fast.join().unwrap(), slow.join().unwrap())
        });
        assert!(waits.0 >= Duration::from_millis(20), "fast rank waited {:?}", waits.0);
        assert!(waits.1 < Duration::from_millis(20), "slow rank waited {:?}", waits.1);
    }

    #[test]
    fn abort_releases_waiters_and_disables_barrier() {
        let b = Arc::new(Barrier::new(2));
        let waiter = {
            let b = b.clone();
            std::thread::spawn(move || b.wait())
        };
        std::thread::sleep(Duration::from_millis(20));
        b.abort();
        waiter.join().expect("waiter released, not stuck");
        // post-abort waits return immediately even with 2 parties
        assert!(b.wait() < Duration::from_millis(5));
    }

    /// The abort flag is observable after release — how a compute thread
    /// distinguishes "step begins" from "world is tearing down".
    #[test]
    fn abort_is_observable_after_release() {
        let b = Barrier::new(2);
        assert!(!b.is_aborted());
        b.abort();
        assert!(b.is_aborted());
        b.wait();
        assert!(b.is_aborted(), "abort is permanent");
    }

    #[test]
    fn single_party_never_blocks() {
        let b = Barrier::new(1);
        for _ in 0..5 {
            assert!(b.wait() < Duration::from_millis(5));
        }
    }
}
