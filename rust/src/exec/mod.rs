//! `exec` — the threaded rank executor: P ranks on real OS threads, each
//! with its own gradient buffer, data shard and per-rank error-feedback
//! state, exchanging *serialized* compressed-payload frames (encoded
//! in place by `RankCompressor::compress_into`, rotated through reusable
//! slot buffers) over a per-rank channel mesh, walking the configured
//! topology's hop schedule (`comm::topology`). Wire accounting is the
//! measured frame length — split per link level — shared with the
//! analytic backend's records; the steady-state compress→encode→rotate
//! path is allocation-free (DESIGN.md §7).
//!
//! This subsystem turns the repo's *simulated* overlap claims into
//! *measured* ones: the analytic backend predicts a step's
//! computation/compression/exposed-communication breakdown from the α–β
//! network model, the threaded backend measures the same quantities from
//! real two-thread-per-rank execution, and [`validate`] + the
//! `exec_vs_sim` bench put the two side by side. Both backends are
//! bitwise-identical in their numerics (same gradients, same per-rank
//! compression arithmetic, same combine order — enforced live via
//! checksum comparison across ranks and by the parity tests), so the only
//! thing that differs is *time*.
//!
//! Module map:
//! * [`ring`] — threaded collectives over a per-rank channel mesh,
//!   executing the configured topology's hop schedule
//!   (`comm::topology`; bitwise-validated against `comm::ring_allreduce`
//!   and the `comm::allgather` oracle) + per-level wire pacing.
//! * [`rank`] — the compute/comm thread pair of one rank.
//! * [`barrier`] — reusable sense-reversing barrier with skew measurement.
//! * [`timeline`] — measured spans -> breakdowns.
//! * [`validate`] — sim-vs-exec cross-validation harness.

pub mod barrier;
pub mod rank;
pub mod ring;
pub mod timeline;
pub mod validate;

pub use barrier::Barrier;
pub use rank::{fifo_layout_gen_at, fnv1a_f32, Cmd, CmdTag, RankMsg, RankStepResult, StepSpec};
pub use ring::{
    allgather_frames, allgather_payloads, allgather_sched, broadcast_abort, make_mesh,
    ring_allreduce_threaded, GatherScratch, MeshError, MeshLink, Pacer, PacerSet, RetryPolicy,
};
pub use timeline::{aggregate, breakdown, MeasuredBreakdown, RankTimeline, Span, SpanKind};
pub use validate::{compare_backends, BackendComparison};

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::comm::topology::HopSchedule;
use crate::compress::{CommRecord, SchemeKind};
use crate::coordinator::CommTensor;
use crate::data::DataShard;
use crate::runtime::RankModel;
use crate::sim::Policy;

/// A named rank failure surfaced by [`ThreadedExec::step`]. Carried as the
/// anyhow error's root cause so the engine's membership controller can
/// downcast it, identify the dead rank, and re-world the fleet instead of
/// aborting the run. `Display` keeps the exact pre-elastic message text —
/// callers that only format the error see no change.
#[derive(Debug, Clone)]
pub struct RankFailure {
    pub rank: usize,
    pub step: u64,
    /// True when the failure surfaced mid-step (after the step was issued
    /// to the fleet), false when the rank was already dead beforehand.
    pub during: bool,
    pub reason: String,
}

impl std::fmt::Display for RankFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let RankFailure { rank, step, reason, .. } = self;
        if self.during {
            write!(f, "rank {rank} failed during step {step}: {reason}")
        } else {
            write!(f, "rank {rank} failed before step {step}: {reason}")
        }
    }
}

impl std::error::Error for RankFailure {}

/// One step's outputs from the threaded executor.
pub struct ExecStepOutput {
    /// Per-rank losses (rank-major).
    pub losses: Vec<f32>,
    /// Per-rank gradient-computation wall times.
    pub comp_walls: Vec<f64>,
    /// Per-tensor accounting records (identical across ranks; rank 0's).
    pub records: Vec<CommRecord>,
    /// The dense reduced update (identical across ranks; rank 0's copy).
    pub reduced: Vec<f32>,
    /// Aggregate measured breakdown (mean busy times, worst-rank wall).
    pub measured: MeasuredBreakdown,
    pub per_rank: Vec<MeasuredBreakdown>,
    pub timelines: Vec<RankTimeline>,
}

/// P persistent rank workers (2P OS threads).
pub struct ThreadedExec {
    world: usize,
    cmd_tx: Vec<Sender<Cmd>>,
    res_rx: Receiver<RankMsg>,
    barrier: Arc<Barrier>,
    computes: Vec<JoinHandle<()>>,
    comms: Vec<JoinHandle<()>>,
}

impl ThreadedExec {
    /// Spawn the rank fleet. `models` and `shards` are rank-major; the
    /// scheme pair is built per rank from identical `(kind, world, seed)`
    /// so all replicas agree. `sched` is the configured topology's
    /// allgather hop schedule over exactly `world` ranks (shared by every
    /// comm thread), `pacers` the per-level emulated wire.
    pub fn new(
        kind: SchemeKind,
        seed: u64,
        models: Vec<Box<dyn RankModel>>,
        shards: Vec<DataShard>,
        sched: Arc<HopSchedule>,
        pacers: PacerSet,
    ) -> ThreadedExec {
        let world = models.len();
        Self::with_state(
            kind,
            seed,
            models,
            shards,
            sched,
            pacers,
            RetryPolicy::default(),
            (0..world).map(|_| None).collect(),
            Vec::new(),
        )
    }

    /// [`ThreadedExec::new`] plus the elastic-membership extras: a mesh
    /// receive [`RetryPolicy`] and per-rank initial EF residuals (`states`,
    /// rank-major, each a flat parameter-space vector sliced by `layout` at
    /// spawn — the redistributed handoff from a previous world). `None`
    /// entries start clean.
    #[allow(clippy::too_many_arguments)]
    pub fn with_state(
        kind: SchemeKind,
        seed: u64,
        models: Vec<Box<dyn RankModel>>,
        shards: Vec<DataShard>,
        sched: Arc<HopSchedule>,
        pacers: PacerSet,
        retry: RetryPolicy,
        mut states: Vec<Option<Vec<f32>>>,
        layout: Vec<(usize, usize)>,
    ) -> ThreadedExec {
        let world = models.len();
        assert!(world >= 1);
        assert_eq!(shards.len(), world);
        assert_eq!(sched.world(), world, "schedule must cover exactly the rank fleet");
        states.resize_with(world, || None);
        let barrier = Arc::new(Barrier::new(world));
        let links = make_mesh(world);
        let (res_tx, res_rx) = channel::<RankMsg>();
        let mut cmd_tx = Vec::with_capacity(world);
        let mut computes = Vec::with_capacity(world);
        let mut comms = Vec::with_capacity(world);
        let mut ranks: Vec<(Box<dyn RankModel>, DataShard, MeshLink)> = models
            .into_iter()
            .zip(shards)
            .zip(links)
            .map(|((m, s), l)| (m, s, l))
            .collect();
        for (r, (model, shard, link)) in ranks.drain(..).enumerate() {
            let (tx, rx) = channel::<Cmd>();
            cmd_tx.push(tx);
            let compute = rank::ComputeCtx {
                rank: r,
                workers: world,
                seed,
                kind: kind.clone(),
                model,
                shard,
                cmd_rx: rx,
                barrier: barrier.clone(),
                res_tx: res_tx.clone(),
                init_state: states[r].take().map(|flat| (flat, layout.clone())),
            };
            let comm = rank::CommCtx {
                rank: r,
                workers: world,
                seed,
                kind: kind.clone(),
                link,
                sched: sched.clone(),
                pacers,
                retry,
                res_tx: res_tx.clone(),
            };
            let (th, ch) = rank::spawn_rank(compute, comm)
                .unwrap_or_else(|e| panic!("spawn rank {r}: {e}"));
            computes.push(th);
            comms.push(ch);
        }
        ThreadedExec { world, cmd_tx, res_rx, barrier, computes, comms }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Swap every rank's scheme (adaptive-interval re-shard). `old`/`new`
    /// are the tensor layouts — `(flat offset, numel)` per slot — before
    /// and after the re-shard, so stateful compressors remap their EF
    /// residuals in place instead of dropping them.
    pub fn reconfigure(&self, kind: &SchemeKind, old: &[(usize, usize)], new: &[(usize, usize)]) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Reconfigure {
                kind: kind.clone(),
                old: old.to_vec(),
                new: new.to_vec(),
            });
        }
    }

    /// Replace the emulated per-level wire pacers on every rank (mid-run
    /// bandwidth change). Cmd/Work queues are FIFO, so a change sent
    /// before a step's `Cmd::Step` applies to that step — in lockstep
    /// with the engine's in-place `cfg.net` update for the modeled side.
    pub fn set_pacers(&self, pacers: PacerSet) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::SetPacer(pacers));
        }
    }

    /// Set one rank's synthetic compute inflation (straggler injection).
    pub fn set_rank_work(&self, rank: usize, work: u32) {
        if let Some(tx) = self.cmd_tx.get(rank) {
            let _ = tx.send(Cmd::SetWork(work));
        }
    }

    /// Kill one rank mid-run (failure injection). The next `step()` call
    /// returns an error naming the rank instead of hanging: the dying
    /// rank's comm thread broadcasts `Frame::Abort` so every peer's
    /// collective fails fast, and the engine aborts the barrier.
    pub fn fail_rank(&self, rank: usize, reason: &str) {
        if let Some(tx) = self.cmd_tx.get(rank) {
            let _ = tx.send(Cmd::Fail { reason: reason.to_string() });
        }
    }

    /// Collect every surviving rank's EF residuals, flattened over
    /// `layout` — the quiesce half of a membership change. Robust to dead
    /// ranks by construction: `skip` names a rank already known dead (no
    /// request is sent), a send onto a closed command channel marks the
    /// rank dead immediately, stale `RankMsg::Step`/`Failed` messages in
    /// the result queue are drained past, and a rank that dies between
    /// the send and its reply falls to the timeout. Because each rank's
    /// command queue is FIFO, any in-flight `Cmd::Reconfigure` is applied
    /// *before* the export — the returned states are never sliced by a
    /// stale shard layout, which is the `fail_rank`-during-reconfigure
    /// race this protocol closes (modeled in `analysis::loom_model`).
    ///
    /// Returns rank-major states; `None` = dead rank or stateless scheme.
    pub fn export_states(
        &mut self,
        layout: &[(usize, usize)],
        skip: Option<usize>,
    ) -> Vec<Option<Vec<f32>>> {
        let world = self.world;
        let mut out: Vec<Option<Vec<f32>>> = (0..world).map(|_| None).collect();
        let mut pending = vec![false; world];
        let mut waiting = 0usize;
        for (r, tx) in self.cmd_tx.iter().enumerate() {
            if Some(r) == skip {
                continue;
            }
            if tx.send(Cmd::ExportState { layout: layout.to_vec() }).is_ok() {
                pending[r] = true;
                waiting += 1;
            }
        }
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        while waiting > 0 {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.res_rx.recv_timeout(deadline - now) {
                Ok(RankMsg::State { rank, residuals }) => {
                    if rank < world && pending[rank] {
                        pending[rank] = false;
                        waiting -= 1;
                        out[rank] = residuals;
                    }
                }
                Ok(RankMsg::Failed { rank, .. }) => {
                    // late death notice: that rank will never reply
                    if rank < world && pending[rank] {
                        pending[rank] = false;
                        waiting -= 1;
                    }
                }
                Ok(RankMsg::Step(_)) => {} // stale result from an aborted step
                Err(_) => break,
            }
        }
        out
    }

    /// Run one synchronous step across all ranks.
    pub fn step(
        &mut self,
        step: u64,
        params: Arc<Vec<f32>>,
        tensors: Arc<Vec<CommTensor>>,
        policy: Policy,
    ) -> Result<ExecStepOutput> {
        let spec = StepSpec { step, params, tensors, policy, epoch: Instant::now() };
        for tx in &self.cmd_tx {
            if tx.send(Cmd::Step(spec.clone())).is_err() {
                // A rank died. Ranks that already received the step would
                // wait forever in the P-party rendezvous for the dead one;
                // poisoning the barrier releases them onto their broken
                // channels, where they fail fast instead of hanging Drop.
                self.barrier.abort();
                // a rank that failed earlier left its reason in the result
                // queue — surface it instead of a generic death notice
                while let Ok(msg) = self.res_rx.try_recv() {
                    if let RankMsg::Failed { rank, reason } = msg {
                        return Err(
                            RankFailure { rank, step, during: false, reason }.into()
                        );
                    }
                }
                anyhow::bail!("rank thread died before step {step}");
            }
        }
        let mut results: Vec<Option<RankStepResult>> =
            (0..self.world).map(|_| None).collect();
        let mut collected = 0usize;
        while collected < self.world {
            let r = match self.res_rx.recv() {
                Ok(RankMsg::Step(r)) => r,
                Ok(RankMsg::Failed { rank, reason }) => {
                    self.barrier.abort();
                    return Err(RankFailure { rank, step, during: true, reason }.into());
                }
                Ok(RankMsg::State { .. }) => {
                    // can't happen in a well-ordered protocol (exports are
                    // only requested between steps); ignore defensively
                    continue;
                }
                Err(_) => {
                    self.barrier.abort();
                    anyhow::bail!("rank threads died during step {step}");
                }
            };
            let idx = r.rank;
            ensure!(results[idx].is_none(), "duplicate result from rank {idx}");
            results[idx] = Some(r);
            collected += 1;
        }
        let results: Vec<RankStepResult> =
            results.into_iter().map(|o| o.expect("all ranks reported")).collect();

        // The live parity invariant: every rank must hold bit-identical
        // reduced gradients.
        let c0 = results[0].checksum;
        for r in &results {
            ensure!(
                r.checksum == c0,
                "rank {} reduced-gradient checksum diverged at step {step} \
                 ({:#x} vs {:#x})",
                r.rank,
                r.checksum,
                c0
            );
        }

        let losses: Vec<f32> = results.iter().map(|r| r.loss).collect();
        let comp_walls: Vec<f64> = results.iter().map(|r| r.comp_wall_s).collect();
        let timelines: Vec<RankTimeline> =
            results.iter().map(|r| r.timeline.clone()).collect();
        let per_rank: Vec<MeasuredBreakdown> = timelines.iter().map(breakdown).collect();
        let measured = aggregate(&per_rank);
        let mut it = results.into_iter();
        let first = it.next().expect("world >= 1");
        let reduced = first.reduced.expect("rank 0 ships the reduced update");
        let records = first.records;
        Ok(ExecStepOutput {
            losses,
            comp_walls,
            records,
            reduced,
            measured,
            per_rank,
            timelines,
        })
    }
}

impl Drop for ThreadedExec {
    fn drop(&mut self) {
        for tx in &self.cmd_tx {
            let _ = tx.send(Cmd::Shutdown);
        }
        // release any rank stranded in the rendezvous by a dead peer
        // (no-op when all ranks are idle at their command queues)
        self.barrier.abort();
        for h in self.computes.drain(..) {
            let _ = h.join();
        }
        for h in self.comms.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticCorpus;
    use crate::runtime::{synthetic, SyntheticModel, SyntheticSpec};

    fn setup(world: usize, kind: &SchemeKind, seed: u64) -> (ThreadedExec, usize) {
        use crate::comm::TopologyKind;
        use crate::network::ClusterSpec;
        let n = 400usize;
        let spec = SyntheticSpec::new(0xBEEF, 1);
        let models: Vec<Box<dyn RankModel>> = (0..world)
            .map(|_| Box::new(SyntheticModel::new(spec)) as Box<dyn RankModel>)
            .collect();
        let corpus = SyntheticCorpus::new(64);
        let shards: Vec<DataShard> =
            (0..world).map(|w| DataShard::new(corpus.clone(), seed, w, 2, 9)).collect();
        let cluster = ClusterSpec::new(world, 1);
        let sched =
            Arc::new(TopologyKind::Auto.resolve(cluster).allgather_schedule(cluster));
        let exec =
            ThreadedExec::new(kind.clone(), seed, models, shards, sched, PacerSet::default());
        (exec, n)
    }

    fn tensors_of(n: usize) -> Arc<Vec<CommTensor>> {
        Arc::new(vec![
            CommTensor { offset: 0, numel: n / 3, bucket: 0 },
            CommTensor { offset: n / 3, numel: n - n / 3, bucket: 1 },
        ])
    }

    /// The executor's reduced update must equal an in-process lockstep
    /// replay: same shards, same models, same scheme arithmetic.
    #[test]
    fn threaded_step_matches_lockstep_replay() {
        for kind in [
            SchemeKind::Baseline,
            SchemeKind::Covap { interval: 2, ef: crate::covap::EfScheduler::constant(1.0) },
            SchemeKind::TopK { ratio: 0.05 },
        ] {
            let world = 3;
            let seed = 11u64;
            let (mut exec, n) = setup(world, &kind, seed);
            let params = Arc::new(vec![0.05f32; n]);
            let tensors = tensors_of(n);

            // lockstep replay of the same streams
            let spec = SyntheticSpec::new(0xBEEF, 1);
            let corpus = SyntheticCorpus::new(64);
            let mut shards: Vec<DataShard> =
                (0..world).map(|w| DataShard::new(corpus.clone(), seed, w, 2, 9)).collect();
            let mut scheme = kind.build(world, seed);

            for step in 0..3u64 {
                let out = exec
                    .step(step, params.clone(), tensors.clone(), Policy::Overlap)
                    .unwrap();

                let grads: Vec<Vec<f32>> = shards
                    .iter_mut()
                    .map(|sh| {
                        let batch = sh.next_batch();
                        let mut m = SyntheticModel::new(spec);
                        m.fwd_bwd(&params, &batch).1
                    })
                    .collect();
                let mut want = vec![0.0f32; n];
                for (idx, t) in tensors.iter().enumerate() {
                    let refs: Vec<&[f32]> = grads
                        .iter()
                        .map(|g| &g[t.offset..t.offset + t.numel])
                        .collect();
                    let (u, _) = scheme.round(idx, step, &refs);
                    if !u.is_empty() {
                        want[t.offset..t.offset + t.numel].copy_from_slice(&u);
                    }
                }
                assert_eq!(out.reduced, want, "{} step {step}", kind.label());
                assert_eq!(out.losses.len(), world);
                assert!(out.measured.wall_s > 0.0);
            }
        }
    }

    #[test]
    fn sequential_policy_also_agrees_bitwise() {
        let kind = SchemeKind::Fp16;
        let (mut exec, n) = setup(4, &kind, 3);
        let params = Arc::new(vec![0.01f32; n]);
        let tensors = tensors_of(n);
        let a = exec
            .step(0, params.clone(), tensors.clone(), Policy::Sequential)
            .unwrap();
        // same step inputs, fresh executor, overlap policy: same bits
        let (mut exec2, _) = setup(4, &kind, 3);
        let b = exec2.step(0, params, tensors, Policy::Overlap).unwrap();
        assert_eq!(a.reduced, b.reduced, "policy must not change numerics");
    }

    /// The issue's wire-measurement criterion: every CommRecord.wire_bytes
    /// the threaded backend reports equals the byte length of the largest
    /// encoded payload frame the ranks exchanged for that tensor (== each
    /// rank's own frame for size-uniform schemes).
    #[test]
    fn records_charge_encoded_frame_lengths() {
        use crate::compress::build_rank_pair;
        for kind in [
            SchemeKind::Baseline,
            SchemeKind::Fp16,
            SchemeKind::TopK { ratio: 0.05 },
            SchemeKind::EfSignSgd,
        ] {
            let world = 2;
            let seed = 13u64;
            let (mut exec, n) = setup(world, &kind, seed);
            let params = Arc::new(vec![0.05f32; n]);
            let tensors = tensors_of(n);
            let out = exec
                .step(0, params.clone(), tensors.clone(), Policy::Overlap)
                .unwrap();

            // replay the per-rank compression to materialize the frames
            let spec = SyntheticSpec::new(0xBEEF, 1);
            let corpus = SyntheticCorpus::new(64);
            let mut shards: Vec<DataShard> =
                (0..world).map(|w| DataShard::new(corpus.clone(), seed, w, 2, 9)).collect();
            let mut cs: Vec<_> =
                (0..world).map(|_| build_rank_pair(&kind, world, seed).0).collect();
            let grads: Vec<Vec<f32>> = shards
                .iter_mut()
                .map(|sh| {
                    let batch = sh.next_batch();
                    let mut m = SyntheticModel::new(spec);
                    m.fwd_bwd(&params, &batch).1
                })
                .collect();
            for (idx, t) in tensors.iter().enumerate() {
                let frames: Vec<usize> = cs
                    .iter_mut()
                    .zip(grads.iter())
                    .map(|(c, g)| {
                        let p = c.compress(idx, 0, &g[t.offset..t.offset + t.numel]);
                        let frame = p.encode();
                        assert_eq!(frame.len(), p.encoded_len());
                        frame.len()
                    })
                    .collect();
                let want = frames.iter().copied().max().unwrap();
                assert_eq!(
                    out.records[idx].wire_bytes, want,
                    "{} tensor {idx}: record must charge the measured frame",
                    kind.label()
                );
            }
        }
    }

    /// The quiesce half of a membership change: residuals export without
    /// disturbing the fleet, re-import bitwise through `with_state`, and
    /// the donor fleet keeps stepping afterwards.
    #[test]
    fn export_states_roundtrip_through_new_world() {
        use crate::comm::TopologyKind;
        use crate::network::ClusterSpec;
        let kind =
            SchemeKind::Covap { interval: 2, ef: crate::covap::EfScheduler::constant(1.0) };
        let seed = 21u64;
        let (mut exec, n) = setup(2, &kind, seed);
        let params = Arc::new(vec![0.05f32; n]);
        let tensors = tensors_of(n);
        // step 0: tensor 1 is dropped (interval 2) — residuals park
        exec.step(0, params.clone(), tensors.clone(), Policy::Overlap).unwrap();
        let layout: Vec<(usize, usize)> =
            tensors.iter().map(|t| (t.offset, t.numel)).collect();
        let states = exec.export_states(&layout, None);
        assert_eq!(states.len(), 2);
        let bits = |s: &Option<Vec<f32>>| {
            s.as_ref().map(|v| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>())
        };
        for s in &states {
            let flat = s.as_ref().expect("covap state is portable");
            assert_eq!(flat.len(), n);
            assert!(flat.iter().any(|x| *x != 0.0), "dropped tensor parked residuals");
        }
        // export is non-destructive: the donor fleet keeps stepping
        exec.step(1, params, tensors, Policy::Overlap).unwrap();

        // adopt the states in a fresh fleet; re-export must be bitwise
        let spec = SyntheticSpec::new(0xBEEF, 1);
        let models: Vec<Box<dyn RankModel>> = (0..2)
            .map(|_| Box::new(SyntheticModel::new(spec)) as Box<dyn RankModel>)
            .collect();
        let corpus = SyntheticCorpus::new(64);
        let shards: Vec<DataShard> =
            (0..2).map(|w| DataShard::new(corpus.clone(), seed, w, 2, 9)).collect();
        let cluster = ClusterSpec::new(2, 1);
        let sched =
            Arc::new(TopologyKind::Auto.resolve(cluster).allgather_schedule(cluster));
        let mut adopted = ThreadedExec::with_state(
            kind,
            seed,
            models,
            shards,
            sched,
            PacerSet::default(),
            RetryPolicy::default(),
            states.clone(),
            layout.clone(),
        );
        let re = adopted.export_states(&layout, None);
        for (r, (a, b)) in states.iter().zip(re.iter()).enumerate() {
            assert_eq!(bits(a), bits(b), "rank {r}: handoff must preserve bits");
        }
    }

    #[test]
    fn reconfigure_swaps_scheme() {
        let (mut exec, n) = setup(2, &SchemeKind::Baseline, 5);
        let params = Arc::new(vec![0.0f32; n]);
        let tensors = tensors_of(n);
        let dense = exec
            .step(0, params.clone(), tensors.clone(), Policy::Overlap)
            .unwrap();
        assert!(dense.records.iter().all(|r| r.wire_bytes > 0));
        exec.reconfigure(
            &SchemeKind::Covap {
                interval: 2,
                ef: crate::covap::EfScheduler::constant(1.0),
            },
            &[],
            &[],
        );
        let covap = exec.step(1, params, tensors, Policy::Overlap).unwrap();
        // with I=2 one of the two tensors is dropped at any step
        assert!(covap.records.iter().any(|r| r.wire_bytes == 0));
        let _ = synthetic::sgd_step(&covap.reduced, &covap.reduced, 0.0);
    }
}
