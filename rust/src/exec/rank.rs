//! The per-rank worker: one OS *compute* thread (data shard -> backward
//! pass -> per-tensor compression, wait-free) feeding one OS *comm* thread
//! (serialized-frame exchange over the configured topology's hop schedule
//! + decode-free combine into the dense update) through a FIFO bucket
//! queue — the executable form of the paper's Fig. 1b/1d two-stream
//! picture. The mesh moves encoded byte frames
//! (`RankCompressor::compress_into` writes them directly), so the
//! timeline's moved-bytes — now split per link level — and the records'
//! wire accounting are measurements of real serialized volume.
//!
//! Buffer lifecycle (DESIGN.md §7): the compute thread compresses into
//! frame buffers recycled from the comm thread (a return channel of spent
//! `Vec<u8>`s), the collective rotates frames through the comm thread's
//! persistent rank-major slots, and the combiner folds the slot bytes into
//! a persistent update buffer — so a steady-state step allocates nothing
//! on the compress→encode→collective path beyond the mpsc channel's
//! internal queue blocks.
//!
//! Under `Policy::Overlap` the compute thread enqueues each tensor the
//! moment its gradient+frame is ready, so communication of early tensors
//! genuinely overlaps computation of later ones; under `Policy::Sequential`
//! it holds everything back until the full backward pass finished (Fig.
//! 1a/1c). A scheme with `data_dependency` (Ok-topk) blocks the compute
//! thread on the tensor's combine completion — the measured form of the
//! simulator's dependency stall.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::comm::topology::{HopSchedule, LevelBytes};
use crate::compress::rank::{build_rank_pair, RankCombiner, RankCompressor, Scratch};
use crate::compress::{CommRecord, SchemeKind};
use crate::coordinator::CommTensor;
use crate::data::DataShard;
use crate::exec::barrier::Barrier;
use crate::exec::ring::{
    allgather_sched, broadcast_abort, GatherScratch, MeshLink, PacerSet, RetryPolicy,
};
use crate::exec::timeline::{RankTimeline, Span, SpanKind};
use crate::runtime::RankModel;
use crate::sim::Policy;

/// Commands from the engine to a rank's compute thread.
pub enum Cmd {
    Step(StepSpec),
    /// Swap / re-shard the compression scheme (adaptive interval). The old
    /// and new tensor layouts — `(flat offset, numel)` per slot — let a
    /// stateful compressor remap its EF residuals in place instead of
    /// dropping them; schemes that can't migrate are rebuilt.
    Reconfigure {
        kind: SchemeKind,
        old: Vec<(usize, usize)>,
        new: Vec<(usize, usize)>,
    },
    /// Replace the emulated per-level wire pacers (mid-run bandwidth
    /// change).
    SetPacer(PacerSet),
    /// Set this rank's synthetic compute inflation (straggler injection;
    /// never changes numerics).
    SetWork(u32),
    /// Kill this rank mid-run (failure injection): the compute thread
    /// stops at its next command, the comm thread broadcasts
    /// `Frame::Abort` so peers' collectives fail fast, and the engine is
    /// told via [`RankMsg::Failed`] — `step()` surfaces an error naming
    /// the rank instead of hanging the barrier.
    Fail { reason: String },
    /// Elastic membership: flatten this rank's EF residuals over `layout`
    /// and reply with [`RankMsg::State`]. Handled by the **compute**
    /// thread (the residuals' owner), so the export still works after a
    /// peer failure killed the comm fleet. Per-rank command FIFO ordering
    /// guarantees any in-flight [`Cmd::Reconfigure`] lands first, so the
    /// exported state can never be sliced by a stale shard layout — the
    /// `fail_rank`-during-reconfigure hazard is ordering, not locking.
    ExportState { layout: Vec<(usize, usize)> },
    Shutdown,
}

impl Cmd {
    /// The payload-free tag of this command — the shape the FIFO-ordering
    /// argument (and the protocol model checker) reasons over.
    pub fn tag(&self) -> CmdTag {
        match self {
            Cmd::Step(_) => CmdTag::Step,
            Cmd::Reconfigure { .. } => CmdTag::Reconfigure,
            Cmd::SetPacer(_) => CmdTag::SetPacer,
            Cmd::SetWork(_) => CmdTag::SetWork,
            Cmd::Fail { .. } => CmdTag::Fail,
            Cmd::ExportState { .. } => CmdTag::ExportState,
            Cmd::Shutdown => CmdTag::Shutdown,
        }
    }
}

/// Payload-free mirror of [`Cmd`], one variant per variant (kept in sync
/// by [`Cmd::tag`]'s exhaustive match). `analysis::model` builds rank
/// command queues out of these, so the checker explores exactly the
/// command vocabulary the real compute thread consumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmdTag {
    Step,
    Reconfigure,
    SetPacer,
    SetWork,
    Fail,
    ExportState,
    Shutdown,
}

/// The per-rank command queue's FIFO semantics as a pure function: given
/// the shard-layout generation a rank currently holds and its queued
/// commands in enqueue order, the generation the command at `idx`
/// observes when the compute thread processes the queue head-first. Only
/// [`Cmd::Reconfigure`] advances the layout, so
/// `observed(idx) = start + #Reconfigures strictly before idx` — this is
/// the whole "an export can never be sliced by a stale layout" argument
/// ([`Cmd::ExportState`]'s doc), stated executably. The engine enqueues
/// any `Reconfigure` *before* the `ExportState` it must cover; FIFO
/// delivery does the rest. Shared by `compute_main` reasoning, the loom
/// models (C/D) and the protocol checker's stale-layout invariant.
// xtask: hot-path
pub fn fifo_layout_gen_at(start: u8, queue: &[CmdTag], idx: usize) -> u8 {
    let mut gen = start;
    for tag in queue.iter().take(idx) {
        if matches!(tag, CmdTag::Reconfigure) {
            gen = gen.saturating_add(1);
        }
    }
    gen
}

/// One step's shared inputs (cheap to clone: Arcs + scalars).
#[derive(Clone)]
pub struct StepSpec {
    pub step: u64,
    pub params: Arc<Vec<f32>>,
    pub tensors: Arc<Vec<CommTensor>>,
    pub policy: Policy,
    /// Shared time origin for all ranks' spans.
    pub epoch: Instant,
}

/// What a rank's comm thread reports to the engine: a completed step, or
/// a failure. Worker threads never panic on mesh errors — a poisoned
/// panic would strand every peer blocked in `rx.recv()` and hang the
/// P-party barrier — so failures are logged through `obs::log` and
/// propagated here; the engine aborts the barrier and returns an error.
pub enum RankMsg {
    Step(RankStepResult),
    Failed { rank: usize, reason: String },
    /// Reply to [`Cmd::ExportState`]: this rank's EF residuals flattened
    /// over the requested layout (`None` = the scheme carries no portable
    /// state). Sent by the compute thread.
    State { rank: usize, residuals: Option<Vec<f32>> },
}

/// What a rank reports back after one step.
pub struct RankStepResult {
    pub rank: usize,
    pub loss: f32,
    /// Gradient-computation wall time only (the analytic engine's
    /// `comp_walls` analogue, feeding the simulator + profiler).
    pub comp_wall_s: f64,
    /// Per-tensor accounting records (identical across ranks).
    pub records: Vec<CommRecord>,
    /// FNV-1a over the reduced update's bit pattern — the engine checks
    /// every rank agrees (the bitwise-parity invariant, enforced live).
    pub checksum: u64,
    /// The dense reduced update; shipped by rank 0 only.
    pub reduced: Option<Vec<f32>>,
    pub timeline: RankTimeline,
}

/// Queue items from a rank's compute thread to its comm thread.
enum Work {
    Begin { step: u64, epoch: Instant, param_len: usize },
    Tensor {
        idx: usize,
        offset: usize,
        numel: usize,
        /// This rank's encoded wire frame (empty = nothing transmitted).
        /// The buffer returns to the compute thread via the recycle
        /// channel after the combine, so steady-state steps reuse a fixed
        /// pool instead of allocating per tensor.
        frame: Vec<u8>,
        compress_s: f64,
        dep: bool,
    },
    Finish { loss: f32, comp_wall_s: f64, spans: Vec<Span>, barrier_wait_s: f64 },
    Reconfig(SchemeKind),
    SetPacer(PacerSet),
    /// Injected failure (`Cmd::Fail`): abort peers, report, exit.
    Fail(String),
    Stop,
}

pub(crate) struct ComputeCtx {
    pub rank: usize,
    pub workers: usize,
    pub seed: u64,
    pub kind: SchemeKind,
    pub model: Box<dyn RankModel>,
    pub shard: DataShard,
    pub cmd_rx: Receiver<Cmd>,
    pub barrier: Arc<Barrier>,
    /// Reply channel for [`Cmd::ExportState`] (clone of the engine's
    /// result receiver's sender; the comm thread holds its own clone).
    pub res_tx: Sender<RankMsg>,
    /// Residuals to adopt at spawn (elastic re-world handoff): a flat
    /// vector in parameter space plus the slot layout to slice it by.
    pub init_state: Option<(Vec<f32>, Vec<(usize, usize)>)>,
}

pub(crate) struct CommCtx {
    pub rank: usize,
    pub workers: usize,
    pub seed: u64,
    pub kind: SchemeKind,
    pub link: MeshLink,
    /// The configured topology's allgather hop schedule (built once per
    /// executor; identical on every rank).
    pub sched: Arc<HopSchedule>,
    pub pacers: PacerSet,
    /// Bounded patience on mesh receives (default: fail fast).
    pub retry: RetryPolicy,
    pub res_tx: Sender<RankMsg>,
}

/// Spawn one rank: returns (work queue sender for internal use is hidden;
/// the engine talks via `Cmd`). Called by `ThreadedExec`. Spawn failures
/// propagate as `Err` — raised on the engine thread, never inside a
/// worker; if the compute thread fails to spawn, its dropped `work_tx`
/// makes the already-running comm thread abort its peers and exit.
pub(crate) fn spawn_rank(
    compute: ComputeCtx,
    comm: CommCtx,
) -> std::io::Result<(std::thread::JoinHandle<()>, std::thread::JoinHandle<()>)> {
    let (work_tx, work_rx) = std::sync::mpsc::channel::<Work>();
    let (dep_tx, dep_rx) = std::sync::mpsc::channel::<usize>();
    // spent frame buffers flow back compute-ward for reuse
    let (recycle_tx, recycle_rx) = std::sync::mpsc::channel::<Vec<u8>>();
    let ch = std::thread::Builder::new()
        .name(format!("covap-comm-{}", comm.rank))
        .spawn(move || comm_main(comm, work_rx, dep_tx, recycle_tx))?;
    let th = std::thread::Builder::new()
        .name(format!("covap-rank-{}", compute.rank))
        .spawn(move || compute_main(compute, work_tx, dep_rx, recycle_rx))?;
    Ok((th, ch))
}

fn compute_main(
    mut ctx: ComputeCtx,
    work_tx: Sender<Work>,
    dep_rx: Receiver<usize>,
    recycle_rx: Receiver<Vec<u8>>,
) {
    let (mut compressor, _) = build_rank_pair(&ctx.kind, ctx.workers, ctx.seed);
    if let Some((flat, layout)) = ctx.init_state.take() {
        // elastic re-world handoff: adopt the redistributed residuals
        // before the first step; stateless schemes simply ignore them
        compressor.import_residuals(&flat, &layout);
    }
    let mut gbuf: Vec<f32> = Vec::new();
    let mut scratch = Scratch::new();
    while let Ok(cmd) = ctx.cmd_rx.recv() {
        match cmd {
            Cmd::Shutdown => {
                let _ = work_tx.send(Work::Stop);
                return;
            }
            Cmd::Reconfigure { kind, old, new } => {
                // stateful schemes (COVAP) migrate in place, remapping EF
                // residuals into the new shard layout; everything else
                // rebuilds (state reset — the pre-remap semantics)
                if !compressor.reconfigure(&kind, &old, &new) {
                    let (c, _) = build_rank_pair(&kind, ctx.workers, ctx.seed);
                    compressor = c;
                }
                ctx.kind = kind.clone();
                let _ = work_tx.send(Work::Reconfig(kind));
            }
            Cmd::SetPacer(p) => {
                let _ = work_tx.send(Work::SetPacer(p));
            }
            Cmd::SetWork(w) => ctx.model.set_work(w),
            Cmd::ExportState { layout } => {
                let residuals = compressor.export_residuals(&layout);
                if ctx
                    .res_tx
                    .send(RankMsg::State { rank: ctx.rank, residuals })
                    .is_err()
                {
                    return; // engine gone
                }
            }
            Cmd::Fail { reason } => {
                crate::log_error!(
                    target: "exec",
                    "rank {}: injected failure: {reason}",
                    ctx.rank
                );
                // the comm thread aborts peers and reports to the engine
                let _ = work_tx.send(Work::Fail(reason));
                return;
            }
            Cmd::Step(spec) => {
                let ok = run_step(
                    &mut ctx,
                    &mut *compressor,
                    &mut gbuf,
                    &mut scratch,
                    &spec,
                    &work_tx,
                    &dep_rx,
                    &recycle_rx,
                );
                if !ok {
                    // comm thread gone (it already aborted peers and told
                    // the engine) — nothing left to serve
                    crate::log_error!(
                        target: "exec",
                        "rank {}: comm thread gone mid-step; stopping compute",
                        ctx.rank
                    );
                    return;
                }
            }
        }
    }
    // engine dropped: stop the comm thread too
    let _ = work_tx.send(Work::Stop);
}

/// Returns `false` when the comm thread is gone — the caller must stop
/// serving commands (the comm side already aborted peers and reported the
/// failure; panicking here would only add a second corpse).
#[allow(clippy::too_many_arguments)]
fn run_step(
    ctx: &mut ComputeCtx,
    compressor: &mut dyn RankCompressor,
    gbuf: &mut Vec<f32>,
    scratch: &mut Scratch,
    spec: &StepSpec,
    work_tx: &Sender<Work>,
    dep_rx: &Receiver<usize>,
    recycle_rx: &Receiver<Vec<u8>>,
) -> bool {
    let n = spec.params.len();
    gbuf.clear();
    gbuf.resize(n, 0.0);
    let barrier_wait = ctx.barrier.wait().as_secs_f64();
    if ctx.barrier.is_aborted() {
        // A peer failed and the engine poisoned the rendezvous: skip the
        // step entirely — no shard advance, no gradient, no EF accumulate
        // — so every survivor's residual state stays bitwise uniform, and
        // stay alive to serve the membership controller's `ExportState`.
        // (Before this check, released survivors marched into the dead
        // mesh, hit the broken work channel, and exited — taking their
        // residuals with them.)
        crate::log_warn!(
            target: "exec",
            "rank {}: barrier aborted — skipping step {} and awaiting membership decision",
            ctx.rank,
            spec.step
        );
        return true;
    }
    if work_tx
        .send(Work::Begin { step: spec.step, epoch: spec.epoch, param_len: n })
        .is_err()
    {
        return false;
    }

    let batch = ctx.shard.next_batch();
    ctx.model.begin_step(&batch);

    let mut spans: Vec<Span> = Vec::with_capacity(spec.tensors.len() * 2);
    let mut comp_wall = 0.0f64;
    let mut pending: Vec<Work> = Vec::new();
    let overlap = spec.policy == Policy::Overlap;

    for (idx, t) in spec.tensors.iter().enumerate() {
        let t0 = spec.epoch.elapsed().as_secs_f64();
        ctx.model.grad_range(&spec.params, t.offset, &mut gbuf[t.offset..t.offset + t.numel]);
        let t1 = spec.epoch.elapsed().as_secs_f64();
        // a spent buffer from the comm thread if one is ready (steady
        // state), a fresh empty Vec only during warmup
        let mut frame = recycle_rx.try_recv().unwrap_or_default();
        compressor.compress_into(
            idx,
            spec.step,
            &gbuf[t.offset..t.offset + t.numel],
            scratch,
            &mut frame,
        );
        let t2 = spec.epoch.elapsed().as_secs_f64();
        comp_wall += t1 - t0;
        spans.push(Span { kind: SpanKind::Compute, tensor: idx, start_s: t0, end_s: t1 });
        spans.push(Span { kind: SpanKind::Compress, tensor: idx, start_s: t1, end_s: t2 });

        let dep = compressor.data_dependency() && overlap;
        let item = Work::Tensor {
            idx,
            offset: t.offset,
            numel: t.numel,
            frame,
            compress_s: t2 - t1,
            dep,
        };
        if overlap {
            if work_tx.send(item).is_err() {
                return false;
            }
            if dep {
                // synchronous collective: stall the backward pass until the
                // comm thread confirms this tensor completed.
                let Ok(done) = dep_rx.recv() else {
                    return false;
                };
                debug_assert_eq!(done, idx);
                let t3 = spec.epoch.elapsed().as_secs_f64();
                spans.push(Span {
                    kind: SpanKind::Compute,
                    tensor: idx,
                    start_s: t3,
                    end_s: t3,
                });
            }
        } else {
            pending.push(item);
        }
    }
    let loss = ctx.model.end_step(n);
    // Sequential: communication starts only now (Fig. 1a/1c).
    for item in pending {
        if work_tx.send(item).is_err() {
            return false;
        }
    }
    work_tx
        .send(Work::Finish { loss, comp_wall_s: comp_wall, spans, barrier_wait_s: barrier_wait })
        .is_ok()
}

fn comm_main(
    mut ctx: CommCtx,
    work_rx: Receiver<Work>,
    dep_tx: Sender<usize>,
    recycle_tx: Sender<Vec<u8>>,
) {
    let (_, mut combiner) = build_rank_pair(&ctx.kind, ctx.workers, ctx.seed);
    // persistent hot-path buffers (capacities grow to the largest tensor,
    // then every later step reuses them)
    let mut slots: Vec<Vec<u8>> = (0..ctx.workers).map(|_| Vec::new()).collect();
    let mut gather = GatherScratch::new();
    let mut scratch = Scratch::new();
    let mut update: Vec<f32> = Vec::new();
    // per-step state
    let mut step = 0u64;
    let mut epoch = Instant::now();
    let mut reduced: Vec<f32> = Vec::new();
    let mut records: Vec<CommRecord> = Vec::new();
    let mut comm_spans: Vec<Span> = Vec::new();
    let mut moved = 0usize;
    let mut moved_levels = LevelBytes::default();

    while let Ok(work) = work_rx.recv() {
        match work {
            Work::Stop => return,
            Work::Fail(reason) => {
                // Injected or propagated failure: unblock every peer stuck in
                // a recv on our link, tell the engine which rank died and why,
                // then exit. Peers' collectives surface `PeerAborted` and walk
                // the same path.
                broadcast_abort(ctx.rank, &ctx.link);
                let _ = ctx.res_tx.send(RankMsg::Failed { rank: ctx.rank, reason });
                return;
            }
            Work::Reconfig(kind) => {
                let (_, cb) = build_rank_pair(&kind, ctx.workers, ctx.seed);
                combiner = cb;
                ctx.kind = kind;
            }
            Work::SetPacer(p) => ctx.pacers = p,
            Work::Begin { step: s, epoch: e, param_len } => {
                step = s;
                epoch = e;
                reduced.clear();
                reduced.resize(param_len, 0.0);
                records.clear();
                comm_spans.clear();
                moved = 0;
                moved_levels = LevelBytes::default();
            }
            Work::Tensor { idx, offset, numel, frame, compress_s, dep } => {
                let c0 = epoch.elapsed().as_secs_f64();
                let lb = match allgather_sched(
                    ctx.rank,
                    &ctx.sched,
                    &frame,
                    &mut slots,
                    &mut gather,
                    &ctx.link,
                    &ctx.pacers,
                    &ctx.retry,
                ) {
                    Ok(lb) => lb,
                    Err(e) => {
                        crate::log_error!(
                            target: "exec",
                            "rank {}: collective failed on tensor {idx}: {e}",
                            ctx.rank
                        );
                        broadcast_abort(ctx.rank, &ctx.link);
                        let _ = ctx
                            .res_tx
                            .send(RankMsg::Failed { rank: ctx.rank, reason: e.to_string() });
                        return;
                    }
                };
                let record = combiner.combine_into(
                    idx,
                    step,
                    numel,
                    &slots,
                    &mut scratch,
                    &mut update,
                    compress_s,
                );
                if !update.is_empty() {
                    reduced[offset..offset + numel].copy_from_slice(&update);
                }
                records.push(record);
                moved += lb.total();
                moved_levels.intra += lb.intra;
                moved_levels.inter += lb.inter;
                // the spent frame buffer flows back for reuse (receiver
                // may be gone during shutdown — then it just drops)
                let _ = recycle_tx.send(frame);
                let c1 = epoch.elapsed().as_secs_f64();
                comm_spans.push(Span {
                    kind: SpanKind::Comm,
                    tensor: idx,
                    start_s: c0,
                    end_s: c1,
                });
                if dep {
                    let _ = dep_tx.send(idx);
                }
            }
            Work::Finish { loss, comp_wall_s, spans, barrier_wait_s } => {
                let mut all_spans = spans;
                all_spans.extend(comm_spans.iter().copied());
                let timeline = RankTimeline {
                    rank: ctx.rank,
                    spans: all_spans,
                    moved_bytes: moved,
                    moved_levels,
                    barrier_wait_s,
                };
                let checksum = fnv1a_f32(&reduced);
                let result = RankStepResult {
                    rank: ctx.rank,
                    loss,
                    comp_wall_s,
                    records: std::mem::take(&mut records),
                    checksum,
                    reduced: if ctx.rank == 0 {
                        Some(std::mem::take(&mut reduced))
                    } else {
                        None
                    },
                    timeline,
                };
                if ctx.res_tx.send(RankMsg::Step(result)).is_err() {
                    return; // engine gone
                }
            }
        }
    }
    // Abnormal exit: the compute thread dropped `work_tx` without sending
    // `Stop` (it panicked or bailed). Release peers and report, instead of
    // leaving the mesh deadlocked on a rank that will never send again.
    crate::log_error!(target: "exec", "rank {}: compute thread vanished", ctx.rank);
    broadcast_abort(ctx.rank, &ctx.link);
    let _ = ctx.res_tx.send(RankMsg::Failed {
        rank: ctx.rank,
        reason: "compute thread exited without Stop".into(),
    });
}

/// FNV-1a over the f32 bit patterns — cheap bitwise fingerprint.
pub fn fnv1a_f32(xs: &[f32]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_distinguishes_bit_patterns() {
        assert_ne!(fnv1a_f32(&[0.0]), fnv1a_f32(&[-0.0]), "must see sign bits");
        assert_eq!(fnv1a_f32(&[1.0, 2.0]), fnv1a_f32(&[1.0, 2.0]));
        assert_ne!(fnv1a_f32(&[1.0, 2.0]), fnv1a_f32(&[2.0, 1.0]));
    }

    #[test]
    fn fifo_ordering_semantics_are_positional() {
        use CmdTag::*;
        // reconfigure-before-export: the export observes the NEW layout
        let q = [Reconfigure, ExportState];
        assert_eq!(fifo_layout_gen_at(0, &q, 0), 0, "the reconfigure itself runs on the old");
        assert_eq!(fifo_layout_gen_at(0, &q, 1), 1, "the export observes the new layout");
        // export-before-reconfigure would observe the stale one
        let q = [ExportState, Reconfigure];
        assert_eq!(fifo_layout_gen_at(3, &q, 0), 3);
        // non-reconfigure traffic never perturbs the layout
        let q = [Step, SetPacer, SetWork, Fail, Shutdown, ExportState];
        assert_eq!(fifo_layout_gen_at(7, &q, 5), 7);
        // multiple reconfigures accumulate in order
        let q = [Reconfigure, Step, Reconfigure, ExportState];
        assert_eq!(fifo_layout_gen_at(0, &q, 3), 2);
    }

    #[test]
    fn cmd_tags_mirror_every_variant() {
        assert_eq!(Cmd::Shutdown.tag(), CmdTag::Shutdown);
        assert_eq!(Cmd::SetWork(1).tag(), CmdTag::SetWork);
        assert_eq!(Cmd::Fail { reason: String::new() }.tag(), CmdTag::Fail);
        assert_eq!(Cmd::ExportState { layout: vec![] }.tag(), CmdTag::ExportState);
        assert_eq!(
            Cmd::Reconfigure { kind: SchemeKind::Baseline, old: vec![], new: vec![] }.tag(),
            CmdTag::Reconfigure
        );
    }
}
