//! Threaded ring collectives over per-edge FIFO channels.
//!
//! Each directed ring edge `r -> (r+1) % P` is one mpsc channel; a rank's
//! [`RingLink`] bundles its outgoing sender and incoming receiver. The
//! dense allreduce follows [`crate::comm::RingSchedule`] chunk-for-chunk —
//! the same schedule the in-place [`crate::comm::ring_allreduce`] walks —
//! so the two are **bitwise identical** (property-tested below): per chunk
//! the sum is the same sequential chain, only executed by P real threads.
//!
//! [`allgather_payloads`] is the compressed-payload rotation: every rank
//! **serializes** its payload with [`Payload::encode`] and the ring moves
//! the raw byte frames — what a real transport would see — decoding the
//! gathered rank-major set only at the end. Hop pacing and the `sent`
//! accounting both use the measured `frame.len()`, so the bytes charged are
//! the bytes a rank actually put on the wire, not a size model. [`Pacer`]
//! optionally throttles every hop to a modeled wire bandwidth + latency so
//! measured timelines can emulate a slow fabric on a fast testbed.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use crate::comm::RingSchedule;
use crate::compress::Payload;

/// One frame on a ring edge.
pub enum Frame {
    /// A chunk of a dense f32 collective.
    Chunk(Vec<f32>),
    /// A serialized compressed-payload frame ([`Payload::encode`]).
    Bytes(Vec<u8>),
}

/// One rank's pair of ring-edge endpoints.
pub struct RingLink {
    /// To rank (r + 1) % P.
    pub tx: Sender<Frame>,
    /// From rank (r - 1 + P) % P.
    pub rx: Receiver<Frame>,
}

/// Build the P directed edges; element r is rank r's link.
pub fn make_links(p: usize) -> Vec<RingLink> {
    assert!(p >= 1);
    let mut txs = Vec::with_capacity(p);
    let mut rxs = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel::<Frame>();
        txs.push(tx);
        rxs.push(rx);
    }
    // rank r sends on edge r (into r+1) and receives on edge r-1.
    rxs.rotate_right(1);
    txs.into_iter()
        .zip(rxs)
        .map(|(tx, rx)| RingLink { tx, rx })
        .collect()
}

/// Emulated wire pacing: every hop of `bytes` costs
/// `bytes / bytes_per_s + latency_s` of sleep on the sending side.
#[derive(Debug, Clone, Copy)]
pub struct Pacer {
    pub bytes_per_s: f64,
    pub latency_s: f64,
}

impl Pacer {
    /// Derive from a NIC line rate (Gbit/s) at the given efficiency.
    pub fn from_gbps(gbps: f64, efficiency: f64, latency_s: f64) -> Pacer {
        Pacer { bytes_per_s: (gbps * 1e9 / 8.0 * efficiency).max(1.0), latency_s }
    }

    pub fn pace(&self, bytes: usize) {
        let s = bytes as f64 / self.bytes_per_s + self.latency_s;
        if s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(s));
        }
    }
}

fn recv_chunk(link: &RingLink) -> Vec<f32> {
    match link.rx.recv() {
        Ok(Frame::Chunk(v)) => v,
        Ok(Frame::Bytes(_)) => panic!("protocol error: expected Chunk, got Bytes"),
        Err(_) => panic!("ring peer disconnected mid-collective"),
    }
}

fn recv_bytes(link: &RingLink) -> Vec<u8> {
    match link.rx.recv() {
        Ok(Frame::Bytes(b)) => b,
        Ok(Frame::Chunk(_)) => panic!("protocol error: expected Bytes, got Chunk"),
        Err(_) => panic!("ring peer disconnected mid-collective"),
    }
}

/// Chunked ring AllReduce (sum), threaded: call from every rank's comm
/// thread with its own buffer. Returns the bytes this rank sent.
///
/// Bitwise-identical to [`crate::comm::ring_allreduce`]: same
/// [`RingSchedule`], same `own += incoming` accumulation order per chunk.
pub fn ring_allreduce_threaded(
    rank: usize,
    world: usize,
    buf: &mut [f32],
    link: &RingLink,
    pacer: Option<&Pacer>,
) -> usize {
    let n = buf.len();
    if world <= 1 || n == 0 {
        return 0;
    }
    let sched = RingSchedule::new(world, n);
    let prev = (rank + world - 1) % world;
    let mut sent = 0usize;

    // Reduce-scatter.
    for s in 0..world - 1 {
        let c_out = sched.rs_chunk(rank, s);
        let out: Vec<f32> = buf[sched.chunk(c_out)].to_vec();
        let bytes = out.len() * 4;
        if let Some(p) = pacer {
            p.pace(bytes);
        }
        sent += bytes;
        link.tx.send(Frame::Chunk(out)).expect("ring send");
        let inc = recv_chunk(link);
        let c_in = sched.rs_chunk(prev, s);
        let range = sched.chunk(c_in);
        debug_assert_eq!(inc.len(), range.len());
        for (d, sv) in buf[range].iter_mut().zip(inc.iter()) {
            *d += sv;
        }
    }
    // Allgather.
    for s in 0..world - 1 {
        let c_out = sched.ag_chunk(rank, s);
        let out: Vec<f32> = buf[sched.chunk(c_out)].to_vec();
        let bytes = out.len() * 4;
        if let Some(p) = pacer {
            p.pace(bytes);
        }
        sent += bytes;
        link.tx.send(Frame::Chunk(out)).expect("ring send");
        let inc = recv_chunk(link);
        let c_in = sched.ag_chunk(prev, s);
        let range = sched.chunk(c_in);
        debug_assert_eq!(inc.len(), range.len());
        buf[range].copy_from_slice(&inc);
    }
    sent
}

/// Serialized ring AllGather: every rank contributes one payload, encoded
/// to its byte frame, and receives the rank-major vector of all payloads
/// after P-1 rotation hops of raw frames. Returns (payloads rank-major,
/// frame bytes this rank sent — the measured wire traffic).
pub fn allgather_payloads(
    rank: usize,
    world: usize,
    mine: Payload,
    link: &RingLink,
    pacer: Option<&Pacer>,
) -> (Vec<Payload>, usize) {
    if world <= 1 {
        return (vec![mine], 0);
    }
    let mut frames: Vec<Option<Vec<u8>>> = (0..world).map(|_| None).collect();
    frames[rank] = Some(mine.encode());
    let mut own = Some(mine);
    let prev = (rank + world - 1) % world;
    let mut sent = 0usize;
    for s in 0..world - 1 {
        let c_out = (rank + world - s) % world;
        let out = frames[c_out].clone().expect("rotation invariant");
        let bytes = out.len();
        if let Some(p) = pacer {
            p.pace(bytes);
        }
        sent += bytes;
        link.tx.send(Frame::Bytes(out)).expect("ring send");
        let inc = recv_bytes(link);
        let c_in = (prev + world - s) % world;
        debug_assert!(frames[c_in].is_none() || c_in == rank);
        frames[c_in] = Some(inc);
    }
    let mut gathered = Vec::with_capacity(world);
    for (i, f) in frames.into_iter().enumerate() {
        let frame = f.expect("all frames arrive after P-1 hops");
        if i == rank {
            // this rank's own payload needs no decode round-trip (the
            // codec's exactness is property-tested; peers decoded it)
            gathered.push(own.take().expect("own payload"));
        } else {
            gathered.push(Payload::decode(&frame).expect("corrupt ring frame"));
        }
    }
    (gathered, sent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ring_allreduce;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Run the threaded allreduce across P scoped threads.
    fn run_threaded(bufs: &[Vec<f32>]) -> (Vec<Vec<f32>>, Vec<usize>) {
        let p = bufs.len();
        let links = make_links(p);
        std::thread::scope(|s| {
            let handles: Vec<_> = links
                .into_iter()
                .enumerate()
                .map(|(r, link)| {
                    let mut buf = bufs[r].clone();
                    s.spawn(move || {
                        let sent = ring_allreduce_threaded(r, p, &mut buf, &link, None);
                        (buf, sent)
                    })
                })
                .collect();
            let mut out = Vec::with_capacity(p);
            let mut sent = Vec::with_capacity(p);
            for h in handles {
                let (b, s) = h.join().expect("rank thread");
                out.push(b);
                sent.push(s);
            }
            (out, sent)
        })
    }

    /// The cross-validation the issue pins down: the threaded ring must be
    /// bitwise identical to the in-place simulator ring — uneven splits,
    /// n < p, p = 1 and empty buffers included.
    #[test]
    fn threaded_ring_bitwise_matches_inplace() {
        prop::check("exec-ring==comm-ring", 0x51D, 40, |rng: &mut Rng| {
            let p = 1 + rng.below(6);
            let n = rng.below(201); // 0, < p, uneven all covered
            let bufs: Vec<Vec<f32>> =
                (0..p).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
            let mut want = bufs.clone();
            ring_allreduce(&mut want);
            let (got, _) = run_threaded(&bufs);
            for r in 0..p {
                assert_eq!(
                    got[r], want[r],
                    "rank {r} diverged from in-place ring (p={p}, n={n})"
                );
            }
        });
    }

    #[test]
    fn threaded_ring_degenerate_cases() {
        for (p, n) in [(1usize, 0usize), (1, 7), (2, 0), (3, 1), (4, 3), (5, 17)] {
            let mut rng = Rng::seed((p * 100 + n) as u64);
            let bufs: Vec<Vec<f32>> =
                (0..p).map(|_| prop::vec_f32(&mut rng, n, 1.0)).collect();
            let mut want = bufs.clone();
            ring_allreduce(&mut want);
            let (got, _) = run_threaded(&bufs);
            assert_eq!(got, want, "p={p} n={n}");
        }
    }

    #[test]
    fn threaded_traffic_matches_schedule() {
        let p = 4;
        let n = 1000;
        let bufs: Vec<Vec<f32>> = (0..p).map(|_| vec![1.0f32; n]).collect();
        let (_, sent) = run_threaded(&bufs);
        let sched = crate::comm::RingSchedule::new(p, n);
        for r in 0..p {
            assert_eq!(sent[r], sched.allreduce_sent_bytes(r), "rank {r}");
        }
    }

    /// Run a payload allgather across P scoped threads; returns the
    /// rank-major gathered payloads and per-rank sent bytes.
    fn run_allgather(payloads: Vec<Payload>) -> (Vec<Vec<Payload>>, Vec<usize>) {
        let p = payloads.len();
        let links = make_links(p);
        std::thread::scope(|s| {
            let handles: Vec<_> = links
                .into_iter()
                .zip(payloads)
                .enumerate()
                .map(|(r, (link, mine))| {
                    s.spawn(move || allgather_payloads(r, p, mine, &link, None))
                })
                .collect();
            let mut out = Vec::with_capacity(p);
            let mut sent = Vec::with_capacity(p);
            for h in handles {
                let (g, s) = h.join().expect("rank thread");
                out.push(g);
                sent.push(s);
            }
            (out, sent)
        })
    }

    #[test]
    fn payload_allgather_is_rank_major() {
        let p = 4;
        let payloads: Vec<Payload> =
            (0..p).map(|r| Payload::Dense(vec![r as f32; 3])).collect();
        let (gathered, _) = run_allgather(payloads);
        for row in &gathered {
            assert_eq!(row.len(), p);
            for (c, pay) in row.iter().enumerate() {
                let Payload::Dense(v) = pay else { panic!("wrong variant") };
                assert_eq!(v, &vec![c as f32; 3], "slot {c}");
            }
        }
    }

    /// Frames survive the wire bitwise for every variant, and the measured
    /// sent bytes are exactly (P-1) hops of encoded frame lengths.
    #[test]
    fn payload_allgather_moves_encoded_frames() {
        let payloads = vec![
            Payload::Dense(vec![1.0, -0.0, f32::NAN]),
            Payload::Empty,
            Payload::Sparse { idx: vec![3, 9], val: vec![0.5, -0.25] },
            Payload::Sign { scale: 0.75, bits: vec![0b1011], n: 5 },
        ];
        let p = payloads.len();
        let (gathered, sent) = run_allgather(payloads.clone());
        for row in &gathered {
            for (want, got) in payloads.iter().zip(row.iter()) {
                assert_eq!(got, want, "payload must survive the wire bitwise");
            }
        }
        // rank r forwards every frame except its successor's: total sent =
        // sum of all frames' encoded lengths minus the one it never sends.
        let lens: Vec<usize> = payloads.iter().map(|p| p.encoded_len()).collect();
        let total: usize = lens.iter().sum();
        for (r, &s) in sent.iter().enumerate() {
            let skipped = lens[(r + 1) % p];
            assert_eq!(s, total - skipped, "rank {r} sent bytes");
        }
    }

    #[test]
    fn single_rank_allgather_is_identity() {
        let (got, sent) =
            allgather_payloads(0, 1, Payload::Dense(vec![1.0, 2.0]), &make_links(1).remove(0), None);
        assert_eq!(got.len(), 1);
        assert_eq!(sent, 0);
    }

    #[test]
    fn pacer_slows_hops() {
        use std::time::Instant;
        let pacer = Pacer { bytes_per_s: 1e6, latency_s: 0.0 };
        let t0 = Instant::now();
        pacer.pace(50_000); // 50 ms at 1 MB/s
        assert!(t0.elapsed() >= Duration::from_millis(40));
    }
}
