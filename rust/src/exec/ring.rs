//! Threaded collectives over a per-rank channel mesh, driven by the
//! topology layer's hop schedules.
//!
//! Every rank owns one [`MeshLink`]: a sender to every other rank and a
//! single inbound queue. [`allgather_sched`] executes a
//! [`crate::comm::topology::HopSchedule`] — flat ring, hierarchical
//! 2-level, or binomial tree; the executor neither knows nor cares which —
//! moving this rank's encoded wire frames into the caller's **persistent
//! slot buffers** (rank-major). The schedule contract (each rank receives
//! each slot exactly once, sources hold what they forward, dependencies
//! point to strictly earlier rounds) makes execution deadlock-free with
//! unbounded channels: a rank sends everything it can, blocks only for
//! frames whose producing hop is strictly earlier, and stores arrivals by
//! their slot tag regardless of arrival order.
//!
//! Buffer discipline is allocation-free in steady state, extending the
//! DESIGN.md §7 rotation contract to arbitrary topologies: each send
//! copies the outgoing slot into a spare buffer popped from a per-thread
//! pool (the one unavoidable copy — the slot must be retained while its
//! bytes ship), ships the spare's allocation through the channel, and
//! each receive adopts the incoming frame's allocation as the slot,
//! pushing the displaced buffer back into the pool — so `Vec` capacities
//! circulate through the mesh and, once every buffer has grown to the
//! largest frame seen, no hop allocates. Because mesh receivers see all
//! senders, a fast peer may race one collective ahead; frames carry an
//! epoch tag and early arrivals park in the scratch's pending queue (a
//! peer can never be **two** collectives ahead — completing a collective
//! requires a frame originating at every other rank).
//!
//! Per-hop pacing is **per level**: a [`PacerSet`] throttles intra-node
//! hops at the modeled PCIe rate and inter-node hops at the emulated NIC
//! rate, so measured timelines reproduce a hierarchical fabric's regime
//! on a flat testbed. Sent-byte accounting is per level too
//! ([`LevelBytes`]) and uses measured frame lengths, not a size model.
//!
//! The dense [`ring_allreduce_threaded`] still follows
//! [`crate::comm::RingSchedule`] chunk-for-chunk — bitwise-identical to
//! the in-place [`crate::comm::ring_allreduce`] (property-tested below).
//! [`allgather_frames`]/[`allgather_payloads`] are the flat-ring oracle
//! wrappers retained for tests and one-shot callers.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::comm::topology::{Collective, HopSchedule, LevelBytes, LinkLevel, RING};
use crate::comm::RingSchedule;
use crate::compress::Payload;
use crate::network::{ClusterSpec, NetworkModel};

/// One frame on a mesh edge.
pub enum Frame {
    /// A chunk of a dense f32 collective (single-sender ring order).
    Chunk(Vec<f32>),
    /// A serialized compressed-payload frame ([`Payload::encode_into`]):
    /// the collective's sequence number, the global slot id whose bytes
    /// these are, and the bytes themselves.
    Slot { epoch: u64, slot: u32, data: Vec<u8> },
    /// A failing rank's last word ([`broadcast_abort`]): unblocks every
    /// peer's `rx.recv()` so collectives fail fast with
    /// [`MeshError::PeerAborted`] instead of waiting forever for frames
    /// that will never come.
    Abort { from: u32 },
}

/// A collective failure observed by a worker thread. Workers *return*
/// this — they must never panic: a panicking comm thread strands every
/// peer blocked in `rx.recv()` and deadlocks the mesh, so failures are
/// logged through `obs::log` and propagated to the engine
/// (`exec::RankMsg::Failed`), which aborts the barrier and surfaces an
/// error from `step()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeshError {
    /// A peer's channel endpoint closed mid-collective.
    PeerDisconnected { rank: usize },
    /// A frame of the wrong protocol variant arrived.
    Protocol { rank: usize, expected: &'static str },
    /// A peer broadcast [`Frame::Abort`] after failing.
    PeerAborted { rank: usize, from: u32 },
    /// A slot frame arrived from an epoch the parking contract forbids —
    /// peers can race at most one collective ahead (the skew ≤ 1 bound
    /// proven statically by `analysis::verify_schedule`).
    EpochSkew { rank: usize, got: u64, current: u64 },
    /// A gathered frame failed to decode (oracle wrappers only).
    Corrupt { rank: usize, slot: usize },
    /// No frame arrived within the configured [`RetryPolicy`]'s bounded
    /// retry-with-backoff budget — the peer is declared failed by timer
    /// rather than by an explicit [`Frame::Abort`].
    Timeout { rank: usize, attempts: u32 },
}

impl std::fmt::Display for MeshError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MeshError::PeerDisconnected { rank } => {
                write!(f, "rank {rank}: mesh peer disconnected mid-collective")
            }
            MeshError::Protocol { rank, expected } => {
                write!(f, "rank {rank}: protocol error — expected {expected} frame")
            }
            MeshError::PeerAborted { rank, from } => {
                write!(f, "rank {rank}: peer rank {from} aborted the collective")
            }
            MeshError::EpochSkew { rank, got, current } => write!(
                f,
                "rank {rank}: frame from epoch {got} while in epoch {current} \
                 (peers may race at most one collective ahead)"
            ),
            MeshError::Corrupt { rank, slot } => {
                write!(f, "rank {rank}: gathered frame for slot {slot} failed to decode")
            }
            MeshError::Timeout { rank, attempts } => write!(
                f,
                "rank {rank}: mesh receive timed out after {attempts} bounded attempt(s)"
            ),
        }
    }
}

impl std::error::Error for MeshError {}

/// Broadcast [`Frame::Abort`] from `rank` to every peer. Called by a
/// failing rank's comm thread before it exits so no peer blocks forever
/// on its silence; send failures are ignored (a peer already gone needs
/// no unblocking).
pub fn broadcast_abort(rank: usize, link: &MeshLink) {
    for (d, tx) in link.txs.iter().enumerate() {
        if d != rank {
            let _ = tx.send(Frame::Abort { from: rank as u32 });
        }
    }
}

/// One rank's endpoints: a sender to every rank plus its inbound queue.
pub struct MeshLink {
    /// `txs[d]` sends to rank `d` (the self entry is unused).
    pub txs: Vec<Sender<Frame>>,
    /// All peers' frames arrive here, slot-tagged.
    pub rx: Receiver<Frame>,
}

/// Build the full mesh; element `r` is rank `r`'s link.
pub fn make_mesh(p: usize) -> Vec<MeshLink> {
    assert!(p >= 1);
    let (txs, rxs): (Vec<Sender<Frame>>, Vec<Receiver<Frame>>) =
        (0..p).map(|_| channel::<Frame>()).unzip();
    rxs.into_iter().map(|rx| MeshLink { txs: txs.clone(), rx }).collect()
}

/// Emulated wire pacing: every hop of `bytes` costs
/// `bytes / bytes_per_s + latency_s` of sleep on the sending side.
#[derive(Debug, Clone, Copy)]
pub struct Pacer {
    pub bytes_per_s: f64,
    pub latency_s: f64,
}

impl Pacer {
    /// Derive from a NIC line rate (Gbit/s) at the given efficiency.
    pub fn from_gbps(gbps: f64, efficiency: f64, latency_s: f64) -> Pacer {
        Pacer { bytes_per_s: (gbps * 1e9 / 8.0 * efficiency).max(1.0), latency_s }
    }

    pub fn pace(&self, bytes: usize) {
        let s = bytes as f64 / self.bytes_per_s + self.latency_s;
        if s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(s));
        }
    }
}

/// Per-link-level pacers: intra-node hops and inter-node hops emulate
/// different fabrics (`None` = move bytes at memcpy speed).
#[derive(Debug, Clone, Copy, Default)]
pub struct PacerSet {
    pub intra: Option<Pacer>,
    pub inter: Option<Pacer>,
}

impl PacerSet {
    /// Pace both levels identically (the pre-topology single-wire knob).
    pub fn uniform(p: Option<Pacer>) -> PacerSet {
        PacerSet { intra: p, inter: p }
    }

    /// Emulate a fabric whose inter-node wire runs at `gbps` Gbit/s:
    /// intra-node hops run faster by the network model's intra/inter
    /// effective-bandwidth ratio, so the emulated hierarchy matches the
    /// modeled one. `gbps <= 0` disables pacing entirely.
    pub fn from_net(gbps: f64, net: &NetworkModel) -> PacerSet {
        if gbps <= 0.0 {
            return PacerSet::default();
        }
        let inter = Pacer::from_gbps(gbps, 1.0, net.latency_s);
        let intra = Pacer {
            bytes_per_s: (inter.bytes_per_s * net.intra_bps() / net.effective_bps()).max(1.0),
            latency_s: NetworkModel::INTRA_LATENCY_S,
        };
        PacerSet { intra: Some(intra), inter: Some(inter) }
    }

    pub fn level(&self, l: LinkLevel) -> Option<&Pacer> {
        match l {
            LinkLevel::Intra => self.intra.as_ref(),
            LinkLevel::Inter => self.inter.as_ref(),
        }
    }
}

/// Bounded patience on the mesh receive path: how long a collective waits
/// for a silent peer before declaring it failed, instead of blocking
/// forever. The default (`timeout_ms == 0`) preserves the PR 7 fail-fast
/// contract exactly — receives block until a frame or an explicit
/// [`Frame::Abort`] arrives, and no timer can evict a merely-slow rank.
/// With a timeout set, attempt `k` waits `timeout_ms << k` (exponential
/// backoff) and the peer is declared [`MeshError::Timeout`] only after
/// `retries` extra attempts — so transient stalls (GC pause, pacer burst,
/// scheduler hiccup) ride out the backoff instead of triggering eviction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Extra receive attempts after the first timed-out wait.
    pub retries: u32,
    /// First attempt's receive timeout in milliseconds; doubles per
    /// retry. 0 disables the timer entirely (block forever — default).
    pub timeout_ms: u64,
}

impl RetryPolicy {
    /// Total worst-case patience across all attempts, for sizing test
    /// timeout guards and the simulator's reconfiguration pricing.
    pub fn max_wait_ms(&self) -> u64 {
        (0..=self.retries)
            .map(|k| self.timeout_ms.saturating_mul(1u64 << k.min(16)))
            .fold(0u64, u64::saturating_add)
    }
}

/// One mesh receive under `retry`: blocking when the policy is fail-fast,
/// bounded retry-with-backoff otherwise.
// xtask: hot-path
fn recv_frame(rank: usize, link: &MeshLink, retry: &RetryPolicy) -> Result<Frame, MeshError> {
    if retry.timeout_ms == 0 {
        return link.rx.recv().map_err(|_| MeshError::PeerDisconnected { rank });
    }
    let mut attempt = 0u32;
    loop {
        let wait = Duration::from_millis(retry.timeout_ms.saturating_mul(1u64 << attempt.min(16)));
        match link.rx.recv_timeout(wait) {
            Ok(f) => return Ok(f),
            Err(RecvTimeoutError::Timeout) => {
                if attempt >= retry.retries {
                    return Err(MeshError::Timeout { rank, attempts: attempt + 1 });
                }
                attempt += 1;
            }
            Err(RecvTimeoutError::Disconnected) => {
                return Err(MeshError::PeerDisconnected { rank });
            }
        }
    }
}

/// Per-thread reusable state for [`allgather_sched`]: the slot-arrival
/// bitmap, the circulating spare-buffer pool, the parking queue for
/// frames that arrive one collective early, and the epoch counter (all
/// ranks run collectives in identical order, so counters agree without
/// coordination). Capacity-only state — contents never survive a call.
#[derive(Default)]
pub struct GatherScratch {
    have: Vec<bool>,
    spares: Vec<Vec<u8>>,
    pending: VecDeque<(u32, Vec<u8>)>,
    epoch: u64,
}

impl GatherScratch {
    pub fn new() -> GatherScratch {
        GatherScratch::default()
    }
}

// xtask: hot-path
fn recv_chunk(rank: usize, link: &MeshLink) -> Result<Vec<f32>, MeshError> {
    match link.rx.recv() {
        Ok(Frame::Chunk(v)) => Ok(v),
        Ok(Frame::Slot { .. }) => Err(MeshError::Protocol { rank, expected: "Chunk" }),
        Ok(Frame::Abort { from }) => Err(MeshError::PeerAborted { rank, from }),
        Err(_) => Err(MeshError::PeerDisconnected { rank }),
    }
}

/// Adopt an arrived frame: its allocation becomes the slot, the displaced
/// slot buffer joins the spare pool.
// xtask: hot-path
fn store_slot(
    slot: usize,
    mut data: Vec<u8>,
    slots: &mut [Vec<u8>],
    have: &mut [bool],
    spares: &mut Vec<Vec<u8>>,
    received: &mut usize,
) {
    debug_assert!(!have[slot], "slot {slot} delivered twice");
    std::mem::swap(&mut slots[slot], &mut data);
    spares.push(data);
    have[slot] = true;
    *received += 1;
}

/// Execute one hop schedule from `rank`'s perspective: `mine` is this
/// rank's encoded wire frame; after the call the caller's `slots` hold
/// the rank-major frames of all ranks (including a copy of `mine` at
/// `slots[rank]`). Returns the per-level bytes this rank sent — the
/// measured wire traffic — or the [`MeshError`] that broke the
/// collective (dead/aborting peer, protocol violation, epoch skew
/// beyond the parking contract). On error the scratch state is stale;
/// callers must treat the executor as poisoned.
// xtask: hot-path
#[allow(clippy::too_many_arguments)]
pub fn allgather_sched(
    rank: usize,
    sched: &HopSchedule,
    mine: &[u8],
    slots: &mut [Vec<u8>],
    gs: &mut GatherScratch,
    link: &MeshLink,
    pacers: &PacerSet,
    retry: &RetryPolicy,
) -> Result<LevelBytes, MeshError> {
    let p = sched.world();
    assert_eq!(slots.len(), p, "one slot per rank");
    assert!(rank < p);
    slots[rank].clear();
    slots[rank].extend_from_slice(mine);
    let epoch = gs.epoch;
    gs.epoch += 1;
    let mut sent = LevelBytes::default();
    if p <= 1 {
        return Ok(sent);
    }
    gs.have.clear();
    gs.have.resize(p, false);
    gs.have[rank] = true;
    let mut received = 0usize;
    let expected = sched.recv_count(rank);
    // frames of THIS collective that arrived while the previous one was
    // still draining
    while let Some((slot, data)) = gs.pending.pop_front() {
        store_slot(slot as usize, data, slots, &mut gs.have, &mut gs.spares, &mut received);
    }
    let recv_one = |slots: &mut [Vec<u8>],
                        have: &mut Vec<bool>,
                        spares: &mut Vec<Vec<u8>>,
                        pending: &mut VecDeque<(u32, Vec<u8>)>,
                        received: &mut usize|
     -> Result<(), MeshError> {
        match recv_frame(rank, link, retry) {
            Ok(Frame::Slot { epoch: e, slot, data }) => {
                if e == epoch {
                    store_slot(slot as usize, data, slots, have, spares, received);
                    Ok(())
                } else if e == epoch + 1 {
                    pending.push_back((slot, data));
                    Ok(())
                } else {
                    // statically impossible for verified schedules (skew
                    // ≤ 1); enforced hard so a regression surfaces as an
                    // error instead of silent misdelivery
                    Err(MeshError::EpochSkew { rank, got: e, current: epoch })
                }
            }
            Ok(Frame::Chunk(_)) => Err(MeshError::Protocol { rank, expected: "Slot" }),
            Ok(Frame::Abort { from }) => Err(MeshError::PeerAborted { rank, from }),
            Err(e) => Err(e),
        }
    };
    for hop in sched.hops() {
        if hop.src as usize != rank {
            continue;
        }
        let slot = hop.slot as usize;
        // a forwarded slot's producing hop is strictly earlier: block
        // until it lands (storing whatever else arrives meanwhile)
        while !gs.have[slot] {
            recv_one(slots, &mut gs.have, &mut gs.spares, &mut gs.pending, &mut received)?;
        }
        let mut spare = gs.spares.pop().unwrap_or_default();
        spare.clear();
        spare.extend_from_slice(&slots[slot]);
        let bytes = spare.len();
        if let Some(pc) = pacers.level(hop.level) {
            pc.pace(bytes);
        }
        link.txs[hop.dst as usize]
            .send(Frame::Slot { epoch, slot: hop.slot, data: spare })
            .map_err(|_| MeshError::PeerDisconnected { rank: hop.dst as usize })?;
        sent.add(hop.level, bytes);
    }
    while received < expected {
        recv_one(slots, &mut gs.have, &mut gs.spares, &mut gs.pending, &mut received)?;
    }
    Ok(sent)
}

/// Chunked ring AllReduce (sum), threaded: call from every rank's comm
/// thread with its own buffer. Returns the bytes this rank sent.
///
/// Bitwise-identical to [`crate::comm::ring_allreduce`]: same
/// [`RingSchedule`], same `own += incoming` accumulation order per chunk.
/// Chunk buffers are recycled hop-to-hop (one spare per call, refilled
/// with the incoming chunk's allocation), so a 2(P-1)-hop collective
/// allocates O(1) buffers instead of O(P). Single-rank worlds are a
/// no-op.
pub fn ring_allreduce_threaded(
    rank: usize,
    world: usize,
    buf: &mut [f32],
    link: &MeshLink,
    pacer: Option<&Pacer>,
) -> Result<usize, MeshError> {
    let n = buf.len();
    if world <= 1 || n == 0 {
        return Ok(0);
    }
    let sched = RingSchedule::new(world, n);
    let next = (rank + 1) % world;
    let prev = (rank + world - 1) % world;
    let mut sent = 0usize;
    let mut spare: Vec<f32> = Vec::new();

    // Reduce-scatter.
    for s in 0..world - 1 {
        let c_out = sched.rs_chunk(rank, s);
        spare.clear();
        spare.extend_from_slice(&buf[sched.chunk(c_out)]);
        let bytes = spare.len() * 4;
        if let Some(p) = pacer {
            p.pace(bytes);
        }
        sent += bytes;
        link.txs[next]
            .send(Frame::Chunk(std::mem::take(&mut spare)))
            .map_err(|_| MeshError::PeerDisconnected { rank: next })?;
        let inc = recv_chunk(rank, link)?;
        let c_in = sched.rs_chunk(prev, s);
        let range = sched.chunk(c_in);
        debug_assert_eq!(inc.len(), range.len());
        for (d, sv) in buf[range].iter_mut().zip(inc.iter()) {
            *d += sv;
        }
        spare = inc;
    }
    // Allgather.
    for s in 0..world - 1 {
        let c_out = sched.ag_chunk(rank, s);
        spare.clear();
        spare.extend_from_slice(&buf[sched.chunk(c_out)]);
        let bytes = spare.len() * 4;
        if let Some(p) = pacer {
            p.pace(bytes);
        }
        sent += bytes;
        link.txs[next]
            .send(Frame::Chunk(std::mem::take(&mut spare)))
            .map_err(|_| MeshError::PeerDisconnected { rank: next })?;
        let inc = recv_chunk(rank, link)?;
        let c_in = sched.ag_chunk(prev, s);
        let range = sched.chunk(c_in);
        debug_assert_eq!(inc.len(), range.len());
        buf[range].copy_from_slice(&inc);
        spare = inc;
    }
    Ok(sent)
}

/// Flat-ring frame AllGather — [`allgather_sched`] specialized to the
/// one-level ring, building its schedule per call. The oracle path for
/// tests and one-shot callers; the executor caches the configured
/// topology's schedule and calls [`allgather_sched`] directly. Returns
/// total frame bytes sent.
pub fn allgather_frames(
    rank: usize,
    world: usize,
    mine: &[u8],
    slots: &mut [Vec<u8>],
    gs: &mut GatherScratch,
    link: &MeshLink,
    pacer: Option<&Pacer>,
) -> Result<usize, MeshError> {
    let sched = RING.allgather_schedule(ClusterSpec::new(world, 1));
    let lb = allgather_sched(
        rank,
        &sched,
        mine,
        slots,
        gs,
        link,
        &PacerSet::uniform(pacer.copied()),
        &RetryPolicy::default(),
    )?;
    Ok(lb.total())
}

/// `Payload`-level oracle wrapper over [`allgather_frames`]: encode,
/// rotate, decode every slot. Returns (payloads rank-major, frame bytes
/// this rank sent). The hot path keeps the frames and combines them
/// decode-free; this wrapper exists for tests and one-shot callers.
pub fn allgather_payloads(
    rank: usize,
    world: usize,
    mine: Payload,
    link: &MeshLink,
    pacer: Option<&Pacer>,
) -> Result<(Vec<Payload>, usize), MeshError> {
    let frame = mine.encode();
    let mut slots: Vec<Vec<u8>> = (0..world).map(|_| Vec::new()).collect();
    let mut gs = GatherScratch::new();
    let sent = allgather_frames(rank, world, &frame, &mut slots, &mut gs, link, pacer)?;
    let mut gathered = Vec::with_capacity(world);
    for (slot, f) in slots.iter().enumerate() {
        gathered.push(Payload::decode(f).map_err(|_| MeshError::Corrupt { rank, slot })?);
    }
    Ok((gathered, sent))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::topology::TopologyKind;
    use crate::comm::ring_allreduce;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Run the threaded allreduce across P scoped threads.
    fn run_threaded(bufs: &[Vec<f32>]) -> (Vec<Vec<f32>>, Vec<usize>) {
        let p = bufs.len();
        let links = make_mesh(p);
        std::thread::scope(|s| {
            let handles: Vec<_> = links
                .into_iter()
                .enumerate()
                .map(|(r, link)| {
                    let mut buf = bufs[r].clone();
                    s.spawn(move || {
                        let sent = ring_allreduce_threaded(r, p, &mut buf, &link, None)
                            .expect("collective");
                        (buf, sent)
                    })
                })
                .collect();
            let mut out = Vec::with_capacity(p);
            let mut sent = Vec::with_capacity(p);
            for h in handles {
                let (b, s) = h.join().expect("rank thread");
                out.push(b);
                sent.push(s);
            }
            (out, sent)
        })
    }

    /// The cross-validation the original issue pinned down: the threaded
    /// ring must be bitwise identical to the in-place simulator ring —
    /// uneven splits, n < p, p = 1 and empty buffers included.
    #[test]
    fn threaded_ring_bitwise_matches_inplace() {
        prop::check("exec-ring==comm-ring", 0x51D, 40, |rng: &mut Rng| {
            let p = 1 + rng.below(6);
            let n = rng.below(201); // 0, < p, uneven all covered
            let bufs: Vec<Vec<f32>> =
                (0..p).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
            let mut want = bufs.clone();
            ring_allreduce(&mut want);
            let (got, _) = run_threaded(&bufs);
            for r in 0..p {
                assert_eq!(
                    got[r], want[r],
                    "rank {r} diverged from in-place ring (p={p}, n={n})"
                );
            }
        });
    }

    #[test]
    fn threaded_ring_degenerate_cases() {
        for (p, n) in [(1usize, 0usize), (1, 7), (2, 0), (3, 1), (4, 3), (5, 17)] {
            let mut rng = Rng::seed((p * 100 + n) as u64);
            let bufs: Vec<Vec<f32>> =
                (0..p).map(|_| prop::vec_f32(&mut rng, n, 1.0)).collect();
            let mut want = bufs.clone();
            ring_allreduce(&mut want);
            let (got, _) = run_threaded(&bufs);
            assert_eq!(got, want, "p={p} n={n}");
        }
    }

    #[test]
    fn threaded_traffic_matches_schedule() {
        let p = 4;
        let n = 1000;
        let bufs: Vec<Vec<f32>> = (0..p).map(|_| vec![1.0f32; n]).collect();
        let (_, sent) = run_threaded(&bufs);
        let sched = crate::comm::RingSchedule::new(p, n);
        for r in 0..p {
            assert_eq!(sent[r], sched.allreduce_sent_bytes(r), "rank {r}");
        }
    }

    /// Run a schedule-driven frame allgather across P scoped threads:
    /// `rounds` consecutive collectives per thread with NO cross-thread
    /// synchronization between them (exercising the epoch parking path).
    /// Returns per-rank (slots after every round, per-level sent bytes of
    /// the last round).
    fn run_sched(
        sched: &HopSchedule,
        rounds: &[Vec<Vec<u8>>],
    ) -> Vec<(Vec<Vec<Vec<u8>>>, LevelBytes)> {
        let p = sched.world();
        let links = make_mesh(p);
        std::thread::scope(|s| {
            let handles: Vec<_> = links
                .into_iter()
                .enumerate()
                .map(|(r, link)| {
                    s.spawn(move || {
                        let mut slots: Vec<Vec<u8>> = (0..p).map(|_| Vec::new()).collect();
                        let mut gs = GatherScratch::new();
                        let mut got = Vec::new();
                        let mut last = LevelBytes::default();
                        let pacers = PacerSet::default();
                        for frames in rounds {
                            last = allgather_sched(
                                r,
                                sched,
                                &frames[r],
                                &mut slots,
                                &mut gs,
                                &link,
                                &pacers,
                                &RetryPolicy::default(),
                            )
                            .expect("collective");
                            got.push(slots.clone());
                        }
                        (got, last)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
        })
    }

    /// The satellite property test: every topology's frame allgather is
    /// bitwise-equal to the `comm::allgather` oracle (the rank-major
    /// payload set itself) for payloads of every variant — including
    /// `Payload::Empty` frames — over degenerate worlds (p = 1,
    /// nodes = 1, gpus_per_node = 1) and back-to-back collectives.
    #[test]
    fn every_topology_matches_allgather_oracle() {
        let shapes = [
            ClusterSpec::new(1, 1),
            ClusterSpec::new(1, 4),
            ClusterSpec::new(4, 1),
            ClusterSpec::new(2, 2),
            ClusterSpec::new(3, 2),
            ClusterSpec::new(2, 3),
        ];
        let mut rng = Rng::seed(0x7070);
        for c in shapes {
            let p = c.world();
            for kind in TopologyKind::all() {
                let sched = kind.resolve(c).allgather_schedule(c);
                // three consecutive rounds of fresh random payloads — the
                // third one all-Empty so zero-length frames rotate too
                let rounds: Vec<Vec<Payload>> = (0..3usize)
                    .map(|round| {
                        (0..p)
                            .map(|r| {
                                if round == 2 {
                                    return Payload::Empty;
                                }
                                let n = rng.below(9);
                                match (r + round) % 4 {
                                    0 => Payload::Dense(prop::vec_f32(&mut rng, n, 1.0)),
                                    1 => Payload::Empty,
                                    2 => Payload::Sparse {
                                        idx: vec![1, 7],
                                        val: vec![0.5, -2.0],
                                    },
                                    _ => Payload::Sign {
                                        scale: 0.25,
                                        bits: vec![0b1011_0010],
                                        n: 7,
                                    },
                                }
                            })
                            .collect()
                    })
                    .collect();
                let frame_rounds: Vec<Vec<Vec<u8>>> = rounds
                    .iter()
                    .map(|ps| ps.iter().map(|p| p.encode()).collect())
                    .collect();
                let results = run_sched(&sched, &frame_rounds);
                for (r, (per_round, _)) in results.iter().enumerate() {
                    for (round, got) in per_round.iter().enumerate() {
                        // oracle: the rank-major frames themselves
                        assert_eq!(
                            got, &frame_rounds[round],
                            "{} {c:?} rank {r} round {round}",
                            kind.spec()
                        );
                        for (slot, f) in got.iter().enumerate() {
                            assert_eq!(
                                Payload::decode(f).unwrap(),
                                rounds[round][slot],
                                "{} {c:?}: payload must survive the mesh bitwise",
                                kind.spec()
                            );
                        }
                    }
                }
            }
        }
    }

    /// Sent-byte accounting matches the schedule's per-level arithmetic
    /// for uniform frames, and the hierarchy really moves fewer
    /// inter-node bytes than the flat ring.
    #[test]
    fn sent_bytes_match_schedule_accounting() {
        let c = ClusterSpec::new(2, 2);
        let frame = vec![0xABu8; 50];
        let frames: Vec<Vec<Vec<u8>>> = vec![(0..4).map(|_| frame.clone()).collect()];
        let mut inter = std::collections::BTreeMap::new();
        for kind in TopologyKind::all() {
            let sched = kind.resolve(c).allgather_schedule(c);
            let results = run_sched(&sched, &frames);
            for (r, (_, sent)) in results.iter().enumerate() {
                assert_eq!(
                    *sent,
                    sched.level_bytes_uniform(r, frame.len()),
                    "{} rank {r}",
                    kind.spec()
                );
            }
            inter.insert(
                kind.spec(),
                results.iter().map(|(_, s)| s.inter).max().unwrap(),
            );
        }
        assert!(
            inter["hier"] < inter["ring"],
            "hier inter bytes {} must undercut ring {}",
            inter["hier"],
            inter["ring"]
        );
    }

    /// Run a payload allgather across P scoped threads; returns the
    /// rank-major gathered payloads and per-rank sent bytes.
    fn run_allgather(payloads: Vec<Payload>) -> (Vec<Vec<Payload>>, Vec<usize>) {
        let p = payloads.len();
        let links = make_mesh(p);
        std::thread::scope(|s| {
            let handles: Vec<_> = links
                .into_iter()
                .zip(payloads)
                .enumerate()
                .map(|(r, (link, mine))| {
                    s.spawn(move || {
                        allgather_payloads(r, p, mine, &link, None).expect("collective")
                    })
                })
                .collect();
            let mut out = Vec::with_capacity(p);
            let mut sent = Vec::with_capacity(p);
            for h in handles {
                let (g, s) = h.join().expect("rank thread");
                out.push(g);
                sent.push(s);
            }
            (out, sent)
        })
    }

    #[test]
    fn payload_allgather_is_rank_major() {
        let p = 4;
        let payloads: Vec<Payload> =
            (0..p).map(|r| Payload::Dense(vec![r as f32; 3])).collect();
        let (gathered, _) = run_allgather(payloads);
        for row in &gathered {
            assert_eq!(row.len(), p);
            for (c, pay) in row.iter().enumerate() {
                let Payload::Dense(v) = pay else { panic!("wrong variant") };
                assert_eq!(v, &vec![c as f32; 3], "slot {c}");
            }
        }
    }

    /// Frames survive the wire bitwise for every variant, and the measured
    /// sent bytes are exactly (P-1) hops of encoded frame lengths.
    #[test]
    fn payload_allgather_moves_encoded_frames() {
        let payloads = vec![
            Payload::Dense(vec![1.0, -0.0, f32::NAN]),
            Payload::Empty,
            Payload::Sparse { idx: vec![3, 9], val: vec![0.5, -0.25] },
            Payload::Sign { scale: 0.75, bits: vec![0b1011], n: 5 },
        ];
        let p = payloads.len();
        let (gathered, sent) = run_allgather(payloads.clone());
        for row in &gathered {
            for (want, got) in payloads.iter().zip(row.iter()) {
                assert_eq!(got, want, "payload must survive the wire bitwise");
            }
        }
        // rank r forwards every frame except its successor's: total sent =
        // sum of all frames' encoded lengths minus the one it never sends.
        let lens: Vec<usize> = payloads.iter().map(|p| p.encoded_len()).collect();
        let total: usize = lens.iter().sum();
        for (r, &s) in sent.iter().enumerate() {
            let skipped = lens[(r + 1) % p];
            assert_eq!(s, total - skipped, "rank {r} sent bytes");
        }
    }

    /// The reuse contract: calling [`allgather_frames`] repeatedly with
    /// the same persistent slots/scratch yields the identical gathered
    /// bytes every round — stale bytes from a previous (larger) round can
    /// never leak into a later one.
    #[test]
    fn frame_slots_are_reusable_across_rounds() {
        let p = 3;
        // round 1: big frames; round 2: smaller, different frames
        let rounds: Vec<Vec<Vec<u8>>> = vec![
            (0..p).map(|r| vec![r as u8 + 1; 64]).collect(),
            (0..p).map(|r| vec![0xF0 | r as u8; 5]).collect(),
            (0..p).map(|_| Vec::new()).collect(), // empty frames rotate too
        ];
        let links = make_mesh(p);
        let results: Vec<Vec<Vec<Vec<u8>>>> = std::thread::scope(|s| {
            let handles: Vec<_> = links
                .into_iter()
                .enumerate()
                .map(|(r, link)| {
                    let rounds = rounds.clone();
                    s.spawn(move || {
                        let mut slots: Vec<Vec<u8>> =
                            (0..p).map(|_| Vec::new()).collect();
                        let mut gs = GatherScratch::new();
                        let mut got = Vec::new();
                        for frames in &rounds {
                            allgather_frames(
                                r, p, &frames[r], &mut slots, &mut gs, &link, None,
                            )
                            .expect("collective");
                            got.push(slots.clone());
                        }
                        got
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
        });
        for (r, per_round) in results.iter().enumerate() {
            for (round, got) in per_round.iter().enumerate() {
                assert_eq!(
                    got, &rounds[round],
                    "rank {r} round {round}: slots must be exactly this round's frames"
                );
            }
        }
    }

    /// Satellite regression: a single-rank world is a no-op collective on
    /// the threaded path too — zero bytes sent, slots hold only `mine`.
    #[test]
    fn single_rank_allgather_is_identity() {
        let (got, sent) = allgather_payloads(
            0,
            1,
            Payload::Dense(vec![1.0, 2.0]),
            &make_mesh(1).remove(0),
            None,
        )
        .expect("collective");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], Payload::Dense(vec![1.0, 2.0]));
        assert_eq!(sent, 0);
    }

    #[test]
    fn pacer_slows_hops() {
        use std::time::Instant;
        let pacer = Pacer { bytes_per_s: 1e6, latency_s: 0.0 };
        let t0 = Instant::now();
        pacer.pace(50_000); // 50 ms at 1 MB/s
        assert!(t0.elapsed() >= Duration::from_millis(40));
    }

    #[test]
    fn pacer_set_derives_levels_from_net() {
        let net = NetworkModel::default();
        let ps = PacerSet::from_net(1.0, &net);
        let (intra, inter) = (ps.intra.unwrap(), ps.inter.unwrap());
        assert!(intra.bytes_per_s > inter.bytes_per_s, "intra fabric must be faster");
        assert!(intra.latency_s < inter.latency_s);
        assert!(PacerSet::from_net(0.0, &net).intra.is_none());
        assert!(PacerSet::from_net(0.0, &net).inter.is_none());
    }

    /// A peer that dies broadcasts [`Frame::Abort`]; a rank blocked in its
    /// receive loop must fail fast with `PeerAborted` instead of hanging.
    #[test]
    fn abort_frame_fails_collective_instead_of_hanging() {
        let mut links = make_mesh(2);
        let l1 = links.pop().unwrap();
        let l0 = links.pop().unwrap();
        broadcast_abort(1, &l1);
        let sched = RING.allgather_schedule(ClusterSpec::new(2, 1));
        let mut slots = vec![Vec::new(), Vec::new()];
        let mut gs = GatherScratch::new();
        let r = allgather_sched(
            0,
            &sched,
            &[1, 2, 3],
            &mut slots,
            &mut gs,
            &l0,
            &PacerSet::default(),
            &RetryPolicy::default(),
        );
        assert_eq!(r, Err(MeshError::PeerAborted { rank: 0, from: 1 }));
    }

    /// Epoch skew beyond the statically proven bound (one collective
    /// ahead) is a hard protocol error, not a silent parking.
    #[test]
    fn far_future_epoch_is_rejected() {
        let mut links = make_mesh(2);
        let l1 = links.pop().unwrap();
        let l0 = links.pop().unwrap();
        l1.txs[0]
            .send(Frame::Slot { epoch: 5, slot: 1, data: vec![9] })
            .unwrap();
        let sched = RING.allgather_schedule(ClusterSpec::new(2, 1));
        let mut slots = vec![Vec::new(), Vec::new()];
        let mut gs = GatherScratch::new();
        let r = allgather_sched(
            0,
            &sched,
            &[1, 2, 3],
            &mut slots,
            &mut gs,
            &l0,
            &PacerSet::default(),
            &RetryPolicy::default(),
        );
        assert_eq!(r, Err(MeshError::EpochSkew { rank: 0, got: 5, current: 0 }));
    }

    /// A configured retry budget declares a silent peer failed by timer —
    /// after the full backoff ladder, not the first stall — while the
    /// default policy keeps the fail-fast semantics (exercised by every
    /// other test in this module, which would hang here instead).
    #[test]
    fn bounded_retry_times_out_on_silent_peer() {
        use std::time::Instant;
        let mut links = make_mesh(2);
        let _l1 = links.pop().unwrap();
        let l0 = links.pop().unwrap();
        let sched = RING.allgather_schedule(ClusterSpec::new(2, 1));
        let mut slots = vec![Vec::new(), Vec::new()];
        let mut gs = GatherScratch::new();
        let retry = RetryPolicy { retries: 2, timeout_ms: 10 };
        let t0 = Instant::now();
        let r = allgather_sched(
            0,
            &sched,
            &[1, 2, 3],
            &mut slots,
            &mut gs,
            &l0,
            &PacerSet::default(),
            &retry,
        );
        // rank 1 never speaks: 10 + 20 + 40 ms of patience, then Timeout
        assert_eq!(r, Err(MeshError::Timeout { rank: 0, attempts: 3 }));
        assert!(t0.elapsed() >= Duration::from_millis(50), "backoff ladder ran");
        assert_eq!(retry.max_wait_ms(), 70);
    }

    /// A transient stall shorter than the budget does NOT evict the peer:
    /// the late frame is consumed on a retry attempt and the collective
    /// completes normally.
    #[test]
    fn transient_stall_survives_within_retry_budget() {
        let mut links = make_mesh(2);
        let l1 = links.pop().unwrap();
        let l0 = links.pop().unwrap();
        let sched = RING.allgather_schedule(ClusterSpec::new(2, 1));
        let retry = RetryPolicy { retries: 4, timeout_ms: 10 };
        let peer = std::thread::spawn(move || {
            // stall past the first attempt, inside the total budget
            std::thread::sleep(Duration::from_millis(25));
            let mut slots = vec![Vec::new(), Vec::new()];
            let mut gs = GatherScratch::new();
            allgather_sched(
                1,
                &sched,
                &[9, 9],
                &mut slots,
                &mut gs,
                &l1,
                &PacerSet::default(),
                &RetryPolicy::default(),
            )
            .expect("late rank still completes");
        });
        let sched0 = RING.allgather_schedule(ClusterSpec::new(2, 1));
        let mut slots = vec![Vec::new(), Vec::new()];
        let mut gs = GatherScratch::new();
        allgather_sched(
            0,
            &sched0,
            &[1, 2, 3],
            &mut slots,
            &mut gs,
            &l0,
            &PacerSet::default(),
            &retry,
        )
        .expect("stall rides out the backoff instead of evicting");
        assert_eq!(slots[1], vec![9, 9]);
        peer.join().expect("peer thread");
    }
}
