//! Threaded ring collectives over per-edge FIFO channels.
//!
//! Each directed ring edge `r -> (r+1) % P` is one mpsc channel; a rank's
//! [`RingLink`] bundles its outgoing sender and incoming receiver. The
//! dense allreduce follows [`crate::comm::RingSchedule`] chunk-for-chunk —
//! the same schedule the in-place [`crate::comm::ring_allreduce`] walks —
//! so the two are **bitwise identical** (property-tested below): per chunk
//! the sum is the same sequential chain, only executed by P real threads.
//!
//! [`allgather_frames`] is the compressed-frame rotation: every rank
//! contributes one encoded wire frame and the ring moves the raw bytes —
//! what a real transport would see — into the caller's **persistent slot
//! buffers** (rank-major). Buffer discipline is allocation-free in steady
//! state: each hop copies the outgoing slot into a `spare` send buffer
//! (the one unavoidable copy — the slot must be retained for combining
//! while its bytes ship), sends the spare's allocation through the
//! channel, adopts the incoming frame's allocation as the slot
//! (zero-copy receive via swap) and keeps the displaced slot buffer as
//! the next spare — so `Vec` capacities circulate around the ring and,
//! once every buffer has grown to the largest frame seen, no hop
//! allocates. (The mpsc channel's internal
//! block allocation is the one remaining transport-layer cost; see
//! DESIGN.md §7.) Hop pacing and the `sent` accounting both use the
//! measured frame length, so the bytes charged are the bytes a rank
//! actually put on the wire, not a size model. [`Pacer`] optionally
//! throttles every hop to a modeled wire bandwidth + latency so measured
//! timelines can emulate a slow fabric on a fast testbed.
//!
//! [`allgather_payloads`] — the `Payload`-level wrapper over
//! [`allgather_frames`] — is retained as the property-test oracle.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use crate::comm::{rot_recv, rot_send, RingSchedule};
use crate::compress::Payload;

/// One frame on a ring edge.
pub enum Frame {
    /// A chunk of a dense f32 collective.
    Chunk(Vec<f32>),
    /// A serialized compressed-payload frame ([`Payload::encode_into`]).
    Bytes(Vec<u8>),
}

/// One rank's pair of ring-edge endpoints.
pub struct RingLink {
    /// To rank (r + 1) % P.
    pub tx: Sender<Frame>,
    /// From rank (r - 1 + P) % P.
    pub rx: Receiver<Frame>,
}

/// Build the P directed edges; element r is rank r's link.
pub fn make_links(p: usize) -> Vec<RingLink> {
    assert!(p >= 1);
    let mut txs = Vec::with_capacity(p);
    let mut rxs = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel::<Frame>();
        txs.push(tx);
        rxs.push(rx);
    }
    // rank r sends on edge r (into r+1) and receives on edge r-1.
    rxs.rotate_right(1);
    txs.into_iter()
        .zip(rxs)
        .map(|(tx, rx)| RingLink { tx, rx })
        .collect()
}

/// Emulated wire pacing: every hop of `bytes` costs
/// `bytes / bytes_per_s + latency_s` of sleep on the sending side.
#[derive(Debug, Clone, Copy)]
pub struct Pacer {
    pub bytes_per_s: f64,
    pub latency_s: f64,
}

impl Pacer {
    /// Derive from a NIC line rate (Gbit/s) at the given efficiency.
    pub fn from_gbps(gbps: f64, efficiency: f64, latency_s: f64) -> Pacer {
        Pacer { bytes_per_s: (gbps * 1e9 / 8.0 * efficiency).max(1.0), latency_s }
    }

    pub fn pace(&self, bytes: usize) {
        let s = bytes as f64 / self.bytes_per_s + self.latency_s;
        if s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(s));
        }
    }
}

fn recv_chunk(link: &RingLink) -> Vec<f32> {
    match link.rx.recv() {
        Ok(Frame::Chunk(v)) => v,
        Ok(Frame::Bytes(_)) => panic!("protocol error: expected Chunk, got Bytes"),
        Err(_) => panic!("ring peer disconnected mid-collective"),
    }
}

fn recv_bytes(link: &RingLink) -> Vec<u8> {
    match link.rx.recv() {
        Ok(Frame::Bytes(b)) => b,
        Ok(Frame::Chunk(_)) => panic!("protocol error: expected Bytes, got Chunk"),
        Err(_) => panic!("ring peer disconnected mid-collective"),
    }
}

/// One byte-frame hop: copy `src` into `spare`, ship the spare's
/// allocation down the ring edge (pacing on the sender side), and return
/// the incoming frame. The caller copies the incoming bytes into its slot
/// and adopts the returned buffer as the next spare — the allocation
/// circulates instead of being dropped.
fn hop_bytes(
    link: &RingLink,
    pacer: Option<&Pacer>,
    src: &[u8],
    spare: &mut Vec<u8>,
) -> Vec<u8> {
    spare.clear();
    spare.extend_from_slice(src);
    if let Some(p) = pacer {
        p.pace(src.len());
    }
    link.tx.send(Frame::Bytes(std::mem::take(spare))).expect("ring send");
    recv_bytes(link)
}

/// Chunked ring AllReduce (sum), threaded: call from every rank's comm
/// thread with its own buffer. Returns the bytes this rank sent.
///
/// Bitwise-identical to [`crate::comm::ring_allreduce`]: same
/// [`RingSchedule`], same `own += incoming` accumulation order per chunk.
/// Chunk buffers are recycled hop-to-hop (one spare per call, refilled
/// with the incoming chunk's allocation), so a 2(P-1)-hop collective
/// allocates O(1) buffers instead of O(P).
pub fn ring_allreduce_threaded(
    rank: usize,
    world: usize,
    buf: &mut [f32],
    link: &RingLink,
    pacer: Option<&Pacer>,
) -> usize {
    let n = buf.len();
    if world <= 1 || n == 0 {
        return 0;
    }
    let sched = RingSchedule::new(world, n);
    let prev = (rank + world - 1) % world;
    let mut sent = 0usize;
    let mut spare: Vec<f32> = Vec::new();

    // Reduce-scatter.
    for s in 0..world - 1 {
        let c_out = sched.rs_chunk(rank, s);
        spare.clear();
        spare.extend_from_slice(&buf[sched.chunk(c_out)]);
        let bytes = spare.len() * 4;
        if let Some(p) = pacer {
            p.pace(bytes);
        }
        sent += bytes;
        link.tx.send(Frame::Chunk(std::mem::take(&mut spare))).expect("ring send");
        let inc = recv_chunk(link);
        let c_in = sched.rs_chunk(prev, s);
        let range = sched.chunk(c_in);
        debug_assert_eq!(inc.len(), range.len());
        for (d, sv) in buf[range].iter_mut().zip(inc.iter()) {
            *d += sv;
        }
        spare = inc;
    }
    // Allgather.
    for s in 0..world - 1 {
        let c_out = sched.ag_chunk(rank, s);
        spare.clear();
        spare.extend_from_slice(&buf[sched.chunk(c_out)]);
        let bytes = spare.len() * 4;
        if let Some(p) = pacer {
            p.pace(bytes);
        }
        sent += bytes;
        link.tx.send(Frame::Chunk(std::mem::take(&mut spare))).expect("ring send");
        let inc = recv_chunk(link);
        let c_in = sched.ag_chunk(prev, s);
        let range = sched.chunk(c_in);
        debug_assert_eq!(inc.len(), range.len());
        buf[range].copy_from_slice(&inc);
        spare = inc;
    }
    sent
}

/// Serialized ring AllGather over **reusable frame buffers**: every rank
/// contributes its encoded wire frame `mine`; after P-1 rotation hops the
/// caller's `slots` hold the rank-major frames of all ranks (including a
/// copy of `mine` at `slots[rank]`). `spare` is the persistent send
/// buffer; its allocation is shipped each hop and replaced by the
/// incoming frame's (capacities circulate — see module docs). Returns the
/// frame bytes this rank sent — the measured wire traffic.
pub fn allgather_frames(
    rank: usize,
    world: usize,
    mine: &[u8],
    slots: &mut [Vec<u8>],
    spare: &mut Vec<u8>,
    link: &RingLink,
    pacer: Option<&Pacer>,
) -> usize {
    assert_eq!(slots.len(), world, "one slot per rank");
    slots[rank].clear();
    slots[rank].extend_from_slice(mine);
    if world <= 1 {
        return 0;
    }
    let mut sent = 0usize;
    for s in 0..world - 1 {
        let c_out = rot_send(world, rank, s);
        sent += slots[c_out].len();
        let mut inc = hop_bytes(link, pacer, &slots[c_out], spare);
        let c_in = rot_recv(world, rank, s);
        debug_assert_ne!(c_in, rank, "rotation must never overwrite our own slot");
        // adopt the incoming buffer as the slot (zero-copy receive); the
        // displaced slot buffer becomes the next hop's spare
        std::mem::swap(&mut slots[c_in], &mut inc);
        *spare = inc;
    }
    sent
}

/// `Payload`-level oracle wrapper over [`allgather_frames`]: encode,
/// rotate, decode every slot. Returns (payloads rank-major, frame bytes
/// this rank sent). The hot path keeps the frames and combines them
/// decode-free; this wrapper exists for tests and one-shot callers.
pub fn allgather_payloads(
    rank: usize,
    world: usize,
    mine: Payload,
    link: &RingLink,
    pacer: Option<&Pacer>,
) -> (Vec<Payload>, usize) {
    let frame = mine.encode();
    let mut slots: Vec<Vec<u8>> = (0..world).map(|_| Vec::new()).collect();
    let mut spare = Vec::new();
    let sent = allgather_frames(rank, world, &frame, &mut slots, &mut spare, link, pacer);
    let gathered = slots
        .iter()
        .map(|f| Payload::decode(f).expect("corrupt ring frame"))
        .collect();
    (gathered, sent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ring_allreduce;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Run the threaded allreduce across P scoped threads.
    fn run_threaded(bufs: &[Vec<f32>]) -> (Vec<Vec<f32>>, Vec<usize>) {
        let p = bufs.len();
        let links = make_links(p);
        std::thread::scope(|s| {
            let handles: Vec<_> = links
                .into_iter()
                .enumerate()
                .map(|(r, link)| {
                    let mut buf = bufs[r].clone();
                    s.spawn(move || {
                        let sent = ring_allreduce_threaded(r, p, &mut buf, &link, None);
                        (buf, sent)
                    })
                })
                .collect();
            let mut out = Vec::with_capacity(p);
            let mut sent = Vec::with_capacity(p);
            for h in handles {
                let (b, s) = h.join().expect("rank thread");
                out.push(b);
                sent.push(s);
            }
            (out, sent)
        })
    }

    /// The cross-validation the issue pins down: the threaded ring must be
    /// bitwise identical to the in-place simulator ring — uneven splits,
    /// n < p, p = 1 and empty buffers included.
    #[test]
    fn threaded_ring_bitwise_matches_inplace() {
        prop::check("exec-ring==comm-ring", 0x51D, 40, |rng: &mut Rng| {
            let p = 1 + rng.below(6);
            let n = rng.below(201); // 0, < p, uneven all covered
            let bufs: Vec<Vec<f32>> =
                (0..p).map(|_| prop::vec_f32(rng, n, 1.0)).collect();
            let mut want = bufs.clone();
            ring_allreduce(&mut want);
            let (got, _) = run_threaded(&bufs);
            for r in 0..p {
                assert_eq!(
                    got[r], want[r],
                    "rank {r} diverged from in-place ring (p={p}, n={n})"
                );
            }
        });
    }

    #[test]
    fn threaded_ring_degenerate_cases() {
        for (p, n) in [(1usize, 0usize), (1, 7), (2, 0), (3, 1), (4, 3), (5, 17)] {
            let mut rng = Rng::seed((p * 100 + n) as u64);
            let bufs: Vec<Vec<f32>> =
                (0..p).map(|_| prop::vec_f32(&mut rng, n, 1.0)).collect();
            let mut want = bufs.clone();
            ring_allreduce(&mut want);
            let (got, _) = run_threaded(&bufs);
            assert_eq!(got, want, "p={p} n={n}");
        }
    }

    #[test]
    fn threaded_traffic_matches_schedule() {
        let p = 4;
        let n = 1000;
        let bufs: Vec<Vec<f32>> = (0..p).map(|_| vec![1.0f32; n]).collect();
        let (_, sent) = run_threaded(&bufs);
        let sched = crate::comm::RingSchedule::new(p, n);
        for r in 0..p {
            assert_eq!(sent[r], sched.allreduce_sent_bytes(r), "rank {r}");
        }
    }

    /// Run a payload allgather across P scoped threads; returns the
    /// rank-major gathered payloads and per-rank sent bytes.
    fn run_allgather(payloads: Vec<Payload>) -> (Vec<Vec<Payload>>, Vec<usize>) {
        let p = payloads.len();
        let links = make_links(p);
        std::thread::scope(|s| {
            let handles: Vec<_> = links
                .into_iter()
                .zip(payloads)
                .enumerate()
                .map(|(r, (link, mine))| {
                    s.spawn(move || allgather_payloads(r, p, mine, &link, None))
                })
                .collect();
            let mut out = Vec::with_capacity(p);
            let mut sent = Vec::with_capacity(p);
            for h in handles {
                let (g, s) = h.join().expect("rank thread");
                out.push(g);
                sent.push(s);
            }
            (out, sent)
        })
    }

    #[test]
    fn payload_allgather_is_rank_major() {
        let p = 4;
        let payloads: Vec<Payload> =
            (0..p).map(|r| Payload::Dense(vec![r as f32; 3])).collect();
        let (gathered, _) = run_allgather(payloads);
        for row in &gathered {
            assert_eq!(row.len(), p);
            for (c, pay) in row.iter().enumerate() {
                let Payload::Dense(v) = pay else { panic!("wrong variant") };
                assert_eq!(v, &vec![c as f32; 3], "slot {c}");
            }
        }
    }

    /// Frames survive the wire bitwise for every variant, and the measured
    /// sent bytes are exactly (P-1) hops of encoded frame lengths.
    #[test]
    fn payload_allgather_moves_encoded_frames() {
        let payloads = vec![
            Payload::Dense(vec![1.0, -0.0, f32::NAN]),
            Payload::Empty,
            Payload::Sparse { idx: vec![3, 9], val: vec![0.5, -0.25] },
            Payload::Sign { scale: 0.75, bits: vec![0b1011], n: 5 },
        ];
        let p = payloads.len();
        let (gathered, sent) = run_allgather(payloads.clone());
        for row in &gathered {
            for (want, got) in payloads.iter().zip(row.iter()) {
                assert_eq!(got, want, "payload must survive the wire bitwise");
            }
        }
        // rank r forwards every frame except its successor's: total sent =
        // sum of all frames' encoded lengths minus the one it never sends.
        let lens: Vec<usize> = payloads.iter().map(|p| p.encoded_len()).collect();
        let total: usize = lens.iter().sum();
        for (r, &s) in sent.iter().enumerate() {
            let skipped = lens[(r + 1) % p];
            assert_eq!(s, total - skipped, "rank {r} sent bytes");
        }
    }

    /// The reuse contract: calling `allgather_frames` repeatedly with the
    /// same persistent slots/spare buffers yields the identical gathered
    /// bytes every round — stale bytes from a previous (larger) round can
    /// never leak into a later one.
    #[test]
    fn frame_slots_are_reusable_across_rounds() {
        let p = 3;
        // round 1: big frames; round 2: smaller, different frames
        let rounds: Vec<Vec<Vec<u8>>> = vec![
            (0..p).map(|r| vec![r as u8 + 1; 64]).collect(),
            (0..p).map(|r| vec![0xF0 | r as u8; 5]).collect(),
            (0..p).map(|_| Vec::new()).collect(), // empty frames rotate too
        ];
        let links = make_links(p);
        let results: Vec<Vec<Vec<Vec<u8>>>> = std::thread::scope(|s| {
            let handles: Vec<_> = links
                .into_iter()
                .enumerate()
                .map(|(r, link)| {
                    let rounds = rounds.clone();
                    s.spawn(move || {
                        let mut slots: Vec<Vec<u8>> =
                            (0..p).map(|_| Vec::new()).collect();
                        let mut spare = Vec::new();
                        let mut got = Vec::new();
                        for frames in &rounds {
                            allgather_frames(
                                r, p, &frames[r], &mut slots, &mut spare, &link, None,
                            );
                            got.push(slots.clone());
                        }
                        got
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
        });
        for (r, per_round) in results.iter().enumerate() {
            for (round, got) in per_round.iter().enumerate() {
                assert_eq!(
                    got, &rounds[round],
                    "rank {r} round {round}: slots must be exactly this round's frames"
                );
            }
        }
    }

    #[test]
    fn single_rank_allgather_is_identity() {
        let (got, sent) =
            allgather_payloads(0, 1, Payload::Dense(vec![1.0, 2.0]), &make_links(1).remove(0), None);
        assert_eq!(got.len(), 1);
        assert_eq!(sent, 0);
    }

    #[test]
    fn pacer_slows_hops() {
        use std::time::Instant;
        let pacer = Pacer { bytes_per_s: 1e6, latency_s: 0.0 };
        let t0 = Instant::now();
        pacer.pace(50_000); // 50 ms at 1 MB/s
        assert!(t0.elapsed() >= Duration::from_millis(40));
    }
}
