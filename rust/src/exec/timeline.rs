//! Measured per-rank timelines and their reduction to the same breakdown
//! shape the discrete-event simulator predicts ([`crate::sim::Breakdown`]),
//! so measured and simulated numbers sit side by side in the trainer logs
//! and the `exec_vs_sim` bench.
//!
//! All spans are seconds relative to the step's shared epoch (the main
//! thread stamps one `Instant` per step and every rank reports offsets
//! from it), so cross-rank alignment is free.

/// What a span on a rank's streams represents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Backward computation producing one tensor's gradient (compute thread).
    Compute,
    /// Local compression of one tensor (compute thread, serializes with
    /// computation — Eq. 6).
    Compress,
    /// Collective exchange + decode of one tensor (comm thread).
    Comm,
}

/// One measured span.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub kind: SpanKind,
    pub tensor: usize,
    pub start_s: f64,
    pub end_s: f64,
}

impl Span {
    /// Span length in seconds. A negative extent means the clock stamps
    /// went backwards (cross-thread skew bug) — that must surface, not
    /// vanish into the breakdowns: debug builds assert, release builds
    /// log a structured warning and clamp to zero.
    pub fn duration(&self) -> f64 {
        let d = self.end_s - self.start_s;
        if d < 0.0 {
            debug_assert!(
                false,
                "negative span: kind={:?} tensor={} start={} end={}",
                self.kind, self.tensor, self.start_s, self.end_s
            );
            crate::log_warn!(
                target: "exec",
                "negative span clamped: kind={:?} tensor={} start_s={} end_s={}",
                self.kind,
                self.tensor,
                self.start_s,
                self.end_s
            );
            return 0.0;
        }
        d
    }
}

/// One rank's measured step timeline.
#[derive(Debug, Clone, Default)]
pub struct RankTimeline {
    pub rank: usize,
    pub spans: Vec<Span>,
    /// Bytes this rank actually pushed through its mesh links this step.
    pub moved_bytes: usize,
    /// The same bytes split by link level (intra- vs inter-node hops of
    /// the configured topology's schedule).
    pub moved_levels: crate::comm::LevelBytes,
    /// Time spent blocked in the step-start barrier (skew indicator).
    pub barrier_wait_s: f64,
}

/// The measured analogue of [`crate::sim::Breakdown`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MeasuredBreakdown {
    /// Total backward computation (busy time on the compute thread).
    pub comp_s: f64,
    /// Total local compression (busy time on the compute thread).
    pub compress_s: f64,
    /// Total collective busy time on the comm thread (includes peer
    /// rendezvous wait, like a real NCCL stream).
    pub comm_s: f64,
    /// Exposed communication: how far the comm stream ran past the end of
    /// the compute stream — the measured T_comm'.
    pub exposed_s: f64,
    /// End-to-end step wall time (max span end).
    pub wall_s: f64,
    /// Bytes moved through the mesh links.
    pub moved_bytes: usize,
    /// Of `moved_bytes`, the bytes that crossed inter-node links — the
    /// measured form of the per-level wire accounting (hierarchical
    /// topologies push most of their volume onto the intra fabric).
    pub moved_inter_bytes: usize,
}

/// Reduce one rank's spans to a breakdown.
pub fn breakdown(t: &RankTimeline) -> MeasuredBreakdown {
    let mut comp = 0.0;
    let mut compress = 0.0;
    let mut comm = 0.0;
    let mut compute_end: f64 = 0.0;
    let mut comm_end: f64 = 0.0;
    let mut wall: f64 = 0.0;
    for s in &t.spans {
        wall = wall.max(s.end_s);
        match s.kind {
            SpanKind::Compute => {
                comp += s.duration();
                compute_end = compute_end.max(s.end_s);
            }
            SpanKind::Compress => {
                compress += s.duration();
                compute_end = compute_end.max(s.end_s);
            }
            SpanKind::Comm => {
                comm += s.duration();
                comm_end = comm_end.max(s.end_s);
            }
        }
    }
    MeasuredBreakdown {
        comp_s: comp,
        compress_s: compress,
        comm_s: comm,
        exposed_s: (comm_end - compute_end).max(0.0),
        wall_s: wall,
        moved_bytes: t.moved_bytes,
        moved_inter_bytes: t.moved_levels.inter,
    }
}

/// Cluster-level reduction: busy times average over ranks (per-worker
/// means, like the profiler), wall and exposure take the slowest rank (the
/// rendezvous semantics of a synchronous step).
pub fn aggregate(per_rank: &[MeasuredBreakdown]) -> MeasuredBreakdown {
    if per_rank.is_empty() {
        return MeasuredBreakdown::default();
    }
    let n = per_rank.len() as f64;
    MeasuredBreakdown {
        comp_s: per_rank.iter().map(|b| b.comp_s).sum::<f64>() / n,
        compress_s: per_rank.iter().map(|b| b.compress_s).sum::<f64>() / n,
        comm_s: per_rank.iter().map(|b| b.comm_s).sum::<f64>() / n,
        exposed_s: per_rank.iter().map(|b| b.exposed_s).fold(0.0, f64::max),
        wall_s: per_rank.iter().map(|b| b.wall_s).fold(0.0, f64::max),
        moved_bytes: per_rank.iter().map(|b| b.moved_bytes).max().unwrap_or(0),
        moved_inter_bytes: per_rank.iter().map(|b| b.moved_inter_bytes).max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, start_s: f64, end_s: f64) -> Span {
        Span { kind, tensor: 0, start_s, end_s }
    }

    #[test]
    fn sequential_shape_exposes_all_comm() {
        // compute [0, 2], comm [2, 5]: exposed = 3
        let t = RankTimeline {
            rank: 0,
            spans: vec![
                span(SpanKind::Compute, 0.0, 2.0),
                span(SpanKind::Comm, 2.0, 5.0),
            ],
            moved_bytes: 100,
            ..Default::default()
        };
        let b = breakdown(&t);
        assert_eq!(b.comp_s, 2.0);
        assert_eq!(b.comm_s, 3.0);
        assert_eq!(b.exposed_s, 3.0);
        assert_eq!(b.wall_s, 5.0);
    }

    #[test]
    fn overlapped_shape_exposes_only_tail() {
        // compute [0,1] [1,2] [2,3]; comm [1,2.5] [2.5,3.5]: tail = 0.5
        let t = RankTimeline {
            rank: 0,
            spans: vec![
                span(SpanKind::Compute, 0.0, 1.0),
                span(SpanKind::Compute, 1.0, 2.0),
                span(SpanKind::Compute, 2.0, 3.0),
                span(SpanKind::Comm, 1.0, 2.5),
                span(SpanKind::Comm, 2.5, 3.5),
            ],
            ..Default::default()
        };
        let b = breakdown(&t);
        assert!((b.exposed_s - 0.5).abs() < 1e-12);
        assert_eq!(b.comp_s, 3.0);
        assert_eq!(b.comm_s, 2.5);
    }

    #[test]
    fn fully_hidden_comm_is_zero_exposed() {
        let t = RankTimeline {
            rank: 0,
            spans: vec![
                span(SpanKind::Compute, 0.0, 4.0),
                span(SpanKind::Comm, 1.0, 2.0),
            ],
            ..Default::default()
        };
        assert_eq!(breakdown(&t).exposed_s, 0.0);
    }

    #[test]
    fn compress_counts_toward_compute_stream() {
        let t = RankTimeline {
            rank: 0,
            spans: vec![
                span(SpanKind::Compute, 0.0, 1.0),
                span(SpanKind::Compress, 1.0, 1.5),
                span(SpanKind::Comm, 1.0, 1.2),
            ],
            ..Default::default()
        };
        let b = breakdown(&t);
        assert_eq!(b.compress_s, 0.5);
        assert_eq!(b.exposed_s, 0.0, "comm ended before compress stream");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "negative span")]
    fn negative_span_asserts_in_debug() {
        span(SpanKind::Compute, 2.0, 1.0).duration();
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn negative_span_clamps_in_release() {
        assert_eq!(span(SpanKind::Compute, 2.0, 1.0).duration(), 0.0);
    }

    #[test]
    fn aggregate_takes_worst_rank_walls() {
        let a = MeasuredBreakdown {
            comp_s: 1.0,
            comm_s: 2.0,
            exposed_s: 0.5,
            wall_s: 3.0,
            moved_bytes: 10,
            ..Default::default()
        };
        let b = MeasuredBreakdown { comp_s: 2.0, exposed_s: 1.5, wall_s: 4.0, ..a };
        let agg = aggregate(&[a, b]);
        assert_eq!(agg.comp_s, 1.5);
        assert_eq!(agg.wall_s, 4.0);
        assert_eq!(agg.exposed_s, 1.5);
    }
}
