//! Sim-vs-exec cross-validation: run the same configuration through the
//! analytic backend (simulated timeline) and the threaded backend
//! (measured timeline), check the two are numerically bit-identical, and
//! report their breakdowns side by side.
//!
//! This is the repo's answer to "On the Utility of Gradient Compression"
//! (Agarwal et al.): overlap/speedup claims from the model are only kept
//! if real concurrent execution reproduces the numerics exactly and the
//! measured exposed communication behaves the way the simulator says it
//! should. Used by `tests/exec_parity.rs`, `benches/exec_vs_sim.rs` and
//! the `covap exec` CLI subcommand.

use anyhow::Result;

use crate::config::{ExecBackend, RunConfig};
use crate::coordinator::DpEngine;
use crate::exec::timeline::MeasuredBreakdown;
use crate::runtime::ModelArtifacts;
use crate::sim::Breakdown;

/// Outcome of one backend comparison.
#[derive(Debug, Clone)]
pub struct BackendComparison {
    pub scheme: String,
    pub world: usize,
    pub steps: u64,
    /// Losses bit-identical every step AND final params bit-identical.
    pub bitwise_equal: bool,
    pub loss_analytic: Vec<f32>,
    pub loss_threaded: Vec<f32>,
    /// Mean simulated breakdown over post-warmup steps (threaded run's
    /// own simulation, so both columns describe the same execution).
    pub sim: Breakdown,
    /// Mean measured breakdown over post-warmup steps.
    pub measured: MeasuredBreakdown,
    /// Mean wire bytes per step (accounting volume).
    pub wire_bytes: usize,
    /// Mean threaded step wall time (whole step incl. optimizer).
    pub step_wall_s: f64,
}

/// Run `base` through both backends on the synthetic model path and
/// compare. `base.backend` is overridden per run; everything else (seed,
/// scheme, workers, policy, pacing) is honored.
pub fn compare_backends(base: &RunConfig, preset: &str, steps: u64) -> Result<BackendComparison> {
    let mut cfg_a = base.clone();
    cfg_a.backend = ExecBackend::Analytic;
    cfg_a.steps = steps;
    let mut cfg_t = base.clone();
    cfg_t.backend = ExecBackend::Threaded;
    cfg_t.steps = steps;

    let mut eng_a = DpEngine::new(cfg_a, ModelArtifacts::synthetic(preset))?;
    let mut eng_t = DpEngine::new(cfg_t, ModelArtifacts::synthetic(preset))?;

    let mut loss_a = Vec::with_capacity(steps as usize);
    let mut loss_t = Vec::with_capacity(steps as usize);
    let mut bitwise = true;

    let mut sim_acc: Option<Breakdown> = None;
    let mut meas_acc = MeasuredBreakdown::default();
    let mut wire_acc = 0usize;
    let mut wall_acc = 0.0f64;
    let mut tail = 0usize; // post-warmup step count

    for s in 0..steps {
        let oa = eng_a.step()?;
        let ot = eng_t.step()?;
        bitwise &= oa.loss.to_bits() == ot.loss.to_bits();
        loss_a.push(oa.loss);
        loss_t.push(ot.loss);
        let m = ot.measured.expect("threaded backend reports measurements");
        // skip step 0: thread-pool warmup, allocator effects
        if s > 0 || steps == 1 {
            tail += 1;
            let b = ot.breakdown;
            sim_acc = Some(match sim_acc {
                None => b,
                Some(a) => Breakdown {
                    t_before_s: a.t_before_s + b.t_before_s,
                    t_comp_s: a.t_comp_s + b.t_comp_s,
                    t_compress_s: a.t_compress_s + b.t_compress_s,
                    t_comm_s: a.t_comm_s + b.t_comm_s,
                    t_comm_exposed_s: a.t_comm_exposed_s + b.t_comm_exposed_s,
                    bubble_s: a.bubble_s + b.bubble_s,
                    total_s: a.total_s + b.total_s,
                },
            });
            meas_acc = MeasuredBreakdown {
                comp_s: meas_acc.comp_s + m.comp_s,
                compress_s: meas_acc.compress_s + m.compress_s,
                comm_s: meas_acc.comm_s + m.comm_s,
                exposed_s: meas_acc.exposed_s + m.exposed_s,
                wall_s: meas_acc.wall_s + m.wall_s,
                moved_bytes: meas_acc.moved_bytes + m.moved_bytes,
                moved_inter_bytes: meas_acc.moved_inter_bytes + m.moved_inter_bytes,
            };
            wire_acc += ot.wire_bytes;
            wall_acc += ot.wall_s;
        }
    }
    bitwise &= eng_a.params() == eng_t.params();

    let inv = 1.0 / tail.max(1) as f64;
    let mut sim = sim_acc.unwrap_or(Breakdown {
        t_before_s: 0.0,
        t_comp_s: 0.0,
        t_compress_s: 0.0,
        t_comm_s: 0.0,
        t_comm_exposed_s: 0.0,
        bubble_s: 0.0,
        total_s: 0.0,
    });
    sim.t_before_s *= inv;
    sim.t_comp_s *= inv;
    sim.t_compress_s *= inv;
    sim.t_comm_s *= inv;
    sim.t_comm_exposed_s *= inv;
    sim.bubble_s *= inv;
    sim.total_s *= inv;
    let measured = MeasuredBreakdown {
        comp_s: meas_acc.comp_s * inv,
        compress_s: meas_acc.compress_s * inv,
        comm_s: meas_acc.comm_s * inv,
        exposed_s: meas_acc.exposed_s * inv,
        wall_s: meas_acc.wall_s * inv,
        moved_bytes: (meas_acc.moved_bytes as f64 * inv) as usize,
        moved_inter_bytes: (meas_acc.moved_inter_bytes as f64 * inv) as usize,
    };

    Ok(BackendComparison {
        scheme: base.scheme.label().to_string(),
        world: base.workers,
        steps,
        bitwise_equal: bitwise,
        loss_analytic: loss_a,
        loss_threaded: loss_t,
        sim,
        measured,
        wire_bytes: (wire_acc as f64 * inv) as usize,
        step_wall_s: wall_acc * inv,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SchemeKind;
    use crate::config::Optimizer;

    #[test]
    fn comparison_reports_parity_and_timings() {
        let cfg = RunConfig {
            workers: 2,
            scheme: SchemeKind::Baseline,
            optimizer: Optimizer::Sgd,
            lr: 0.05,
            seed: 9,
            bucket_bytes: 32 * 1024,
            ..RunConfig::default()
        };
        let c = compare_backends(&cfg, "tiny", 3).unwrap();
        assert!(c.bitwise_equal, "backends diverged: {:?} vs {:?}", c.loss_analytic, c.loss_threaded);
        assert_eq!(c.loss_analytic.len(), 3);
        assert!(c.measured.wall_s > 0.0);
        assert!(c.sim.total_s > 0.0);
        assert!(c.wire_bytes > 0);
    }
}
