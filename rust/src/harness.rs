//! Evaluation harness shared by the paper-table benches and examples:
//! per-scheme cost measurement (real compressor timings, extrapolated to
//! workload scale), analytic wire volumes, workload-level iteration
//! breakdowns averaged over a COVAP interval, and the machine-readable
//! `BENCH_*.json` emitter that gives the bench trajectory a stable format
//! to accumulate in CI.

use std::path::Path;

use anyhow::{Context, Result};

use crate::comm::Collective;
use crate::compress::{
    dense_frame_len, half_frame_len, k_of, sign_frame_len, sparse_frame_len, CollectiveOp,
    PowerSgd, SchemeKind,
};
use crate::util::json::Json;
use crate::coordinator::bucketize_layers;
use crate::covap::{shard_buckets, CoarseFilter};
use crate::network::{ClusterSpec, NetworkModel};
use crate::sim::{simulate_iteration_on, Breakdown, Policy, TensorCost};
use crate::util::bench::time_fn;
use crate::util::rng::Rng;
use crate::workload::Workload;

/// Per-element local compression cost of a scheme, measured on real data.
#[derive(Debug, Clone, Copy)]
pub struct CompressProfile {
    /// Seconds per gradient element (compress + decompress, per worker).
    pub s_per_elem: f64,
    /// Sample size the measurement used.
    pub sample_elems: usize,
}

/// Measure a scheme's per-element compression cost on `sample_elems`
/// synthetic gradients (N(0,1)), `iters` timed repetitions.
pub fn measure_compress(kind: &SchemeKind, sample_elems: usize, iters: usize) -> CompressProfile {
    let mut rng = Rng::seed(0xC0317);
    let g: Vec<f32> = (0..sample_elems).map(|_| rng.normal() as f32).collect();
    let refs: Vec<&[f32]> = vec![&g];
    let mut scheme = kind.build(1, 1);
    // One warm round (allocates EF state), then timed rounds. Steps advance
    // so COVAP alternates keep/drop realistically; we time the *kept* path
    // for COVAP by using interval 1 here and relying on wire math for drops.
    let mut step = 0u64;
    let stats = time_fn(1, iters, || {
        let (_, rec) = scheme.round(0, step, &refs);
        step += 1;
        rec.compress_s
    });
    CompressProfile { s_per_elem: stats.median_s / sample_elems as f64, sample_elems }
}

/// The paper's V100 anchor: FP16 compression of the whole VGG-19 gradient
/// (143.6 M elements) costs 5 ms (Table II) => 3.48e-11 s/elem on the
/// paper's hardware. Our testbed is a single CPU core, so raw measured
/// costs are ~100x larger; `calibrated_profiles` rescales every scheme by
/// the common CPU/GPU factor derived from the FP16 anchor, preserving the
/// *relative* costs between schemes that we actually measured. See
/// EXPERIMENTS.md "Calibration".
pub const V100_FP16_S_PER_ELEM: f64 = 5.0e-3 / 143_652_544.0;

/// Measure every scheme's compression cost and rescale to the V100
/// timescale via the FP16 anchor.
pub fn calibrated_profiles(
    kinds: &[SchemeKind],
    sample_elems: usize,
    iters: usize,
) -> Vec<(SchemeKind, CompressProfile)> {
    let fp16 = measure_compress(&SchemeKind::Fp16, sample_elems, iters);
    let scale = V100_FP16_S_PER_ELEM / fp16.s_per_elem;
    kinds
        .iter()
        .map(|k| {
            let mut p = match k {
                SchemeKind::Fp16 => fp16,
                _ => measure_compress(k, sample_elems, iters),
            };
            p.s_per_elem *= scale;
            // COVAP's filter decision is O(1) per tensor; its measured cost
            // is the EF pass, which the paper counts as ~zero because it
            // fuses into the optimizer kernel. We keep our measured EF cost
            // (scaled) — an honest upper bound that is still near-zero.
            (k.clone(), p)
        })
        .collect()
}

/// The paper's own measured compression overheads (Table II, VGG-19 whole
/// model = 143.65 M gradients) expressed per element — use these to replay
/// the paper's exact overhead regime in the figure benches (our native rust
/// compressors are faster than some of the paper's implementations, notably
/// Ok-topk's mpi4py version; see EXPERIMENTS.md).
pub fn paper_profile(kind: &SchemeKind) -> CompressProfile {
    const N: f64 = 143_652_544.0;
    let total_s = match kind {
        SchemeKind::Baseline => 0.0,
        // "close to zero" (§III.A); auto mode runs the same filter + EF pass
        SchemeKind::Covap { .. } | SchemeKind::CovapAuto { .. } => 0.002,
        SchemeKind::TopK { .. } => 1.560,
        SchemeKind::Dgc { .. } => 0.025,
        SchemeKind::RandomK { .. } => 0.200,
        SchemeKind::Fp16 => 0.005,
        SchemeKind::EfSignSgd => 0.020,
        SchemeKind::PowerSgd { .. } => 0.020,
        SchemeKind::OkTopk { .. } => 0.500,
    };
    CompressProfile { s_per_elem: total_s / N, sample_elems: 143_652_544 }
}

/// Wire bytes for one tensor of `n` elements under a scheme: the encoded
/// frame length of the payload the scheme's compressor emits — the codec's
/// own framing arithmetic (`Payload::encoded_len`), not a hand-maintained
/// size model. `wire_bytes_equal_encoded_representative_frames` pins this
/// against actually encoding representative payloads, so the benches price
/// the same measured sizes the executor moves.
pub fn wire_bytes(kind: &SchemeKind, n: usize) -> usize {
    match kind {
        SchemeKind::Baseline => dense_frame_len(n),
        // when kept; the filter is upstream (auto mode warms up dense)
        SchemeKind::Covap { .. } | SchemeKind::CovapAuto { .. } => dense_frame_len(n),
        SchemeKind::TopK { ratio }
        | SchemeKind::RandomK { ratio }
        | SchemeKind::OkTopk { ratio }
        | SchemeKind::Dgc { ratio } => sparse_frame_len(k_of(*ratio, n)),
        SchemeKind::Fp16 => half_frame_len(n),
        SchemeKind::EfSignSgd => sign_frame_len(n),
        SchemeKind::PowerSgd { rank } => PowerSgd::factor_frame_bytes(n, *rank),
    }
}

pub fn collective_of(kind: &SchemeKind) -> CollectiveOp {
    match kind {
        SchemeKind::TopK { .. }
        | SchemeKind::Dgc { .. }
        | SchemeKind::RandomK { .. }
        | SchemeKind::EfSignSgd => CollectiveOp::AllGather,
        _ => CollectiveOp::AllReduce,
    }
}

pub fn rounds_of(kind: &SchemeKind) -> (u32, u32, bool) {
    // (collective rounds, sync rounds, data dependency)
    match kind {
        // PowerSGD's two rounds are per-bucket dependent, but the DDP hook
        // still overlaps them with *other* buckets' computation (warm-start
        // Q breaks cross-bucket dependencies) -> overlappable, 2 rounds.
        SchemeKind::PowerSgd { .. } => (2, 0, false),
        // Ok-topk's split/threshold rendezvous sits on the compute path:
        // its communication cannot be overlapped (paper §IV.C.1).
        SchemeKind::OkTopk { .. } => (1, 2, true),
        _ => (1, 0, false),
    }
}

/// Bucket element counts for a workload: the paper's observed buckets when
/// available, otherwise the DDP bucketizer at 25 MiB.
pub fn workload_buckets(w: &Workload) -> Vec<usize> {
    w.paper_buckets.clone().unwrap_or_else(|| {
        bucketize_layers(
            &w.layers.iter().map(|l| (l.name.clone(), l.numel)).collect::<Vec<_>>(),
            25 * 1024 * 1024,
        )
        .iter()
        .map(|b| b.numel)
        .collect()
    })
}

/// Compute-time fraction of each bucket: layers are consumed in reverse
/// (gradient-ready) order into the bucket sizes; a bucket's weight is the
/// sum of its layers' `comp_weight`, proportionally split if a boundary
/// lands inside a layer (only with synthetic bucket sizes).
pub fn bucket_comp_fractions(w: &Workload, bucket_sizes: &[usize]) -> Vec<f64> {
    let total_w: f64 = w.layers.iter().map(|l| l.comp_weight).sum();
    let mut fracs = vec![0.0f64; bucket_sizes.len()];
    let rev: Vec<&crate::workload::LayerSpec> = w.layers.iter().rev().collect();
    let mut li = 0usize; // current layer
    let mut loff = 0usize; // elements of layer li already consumed
    for (b, &target) in bucket_sizes.iter().enumerate() {
        let mut need = target;
        while need > 0 && li < rev.len() {
            let l = rev[li];
            let avail = l.numel - loff;
            let take = avail.min(need);
            fracs[b] += l.comp_weight * take as f64 / l.numel.max(1) as f64;
            need -= take;
            loff += take;
            if loff == l.numel {
                li += 1;
                loff = 0;
            }
        }
    }
    // any residual layers (bucket list shorter than model) fold into last
    while li < rev.len() {
        let l = rev[li];
        let frac = (l.numel - loff) as f64 / l.numel.max(1) as f64;
        *fracs.last_mut().unwrap() += l.comp_weight * frac;
        li += 1;
        loff = 0;
    }
    if total_w > 0.0 {
        for f in &mut fracs {
            *f /= total_w;
        }
    }
    fracs
}

/// Simulated per-iteration breakdown of (workload, scheme) on a cluster
/// under the collective topology `topo` (pass
/// `TopologyKind::Auto.resolve(cluster)` for the pre-topology behavior).
///
/// For COVAP the breakdown is averaged over one full interval of steps
/// (different steps transmit different shards); other schemes are
/// step-invariant. `profile` supplies the measured compression cost.
/// Per-bucket computation time is FLOPs-weighted (`bucket_comp_fractions`),
/// and all shards of one bucket become ready together (the bucket's compute
/// is attached to its first shard).
pub fn scheme_breakdown(
    w: &Workload,
    kind: &SchemeKind,
    profile: &CompressProfile,
    net: &NetworkModel,
    cluster: ClusterSpec,
    topo: &dyn Collective,
    policy: Policy,
) -> Breakdown {
    let buckets = workload_buckets(w);
    let comp_fracs = bucket_comp_fractions(w, &buckets);
    let (rounds, sync_rounds, dep) = rounds_of(kind);

    // (numel, comp_s) per tensor; `keep` gates wire bytes per step.
    let build_costs = |tensors: &[(usize, f64)], keep: &dyn Fn(usize) -> bool| -> Vec<TensorCost> {
        tensors
            .iter()
            .enumerate()
            .map(|(i, &(n, comp_s))| TensorCost {
                comp_s,
                compress_s: profile.s_per_elem * n as f64,
                wire_bytes: if keep(i) { wire_bytes(kind, n) } else { 0 },
                collective: collective_of(kind),
                rounds,
                sync_rounds,
                data_dependency: dep,
            })
            .collect()
    };

    match kind {
        SchemeKind::Covap { interval, .. } => {
            // shard, then average the timeline over I consecutive steps;
            // a bucket's compute time rides on its first shard (all shards
            // of a bucket become ready at the same instant).
            let shards = shard_buckets(&buckets, *interval);
            let sizes: Vec<(usize, f64)> = shards
                .iter()
                .map(|s| {
                    let comp =
                        if s.offset == 0 { w.t_comp_s * comp_fracs[s.bucket] } else { 0.0 };
                    (s.len, comp)
                })
                .collect();
            let filter = CoarseFilter::new(*interval);
            let mut acc: Option<Breakdown> = None;
            for step in 0..*interval as u64 {
                let costs = build_costs(&sizes, &|i| filter.keep(i, step));
                let b =
                    simulate_iteration_on(topo, net, cluster, w.t_before_s, &costs, policy);
                acc = Some(match acc {
                    None => b,
                    Some(a) => Breakdown {
                        t_before_s: a.t_before_s,
                        t_comp_s: a.t_comp_s,
                        t_compress_s: a.t_compress_s + b.t_compress_s,
                        t_comm_s: a.t_comm_s + b.t_comm_s,
                        t_comm_exposed_s: a.t_comm_exposed_s + b.t_comm_exposed_s,
                        bubble_s: a.bubble_s + b.bubble_s,
                        total_s: a.total_s + b.total_s,
                    },
                });
            }
            let mut b = acc.unwrap();
            let inv = 1.0 / *interval as f64;
            b.t_compress_s *= inv;
            b.t_comm_s *= inv;
            b.t_comm_exposed_s *= inv;
            b.bubble_s *= inv;
            b.total_s *= inv;
            b
        }
        _ => {
            let tensors: Vec<(usize, f64)> = buckets
                .iter()
                .zip(comp_fracs.iter())
                .map(|(&n, &f)| (n, w.t_comp_s * f))
                .collect();
            let costs = build_costs(&tensors, &|_| true);
            simulate_iteration_on(topo, net, cluster, w.t_before_s, &costs, policy)
        }
    }
}

/// Memory footprint of aggregation per rank — the paper's "could not scale
/// beyond 16 GPUs: AllGather OOM" exclusion rule (§IV.D). GRACE-style
/// allgather aggregation decompresses every rank's payload to a dense
/// buffer before summing, so the per-rank footprint grows as
/// world * dense model bytes; allreduce stays at one dense buffer.
/// (VGG-19 at 32 ranks: 32 * 575 MB = 18 GB > 16 GB V100 — OOM, matching
/// the paper's Fig. 11b exclusions.)
pub fn allgather_rank_memory(kind: &SchemeKind, model_params: usize, world: usize) -> usize {
    match collective_of(kind) {
        CollectiveOp::AllGather => model_params * 4 * world,
        CollectiveOp::AllReduce => model_params * 4,
    }
}

/// Per-level wire bytes the *busiest* rank sends per step under
/// `(kind, topo)` on `cluster` (worst-rank maxima per level, like the
/// engine's record accounting and the measured aggregate — on a
/// multi-node flat ring the inter column is the node-boundary rank's
/// NIC): every bucket's frame priced by the codec arithmetic
/// ([`wire_bytes`]) and routed through the topology's hop schedule.
pub fn scheme_level_bytes(
    w: &Workload,
    kind: &SchemeKind,
    topo: &dyn Collective,
    cluster: ClusterSpec,
) -> crate::comm::LevelBytes {
    let hops = topo.allgather_schedule(cluster).max_level_hops();
    let mut out = crate::comm::LevelBytes::default();
    for n in workload_buckets(w) {
        let b = wire_bytes(kind, n);
        out.intra += hops.intra * b;
        out.inter += hops.inter * b;
    }
    out
}

/// One row of a `BENCH_*.json` artifact: a (scheme, world, policy) cell
/// with measured and simulated timings side by side. Fields that a bench
/// cannot fill (e.g. measured columns on a sim-only bench) stay NaN/0 and
/// serialize as null/0.
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub scheme: String,
    pub world: usize,
    pub policy: String,
    /// Measured step wall time (threaded executor), seconds.
    pub measured_wall_s: f64,
    /// Simulated step wall time (timeline simulator), seconds.
    pub sim_wall_s: f64,
    /// Measured exposed communication (T_comm'), seconds.
    pub measured_exposed_s: f64,
    /// Simulated exposed communication, seconds.
    pub sim_exposed_s: f64,
    /// Accounting wire bytes per rank per step (encoded frame lengths).
    pub wire_bytes: usize,
    /// Measured ring traffic per step: bytes of serialized frames the
    /// worst rank actually moved (threaded backend; 0 on sim-only rows).
    pub moved_bytes: usize,
    /// Whether the threaded backend matched the analytic one bitwise.
    pub bitwise_equal: Option<bool>,
}

fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::from(x)
    } else {
        Json::Null
    }
}

/// Version of the `BENCH_*.json` envelope. Bump when the envelope shape
/// (not a bench's row shape) changes, so the cross-run diff tooling the
/// ROADMAP item-3 barometer builds on can refuse to compare apples to
/// pears. v2 introduced the `meta` block itself.
pub const BENCH_SCHEMA_VERSION: u64 = 2;

/// The shared meta block every `BENCH_*.json` carries: schema version,
/// a caller-supplied ISO-8601 timestamp (benches pass
/// [`iso_timestamp_now`]; deterministic tests pass a fixed string), and
/// the scheme/topology/backend configuration the run priced — enough to
/// decide whether two artifacts from different runs are comparable.
#[derive(Debug, Clone, Default)]
pub struct BenchMeta {
    /// ISO-8601 UTC timestamp, supplied by the caller.
    pub timestamp: String,
    /// Scheme spec (`covap@auto`, `baseline`, or a sweep label).
    pub scheme: String,
    /// Collective topology (`ring`, `hier`, `tree`, `auto`, or a label).
    pub topology: String,
    /// Execution backend (`analytic`, `threaded`, `both`, ...).
    pub backend: String,
}

impl BenchMeta {
    /// A meta block with the given timestamp; fill the config fields
    /// with the builder-style setters.
    pub fn new(timestamp: impl Into<String>) -> BenchMeta {
        BenchMeta { timestamp: timestamp.into(), ..BenchMeta::default() }
    }

    pub fn scheme(mut self, s: impl Into<String>) -> BenchMeta {
        self.scheme = s.into();
        self
    }

    pub fn topology(mut self, t: impl Into<String>) -> BenchMeta {
        self.topology = t.into();
        self
    }

    pub fn backend(mut self, b: impl Into<String>) -> BenchMeta {
        self.backend = b.into();
        self
    }

    /// Meta block describing one `RunConfig`'s scheme/topology/backend.
    pub fn from_config(timestamp: impl Into<String>, cfg: &crate::config::RunConfig) -> BenchMeta {
        BenchMeta {
            timestamp: timestamp.into(),
            scheme: cfg.scheme.spec(),
            topology: cfg.topology.spec().to_string(),
            backend: cfg.backend.label().to_string(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::from(BENCH_SCHEMA_VERSION as usize)),
            ("timestamp", Json::from(self.timestamp.as_str())),
            ("scheme", Json::from(self.scheme.as_str())),
            ("topology", Json::from(self.topology.as_str())),
            ("backend", Json::from(self.backend.as_str())),
        ])
    }
}

/// Current wall time as an ISO-8601 UTC string (`2026-08-07T12:34:56Z`),
/// dependency-free (civil-from-days arithmetic). Benches pass this into
/// [`BenchMeta`]; anything that must stay bitwise-reproducible passes a
/// fixed string instead.
pub fn iso_timestamp_now() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (days, rem) = (secs / 86_400, secs % 86_400);
    let (h, m, s) = (rem / 3600, (rem % 3600) / 60, rem % 60);
    // civil-from-days (Howard Hinnant's algorithm), valid for the unix era
    let z = days as i64 + 719_468;
    let era = z / 146_097;
    let doe = z - era * 146_097;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let mth = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if mth <= 2 { y + 1 } else { y };
    format!("{y:04}-{mth:02}-{d:02}T{h:02}:{m:02}:{s:02}Z")
}

/// Write a `BENCH_<name>.json` artifact with caller-shaped rows — the
/// generic form of [`write_bench_json`] for benches whose rows are not
/// (scheme, world, policy) cells (e.g. `perf_hotpath`'s throughput +
/// allocation counts). Stable envelope:
/// `{"bench": ..., "meta": {...}, "metrics": {...}, "rows": [..]}` where
/// `"meta"` is the shared [`BenchMeta`] block (schema version, caller
/// timestamp, scheme/topology/backend) that makes artifacts diffable
/// across runs, and `"metrics"` is a snapshot of the process-wide obs
/// registry (DESIGN.md §10) — counters, gauges and p50/p95/p99
/// histograms stamped by everything that ran in this process before the
/// write.
pub fn write_bench_doc(path: &Path, bench: &str, meta: &BenchMeta, rows: Vec<Json>) -> Result<()> {
    let doc = Json::obj(vec![
        ("bench", Json::from(bench)),
        ("meta", meta.to_json()),
        ("metrics", crate::obs::registry::global_snapshot()),
        ("rows", Json::Arr(rows)),
    ]);
    std::fs::write(path, format!("{doc}\n"))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Write `BENCH_<name>.json` next to `dir` (typically the repo root): a
/// stable, machine-readable artifact CI uploads so the bench trajectory
/// accumulates across PRs.
pub fn write_bench_json(
    path: &Path,
    bench: &str,
    meta: &BenchMeta,
    rows: &[BenchRow],
) -> Result<()> {
    let rows_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("scheme", Json::from(r.scheme.as_str())),
                ("world", Json::from(r.world)),
                ("policy", Json::from(r.policy.as_str())),
                ("measured_wall_s", num_or_null(r.measured_wall_s)),
                ("sim_wall_s", num_or_null(r.sim_wall_s)),
                ("measured_exposed_s", num_or_null(r.measured_exposed_s)),
                ("sim_exposed_s", num_or_null(r.sim_exposed_s)),
                ("wire_bytes", Json::from(r.wire_bytes)),
                ("moved_bytes", Json::from(r.moved_bytes)),
                (
                    "bitwise_equal",
                    match r.bitwise_equal {
                        Some(b) => Json::from(b),
                        None => Json::Null,
                    },
                ),
            ])
        })
        .collect();
    write_bench_doc(path, bench, meta, rows_json)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    fn prof() -> CompressProfile {
        CompressProfile { s_per_elem: 1e-9, sample_elems: 1 << 20 }
    }

    #[test]
    fn wire_bytes_shapes() {
        let n = 1_000_000;
        assert_eq!(wire_bytes(&SchemeKind::Baseline, n), dense_frame_len(n));
        assert_eq!(wire_bytes(&SchemeKind::Fp16, n), half_frame_len(n));
        assert_eq!(
            wire_bytes(&SchemeKind::TopK { ratio: 0.01 }, n),
            sparse_frame_len(10_000)
        );
        assert_eq!(wire_bytes(&SchemeKind::EfSignSgd, n), sign_frame_len(n));
        assert!(wire_bytes(&SchemeKind::PowerSgd { rank: 1 }, n) < 20_000);
    }

    /// The size "model" is the codec itself: for every deterministic-size
    /// scheme, `wire_bytes(kind, n)` equals the byte length of actually
    /// encoding the payload a rank compressor emits on a representative
    /// gradient. Variable-size schemes (DGC's over-selection, Ok-topk's
    /// stale thresholds) are bounded by their caps.
    #[test]
    fn wire_bytes_equal_encoded_representative_frames() {
        use crate::compress::build_rank_pair;
        let mut rng = Rng::seed(0xF7A);
        for n in [64usize, 1000, 4097] {
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            for kind in SchemeKind::evaluation_set() {
                let expect = wire_bytes(&kind, n);
                match kind {
                    SchemeKind::Dgc { ratio } => {
                        let (mut c, _) = build_rank_pair(&kind, 1, 1);
                        let frame = c.compress(0, 0, &g).encode().len();
                        let cap = sparse_frame_len(2 * k_of(ratio, n));
                        assert!(frame <= cap, "DGC n={n}: frame {frame} > cap {cap}");
                    }
                    SchemeKind::OkTopk { ratio } => {
                        let refs: Vec<&[f32]> = vec![&g];
                        let (_, rec) = kind.build(1, 1).round(0, 0, &refs);
                        let cap = sparse_frame_len(2 * k_of(ratio, n));
                        assert!(rec.wire_bytes <= cap, "Ok-topk n={n}");
                    }
                    SchemeKind::PowerSgd { .. } => {
                        let refs: Vec<&[f32]> = vec![&g];
                        let (_, rec) = kind.build(1, 1).round(0, 0, &refs);
                        assert_eq!(expect, rec.wire_bytes, "PowerSGD n={n}");
                    }
                    _ => {
                        let (mut c, _) = build_rank_pair(&kind, 1, 1);
                        let frame = c.compress(0, 0, &g).encode().len();
                        assert_eq!(expect, frame, "{} n={n}", kind.label());
                    }
                }
            }
        }
    }

    /// Regression pin: the codec's framing must not drift the old Table II
    /// compression ratios (dense 4n / scheme bytes) at bucket scale.
    #[test]
    fn table2_wire_ratio_regression() {
        let n = 25 * 1024 * 1024 / 4; // one 25 MiB DDP bucket of f32s
        let dense = 4.0 * n as f64;
        let cases: [(SchemeKind, f64); 5] = [
            (SchemeKind::Baseline, 1.0),
            (SchemeKind::Fp16, 2.0),
            (SchemeKind::TopK { ratio: 0.01 }, 50.0),
            (SchemeKind::Dgc { ratio: 0.001 }, 500.0),
            (SchemeKind::EfSignSgd, 32.0),
        ];
        for (kind, want) in cases {
            let ratio = dense / wire_bytes(&kind, n) as f64;
            assert!(
                (ratio / want - 1.0).abs() < 1e-3,
                "{}: compression ratio {ratio:.3} drifted from {want}",
                kind.label()
            );
        }
    }

    #[test]
    fn covap_breakdown_faster_than_baseline() {
        let w = workload::vgg19();
        let net = NetworkModel::default();
        let c = ClusterSpec::ecs(64);
        let topo = crate::comm::TopologyKind::Auto.resolve(c);
        let base =
            scheme_breakdown(&w, &SchemeKind::Baseline, &prof(), &net, c, topo, Policy::Overlap);
        let covap = scheme_breakdown(
            &w,
            &SchemeKind::Covap { interval: 4, ef: Default::default() },
            &prof(),
            &net,
            c,
            topo,
            Policy::Overlap,
        );
        assert!(covap.total_s < base.total_s * 0.6, "{} vs {}", covap.total_s, base.total_s);
        assert!(covap.speedup(64) > 40.0, "covap speedup {}", covap.speedup(64));
    }

    #[test]
    fn covap_interval_matches_ccr_saturation() {
        // Fig. 5 shape: speedup rises until I = ceil(CCR), then flattens.
        let w = workload::vgg19(); // CCR ~ 4
        let net = NetworkModel::default();
        let c = ClusterSpec::ecs(64);
        let speedup_at = |i: usize| {
            scheme_breakdown(
                &w,
                &SchemeKind::Covap { interval: i, ef: Default::default() },
                &prof(),
                &net,
                c,
                crate::comm::TopologyKind::Auto.resolve(c),
                Policy::Overlap,
            )
            .speedup(64)
        };
        let s2 = speedup_at(2);
        let s4 = speedup_at(4);
        let s8 = speedup_at(8);
        assert!(s4 > s2 * 1.15, "rising region: {s2} -> {s4}");
        assert!(s8 < s4 * 1.10, "saturation: {s4} -> {s8}");
    }

    #[test]
    fn bench_json_roundtrips() {
        let dir = std::env::temp_dir().join("covap_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let rows = vec![BenchRow {
            scheme: "COVAP".into(),
            world: 4,
            policy: "overlap".into(),
            measured_wall_s: 0.01,
            sim_wall_s: 0.02,
            measured_exposed_s: 0.001,
            sim_exposed_s: f64::NAN, // -> null
            wire_bytes: 1234,
            moved_bytes: 5678,
            bitwise_equal: Some(true),
        }];
        let meta = BenchMeta::new("2026-01-02T03:04:05Z")
            .scheme("covap@4")
            .topology("ring")
            .backend("both");
        write_bench_json(&path, "test", &meta, &rows).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str().unwrap(), "test");
        // Shared meta block: schema version + caller timestamp + config
        // labels, identical shape in every artifact.
        let m = j.get("meta").unwrap();
        assert_eq!(
            m.get("schema_version").unwrap().as_usize().unwrap(),
            BENCH_SCHEMA_VERSION as usize
        );
        assert_eq!(m.get("timestamp").unwrap().as_str().unwrap(), "2026-01-02T03:04:05Z");
        assert_eq!(m.get("scheme").unwrap().as_str().unwrap(), "covap@4");
        assert_eq!(m.get("topology").unwrap().as_str().unwrap(), "ring");
        assert_eq!(m.get("backend").unwrap().as_str().unwrap(), "both");
        // Envelope embeds the obs registry snapshot (DESIGN.md §10).
        let metrics = j.get("metrics").unwrap();
        assert!(metrics.get("counters").is_ok());
        assert!(metrics.get("gauges").is_ok());
        assert!(metrics.get("histograms").is_ok());
        let arr = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("world").unwrap().as_usize().unwrap(), 4);
        assert_eq!(arr[0].get("moved_bytes").unwrap().as_usize().unwrap(), 5678);
        assert_eq!(arr[0].get("sim_exposed_s").unwrap(), &Json::Null);
    }

    #[test]
    fn iso_timestamp_shape_and_config_meta() {
        let ts = iso_timestamp_now();
        // 2026-08-07T12:34:56Z: fixed width, date/time separators in place
        assert_eq!(ts.len(), 20, "{ts}");
        assert_eq!(&ts[4..5], "-");
        assert_eq!(&ts[7..8], "-");
        assert_eq!(&ts[10..11], "T");
        assert_eq!(&ts[13..14], ":");
        assert_eq!(&ts[16..17], ":");
        assert!(ts.ends_with('Z'));
        assert!(ts.starts_with("20"), "unix-era year: {ts}");
        let cfg = crate::config::RunConfig::default();
        let m = BenchMeta::from_config("2026-01-01T00:00:00Z", &cfg);
        assert_eq!(m.scheme, cfg.scheme.spec());
        assert_eq!(m.backend, "analytic");
        assert_eq!(m.topology, cfg.topology.spec());
    }

    #[test]
    fn allgather_memory_blows_up_with_world() {
        let k = SchemeKind::TopK { ratio: 0.01 };
        let m16 = allgather_rank_memory(&k, 143_652_544, 16);
        let m64 = allgather_rank_memory(&k, 143_652_544, 64);
        assert_eq!(m64, 4 * m16);
    }
}
