//! COVAP — reproduction of "Near-Linear Scaling Data Parallel Training with
//! Overlapping-Aware Gradient Compression" (Meng, Sun & Li, 2023).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — the coordinator: data-parallel orchestration,
//!   gradient bucketing, overlapping engine, the COVAP compression scheme and
//!   all baseline GC schemes, collectives, network timing models, the
//!   distributed profiler and the discrete-event timeline simulator.
//! * **L2/L1 (python, build-time only)** — the transformer model (JAX) and
//!   the Pallas kernels, AOT-lowered to HLO-text artifacts which this crate
//!   loads and executes through the PJRT CPU client (`runtime`).
//!
//! Python never runs on the training path: `make artifacts` emits
//! `artifacts/<preset>/*.hlo.txt` + `manifest.json` once, and the rust binary
//! is self-contained afterwards. Without the `pjrt` cargo feature the
//! synthetic-gradient backend (`runtime::synthetic`) stands in for the
//! artifacts, so every path below builds and runs everywhere.
//!
//! Execution backends (`config::ExecBackend`): the *analytic* path runs
//! workers in lockstep and predicts the overlap timeline with the
//! discrete-event simulator (`sim`); the *threaded* path (`exec`) runs P
//! ranks on real OS threads with ring collectives over channels and
//! measures it. Both are numerically bit-identical; `benches/exec_vs_sim`
//! cross-validates their timings.

// The paper-faithful numeric kernels favor explicit index loops that
// mirror the equations; keep clippy's style lints from fighting that.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::field_reassign_with_default,
    clippy::type_complexity
)]

pub mod analysis;
pub mod comm;
pub mod compress;
pub mod harness;
pub mod config;
pub mod coordinator;
pub mod covap;
pub mod data;
pub mod exec;
pub mod metrics;
pub mod network;
pub mod obs;
pub mod profiler;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod trainer;
pub mod util;
pub mod workload;

pub use anyhow::{anyhow, bail, Context, Result};
