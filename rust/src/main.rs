//! covap — leader CLI.
//!
//! Subcommands:
//!   smoke     [--artifacts DIR]                 artifact round-trip check
//!   train     [--artifacts DIR] [--workers N] [--scheme S | --interval I]
//!             [--steps N] [--lr F] [--optimizer sgd|adam] [--seed N]
//!             [--bucket-mb F] [--profile-steps N] [--metrics-csv PATH]
//!             [--gpus N] [--bandwidth-gbps F] [--config FILE]
//!   profile   [--artifacts DIR] [--workers N] [--steps N]
//!             measure CCR with the distributed profiler, print chosen I
//!   simulate  [--dnn NAME] [--gpus N] [--bandwidth-gbps F]
//!             one-iteration timeline breakdown for a paper workload
//!   exec      [--workers N] [--scheme S] [--steps N] [--pace-gbps F]
//!             [--synth-work N] [--preset tiny|small]
//!             run the threaded rank executor against the analytic
//!             backend: bitwise parity check + measured-vs-simulated
//!             breakdown for both policies
//!   schemes   list available GC schemes
//!   verify-schedules  [--json PATH]
//!             statically verify every collective topology's hop schedule
//!             (deadlock-freedom, exactly-once delivery, strictly-earlier
//!             sourcing, bounded in-flight frames, wire-byte conservation)
//!             over cluster shapes up to P=1024 — including the evolved
//!             post-membership-event shapes the elastic engine rebuilds
//!             onto; writes a bench doc
//!   check-protocol  [--min-world N] [--max-world N] [--steps N]
//!             [--max-states N] [--json PATH]
//!             exhaustively model-check the elastic membership protocol
//!             (DESIGN.md §13): BFS over every interleaving of scheduled
//!             and detected fail/join/leave events, proving EF-mass
//!             conservation, exactly-once export, FIFO reconfigure/export
//!             ordering, uniform torn-step skipping and deadlock-free
//!             quiescence on the production transition functions, then
//!             run the seeded-mutant self-test; writes a bench doc
//!   serve     [--jobs FILE] [--backend analytic|threaded] [--quick]
//!             [--json PATH]
//!             run the multi-tenant training service (DESIGN.md §14):
//!             jobs from a `jobs.json` trace (or the built-in scripted
//!             4-job demo) are queued, gang-scheduled onto the shared
//!             cluster, and stepped on a virtual clock while the
//!             contention model splits the inter-node fabric among
//!             overlapping tenants; prints per-job time-to-solution,
//!             queue wait and tail step latency, errors if any job
//!             starves, and optionally writes a bench doc. Trace format:
//!             {"cluster": {"nodes": N, "gpus_per_node": G},
//!              "nic_gbps": F, "jobs": [{"name": S, "scheme": S,
//!              "workers": N, "nodes": N, "priority": N, "arrival_s": F,
//!              "steps": N, "elastic": B, "backend": S}, ...]}
//!
//! train also accepts --backend analytic|threaded, --policy overlap|seq,
//! --topology ring|hier|tree|auto (collective topology: flat ring,
//! hierarchical 2-level, binomial tree, or pick by cluster shape),
//! --pace-gbps F and --synth-work N (see config). Adaptive COVAP is
//! `--scheme covap@auto`: profiling (`--profile-steps`) selects
//! I = ceil(CCR) and a windowed controller (`--profile-window`,
//! `--profile-hysteresis`) keeps re-selecting as CCR drifts; with any
//! other scheme, profiling only reports — nothing is swapped. Drift
//! scenarios: `--pace-schedule step:gbps,...` (mid-run bandwidth change)
//! and `--straggler rank:factor[:from[:until]],...` (per-rank skew).
//!
//! Observability (DESIGN.md §10): `--trace-out PATH` writes a
//! Perfetto-loadable trace.json (measured per-rank spans + the predicted
//! analytic timeline, barrier/pacer/controller instants, wire-byte
//! counters); `--log-level off|error|warn|info|debug` (or the COVAP_LOG
//! env var) gates the stderr diagnostics.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use covap::compress::SchemeKind;
use covap::config::RunConfig;
use covap::coordinator::DpEngine;
use covap::network::{ClusterSpec, NetworkModel};
use covap::runtime::{ModelArtifacts, Runtime};
use covap::sim::{dense_tensors, simulate_iteration, Policy};
use covap::util::cli::Args;
use covap::util::fmt_secs;
use covap::{trainer, workload};

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(raw)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("smoke") => smoke(&args),
        Some("train") => train(&args),
        Some("profile") => profile(&args),
        Some("simulate") => simulate(&args),
        Some("exec") => exec_cmd(&args),
        Some("verify-schedules") => verify_schedules(&args),
        Some("check-protocol") => check_protocol(&args),
        Some("serve") => serve(&args),
        Some("schemes") => {
            for k in SchemeKind::evaluation_set() {
                println!("{}", k.label());
            }
            Ok(())
        }
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand '{o}'");
            }
            eprintln!(
                "usage: covap <smoke|train|profile|simulate|exec|serve|verify-schedules|check-protocol|schemes> [flags]"
            );
            std::process::exit(2);
        }
    }
}

fn config_from(args: &Args) -> Result<RunConfig> {
    let path = args.get("config").map(PathBuf::from);
    let cfg = RunConfig::load(path.as_deref(), args)?;
    if let Some(lv) = cfg.log_level {
        covap::obs::log::set_level(lv);
    }
    Ok(cfg)
}

fn smoke(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts/tiny");
    let rt = Runtime::cpu()?;
    println!("platform = {}", rt.platform());
    let arts = ModelArtifacts::load(&rt, Path::new(&dir))?;
    let m = &arts.manifest;
    println!("preset = {}  params = {}", m.preset, m.param_count);
    let cfg = RunConfig {
        artifacts: PathBuf::from(&dir),
        workers: 2,
        steps: 2,
        ..RunConfig::default()
    };
    let mut engine = DpEngine::new(cfg, arts)?;
    let out = engine.step()?;
    anyhow::ensure!(out.loss.is_finite());
    println!("step 0: loss = {:.4}  sim = {}", out.loss, fmt_secs(out.breakdown.total_s));
    println!("smoke OK");
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    println!(
        "train: {} | {} workers | cluster {}x{} | scheme {} | {} steps",
        cfg.artifacts.display(),
        cfg.workers,
        cfg.cluster.nodes,
        cfg.cluster.gpus_per_node,
        cfg.scheme.label(),
        cfg.steps
    );
    let report = trainer::train(cfg, true)?;
    let s = report.metrics.summary();
    println!(
        "done: final loss {:.4} | mean last-10 {:.4} | sim total {} | wall total {} | mean speedup {:.2}x",
        s.final_loss,
        s.mean_loss_last10,
        fmt_secs(s.total_sim_s),
        fmt_secs(s.total_wall_s),
        report.mean_speedup,
    );
    if let Some(i) = report.chosen_interval {
        println!("adaptive interval chosen: {i}");
    }
    Ok(())
}

fn profile(args: &Args) -> Result<()> {
    let mut cfg = config_from(args)?;
    let steps = args.get_parsed("steps", 3u64)?;
    cfg.profile_steps = steps;
    cfg.steps = steps;
    cfg.scheme = SchemeKind::Baseline;
    let rt = Runtime::cpu()?;
    let arts = ModelArtifacts::load(&rt, &cfg.artifacts)?;
    let mut engine = DpEngine::new(cfg, arts)?;
    for _ in 0..steps {
        engine.step()?;
    }
    let r = engine.profile_report();
    println!("distributed profiler ({steps} iterations):");
    println!("  T_comp        = {}", fmt_secs(r.comp_s));
    println!("  T_comm naive  = {}  (includes rendezvous wait)", fmt_secs(r.naive_comm_s));
    println!("  T_comm aligned= {}", fmt_secs(r.aligned_comm_s));
    println!("  CCR naive     = {:.2}", r.naive_ccr);
    println!("  CCR aligned   = {:.2}", r.ccr);
    println!("  interval I    = {}", covap::covap::interval_from_ccr(r.ccr));
    Ok(())
}

fn exec_cmd(args: &Args) -> Result<()> {
    use covap::exec::compare_backends;
    use covap::util::bench::Table;

    let workers: usize = args.get_parsed("workers", 4usize)?;
    let steps: u64 = args.get_parsed("steps", 4u64)?;
    let preset = args.get_or("preset", "tiny");
    let scheme = SchemeKind::parse(&args.get_or("scheme", "covap"))
        .ok_or_else(|| anyhow::anyhow!("unknown scheme spec (e.g. covap, topk@0.05)"))?;
    let mut cfg = RunConfig {
        workers,
        scheme,
        ..RunConfig::default()
    };
    cfg.pace_gbps = args.get_parsed("pace-gbps", 1.0)?;
    cfg.synth_work = args.get_parsed("synth-work", 6u32)?;
    cfg.bucket_bytes = 16 * 1024;
    cfg.optimizer = covap::config::Optimizer::Sgd;

    let mut t = Table::new(&[
        "policy", "bitwise", "meas wall", "sim wall", "meas exp'", "sim exp'",
    ]);
    for policy in [Policy::Overlap, Policy::Sequential] {
        let mut c = cfg.clone();
        c.policy = policy;
        let r = compare_backends(&c, &preset, steps)?;
        t.row(&[
            format!("{policy:?}"),
            if r.bitwise_equal { "yes".into() } else { "NO".into() },
            fmt_secs(r.measured.wall_s),
            fmt_secs(r.sim.total_s),
            fmt_secs(r.measured.exposed_s),
            fmt_secs(r.sim.t_comm_exposed_s),
        ]);
    }
    t.print(&format!(
        "{} on {} threaded ranks (paced {} Gbps) — measured vs simulated",
        cfg.scheme.label(),
        workers,
        cfg.pace_gbps
    ));
    Ok(())
}

/// Statically verify every topology's hop schedule over a sweep of cluster
/// shapes — no executor, no threads, pure schedule analysis (DESIGN.md
/// §11). For each (topology, shape): prove deadlock-freedom, exactly-once
/// slot delivery, strictly-earlier-round sourcing and the bounded
/// in-flight-frame invariant via `analysis::verify_schedule`, then check
/// wire-byte conservation against the codec arithmetic for every scheme in
/// the evaluation set. Emits one bench-doc row per (topology, shape).
fn verify_schedules(args: &Args) -> Result<()> {
    use covap::analysis::{verify_frame_lengths, verify_schedule, wire_conservation};
    use covap::comm::{Collective as _, TopologyKind};
    use covap::coordinator::membership::{next_cluster, MembershipAction};
    use covap::util::json::Json;

    let t0 = std::time::Instant::now();
    // (nodes, gpus_per_node): degenerates (p=1, nodes=1, g=1), ragged
    // shapes, and ECS-like scale points up to P = 1024.
    let shapes: &[(usize, usize)] = &[
        (1, 1),
        (1, 2),
        (2, 1),
        (1, 8),
        (8, 1),
        (2, 2),
        (3, 2),
        (2, 3),
        (5, 3),
        (4, 8),
        (3, 7),
        (16, 8),
        (32, 8),
        (64, 8),
        (128, 8),
        (1024, 1),
        (1, 64),
    ];
    const TENSOR_NUMEL: usize = 4096;
    let mut rows: Vec<Json> = Vec::new();
    let mut checked = 0usize;
    let mut max_world = 0usize;
    let mut evolved_checked = 0usize;
    for kind in TopologyKind::all() {
        for &(nodes, g) in shapes {
            // the static shape, then every shape the elastic engine can
            // rebuild onto after one membership event — re-derived
            // through the same `next_cluster` rule `apply_membership`
            // uses, so the generation-mixed worlds PR 8 builds are
            // certified before any rank thread is spawned onto them
            let p0 = ClusterSpec::new(nodes, g).world();
            let mut variants: Vec<(usize, usize, String)> =
                vec![(nodes, g, String::new())];
            let events = [
                MembershipAction::Fail { rank: 0 },
                MembershipAction::Leave { rank: p0.saturating_sub(1) },
                MembershipAction::Join { count: 1 },
            ];
            for action in events {
                let evolved = action.next_world(p0);
                if evolved == 0 || evolved == p0 {
                    continue; // event would empty (or not change) this world
                }
                let (n2, g2) = next_cluster(evolved, g);
                variants.push((n2, g2, action.spec()));
            }
            for (vn, vg, event) in variants {
                let c = ClusterSpec::new(vn, vg);
                let p = c.world();
                let topo = kind.resolve(c);
                let sched = topo.allgather_schedule(c);
                let report = verify_schedule(&sched).map_err(|v| {
                    anyhow::anyhow!("{} on {vn}x{vg}: INVALID schedule: {v}", topo.name())
                })?;
                let mut wire_total = 0usize;
                for scheme in SchemeKind::evaluation_set() {
                    let len = covap::harness::wire_bytes(&scheme, TENSOR_NUMEL);
                    let lens = vec![len; p];
                    verify_frame_lengths(&scheme, TENSOR_NUMEL, &lens).map_err(|v| {
                        anyhow::anyhow!("{}: frame-length check failed: {v}", scheme.label())
                    })?;
                    let wire = wire_conservation(&sched, &lens).map_err(|v| {
                        anyhow::anyhow!(
                            "{} on {vn}x{vg} ({}): wire conservation failed: {v}",
                            topo.name(),
                            scheme.label()
                        )
                    })?;
                    wire_total = wire_total.max(wire.total_sent);
                }
                if !event.is_empty() {
                    evolved_checked += 1;
                }
                rows.push(Json::obj(vec![
                    ("topology", Json::Str(topo.name().to_string())),
                    ("nodes", Json::Num(vn as f64)),
                    ("gpus_per_node", Json::Num(vg as f64)),
                    ("event", Json::Str(event)),
                    ("world", Json::Num(p as f64)),
                    ("hops", Json::Num(report.hops as f64)),
                    ("rounds", Json::Num(report.rounds as f64)),
                    ("max_recv", Json::Num(report.max_recv as f64)),
                    ("max_in_flight", Json::Num(report.max_in_flight as f64)),
                    ("epoch_skew", Json::Num(report.epoch_skew as f64)),
                    ("wire_total_sent", Json::Num(wire_total as f64)),
                    ("verify_s", Json::Num(t0.elapsed().as_secs_f64())),
                ]));
                checked += 1;
                max_world = max_world.max(p);
            }
        }
    }
    let out = args.get_or("json", "BENCH_schedule_verify.json");
    let meta = covap::harness::BenchMeta::new(covap::harness::iso_timestamp_now())
        .scheme("evaluation-set")
        .topology("all")
        .backend("static");
    covap::harness::write_bench_doc(Path::new(&out), "schedule_verify", &meta, rows)?;
    println!(
        "verify-schedules: {} topology x shape combinations OK ({} post-membership-event shapes, max P = {}) in {}",
        checked,
        evolved_checked,
        max_world,
        fmt_secs(t0.elapsed().as_secs_f64())
    );
    println!("wrote {out}");
    Ok(())
}

/// Exhaustively model-check the elastic membership protocol (DESIGN.md
/// §13): for every world size in `[--min-world, --max-world]`, explore
/// every interleaving of the auto-enumerated scheduled + detected
/// fail/join/leave scripts over the production transition functions,
/// then run the seeded-mutant self-test that proves each invariant
/// would fire. Emits one bench-doc row per world plus one per mutant;
/// the final summary row carries the CI state-count budget gate.
fn check_protocol(args: &Args) -> Result<()> {
    use covap::analysis::{check_world, run_self_test, Bounds, Transitions};
    use covap::util::json::Json;

    let t0 = std::time::Instant::now();
    let min_world: usize = args.get_parsed("min-world", 2usize)?;
    let max_world: usize = args.get_parsed("max-world", 5usize)?;
    let steps: u8 = args.get_parsed("steps", 2u8)?;
    let max_states: usize = args.get_parsed("max-states", 500_000usize)?;
    if min_world < 2 || max_world < min_world {
        bail!("check-protocol: need 2 <= --min-world <= --max-world");
    }
    let bounds = Bounds { max_states };
    let real = Transitions::real();
    let mut rows: Vec<Json> = Vec::new();
    let mut total_states = 0usize;
    let mut total_scripts = 0usize;
    let mut total_transitions = 0usize;
    let mut max_depth = 0usize;
    for world in min_world..=max_world {
        let rep = check_world(world, steps, &real, &bounds).map_err(|(label, v)| {
            anyhow::anyhow!("protocol violation [{}] in script {label}: {v}", v.kind())
        })?;
        println!(
            "world {world}: {} scripts, {} states, {} transitions, depth {}, {} terminals",
            rep.scripts, rep.states, rep.transitions, rep.max_depth, rep.terminals
        );
        rows.push(Json::obj(vec![
            ("world", Json::Num(world as f64)),
            ("steps", Json::Num(steps as f64)),
            ("scripts", Json::Num(rep.scripts as f64)),
            ("states", Json::Num(rep.states as f64)),
            ("transitions", Json::Num(rep.transitions as f64)),
            ("max_depth", Json::Num(rep.max_depth as f64)),
            ("terminals", Json::Num(rep.terminals as f64)),
        ]));
        total_states += rep.states;
        total_scripts += rep.scripts;
        total_transitions += rep.transitions;
        max_depth = max_depth.max(rep.max_depth);
    }
    let caught = run_self_test(&bounds)
        .map_err(|e| anyhow::anyhow!("seeded-mutant self-test FAILED: {e}"))?;
    for &(name, kind) in &caught {
        rows.push(Json::obj(vec![
            ("mutant", Json::Str(name.to_string())),
            ("caught_as", Json::Str(kind.to_string())),
        ]));
    }
    rows.push(Json::obj(vec![
        ("summary", Json::Num(1.0)),
        ("total_states", Json::Num(total_states as f64)),
        ("total_scripts", Json::Num(total_scripts as f64)),
        ("total_transitions", Json::Num(total_transitions as f64)),
        ("max_depth", Json::Num(max_depth as f64)),
        ("mutants_caught", Json::Num(caught.len() as f64)),
        ("check_s", Json::Num(t0.elapsed().as_secs_f64())),
    ]));
    let out = args.get_or("json", "BENCH_protocol_check.json");
    let meta = covap::harness::BenchMeta::new(covap::harness::iso_timestamp_now())
        .scheme("membership-protocol")
        .topology("model")
        .backend("static");
    covap::harness::write_bench_doc(Path::new(&out), "protocol_check", &meta, rows)?;
    println!(
        "check-protocol: worlds {min_world}-{max_world} exhaustive ({total_scripts} \
         scripts, {total_states} states, {total_transitions} transitions, depth <= \
         {max_depth}); {} seeded mutants each caught with a distinct violation; in {}",
        caught.len(),
        fmt_secs(t0.elapsed().as_secs_f64())
    );
    println!("wrote {out}");
    Ok(())
}

/// Run the multi-tenant training service (DESIGN.md §14) over a job
/// trace: queue → gang-schedule → contention-paced stepping on a virtual
/// clock. Errors if any job cannot complete (the no-starvation gate CI
/// relies on); prints the per-job summary table and service aggregates.
fn serve(args: &Args) -> Result<()> {
    use covap::harness::{iso_timestamp_now, write_bench_doc, BenchMeta};
    use covap::service::{ServiceDaemon, ServiceSpec};
    use covap::util::bench::Table;
    use covap::util::json::Json;

    if let Some(lv) = args.get("log-level").and_then(|s| covap::obs::log::LogLevel::parse(&s)) {
        covap::obs::log::set_level(lv);
    }
    let quick = args.has("quick");
    let mut spec = match args.get("jobs") {
        Some(path) => ServiceSpec::parse(
            &std::fs::read_to_string(&path)
                .with_context(|| format!("reading job trace {path}"))?,
        )?,
        None => ServiceSpec::demo(quick),
    };
    if let Some(b) = args.get("backend") {
        let backend = covap::config::ExecBackend::parse(&b)
            .ok_or_else(|| anyhow::anyhow!("unknown backend '{b}' (analytic|threaded)"))?;
        spec = spec.with_backend(backend);
    }
    let submitted = spec.jobs.len();
    let cluster = spec.cluster;
    let base_gbps = spec.base_gbps;
    let backends: Vec<&str> = {
        let mut b: Vec<&str> =
            spec.jobs.iter().map(|j| j.backend.label()).collect::<std::collections::BTreeSet<_>>().into_iter().collect();
        if b.is_empty() {
            b.push("analytic");
        }
        b
    };
    let backend_label = backends.join("+");
    println!(
        "serve: {} job(s) on a {}x{} cluster @ {} Gbps shared fabric [{}]",
        submitted, cluster.nodes, cluster.gpus_per_node, base_gbps, backend_label
    );
    let mut daemon = ServiceDaemon::new(spec)?;
    let report = daemon.run()?;
    if report.jobs.len() != submitted {
        bail!(
            "starvation: only {}/{} jobs completed",
            report.jobs.len(),
            submitted
        );
    }

    let mut t = Table::new(&[
        "job", "scheme", "ranks", "pri", "arrive", "wait", "tts", "exposed comm", "p95 step",
        "preempt",
    ]);
    for j in &report.jobs {
        t.row(&[
            j.name.clone(),
            j.scheme.clone(),
            j.workers.to_string(),
            j.priority.to_string(),
            fmt_secs(j.arrival_s),
            fmt_secs(j.queue_wait_s),
            fmt_secs(j.tts_s),
            fmt_secs(j.sim_exposed_s),
            fmt_secs(j.step_p95_s),
            format!("{}/{}", j.preemptions, j.regrows),
        ]);
    }
    t.print("multi-tenant service — per-job summary (virtual time)");
    println!(
        "makespan {} | fabric load {:.2} | gpu utilization {:.2} | all {} job(s) completed",
        fmt_secs(report.makespan_s),
        report.fabric_load,
        report.gpu_utilization,
        report.jobs.len()
    );

    if let Some(out) = args.get("json") {
        let meta = BenchMeta::new(iso_timestamp_now())
            .scheme("multi-tenant")
            .topology("auto")
            .backend(&backend_label);
        let mut rows: Vec<Json> = report.jobs.iter().map(|j| j.to_json()).collect();
        rows.push(Json::obj(vec![
            ("summary", Json::from(1usize)),
            ("jobs", Json::from(report.jobs.len())),
            ("makespan_s", Json::from(report.makespan_s)),
            ("fabric_load", Json::from(report.fabric_load)),
            ("gpu_utilization", Json::from(report.gpu_utilization)),
            ("tail_tts_s", Json::from(report.tail_tts_s())),
        ]));
        write_bench_doc(Path::new(&out), "service", &meta, rows)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let name = args.get_or("dnn", "VGG-19");
    let Some(w) = workload::by_name(&name) else {
        bail!("unknown DNN '{name}' (try: ResNet-101, VGG-19, Bert, GPT-2)");
    };
    let gpus: usize = args.get_parsed("gpus", 64usize)?;
    let cluster = if gpus % 8 == 0 { ClusterSpec::ecs(gpus) } else { ClusterSpec::new(gpus, 1) };
    let mut net = NetworkModel::default();
    if let Some(bw) = args.get("bandwidth-gbps") {
        net.nic_gbps = bw.parse()?;
    }
    let buckets = w.paper_buckets.clone().unwrap_or_else(|| {
        covap::coordinator::bucketize_layers(
            &w.layers.iter().map(|l| (l.name.clone(), l.numel)).collect::<Vec<_>>(),
            25 * 1024 * 1024,
        )
        .iter()
        .map(|b| b.numel)
        .collect()
    });
    let tensors = dense_tensors(&buckets, w.t_comp_s, 0.0);
    let seq = simulate_iteration(&net, cluster, w.t_before_s, &tensors, Policy::Sequential);
    let ovl = simulate_iteration(&net, cluster, w.t_before_s, &tensors, Policy::Overlap);
    println!("{} on {} GPUs @ {} Gbps:", w.name, gpus, net.nic_gbps);
    println!("  params        = {} ({})", w.total_params(), covap::util::fmt_bytes(w.total_bytes()));
    println!("  CCR           = {:.2}", w.ccr(&net, cluster));
    println!("  T_iter seq    = {}  speedup {:.2}x", fmt_secs(seq.total_s), seq.speedup(gpus));
    println!("  T_iter ovlp   = {}  speedup {:.2}x", fmt_secs(ovl.total_s), ovl.speedup(gpus));
    println!("  T_comm'       = {}", fmt_secs(ovl.t_comm_exposed_s));
    println!("  linear scaling= {:.0}x", gpus as f64);
    Ok(())
}
