//! Metrics: per-step training records, aggregated run summaries, and
//! CSV/JSONL emission for the bench harnesses and EXPERIMENTS.md.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One training step's record.
#[derive(Debug, Clone, PartialEq)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    /// Wall-clock seconds spent in this step on the testbed.
    pub wall_s: f64,
    /// Simulated cluster time for this step (network model).
    pub sim_s: f64,
    /// Bytes put on the wire per rank this step.
    pub wire_bytes: usize,
    /// Compression overhead this step (per-worker mean).
    pub compress_s: f64,
}

/// Accumulates step records; emits summaries and files.
#[derive(Debug, Default)]
pub struct RunMetrics {
    pub records: Vec<StepRecord>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RunSummary {
    pub steps: usize,
    pub final_loss: f32,
    pub mean_loss_last10: f32,
    pub total_sim_s: f64,
    pub total_wall_s: f64,
    pub total_wire_bytes: usize,
    pub mean_step_sim_s: f64,
}

impl RunMetrics {
    pub fn new() -> RunMetrics {
        RunMetrics::default()
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn summary(&self) -> RunSummary {
        let n = self.records.len();
        let last10 = &self.records[n.saturating_sub(10)..];
        let mean10 = if last10.is_empty() {
            f32::NAN
        } else {
            last10.iter().map(|r| r.loss).sum::<f32>() / last10.len() as f32
        };
        RunSummary {
            steps: n,
            final_loss: self.records.last().map(|r| r.loss).unwrap_or(f32::NAN),
            mean_loss_last10: mean10,
            total_sim_s: self.records.iter().map(|r| r.sim_s).sum(),
            total_wall_s: self.records.iter().map(|r| r.wall_s).sum(),
            total_wire_bytes: self.records.iter().map(|r| r.wire_bytes).sum(),
            mean_step_sim_s: if n == 0 {
                f64::NAN
            } else {
                self.records.iter().map(|r| r.sim_s).sum::<f64>() / n as f64
            },
        }
    }

    /// CSV with a header row — the loss-curve format EXPERIMENTS.md cites.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(f, "step,loss,wall_s,sim_s,wire_bytes,compress_s")?;
        for r in &self.records {
            writeln!(
                f,
                "{},{},{:.6},{:.6},{},{:.6}",
                r.step, r.loss, r.wall_s, r.sim_s, r.wire_bytes, r.compress_s
            )?;
        }
        Ok(())
    }

    /// Stamp the run-level aggregates into the global obs registry so
    /// `harness::write_bench_doc` embeds them in every `BENCH_*.json`
    /// (DESIGN.md §10). Safe to call on an empty run: NaN gauges are
    /// serialized as null by the registry snapshot.
    pub fn stamp_registry(&self) {
        let s = self.summary();
        crate::obs::registry::with_global(|r| {
            r.counter_add("run_steps", s.steps as u64);
            r.gauge_set("run_final_loss", s.final_loss as f64);
            r.gauge_set("run_total_wall_s", s.total_wall_s);
            r.gauge_set("run_total_sim_s", s.total_sim_s);
        });
    }

    /// JSONL (one object per step).
    pub fn write_jsonl(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        for r in &self.records {
            let j = Json::obj(vec![
                ("step", Json::from(r.step as usize)),
                ("loss", Json::from(r.loss as f64)),
                ("wall_s", Json::from(r.wall_s)),
                ("sim_s", Json::from(r.sim_s)),
                ("wire_bytes", Json::from(r.wire_bytes)),
                ("compress_s", Json::from(r.compress_s)),
            ]);
            writeln!(f, "{j}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, loss: f32) -> StepRecord {
        StepRecord { step, loss, wall_s: 0.1, sim_s: 0.2, wire_bytes: 100, compress_s: 0.01 }
    }

    #[test]
    fn summary_aggregates() {
        let mut m = RunMetrics::new();
        for i in 0..20 {
            m.push(rec(i, 10.0 - i as f32 * 0.1));
        }
        let s = m.summary();
        assert_eq!(s.steps, 20);
        assert!((s.final_loss - 8.1).abs() < 1e-6);
        assert!((s.total_sim_s - 4.0).abs() < 1e-9);
        assert_eq!(s.total_wire_bytes, 2000);
        assert!(s.mean_loss_last10 < 9.0);
    }

    #[test]
    fn csv_and_jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("covap_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut m = RunMetrics::new();
        m.push(rec(0, 5.0));
        m.push(rec(1, 4.5));
        let csv = dir.join("m.csv");
        let jsonl = dir.join("m.jsonl");
        m.write_csv(&csv).unwrap();
        m.write_jsonl(&jsonl).unwrap();
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.starts_with("step,loss"));
        assert_eq!(csv_text.lines().count(), 3);
        let jl = std::fs::read_to_string(&jsonl).unwrap();
        for line in jl.lines() {
            let v = Json::parse(line).unwrap();
            assert!(v.get("loss").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn empty_summary_is_nan_safe() {
        let s = RunMetrics::new().summary();
        assert_eq!(s.steps, 0);
        assert!(s.final_loss.is_nan());
    }

    #[test]
    fn stamp_registry_publishes_run_summary() {
        let mut m = RunMetrics::new();
        m.push(rec(0, 5.0));
        m.push(rec(1, 4.0));
        m.stamp_registry();
        crate::obs::registry::with_global(|r| {
            assert!(r.counter("run_steps") >= 2);
            assert_eq!(r.gauge("run_final_loss"), Some(4.0));
            assert_eq!(r.gauge("run_total_wall_s"), Some(0.2));
            assert_eq!(r.gauge("run_total_sim_s"), Some(0.4));
        });
    }
}
