//! Network timing models — the substitute for the paper's 30 Gbps Alibaba
//! ECS fabric (see DESIGN.md §2).
//!
//! α–β cost model: a collective over n bytes costs
//! `steps * α + volume(n, P) / effective_bandwidth`. The effective bandwidth
//! is the per-node NIC bandwidth derated by `efficiency` — calibrated so the
//! paper's measured per-model communication times (Table I) reproduce:
//! ResNet-101 178.6 MB -> 280 ms, VGG-19 574.6 MB -> 842 ms,
//! Bert 409 MB -> 520 ms all imply ~1.2 GB/s effective on a 30 Gbps NIC
//! (eta ~ 0.32), consistent with NCCL ring efficiency on TCP fabrics.

/// Cluster shape: `nodes * gpus_per_node` ranks; ring collectives cross the
/// per-node NIC (intra-node traffic is modeled as free, like NVLink next to
/// a 30 Gbps NIC).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterSpec {
    pub nodes: usize,
    pub gpus_per_node: usize,
}

impl ClusterSpec {
    pub fn new(nodes: usize, gpus_per_node: usize) -> ClusterSpec {
        assert!(nodes >= 1 && gpus_per_node >= 1);
        ClusterSpec { nodes, gpus_per_node }
    }

    /// The paper's testbed: N nodes x 8 V100.
    pub fn ecs(gpus: usize) -> ClusterSpec {
        assert!(gpus % 8 == 0 && gpus >= 8, "paper clusters are multiples of 8 GPUs");
        ClusterSpec { nodes: gpus / 8, gpus_per_node: 8 }
    }

    pub fn world(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Node housing `rank` under rank-major placement (ranks `[n*g,
    /// (n+1)*g)` live on node `n`) — what classifies a hop as intra- vs
    /// inter-node in the topology layer.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// Per-node NIC bandwidth, Gbit/s.
    pub nic_gbps: f64,
    /// Achievable fraction of the NIC line rate (protocol + ring overheads).
    pub efficiency: f64,
    /// Per-collective-step latency, seconds.
    pub latency_s: f64,
    /// Effective intra-node ring bandwidth, Gbit/s (PCIe-attached V100s on
    /// cloud instances; protocol efficiency folded in). NCCL pipelines the
    /// intra- and inter-node stages, so collectives cost
    /// max(inter, intra) — calibrated so single-node 8-GPU DDPovlp lands
    /// near the paper's Fig. 11 left edge (~70% of linear on ResNet-101).
    pub intra_gbps: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        // The paper's environment: 30 Gbps public-cloud network.
        NetworkModel { nic_gbps: 30.0, efficiency: 0.32, latency_s: 50e-6, intra_gbps: 12.0 }
    }
}

impl NetworkModel {
    /// Per-hop latency of the intra-node fabric (PCIe peer copies), used
    /// by both the α–β intra stage and the topology layer's per-level hop
    /// pricing.
    pub const INTRA_LATENCY_S: f64 = 5e-6;

    pub fn hpc_100g() -> NetworkModel {
        NetworkModel { nic_gbps: 100.0, efficiency: 0.45, latency_s: 10e-6, intra_gbps: 48.0 }
    }

    /// Effective node-to-node bandwidth in bytes/second.
    pub fn effective_bps(&self) -> f64 {
        self.nic_gbps * 1e9 / 8.0 * self.efficiency
    }

    /// Effective intra-node ring bandwidth in bytes/second.
    pub fn intra_bps(&self) -> f64 {
        self.intra_gbps * 1e9 / 8.0
    }

    /// Intra-node stage of a ring allreduce over g local ranks.
    fn intra_allreduce_s(&self, bytes: usize, g: usize) -> f64 {
        if g <= 1 {
            return 0.0;
        }
        let g = g as f64;
        2.0 * (g - 1.0) / g * bytes as f64 / self.intra_bps()
            + Self::INTRA_LATENCY_S * 2.0 * (g - 1.0)
    }

    /// Ring AllReduce over `bytes` payload per rank.
    ///
    /// Per-node wire traffic: 2*(N-1)/N * bytes where N = node count (the
    /// ring is across nodes; each node's 8 local ranks reduce intra-node
    /// first, which we model as free). Steps: 2*(N-1).
    pub fn allreduce_s(&self, bytes: usize, cluster: ClusterSpec) -> f64 {
        let n = cluster.nodes as f64;
        let intra = self.intra_allreduce_s(bytes, cluster.gpus_per_node);
        if cluster.nodes == 1 {
            return intra.max(self.latency_s);
        }
        let volume = 2.0 * (n - 1.0) / n * bytes as f64;
        let inter = volume / self.effective_bps() + 2.0 * (n - 1.0) * self.latency_s;
        // NCCL pipelines the hierarchical stages: the slower stage binds.
        inter.max(intra)
    }

    /// AllGather where each rank contributes `bytes`. Every node must
    /// receive the payloads of all other nodes' ranks: with g ranks/node,
    /// inbound volume per node is (N-1) * g * bytes.
    ///
    /// This is why allgather-based GC schemes (Top-k, Random-k, EFsignSGD,
    /// DGC) scale poorly in Fig. 11: volume grows with world size while
    /// allreduce volume is ~constant.
    pub fn allgather_s(&self, bytes: usize, cluster: ClusterSpec) -> f64 {
        let n = cluster.nodes as f64;
        let g = cluster.gpus_per_node as f64;
        // intra stage: every local rank ends up with all g*world payloads;
        // local distribution moves (g-1) * world_bytes over the PCIe ring.
        let world_bytes = (cluster.world() as f64 - 1.0) * bytes as f64;
        let intra = if cluster.gpus_per_node > 1 { world_bytes / self.intra_bps() } else { 0.0 };
        if cluster.nodes == 1 {
            return intra.max(self.latency_s);
        }
        let volume = (n - 1.0) * g * bytes as f64;
        let inter = volume / self.effective_bps() + (n - 1.0) * self.latency_s;
        inter.max(intra)
    }

    /// A small synchronous rendezvous (threshold / count exchange) — the
    /// "data dependency" collectives of Ok-topk-like schemes.
    pub fn sync_round_s(&self, cluster: ClusterSpec) -> f64 {
        if cluster.nodes == 1 {
            self.latency_s
        } else {
            2.0 * (cluster.nodes as f64 - 1.0) * self.latency_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: usize = 1024 * 1024;

    #[test]
    fn calibration_matches_paper_table1() {
        // Table I: ResNet-101 T_comm = 280 ms, VGG-19 = 842 ms, Bert = 520 ms
        // at 64 GPUs (8 nodes), 30 Gbps.
        let net = NetworkModel::default();
        let c = ClusterSpec::ecs(64);
        let cases = [
            (44_654_504usize, 0.280),
            (143_652_544, 0.842),
            (102_267_648, 0.520),
        ];
        for (params, t_paper) in cases {
            let t = net.allreduce_s(params * 4, c);
            let ratio = t / t_paper;
            assert!(
                (0.75..1.35).contains(&ratio),
                "params={params}: modeled {t:.3}s vs paper {t_paper}s"
            );
        }
    }

    #[test]
    fn allreduce_volume_saturates_with_nodes() {
        // 2(N-1)/N -> 2: going 2 -> 8 nodes costs at most 2x-ish, not 4x.
        let net = NetworkModel::default();
        let t2 = net.allreduce_s(100 * MB, ClusterSpec::ecs(16));
        let t8 = net.allreduce_s(100 * MB, ClusterSpec::ecs(64));
        assert!(t8 / t2 < 2.0);
        assert!(t8 > t2);
    }

    #[test]
    fn allgather_grows_linearly_with_nodes() {
        let net = NetworkModel::default();
        let t2 = net.allgather_s(MB, ClusterSpec::ecs(16));
        let t8 = net.allgather_s(MB, ClusterSpec::ecs(64));
        assert!(t8 / t2 > 3.0, "allgather must scale ~(N-1): {}", t8 / t2);
    }

    #[test]
    fn allgather_worse_than_allreduce_at_scale() {
        let net = NetworkModel::default();
        let c = ClusterSpec::ecs(64);
        assert!(net.allgather_s(10 * MB, c) > net.allreduce_s(10 * MB, c));
    }

    #[test]
    fn single_node_bound_by_pcie_ring() {
        let net = NetworkModel::default();
        let c = ClusterSpec::new(1, 8);
        let t = net.allreduce_s(100 * MB, c);
        // 2*(7/8)*100MB / 1.5 GB/s ~ 122 ms
        assert!((0.08..0.2).contains(&t), "{t}");
        // single *rank* is free
        assert_eq!(net.allreduce_s(100 * MB, ClusterSpec::new(1, 1)), net.latency_s);
    }

    #[test]
    fn multinode_never_cheaper_than_intra_stage() {
        let net = NetworkModel::default();
        let t1 = net.allreduce_s(100 * MB, ClusterSpec::new(1, 8));
        let t8 = net.allreduce_s(100 * MB, ClusterSpec::ecs(64));
        assert!(t8 >= t1);
    }
}
