//! Leveled, target-tagged logging (DESIGN.md §10).
//!
//! One process-wide level (atomic; `COVAP_LOG=debug` env or
//! `--log-level` / `"log_level"` config knob via [`set_level`]) gates the
//! [`crate::log_error!`] / [`crate::log_warn!`] / [`crate::log_info!`] /
//! [`crate::log_debug!`] macros. Every message carries a *target* — the
//! subsystem it came from (`engine`, `trainer`, `config`, `exec`,
//! `controller`, `bench`, ...) — and goes to **stderr**, so stdout stays
//! reserved for primary program output (tables, reports, bench JSON
//! paths).
//!
//! Zero-cost when disabled: the macros test [`enabled`] (one relaxed
//! atomic load) before touching `format_args!`, so a suppressed call
//! formats nothing and allocates nothing — asserted by
//! `benches/perf_hotpath.rs`.
//!
//! Structured events (the controller's interval decisions) go through
//! [`emit_kv`] as `event key=value ...` lines, grep- and parse-friendly.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

/// Verbosity levels, ordered so that a message passes when its level is
/// at or below the active one. [`LogLevel::Off`] silences everything.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum LogLevel {
    /// No output at all.
    Off = 0,
    /// Unrecoverable problems.
    Error = 1,
    /// Suspicious-but-continuing conditions (the config warnings,
    /// negative-span clamps).
    Warn = 2,
    /// Run milestones: progress lines, controller decisions, artifact
    /// paths. The default.
    Info = 3,
    /// Per-step diagnostics.
    Debug = 4,
}

impl LogLevel {
    /// Parse a level name (case-insensitive): off|error|warn|info|debug.
    pub fn parse(s: &str) -> Option<LogLevel> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" => Some(LogLevel::Off),
            "error" => Some(LogLevel::Error),
            "warn" | "warning" => Some(LogLevel::Warn),
            "info" => Some(LogLevel::Info),
            "debug" => Some(LogLevel::Debug),
            _ => None,
        }
    }

    /// The canonical lowercase name (round-trips through [`parse`]).
    ///
    /// [`parse`]: LogLevel::parse
    pub fn as_str(&self) -> &'static str {
        match self {
            LogLevel::Off => "off",
            LogLevel::Error => "error",
            LogLevel::Warn => "warn",
            LogLevel::Info => "info",
            LogLevel::Debug => "debug",
        }
    }

    fn from_u8(v: u8) -> LogLevel {
        match v {
            0 => LogLevel::Off,
            1 => LogLevel::Error,
            2 => LogLevel::Warn,
            4 => LogLevel::Debug,
            _ => LogLevel::Info,
        }
    }
}

/// Sentinel: the global level has not been initialized yet (first read
/// consults the `COVAP_LOG` environment variable).
const UNINIT: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(UNINIT);

fn init_from_env() -> u8 {
    let lv = std::env::var("COVAP_LOG")
        .ok()
        .and_then(|s| LogLevel::parse(&s))
        .unwrap_or(LogLevel::Info);
    LEVEL.store(lv as u8, Ordering::Relaxed);
    lv as u8
}

/// The active level (lazily read from `COVAP_LOG`, default `info`).
pub fn level() -> LogLevel {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == UNINIT { init_from_env() } else { raw };
    LogLevel::from_u8(raw)
}

/// Override the active level (CLI `--log-level` / config `"log_level"`).
pub fn set_level(lv: LogLevel) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

/// Would a message at `lv` be emitted right now? One relaxed atomic load —
/// the macros call this before formatting anything.
#[inline]
pub fn enabled(lv: LogLevel) -> bool {
    lv != LogLevel::Off && lv <= level()
}

/// Emit one line to stderr: `[<level> <target>] <message>`. The macros
/// hand in `format_args!` directly, so an enabled message is formatted
/// straight into the stderr writer without an intermediate `String`.
pub fn emit(level: LogLevel, target: &str, args: fmt::Arguments<'_>) {
    eprintln!("[{} {target}] {args}", level.as_str());
}

/// Emit a structured `event key=value ...` line (checks [`enabled`]
/// itself, so callers can build the pairs unconditionally only when they
/// are cheap — or gate on [`enabled`] first).
pub fn emit_kv(level: LogLevel, target: &str, event: &str, kvs: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    let mut line = String::with_capacity(event.len() + kvs.len() * 16);
    line.push_str(event);
    for (k, v) in kvs {
        line.push(' ');
        line.push_str(k);
        line.push('=');
        line.push_str(v);
    }
    emit(level, target, format_args!("{line}"));
}

/// Log at `error` level: `log_error!(target: "engine", "...", ...)`.
#[macro_export]
macro_rules! log_error {
    (target: $target:expr, $($arg:tt)*) => {{
        if $crate::obs::log::enabled($crate::obs::log::LogLevel::Error) {
            $crate::obs::log::emit(
                $crate::obs::log::LogLevel::Error, $target, format_args!($($arg)*));
        }
    }};
}

/// Log at `warn` level: `log_warn!(target: "config", "...", ...)`.
#[macro_export]
macro_rules! log_warn {
    (target: $target:expr, $($arg:tt)*) => {{
        if $crate::obs::log::enabled($crate::obs::log::LogLevel::Warn) {
            $crate::obs::log::emit(
                $crate::obs::log::LogLevel::Warn, $target, format_args!($($arg)*));
        }
    }};
}

/// Log at `info` level: `log_info!(target: "trainer", "...", ...)`.
#[macro_export]
macro_rules! log_info {
    (target: $target:expr, $($arg:tt)*) => {{
        if $crate::obs::log::enabled($crate::obs::log::LogLevel::Info) {
            $crate::obs::log::emit(
                $crate::obs::log::LogLevel::Info, $target, format_args!($($arg)*));
        }
    }};
}

/// Log at `debug` level: `log_debug!(target: "exec", "...", ...)`.
#[macro_export]
macro_rules! log_debug {
    (target: $target:expr, $($arg:tt)*) => {{
        if $crate::obs::log::enabled($crate::obs::log::LogLevel::Debug) {
            $crate::obs::log::emit(
                $crate::obs::log::LogLevel::Debug, $target, format_args!($($arg)*));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_roundtrip() {
        for lv in [
            LogLevel::Off,
            LogLevel::Error,
            LogLevel::Warn,
            LogLevel::Info,
            LogLevel::Debug,
        ] {
            assert_eq!(LogLevel::parse(lv.as_str()), Some(lv));
        }
        assert_eq!(LogLevel::parse("WARN"), Some(LogLevel::Warn));
        assert_eq!(LogLevel::parse("verbose"), None);
    }

    #[test]
    fn levels_order_by_verbosity() {
        assert!(LogLevel::Off < LogLevel::Error);
        assert!(LogLevel::Error < LogLevel::Warn);
        assert!(LogLevel::Warn < LogLevel::Info);
        assert!(LogLevel::Info < LogLevel::Debug);
    }

    #[test]
    fn enabled_respects_set_level() {
        // restore whatever the process-wide level was (tests share it)
        let prev = level();
        set_level(LogLevel::Warn);
        assert!(enabled(LogLevel::Error));
        assert!(enabled(LogLevel::Warn));
        assert!(!enabled(LogLevel::Info));
        assert!(!enabled(LogLevel::Debug));
        set_level(LogLevel::Off);
        assert!(!enabled(LogLevel::Error));
        assert!(!enabled(LogLevel::Off), "Off is never an emit level");
        set_level(prev);
    }

    #[test]
    fn macros_compile_for_all_levels() {
        // smoke: the macro forms expand inside the crate
        crate::log_error!(target: "test", "e {}", 1);
        crate::log_warn!(target: "test", "w {}", 2);
        crate::log_info!(target: "test", "i {}", 3);
        crate::log_debug!(target: "test", "d {}", 4);
        emit_kv(
            LogLevel::Debug,
            "test",
            "event",
            &[("k", "v".to_string()), ("n", 7.to_string())],
        );
    }
}
