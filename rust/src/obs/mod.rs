//! Unified observability layer (DESIGN.md §10).
//!
//! Three pieces, threaded through both execution backends:
//!
//! * [`trace`] — Chrome Trace Event / Perfetto export: measured
//!   `RankTimeline`s and the analytic simulator's predicted spans in one
//!   `trace.json` (`--trace-out`), plus instant events for barrier
//!   waits, pacer changes and `IntervalController` decisions, and
//!   cumulative per-level wire-byte counters.
//! * [`registry`] — process-wide counter/gauge/histogram registry the
//!   engine stamps each step; `harness::write_bench_doc` embeds its
//!   snapshot into every `BENCH_*.json`.
//! * [`log`] — leveled, target-tagged logging to stderr behind the
//!   [`crate::log_error!`]/[`crate::log_warn!`]/[`crate::log_info!`]/
//!   [`crate::log_debug!`] macros (`--log-level` / `COVAP_LOG`).
//!
//! All of it is zero-cost when disabled: tracing only runs when
//! `trace_out` is set, registry stamping happens at step (not
//! per-tensor) granularity, and suppressed log macros are a single
//! relaxed atomic load — `benches/perf_hotpath.rs` asserts the
//! steady-state hot path still performs zero allocations.

pub mod log;
pub mod registry;
pub mod trace;

pub use log::LogLevel;
pub use registry::{global_snapshot, with_global, Registry};
pub use trace::{validate_trace, TraceBuilder, TID_COMM, TID_COMPUTE};
