//! Step-metrics registry (DESIGN.md §10): counters, gauges and
//! percentile histograms the engine stamps every step.
//!
//! One process-wide [`Registry`] behind a mutex ([`with_global`]) so the
//! engine, the trainer and the benches all accumulate into the same
//! snapshot, and `harness::write_bench_doc` embeds it into every
//! `BENCH_*.json` envelope (the `"metrics"` field) — replacing ad-hoc
//! per-bench aggregation with one shared vocabulary:
//!
//! * counters — `steps`, `wire_bytes{,_intra,_inter}`,
//!   `controller_decisions`, `controller_switches`, `run_steps`,
//!   `bench_steady_allocs`
//! * gauges — `interval`, `ccr`, `barrier_skew_s`, `run_final_loss`,
//!   `run_total_{wall,sim}_s`
//! * histograms (p50/p95/p99) — `step_wall_s`, `sim_total_s`,
//!   `sim_exposed_s`, `compress_s`, `barrier_wait_s`, and per-`SpanKind`
//!   durations `span_{compute,compress,comm}_s`
//!
//! Stamping happens at engine-step granularity, far from the
//! compress→encode→combine hot path, so the zero-allocation steady-state
//! guarantee (`benches/perf_hotpath.rs`) is untouched.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

/// Sample cap per histogram: beyond this the reservoir wraps around
/// (bounded memory for arbitrarily long runs; percentiles then reflect a
/// rolling window of recent observations).
const HIST_CAP: usize = 8192;

/// A streaming histogram: exact count/sum/max plus a bounded sample
/// reservoir for percentile estimates.
#[derive(Debug, Clone)]
pub struct Histogram {
    count: u64,
    sum: f64,
    max: f64,
    samples: Vec<f64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { count: 0, sum: 0.0, max: f64::NEG_INFINITY, samples: Vec::new() }
    }
}

impl Histogram {
    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
        if self.samples.len() < HIST_CAP {
            self.samples.push(v);
        } else {
            self.samples[(self.count as usize) % HIST_CAP] = v;
        }
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// The `q`-th percentile (0..=100) over the retained samples; NaN when
    /// empty.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let idx = (q / 100.0 * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }

    /// Summary as a JSON object: count, sum, mean, p50/p95/p99, max.
    pub fn to_json(&self) -> Json {
        let mean = if self.count == 0 { 0.0 } else { self.sum / self.count as f64 };
        let pct = |q: f64| {
            let v = self.percentile(q);
            if v.is_finite() { Json::Num(v) } else { Json::Null }
        };
        Json::obj(vec![
            ("count", Json::from(self.count as usize)),
            ("sum", Json::from(self.sum)),
            ("mean", Json::from(mean)),
            ("p50", pct(50.0)),
            ("p95", pct(95.0)),
            ("p99", pct(99.0)),
            ("max", if self.count == 0 { Json::Null } else { Json::Num(self.max) }),
        ])
    }
}

/// Counter/gauge/histogram registry. Plain struct — unit tests build their
/// own; production code shares the process-wide one via [`with_global`].
#[derive(Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Add `v` to a monotone counter (created at 0 on first use).
    pub fn counter_add(&mut self, name: &str, v: u64) {
        match self.counters.get_mut(name) {
            Some(c) => *c += v,
            None => {
                self.counters.insert(name.to_string(), v);
            }
        }
    }

    /// Current counter value (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a gauge to its latest value.
    pub fn gauge_set(&mut self, name: &str, v: f64) {
        match self.gauges.get_mut(name) {
            Some(g) => *g = v,
            None => {
                self.gauges.insert(name.to_string(), v);
            }
        }
    }

    /// Current gauge value, if ever set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record one observation into a histogram (created on first use).
    pub fn observe(&mut self, name: &str, v: f64) {
        match self.histograms.get_mut(name) {
            Some(h) => h.observe(v),
            None => {
                let mut h = Histogram::default();
                h.observe(v);
                self.histograms.insert(name.to_string(), h);
            }
        }
    }

    /// The named histogram, if any observation was ever recorded.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Drop all series (tests isolate themselves with fresh registries
    /// instead; the global registry is append-only in production).
    pub fn clear(&mut self) {
        self.counters.clear();
        self.gauges.clear();
        self.histograms.clear();
    }

    /// Snapshot as `{"counters": {...}, "gauges": {...},
    /// "histograms": {name: {count, sum, mean, p50, p95, p99, max}}}`.
    pub fn to_json(&self) -> Json {
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
            .collect();
        let gauges = self
            .gauges
            .iter()
            .map(|(k, v)| {
                (k.clone(), if v.is_finite() { Json::Num(*v) } else { Json::Null })
            })
            .collect();
        let hists =
            self.histograms.iter().map(|(k, h)| (k.clone(), h.to_json())).collect();
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(hists)),
        ])
    }
}

/// Run `f` against the process-wide registry (engine steps, trainer run
/// summaries and bench instruments all land here).
pub fn with_global<T>(f: impl FnOnce(&mut Registry) -> T) -> T {
    static GLOBAL: OnceLock<Mutex<Registry>> = OnceLock::new();
    let m = GLOBAL.get_or_init(|| Mutex::new(Registry::new()));
    let mut guard = m.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    f(&mut guard)
}

/// JSON snapshot of the process-wide registry — what
/// `harness::write_bench_doc` embeds into every `BENCH_*.json`.
pub fn global_snapshot() -> Json {
    with_global(|r| r.to_json())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_default_zero() {
        let mut r = Registry::new();
        assert_eq!(r.counter("steps"), 0);
        r.counter_add("steps", 1);
        r.counter_add("steps", 4);
        assert_eq!(r.counter("steps"), 5);
    }

    #[test]
    fn gauges_keep_latest() {
        let mut r = Registry::new();
        assert_eq!(r.gauge("ccr"), None);
        r.gauge_set("ccr", 1.5);
        r.gauge_set("ccr", 2.5);
        assert_eq!(r.gauge("ccr"), Some(2.5));
    }

    #[test]
    fn histogram_percentiles_on_known_data() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum() - 5050.0).abs() < 1e-9);
        let p50 = h.percentile(50.0);
        assert!((49.0..=52.0).contains(&p50), "p50 = {p50}");
        let p99 = h.percentile(99.0);
        assert!((98.0..=100.0).contains(&p99), "p99 = {p99}");
        assert_eq!(h.percentile(100.0), 100.0);
    }

    #[test]
    fn histogram_reservoir_is_bounded() {
        let mut h = Histogram::default();
        for i in 0..(HIST_CAP * 3) {
            h.observe(i as f64);
        }
        assert_eq!(h.count() as usize, HIST_CAP * 3);
        assert_eq!(h.max, (HIST_CAP * 3 - 1) as f64);
        assert!(h.samples.len() <= HIST_CAP);
    }

    #[test]
    fn snapshot_shape() {
        let mut r = Registry::new();
        r.counter_add("wire_bytes", 128);
        r.gauge_set("interval", 3.0);
        r.observe("step_wall_s", 0.5);
        r.observe("step_wall_s", 1.5);
        let j = r.to_json();
        assert_eq!(
            j.get("counters").unwrap().get("wire_bytes").unwrap().as_usize().unwrap(),
            128
        );
        assert_eq!(
            j.get("gauges").unwrap().get("interval").unwrap().as_f64().unwrap(),
            3.0
        );
        let h = j.get("histograms").unwrap().get("step_wall_s").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 2);
        assert!((h.get("mean").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-12);
        assert!(h.get("max").unwrap().as_f64().unwrap() >= 1.5);
    }

    #[test]
    fn empty_histogram_summary_is_null_safe() {
        let h = Histogram::default();
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_usize().unwrap(), 0);
        assert_eq!(*j.get("p50").unwrap(), Json::Null);
        assert_eq!(*j.get("max").unwrap(), Json::Null);
    }

    #[test]
    fn non_finite_gauges_serialize_as_null() {
        let mut r = Registry::new();
        r.gauge_set("run_final_loss", f64::NAN);
        r.gauge_set("ok", 1.25);
        let j = r.to_json();
        assert_eq!(*j.get("gauges").unwrap().get("run_final_loss").unwrap(), Json::Null);
        assert_eq!(j.get("gauges").unwrap().get("ok").unwrap().as_f64().unwrap(), 1.25);
        // And the snapshot parses back as valid JSON.
        let text = j.to_string();
        assert!(Json::parse(&text).is_ok(), "snapshot must be valid JSON: {text}");
    }

    #[test]
    fn global_registry_is_shared() {
        with_global(|r| r.counter_add("test_shared_counter", 2));
        with_global(|r| r.counter_add("test_shared_counter", 3));
        let v = with_global(|r| r.counter("test_shared_counter"));
        assert!(v >= 5, "global accumulates across calls, got {v}");
        let snap = global_snapshot();
        assert!(snap.get("counters").is_ok());
    }
}
