//! Chrome Trace Event Format / Perfetto export (DESIGN.md §10).
//!
//! [`TraceBuilder`] turns per-step span data — the threaded backend's
//! measured `RankTimeline`s *and* the analytic simulator's predicted
//! spans — into one `trace.json` that loads directly in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`:
//!
//! * **pid** — one process per measured rank (`rank 0..P-1`), plus one
//!   extra process `sim (predicted)` at pid = P carrying the analytic
//!   model's predicted timeline. Both backends emit the predicted
//!   process, so predicted-vs-measured overlap can be diffed in one
//!   window.
//! * **tid** — 0 = compute stream (Compute + Compress spans),
//!   1 = comm stream (Comm spans).
//! * **complete events** (`ph:"X"`) carry `args` with tensor id, scheme,
//!   wire/intra/inter bytes and step.
//! * **instant events** (`ph:"i"`) mark barrier waits (measured, per
//!   rank), barrier skew (predicted), pacer state changes, and
//!   `IntervalController` decisions (measured CCR, proposed/chosen
//!   interval, whether a re-shard happened).
//! * **counter events** (`ph:"C"`) track cumulative per-level wire bytes
//!   (`intra`/`inter` series) — monotone by construction.
//!
//! Steps are laid out back-to-back on a single timeline: the builder
//! keeps a cursor (µs) advanced past each step's latest event at
//! [`TraceBuilder::end_step`], so span times passed in are
//! *step-relative seconds*.
//!
//! [`validate_trace`] is the schema check the property tests and the CI
//! trace job run against every emitted document: required keys per
//! phase, non-negative finite times, per-(pid, tid) span non-overlap,
//! and monotone wire-byte counter series.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Thread id of the compute stream within each trace process.
pub const TID_COMPUTE: usize = 0;
/// Thread id of the comm stream within each trace process.
pub const TID_COMM: usize = 1;

/// Incrementally builds a Chrome Trace Event document; one per engine
/// run, fed at step granularity (never from the per-tensor hot path).
#[derive(Debug, Default)]
pub struct TraceBuilder {
    events: Vec<Json>,
    /// Start of the current step on the global trace clock, in µs.
    cursor_us: f64,
    /// Latest event end seen this step, relative to `cursor_us`, in µs.
    step_max_us: f64,
    named_procs: BTreeSet<usize>,
    named_threads: BTreeSet<(usize, usize)>,
    /// Cumulative counter series, keyed (pid, counter name, series key).
    counter_totals: BTreeMap<(usize, String, String), f64>,
}

impl TraceBuilder {
    /// An empty trace.
    pub fn new() -> TraceBuilder {
        TraceBuilder::default()
    }

    /// Number of events emitted so far (metadata included).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when no events have been emitted.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Name a trace process (once per pid; later calls are no-ops).
    pub fn process(&mut self, pid: usize, name: &str) {
        if !self.named_procs.insert(pid) {
            return;
        }
        self.events.push(Json::obj(vec![
            ("ph", Json::from("M")),
            ("name", Json::from("process_name")),
            ("pid", Json::from(pid)),
            ("tid", Json::from(0usize)),
            ("ts", Json::from(0.0)),
            ("args", Json::obj(vec![("name", Json::from(name))])),
        ]));
    }

    /// Name a thread within a process (once per (pid, tid)).
    pub fn thread(&mut self, pid: usize, tid: usize, name: &str) {
        if !self.named_threads.insert((pid, tid)) {
            return;
        }
        self.events.push(Json::obj(vec![
            ("ph", Json::from("M")),
            ("name", Json::from("thread_name")),
            ("pid", Json::from(pid)),
            ("tid", Json::from(tid)),
            ("ts", Json::from(0.0)),
            ("args", Json::obj(vec![("name", Json::from(name))])),
        ]));
    }

    /// Emit a complete (`ph:"X"`) event. `start_s`/`end_s` are
    /// step-relative seconds; a non-positive duration clamps to zero
    /// (the upstream `Span::duration()` warning already flagged it).
    pub fn complete(
        &mut self,
        pid: usize,
        tid: usize,
        name: &str,
        cat: &str,
        start_s: f64,
        end_s: f64,
        args: Vec<(&str, Json)>,
    ) {
        let start_us = (start_s * 1e6).max(0.0);
        let dur_us = ((end_s - start_s) * 1e6).max(0.0);
        self.step_max_us = self.step_max_us.max(start_us + dur_us);
        self.events.push(Json::obj(vec![
            ("ph", Json::from("X")),
            ("name", Json::from(name)),
            ("cat", Json::from(cat)),
            ("pid", Json::from(pid)),
            ("tid", Json::from(tid)),
            ("ts", Json::from(self.cursor_us + start_us)),
            ("dur", Json::from(dur_us)),
            ("args", Json::obj(args)),
        ]));
    }

    /// Emit a thread-scoped instant (`ph:"i"`, `s:"t"`) event at a
    /// step-relative time.
    pub fn instant(
        &mut self,
        pid: usize,
        tid: usize,
        name: &str,
        ts_s: f64,
        args: Vec<(&str, Json)>,
    ) {
        let ts_us = (ts_s * 1e6).max(0.0);
        self.step_max_us = self.step_max_us.max(ts_us);
        self.events.push(Json::obj(vec![
            ("ph", Json::from("i")),
            ("s", Json::from("t")),
            ("name", Json::from(name)),
            ("pid", Json::from(pid)),
            ("tid", Json::from(tid)),
            ("ts", Json::from(self.cursor_us + ts_us)),
            ("args", Json::obj(args)),
        ]));
    }

    /// Emit a counter (`ph:"C"`) sample. Each `series` entry is *added*
    /// to the running total for (pid, name, key), so the emitted values
    /// are cumulative and therefore monotone — which is what
    /// [`validate_trace`] checks for the `wire_bytes` counter.
    pub fn counter(&mut self, pid: usize, name: &str, ts_s: f64, series: &[(&str, f64)]) {
        let ts_us = (ts_s * 1e6).max(0.0);
        self.step_max_us = self.step_max_us.max(ts_us);
        let mut args: Vec<(&str, Json)> = Vec::with_capacity(series.len());
        for (key, delta) in series {
            let slot = self
                .counter_totals
                .entry((pid, name.to_string(), key.to_string()))
                .or_insert(0.0);
            *slot += delta.max(0.0);
            args.push((key, Json::Num(*slot)));
        }
        self.events.push(Json::obj(vec![
            ("ph", Json::from("C")),
            ("name", Json::from(name)),
            ("pid", Json::from(pid)),
            ("tid", Json::from(0usize)),
            ("ts", Json::from(self.cursor_us + ts_us)),
            ("args", Json::obj(args)),
        ]));
    }

    /// Close the current step: advance the cursor past every event seen,
    /// plus a 1 µs gap so adjacent steps never touch.
    pub fn end_step(&mut self) {
        self.cursor_us += self.step_max_us + 1.0;
        self.step_max_us = 0.0;
    }

    /// The full document: `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("traceEvents", Json::Arr(self.events.clone())),
            ("displayTimeUnit", Json::from("ms")),
        ])
    }

    /// Write the document to `path` (the `--trace-out` target).
    pub fn write(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing trace to {}", path.display()))
    }
}

fn ev_num(e: &Json, key: &str, i: usize) -> Result<f64> {
    let v = e
        .get(key)
        .with_context(|| format!("event {i}: missing '{key}'"))?
        .as_f64()
        .with_context(|| format!("event {i}: '{key}' not a number"))?;
    if !v.is_finite() {
        bail!("event {i}: '{key}' is not finite");
    }
    Ok(v)
}

/// Validate a trace document against the schema the repo promises
/// (ISSUE 6 / DESIGN.md §10):
///
/// * top level has a `traceEvents` array;
/// * every event has `ph`, `name`, `ts`, `pid`, `tid`, with `ts` finite
///   and non-negative;
/// * `"X"` events have a finite non-negative `dur`, and per (pid, tid)
///   the spans do not overlap (1 ms tolerance for float noise);
/// * `"i"` events carry a valid scope `s`;
/// * `"C"` events have all-numeric args, and the `wire_bytes` counter's
///   series are non-decreasing per (pid, series key);
/// * only phases `X`/`i`/`C`/`M` appear.
pub fn validate_trace(doc: &Json) -> Result<()> {
    let events = doc
        .get("traceEvents")
        .context("trace document: missing 'traceEvents'")?
        .as_arr()
        .context("trace document: 'traceEvents' not an array")?;
    // (pid, tid) -> list of (start, end) µs for "X" events
    let mut spans: BTreeMap<(usize, usize), Vec<(f64, f64)>> = BTreeMap::new();
    // (pid, series key) -> last value for the wire_bytes counter
    let mut wire_last: BTreeMap<(usize, String), f64> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .with_context(|| format!("event {i}: missing 'ph'"))?
            .as_str()
            .with_context(|| format!("event {i}: 'ph' not a string"))?
            .to_string();
        e.get("name").with_context(|| format!("event {i}: missing 'name'"))?;
        let ts = ev_num(e, "ts", i)?;
        if ts < 0.0 {
            bail!("event {i}: negative ts {ts}");
        }
        let pid = e
            .get("pid")
            .with_context(|| format!("event {i}: missing 'pid'"))?
            .as_usize()
            .with_context(|| format!("event {i}: bad 'pid'"))?;
        let tid = e
            .get("tid")
            .with_context(|| format!("event {i}: missing 'tid'"))?
            .as_usize()
            .with_context(|| format!("event {i}: bad 'tid'"))?;
        match ph.as_str() {
            "X" => {
                let dur = ev_num(e, "dur", i)?;
                if dur < 0.0 {
                    bail!("event {i}: negative dur {dur}");
                }
                spans.entry((pid, tid)).or_default().push((ts, ts + dur));
            }
            "i" => {
                let s = e
                    .get("s")
                    .with_context(|| format!("event {i}: instant missing scope 's'"))?
                    .as_str()
                    .with_context(|| format!("event {i}: 's' not a string"))?;
                if !matches!(s, "t" | "p" | "g") {
                    bail!("event {i}: invalid instant scope '{s}'");
                }
            }
            "C" => {
                let name = e.get("name")?.as_str()?.to_string();
                let args = e
                    .get("args")
                    .with_context(|| format!("event {i}: counter missing 'args'"))?
                    .as_obj()
                    .with_context(|| format!("event {i}: counter 'args' not an object"))?;
                for (key, v) in args {
                    let v = v
                        .as_f64()
                        .with_context(|| format!("event {i}: counter series '{key}' not numeric"))?;
                    if name == "wire_bytes" {
                        let slot = wire_last.entry((pid, key.clone())).or_insert(f64::NEG_INFINITY);
                        if v < *slot {
                            bail!(
                                "event {i}: counter wire_bytes/{key} decreased ({} -> {v})",
                                *slot
                            );
                        }
                        *slot = v;
                    }
                }
            }
            "M" => {}
            other => bail!("event {i}: unsupported phase '{other}'"),
        }
    }
    for ((pid, tid), mut list) in spans {
        list.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        for w in list.windows(2) {
            let (prev, next) = (w[0], w[1]);
            // 1 ms slack: span ends are reconstructed from f64 seconds.
            if next.0 < prev.1 - 1e-3 {
                bail!(
                    "pid {pid} tid {tid}: overlapping spans [{:.3}, {:.3}] and [{:.3}, {:.3}] µs",
                    prev.0,
                    prev.1,
                    next.0,
                    next.1
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn built_trace_validates_and_roundtrips() {
        let mut t = TraceBuilder::new();
        t.process(0, "rank 0");
        t.thread(0, TID_COMPUTE, "compute");
        t.thread(0, TID_COMM, "comm");
        t.complete(0, TID_COMPUTE, "compute", "measured", 0.0, 1e-3, vec![
            ("tensor", Json::from(0usize)),
        ]);
        t.complete(0, TID_COMM, "comm", "measured", 5e-4, 2e-3, vec![]);
        t.instant(0, TID_COMM, "barrier_wait", 2e-3, vec![("wait_s", Json::from(1e-4))]);
        t.counter(0, "wire_bytes", 2e-3, &[("intra", 100.0), ("inter", 50.0)]);
        t.end_step();
        t.complete(0, TID_COMPUTE, "compute", "measured", 0.0, 1e-3, vec![]);
        t.counter(0, "wire_bytes", 1e-3, &[("intra", 10.0), ("inter", 0.0)]);
        t.end_step();
        let doc = t.to_json();
        validate_trace(&doc).unwrap();
        // writer output parses back to the same document
        let back = Json::parse(&doc.to_string()).unwrap();
        validate_trace(&back).unwrap();
        assert!(t.len() >= 7);
    }

    #[test]
    fn steps_do_not_overlap_on_the_global_clock() {
        let mut t = TraceBuilder::new();
        // Same [0, 1ms] window in two consecutive steps, same tid: only
        // legal because end_step() advances the cursor.
        t.complete(0, TID_COMPUTE, "compute", "measured", 0.0, 1e-3, vec![]);
        t.end_step();
        t.complete(0, TID_COMPUTE, "compute", "measured", 0.0, 1e-3, vec![]);
        t.end_step();
        validate_trace(&t.to_json()).unwrap();
    }

    #[test]
    fn negative_duration_clamps_to_zero() {
        let mut t = TraceBuilder::new();
        t.complete(0, TID_COMPUTE, "compute", "measured", 2e-3, 1e-3, vec![]);
        let doc = t.to_json();
        validate_trace(&doc).unwrap();
        let ev = &doc.get("traceEvents").unwrap().as_arr().unwrap()[0];
        assert_eq!(ev.get("dur").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn validator_rejects_overlap() {
        let mk = |ts: f64, dur: f64| {
            Json::obj(vec![
                ("ph", Json::from("X")),
                ("name", Json::from("compute")),
                ("pid", Json::from(0usize)),
                ("tid", Json::from(0usize)),
                ("ts", Json::from(ts)),
                ("dur", Json::from(dur)),
                ("args", Json::obj(vec![])),
            ])
        };
        let doc = Json::obj(vec![(
            "traceEvents",
            Json::Arr(vec![mk(0.0, 100.0), mk(50.0, 100.0)]),
        )]);
        let err = validate_trace(&doc).unwrap_err().to_string();
        assert!(err.contains("overlapping"), "got: {err}");
    }

    #[test]
    fn validator_rejects_decreasing_wire_bytes() {
        let mk = |ts: f64, v: f64| {
            Json::obj(vec![
                ("ph", Json::from("C")),
                ("name", Json::from("wire_bytes")),
                ("pid", Json::from(0usize)),
                ("tid", Json::from(0usize)),
                ("ts", Json::from(ts)),
                ("args", Json::obj(vec![("intra", Json::Num(v))])),
            ])
        };
        let doc =
            Json::obj(vec![("traceEvents", Json::Arr(vec![mk(0.0, 100.0), mk(1.0, 90.0)]))]);
        let err = validate_trace(&doc).unwrap_err().to_string();
        assert!(err.contains("decreased"), "got: {err}");
    }

    #[test]
    fn validator_rejects_missing_fields_and_bad_phase() {
        let no_ts = Json::obj(vec![
            ("ph", Json::from("X")),
            ("name", Json::from("x")),
            ("pid", Json::from(0usize)),
            ("tid", Json::from(0usize)),
            ("dur", Json::from(1.0)),
        ]);
        let doc = Json::obj(vec![("traceEvents", Json::Arr(vec![no_ts]))]);
        assert!(validate_trace(&doc).is_err());
        let bad_ph = Json::obj(vec![
            ("ph", Json::from("Q")),
            ("name", Json::from("x")),
            ("pid", Json::from(0usize)),
            ("tid", Json::from(0usize)),
            ("ts", Json::from(0.0)),
        ]);
        let doc = Json::obj(vec![("traceEvents", Json::Arr(vec![bad_ph]))]);
        assert!(validate_trace(&doc).is_err());
    }

    #[test]
    fn metadata_emitted_once_per_target() {
        let mut t = TraceBuilder::new();
        t.process(3, "rank 3");
        t.process(3, "rank 3");
        t.thread(3, 0, "compute");
        t.thread(3, 0, "compute");
        assert_eq!(t.len(), 2);
        validate_trace(&t.to_json()).unwrap();
    }
}
