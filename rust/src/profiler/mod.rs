//! §III.B — the distributed profiler.
//!
//! Measures per-worker computation and communication durations for one
//! training iteration and computes the CCR that drives COVAP's interval
//! selection. The naive per-process measurement inflates communication on
//! fast workers: a worker finishing its computation early blocks in the
//! collective waiting for stragglers, so its "communication" interval
//! includes rendezvous wait (the paper observed up to 20% error).
//!
//! The fix (Fig. 3): align the timelines at the *end* of each communication
//! operator — all ranks leave a collective together — and take the true
//! transfer time as `end - max_w(start_w)`: the interval during which every
//! rank was actually inside the collective.

use std::collections::BTreeMap;

/// One timed operator on a worker's stream.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub worker: usize,
    pub kind: EventKind,
    /// Operator sequence id — communication ops with the same id are the
    /// same collective across workers.
    pub op: usize,
    pub start_s: f64,
    pub end_s: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Compute,
    Comm,
}

impl Event {
    pub fn duration(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// Per-iteration profile of a whole worker group.
#[derive(Debug, Default, Clone)]
pub struct Profile {
    events: Vec<Event>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CcrReport {
    /// Mean per-worker computation time (sum of compute ops).
    pub comp_s: f64,
    /// Naive communication time (includes rendezvous wait) — what a
    /// single-process profiler would report.
    pub naive_comm_s: f64,
    /// Skew-corrected communication time (timeline-aligned).
    pub aligned_comm_s: f64,
    pub naive_ccr: f64,
    pub ccr: f64,
}

impl Profile {
    pub fn new() -> Profile {
        Profile::default()
    }

    pub fn record(&mut self, e: Event) {
        assert!(e.end_s >= e.start_s, "negative duration");
        self.events.push(e);
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    fn workers(&self) -> usize {
        self.events.iter().map(|e| e.worker + 1).max().unwrap_or(0)
    }

    /// CCR per the distributed-profiler algorithm.
    pub fn ccr(&self) -> CcrReport {
        let nw = self.workers().max(1);

        // computation: mean over workers of total compute time
        let mut comp = vec![0.0f64; nw];
        for e in self.events.iter().filter(|e| e.kind == EventKind::Compute) {
            comp[e.worker] += e.duration();
        }
        let comp_s = comp.iter().sum::<f64>() / nw as f64;

        // communication: group by op id
        let mut by_op: BTreeMap<usize, Vec<&Event>> = BTreeMap::new();
        for e in self.events.iter().filter(|e| e.kind == EventKind::Comm) {
            by_op.entry(e.op).or_default().push(e);
        }
        let mut naive = 0.0f64;
        let mut aligned = 0.0f64;
        for (_op, evs) in &by_op {
            // naive: average of per-worker durations (incl. waiting)
            naive += evs.iter().map(|e| e.duration()).sum::<f64>() / evs.len() as f64;
            // aligned: the collective really runs only once every rank has
            // arrived; all ranks finish together.
            let last_start = evs.iter().map(|e| e.start_s).fold(f64::MIN, f64::max);
            let end = evs.iter().map(|e| e.end_s).fold(f64::MIN, f64::max);
            aligned += (end - last_start).max(0.0);
        }
        CcrReport {
            comp_s,
            naive_comm_s: naive,
            aligned_comm_s: aligned,
            naive_ccr: if comp_s > 0.0 { naive / comp_s } else { f64::NAN },
            ccr: if comp_s > 0.0 { aligned / comp_s } else { f64::NAN },
        }
    }
}

/// Build a synthetic skewed profile: `nw` workers, per-op true comm time
/// `comm_s`, per-worker compute `comp_s` jittered by ±`skew` (fraction).
/// Used by tests and the profile_ccr example to show the naive-vs-aligned
/// gap the paper describes.
pub fn synthetic_profile(
    nw: usize,
    ops: usize,
    comp_s: f64,
    comm_s: f64,
    skew: f64,
    seed: u64,
) -> Profile {
    use crate::util::rng::Rng;
    let mut rng = Rng::seed(seed);
    let mut p = Profile::new();
    let mut clock = vec![0.0f64; nw];
    for op in 0..ops {
        // compute phase (jittered per worker)
        let mut ends = vec![0.0; nw];
        for w in 0..nw {
            let jitter = 1.0 + skew * (2.0 * rng.next_f64() - 1.0);
            let d = comp_s / ops as f64 * jitter;
            p.record(Event {
                worker: w,
                kind: EventKind::Compute,
                op,
                start_s: clock[w],
                end_s: clock[w] + d,
            });
            clock[w] += d;
            ends[w] = clock[w];
        }
        // collective: starts per-worker at its arrival, ends for everyone
        // once the slowest arrived + transfer time
        let last = ends.iter().copied().fold(f64::MIN, f64::max);
        let end = last + comm_s / ops as f64;
        for w in 0..nw {
            p.record(Event {
                worker: w,
                kind: EventKind::Comm,
                op,
                start_s: ends[w],
                end_s: end,
            });
            clock[w] = end;
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_skew_naive_equals_aligned() {
        let p = synthetic_profile(4, 8, 0.1, 0.2, 0.0, 1);
        let r = p.ccr();
        assert!((r.naive_comm_s - r.aligned_comm_s).abs() < 1e-9);
        assert!((r.ccr - 2.0).abs() < 1e-6, "ccr={}", r.ccr);
    }

    #[test]
    fn skew_inflates_naive_only() {
        let p = synthetic_profile(8, 16, 0.1, 0.2, 0.5, 2);
        let r = p.ccr();
        assert!(
            r.naive_comm_s > r.aligned_comm_s * 1.05,
            "naive {} vs aligned {}",
            r.naive_comm_s,
            r.aligned_comm_s
        );
        // aligned recovers the true comm time
        assert!((r.aligned_comm_s - 0.2).abs() < 0.02, "{}", r.aligned_comm_s);
    }

    #[test]
    fn paper_20pct_error_scenario() {
        // With moderate skew the naive measurement overshoots by ~the skew
        // magnitude; the aligned one stays within a few percent.
        let p = synthetic_profile(8, 10, 0.2, 0.2, 0.4, 3);
        let r = p.ccr();
        let naive_err = (r.naive_comm_s - 0.2_f64).abs() / 0.2;
        let aligned_err = (r.aligned_comm_s - 0.2_f64).abs() / 0.2;
        assert!(naive_err > 0.08, "naive error {naive_err}");
        assert!(aligned_err < 0.05, "aligned error {aligned_err}");
    }

    #[test]
    fn single_worker_degenerate() {
        let p = synthetic_profile(1, 4, 0.1, 0.3, 0.0, 4);
        let r = p.ccr();
        assert!((r.ccr - 3.0).abs() < 1e-6);
        assert!((r.naive_ccr - 3.0).abs() < 1e-6);
    }

    #[test]
    fn empty_profile_is_nan() {
        let r = Profile::new().ccr();
        assert!(r.ccr.is_nan());
    }
}
