//! §III.B — the distributed profiler.
//!
//! Measures per-worker computation and communication durations for one
//! training iteration and computes the CCR that drives COVAP's interval
//! selection. The naive per-process measurement inflates communication on
//! fast workers: a worker finishing its computation early blocks in the
//! collective waiting for stragglers, so its "communication" interval
//! includes rendezvous wait (the paper observed up to 20% error).
//!
//! The fix (Fig. 3): align the timelines at the *end* of each communication
//! operator — all ranks leave a collective together — and take the true
//! transfer time as `end - max_w(start_w)`: the interval during which every
//! rank was actually inside the collective.
//!
//! Collectives are identified by `(step, op)`: op ids are local to a
//! training step, so profiles spanning many steps (the adaptive
//! controller's windows) can reuse per-tensor op ids without aliasing even
//! when the tensor count changes mid-profile (an interval re-shard). The
//! world size can be given explicitly ([`Profile::for_world`]) — worker ids
//! may then be sparse or gapped; when it is inferred, the profiler counts
//! *distinct* worker ids rather than assuming a dense `0..=max` range.

use std::collections::BTreeMap;

/// One timed operator on a worker's stream.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub worker: usize,
    pub kind: EventKind,
    /// Training step this operator belongs to.
    pub step: u64,
    /// Operator sequence id within the step — communication ops with the
    /// same `(step, op)` are the same collective across workers.
    pub op: usize,
    pub start_s: f64,
    pub end_s: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Compute,
    Comm,
}

impl Event {
    pub fn duration(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// Per-iteration profile of a whole worker group.
#[derive(Debug, Default, Clone)]
pub struct Profile {
    events: Vec<Event>,
    /// Explicit world size; `None` = count distinct worker ids.
    world: Option<usize>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CcrReport {
    /// Mean per-worker computation time (sum of compute ops).
    pub comp_s: f64,
    /// Naive communication time (includes rendezvous wait) — what a
    /// single-process profiler would report.
    pub naive_comm_s: f64,
    /// Skew-corrected communication time (timeline-aligned).
    pub aligned_comm_s: f64,
    pub naive_ccr: f64,
    pub ccr: f64,
}

impl Profile {
    pub fn new() -> Profile {
        Profile::default()
    }

    /// A profile with an explicit world size. Worker ids may be sparse or
    /// gapped (e.g. only the stragglers of a large fleet report); the
    /// per-worker mean still divides by the true world size instead of a
    /// guess derived from the largest id seen.
    pub fn for_world(world: usize) -> Profile {
        Profile { events: Vec::new(), world: Some(world) }
    }

    pub fn record(&mut self, e: Event) {
        assert!(e.end_s >= e.start_s, "negative duration");
        self.events.push(e);
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Drop all recorded events, keeping the world-size configuration
    /// (window rollover in the adaptive controller).
    pub fn clear(&mut self) {
        self.events.clear();
    }

    fn workers(&self) -> usize {
        match self.world {
            Some(w) => w,
            None => {
                // Count distinct worker ids: a gapped id set (worker 7
                // without workers 1..=6) must not inflate the denominator.
                let mut ids: Vec<usize> = self.events.iter().map(|e| e.worker).collect();
                ids.sort_unstable();
                ids.dedup();
                ids.len()
            }
        }
    }

    /// CCR per the distributed-profiler algorithm.
    pub fn ccr(&self) -> CcrReport {
        let nw = self.workers().max(1);

        // computation: mean over workers of total compute time
        let mut comp: BTreeMap<usize, f64> = BTreeMap::new();
        for e in self.events.iter().filter(|e| e.kind == EventKind::Compute) {
            *comp.entry(e.worker).or_insert(0.0) += e.duration();
        }
        let comp_s = comp.values().sum::<f64>() / nw as f64;

        // communication: group collectives by (step, op)
        let mut by_op: BTreeMap<(u64, usize), Vec<&Event>> = BTreeMap::new();
        for e in self.events.iter().filter(|e| e.kind == EventKind::Comm) {
            by_op.entry((e.step, e.op)).or_default().push(e);
        }
        let mut naive = 0.0f64;
        let mut aligned = 0.0f64;
        for evs in by_op.values() {
            // naive: average of per-worker durations (incl. waiting)
            naive += evs.iter().map(|e| e.duration()).sum::<f64>() / evs.len() as f64;
            // aligned: the collective really runs only once every rank has
            // arrived; all ranks finish together.
            let last_start = evs.iter().map(|e| e.start_s).fold(f64::MIN, f64::max);
            let end = evs.iter().map(|e| e.end_s).fold(f64::MIN, f64::max);
            aligned += (end - last_start).max(0.0);
        }
        CcrReport {
            comp_s,
            naive_comm_s: naive,
            aligned_comm_s: aligned,
            naive_ccr: if comp_s > 0.0 { naive / comp_s } else { f64::NAN },
            ccr: if comp_s > 0.0 { aligned / comp_s } else { f64::NAN },
        }
    }
}

/// Build a synthetic skewed profile: `nw` workers, per-op true comm time
/// `comm_s`, per-worker compute `comp_s` jittered by ±`skew` (fraction).
/// Used by tests and the profile_ccr example to show the naive-vs-aligned
/// gap the paper describes.
pub fn synthetic_profile(
    nw: usize,
    ops: usize,
    comp_s: f64,
    comm_s: f64,
    skew: f64,
    seed: u64,
) -> Profile {
    use crate::util::rng::Rng;
    let mut rng = Rng::seed(seed);
    let mut p = Profile::new();
    let mut clock = vec![0.0f64; nw];
    for op in 0..ops {
        // compute phase (jittered per worker)
        let mut ends = vec![0.0; nw];
        for w in 0..nw {
            let jitter = 1.0 + skew * (2.0 * rng.next_f64() - 1.0);
            let d = comp_s / ops as f64 * jitter;
            p.record(Event {
                worker: w,
                kind: EventKind::Compute,
                step: 0,
                op,
                start_s: clock[w],
                end_s: clock[w] + d,
            });
            clock[w] += d;
            ends[w] = clock[w];
        }
        // collective: starts per-worker at its arrival, ends for everyone
        // once the slowest arrived + transfer time
        let last = ends.iter().copied().fold(f64::MIN, f64::max);
        let end = last + comm_s / ops as f64;
        for w in 0..nw {
            p.record(Event {
                worker: w,
                kind: EventKind::Comm,
                step: 0,
                op,
                start_s: ends[w],
                end_s: end,
            });
            clock[w] = end;
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn no_skew_naive_equals_aligned() {
        let p = synthetic_profile(4, 8, 0.1, 0.2, 0.0, 1);
        let r = p.ccr();
        assert!((r.naive_comm_s - r.aligned_comm_s).abs() < 1e-9);
        assert!((r.ccr - 2.0).abs() < 1e-6, "ccr={}", r.ccr);
    }

    #[test]
    fn skew_inflates_naive_only() {
        let p = synthetic_profile(8, 16, 0.1, 0.2, 0.5, 2);
        let r = p.ccr();
        assert!(
            r.naive_comm_s > r.aligned_comm_s * 1.05,
            "naive {} vs aligned {}",
            r.naive_comm_s,
            r.aligned_comm_s
        );
        // aligned recovers the true comm time
        assert!((r.aligned_comm_s - 0.2).abs() < 0.02, "{}", r.aligned_comm_s);
    }

    #[test]
    fn paper_20pct_error_scenario() {
        // With moderate skew the naive measurement overshoots by ~the skew
        // magnitude; the aligned one stays within a few percent.
        let p = synthetic_profile(8, 10, 0.2, 0.2, 0.4, 3);
        let r = p.ccr();
        let naive_err = (r.naive_comm_s - 0.2_f64).abs() / 0.2;
        let aligned_err = (r.aligned_comm_s - 0.2_f64).abs() / 0.2;
        assert!(naive_err > 0.08, "naive error {naive_err}");
        assert!(aligned_err < 0.05, "aligned error {aligned_err}");
    }

    #[test]
    fn single_worker_degenerate() {
        let p = synthetic_profile(1, 4, 0.1, 0.3, 0.0, 4);
        let r = p.ccr();
        assert!((r.ccr - 3.0).abs() < 1e-6);
        assert!((r.naive_ccr - 3.0).abs() < 1e-6);
    }

    #[test]
    fn empty_profile_is_nan() {
        let r = Profile::new().ccr();
        assert!(r.ccr.is_nan());
    }

    /// Remap a profile's worker ids through a strictly increasing gapped
    /// mapping (0 -> gaps[0], 1 -> gaps[1], ...).
    fn relabel(p: &Profile, gaps: &[usize]) -> Profile {
        let mut out = Profile::new();
        for e in p.events() {
            let mut e = e.clone();
            e.worker = gaps[e.worker];
            out.record(e);
        }
        out
    }

    /// Satellite (workers() audit): the CCR must be invariant under worker
    /// relabeling — gapped/sparse worker ids may not inflate the per-worker
    /// mean. The old `max id + 1` inference divided an 8-worker gap set's
    /// compute by 8 instead of 2.
    #[test]
    fn gapped_worker_ids_do_not_inflate_ccr() {
        prop::check("profiler-gapped-ids", 0x6A99ED, 40, |rng: &mut Rng| {
            let nw = 1 + rng.below(6);
            let p = synthetic_profile(nw, 4, 0.1, 0.2, 0.3, rng.next_u64());
            // strictly increasing gapped ids: cumulative positive offsets
            let mut gaps = Vec::with_capacity(nw);
            let mut id = 0usize;
            for _ in 0..nw {
                id += 1 + rng.below(5);
                gaps.push(id);
            }
            let dense = p.ccr();
            let sparse = relabel(&p, &gaps).ccr();
            assert_eq!(dense, sparse, "relabeling {gaps:?} changed the report");
        });
    }

    /// An explicit world size wins over inference: with only one worker
    /// reporting out of 4, the mean compute divides by 4.
    #[test]
    fn explicit_world_size_divides_the_mean() {
        let mut p = Profile::for_world(4);
        p.record(Event {
            worker: 2,
            kind: EventKind::Compute,
            step: 0,
            op: 0,
            start_s: 0.0,
            end_s: 2.0,
        });
        p.record(Event {
            worker: 2,
            kind: EventKind::Comm,
            step: 0,
            op: 0,
            start_s: 2.0,
            end_s: 3.0,
        });
        let r = p.ccr();
        assert!((r.comp_s - 0.5).abs() < 1e-12, "2.0 / world 4 = 0.5, got {}", r.comp_s);
        assert!((r.ccr - 2.0).abs() < 1e-12);
    }

    /// Satellite (op-collision audit): the same per-tensor op id used on
    /// two different steps is two collectives, not one. Keyed only by op,
    /// the aligned window would span both steps and swallow the compute
    /// time between them.
    #[test]
    fn same_op_id_across_steps_does_not_alias() {
        let mut p = Profile::for_world(1);
        for step in 0..2u64 {
            let base = step as f64 * 10.0;
            p.record(Event {
                worker: 0,
                kind: EventKind::Compute,
                step,
                op: 0,
                start_s: base,
                end_s: base + 4.0,
            });
            p.record(Event {
                worker: 0,
                kind: EventKind::Comm,
                step,
                op: 0, // identical op id on both steps (tensor 0)
                start_s: base + 4.0,
                end_s: base + 5.0,
            });
        }
        let r = p.ccr();
        // two 1 s collectives, not one [4, 15] monster window
        assert!((r.aligned_comm_s - 2.0).abs() < 1e-12, "{}", r.aligned_comm_s);
        assert!((r.comp_s - 8.0).abs() < 1e-12);
    }
}
