//! A compiled PJRT executable with tuple-output unwrapping.

use anyhow::{Context, Result};

/// One compiled HLO module. All aot.py artifacts are lowered with
/// `return_tuple=True`, so execution yields a single tuple literal which
/// `run` decomposes into per-output literals.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    pub(crate) fn new(name: String, exe: xla::PjRtLoadedExecutable) -> Executable {
        Executable { name, exe }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.name))?;
        Ok(tuple.to_tuple()?)
    }
}
